"""Model-level tests: prefill/decode vs full-forward consistency, DMS
mask effects, Quest selection, and shape contracts of the AOT surface."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.model import (
    Config,
    decode_step,
    forward_train,
    init_params,
    prefill_chunk,
)

CFG = Config()
L, HKV, HD, PS = CFG.n_layers, CFG.n_kv_heads, CFG.head_dim, CFG.page_size


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, 0)


def _empty_cache(b, s):
    kc = jnp.zeros((L, b, HKV, s, HD))
    vc = jnp.zeros((L, b, HKV, s, HD))
    mask = jnp.full((L, b, HKV, s), -1e9)
    return kc, vc, mask


def test_incremental_decode_matches_full_forward(params):
    toks = np.array([[1, 5, 9, 12, 33, 7, 21, 40, 11, 3, 2, 17]], np.int32)
    b, t = toks.shape
    val = np.ones((b, t), np.float32)
    ref, _ = forward_train(
        params, CFG, jnp.asarray(toks), jnp.asarray(val),
        alpha_mode="off", q_first_scale=0.0,
    )
    ref = np.asarray(ref)

    s, c = 64, 8
    kc, vc, mask = _empty_cache(b, s)
    pos = jnp.arange(c, dtype=jnp.int32)[None, :]
    lg, kn, vn, _ = prefill_chunk(
        params, CFG, kc, vc, mask, jnp.asarray(toks[:, :c]), pos,
        jnp.ones((b, c), jnp.float32), window=16, dms_enabled=False,
        use_pallas=True,
    )
    np.testing.assert_allclose(np.asarray(lg), ref[:, :c], rtol=2e-4, atol=2e-4)

    kc = kc.at[:, :, :, :c, :].set(kn)
    vc = vc.at[:, :, :, :c, :].set(vn)
    mask = mask.at[:, :, :, :c].set(0.0)
    p = s // PS
    pmin = jnp.zeros((L, b, HKV, p, HD))
    pmax = jnp.zeros((L, b, HKV, p, HD))
    qk = jnp.asarray(p, jnp.int32)
    for t_i in range(c, t):
        lg2, kn2, vn2, _, _, _, _ = decode_step(
            params, CFG, kc, vc, jnp.asarray(toks[:, t_i]),
            jnp.asarray([t_i], jnp.int32), mask, pmin, pmax, qk,
            use_pallas=True,
        )
        np.testing.assert_allclose(
            np.asarray(lg2), ref[:, t_i], rtol=2e-4, atol=2e-4
        )
        kc = kc.at[:, :, :, t_i, :].set(kn2)
        vc = vc.at[:, :, :, t_i, :].set(vn2)
        mask = mask.at[:, :, :, t_i].set(0.0)


def test_decode_output_shapes(params):
    b, s = 2, 32
    kc, vc, mask = _empty_cache(b, s)
    mask = mask.at[:, :, :, 0].set(0.0)
    p = s // PS
    outs = decode_step(
        params, CFG, kc, vc,
        jnp.asarray([3, 4], jnp.int32), jnp.asarray([1, 1], jnp.int32),
        mask, jnp.zeros((L, b, HKV, p, HD)), jnp.zeros((L, b, HKV, p, HD)),
        jnp.asarray(p, jnp.int32), use_pallas=False,
    )
    logits, k_new, v_new, alpha, attn, attn_self, qsel = outs
    assert logits.shape == (b, CFG.vocab)
    assert k_new.shape == (L, b, HKV, HD)
    assert alpha.shape == (L, b, HKV)
    assert attn.shape == (L, b, HKV, s)
    assert attn_self.shape == (L, b, HKV)
    assert qsel.shape == (L, b, HKV, p)
    assert np.isfinite(np.asarray(logits)).all()
    assert (np.asarray(alpha) >= 0).all() and (np.asarray(alpha) <= 1).all()


def test_masked_slots_do_not_influence_logits(params):
    """Evicted (masked) cache content must be invisible."""
    b, s = 1, 32
    kc, vc, mask = _empty_cache(b, s)
    rng = np.random.default_rng(0)
    # fill slots 0..3 live, slot 4 dead with huge garbage
    for slot in range(4):
        kc = kc.at[:, :, :, slot, :].set(
            jnp.asarray(rng.normal(size=(L, b, HKV, HD)), jnp.float32)
        )
        mask = mask.at[:, :, :, slot].set(0.0)
    p = s // PS
    pmin = jnp.zeros((L, b, HKV, p, HD))
    pmax = jnp.zeros((L, b, HKV, p, HD))
    qk = jnp.asarray(p, jnp.int32)
    args = (jnp.asarray([5], jnp.int32), jnp.asarray([4], jnp.int32), mask,
            pmin, pmax, qk)
    lg1 = decode_step(params, CFG, kc, vc, *args, use_pallas=False)[0]
    kc_garbage = kc.at[:, :, :, 4, :].set(1e3)
    vc_garbage = vc.at[:, :, :, 4, :].set(1e3)
    lg2 = decode_step(params, CFG, kc_garbage, vc_garbage, *args,
                      use_pallas=False)[0]
    np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg2), rtol=1e-6)


def test_quest_k_full_equals_disabled(params):
    """quest_k = P must reproduce unrestricted attention."""
    b, s = 1, 32
    kc, vc, mask = _empty_cache(b, s)
    rng = np.random.default_rng(1)
    for slot in range(10):
        kc = kc.at[:, :, :, slot, :].set(
            jnp.asarray(rng.normal(size=(L, b, HKV, HD)), jnp.float32)
        )
        vc = vc.at[:, :, :, slot, :].set(
            jnp.asarray(rng.normal(size=(L, b, HKV, HD)), jnp.float32)
        )
        mask = mask.at[:, :, :, slot].set(0.0)
    p = s // PS
    # realistic page bounds from the keys
    kk = np.asarray(kc).reshape(L, b, HKV, p, PS, HD)
    pmin = jnp.asarray(kk.min(axis=4))
    pmax = jnp.asarray(kk.max(axis=4))
    toks = jnp.asarray([5], jnp.int32)
    pos = jnp.asarray([10], jnp.int32)
    lg_full = decode_step(params, CFG, kc, vc, toks, pos, mask, pmin, pmax,
                          jnp.asarray(p, jnp.int32), use_pallas=False)[0]
    lg_k1 = decode_step(params, CFG, kc, vc, toks, pos, mask, pmin, pmax,
                        jnp.asarray(1, jnp.int32), use_pallas=False)
    # with k=1 only one page of the ten live slots is readable
    qsel = np.asarray(lg_k1[6])
    live_pages_selected = qsel.sum(axis=-1)
    assert (live_pages_selected <= 1.0 + 1e-6).all()
    assert np.isfinite(np.asarray(lg_k1[0])).all()
    assert np.isfinite(np.asarray(lg_full)).all()


def test_prefill_dms_alpha_is_binary_and_padded(params):
    b, s, c = 1, 32, 8
    kc, vc, mask = _empty_cache(b, s)
    toks = jnp.asarray(np.full((b, c), 5, np.int32))
    pos = jnp.arange(c, dtype=jnp.int32)[None, :]
    valid = jnp.asarray([[1, 1, 1, 1, 1, 0, 0, 0]], jnp.float32)
    _, _, _, alpha = prefill_chunk(
        params, CFG, kc, vc, mask, toks, pos, valid,
        window=4, dms_enabled=True, use_pallas=False,
    )
    a = np.asarray(alpha)
    assert set(np.unique(a)).issubset({0.0, 1.0})
    assert (a[:, :, :, 5:] == 0).all(), "padding must have α = 0"


def test_forward_train_dms_mask_changes_output(params):
    toks = jnp.asarray(np.full((1, 24), 7, np.int32))
    val = jnp.ones((1, 24))
    lg_off, _ = forward_train(params, CFG, toks, val, alpha_mode="off",
                              q_first_scale=0.0)
    # force α high by biasing: use stochastic key with strong logits is
    # impractical here; instead verify dms mode runs and yields finite
    # outputs plus α in [0,1]
    lg_dms, alphas = forward_train(
        params, CFG, toks, val, alpha_mode="dms", window=4,
        gumbel_key=jax.random.PRNGKey(0), q_first_scale=0.0,
    )
    assert np.isfinite(np.asarray(lg_dms)).all()
    a = np.asarray(alphas)
    assert (a >= 0).all() and (a <= 1).all()
    assert lg_off.shape == lg_dms.shape
