"""Task generator and tokenizer tests (the cross-language contract)."""

import pytest

from compile import tasks


def test_vocab_is_64_unique_symbols():
    assert len(tasks.VOCAB) == 64
    assert len(set(tasks.VOCAB)) == 64


def test_encode_decode_roundtrip():
    text = "Q:7+5-3*4=? A:4 B:9\nT:PUSH 3|MUL key u=7."
    assert tasks.decode(tasks.encode(text)) == text


def test_encode_rejects_oov():
    with pytest.raises(KeyError):
        tasks.encode("hello!")


def test_splitmix64_known_stream():
    r = tasks.SplitMix64(0)
    assert r.next_u64() == 0xE220A8397B1DCDAF
    assert r.next_u64() == 0x6E789E6AA1B965F4


@pytest.mark.parametrize("suite", sorted(tasks.SUITES))
def test_all_suites_generate_valid_problems(suite):
    for i in range(5):
        p = tasks.gen_problem(suite, 3, i)
        tasks.encode(p.full_text())  # in-vocab
        assert p.prompt.startswith("Q:")
        assert p.prompt.endswith("T:")
        assert tasks.extract_answer(p.solution) == p.answer


def test_gen_problem_deterministic():
    a = tasks.gen_problem("aime", 9, 4)
    b = tasks.gen_problem("aime", 9, 4)
    assert a.prompt == b.prompt and a.solution == b.solution


def test_arith_chain_is_correct():
    rng = tasks.SplitMix64(5)
    p = tasks.gen_arith(rng, 6)
    # replay the trace: every step must be consistent mod 10
    steps = p.solution.split(" A:")[0].split(" ")
    acc = None
    for s in steps:
        lhs, res = s.split("=")
        if acc is not None:
            assert int(lhs[0]) == acc, s
        a, op, b = int(lhs[0]), lhs[1], int(lhs[2])
        acc = tasks._apply(op, a, b)
        assert acc == int(res), s
    assert str(acc) == p.answer


def test_mcq_letter_is_correct_option():
    for i in range(10):
        p = tasks.gen_problem("gpqa", 2, i)
        # find the option with the letter
        opts = p.prompt.split("=? ")[1].split("\nT:")[0].split(" ")
        mapping = dict(o.split(":") for o in opts)
        # recompute the chain value from the trace's last step
        last = p.solution.split(" A:")[0].split(" ")[-1]
        assert mapping[p.answer] == last.split("=")[1]


def test_code_trace_matches_stack_machine():
    for i in range(10):
        p = tasks.gen_problem("lcb", 4, i)
        instrs = p.prompt[2:].split("\nT:")[0].split("|")
        stack = []
        for ins in instrs:
            if ins.startswith("PUSH"):
                stack.append(int(ins.split()[1]))
            else:
                b, a = stack.pop(), stack.pop()
                stack.append(
                    {"ADD": (a + b), "MUL": (a * b), "SUB": (a - b)}[ins] % 10
                )
        assert str(stack[-1]) == p.answer


def test_vt_answer_tracks_chain():
    for i in range(10):
        p = tasks.gen_problem("vt", 8, i)
        stmts = p.prompt[2:].split("\nT:")[0]
        target = stmts.split("?")[1].strip()
        env = {}
        for stmt in stmts.split("?")[0].split(". "):
            stmt = stmt.strip().rstrip(".")
            if not stmt:
                continue
            k, v = stmt.split("=")
            env[k] = env[v] if v in env else int(v)
        assert str(env[target]) == p.answer


def test_niah_prompt_sizes_scale_with_fillers():
    r1 = tasks.SplitMix64(1)
    r2 = tasks.SplitMix64(1)
    small = tasks.gen_niah(r1, 3)
    large = tasks.gen_niah(r2, 12)
    assert len(large.prompt) > len(small.prompt)


def test_extract_answer_edge_cases():
    assert tasks.extract_answer("no marker") is None
    assert tasks.extract_answer("A:") is None
    assert tasks.extract_answer("x A:4 B:9 ... A:B\n") == "B"
