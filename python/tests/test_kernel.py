"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes and mask densities; assert_allclose against
ref.py. Kernels run under interpret=True (CPU)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import attention as A
from compile.kernels import ref as R

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


def _mask(rng, *shape, density=0.3):
    m = np.where(rng.random(shape) < density, R.NEG_INF, 0.0).astype(np.float32)
    return jnp.asarray(m)


@given(
    b=st.integers(1, 3),
    h=st.integers(1, 3),
    g=st.integers(1, 4),
    s=st.integers(1, 40),
    hd=st.sampled_from([4, 8, 16]),
    density=st.floats(0.0, 0.8),
    seed=st.integers(0, 2**16),
)
def test_decode_attn_matches_ref(b, h, g, s, hd, density, seed):
    rng = np.random.default_rng(seed)
    q = _rand(rng, b, h, g, hd)
    k = _rand(rng, b, h, s, hd)
    v = _rand(rng, b, h, s, hd)
    mask = _mask(rng, b, h, s, density=density)
    # guarantee at least one visible slot per row
    mask = mask.at[..., 0].set(0.0)
    o1, a1 = A.decode_attn(q, k, v, mask)
    o2, a2 = R.decode_attn_ref(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2), rtol=1e-5, atol=1e-5)


@given(
    b=st.integers(1, 2),
    h=st.integers(1, 2),
    g=st.integers(1, 4),
    c=st.integers(1, 12),
    t_extra=st.integers(0, 24),
    hd=st.sampled_from([4, 16]),
    seed=st.integers(0, 2**16),
)
def test_chunk_attn_matches_ref(b, h, g, c, t_extra, hd, seed):
    rng = np.random.default_rng(seed)
    t = c + t_extra
    q = _rand(rng, b, h, g, c, hd)
    k = _rand(rng, b, h, t, hd)
    v = _rand(rng, b, h, t, hd)
    mask = _mask(rng, b, h, c, t, density=0.3)
    mask = mask.at[..., 0].set(0.0)
    o1 = A.chunk_attn(q, k, v, mask)
    o2 = R.chunk_attn_ref(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-5, atol=1e-5)


def test_decode_attn_fully_masked_rows_prefer_self():
    """Typical engine state: all cache slots dead + live self token."""
    rng = np.random.default_rng(0)
    q = _rand(rng, 1, 1, 2, 8)
    k = _rand(rng, 1, 1, 5, 8)
    v = _rand(rng, 1, 1, 5, 8)
    mask = jnp.full((1, 1, 5), R.NEG_INF).at[..., 4].set(0.0)  # only "self"
    out, attn = A.decode_attn(q, k, v, mask)
    # all attention mass on the only visible slot (2 group heads)
    np.testing.assert_allclose(np.asarray(attn)[0, 0, 4], 2.0, rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(out)[0, 0, 0], np.asarray(v)[0, 0, 4], rtol=1e-5
    )


def test_attention_is_permutation_invariant_over_slots():
    """Slot order must not matter (paged caches reorder physically)."""
    rng = np.random.default_rng(3)
    q = _rand(rng, 1, 1, 2, 8)
    k = _rand(rng, 1, 1, 6, 8)
    v = _rand(rng, 1, 1, 6, 8)
    mask = jnp.zeros((1, 1, 6))
    o1, _ = A.decode_attn(q, k, v, mask)
    perm = np.array([3, 1, 5, 0, 2, 4])
    o2, _ = A.decode_attn(q, k[:, :, perm], v[:, :, perm], mask)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-5, atol=1e-6)


def test_mask_actually_excludes_tokens():
    rng = np.random.default_rng(4)
    q = _rand(rng, 1, 1, 1, 8)
    k = _rand(rng, 1, 1, 4, 8)
    v = _rand(rng, 1, 1, 4, 8)
    m_all = jnp.zeros((1, 1, 4))
    m_cut = m_all.at[0, 0, 2].set(R.NEG_INF)
    o_all, a_all = A.decode_attn(q, k, v, m_all)
    o_cut, a_cut = A.decode_attn(q, k, v, m_cut)
    assert np.asarray(a_cut)[0, 0, 2] < 1e-12
    assert not np.allclose(np.asarray(o_all), np.asarray(o_cut))


@pytest.mark.parametrize("scale", [1.0, 10.0])
def test_numerical_stability_large_logits(scale):
    rng = np.random.default_rng(5)
    q = _rand(rng, 2, 2, 4, 16)
    k = _rand(rng, 2, 2, 33, 16) * scale
    v = _rand(rng, 2, 2, 33, 16)
    mask = jnp.zeros((2, 2, 33))
    out, attn = A.decode_attn(q, k, v, mask)
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(
        np.asarray(attn).sum(-1), 4.0, rtol=1e-4
    )  # softmax rows sum to G
