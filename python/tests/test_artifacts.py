"""Artifact integrity tests (run after `make artifacts`; skipped when
the artifacts directory hasn't been built yet)."""

import json
import os
import struct

import numpy as np
import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built",
)


@pytest.fixture(scope="module")
def manifest():
    with open(os.path.join(ART, "manifest.json")) as f:
        return json.load(f)


def test_manifest_schema(manifest):
    for key in ("config", "param_order", "vocab", "specials", "variants",
                "executables"):
        assert key in manifest, key
    assert len(manifest["vocab"]) == manifest["config"]["vocab"] == 64
    assert manifest["specials"] == {"pad": 0, "bos": 1, "eos": 2}


def test_all_referenced_files_exist(manifest):
    for tag, v in manifest["variants"].items():
        assert os.path.exists(os.path.join(ART, v["weights"])), tag
    for name, e in manifest["executables"].items():
        assert os.path.exists(os.path.join(ART, "hlo", e["file"])), name


def test_hlo_text_is_parseable_header(manifest):
    for name, e in manifest["executables"].items():
        path = os.path.join(ART, "hlo", e["file"])
        with open(path) as f:
            head = f.read(200)
        assert head.startswith("HloModule"), name


def test_weights_bin_roundtrip(manifest):
    """The .bin format must decode to the same tensors as the npz."""
    tag, v = next(iter(manifest["variants"].items()))
    path = os.path.join(ART, v["weights"])
    with open(path, "rb") as f:
        raw = f.read()
    (hlen,) = struct.unpack("<I", raw[:4])
    header = json.loads(raw[4 : 4 + hlen])
    payload = raw[4 + hlen :]
    names = [t["name"] for t in header["tensors"]]
    assert names == manifest["param_order"]
    total = 0
    for t in header["tensors"]:
        n = int(np.prod(t["shape"]))
        arr = np.frombuffer(
            payload, np.float32, count=n, offset=t["offset"]
        )
        assert np.isfinite(arr).all(), t["name"]
        total += n
    # ~0.57M parameter model
    assert 3e5 < total < 2e6, total


def test_golden_tasks_match_generators():
    """tasks_golden.json pins the generators both languages share."""
    from compile import tasks

    with open(os.path.join(ART, "tasks_golden.json")) as f:
        golden = json.load(f)
    for suite, rows in golden.items():
        for i, row in enumerate(rows):
            p = tasks.gen_problem(suite, 42, i)
            assert p.prompt == row["prompt"], (suite, i)
            assert p.solution == row["solution"], (suite, i)
            assert p.answer == row["answer"], (suite, i)


def test_variant_weights_differ_from_base(manifest):
    """Retrofitted variants must not be byte-identical to base."""
    def load(tag):
        path = os.path.join(ART, manifest["variants"][tag]["weights"])
        with open(path, "rb") as f:
            raw = f.read()
        (hlen,) = struct.unpack("<I", raw[:4])
        return raw[4 + hlen :]

    if "dms_w16_cr4" in manifest["variants"]:
        assert load("base") != load("dms_w16_cr4")
