"""DMS training machinery tests: mask semantics, losses, schedules."""

import numpy as np
import jax
import jax.numpy as jnp

from compile import dms

NEG = dms.NEG_INF


def mk_alpha(vals):
    """[T] -> [1, 1, T]"""
    return jnp.asarray(np.array(vals, np.float32)[None, None, :])


# α is clipped to 1 − 1e-6 in the training relaxation (gradient
# stability), so a "fully evicted" token carries log(1e-6) ≈ −13.8 —
# an attention weight of ~1e-6, i.e. effectively masked.
EFF_MASKED = np.log(1e-6) + 0.1


class TestDelayedMask:
    def test_causality_always_enforced(self):
        m = dms.build_dms_mask(mk_alpha([0, 0, 0, 0]), window=2)
        m = np.asarray(m)[0, 0]
        for i in range(4):
            for j in range(4):
                if j > i:
                    assert m[i, j] <= NEG / 2, (i, j)
                else:
                    assert m[i, j] == 0.0, (i, j)

    def test_evicted_token_visible_within_window(self):
        # α_0 = 1: token 0 must remain visible to queries i < 0 + w
        m = np.asarray(dms.build_dms_mask(mk_alpha([1, 0, 0, 0, 0]), window=3))[0, 0]
        assert m[1, 0] == 0.0
        assert m[2, 0] == 0.0
        assert m[3, 0] <= EFF_MASKED  # i = j + w → evicted
        assert m[4, 0] <= EFF_MASKED

    def test_partial_alpha_partial_mask(self):
        m = np.asarray(dms.build_dms_mask(mk_alpha([0.5, 0, 0]), window=1))[0, 0]
        # log(1 - 0.5) ≈ -0.693 applied beyond the window
        assert abs(m[1, 0] - np.log(0.5)) < 1e-5
        assert m[0, 0] == 0.0

    def test_immediate_uses_future_decision(self):
        # immediate: α_{j+w} hides token j from queries ≥ j+w.
        # α = [0, 0, 1, 0]: with w=2 the decision at t=2 evicts token 0.
        m = np.asarray(
            dms.build_dms_mask(mk_alpha([0, 0, 1, 0]), window=2, immediate=True)
        )[0, 0]
        assert m[2, 0] <= EFF_MASKED
        assert m[3, 0] <= EFF_MASKED
        # token 1's decision index is 3 (α=0) → stays visible
        assert m[3, 1] == 0.0

    def test_delayed_vs_immediate_differ(self):
        a = mk_alpha([1, 0, 0, 0])
        d = np.asarray(dms.build_dms_mask(a, window=2))
        i = np.asarray(dms.build_dms_mask(a, window=2, immediate=True))
        assert not np.allclose(d, i)


class TestDmc:
    def test_accumulate_is_running_average_when_merging(self):
        b, h, t, hd = 1, 1, 3, 2
        k = jnp.ones((b, h, t, hd)) * jnp.asarray([1.0, 2.0, 4.0])[None, None, :, None]
        v = k * 10
        alpha = mk_alpha([0, 1, 1])  # merge tokens 1 and 2 into 0
        ka, va, _ = dms.dmc_accumulate(k, v, alpha)
        ka = np.asarray(ka)[0, 0]
        # t0: 1 ; t1: (1+2)/2 = 1.5 ; t2: (1.5*2+4)/3 = 7/3
        assert abs(ka[0, 0] - 1.0) < 1e-5
        assert abs(ka[1, 0] - 1.5) < 1e-5
        assert abs(ka[2, 0] - 7.0 / 3.0) < 1e-5

    def test_no_merge_passthrough(self):
        rng = np.random.default_rng(0)
        k = jnp.asarray(rng.normal(size=(2, 2, 5, 4)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(2, 2, 5, 4)).astype(np.float32))
        alpha = jnp.zeros((2, 2, 5))
        ka, va, absorb = dms.dmc_accumulate(k, v, alpha)
        np.testing.assert_allclose(np.asarray(ka), np.asarray(k), rtol=1e-5)
        assert np.asarray(absorb).max() == 0.0

    def test_dmc_mask_hides_absorbed(self):
        m = np.asarray(dms.build_dmc_mask(mk_alpha([0, 1, 0])))[0, 0]
        # α_1 = 1 → token 0 hidden for queries ≥ 1
        assert m[1, 0] <= EFF_MASKED
        assert m[2, 0] <= EFF_MASKED
        assert m[0, 0] == 0.0
        assert m[2, 1] == 0.0


class TestLossesAndSchedules:
    def test_aux_loss_one_sided(self):
        alphas = jnp.full((2, 1, 2, 4), 0.6)
        valid = jnp.ones((1, 4))
        # mean α = 0.6 ≥ target 0.5 → no loss
        assert float(dms.aux_compression_loss(alphas, valid, 0.5)) == 0.0
        # target 0.75 → loss 0.15
        assert abs(float(dms.aux_compression_loss(alphas, valid, 0.75)) - 0.15) < 1e-6

    def test_aux_loss_ignores_padding(self):
        alphas = jnp.concatenate(
            [jnp.ones((1, 1, 1, 2)), jnp.zeros((1, 1, 1, 2))], axis=-1
        )
        valid = jnp.asarray([[1.0, 1.0, 0.0, 0.0]])
        # valid positions all have α=1 → mean 1 → no loss even at target 1
        assert float(dms.aux_compression_loss(alphas, valid, 1.0)) == 0.0

    def test_cr_schedule_linear_after_warmup(self):
        assert dms.cr_schedule(0) == 1.0
        assert dms.cr_schedule(100) == 1.0
        assert dms.cr_schedule(200) == 2.0
        assert dms.cr_schedule(800) == 8.0
        assert dms.cr_schedule(5000, cr_max=8.0) == 8.0

    def test_gumbel_sigmoid_bounds_and_determinism(self):
        key = jax.random.PRNGKey(0)
        logits = jnp.asarray([[-5.0, 0.0, 5.0]])
        a1 = dms.gumbel_sigmoid(logits, key)
        a2 = dms.gumbel_sigmoid(logits, key)
        np.testing.assert_allclose(np.asarray(a1), np.asarray(a2))
        assert ((np.asarray(a1) >= 0) & (np.asarray(a1) <= 1)).all()

    def test_gumbel_sigmoid_tracks_logits(self):
        key = jax.random.PRNGKey(1)
        logits = jnp.full((1000,), -5.0)
        lo = float(jnp.mean(dms.gumbel_sigmoid(logits, key)))
        hi = float(jnp.mean(dms.gumbel_sigmoid(logits + 10.0, key)))
        assert lo < 0.1 and hi > 0.9
