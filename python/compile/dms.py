"""Dynamic Memory Sparsification — training-time machinery (paper §3.2).

Implements:
  * Gumbel-sigmoid stochastic relaxation of eviction decisions (Eq. 1);
  * the additive training mask ``M_α`` with *delayed* eviction via a
    sliding window (Fig. 2b), plus the *immediate*-eviction variant used
    by the §5.3 ablation;
  * the DMC relaxation (merge-into-previous via weighted averaging) used
    as the retrofitted baseline;
  * the one-sided L1 compression loss and the linear CR annealing
    schedule ``CR(t) = t/100 + 1``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e9
ALPHA_BIAS = -5.0  # paper: b = -5 so training starts with alpha ~ 0
GUMBEL_TAU = 0.3   # low temperature -> near-discrete decisions


def gumbel_sigmoid(logits, key, tau: float = GUMBEL_TAU):
    """BinConcrete / Gumbel-sigmoid sample in [0, 1] (Louizos et al.)."""
    u = jax.random.uniform(key, logits.shape, minval=1e-6, maxval=1.0 - 1e-6)
    noise = jnp.log(u) - jnp.log1p(-u)  # logistic noise
    return jax.nn.sigmoid((logits + noise) / tau)


def build_dms_mask(alpha, window: int, *, immediate: bool = False):
    """Training mask M_α for one KV head group, shape [B, H, T, T].

    Delayed (default): the decision α_j made at timestep j hides token j
    from queries i ≥ j + w with weight log(1 − α_j); until then the token
    is fully visible. Causality (j > i → −inf) is included.

    Immediate (ablation): the decision α_{j+w} (made w steps later) hides
    token j from queries i ≥ j + w — eviction executes as soon as the
    decision is made, matching classic token-eviction methods.

    Args:
      alpha: f32[B, H, T] in [0, 1].
      window: sliding-window size w ≥ 1.
    """
    b, h, t = alpha.shape
    i = jnp.arange(t)[:, None]  # queries
    j = jnp.arange(t)[None, :]  # keys
    causal = jnp.where(j <= i, 0.0, NEG_INF)  # [T, T]
    beyond = (i >= j + window).astype(alpha.dtype)  # [T, T]
    if immediate:
        # decision index is j + w (clamped); tokens near the end whose
        # decision point lies beyond T are never evicted.
        dec_idx = jnp.minimum(j + window, t - 1)
        dec_alpha = alpha[:, :, dec_idx[0]]  # [B, H, T] gathered at j+w
        in_range = (j + window <= t - 1).astype(alpha.dtype)[0]  # [T]
        a = dec_alpha * in_range[None, None, :]
    else:
        a = alpha  # decision at j controls token j
    # log(1 - α), clamped for numerical safety; α=1 -> NEG_INF.
    evict = jnp.log1p(-jnp.clip(a, 0.0, 1.0 - 1e-6))  # [B, H, T]
    evict = jnp.maximum(evict, NEG_INF)
    mask = causal[None, None] + beyond[None, None] * evict[:, :, None, :]
    return jnp.maximum(mask, NEG_INF)


def dmc_accumulate(k, v, alpha):
    """DMC relaxation: merge (k_t, v_t) into the running entry when α_t→1.

    Running weighted average along T (lax.scan):
        c_t  = α_t · c_{t−1} + 1
        k̃_t = (α_t · k̃_{t−1} · c_{t−1} + k_t) / c_t      (ṽ likewise)

    Token t−1 is hidden (for queries ≥ t) with weight log(1 − α_t): its
    content now lives inside k̃_t. Returns (k̃, ṽ, absorb_mask_term) where
    the mask term is f32[B, H, T] to be applied at key position t−1.

    Args:
      k, v:  f32[B, H, T, hd]
      alpha: f32[B, H, T] (α_0 is forced to 0 — nothing to merge into).
    """
    b, h, t, hd = k.shape
    alpha = alpha.at[:, :, 0].set(0.0)

    def step(carry, xs):
        ka, va, c = carry
        kt, vt, at = xs
        c_new = at * c + 1.0
        ka_new = (at[..., None] * ka * c[..., None] + kt) / c_new[..., None]
        va_new = (at[..., None] * va * c[..., None] + vt) / c_new[..., None]
        return (ka_new, va_new, c_new), (ka_new, va_new)

    init = (
        jnp.zeros((b, h, hd), k.dtype),
        jnp.zeros((b, h, hd), v.dtype),
        jnp.zeros((b, h), k.dtype),
    )
    xs = (
        jnp.moveaxis(k, 2, 0),
        jnp.moveaxis(v, 2, 0),
        jnp.moveaxis(alpha, 2, 0),
    )
    _, (ka, va) = jax.lax.scan(step, init, xs)
    ka = jnp.moveaxis(ka, 0, 2)
    va = jnp.moveaxis(va, 0, 2)
    # absorb term: token j hidden by α_{j+1} for queries i ≥ j+1
    a_next = jnp.concatenate([alpha[:, :, 1:], jnp.zeros((b, h, 1))], axis=2)
    absorb = jnp.log1p(-jnp.clip(a_next, 0.0, 1.0 - 1e-6))
    return ka, va, jnp.maximum(absorb, NEG_INF)


def build_dmc_mask(alpha):
    """Causal mask + absorb terms for the DMC relaxation. [B, H, T, T]."""
    b, h, t = alpha.shape
    i = jnp.arange(t)[:, None]
    j = jnp.arange(t)[None, :]
    causal = jnp.where(j <= i, 0.0, NEG_INF)
    beyond = (i > j).astype(alpha.dtype)  # absorb applies to queries i ≥ j+1
    a_next = jnp.concatenate([alpha[:, :, 1:], jnp.zeros((b, h, 1))], axis=2)
    absorb = jnp.log1p(-jnp.clip(a_next, 0.0, 1.0 - 1e-6))
    mask = causal[None, None] + beyond[None, None] * absorb[:, :, None, :]
    return jnp.maximum(mask, NEG_INF)


def aux_compression_loss(alphas, valid, target_frac):
    """One-sided L1 loss: push mean(α) up to the target evicted fraction.

    L_aux = max(α* − mean(α over layers, heads, valid tokens), 0)

    Args:
      alphas: f32[L, B, H, T] relaxed decisions.
      valid:  f32[B, T] 1 for real tokens.
      target_frac: α* = 1 − 1/CR(t).
    """
    n_layers, _, n_heads, _ = alphas.shape
    w = valid[None, :, None, :]  # broadcasts over L and H
    denom = jnp.maximum(jnp.sum(valid) * n_layers * n_heads, 1.0)
    mean_alpha = jnp.sum(alphas * w) / denom
    return jnp.maximum(target_frac - mean_alpha, 0.0)


def cr_schedule(step: int, warmup: int = 100, per_unit: int = 100, cr_max: float = 8.0):
    """Linear annealing: CR(t) = 1 + max(0, t − warmup)/per_unit, capped.

    The paper trains 100 steps per unit of CR; `warmup` covers the App. B
    α-neuron zeroing phase that precedes compression.
    """
    cr = 1.0 + max(0.0, step - warmup) / per_unit
    return min(cr, cr_max)


def neuron_zero_scale(step: int, n_t: int = 100) -> float:
    """App. B: q_first[0] is annealed to zero over the first n_t steps."""
    return max(0.0, 1.0 - step / n_t)
