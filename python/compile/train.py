"""Retrofitting pipeline (paper §4, App. B/C).

Stages:
  1. **pretrain** — next-token CE on the synthetic task mixture (stands in
     for the public base model; see DESIGN.md §2);
  2. **retrofit** — logit distillation from the pretrained teacher plus
     the one-sided L1 compression loss, with the α-neuron zeroing phase
     folded into the warmup and the target CR linearly annealed
     (CR(t) = 1 + max(0, t−warmup)/100, the paper's 100-steps-per-unit
     schedule). Variants: DMS delayed (w=16 default, w=4), DMS immediate
     (ablation), DMC (baseline).

Snapshots are saved at fixed steps so that Fig. 5-right (accuracy vs
training tokens) needs a single run per method.

Everything here is build-time only; `aot.py` calls into it.
"""

from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import dms, tasks
from .model import Config, forward_train, init_params

SEQ_LEN = 160
BATCH = 8
PAD = tasks.PAD_ID
LAMBDA_AUX = 20.0


# --------------------------------------------------------------------------
# Data
# --------------------------------------------------------------------------


def make_batch(rng: tasks.SplitMix64, batch=BATCH, seq=SEQ_LEN):
    """Token batch [B, T] (BOS + problem text, PAD-filled) + valid mask."""
    toks = np.full((batch, seq), PAD, np.int32)
    val = np.zeros((batch, seq), np.float32)
    texts = tasks.training_batch_texts(rng, batch)
    for r, text in enumerate(texts):
        ids = [tasks.BOS_ID] + tasks.encode(text) + [tasks.EOS_ID]
        ids = ids[:seq]
        toks[r, : len(ids)] = ids
        val[r, : len(ids)] = 1.0
    return jnp.asarray(toks), jnp.asarray(val)


# --------------------------------------------------------------------------
# Adam (hand-rolled; optax is not in the image)
# --------------------------------------------------------------------------


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.98, eps=1e-9):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(
        lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads
    )
    v = jax.tree_util.tree_map(
        lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads
    )
    mhat_scale = 1.0 / (1 - b1**t)
    vhat_scale = 1.0 / (1 - b2**t)
    new_params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale)
        / (jnp.sqrt(v_ * vhat_scale) + eps),
        params,
        m,
        v,
    )
    return new_params, {"m": m, "v": v, "t": t}


# --------------------------------------------------------------------------
# Losses
# --------------------------------------------------------------------------


def ce_loss(logits, tokens, valid):
    """Next-token cross entropy over valid target positions."""
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    tgt = tokens[:, 1:]
    w = valid[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)


def kl_loss(student_logits, teacher_logits, valid):
    """Logit distillation: KL(teacher || student), mean over valid pos."""
    t = jax.nn.log_softmax(teacher_logits, axis=-1)
    s = jax.nn.log_softmax(student_logits, axis=-1)
    kl = jnp.sum(jnp.exp(t) * (t - s), axis=-1)  # [B, T]
    return jnp.sum(kl * valid) / jnp.maximum(jnp.sum(valid), 1.0)


# --------------------------------------------------------------------------
# Train steps (jitted; scalars enter as traced args to avoid recompiles)
# --------------------------------------------------------------------------


def make_pretrain_step(cfg: Config):
    def step(params, opt, tokens, valid, lr, q_first_scale):
        def loss_fn(p):
            logits, _ = forward_train(
                p, cfg, tokens, valid, alpha_mode="off",
                q_first_scale=q_first_scale,
            )
            return ce_loss(logits, tokens, valid)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt = adam_update(params, grads, opt, lr)
        return params, opt, loss

    return jax.jit(step)


def make_retrofit_step(cfg: Config, alpha_mode: str, window: int):
    def step(params, teacher, opt, tokens, valid, lr, target_frac,
             q_first_scale, key):
        t_logits, _ = forward_train(teacher, cfg, tokens, valid, alpha_mode="off")

        def loss_fn(p):
            s_logits, alphas = forward_train(
                p, cfg, tokens, valid,
                alpha_mode=alpha_mode, window=window,
                gumbel_key=key, q_first_scale=q_first_scale,
            )
            l_d = kl_loss(s_logits, t_logits, valid)
            l_aux = dms.aux_compression_loss(alphas, valid, target_frac)
            return l_d + LAMBDA_AUX * l_aux, (l_d, l_aux, jnp.mean(alphas))

        (loss, (l_d, l_aux, mean_a)), grads = jax.value_and_grad(
            loss_fn, has_aux=True
        )(params)
        params, opt = adam_update(params, grads, opt, lr)
        return params, opt, loss, l_d, l_aux, mean_a

    return jax.jit(step)


# --------------------------------------------------------------------------
# Checkpoint I/O (flat npz)
# --------------------------------------------------------------------------


def flatten_params(params) -> dict:
    flat = {
        "embed": params["embed"],
        "ln_f": params["ln_f"],
        "lm_head": params["lm_head"],
    }
    for i, layer in enumerate(params["layers"]):
        for k, v in layer.items():
            flat[f"layers.{i}.{k}"] = v
    return flat


def unflatten_params(flat: dict, cfg: Config) -> dict:
    params = {
        "embed": jnp.asarray(flat["embed"]),
        "ln_f": jnp.asarray(flat["ln_f"]),
        "lm_head": jnp.asarray(flat["lm_head"]),
        "layers": [],
    }
    for i in range(cfg.n_layers):
        params["layers"].append(
            {
                k: jnp.asarray(flat[f"layers.{i}.{k}"])
                for k in ("ln1", "wq", "wk", "wv", "wo", "ln2", "w1", "w3", "w2")
            }
        )
    return params


def save_ckpt(path: str, params):
    np.savez(path, **{k: np.asarray(v) for k, v in flatten_params(params).items()})


def load_ckpt(path: str, cfg: Config):
    with np.load(path) as z:
        return unflatten_params(dict(z.items()), cfg)


# --------------------------------------------------------------------------
# Greedy eval (sanity probe used during training; the real evaluation
# happens in the Rust engine over the AOT artifacts)
# --------------------------------------------------------------------------


def greedy_accuracy(params, cfg: Config, task: str, n=16, max_gen=90,
                    alpha_mode="off", window=16, seed=123):
    """Greedy decode by full re-forward (O(T²), fine for a probe)."""
    fwd = jax.jit(
        lambda p, t, v: forward_train(
            p, cfg, t, v, alpha_mode=alpha_mode, window=window,
            gumbel_key=None, q_first_scale=0.0,
        )[0]
    )
    correct = 0
    for i in range(n):
        prob = tasks.gen_problem(task, seed, i)
        ids = [tasks.BOS_ID] + tasks.encode(prob.prompt)
        buf = np.full((1, SEQ_LEN), PAD, np.int32)
        gen_start = len(ids)
        if gen_start >= SEQ_LEN - 2:
            continue
        buf[0, :gen_start] = ids
        val = np.zeros((1, SEQ_LEN), np.float32)
        pos = gen_start
        val[0, :pos] = 1.0
        for _ in range(min(max_gen, SEQ_LEN - gen_start - 1)):
            logits = fwd(params, jnp.asarray(buf), jnp.asarray(val))
            nxt = int(jnp.argmax(logits[0, pos - 1]))
            if nxt == tasks.EOS_ID:
                break
            buf[0, pos] = nxt
            val[0, pos] = 1.0
            pos += 1
            if buf[0, pos - 1] == tasks.encode("\n")[0]:
                break
        text = tasks.decode(list(buf[0, gen_start:pos]))
        if tasks.extract_answer(text) == prob.answer:
            correct += 1
    return correct / n


# --------------------------------------------------------------------------
# Top-level stages
# --------------------------------------------------------------------------


def pretrain(cfg: Config, steps: int, seed=0, log_every=50, params=None,
             zero_steps: int | None = None):
    """Pretrain, then run the App. B α-neuron zeroing phase.

    The zeroing phase (last `zero_steps` steps) anneals the contribution
    of q_first[0] from 1 to 0 under the LM loss, so the deployed base
    checkpoint — like every retrofit that starts from it — operates with
    the neuron zeroed. The base ("vanilla") baseline is therefore exactly
    the model the inference executables compute.
    """
    params = params or init_params(cfg, seed)
    opt = adam_init(params)
    step_fn = make_pretrain_step(cfg)
    rng = tasks.SplitMix64(seed * 7919 + 11)
    if zero_steps is None:
        zero_steps = max(1, steps // 7)
    t0 = time.time()
    total = steps + zero_steps
    for t in range(total):
        lr = 1e-3 * min(1.0, (t + 1) / 100) * (0.1 ** (t / max(total, 1)))
        scale = 1.0 if t < steps else max(0.0, 1.0 - (t - steps) / max(zero_steps - 1, 1))
        tokens, valid = make_batch(rng)
        params, opt, loss = step_fn(params, opt, tokens, valid, lr, scale)
        if t % log_every == 0 or t == total - 1:
            print(
                f"[pretrain] step {t} loss {float(loss):.4f} scale {scale:.2f} "
                f"({time.time() - t0:.0f}s)",
                flush=True,
            )
    return params


def retrofit(
    cfg: Config,
    teacher,
    *,
    alpha_mode: str,
    window: int,
    steps: int,
    warmup: int = 100,
    per_unit: int = 100,
    cr_max: float = 8.0,
    snapshot_steps=(),
    snapshot_dir: str | None = None,
    tag: str = "dms",
    seed: int = 1,
    log_every: int = 50,
):
    """Distill-retrofit `teacher` into an eviction-aware student."""
    params = jax.tree_util.tree_map(jnp.copy, teacher)
    opt = adam_init(params)
    step_fn = make_retrofit_step(cfg, alpha_mode, window)
    rng = tasks.SplitMix64(seed * 104729 + 3)
    key = jax.random.PRNGKey(seed)
    t0 = time.time()
    for t in range(steps):
        lr = 3e-4 * min(1.0, (t + 1) / 50)
        cr = dms.cr_schedule(t, warmup=warmup, per_unit=per_unit, cr_max=cr_max)
        target = 1.0 - 1.0 / cr
        scale = 0.0  # α neuron already zeroed during the pretrain phase
        tokens, valid = make_batch(rng)
        key, sub = jax.random.split(key)
        params, opt, loss, l_d, l_aux, mean_a = step_fn(
            params, teacher, opt, tokens, valid, lr, target, scale, sub
        )
        if t % log_every == 0 or t == steps - 1:
            print(
                f"[{tag}] step {t} CR {cr:.2f} loss {float(loss):.4f} "
                f"kl {float(l_d):.4f} aux {float(l_aux):.4f} "
                f"mean_a {float(mean_a):.3f} ({time.time() - t0:.0f}s)",
                flush=True,
            )
        if (t + 1) in snapshot_steps and snapshot_dir:
            path = os.path.join(snapshot_dir, f"{tag}_step{t + 1}.npz")
            save_ckpt(path, params)
            print(f"[{tag}] snapshot -> {path}", flush=True)
    return params
