"""AOT build: train (cached) → export HLO text artifacts + manifest.

This is the *only* entry point that runs Python; after `make artifacts`
the Rust binary is self-contained. Interchange is HLO **text** (the
image's xla_extension 0.5.1 rejects jax≥0.5 serialized protos whose
instruction ids exceed INT_MAX; the text parser reassigns ids).

Weights are passed to the executables as leading *inputs* rather than
baked as constants — baking 0.57M f32 as decimal text would blow each
HLO file up by ~20 MB, and passing them lets the Rust runtime upload the
parameter literals once and reuse them across calls.

Usage:  cd python && python -m compile.aot --out ../artifacts
Env:    HS_FAST=1   smoke mode (tiny step counts; for CI only)
"""

from __future__ import annotations

import argparse
import json
import os
import struct
import time

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from . import tasks, train
from .model import Config, decode_step, prefill_chunk, init_params

FAST = os.environ.get("HS_FAST", "") == "1"

# Retrofit schedule (paper: 100 steps per CR unit after the zeroing phase)
PRETRAIN_STEPS = 60 if FAST else 3400
W16_STEPS = 30 if FAST else 800          # reaches CR8 at step 800
SIDE_STEPS = 20 if FAST else 400         # reaches CR4
DMC_STEPS = 20 if FAST else 500
SNAPSHOTS_W16 = (4, 8) if FAST else (150, 200, 300, 400, 500, 600, 800)
SNAPSHOTS_SIDE = () if FAST else (200, 300, 400)
SNAPSHOTS_DMC = (4, 8) if FAST else (150, 200, 300, 400, 500)

PARAM_KEYS = ("ln1", "wq", "wk", "wv", "wo", "ln2", "w1", "w3", "w2")


def param_order(cfg: Config) -> list[str]:
    """Canonical flat parameter order shared with the Rust runtime."""
    names = ["embed", "ln_f", "lm_head"]
    for i in range(cfg.n_layers):
        names += [f"layers.{i}.{k}" for k in PARAM_KEYS]
    return names


def params_to_list(params, cfg: Config):
    flat = train.flatten_params(params)
    return [flat[n] for n in param_order(cfg)]


def list_to_params(lst, cfg: Config):
    flat = dict(zip(param_order(cfg), lst))
    return train.unflatten_params(flat, cfg)


# --------------------------------------------------------------------------
# .bin checkpoint format (JSON header + raw little-endian f32 payload)
#   [u32 header_len][header JSON][payload]
#   header: {"tensors": [{"name": str, "shape": [..], "offset": int}, ...]}
# Mirrored by rust/src/runtime/weights.rs.
# --------------------------------------------------------------------------


def save_bin(path: str, params, cfg: Config):
    flat = train.flatten_params(params)
    tensors, payload = [], b""
    for name in param_order(cfg):
        arr = np.ascontiguousarray(np.asarray(flat[name], np.float32))
        tensors.append(
            {"name": name, "shape": list(arr.shape), "offset": len(payload)}
        )
        payload += arr.tobytes()
    header = json.dumps({"tensors": tensors}).encode()
    with open(path, "wb") as f:
        f.write(struct.pack("<I", len(header)))
        f.write(header)
        f.write(payload)


# --------------------------------------------------------------------------
# HLO export
# --------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_decode(cfg: Config, out_path: str, *, batch: int, slots: int,
                  use_pallas: bool):
    """Decode-step executable. Inputs: params… then
    (k_cache, v_cache, tokens, positions, mask, pmin, pmax, quest_k)."""
    l, h, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    p = slots // cfg.page_size
    n_params = len(param_order(cfg))

    def fn(*args):
        prm = list_to_params(args[:n_params], cfg)
        kc, vc, tok, pos, mask, pmin, pmax, qk = args[n_params:]
        return decode_step(
            prm, cfg, kc, vc, tok, pos, mask, pmin, pmax, qk,
            use_pallas=use_pallas,
        )

    f32, i32 = np.float32, np.int32
    specs = [
        jax.ShapeDtypeStruct(np.asarray(a).shape, np.asarray(a).dtype)
        for a in params_to_list(init_params(cfg), cfg)
    ]
    specs += [
        jax.ShapeDtypeStruct((l, batch, h, slots, hd), f32),
        jax.ShapeDtypeStruct((l, batch, h, slots, hd), f32),
        jax.ShapeDtypeStruct((batch,), i32),
        jax.ShapeDtypeStruct((batch,), i32),
        jax.ShapeDtypeStruct((l, batch, h, slots), f32),
        jax.ShapeDtypeStruct((l, batch, h, p, hd), f32),
        jax.ShapeDtypeStruct((l, batch, h, p, hd), f32),
        jax.ShapeDtypeStruct((), i32),
    ]
    text = to_hlo_text(jax.jit(fn).lower(*specs))
    with open(out_path, "w") as f:
        f.write(text)
    return {
        "kind": "decode", "batch": batch, "slots": slots, "pages": p,
        "pallas": use_pallas, "file": os.path.basename(out_path),
    }


def export_prefill(cfg: Config, out_path: str, *, batch: int, chunk: int,
                   slots: int, window: int, immediate: bool,
                   dms_enabled: bool, use_pallas: bool):
    """Prefill-chunk executable. Inputs: params… then
    (k_cache, v_cache, cache_mask, tokens, positions, valid)."""
    l, h, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    n_params = len(param_order(cfg))

    def fn(*args):
        prm = list_to_params(args[:n_params], cfg)
        kc, vc, cmask, tok, pos, val = args[n_params:]
        return prefill_chunk(
            prm, cfg, kc, vc, cmask, tok, pos, val,
            window=window, immediate=immediate, dms_enabled=dms_enabled,
            use_pallas=use_pallas,
        )

    f32, i32 = np.float32, np.int32
    specs = [
        jax.ShapeDtypeStruct(np.asarray(a).shape, np.asarray(a).dtype)
        for a in params_to_list(init_params(cfg), cfg)
    ]
    specs += [
        jax.ShapeDtypeStruct((l, batch, h, slots, hd), f32),
        jax.ShapeDtypeStruct((l, batch, h, slots, hd), f32),
        jax.ShapeDtypeStruct((l, batch, h, slots), f32),
        jax.ShapeDtypeStruct((batch, chunk), i32),
        jax.ShapeDtypeStruct((batch, chunk), i32),
        jax.ShapeDtypeStruct((batch, chunk), f32),
    ]
    text = to_hlo_text(jax.jit(fn).lower(*specs))
    with open(out_path, "w") as f:
        f.write(text)
    return {
        "kind": "prefill", "batch": batch, "chunk": chunk, "slots": slots,
        "window": window, "immediate": immediate, "dms": dms_enabled,
        "pallas": use_pallas, "file": os.path.basename(out_path),
    }


# --------------------------------------------------------------------------
# Golden task samples (cross-language generator pinning)
# --------------------------------------------------------------------------


def golden_tasks() -> dict:
    out = {}
    for suite in sorted(tasks.SUITES):
        rows = []
        for i in range(3):
            p = tasks.gen_problem(suite, 42, i)
            rows.append(
                {"prompt": p.prompt, "solution": p.solution, "answer": p.answer}
            )
        out[suite] = rows
    return out


# --------------------------------------------------------------------------
# Main build
# --------------------------------------------------------------------------


def build(out_dir: str):
    cfg = Config()
    os.makedirs(out_dir, exist_ok=True)
    ckpt_dir = os.path.join(out_dir, "ckpt")
    hlo_dir = os.path.join(out_dir, "hlo")
    os.makedirs(ckpt_dir, exist_ok=True)
    os.makedirs(hlo_dir, exist_ok=True)
    t_start = time.time()

    # ---------------- stage 1: pretrain (the "public base model") --------
    base_path = os.path.join(ckpt_dir, "base.npz")
    warm_path = os.path.join(ckpt_dir, "base_warmstart.npz")
    if os.path.exists(base_path):
        base = train.load_ckpt(base_path, cfg)
        print("[aot] loaded cached base ckpt", flush=True)
    else:
        warm = None
        if os.path.exists(warm_path):
            warm = train.load_ckpt(warm_path, cfg)
            print("[aot] warm-starting pretrain from previous base", flush=True)
        base = train.pretrain(cfg, PRETRAIN_STEPS, params=warm)
        train.save_ckpt(base_path, base)
        for task in ("math", "gsm8k", "niah", "vt"):
            acc = train.greedy_accuracy(base, cfg, task, n=16, max_gen=80, seed=17)
            print(f"[aot] base {task} greedy acc {acc:.2f}", flush=True)

    # ---------------- stage 2: retrofit variants -------------------------
    def retro(tag, mode, window, steps, snaps, cr_max=8.0):
        final_path = os.path.join(ckpt_dir, f"{tag}.npz")
        if os.path.exists(final_path):
            print(f"[aot] cached {tag}", flush=True)
            return train.load_ckpt(final_path, cfg)
        p = train.retrofit(
            cfg, base, alpha_mode=mode, window=window, steps=steps,
            snapshot_steps=snaps, snapshot_dir=ckpt_dir, tag=tag,
            cr_max=cr_max,
        )
        train.save_ckpt(final_path, p)
        return p

    retro("dms_w16", "dms", 16, W16_STEPS, SNAPSHOTS_W16)
    retro("dms_w4", "dms", 4, SIDE_STEPS, SNAPSHOTS_SIDE, cr_max=4.0)
    retro("dms_imm_w4", "dms_immediate", 4, SIDE_STEPS, SNAPSHOTS_SIDE,
          cr_max=4.0)
    retro("dms_imm_w16", "dms_immediate", 16, SIDE_STEPS, SNAPSHOTS_SIDE,
          cr_max=4.0)
    retro("dmc", "dmc", 16, DMC_STEPS, SNAPSHOTS_DMC, cr_max=4.0)

    # ---------------- stage 3: Fig. 5 snapshot evals (python-side) -------
    fig5_path = os.path.join(out_dir, "fig5_data.json")
    if not os.path.exists(fig5_path):
        fig5 = {"delayed_vs_immediate": [], "data_efficiency": []}
        n_eval = 4 if FAST else 24
        tok_per_step = train.BATCH * train.SEQ_LEN
        for tag, mode, w in (
            ("dms_w4", "dms", 4),
            ("dms_w16", "dms", 16),
            ("dms_imm_w4", "dms_immediate", 4),
            ("dms_imm_w16", "dms_immediate", 16),
        ):
            for step in SNAPSHOTS_SIDE:
                path = os.path.join(ckpt_dir, f"{tag}_step{step}.npz")
                if not os.path.exists(path):
                    continue
                p = train.load_ckpt(path, cfg)
                acc = train.greedy_accuracy(
                    p, cfg, "gsm8k", n=n_eval, alpha_mode=mode, window=w
                )
                cr = 1.0 + max(0, step - 100) / 100
                fig5["delayed_vs_immediate"].append(
                    {"variant": tag, "cr": cr, "step": step, "acc": acc}
                )
                print(f"[fig5] {tag} step {step} cr {cr} acc {acc:.2f}",
                      flush=True)
        for tag, mode, snaps in (
            ("dms_w16", "dms", SNAPSHOTS_W16),
            ("dmc", "dmc", SNAPSHOTS_DMC),
        ):
            for step in snaps:
                path = os.path.join(ckpt_dir, f"{tag}_step{step}.npz")
                if not os.path.exists(path):
                    continue
                p = train.load_ckpt(path, cfg)
                acc = train.greedy_accuracy(
                    p, cfg, "gsm8k", n=n_eval, alpha_mode=mode, window=16
                )
                fig5["data_efficiency"].append(
                    {
                        "variant": tag, "step": step,
                        "tokens": step * tok_per_step, "acc": acc,
                        "cr": 1.0 + max(0, step - 100) / 100,
                    }
                )
                print(f"[fig5] {tag} step {step} acc {acc:.2f}", flush=True)
        with open(fig5_path, "w") as f:
            json.dump(fig5, f, indent=1)

    # ---------------- stage 4: export HLO + .bin weights ------------------
    variants = {
        "base": {"ckpt": "base.npz", "alpha_mode": "off", "window": 16,
                 "immediate": False},
        "dms_w16_cr2": {"ckpt": "dms_w16_step200.npz", "alpha_mode": "dms",
                        "window": 16, "immediate": False},
        "dms_w16_cr3": {"ckpt": "dms_w16_step300.npz", "alpha_mode": "dms",
                        "window": 16, "immediate": False},
        "dms_w16_cr4": {"ckpt": "dms_w16_step400.npz", "alpha_mode": "dms",
                        "window": 16, "immediate": False},
        "dmc_cr2": {"ckpt": "dmc_step200.npz", "alpha_mode": "dmc",
                    "window": 16, "immediate": False},
        "dmc_cr3": {"ckpt": "dmc_step300.npz", "alpha_mode": "dmc",
                    "window": 16, "immediate": False},
        "dms_w16_cr8": {"ckpt": "dms_w16.npz", "alpha_mode": "dms",
                        "window": 16, "immediate": False},
        "dms_w4": {"ckpt": "dms_w4.npz", "alpha_mode": "dms", "window": 4,
                   "immediate": False},
        "dms_imm_w16": {"ckpt": "dms_imm_w16.npz",
                        "alpha_mode": "dms_immediate", "window": 16,
                        "immediate": True},
        "dmc": {"ckpt": "dmc.npz", "alpha_mode": "dmc", "window": 16,
                "immediate": False},
    }
    if FAST:
        variants["dms_w16_cr4"]["ckpt"] = "dms_w16.npz"

    manifest = {
        "config": cfg.as_dict(),
        "param_order": param_order(cfg),
        "vocab": tasks.VOCAB,
        "specials": {"pad": tasks.PAD_ID, "bos": tasks.BOS_ID,
                     "eos": tasks.EOS_ID},
        "variants": {},
        "executables": {},
    }

    exe_specs = [
        ("decode_b8_s320", dict(batch=8, slots=320, use_pallas=True)),
        ("decode_b8_s192", dict(batch=8, slots=192, use_pallas=True)),
        ("decode_b1_s320", dict(batch=1, slots=320, use_pallas=True)),
        ("decode_b8_s320_jnp", dict(batch=8, slots=320, use_pallas=False)),
    ]
    for name, kw in exe_specs:
        path = os.path.join(hlo_dir, f"{name}.hlo.txt")
        if not os.path.exists(path):
            t0 = time.time()
            meta = export_decode(cfg, path, **kw)
            print(f"[aot] exported {name} ({time.time()-t0:.1f}s, "
                  f"{os.path.getsize(path)//1024}KB)", flush=True)
        else:
            p_ = kw["slots"] // cfg.page_size
            meta = {"kind": "decode", "batch": kw["batch"],
                    "slots": kw["slots"], "pages": p_,
                    "pallas": kw["use_pallas"], "file": f"{name}.hlo.txt"}
        manifest["executables"][name] = meta

    prefill_flavours = [
        ("prefill_dense_b8", dict(window=16, immediate=False,
                                  dms_enabled=False)),
        ("prefill_dms_w16_b8", dict(window=16, immediate=False,
                                    dms_enabled=True)),
        ("prefill_dms_w4_b8", dict(window=4, immediate=False,
                                   dms_enabled=True)),
        ("prefill_imm_w16_b8", dict(window=16, immediate=True,
                                    dms_enabled=True)),
        ("prefill_dense_b1", dict(window=16, immediate=False,
                                  dms_enabled=False, batch=1)),
        ("prefill_dms_w16_b1", dict(window=16, immediate=False,
                                    dms_enabled=True, batch=1)),
        # s192 bucket (perf pass: smaller uploads for short configs)
        ("prefill_dense_b8_s192", dict(window=16, immediate=False,
                                       dms_enabled=False, slots=192)),
        ("prefill_dms_w16_b8_s192", dict(window=16, immediate=False,
                                         dms_enabled=True, slots=192)),
    ]
    for name, kw in prefill_flavours:
        batch = kw.pop("batch", 8)
        slots = kw.pop("slots", 320)
        path = os.path.join(hlo_dir, f"{name}.hlo.txt")
        if not os.path.exists(path):
            t0 = time.time()
            meta = export_prefill(cfg, path, batch=batch, chunk=32,
                                  slots=slots, use_pallas=True, **kw)
            print(f"[aot] exported {name} ({time.time()-t0:.1f}s)", flush=True)
        else:
            meta = {"kind": "prefill", "batch": batch, "chunk": 32,
                    "slots": slots, "pallas": True,
                    "file": f"{name}.hlo.txt",
                    "window": kw["window"], "immediate": kw["immediate"],
                    "dms": kw["dms_enabled"]}
        manifest["executables"][name] = meta

    for tag, spec in variants.items():
        ck = os.path.join(ckpt_dir, spec["ckpt"])
        if not os.path.exists(ck):
            print(f"[aot] WARNING missing ckpt for {tag}: {ck}", flush=True)
            continue
        params = train.load_ckpt(ck, cfg)
        bin_path = os.path.join(out_dir, f"weights_{tag}.bin")
        if not os.path.exists(bin_path):
            save_bin(bin_path, params, cfg)
        manifest["variants"][tag] = {
            "weights": f"weights_{tag}.bin",
            "alpha_mode": spec["alpha_mode"],
            "window": spec["window"],
            "immediate": spec["immediate"],
        }

    # ---------------- stage 5: golden tasks + manifest -------------------
    with open(os.path.join(out_dir, "tasks_golden.json"), "w") as f:
        json.dump(golden_tasks(), f, indent=1)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] done in {time.time()-t_start:.0f}s -> {out_dir}", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    build(args.out)


if __name__ == "__main__":
    main()
