"""Synthetic task suite + char-level tokenizer.

This module is the *specification*: `rust/src/tasks/` mirrors it
generator-for-generator, and a golden-file test (`tasks_golden.json`,
emitted by aot.py) pins the two implementations together byte-for-byte.

Tasks (paper analog in parentheses — see DESIGN.md §2):
  * arith  — modular-arithmetic chain-of-thought      (MATH 500 / AIME 24)
  * mcq    — 4-choice question over an arith chain    (GPQA Diamond)
  * code   — stack-machine trace, scored pass@all     (LiveCodeBench)
  * niah   — needle in a haystack                     (RULER NIAH)
  * vt     — variable tracking                        (RULER VT)

All generators are driven by SplitMix64 so that Python and Rust produce
identical problems from identical seeds.
"""

from __future__ import annotations

from dataclasses import dataclass

# --------------------------------------------------------------------------
# Tokenizer: fixed 64-symbol char vocabulary. Order is load-bearing.
# --------------------------------------------------------------------------

SPECIALS = ["<pad>", "<bos>", "<eos>"]
CHARS = (
    "0123456789"           # digits
    "abcdefghijklmnopqrstuvwxyz"  # identifiers / filler words
    "ABCD"                 # MCQ choices
    "+-*=?"                # operators
    " \n.,:|#"             # punctuation / separators
    "PUSHML"               # uppercase for code task keywords (with A,B,C,D,S above)
    "QT%"                  # Q:/T: prompt markers + one reserved symbol
)
VOCAB = SPECIALS + list(CHARS)
assert len(VOCAB) == 64, f"vocab must be 64, got {len(VOCAB)}"

PAD_ID, BOS_ID, EOS_ID = 0, 1, 2
_CHAR_TO_ID = {c: i + len(SPECIALS) for i, c in enumerate(CHARS)}
_ID_TO_CHAR = {i + len(SPECIALS): c for i, c in enumerate(CHARS)}


def encode(text: str) -> list[int]:
    """Encode text; raises on symbols outside the vocabulary."""
    return [_CHAR_TO_ID[c] for c in text]


def decode(ids: list[int]) -> str:
    """Decode ids, skipping special tokens."""
    return "".join(_ID_TO_CHAR.get(i, "") for i in ids)


# --------------------------------------------------------------------------
# SplitMix64 — tiny, portable, identical in Rust.
# --------------------------------------------------------------------------

_M64 = (1 << 64) - 1


class SplitMix64:
    """Deterministic RNG shared with rust/src/util/rng.rs."""

    def __init__(self, seed: int):
        self.state = seed & _M64

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & _M64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _M64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _M64
        return z ^ (z >> 31)

    def below(self, n: int) -> int:
        """Uniform in [0, n) via modulo (n << 2^32 so bias is negligible
        and, crucially, reproducible)."""
        return self.next_u64() % n

    def choice(self, xs):
        return xs[self.below(len(xs))]


# --------------------------------------------------------------------------
# Problem container
# --------------------------------------------------------------------------


@dataclass
class Problem:
    task: str
    prompt: str          # text fed to the model (after <bos>)
    solution: str        # full gold completion incl. reasoning + answer
    answer: str          # canonical final answer (for exact match)
    meta: dict

    def full_text(self) -> str:
        return self.prompt + self.solution


def extract_answer(text: str) -> str | None:
    """Final answer = text following the last 'A:' marker, up to newline/end.

    Mirrors rust/src/tasks/mod.rs::extract_answer.
    """
    idx = text.rfind("A:")
    if idx < 0:
        return None
    out = []
    for c in text[idx + 2:]:
        if c in "\n|":
            break
        out.append(c)
    ans = "".join(out).strip()
    return ans if ans else None


# --------------------------------------------------------------------------
# arith — chain of single-digit modular arithmetic.
#
#   Q:7+5-3*4=?
#   T:7+5=2 2-3=9 9*4=6 A:6
#
# All values mod 10; '-' is mod-10 subtraction. Difficulty = chain length.
# --------------------------------------------------------------------------

_OPS = "+-*"


def _apply(op: str, a: int, b: int) -> int:
    if op == "+":
        return (a + b) % 10
    if op == "-":
        return (a - b) % 10
    return (a * b) % 10


def gen_arith(rng: SplitMix64, n_ops: int) -> Problem:
    vals = [rng.below(10)]
    ops = []
    for _ in range(n_ops):
        ops.append(_OPS[rng.below(3)])
        vals.append(rng.below(10))
    expr = str(vals[0]) + "".join(o + str(v) for o, v in zip(ops, vals[1:]))
    acc = vals[0]
    steps = []
    for o, v in zip(ops, vals[1:]):
        nxt = _apply(o, acc, v)
        steps.append(f"{acc}{o}{v}={nxt}")
        acc = nxt
    prompt = f"Q:{expr}=?\nT:"
    solution = " ".join(steps) + f" A:{acc}\n"
    return Problem("arith", prompt, solution, str(acc), {"n_ops": n_ops})


# --------------------------------------------------------------------------
# mcq — the same chain, but the model must pick the letter whose option
# equals the chain value. Options are distinct digits.
#
#   Q:7+5-3=? A:4 B:9 C:1 D:6\nT:7+5=2 2-3=9 A:B
# --------------------------------------------------------------------------


def gen_mcq(rng: SplitMix64, n_ops: int) -> Problem:
    base = gen_arith(rng, n_ops)
    correct = int(base.answer)
    opts = [correct]
    while len(opts) < 4:
        d = rng.below(10)
        if d not in opts:
            opts.append(d)
    # deterministic shuffle: Fisher-Yates
    for i in range(3, 0, -1):
        j = rng.below(i + 1)
        opts[i], opts[j] = opts[j], opts[i]
    letter = "ABCD"[opts.index(correct)]
    expr = base.prompt[2:-5]  # strip "Q:" and "=?\nT:"
    prompt = (
        f"Q:{expr}=? "
        + " ".join(f"{l}:{o}" for l, o in zip("ABCD", opts))
        + "\nT:"
    )
    steps = base.solution[: base.solution.rfind(" A:")]
    solution = steps + f" A:{letter}\n"
    return Problem("mcq", prompt, solution, letter, {"n_ops": n_ops})


# --------------------------------------------------------------------------
# code — stack machine. Program of PUSH d / ADD / MUL / SUB ops; the model
# traces the stack after each instruction and answers with the final top.
# Keywords use only vocab letters: PUSH, ADD, MUL, SUB.
#
#   Q:PUSH 3|PUSH 4|ADD|PUSH 2|MUL\nT:3 34 7 72 4 A:4
#
# Trace prints the stack (concatenated digits, bottom->top) after each op.
# All arithmetic mod 10 to stay in-vocab.
# --------------------------------------------------------------------------

_CODE_OPS = ["ADD", "MUL", "SUB"]


def gen_code(rng: SplitMix64, n_instr: int) -> Problem:
    instrs: list[str] = []
    stack: list[int] = []
    trace: list[str] = []
    for _ in range(n_instr):
        if len(stack) < 2 or rng.below(2) == 0:
            d = rng.below(10)
            instrs.append(f"PUSH {d}")
            stack.append(d)
        else:
            op = _CODE_OPS[rng.below(3)]
            b, a = stack.pop(), stack.pop()
            if op == "ADD":
                stack.append((a + b) % 10)
            elif op == "MUL":
                stack.append((a * b) % 10)
            else:
                stack.append((a - b) % 10)
            instrs.append(op)
        trace.append("".join(str(v) for v in stack))
    # ensure non-empty final stack (always true: first instr is a PUSH)
    ans = str(stack[-1])
    prompt = "Q:" + "|".join(instrs) + "\nT:"
    solution = " ".join(trace) + f" A:{ans}\n"
    return Problem("code", prompt, solution, ans, {"n_instr": n_instr})


# lowercase keyword chars must exist in vocab; check once at import
for kw in ["PUSH", "ADD", "MUL", "SUB"]:
    for ch in kw:
        assert ch in _CHAR_TO_ID or ch in "ADBC", kw

# 'PUSH': P,U,S,H — we appended "PUSHML" to CHARS; A,D,B,C from choices;
# M,U,L: U comes from "PUSHML"? -> P,U,S,H,M,L are in vocab. ADD uses A,D.
# SUB uses S,U,B — B is in "ABCD". MUL uses M,U,L. All covered.


# --------------------------------------------------------------------------
# niah — needle in a haystack: filler sentences + one "key" fact.
#
#   Q:the bird saw a tree. key u=7. the fish ate a leaf. ... ?u\nT:A:7
# --------------------------------------------------------------------------

_NOUNS = ["bird", "fish", "tree", "leaf", "rock", "star", "frog", "moon"]
_VERBS = ["saw", "ate", "hid", "made", "took", "lost"]


def _filler(rng: SplitMix64) -> str:
    return (
        f"the {_NOUNS[rng.below(8)]} {_VERBS[rng.below(6)]} "
        f"a {_NOUNS[rng.below(8)]}."
    )


def gen_niah(rng: SplitMix64, n_fillers: int) -> Problem:
    var = "uvwxyz"[rng.below(6)]
    val = rng.below(10)
    pos = rng.below(n_fillers + 1)
    parts = []
    for i in range(n_fillers + 1):
        if i == pos:
            parts.append(f"key {var}={val}.")
        else:
            parts.append(_filler(rng))
    prompt = "Q:" + " ".join(parts) + f" ?{var}\nT:"
    solution = f"A:{val}\n"
    return Problem("niah", prompt, solution, str(val), {"n_fillers": n_fillers})


# --------------------------------------------------------------------------
# vt — variable tracking: assignment chain with copies, query a variable.
#
#   Q:a=5. b=a. c=b. d=2. ?c\nT:A:5
#
# Single-letter variables from a distinct pool; `n_chain` copies.
# --------------------------------------------------------------------------


def gen_vt(rng: SplitMix64, n_chain: int, n_noise: int) -> Problem:
    pool = list("abcdefghijklmnopqrst")
    # deterministic shuffle
    for i in range(len(pool) - 1, 0, -1):
        j = rng.below(i + 1)
        pool[i], pool[j] = pool[j], pool[i]
    chain = pool[: n_chain + 1]
    noise = pool[n_chain + 1 : n_chain + 1 + n_noise]
    stmts = [f"{chain[0]}={rng.below(10)}"]
    val = int(stmts[0][-1])
    for i in range(1, len(chain)):
        stmts.append(f"{chain[i]}={chain[i-1]}")
    for v in noise:
        stmts.append(f"{v}={rng.below(10)}")
    # interleave noise deterministically: rotate by rng
    order = list(range(1, len(stmts)))
    for i in range(len(order) - 1, 0, -1):
        j = rng.below(i + 1)
        order[i], order[j] = order[j], order[i]
    # dependency order must be preserved for chain stmts; simple fix:
    # sort chain statements back into relative order.
    chain_set = set(range(1, n_chain + 1))
    chain_positions = [k for k, idx in enumerate(order) if idx in chain_set]
    chain_sorted = sorted(idx for idx in order if idx in chain_set)
    for k, idx in zip(chain_positions, chain_sorted):
        order[k] = idx
    body = [stmts[0]] + [stmts[i] for i in order]
    target = chain[-1] if n_chain > 0 else chain[0]
    prompt = "Q:" + ". ".join(body) + f". ?{target}\nT:"
    solution = f"A:{val}\n"
    return Problem(
        "vt", prompt, solution, str(val), {"n_chain": n_chain, "n_noise": n_noise}
    )


# --------------------------------------------------------------------------
# Suite presets (difficulty bands used across experiments; the Rust side
# mirrors these numbers in tasks/suite.rs)
# --------------------------------------------------------------------------

SUITES = {
    # task: (gen_name, params) — eval presets
    "math": ("arith", {"n_ops": (3, 6)}),     # MATH 500 analog (easy band)
    "aime": ("arith", {"n_ops": (8, 13)}),    # AIME 24 analog (hard band)
    "gpqa": ("mcq", {"n_ops": (4, 8)}),
    "lcb": ("code", {"n_instr": (6, 10)}),
    "gsm8k": ("arith", {"n_ops": (4, 8)}),    # ablation probe band
    "niah": ("niah", {"n_fillers": (3, 5)}),
    "vt": ("vt", {"n_chain": (3, 6), "n_noise": (4, 8)}),
    # Table-1 analogs for the short-context battery (see DESIGN.md §2)
    "mmlu": ("mcq", {"n_ops": (2, 5)}),
    "hellaswag": ("code", {"n_instr": (3, 6)}),
}


def gen_problem(task: str, seed: int, index: int) -> Problem:
    """Generate problem `index` of suite `task`. Deterministic across langs."""
    rng = SplitMix64((seed * 0x51_7C_C1B7_2722_0A95 + index * 2 + 1) & _M64)
    gen, params = SUITES[task]
    if gen == "arith":
        lo, hi = params["n_ops"]
        return gen_arith(rng, lo + rng.below(hi - lo + 1))
    if gen == "mcq":
        lo, hi = params["n_ops"]
        return gen_mcq(rng, lo + rng.below(hi - lo + 1))
    if gen == "code":
        lo, hi = params["n_instr"]
        return gen_code(rng, lo + rng.below(hi - lo + 1))
    if gen == "niah":
        lo, hi = params["n_fillers"]
        return gen_niah(rng, lo + rng.below(hi - lo + 1))
    if gen == "vt":
        lo, hi = params["n_chain"]
        nlo, nhi = params["n_noise"]
        n_chain = lo + rng.below(hi - lo + 1)
        return gen_vt(rng, n_chain, nlo + rng.below(nhi - nlo + 1))
    raise ValueError(task)


def training_batch_texts(rng: SplitMix64, n: int) -> list[str]:
    """Mixture used for pretraining + distillation corpora."""
    texts = []
    kinds = ["math", "aime", "gpqa", "lcb", "gsm8k", "niah", "vt"]
    for _ in range(n):
        task = kinds[rng.below(len(kinds))]
        p = gen_problem(task, rng.next_u64() & 0x7FFFFFFF, 0)
        texts.append(p.full_text())
    return texts
