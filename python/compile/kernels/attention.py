"""Layer-1 Pallas attention kernels (DMS-masked GQA attention).

Two kernels cover the whole inference surface:

  * ``decode_attn``  — one auto-regressive step over the slot cache.
  * ``chunk_attn``   — a block of C queries (prefill chunks; training uses
                       the same kernel shape with cache size 0, C = T).

Hardware adaptation (DESIGN.md §9): the paper's H100 kernels pass the DMS
eviction decisions as a compact per-token vector into a FlashMask /
FlexAttention-style fused kernel. On TPU-shaped hardware we express the
same contract with Pallas: the additive mask enters VMEM as a per-KV-head
vector block — never materialised as a [T, T] tensor per query head — and
the MXU sees (G×hd)·(hd×S) matmuls per block.

VMEM budgeting (fp32): a (B, Hkv) grid cell holds
    K block  S·hd·4 B   + V block  S·hd·4 B
  + mask     S·4 B      + q        G·hd·4 B     + out G·hd·4 B
With the repo defaults (S=321, hd=16, G=4) that is ≈ 43 KiB — far under
the ~16 MiB VMEM of a TPU core, so a single-shot (non-looped) softmax per
grid cell is the right schedule; for S beyond ~64K the kernel would tile S
with an online-softmax accumulator instead.

Kernels run with ``interpret=True`` (CPU PJRT cannot execute Mosaic custom
calls); numerics are validated against ``ref.py`` by pytest + hypothesis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _decode_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, attn_ref):
    """Grid cell: one (batch, kv-head) pair.

    q_ref:    [G, hd]      mask_ref: [S]
    k_ref:    [S, hd]      o_ref:    [G, hd]
    v_ref:    [S, hd]      attn_ref: [S]
    """
    q = q_ref[...]
    k = k_ref[...]
    v = v_ref[...]
    mask = mask_ref[...]
    hd = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, q.dtype))
    # MXU-shaped contraction: [S, hd] x [hd, G] -> [S, G]
    scores = jnp.dot(k, q.T) * scale + mask[:, None]
    m = jnp.max(scores, axis=0, keepdims=True)
    w = jnp.exp(scores - m)
    denom = jnp.sum(w, axis=0, keepdims=True)
    w = w / denom
    # [G, S] x [S, hd] -> [G, hd]
    o_ref[...] = jnp.dot(w.T, v)
    attn_ref[...] = jnp.sum(w, axis=1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def decode_attn(q, k, v, mask, *, interpret: bool = True):
    """Pallas single-step decode attention.

    Shapes as in ``ref.decode_attn_ref``:
      q [B, Hkv, G, hd], k/v [B, Hkv, S, hd], mask [B, Hkv, S]
    Returns (out [B, Hkv, G, hd], attn [B, Hkv, S]).
    """
    b, h, g, hd = q.shape
    s = k.shape[2]
    grid = (b, h)
    return pl.pallas_call(
        _decode_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, g, hd), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((None, None, s, hd), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((None, None, s, hd), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((None, None, s), lambda i, j: (i, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, None, g, hd), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((None, None, s), lambda i, j: (i, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, g, hd), q.dtype),
            jax.ShapeDtypeStruct((b, h, s), q.dtype),
        ],
        interpret=interpret,
    )(q, k, v, mask)


def _chunk_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref):
    """Grid cell: one (batch, kv-head, group-head) triple.

    q_ref: [C, hd], k_ref/v_ref: [T, hd], mask_ref: [C, T], o_ref: [C, hd]
    """
    q = q_ref[...]
    k = k_ref[...]
    v = v_ref[...]
    mask = mask_ref[...]
    hd = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, q.dtype))
    scores = jnp.dot(q, k.T) * scale + mask  # [C, T]
    m = jnp.max(scores, axis=1, keepdims=True)
    w = jnp.exp(scores - m)
    w = w / jnp.sum(w, axis=1, keepdims=True)
    o_ref[...] = jnp.dot(w, v)


@functools.partial(jax.jit, static_argnames=("interpret",))
def chunk_attn(q, k, v, mask, *, interpret: bool = True):
    """Pallas chunked attention.

    Shapes as in ``ref.chunk_attn_ref``:
      q [B, Hkv, G, C, hd], k/v [B, Hkv, T, hd], mask [B, Hkv, C, T]
    Returns out [B, Hkv, G, C, hd].

    The mask block is shared across the G query heads of a group — the
    per-query-head mask tensor of a naive implementation never exists.
    """
    b, h, g, c, hd = q.shape
    t = k.shape[2]
    grid = (b, h, g)
    return pl.pallas_call(
        _chunk_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, None, c, hd), lambda i, j, l: (i, j, l, 0, 0)),
            pl.BlockSpec((None, None, t, hd), lambda i, j, l: (i, j, 0, 0)),
            pl.BlockSpec((None, None, t, hd), lambda i, j, l: (i, j, 0, 0)),
            pl.BlockSpec((None, None, c, t), lambda i, j, l: (i, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (None, None, None, c, hd), lambda i, j, l: (i, j, l, 0, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, h, g, c, hd), q.dtype),
        interpret=interpret,
    )(q, k, v, mask)
