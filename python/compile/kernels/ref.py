"""Pure-jnp oracles for the Pallas kernels.

These are the correctness contract: pytest + hypothesis assert that the
Pallas kernels in `attention.py` match these references across shapes and
dtypes. They are also the (fast) attention path used during retrofitting,
where interpret-mode Pallas would dominate step time; the equivalence is
what licenses the swap.
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e9


def decode_attn_ref(q, k, v, mask):
    """Single-step GQA decode attention over a slot cache.

    Args:
      q:    f32[B, Hkv, G, hd]   — queries, grouped per KV head.
      k:    f32[B, Hkv, S, hd]   — key slots (S includes the current token).
      v:    f32[B, Hkv, S, hd]
      mask: f32[B, Hkv, S]       — additive mask (0 live, NEG_INF dead).

    Returns:
      out:  f32[B, Hkv, G, hd]
      attn: f32[B, Hkv, S]       — softmax weights summed over the G query
                                   heads of the group (TOVA/H2O signal).
    """
    hd = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, q.dtype))
    # scores[b,h,g,s] = q . k
    scores = jnp.einsum("bhgd,bhsd->bhgs", q, k) * scale
    scores = scores + mask[:, :, None, :]
    w = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    out = jnp.einsum("bhgs,bhsd->bhgd", w, v)
    return out, jnp.sum(w, axis=2)


def chunk_attn_ref(q, k, v, mask):
    """Chunked (prefill/training) GQA attention.

    Args:
      q:    f32[B, Hkv, G, C, hd] — C chunk queries per group head.
      k:    f32[B, Hkv, T, hd]    — T = cache slots + chunk (keys for all
                                    positions the chunk may attend to).
      v:    f32[B, Hkv, T, hd]
      mask: f32[B, Hkv, C, T]     — additive (causality + DMS + validity
                                    pre-combined by the caller).

    Returns:
      out:  f32[B, Hkv, G, C, hd]
    """
    hd = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, q.dtype))
    scores = jnp.einsum("bhgcd,bhtd->bhgct", q, k) * scale
    scores = scores + mask[:, :, None, :, :]
    w = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    return jnp.einsum("bhgct,bhtd->bhgcd", w, v)
