"""Layer-2: GQA Transformer LM with DMS (paper §3), in JAX.

One model definition serves three roles:

  * ``forward_train`` — full-sequence forward used for pretraining and
    for DMS/DMC retrofitting (continuous α, training mask M_α);
  * ``prefill_chunk`` — C-token chunked prefill over an external slot
    cache (AOT-exported; DMS sparsity applied intra-chunk with binary α);
  * ``decode_step``  — single-token decode over the slot cache with
    per-(layer, KV-head) additive masks and in-graph Quest page
    selection (AOT-exported; the Rust engine drives it).

α extraction follows App. B: the first neuron of the first query head in
each query group is re-purposed as the eviction logit (no new
parameters); after the zeroing phase that neuron no longer contributes to
attention.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from . import dms
from .kernels import attention as K
from .kernels import ref as R

NEG_INF = -1e9


@dataclass(frozen=True)
class Config:
    vocab: int = 64
    d_model: int = 128
    n_layers: int = 4
    n_q_heads: int = 8
    n_kv_heads: int = 2
    head_dim: int = 16
    d_ff: int = 256
    max_pos: int = 512
    rope_base: float = 10000.0
    page_size: int = 16

    @property
    def group(self) -> int:
        return self.n_q_heads // self.n_kv_heads

    def as_dict(self) -> dict:
        return {
            "vocab": self.vocab,
            "d_model": self.d_model,
            "n_layers": self.n_layers,
            "n_q_heads": self.n_q_heads,
            "n_kv_heads": self.n_kv_heads,
            "head_dim": self.head_dim,
            "d_ff": self.d_ff,
            "max_pos": self.max_pos,
            "rope_base": self.rope_base,
            "page_size": self.page_size,
        }


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------


def init_params(cfg: Config, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)

    def w(*shape, scale=0.02):
        return jnp.asarray(rng.normal(0.0, scale, shape), jnp.float32)

    d, hd = cfg.d_model, cfg.head_dim
    params = {
        "embed": w(cfg.vocab, d),
        "ln_f": jnp.ones((d,), jnp.float32),
        "lm_head": w(d, cfg.vocab),
        "layers": [],
    }
    out_scale = 0.02 / np.sqrt(2 * cfg.n_layers)
    for _ in range(cfg.n_layers):
        params["layers"].append(
            {
                "ln1": jnp.ones((d,), jnp.float32),
                "wq": w(d, cfg.n_q_heads * hd),
                "wk": w(d, cfg.n_kv_heads * hd),
                "wv": w(d, cfg.n_kv_heads * hd),
                "wo": w(cfg.n_q_heads * hd, d, scale=out_scale),
                "ln2": jnp.ones((d,), jnp.float32),
                "w1": w(d, cfg.d_ff),
                "w3": w(d, cfg.d_ff),
                "w2": w(cfg.d_ff, d, scale=out_scale),
            }
        )
    return params


def rmsnorm(x, g):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6) * g


def rope_tables(cfg: Config):
    half = cfg.head_dim // 2
    freqs = cfg.rope_base ** (-jnp.arange(half, dtype=jnp.float32) / half)
    angles = jnp.arange(cfg.max_pos, dtype=jnp.float32)[:, None] * freqs[None, :]
    return jnp.cos(angles), jnp.sin(angles)  # [max_pos, half]


def apply_rope(x, positions, cos_tab, sin_tab):
    """x: [..., hd]; positions broadcastable to x.shape[:-1]."""
    half = x.shape[-1] // 2
    cos = jnp.take(cos_tab, positions, axis=0)
    sin = jnp.take(sin_tab, positions, axis=0)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


# --------------------------------------------------------------------------
# Shared projection helper
# --------------------------------------------------------------------------


def _qkv(layer, x, cfg: Config, q_first_scale):
    """Project x [..., d] -> q [..., Hq, hd], k/v [..., Hkv, hd], α logits.

    α logit for KV head h = q[..., h*G, 0] + b  (App. B); the neuron's
    attention contribution is scaled by ``q_first_scale`` (1 during
    pretraining, annealed to 0 in the zeroing phase, 0 afterwards).
    """
    hd = cfg.head_dim
    q = (x @ layer["wq"]).reshape(*x.shape[:-1], cfg.n_q_heads, hd)
    k = (x @ layer["wk"]).reshape(*x.shape[:-1], cfg.n_kv_heads, hd)
    v = (x @ layer["wv"]).reshape(*x.shape[:-1], cfg.n_kv_heads, hd)
    first = jnp.arange(cfg.n_kv_heads) * cfg.group
    alpha_logit = q[..., first, 0] + dms.ALPHA_BIAS  # [..., Hkv]
    scale_vec = jnp.ones((cfg.n_q_heads,), q.dtype).at[first].set(q_first_scale)
    q = q.at[..., 0].multiply(scale_vec)
    return q, k, v, alpha_logit


def _mlp(layer, x):
    return (jax.nn.silu(x @ layer["w1"]) * (x @ layer["w3"])) @ layer["w2"]


# --------------------------------------------------------------------------
# Training forward (full sequence)
# --------------------------------------------------------------------------


def forward_train(
    params,
    cfg: Config,
    tokens,           # i32[B, T]
    valid,            # f32[B, T]
    *,
    alpha_mode: str = "off",   # off | dms | dms_immediate | dmc
    window: int = 16,
    gumbel_key=None,           # PRNGKey -> stochastic α; None -> hard α
    q_first_scale: float = 1.0,
):
    """Returns (logits f32[B,T,V], alphas f32[L,B,Hkv,T])."""
    b, t = tokens.shape
    cos_tab, sin_tab = rope_tables(cfg)
    positions = jnp.arange(t)[None, :].repeat(b, axis=0)
    h = jnp.take(params["embed"], tokens, axis=0)

    i = jnp.arange(t)[:, None]
    j = jnp.arange(t)[None, :]
    causal = jnp.where(j <= i, 0.0, NEG_INF)[None, None]              # [1,1,T,T]
    key_valid = jnp.where(valid > 0, 0.0, NEG_INF)[:, None, None, :]  # [B,1,1,T]

    alphas = []
    for li, layer in enumerate(params["layers"]):
        x = rmsnorm(h, layer["ln1"])
        q, k, v, alpha_logit = _qkv(layer, x, cfg, q_first_scale)
        if alpha_mode == "off":
            alpha = jnp.zeros((b, t, cfg.n_kv_heads), h.dtype)
        elif gumbel_key is not None:
            alpha = dms.gumbel_sigmoid(
                alpha_logit, jax.random.fold_in(gumbel_key, li)
            )
        else:
            alpha = (alpha_logit > 0).astype(h.dtype)
        alpha = alpha * valid[:, :, None]
        alpha_bht = jnp.moveaxis(alpha, -1, 1)  # [B, Hkv, T]
        alphas.append(alpha_bht)

        q = apply_rope(q, positions[:, :, None], cos_tab, sin_tab)
        k = apply_rope(k, positions[:, :, None], cos_tab, sin_tab)
        qg = jnp.moveaxis(
            q.reshape(b, t, cfg.n_kv_heads, cfg.group, cfg.head_dim), 1, 3
        )  # [B, Hkv, G, T, hd]
        kg = jnp.moveaxis(k, 1, 2)  # [B, Hkv, T, hd]
        vg = jnp.moveaxis(v, 1, 2)

        if alpha_mode == "dmc":
            kg, vg, _ = dms.dmc_accumulate(kg, vg, alpha_bht)
            mask = jnp.maximum(key_valid + dms.build_dmc_mask(alpha_bht), NEG_INF)
        elif alpha_mode in ("dms", "dms_immediate"):
            m_alpha = dms.build_dms_mask(
                alpha_bht, window, immediate=(alpha_mode == "dms_immediate")
            )
            mask = jnp.maximum(key_valid + m_alpha, NEG_INF)
        else:
            mask = jnp.broadcast_to(causal + key_valid, (b, 1, t, t))
        mask = jnp.broadcast_to(mask, (b, cfg.n_kv_heads, t, t))

        out = R.chunk_attn_ref(qg, kg, vg, mask)  # [B, Hkv, G, T, hd]
        out = jnp.moveaxis(out, 3, 1).reshape(b, t, cfg.n_q_heads * cfg.head_dim)
        h = h + out @ layer["wo"]
        h = h + _mlp(layer, rmsnorm(h, layer["ln2"]))

    h = rmsnorm(h, params["ln_f"])
    logits = h @ params["lm_head"]
    return logits, jnp.stack(alphas)  # [L, B, Hkv, T]


# --------------------------------------------------------------------------
# Decode step (AOT-exported; fixed B, S)
# --------------------------------------------------------------------------


def decode_step(
    params,
    cfg: Config,
    k_cache,    # f32[L, B, Hkv, S, hd]  (keys stored post-RoPE)
    v_cache,    # f32[L, B, Hkv, S, hd]
    tokens,     # i32[B]
    positions,  # i32[B]
    mask,       # f32[L, B, Hkv, S] additive (0 live / NEG_INF dead)
    pmin,       # f32[L, B, Hkv, P, hd]  Quest page lower bounds
    pmax,       # f32[L, B, Hkv, P, hd]  Quest page upper bounds
    quest_k,    # i32[]  pages kept per head; >= P disables Quest
    *,
    use_pallas: bool = True,
):
    """One decode step over the slot cache.

    Returns (logits [B,V], k_new [L,B,Hkv,hd], v_new, alpha [L,B,Hkv],
    attn [L,B,Hkv,S], attn_self [L,B,Hkv], qsel [L,B,Hkv,P]).
    """
    l, b, hkv, s, hd = k_cache.shape
    p = pmin.shape[3]
    ps = s // p
    cos_tab, sin_tab = rope_tables(cfg)
    h = jnp.take(params["embed"], tokens, axis=0)  # [B, d]

    k_news, v_news, alphas, attns, attn_selfs, qsels = [], [], [], [], [], []

    def attn_fn(q_, k_, v_, m_):
        if use_pallas:
            return K.decode_attn(q_, k_, v_, m_)
        return R.decode_attn_ref(q_, k_, v_, m_)

    for li, layer in enumerate(params["layers"]):
        x = rmsnorm(h, layer["ln1"])
        q, k, v, alpha_logit = _qkv(layer, x, cfg, 0.0)  # [B,Hq,hd] / [B,Hkv,hd]
        q = apply_rope(q, positions[:, None], cos_tab, sin_tab)
        k = apply_rope(k, positions[:, None], cos_tab, sin_tab)
        qg = q.reshape(b, hkv, cfg.group, hd)

        lm = mask[li]  # [B, Hkv, S]
        # ---- Quest page selection (in-graph; App. F.1 semantics) ----
        page_live = jnp.any(
            lm.reshape(b, hkv, p, ps) > NEG_INF / 2, axis=-1
        )  # [B, Hkv, P]
        qs = qg[:, :, :, None, :]  # [B,Hkv,G,1,hd]
        hi = jnp.maximum(qs * pmin[li][:, :, None], qs * pmax[li][:, :, None])
        scores = jnp.sum(hi, axis=-1)  # [B, Hkv, G, P]
        scores = jnp.where(page_live[:, :, None, :], scores, NEG_INF)
        # rank pages per query head; selected iff rank < quest_k
        order = jnp.argsort(-scores, axis=-1)
        ranks = jnp.argsort(order, axis=-1)
        sel_per_qh = ranks < quest_k  # [B, Hkv, G, P]
        selected = jnp.any(sel_per_qh, axis=2) & page_live  # union over group
        qmask = jnp.where(selected, 0.0, NEG_INF)  # [B, Hkv, P]
        lm = jnp.maximum(lm + jnp.repeat(qmask, ps, axis=-1), NEG_INF)
        qsels.append(selected.astype(jnp.float32))

        # ---- attention over cache ∪ {current token} ----
        k_full = jnp.concatenate([k_cache[li], k.reshape(b, hkv, 1, hd)], axis=2)
        v_full = jnp.concatenate([v_cache[li], v.reshape(b, hkv, 1, hd)], axis=2)
        m_full = jnp.concatenate([lm, jnp.zeros((b, hkv, 1), lm.dtype)], axis=2)
        out, attn_w = attn_fn(qg, k_full, v_full, m_full)
        out = out.reshape(b, cfg.n_q_heads * hd)
        h = h + out @ layer["wo"]
        h = h + _mlp(layer, rmsnorm(h, layer["ln2"]))

        k_news.append(k)
        v_news.append(v)
        alphas.append(jax.nn.sigmoid(alpha_logit))
        attns.append(attn_w[:, :, :s])
        attn_selfs.append(attn_w[:, :, s])

    h = rmsnorm(h, params["ln_f"])
    logits = h @ params["lm_head"]
    return (
        logits,
        jnp.stack(k_news),
        jnp.stack(v_news),
        jnp.stack(alphas),
        jnp.stack(attns),
        jnp.stack(attn_selfs),
        jnp.stack(qsels),
    )


# --------------------------------------------------------------------------
# Prefill chunk (AOT-exported; fixed B, C, S)
# --------------------------------------------------------------------------


def prefill_chunk(
    params,
    cfg: Config,
    k_cache,     # f32[L, B, Hkv, S, hd]
    v_cache,     # f32[L, B, Hkv, S, hd]
    cache_mask,  # f32[L, B, Hkv, S]
    tokens,      # i32[B, C]
    positions,   # i32[B, C]
    valid,       # f32[B, C] (1 real token / 0 pad)
    *,
    window: int = 16,
    immediate: bool = False,
    dms_enabled: bool = True,
    use_pallas: bool = True,
):
    """Process a chunk of C prompt tokens against the existing cache.

    DMS sparsity is applied *within* the chunk with binary α (delayed or
    immediate, matching the retrofit variant); cross-chunk eviction is
    executed by the Rust engine between chunk calls using the returned α.

    Returns (logits [B,C,V], k_new [L,B,Hkv,C,hd], v_new, alpha [L,B,Hkv,C]).
    """
    l, b, hkv, s, hd = k_cache.shape
    c = tokens.shape[1]
    cos_tab, sin_tab = rope_tables(cfg)
    h = jnp.take(params["embed"], tokens, axis=0)  # [B, C, d]

    i = jnp.arange(c)[:, None]
    j = jnp.arange(c)[None, :]
    causal = jnp.where(j <= i, 0.0, NEG_INF)[None, None]              # [1,1,C,C]
    key_valid = jnp.where(valid > 0, 0.0, NEG_INF)[:, None, None, :]  # [B,1,1,C]
    beyond = (i >= j + window).astype(jnp.float32)[None, None]

    k_news, v_news, alphas = [], [], []
    attn_fn = K.chunk_attn if use_pallas else R.chunk_attn_ref

    for li, layer in enumerate(params["layers"]):
        x = rmsnorm(h, layer["ln1"])
        q, k, v, alpha_logit = _qkv(layer, x, cfg, 0.0)  # [B,C,Hq,hd]
        if dms_enabled:
            alpha = (alpha_logit > 0).astype(jnp.float32) * valid[:, :, None]
        else:
            alpha = jnp.zeros((b, c, hkv), jnp.float32)
        alpha_bhc = jnp.moveaxis(alpha, -1, 1)  # [B, Hkv, C]
        alphas.append(alpha_bhc)

        q = apply_rope(q, positions[:, :, None], cos_tab, sin_tab)
        k = apply_rope(k, positions[:, :, None], cos_tab, sin_tab)
        qg = jnp.moveaxis(q.reshape(b, c, hkv, cfg.group, hd), 1, 3)
        kg = jnp.moveaxis(k, 1, 2)  # [B,Hkv,C,hd]
        vg = jnp.moveaxis(v, 1, 2)

        # intra-chunk mask with binary α (delayed or immediate eviction)
        if immediate:
            dec_idx = jnp.minimum(jnp.arange(c) + window, c - 1)
            in_range = (jnp.arange(c) + window <= c - 1).astype(jnp.float32)
            a_eff = alpha_bhc[:, :, dec_idx] * in_range[None, None, :]
        else:
            a_eff = alpha_bhc
        evict = jnp.where(a_eff > 0.5, NEG_INF, 0.0)  # [B,Hkv,C]
        intra = causal + key_valid + beyond * evict[:, :, None, :]
        intra = jnp.maximum(intra, NEG_INF)
        intra = jnp.broadcast_to(intra, (b, hkv, c, c))

        cache_part = jnp.broadcast_to(
            cache_mask[li][:, :, None, :], (b, hkv, c, s)
        )
        m_full = jnp.concatenate([cache_part, intra], axis=-1)  # [B,Hkv,C,S+C]
        k_full = jnp.concatenate([k_cache[li], kg], axis=2)
        v_full = jnp.concatenate([v_cache[li], vg], axis=2)

        out = attn_fn(qg, k_full, v_full, m_full)  # [B,Hkv,G,C,hd]
        out = jnp.moveaxis(out, 3, 1).reshape(b, c, cfg.n_q_heads * hd)
        h = h + out @ layer["wo"]
        h = h + _mlp(layer, rmsnorm(h, layer["ln2"]))

        k_news.append(kg)
        v_news.append(vg)

    h = rmsnorm(h, params["ln_f"])
    logits = h @ params["lm_head"]
    return logits, jnp.stack(k_news), jnp.stack(v_news), jnp.stack(alphas)
