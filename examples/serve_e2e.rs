// Shared lint config for non-lib targets (benches/tests/examples are
// separate crates, so the crate-wide allows in rust/src/lib.rs do not
// reach them): the same flat-layout indexing idiom applies here, and
// vec! payloads deliberately mirror the engine's heap buffers.
// Correctness lints stay on — CI denies all remaining warnings via
// `cargo clippy --all-targets -- -D warnings`.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::manual_div_ceil,
    clippy::uninlined_format_args,
    clippy::useless_vec
)]

//! End-to-end serving driver (the repo's E2E validation run): start the
//! TCP server with the DMS model, fire a batch of concurrent client
//! requests (parallel-scaling W=4 reasoning queries), and report
//! accuracy, latency percentiles, throughput, and KV budget use.
//!
//! Run:  cargo run --release --example serve_e2e -- \
//!           [--requests 12] [--width 4] [--policy dms --cr 4]
//!
//! Results are recorded in EXPERIMENTS.md §E2E.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use hyperscale::compress::PolicyKind;
use hyperscale::config::EngineConfig;
use hyperscale::server::{serve, Client};
use hyperscale::tasks::gen_problem;
use hyperscale::util::{Args, Json};

fn main() -> hyperscale::Result<()> {
    let args = Args::from_env();
    let n_requests = args.get_usize("requests", 12)?;
    let width = args.get_usize("width", 4)?;
    let addr = args.get_str("addr", "127.0.0.1:7441").to_string();
    let policy: PolicyKind = args.get_str("policy", "dms").parse()?;
    let cr = args.get_f64("cr", 4.0)?;
    let variant = args
        .get("variant")
        .map(String::from)
        .unwrap_or_else(|| policy.default_variant(cr).to_string());

    let cfg = EngineConfig {
        artifacts: args.get_str("artifacts", "artifacts").into(),
        variant,
        policy,
        cr,
        temperature: 0.7,
        ..Default::default()
    };

    // server thread (owns the engine)
    let saddr = addr.clone();
    let server = std::thread::spawn(move || {
        if let Err(e) = serve(cfg, &saddr) {
            eprintln!("server error: {e:#}");
        }
    });
    std::thread::sleep(Duration::from_millis(300));
    // wait for the server to accept (compilation takes a few seconds)
    let mut probe = None;
    for _ in 0..100 {
        match Client::connect(&addr) {
            Ok(c) => {
                probe = Some(c);
                break;
            }
            Err(_) => std::thread::sleep(Duration::from_millis(200)),
        }
    }
    let Some(_probe) = probe else {
        anyhow::bail!("server did not come up");
    };

    // client load: n_requests problems, 3 concurrent client threads
    let t_start = Instant::now();
    let (tx, rx) = mpsc::channel();
    let n_clients = 3usize;
    for c in 0..n_clients {
        let tx = tx.clone();
        let addr = addr.clone();
        std::thread::spawn(move || {
            let mut client = Client::connect(&addr).expect("connect");
            let mut i = c as u64;
            while i < n_requests as u64 {
                let p = gen_problem("gsm8k", 7, i);
                let req = Json::obj()
                    .set("id", i)
                    .set("prompt", p.prompt.as_str())
                    .set("width", width)
                    .set("max_len", 192usize)
                    .set("temperature", 0.7)
                    .set("seed", i);
                let t0 = Instant::now();
                let resp = client.call(&req).expect("call");
                let latency = t0.elapsed().as_secs_f64();
                let correct = resp.get("answer").and_then(Json::as_str)
                    == Some(p.answer.as_str());
                let reads = resp
                    .get("reads")
                    .and_then(|x| x.as_f64())
                    .unwrap_or(0.0);
                let peak = resp
                    .get("peak_tokens")
                    .and_then(|x| x.as_f64())
                    .unwrap_or(0.0);
                let ttft = resp
                    .get("ttft_ms")
                    .and_then(|x| x.as_f64())
                    .unwrap_or(0.0);
                tx.send((latency, correct, reads, peak, ttft)).unwrap();
                i += n_clients as u64;
            }
        });
    }
    drop(tx);

    let mut latencies = Vec::new();
    let mut ttfts = Vec::new();
    let mut correct = 0usize;
    let mut reads = 0.0;
    let mut peak: f64 = 0.0;
    for (lat, ok, r, p, ttft) in rx {
        latencies.push(lat);
        ttfts.push(ttft);
        if ok {
            correct += 1;
        }
        reads += r;
        peak = peak.max(p);
    }
    let wall = t_start.elapsed().as_secs_f64();

    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| latencies[(p * (latencies.len() - 1) as f64) as usize];
    println!("\n=== serve_e2e report ===");
    println!("policy {} CR {cr} width {width}", policy.name());
    println!("requests: {} (x{} chains)", latencies.len(), width);
    println!(
        "accuracy (majority vote): {:.1}%",
        100.0 * correct as f64 / latencies.len() as f64
    );
    println!(
        "latency s: p50 {:.2}  p90 {:.2}  max {:.2}",
        pct(0.5),
        pct(0.9),
        latencies.last().unwrap()
    );
    ttfts.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!(
        "TTFT ms (server-side): p50 {:.1}  max {:.1}",
        ttfts[ttfts.len() / 2],
        ttfts.last().unwrap()
    );
    println!(
        "throughput: {:.2} req/s ({:.1} chains/s)",
        latencies.len() as f64 / wall,
        (latencies.len() * width) as f64 / wall
    );
    println!(
        "KV reads total: {:.0} token-units   peak per-request memory: {:.1} tokens",
        reads, peak
    );

    // shut the server down
    let mut c = Client::connect(&addr)?;
    c.shutdown()?;
    let _ = server.join();
    Ok(())
}
