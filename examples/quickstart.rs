// Shared lint config for non-lib targets (benches/tests/examples are
// separate crates, so the crate-wide allows in rust/src/lib.rs do not
// reach them): the same flat-layout indexing idiom applies here, and
// vec! payloads deliberately mirror the engine's heap buffers.
// Correctness lints stay on — CI denies all remaining warnings via
// `cargo clippy --all-targets -- -D warnings`.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::manual_div_ceil,
    clippy::uninlined_format_args,
    clippy::useless_vec
)]

//! Quickstart: load the DMS-retrofitted model, generate a reasoning
//! chain for one arithmetic problem, and print the efficiency stats.
//!
//! Run:  cargo run --release --example quickstart -- [--artifacts DIR]

use hyperscale::compress::PolicyKind;
use hyperscale::config::EngineConfig;
use hyperscale::engine::{Engine, GenRequest};
use hyperscale::tasks::{extract_answer, gen_problem};
use hyperscale::util::Args;

fn main() -> hyperscale::Result<()> {
    let args = Args::from_env();
    let artifacts = args.get_str("artifacts", "artifacts");

    // 1. an engine with the DMS CR4 model + delayed-eviction policy
    let mut engine = Engine::new(EngineConfig {
        artifacts: artifacts.into(),
        variant: "dms_w16_cr4".into(),
        policy: PolicyKind::Dms,
        cr: 4.0,
        temperature: 0.0,
        ..Default::default()
    })?;

    // 2. a synthetic chain-of-thought problem (MATH-500 analog)
    let problem = gen_problem("math", 42, 0);
    println!("prompt:   {:?}", problem.prompt);
    println!("gold:     {}", problem.answer);

    // 3. generate
    let res = engine.generate(GenRequest {
        prompt: problem.prompt.clone(),
        width: 1,
        max_len: 160,
        temperature: 0.0,
        seed: 0,
    })?;
    let chain = &res.chains[0];
    println!("model:    {:?}", chain.text);
    println!("answer:   {:?}", extract_answer(&chain.text));

    // 4. the paper's efficiency metrics for this generation
    println!(
        "KV reads: {:.0} token-units   peak memory: {:.1} tokens   achieved CR: {:.2}x",
        chain.stats.total_reads(),
        chain.stats.peak_tokens,
        chain.stats.achieved_cr()
    );
    Ok(())
}
