// Shared lint config for non-lib targets (benches/tests/examples are
// separate crates, so the crate-wide allows in rust/src/lib.rs do not
// reach them): the same flat-layout indexing idiom applies here, and
// vec! payloads deliberately mirror the engine's heap buffers.
// Correctness lints stay on — CI denies all remaining warnings via
// `cargo clippy --all-targets -- -D warnings`.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::manual_div_ceil,
    clippy::uninlined_format_args,
    clippy::useless_vec
)]

//! Inference-time hyper-scaling demo (the paper's headline experiment,
//! condensed): sweep L-W-CR configurations for vanilla vs DMS on one
//! reasoning task and print both Pareto frontiers.
//!
//! Run:  cargo run --release --example hyperscale_sweep -- \
//!           [--task aime] [--n 10] [--artifacts DIR]

use hyperscale::compress::PolicyKind;
use hyperscale::config::EngineConfig;
use hyperscale::experiments::{EvalSpec, Harness};
use hyperscale::scaling::{frontier, margin, ScalePoint};
use hyperscale::util::Args;

fn main() -> hyperscale::Result<()> {
    let args = Args::from_env();
    let task = args.get_str("task", "aime").to_string();
    let n = args.get_usize("n", 10)?;
    let mut harness = Harness::new(EngineConfig {
        artifacts: args.get_str("artifacts", "artifacts").into(),
        ..Default::default()
    })?;

    let mut clouds: Vec<(&str, Vec<ScalePoint>)> = Vec::new();
    for (name, policy, crs) in [
        ("vanilla", PolicyKind::Vanilla, vec![1.0]),
        ("dms", PolicyKind::Dms, vec![4.0, 8.0]),
    ] {
        let mut points = Vec::new();
        for &(l, w) in &[(96usize, 1usize), (96, 4), (192, 1), (192, 4), (192, 8)] {
            for &cr in &crs {
                let mut spec = EvalSpec::new(&task, policy, cr);
                spec.max_len = l;
                spec.width = w;
                spec.n_problems = n;
                let out = harness.eval(&spec)?;
                if out.n_problems == 0 {
                    continue;
                }
                println!(
                    "{name:8} {l}-{w}-{cr}: acc {:.2} reads {:>7.0} peak {:>6.1} ({:.1}s)",
                    out.accuracy, out.mean_reads, out.mean_peak, out.wall_s
                );
                points.push(ScalePoint {
                    budget: out.mean_reads,
                    accuracy: out.accuracy,
                    label: format!("{l}-{w}-{cr}"),
                });
            }
        }
        clouds.push((name, points));
    }

    println!("\nPareto frontiers (accuracy vs KV reads):");
    let mut fronts = Vec::new();
    for (name, points) in &clouds {
        let f = frontier(points);
        print!("  {name:8}");
        for p in &f.points {
            print!("  {}:{:.0}→{:.0}%", p.label, p.budget, 100.0 * p.accuracy);
        }
        println!();
        fronts.push(f);
    }
    if let Some(m) = margin(&fronts[1], &fronts[0]) {
        println!(
            "\nDMS vs vanilla average frontier margin (App. E): {:+.1} points",
            100.0 * m
        );
    } else {
        println!("\nfrontier projections disjoint (NA)");
    }
    Ok(())
}
