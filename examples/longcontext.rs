// Shared lint config for non-lib targets (benches/tests/examples are
// separate crates, so the crate-wide allows in rust/src/lib.rs do not
// reach them): the same flat-layout indexing idiom applies here, and
// vec! payloads deliberately mirror the engine's heap buffers.
// Correctness lints stay on — CI denies all remaining warnings via
// `cargo clippy --all-targets -- -D warnings`.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::manual_div_ceil,
    clippy::uninlined_format_args,
    clippy::useless_vec
)]

//! Long-context retention demo: needle-in-a-haystack at growing context
//! lengths for every compression policy — the Table 2 phenomenon in a
//! runnable example (watch H2O/TOVA drop the needle while DMS keeps it).
//!
//! Run:  cargo run --release --example longcontext -- [--n 8]

use hyperscale::compress::PolicyKind;
use hyperscale::config::EngineConfig;
use hyperscale::engine::{aggregate, Engine, GenRequest};
use hyperscale::tasks::gen_niah_with_fillers;
use hyperscale::util::Args;

fn main() -> hyperscale::Result<()> {
    let args = Args::from_env();
    let n = args.get_usize("n", 8)?;
    let cr = args.get_f64("cr", 4.0)?;
    let mut engine = Engine::new(EngineConfig {
        artifacts: args.get_str("artifacts", "artifacts").into(),
        temperature: 0.0,
        ..Default::default()
    })?;

    println!("NIAH accuracy by context size (CR {cr}x, greedy):\n");
    println!("{:>12} {:>9} {:>9} {:>9}", "policy", "short", "medium", "long");
    for (policy, variant) in [
        (PolicyKind::Vanilla, "base"),
        (PolicyKind::Dms, "dms_w16_cr4"),
        (PolicyKind::Quest, "base"),
        (PolicyKind::Tova, "base"),
        (PolicyKind::H2o, "base"),
        (PolicyKind::Dmc, "dmc"),
    ] {
        engine.set_variant(variant)?;
        engine.set_policy(
            policy,
            if policy == PolicyKind::Vanilla { 1.0 } else { cr },
        )?;
        print!("{:>12}", policy.name());
        for fillers in [4usize, 8, 12] {
            let mut requests = Vec::new();
            let mut golds = Vec::new();
            for i in 0..n as u64 {
                let p = gen_niah_with_fillers(3, i, fillers);
                if p.prompt.len() + 12 > engine.geometry().slots {
                    continue;
                }
                let max_len = p.prompt.len() + 12;
                requests.push(GenRequest {
                    prompt: p.prompt,
                    width: 1,
                    max_len,
                    temperature: 0.0,
                    seed: i,
                });
                golds.push(p.answer);
            }
            let (results, _) = engine.run(&requests)?;
            let ok = results
                .iter()
                .zip(&golds)
                .filter(|(r, g)| aggregate("niah", &r.texts(), g))
                .count();
            print!(" {:>8.0}%", 100.0 * ok as f64 / results.len().max(1) as f64);
        }
        println!();
    }
    Ok(())
}
