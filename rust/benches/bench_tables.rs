// Shared lint config for non-lib targets (benches/tests/examples are
// separate crates, so the crate-wide allows in rust/src/lib.rs do not
// reach them): the same flat-layout indexing idiom applies here, and
// vec! payloads deliberately mirror the engine's heap buffers.
// Correctness lints stay on — CI denies all remaining warnings via
// `cargo clippy --all-targets -- -D warnings`.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::manual_div_ceil,
    clippy::uninlined_format_args,
    clippy::useless_vec
)]

//! End-to-end experiment benches — reduced-size runs of every paper
//! table/figure driver, verifying each regenerates within budget.
//! (`--n`/`--full` on the `hyperscale exp` CLI produce the real ones.)

use hyperscale::experiments as exp;
use hyperscale::util::{timer::timed, Args};

fn main() -> hyperscale::Result<()> {
    let args = Args::from_env();
    let artifacts = std::path::PathBuf::from(args.get_str("artifacts", "artifacts"));
    let n = args.get_usize("n", 4)?;
    println!("# bench_tables — reduced paper-experiment regeneration (n={n})");

    let ((), t) = timed(|| exp::run_fig7(&artifacts).expect("fig7"));
    println!("bench table:fig7      {t:>8.2}s");

    let (_, t) = timed(|| {
        exp::run_pareto(&artifacts, &["math".to_string()], n, false).expect("pareto")
    });
    println!("bench table:fig3/4    {t:>8.2}s (task=math)");

    let ((), t) = timed(|| exp::run_fig1(&artifacts).expect("fig1"));
    println!("bench table:fig1      {t:>8.2}s");

    let ((), t) = timed(|| exp::run_fig5(&artifacts, n).expect("fig5"));
    println!("bench table:fig5      {t:>8.2}s");

    let ((), t) = timed(|| exp::run_fig6(&artifacts, n).expect("fig6"));
    println!("bench table:fig6      {t:>8.2}s");

    let ((), t) = timed(|| exp::run_points(&artifacts, n).expect("points"));
    println!("bench table:7/8/9     {t:>8.2}s");

    let ((), t) = timed(|| exp::run_table1(&artifacts, n, false).expect("table1"));
    println!("bench table:1/4       {t:>8.2}s");

    let ((), t) = timed(|| exp::run_table2(&artifacts, n).expect("table2"));
    println!("bench table:2         {t:>8.2}s");
    Ok(())
}
