// Shared lint config for non-lib targets (benches/tests/examples are
// separate crates, so the crate-wide allows in rust/src/lib.rs do not
// reach them): the same flat-layout indexing idiom applies here, and
// vec! payloads deliberately mirror the engine's heap buffers.
// Correctness lints stay on — CI denies all remaining warnings via
// `cargo clippy --all-targets -- -D warnings`.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::manual_div_ceil,
    clippy::uninlined_format_args,
    clippy::useless_vec
)]

//! Deterministic cluster timing bench over `engine::timeflow` — a perf
//! *model* in CI, not a wall-clock bench. Every gated number is a pure
//! function of the seed and the priced cost model, so the ±25%
//! `bench_compare` tolerance exists only to absorb pathological
//! last-ulp divergence between platforms; two consecutive runs on the
//! same machine are bit-identical (asserted inline, and again by the
//! CI `sim-gate` job which `cmp`s two `--out` files).
//!
//! Scenario groups:
//!
//! * `cost.*` — the priced per-stage ns constants (App. G latency
//!   model × payload dtype), pinned analytically by
//!   `tools/seed_bench_sim.py`;
//! * `uncontended.*` — round-robin over 4 replicas with arrival gaps
//!   far above worst-case service: zero queueing, so p50/p99/p999
//!   TTFT, span, and tokens/s are closed-form (seeder-pinned);
//! * `workload.*` — integer draw totals of the contended grid
//!   workload (seeder-pinned);
//! * `grid.*` — the routing×steal sweep under Poisson + bursty
//!   contention (structurally gated until refreshed from a CI
//!   artifact — queueing values are model-stable but not worth
//!   hand-deriving);
//! * `fail.*` — replica-death conservation: settled == requests is
//!   pinned; the completed/failed split is structural;
//! * `alloc.*` — budget-conserving allocators must price decode
//!   identically (plan *total*, not shape, sets the memory share).
//!
//! Without `--smoke`, a 64→512-replica sweep over large synthetic
//! workloads is also run and reported as info (wall-clock only).

use hyperscale::compress::AllocatorKind;
use hyperscale::config::RoutingPolicy;
use hyperscale::engine::timeflow::{
    generate_workload, simulate, Arrival, CostModel, ReplicaFailure, SimReport, TimeflowConfig,
    WorkloadSpec,
};
use hyperscale::kvcache::KvDtype;
use hyperscale::util::{Args, Json};
use std::time::Instant;

/// Workload seed for every gated scenario (any fixed value works; the
/// baselines are seeded for this one).
const SEED: u64 = 0x51D_CAFE;

/// The contended grid spec: 8 replicas × 2 lanes, Poisson arrivals at
/// ~80% of modeled capacity, q8 payloads.
fn grid_spec(cost: &CostModel, replicas: usize, lanes: usize, requests: usize) -> WorkloadSpec {
    let mut spec = WorkloadSpec::new(requests, SEED);
    // mean service of one request (mean prompt 64, mean gen 40 tokens)
    let service_ns = 64 * cost.prefill_ns + 40 * cost.decode_ns;
    // arrival rate = 0.8 × cluster capacity
    spec.mean_gap_ns = service_ns * 10 / (8 * (replicas * lanes) as u64);
    spec
}

fn assert_bit_identical(a: &SimReport, b: &SimReport, label: &str) {
    assert_eq!(
        a.registry.histogram_samples("sim.ttft_ns"),
        b.registry.histogram_samples("sim.ttft_ns"),
        "{label}: TTFT histograms diverged between identical runs"
    );
    assert_eq!(a.span_ns, b.span_ns, "{label}: span diverged");
    assert_eq!(
        a.tokens_per_s.to_bits(),
        b.tokens_per_s.to_bits(),
        "{label}: tokens/s diverged"
    );
}

fn smoke_scenarios() -> (Json, Json) {
    let mut gated = Json::obj();
    let mut info = Json::obj();

    // ------------------------------------------------------------------
    // cost.* — priced constants
    // ------------------------------------------------------------------
    println!("# cost model (Llama 3.1 8B on H100, per-token ns)");
    for dtype in [KvDtype::F32, KvDtype::Q8, KvDtype::Q4] {
        let c = CostModel::default_for(dtype, AllocatorKind::Uniform);
        println!(
            "  {:<4} prefill {:>7} decode {:>7} dequant {:>6} cold_hit {:>6} kvB/tok {:>7}",
            dtype.name(),
            c.prefill_ns,
            c.decode_ns,
            c.dequant_ns,
            c.cold_hit_ns,
            c.kv_bytes_per_token
        );
        gated = gated
            .set(&format!("cost.{}.prefill_ns", dtype.name()), c.prefill_ns)
            .set(&format!("cost.{}.decode_ns", dtype.name()), c.decode_ns)
            .set(&format!("cost.{}.dequant_ns", dtype.name()), c.dequant_ns)
            .set(&format!("cost.{}.cold_hit_ns", dtype.name()), c.cold_hit_ns);
    }

    // ------------------------------------------------------------------
    // alloc.* — budget-conserving plans price decode identically
    // ------------------------------------------------------------------
    for alloc in AllocatorKind::all() {
        let c = CostModel::default_for(KvDtype::Q8, alloc);
        gated = gated.set(&format!("alloc.q8.decode_ns.{}", alloc.name()), c.decode_ns);
    }

    // ------------------------------------------------------------------
    // uncontended.* — closed-form scenario
    // ------------------------------------------------------------------
    let mut cfg = TimeflowConfig::new(4, 1, RoutingPolicy::RoundRobin);
    cfg.steal = false;
    cfg.prefix_cache = false;
    let mut spec = WorkloadSpec::new(2048, SEED);
    spec.arrival = Arrival::Uniform;
    spec.mean_gap_ns = 20_000_000; // 20 ms ≫ worst-case ~12 ms service
    let t0 = Instant::now();
    let rep = simulate(&cfg, &spec);
    let rep2 = simulate(&cfg, &spec);
    assert_bit_identical(&rep, &rep2, "uncontended");
    assert_eq!(rep.completed, spec.requests);
    assert_eq!(rep.stolen, 0);
    println!(
        "\n# uncontended [{}]: p50 {:.0}ns p99 {:.0}ns p999 {:.0}ns  {:.3} tok/s  ({:.2}s wall)",
        rep.label,
        rep.ttft_p50_ns,
        rep.ttft_p99_ns,
        rep.ttft_p999_ns,
        rep.tokens_per_s,
        t0.elapsed().as_secs_f64()
    );
    gated = gated
        .set("uncontended.completed", rep.completed)
        .set("uncontended.gen_tokens", rep.gen_tokens)
        .set("uncontended.ttft_p50_ns", rep.ttft_p50_ns)
        .set("uncontended.ttft_p99_ns", rep.ttft_p99_ns)
        .set("uncontended.ttft_p999_ns", rep.ttft_p999_ns)
        .set("uncontended.span_ns", rep.span_ns)
        .set("uncontended.tokens_per_s", rep.tokens_per_s);
    info = info.set("uncontended.utilization", rep.utilization);

    // ------------------------------------------------------------------
    // workload.* — integer draw totals of the contended grid workload
    // ------------------------------------------------------------------
    let q8_cost = CostModel::default_for(KvDtype::Q8, AllocatorKind::Uniform);
    let gspec = grid_spec(&q8_cost, 8, 2, 4096);
    let work = generate_workload(&gspec);
    let prompt_total: u64 = work.iter().map(|r| r.prompt_tokens as u64).sum();
    let gen_total: u64 = work.iter().map(|r| r.gen_tokens as u64).sum();
    let head_count = work.iter().filter(|r| r.prompt_id == 0).count();
    println!(
        "\n# grid workload: {} requests, {} prompt tokens, {} gen tokens, head prompt ×{}",
        work.len(),
        prompt_total,
        gen_total,
        head_count
    );
    gated = gated
        .set("workload.grid.prompt_tokens", prompt_total)
        .set("workload.grid.gen_tokens", gen_total)
        .set("workload.grid.head_count", head_count);

    // ------------------------------------------------------------------
    // grid.* — routing × steal under contention (q8 payloads)
    // ------------------------------------------------------------------
    println!("\n# grid: 8 replicas × 2 lanes, poisson @ 0.8 load, q8");
    let mut first_cell: Option<SimReport> = None;
    for routing in [
        RoutingPolicy::Prefix,
        RoutingPolicy::LeastLoaded,
        RoutingPolicy::RoundRobin,
    ] {
        for steal in [true, false] {
            let mut cfg =
                TimeflowConfig::new(8, 2, routing).with_kv(KvDtype::Q8, AllocatorKind::Uniform);
            cfg.steal = steal;
            let rep = simulate(&cfg, &gspec);
            assert_eq!(rep.completed, gspec.requests);
            let key = format!("{}-{}", routing.name(), if steal { "steal" } else { "nosteal" });
            println!(
                "  {key:<22} p99 {:>12.0}ns  {:>9.3} tok/s  util {:>5.1}%  stolen {}",
                rep.ttft_p99_ns,
                rep.tokens_per_s,
                rep.utilization * 100.0,
                rep.stolen
            );
            gated = gated
                .set(&format!("grid.{key}.ttft_p99_ns"), rep.ttft_p99_ns)
                .set(&format!("grid.{key}.tokens_per_s"), rep.tokens_per_s);
            info = info
                .set(&format!("grid.{key}.ttft_p50_ns"), rep.ttft_p50_ns)
                .set(&format!("grid.{key}.ttft_p999_ns"), rep.ttft_p999_ns)
                .set(&format!("grid.{key}.stolen"), rep.stolen)
                .set(&format!("grid.{key}.utilization"), rep.utilization);
            if first_cell.is_none() {
                // double-run the first cell: contended paths (steal,
                // transfer, affinity) must also be bit-stable
                let again = simulate(&cfg, &gspec);
                assert_bit_identical(&rep, &again, "grid.prefix-steal");
                first_cell = Some(rep);
            }
        }
    }

    // bursty arrivals through the busiest configuration
    let mut bspec = gspec;
    bspec.arrival = Arrival::Bursty;
    let cfg = TimeflowConfig::new(8, 2, RoutingPolicy::Prefix)
        .with_kv(KvDtype::Q8, AllocatorKind::Uniform);
    let rep = simulate(&cfg, &bspec);
    assert_eq!(rep.completed, bspec.requests);
    println!(
        "  {:<22} p99 {:>12.0}ns  {:>9.3} tok/s  stolen {}",
        "bursty/prefix-steal", rep.ttft_p99_ns, rep.tokens_per_s, rep.stolen
    );
    gated = gated
        .set("grid.bursty.ttft_p99_ns", rep.ttft_p99_ns)
        .set("grid.bursty.tokens_per_s", rep.tokens_per_s);

    // ------------------------------------------------------------------
    // fail.* — replica death conserves requests
    // ------------------------------------------------------------------
    let mut cfg = TimeflowConfig::new(8, 2, RoutingPolicy::Prefix)
        .with_kv(KvDtype::Q8, AllocatorKind::Uniform);
    cfg.failure = Some(ReplicaFailure {
        replica: 0,
        at_ns: gspec.mean_gap_ns * 512, // mid-workload
    });
    let rep = simulate(&cfg, &gspec);
    let settled = rep.completed + rep.failed;
    println!(
        "\n# replica death: settled {}/{} (completed {}, failed {}, rerouted {})",
        settled,
        gspec.requests,
        rep.completed,
        rep.failed,
        rep.registry
            .counters
            .get("sim.route.rerouted_dead")
            .map_or(0.0, |c| c.get())
    );
    assert_eq!(settled, gspec.requests, "death must lose nothing");
    gated = gated
        .set("fail.settled", settled)
        .set("fail.completed", rep.completed)
        .set("fail.failed", rep.failed);

    (gated, info)
}

/// Full mode: the 64→512 replica sweep the tentpole calls for.
/// Wall-clock is machine-dependent → printed only, never in the JSON.
fn replica_sweep() {
    println!("\n# replica sweep (full mode)");
    for &replicas in &[64usize, 128, 256, 512] {
        for routing in [
            RoutingPolicy::Prefix,
            RoutingPolicy::LeastLoaded,
            RoutingPolicy::RoundRobin,
        ] {
            // prefix routing probes every replica's shadow trie per
            // request (O(replicas) with a real constant); cap its
            // request count at scale so the sweep stays in seconds
            let requests = match routing {
                RoutingPolicy::Prefix if replicas >= 256 => 250_000,
                _ => 1_000_000,
            };
            let cfg = TimeflowConfig::new(replicas, 4, routing)
                .with_kv(KvDtype::Q8, AllocatorKind::Uniform);
            let mut spec = grid_spec(&cfg.cost, replicas, 4, requests);
            spec.n_prompts = 1024;
            let t0 = Instant::now();
            let rep = simulate(&cfg, &spec);
            let wall = t0.elapsed().as_secs_f64();
            println!(
                "  {replicas:>4}r {:<12} {requests:>8} reqs  p99 {:>12.0}ns  {:>10.0} tok/s  {wall:>6.2}s wall",
                routing.name(),
                rep.ttft_p99_ns,
                rep.tokens_per_s
            );
        }
    }
}

fn main() -> hyperscale::Result<()> {
    let args = Args::from_env();
    let smoke = args.flag("smoke");

    println!("# bench_sim — discrete-event cluster timing model");
    let (gated, info) = smoke_scenarios();
    if !smoke {
        replica_sweep();
    }

    if let Some(path) = args.get("out") {
        // NOTE: nothing wall-clock goes into this file — the sim-gate
        // CI job byte-compares two consecutive runs
        let report = Json::obj()
            .set("bench", "sim")
            .set("schema", 1u64)
            .set("smoke", smoke)
            .set("gated", gated)
            .set("info", info);
        std::fs::write(path, report.to_string())?;
        println!("wrote {path}");
    }
    Ok(())
}
