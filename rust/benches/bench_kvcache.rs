// Shared lint config for non-lib targets (benches/tests/examples are
// separate crates, so the crate-wide allows in rust/src/lib.rs do not
// reach them): the same flat-layout indexing idiom applies here, and
// vec! payloads deliberately mirror the engine's heap buffers.
// Correctness lints stay on — CI denies all remaining warnings via
// `cargo clippy --all-targets -- -D warnings`.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::manual_div_ceil,
    clippy::uninlined_format_args,
    clippy::useless_vec
)]

//! KV-cache substrate micro-benchmarks: allocator ops, writes, forks,
//! delayed-eviction sweeps, quantized-payload publish/restore costs —
//! the L3 overhead that must stay far below the XLA step time.
//!
//! `--smoke` runs only the payload-format section with reduced
//! iterations and emits the perf-regression JSON (`--out
//! BENCH_kvcache.json`) CI diffs against `tools/bench_baselines/`.
//! Gated metrics are the *deterministic* byte-accounting numbers
//! (pooled bytes per cached token per dtype and the compression ratios
//! vs f32); publish/restore latencies are machine-dependent info.

use hyperscale::kvcache::{CacheStore, Geometry, KvDtype};
use hyperscale::util::benchkit::bench;
use hyperscale::util::{Args, Json};

fn geom() -> Geometry {
    Geometry {
        layers: 4,
        kv_heads: 2,
        slots: 320,
        head_dim: 16,
        page_size: 16,
    }
}

/// A head-dim-64 geometry (realistic GQA head size) for the payload
/// format comparison — quant metadata amortizes better at larger hd.
fn geom_hd64() -> Geometry {
    Geometry {
        layers: 2,
        kv_heads: 2,
        slots: 128,
        head_dim: 64,
        page_size: 16,
    }
}

fn main() {
    let args = Args::from_env();
    let smoke = args.flag("smoke");
    println!("# bench_kvcache");
    if !smoke {
        substrate_benches();
    }
    let (gated, info) = payload_format_benches(smoke);
    if let Some(path) = args.get("out") {
        let report = Json::obj()
            .set("bench", "kvcache")
            .set("schema", 1u64)
            .set("smoke", smoke)
            .set("gated", gated)
            .set("info", info);
        std::fs::write(path, report.to_string()).expect("write bench json");
        println!("wrote {path}");
    }
}

fn substrate_benches() {
    let g = geom();

    // alloc+write+evict cycle across all (l, h)
    let mut c = CacheStore::new(g, 8);
    let k = vec![0.5f32; g.head_dim];
    let v = vec![0.25f32; g.head_dim];
    let r = bench("write_token_all_heads", 10, 200, || {
        for l in 0..g.layers {
            for h in 0..g.kv_heads {
                if let Some(s) = c.alloc_slot(0, l, h) {
                    c.write(0, l, h, s, 0, &k, &v);
                    c.evict(0, l, h, s);
                }
            }
        }
    });
    r.print_throughput(g.lh() as f64, "writes");

    // steady-state decode pattern: write + scheduled eviction sweep
    let mut c = CacheStore::new(g, 8);
    let mut pos = 0usize;
    let r = bench("decode_pattern_w16", 10, 500, || {
        for l in 0..g.layers {
            for h in 0..g.kv_heads {
                if let Some(s) = c.alloc_slot(0, l, h) {
                    c.write(0, l, h, s, pos, &k, &v);
                    if pos % 2 == 0 {
                        c.schedule_eviction(0, l, h, s, pos + 16);
                    }
                }
            }
        }
        c.apply_due_evictions(0, pos);
        pos += 1;
        if pos % 300 == 0 {
            c.reset_lane(0);
        }
    });
    r.print();

    // prefix-sharing fork (the W>1 parallel-scaling fast path):
    // legacy full-lane memcpy vs COW refcount-bump fork, across prompt
    // lengths. The memcpy fork copies the whole lane (O(S·hd)); the COW
    // fork is metadata-only (flat in prompt length), with the payload
    // copy deferred to materialize_pending and page-granular (O(live)).
    for tokens in [32usize, 128, 304] {
        let mut c = CacheStore::new(g, 8);
        for p in 0..tokens {
            for l in 0..g.layers {
                for h in 0..g.kv_heads {
                    let s = c.alloc_slot(0, l, h).unwrap();
                    c.write(0, l, h, s, p, &k, &v);
                }
            }
        }
        let r = bench(&format!("fork_memcpy_{tokens}_tokens"), 10, 200, || {
            c.fork_lane(0, 1);
        });
        r.print();
        let r = bench(&format!("fork_cow_{tokens}_tokens"), 10, 200, || {
            c.fork_lane_cow(0, 2);
            c.reset_lane(2); // teardown (zeroing only, no payload copy)
        });
        r.print();
        let r = bench(
            &format!("fork_cow_materialized_{tokens}_tokens"),
            10,
            200,
            || {
                c.fork_lane_cow(0, 2);
                c.materialize_pending();
                c.reset_lane(2);
            },
        );
        r.print();
    }

    // mask slice access (uploaded every step)
    let c2 = CacheStore::new(g, 8);
    let r = bench("mask_slice_checksum", 10, 500, || {
        c2.mask_slice().iter().sum::<f32>()
    });
    r.print();
}

// ----------------------------------------------------------------------
// Quantized pool payloads: host bytes per cached token, publish
// (quantize) + restore (dequant-on-upload) latency, and pool capacity
// at a fixed host-memory budget, per dtype. Returns (gated, info)
// metric maps for the perf-regression JSON.
// ----------------------------------------------------------------------
fn payload_format_benches(smoke: bool) -> (Json, Json) {
    let iters = if smoke { 20 } else { 100 };
    let mut gated = Json::obj();
    let mut info = Json::obj();
    for (label, g2) in [("hd16", geom()), ("hd64", geom_hd64())] {
        println!("\n# pool payload formats ({label})");
        let tokens = 4 * g2.page_size; // 4 full pages
        let mut f32_per_token = 0.0f64;
        for dtype in [KvDtype::F32, KvDtype::Q8, KvDtype::Q4] {
            let mut c = CacheStore::with_dtype(g2, 2, dtype);
            for pos in 0..tokens {
                let payload: Vec<f32> = (0..g2.head_dim)
                    .map(|d| (pos as f32) * 0.31 + (d as f32) * 0.07 - 1.5)
                    .collect();
                for l in 0..g2.layers {
                    for h in 0..g2.kv_heads {
                        let s = c.alloc_slot(0, l, h).unwrap();
                        c.write(0, l, h, s, pos, &payload, &payload);
                    }
                }
            }
            let n_pages = tokens / g2.page_size;

            // publish cost: snapshot + encode one page into the pool
            let r = bench(&format!("publish_{dtype}_{label}"), 5, iters, || {
                let id = c.export_page(0, 0);
                c.release_page(id);
            });
            r.print();
            info = info.set(
                &format!("kvcache.{label}.{dtype}.publish_ms"),
                r.mean_s * 1e3,
            );

            // bytes-per-cached-token accounting over retained pages
            let ids: Vec<_> = (0..n_pages).map(|p| c.export_page(0, p)).collect();
            let bytes = c.pool_payload_bytes();
            let per_token = bytes as f64 / (tokens * g2.lh()) as f64;
            if dtype == KvDtype::F32 {
                f32_per_token = per_token;
            }
            let budget_mib = 64.0;
            let cap_tokens = budget_mib * 1024.0 * 1024.0 / (per_token * g2.lh() as f64);
            println!(
                "{dtype}: {bytes} B pooled, {per_token:.1} B/token/(l,h) \
                 (nominal {:.1}), {:.2}x vs f32, {:.0} tokens per {budget_mib} MiB pool",
                c.payload_bytes_per_token(),
                f32_per_token / per_token,
                cap_tokens
            );
            if dtype == KvDtype::Q8 {
                assert!(
                    f32_per_token / per_token >= 3.0,
                    "q8 must shrink host bytes-per-cached-token >= 3x \
                     (got {:.2}x at {label})",
                    f32_per_token / per_token
                );
            }
            // byte accounting is a pure function of dtype/geometry —
            // exactly reproducible, so it gates regressions in the
            // payload codec layout
            gated = gated.set(
                &format!("kvcache.{label}.{dtype}.bytes_per_token"),
                per_token,
            );
            if dtype != KvDtype::F32 {
                gated = gated.set(
                    &format!("kvcache.{label}.{dtype}.ratio_vs_f32"),
                    f32_per_token / per_token,
                );
            }

            // restore cost: map retained pages into a clean lane and
            // materialize (the dequant-on-upload path)
            let r = bench(&format!("restore_{dtype}_{label}"), 5, iters, || {
                for &id in &ids {
                    c.retain_page(id);
                }
                c.map_prefix_pages(1, &ids);
                c.materialize_pending();
                c.recycle_lane(1);
            });
            r.print();
            info = info.set(
                &format!("kvcache.{label}.{dtype}.restore_ms"),
                r.mean_s * 1e3,
            );
            println!("{dtype}: cumulative dequant-on-upload {:.1} us", c.dequant_us());
        }
    }
    (gated, info)
}
