// Shared lint config for non-lib targets (benches/tests/examples are
// separate crates, so the crate-wide allows in rust/src/lib.rs do not
// reach them): the same flat-layout indexing idiom applies here, and
// vec! payloads deliberately mirror the engine's heap buffers.
// Correctness lints stay on — CI denies all remaining warnings via
// `cargo clippy --all-targets -- -D warnings`.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::manual_div_ceil,
    clippy::uninlined_format_args,
    clippy::useless_vec
)]

//! KV-cache substrate micro-benchmarks: allocator ops, writes, forks,
//! delayed-eviction sweeps, quantized-payload publish/restore costs —
//! the L3 overhead that must stay far below the XLA step time.
//!
//! `--smoke` runs only the payload-format section with reduced
//! iterations and emits the perf-regression JSON (`--out
//! BENCH_kvcache.json`) CI diffs against `tools/bench_baselines/`.
//! Gated metrics: the *deterministic* byte-accounting numbers (pooled
//! bytes per cached token per dtype and the compression ratios vs
//! f32) gate by value; publish/restore latencies and the
//! scalar-vs-vectorized codec speedups are machine-dependent, so they
//! gate *structurally* (null baselines: present + numeric). The
//! codec-speedup legs additionally assert in-bench that the
//! production [`VectorizedCodec`] beats the retained [`ScalarCodec`]
//! reference by >= 2x on the publish/restore (encode+decode) work at
//! q8 and q4. Publish-side buffer-acquisition time (`kv.alloc_us`) is
//! reported separately from codec time (`kv.dequant_us`) so allocator
//! churn is never conflated with encode/decode cost.

use hyperscale::kvcache::{CacheStore, Codec, Geometry, KvDtype, ScalarCodec, VectorizedCodec};
use hyperscale::util::benchkit::bench;
use hyperscale::util::{Args, Json};

fn geom() -> Geometry {
    Geometry {
        layers: 4,
        kv_heads: 2,
        slots: 320,
        head_dim: 16,
        page_size: 16,
    }
}

/// A head-dim-64 geometry (realistic GQA head size) for the payload
/// format comparison — quant metadata amortizes better at larger hd.
fn geom_hd64() -> Geometry {
    Geometry {
        layers: 2,
        kv_heads: 2,
        slots: 128,
        head_dim: 64,
        page_size: 16,
    }
}

fn main() {
    let args = Args::from_env();
    let smoke = args.flag("smoke");
    println!("# bench_kvcache");
    if !smoke {
        substrate_benches();
    }
    let (gated, info) = payload_format_benches(smoke);
    if let Some(path) = args.get("out") {
        let report = Json::obj()
            .set("bench", "kvcache")
            .set("schema", 1u64)
            .set("smoke", smoke)
            .set("gated", gated)
            .set("info", info);
        std::fs::write(path, report.to_string()).expect("write bench json");
        println!("wrote {path}");
    }
}

fn substrate_benches() {
    let g = geom();

    // alloc+write+evict cycle across all (l, h)
    let mut c = CacheStore::new(g, 8);
    let k = vec![0.5f32; g.head_dim];
    let v = vec![0.25f32; g.head_dim];
    let r = bench("write_token_all_heads", 10, 200, || {
        for l in 0..g.layers {
            for h in 0..g.kv_heads {
                if let Some(s) = c.alloc_slot(0, l, h) {
                    c.write(0, l, h, s, 0, &k, &v);
                    c.evict(0, l, h, s);
                }
            }
        }
    });
    r.print_throughput(g.lh() as f64, "writes");

    // steady-state decode pattern: write + scheduled eviction sweep
    let mut c = CacheStore::new(g, 8);
    let mut pos = 0usize;
    let r = bench("decode_pattern_w16", 10, 500, || {
        for l in 0..g.layers {
            for h in 0..g.kv_heads {
                if let Some(s) = c.alloc_slot(0, l, h) {
                    c.write(0, l, h, s, pos, &k, &v);
                    if pos % 2 == 0 {
                        c.schedule_eviction(0, l, h, s, pos + 16);
                    }
                }
            }
        }
        c.apply_due_evictions(0, pos);
        pos += 1;
        if pos % 300 == 0 {
            c.reset_lane(0);
        }
    });
    r.print();

    // prefix-sharing fork (the W>1 parallel-scaling fast path):
    // legacy full-lane memcpy vs COW refcount-bump fork, across prompt
    // lengths. The memcpy fork copies the whole lane (O(S·hd)); the COW
    // fork is metadata-only (flat in prompt length), with the payload
    // copy deferred to materialize_pending and page-granular (O(live)).
    for tokens in [32usize, 128, 304] {
        let mut c = CacheStore::new(g, 8);
        for p in 0..tokens {
            for l in 0..g.layers {
                for h in 0..g.kv_heads {
                    let s = c.alloc_slot(0, l, h).unwrap();
                    c.write(0, l, h, s, p, &k, &v);
                }
            }
        }
        let r = bench(&format!("fork_memcpy_{tokens}_tokens"), 10, 200, || {
            c.fork_lane(0, 1);
        });
        r.print();
        let r = bench(&format!("fork_cow_{tokens}_tokens"), 10, 200, || {
            c.fork_lane_cow(0, 2);
            c.reset_lane(2); // teardown (zeroing only, no payload copy)
        });
        r.print();
        let r = bench(
            &format!("fork_cow_materialized_{tokens}_tokens"),
            10,
            200,
            || {
                c.fork_lane_cow(0, 2);
                c.materialize_pending();
                c.reset_lane(2);
            },
        );
        r.print();
    }

    // mask slice access (uploaded every step)
    let c2 = CacheStore::new(g, 8);
    let r = bench("mask_slice_checksum", 10, 500, || {
        c2.mask_slice().iter().sum::<f32>()
    });
    r.print();
}

// ----------------------------------------------------------------------
// Quantized pool payloads: host bytes per cached token, publish
// (quantize) + restore (dequant-on-upload) latency, and pool capacity
// at a fixed host-memory budget, per dtype. Returns (gated, info)
// metric maps for the perf-regression JSON.
// ----------------------------------------------------------------------
fn payload_format_benches(smoke: bool) -> (Json, Json) {
    let iters = if smoke { 20 } else { 100 };
    let mut gated = Json::obj();
    let mut info = Json::obj();
    for (label, g2) in [("hd16", geom()), ("hd64", geom_hd64())] {
        println!("\n# pool payload formats ({label})");
        let tokens = 4 * g2.page_size; // 4 full pages
        let mut f32_per_token = 0.0f64;
        for dtype in [KvDtype::F32, KvDtype::Q8, KvDtype::Q4] {
            let mut c = CacheStore::with_dtype(g2, 2, dtype);
            for pos in 0..tokens {
                let payload: Vec<f32> = (0..g2.head_dim)
                    .map(|d| (pos as f32) * 0.31 + (d as f32) * 0.07 - 1.5)
                    .collect();
                for l in 0..g2.layers {
                    for h in 0..g2.kv_heads {
                        let s = c.alloc_slot(0, l, h).unwrap();
                        c.write(0, l, h, s, pos, &payload, &payload);
                    }
                }
            }
            let n_pages = tokens / g2.page_size;

            // publish cost: snapshot + encode one page into the pool.
            // Machine-dependent, so the baseline entry is null
            // (structural gate: must exist and be numeric).
            let r = bench(&format!("publish_{dtype}_{label}"), 5, iters, || {
                let id = c.export_page(0, 0);
                c.release_page(id);
            });
            r.print();
            gated = gated.set(
                &format!("kvcache.{label}.{dtype}.publish_ms"),
                r.mean_s * 1e3,
            );

            // bytes-per-cached-token accounting over retained pages
            let ids: Vec<_> = (0..n_pages).map(|p| c.export_page(0, p)).collect();
            let bytes = c.pool_payload_bytes();
            let per_token = bytes as f64 / (tokens * g2.lh()) as f64;
            if dtype == KvDtype::F32 {
                f32_per_token = per_token;
            }
            let budget_mib = 64.0;
            let cap_tokens = budget_mib * 1024.0 * 1024.0 / (per_token * g2.lh() as f64);
            println!(
                "{dtype}: {bytes} B pooled, {per_token:.1} B/token/(l,h) \
                 (nominal {:.1}), {:.2}x vs f32, {:.0} tokens per {budget_mib} MiB pool",
                c.payload_bytes_per_token(),
                f32_per_token / per_token,
                cap_tokens
            );
            if dtype == KvDtype::Q8 {
                assert!(
                    f32_per_token / per_token >= 3.0,
                    "q8 must shrink host bytes-per-cached-token >= 3x \
                     (got {:.2}x at {label})",
                    f32_per_token / per_token
                );
            }
            // byte accounting is a pure function of dtype/geometry —
            // exactly reproducible, so it gates regressions in the
            // payload codec layout
            gated = gated.set(
                &format!("kvcache.{label}.{dtype}.bytes_per_token"),
                per_token,
            );
            if dtype != KvDtype::F32 {
                gated = gated.set(
                    &format!("kvcache.{label}.{dtype}.ratio_vs_f32"),
                    f32_per_token / per_token,
                );
            }

            // restore cost: map retained pages into a clean lane and
            // materialize (the dequant-on-upload path)
            let r = bench(&format!("restore_{dtype}_{label}"), 5, iters, || {
                for &id in &ids {
                    c.retain_page(id);
                }
                c.map_prefix_pages(1, &ids);
                c.materialize_pending();
                c.recycle_lane(1);
            });
            r.print();
            gated = gated.set(
                &format!("kvcache.{label}.{dtype}.restore_ms"),
                r.mean_s * 1e3,
            );
            // the alloc/codec split: buffer acquisition at the publish
            // boundary (spare-arena reuse or fresh Box) vs actual
            // decode work — the same split the engine exports as the
            // kv.alloc_us / kv.dequant_us gauges
            println!(
                "{dtype}: cumulative alloc {:.1} us vs dequant-on-upload {:.1} us \
                 ({} spare page(s) parked)",
                c.alloc_us(),
                c.dequant_us(),
                c.pool_spare_pages()
            );
            info = info
                .set(&format!("kvcache.{label}.{dtype}.alloc_us"), c.alloc_us())
                .set(&format!("kvcache.{label}.{dtype}.dequant_us"), c.dequant_us());
        }
    }

    codec_speedup_benches(smoke, gated, info)
}

// ----------------------------------------------------------------------
// Codec-level publish/restore legs: the retained scalar reference vs
// the production vectorized codec on identical page-shaped buffers.
// The speedup ratios are machine-dependent (structurally gated), but
// the >= 2x floor is asserted right here so a codec regression fails
// the bench run itself, on any machine.
// ----------------------------------------------------------------------
fn codec_speedup_benches(smoke: bool, mut gated: Json, mut info: Json) -> (Json, Json) {
    const ROWS: usize = 256;
    const ROW_LEN: usize = 64;
    let iters = if smoke { 40 } else { 200 };
    println!("\n# codec: scalar reference vs vectorized ({ROWS} rows x {ROW_LEN})");
    // deterministic NaN-free payload (the production case: lane f32 is
    // always finite), same shape the hd64 publish path encodes
    let src: Vec<f32> = (0..ROWS * ROW_LEN)
        .map(|i| ((i / ROW_LEN) as f32) * 0.31 + ((i % ROW_LEN) as f32) * 0.07 - 1.5)
        .collect();
    for dtype in [KvDtype::Q8, KvDtype::Q4] {
        let stride = dtype.row_code_bytes(ROW_LEN);
        let mut codes = vec![0u8; ROWS * stride];
        let mut scale = vec![0f32; ROWS];
        let mut zp = vec![0u8; ROWS];
        let mut out = vec![0f32; ROWS * ROW_LEN];
        // dyn dispatch keeps both codecs behind the same call overhead
        // and stops the optimizer from folding the benched work away
        let mut leg = |codec: &dyn Codec| {
            let enc = bench(
                &format!("codec_encode_{dtype}_{}", codec.name()),
                5,
                iters,
                || {
                    codec.encode_rows_into(
                        dtype, ROWS, ROW_LEN, &src, &mut codes, &mut scale, &mut zp,
                    );
                },
            );
            enc.print();
            let dec = bench(
                &format!("codec_decode_{dtype}_{}", codec.name()),
                5,
                iters,
                || {
                    codec.decode_rows_into(dtype, ROWS, ROW_LEN, &codes, &scale, &zp, &mut out);
                },
            );
            dec.print();
            (enc.mean_s, dec.mean_s)
        };
        let (se, sd) = leg(&ScalarCodec);
        let (ve, vd) = leg(&VectorizedCodec);
        let enc_speedup = se / ve;
        let dec_speedup = sd / vd;
        let roundtrip_speedup = (se + sd) / (ve + vd);
        println!(
            "{dtype}: vectorized speedup — encode {enc_speedup:.2}x, \
             decode {dec_speedup:.2}x, publish+restore {roundtrip_speedup:.2}x"
        );
        assert!(
            roundtrip_speedup >= 2.0,
            "vectorized codec must run the publish/restore (encode+decode) leg \
             >= 2x faster than the scalar reference (got {roundtrip_speedup:.2}x at {dtype})"
        );
        gated = gated
            .set(&format!("codec.{dtype}.encode_speedup"), enc_speedup)
            .set(&format!("codec.{dtype}.decode_speedup"), dec_speedup)
            .set(&format!("codec.{dtype}.roundtrip_speedup"), roundtrip_speedup);
        info = info
            .set(&format!("codec.{dtype}.scalar_encode_ms"), se * 1e3)
            .set(&format!("codec.{dtype}.vectorized_encode_ms"), ve * 1e3);
    }
    (gated, info)
}
