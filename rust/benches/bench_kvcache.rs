//! KV-cache substrate micro-benchmarks: allocator ops, writes, forks,
//! delayed-eviction sweeps — the L3 overhead that must stay far below
//! the XLA step time.

use hyperscale::kvcache::{CacheStore, Geometry};
use hyperscale::util::benchkit::bench;

fn geom() -> Geometry {
    Geometry {
        layers: 4,
        kv_heads: 2,
        slots: 320,
        head_dim: 16,
        page_size: 16,
    }
}

fn main() {
    println!("# bench_kvcache");
    let g = geom();

    // alloc+write+evict cycle across all (l, h)
    let mut c = CacheStore::new(g, 8);
    let k = vec![0.5f32; g.head_dim];
    let v = vec![0.25f32; g.head_dim];
    let r = bench("write_token_all_heads", 10, 200, || {
        for l in 0..g.layers {
            for h in 0..g.kv_heads {
                if let Some(s) = c.alloc_slot(0, l, h) {
                    c.write(0, l, h, s, 0, &k, &v);
                    c.evict(0, l, h, s);
                }
            }
        }
    });
    r.print_throughput(g.lh() as f64, "writes");

    // steady-state decode pattern: write + scheduled eviction sweep
    let mut c = CacheStore::new(g, 8);
    let mut pos = 0usize;
    let r = bench("decode_pattern_w16", 10, 500, || {
        for l in 0..g.layers {
            for h in 0..g.kv_heads {
                if let Some(s) = c.alloc_slot(0, l, h) {
                    c.write(0, l, h, s, pos, &k, &v);
                    if pos % 2 == 0 {
                        c.schedule_eviction(0, l, h, s, pos + 16);
                    }
                }
            }
        }
        c.apply_due_evictions(0, pos);
        pos += 1;
        if pos % 300 == 0 {
            c.reset_lane(0);
        }
    });
    r.print();

    // prefix-sharing fork (the W>1 parallel-scaling fast path):
    // legacy full-lane memcpy vs COW refcount-bump fork, across prompt
    // lengths. The memcpy fork copies the whole lane (O(S·hd)); the COW
    // fork is metadata-only (flat in prompt length), with the payload
    // copy deferred to materialize_pending and page-granular (O(live)).
    for tokens in [32usize, 128, 304] {
        let mut c = CacheStore::new(g, 8);
        for p in 0..tokens {
            for l in 0..g.layers {
                for h in 0..g.kv_heads {
                    let s = c.alloc_slot(0, l, h).unwrap();
                    c.write(0, l, h, s, p, &k, &v);
                }
            }
        }
        let r = bench(&format!("fork_memcpy_{tokens}_tokens"), 10, 200, || {
            c.fork_lane(0, 1);
        });
        r.print();
        let r = bench(&format!("fork_cow_{tokens}_tokens"), 10, 200, || {
            c.fork_lane_cow(0, 2);
            c.reset_lane(2); // teardown (zeroing only, no payload copy)
        });
        r.print();
        let r = bench(
            &format!("fork_cow_materialized_{tokens}_tokens"),
            10,
            200,
            || {
                c.fork_lane_cow(0, 2);
                c.materialize_pending();
                c.reset_lane(2);
            },
        );
        r.print();
    }

    // mask slice access (uploaded every step)
    let c2 = CacheStore::new(g, 8);
    let r = bench("mask_slice_checksum", 10, 500, || {
        c2.mask_slice().iter().sum::<f32>()
    });
    r.print();
}
