//! Serving throughput bench: engine-level requests/s and tokens/s for
//! vanilla vs DMS at the same slot budget (the paper's "more tokens for
//! the same compute" claim, measured on this testbed).

use hyperscale::compress::PolicyKind;
use hyperscale::config::EngineConfig;
use hyperscale::engine::{Engine, GenRequest};
use hyperscale::util::benchkit::bench;
use hyperscale::util::Args;

fn main() -> hyperscale::Result<()> {
    let args = Args::from_env();
    let artifacts = args.get_str("artifacts", "artifacts");
    let iters = args.get_usize("iters", 3)?;
    println!("# bench_serve — engine throughput (8 lanes, W=2, gsm8k prompts)");

    for (name, policy, variant, cr) in [
        ("vanilla", PolicyKind::Vanilla, "base", 1.0),
        ("dms_cr4", PolicyKind::Dms, "dms_w16_cr4", 4.0),
        ("dms_cr8", PolicyKind::Dms, "dms_w16_cr8", 8.0),
        ("quest_cr4", PolicyKind::Quest, "base", 4.0),
    ] {
        let mut engine = match Engine::new(EngineConfig {
            artifacts: artifacts.into(),
            variant: variant.into(),
            policy,
            cr,
            temperature: 0.7,
            ..Default::default()
        }) {
            Ok(e) => e,
            Err(e) => {
                println!("skip {name}: {e:#}");
                continue;
            }
        };
        let reqs: Vec<GenRequest> = (0..6)
            .map(|i| GenRequest {
                prompt: hyperscale::tasks::gen_problem("gsm8k", 11, i).prompt,
                width: 2,
                max_len: 144,
                temperature: 0.7,
                seed: i,
            })
            .collect();
        let mut gen_tokens = 0f64;
        let mut reads = 0f64;
        let r = bench(&format!("serve_{name}"), 1, iters, || {
            let (results, _) = engine.run(&reqs).expect("run");
            gen_tokens = results
                .iter()
                .flat_map(|r| &r.chains)
                .map(|c| c.stats.gen_tokens as f64)
                .sum();
            reads = results.iter().map(|r| r.total_reads()).sum();
        });
        r.print_throughput(gen_tokens, "gen-tokens");
        println!(
            "      KV reads per generated token: {:.1}",
            reads / gen_tokens.max(1.0)
        );
    }
    Ok(())
}
