//! Serving throughput bench: engine-level requests/s and tokens/s for
//! vanilla vs DMS at the same slot budget (the paper's "more tokens for
//! the same compute" claim, measured on this testbed), plus the
//! continuous-batching comparison: dynamic admission (concurrent
//! requests share the executor's lanes) vs the pre-refactor serving
//! path that ran each request as its own static batch, leaving
//! `batch − width` lanes idle.

use hyperscale::compress::PolicyKind;
use hyperscale::config::EngineConfig;
use hyperscale::engine::{Engine, GenRequest};
use std::time::Instant;

use hyperscale::util::benchkit::bench;
use hyperscale::util::Args;

fn requests(n: usize, width: usize, max_len: usize) -> Vec<GenRequest> {
    (0..n as u64)
        .map(|i| GenRequest {
            prompt: hyperscale::tasks::gen_problem("gsm8k", 11, i).prompt,
            width,
            max_len,
            temperature: 0.7,
            seed: i,
        })
        .collect()
}

fn main() -> hyperscale::Result<()> {
    let args = Args::from_env();
    let artifacts = args.get_str("artifacts", "artifacts");
    let iters = args.get_usize("iters", 3)?;
    println!("# bench_serve — engine throughput (8 lanes, W=2, gsm8k prompts)");

    for (name, policy, variant, cr) in [
        ("vanilla", PolicyKind::Vanilla, "base", 1.0),
        ("dms_cr4", PolicyKind::Dms, "dms_w16_cr4", 4.0),
        ("dms_cr8", PolicyKind::Dms, "dms_w16_cr8", 8.0),
        ("quest_cr4", PolicyKind::Quest, "base", 4.0),
    ] {
        let mut engine = match Engine::new(EngineConfig {
            artifacts: artifacts.into(),
            variant: variant.into(),
            policy,
            cr,
            temperature: 0.7,
            // keep the policy comparison pure: repeated iterations must
            // not hit prefixes retained by earlier ones
            prefix_cache: false,
            ..Default::default()
        }) {
            Ok(e) => e,
            Err(e) => {
                println!("skip {name}: {e:#}");
                continue;
            }
        };
        let reqs = requests(6, 2, 144);
        let mut gen_tokens = 0f64;
        let mut reads = 0f64;
        let r = bench(&format!("serve_{name}"), 1, iters, || {
            let (results, _) = engine.run(&reqs).expect("run");
            gen_tokens = results
                .iter()
                .flat_map(|r| &r.chains)
                .map(|c| c.stats.gen_tokens as f64)
                .sum();
            reads = results.iter().map(|r| r.total_reads()).sum();
        });
        r.print_throughput(gen_tokens, "gen-tokens");
        println!(
            "      KV reads per generated token: {:.1}",
            reads / gen_tokens.max(1.0)
        );
    }

    // ------------------------------------------------------------------
    // Dynamic admission vs per-request static batches, equal cache
    // budget (same engine, same slots, same policy). "static" replays
    // the pre-refactor server: one engine.run per request, so a W=2
    // request occupies 2 of 8 lanes and the rest idle. "dynamic"
    // submits every request into one continuous-batching session.
    // ------------------------------------------------------------------
    println!("\n# dynamic admission vs static per-request batches");
    for (name, policy, variant, cr) in [
        ("dms_cr4", PolicyKind::Dms, "dms_w16_cr4", 4.0),
        ("vanilla", PolicyKind::Vanilla, "base", 1.0),
    ] {
        let mut engine = match Engine::new(EngineConfig {
            artifacts: artifacts.into(),
            variant: variant.into(),
            policy,
            cr,
            temperature: 0.7,
            // the static run must not seed prefix hits for the dynamic
            // run — admission packing is the variable under test
            prefix_cache: false,
            ..Default::default()
        }) {
            Ok(e) => e,
            Err(e) => {
                println!("skip {name}: {e:#}");
                continue;
            }
        };
        let reqs = requests(12, 2, 144);

        let mut static_tokens = 0f64;
        let sw = Instant::now();
        for req in &reqs {
            let (results, _) = engine.run(std::slice::from_ref(req)).expect("run");
            static_tokens += results
                .iter()
                .flat_map(|r| &r.chains)
                .map(|c| c.stats.gen_tokens as f64)
                .sum::<f64>();
        }
        let static_s = sw.elapsed().as_secs_f64();

        let mut dynamic_tokens = 0f64;
        let sw = Instant::now();
        let mut session = engine.begin_session();
        for req in &reqs {
            engine.submit(&mut session, req).expect("submit");
        }
        while !engine.is_idle(&session) {
            for done in engine.tick(&mut session).expect("tick") {
                dynamic_tokens += done.timing.gen_tokens as f64;
            }
        }
        let dynamic_s = sw.elapsed().as_secs_f64();

        let st = static_tokens / static_s.max(1e-9);
        let dt = dynamic_tokens / dynamic_s.max(1e-9);
        println!(
            "{name:<10} static  {static_s:>8.3}s  {st:>10.1} gen-tokens/s"
        );
        println!(
            "{name:<10} dynamic {dynamic_s:>8.3}s  {dt:>10.1} gen-tokens/s   speedup {:.2}x",
            dt / st.max(1e-9)
        );
    }

    // ------------------------------------------------------------------
    // Radix prefix cache: repeated-system-prompt workload. The same
    // prompt hits the engine 10 times (arriving one per tick, as from
    // independent clients); with the prefix cache on, every request
    // after the first starts prefill at the divergence point. Reported:
    // prefill tokens skipped (hit rate), mean TTFT with/without the
    // cache, and whether the token streams stayed identical.
    // ------------------------------------------------------------------
    println!("\n# prefix cache: repeated-system-prompt workload");
    let mut texts_by_mode: Vec<Vec<String>> = Vec::new();
    for prefix_cache in [false, true] {
        let mut engine = match Engine::new(EngineConfig {
            artifacts: artifacts.into(),
            variant: "base".into(),
            policy: PolicyKind::Vanilla,
            cr: 1.0,
            temperature: 0.7,
            prefix_cache,
            ..Default::default()
        }) {
            Ok(e) => e,
            Err(e) => {
                println!("skip prefix-cache bench: {e:#}");
                break;
            }
        };
        // a system-style preamble (64-symbol vocabulary only) shared by
        // every request, long enough to span several KV pages
        let question = hyperscale::tasks::gen_problem("gsm8k", 11, 0).prompt;
        let prompt = format!(
            "system: you are a careful math solver. think step by step \
             and answer with the final number only.|{question}"
        );
        let mut session = engine.begin_session();
        let mut ttfts: Vec<f64> = Vec::new();
        let mut hit_tokens = 0f64;
        let mut prompt_tokens = 0f64;
        let mut texts: Vec<String> = Vec::new();
        // requests arrive one after another (the repeated-system-prompt
        // pattern the prefix cache targets), so each can hit the pages
        // its predecessor retained
        for i in 0..10u64 {
            let req = GenRequest {
                prompt: prompt.clone(),
                width: 1,
                max_len: 144,
                temperature: 0.7,
                seed: i,
            };
            engine.submit(&mut session, &req).expect("submit");
            while !engine.is_idle(&session) {
                for done in engine.tick(&mut session).expect("tick") {
                    for c in &done.result.chains {
                        hit_tokens += c.stats.prefix_hit_tokens as f64;
                        prompt_tokens += c.stats.prompt_tokens as f64;
                        texts.push(c.text.clone());
                    }
                    ttfts.push(done.timing.ttft_ms);
                }
            }
        }
        let mean_ttft = ttfts.iter().sum::<f64>() / ttfts.len().max(1) as f64;
        // the first request can never hit; report the steady-state too
        let rest_ttft = if ttfts.len() > 1 {
            ttfts[1..].iter().sum::<f64>() / (ttfts.len() - 1) as f64
        } else {
            mean_ttft
        };
        println!(
            "prefix_cache={prefix_cache:<5}  prefill tokens skipped {:>6.0}/{:>6.0} ({:>5.1}%)  \
             mean TTFT {mean_ttft:>7.2} ms  steady-state TTFT {rest_ttft:>7.2} ms",
            hit_tokens,
            prompt_tokens,
            100.0 * hit_tokens / prompt_tokens.max(1.0),
        );
        texts_by_mode.push(texts);
    }
    if texts_by_mode.len() == 2 {
        let identical = texts_by_mode[0] == texts_by_mode[1];
        println!("identical output streams with/without prefix cache: {identical}");
        assert!(identical, "prefix-cache reuse changed a token stream");
    }
    Ok(())
}
