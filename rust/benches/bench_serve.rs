// Shared lint config for non-lib targets (benches/tests/examples are
// separate crates, so the crate-wide allows in rust/src/lib.rs do not
// reach them): the same flat-layout indexing idiom applies here, and
// vec! payloads deliberately mirror the engine's heap buffers.
// Correctness lints stay on — CI denies all remaining warnings via
// `cargo clippy --all-targets -- -D warnings`.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::manual_div_ceil,
    clippy::uninlined_format_args,
    clippy::useless_vec
)]

//! Serving throughput bench: engine-level requests/s and tokens/s for
//! vanilla vs DMS at the same slot budget (the paper's "more tokens for
//! the same compute" claim, measured on this testbed), plus the
//! continuous-batching comparison (dynamic admission vs per-request
//! static batches), the radix prefix-cache workload, and — since the
//! engine cluster — routing-policy scenarios over 4 sim-engine
//! replicas (prefix-affinity vs least-loaded vs round-robin on a
//! skewed repeated-prefix workload, plus a work-stealing saturation
//! run).
//!
//! `--smoke` runs only the artifact-free cluster scenarios and emits
//! the perf-regression JSON (`--out BENCH_serve.json`) that CI diffs
//! against `tools/bench_baselines/` (see `tools/bench_compare.py`).
//! Gated metrics are deterministic counters (token/hit totals from
//! seeded sim runs); wall-clock throughputs are reported as info. The
//! smoke run also *asserts* the issue's acceptance invariant: at 4
//! replicas on the skewed workload, `prefix` routing must beat
//! `round-robin` on both aggregate tokens/s and `prefix_hit_tokens`,
//! and — since the tiered prefix cache — hot+cold at the same hot
//! budget must recover strictly more prefix hit tokens than hot-only
//! on the same zipf workload, with the spill dir left empty.
//!
//! The SLO leg (`--slo-out BENCH_slo.json`) is a separate document:
//! seeded mixed-workload draw totals per arrival process (mirrored
//! bit-for-bit by `tools/seed_bench_slo.py`), the q4-vs-f32 admission
//! delta at equal byte capacity, an EDF+admission-vs-FCFS overload
//! comparison, and a 64–512-replica hyperscale sweep — all in virtual
//! time, so every gated value is a pure function of the seed.

use hyperscale::compress::{AllocatorKind, PolicyKind};
use hyperscale::config::{ClusterConfig, EngineConfig, RoutingPolicy};
use hyperscale::engine::{
    byte_capacity, generate_mixed_workload, simulate_slo, slo_requests, AdmissionController,
    ArrivalKind, CostModel, Engine, GenRequest, RequestClass, SimEngine, SimEngineConfig,
    SloPolicy, TimeflowConfig, WorkloadConfig,
};
use hyperscale::kvcache::{Geometry, KvDtype};
use hyperscale::server::{Cluster, ServeRequest};
use hyperscale::util::benchkit::bench;
use hyperscale::util::{Args, Json, SplitMix64};
use std::path::PathBuf;
use std::time::Instant;

fn requests(n: usize, width: usize, max_len: usize) -> Vec<GenRequest> {
    (0..n as u64)
        .map(|i| GenRequest {
            prompt: hyperscale::tasks::gen_problem("gsm8k", 11, i).prompt,
            width,
            max_len,
            temperature: 0.7,
            seed: i,
        })
        .collect()
}

// ----------------------------------------------------------------------
// Cluster routing scenarios (sim engines — run without artifacts)
// ----------------------------------------------------------------------

/// Skewed repeated-prefix workload: three system preambles drawn
/// zipf-style (~60/30/10), each prompt ending in a unique one-byte tail
/// so every pair of same-system prompts shares exactly the preamble.
/// Deterministic: the sequence is fixed by a seeded RNG.
fn skewed_workload() -> Vec<(u64, String)> {
    // 102 chars + '|' -> with BOS a 104-token shared prefix: 6 full
    // 16-token KV pages per same-system pair
    let systems = [
        "system A: you are a careful and methodical math solver, reason step by step, keep it brief, answer",
        "system B: you are a terse coding assistant, answer with a single code line and then stop right there",
        "system C: you translate numbers to words precisely and then immediately stop, no extra text, answer",
    ];
    let mut rng = SplitMix64::new(0xC1A5_7E12);
    (0..24u64)
        .map(|id| {
            let r = rng.f64();
            let sys = if r < 0.6 {
                systems[0]
            } else if r < 0.9 {
                systems[1]
            } else {
                systems[2]
            };
            let tail = (b'a' + (id as u8)) as char;
            (id, format!("{sys}|{tail}"))
        })
        .collect()
}

struct ClusterRun {
    wall_s: f64,
    gen_tokens: f64,
    hit_tokens: f64,
}

impl ClusterRun {
    fn tokens_per_s(&self) -> f64 {
        self.gen_tokens / self.wall_s.max(1e-9)
    }
}

/// Serve the skewed workload sequentially through a 4-replica cluster
/// under `routing`. Sequential submission makes the hit totals exact:
/// each request completes (and retains its prefix) before the next is
/// routed.
fn run_cluster_policy(routing: RoutingPolicy, work_per_token: usize) -> ClusterRun {
    let ccfg = ClusterConfig {
        replicas: 4,
        routing,
        steal: false, // routing is the variable; stealing measured below
    };
    let cluster = Cluster::start(ccfg, move |_| {
        Ok(SimEngine::new(SimEngineConfig {
            lanes: 2,
            work_per_token,
            ..Default::default()
        }))
    });
    let t0 = Instant::now();
    let mut gen_tokens = 0.0;
    let mut hit_tokens = 0.0;
    for (id, prompt) in skewed_workload() {
        let j = cluster
            .call_blocking(ServeRequest {
                id,
                prompt,
                width: 1,
                max_len: 224,
                temperature: 0.7,
                seed: id,
                slo: None,
            })
            .expect("cluster response");
        assert!(j.get("error").is_none(), "cluster error: {}", j.to_string());
        gen_tokens += j.get("gen_tokens").and_then(Json::as_f64).unwrap_or(0.0);
        hit_tokens += j
            .get("prefix_hit_tokens")
            .and_then(Json::as_f64)
            .unwrap_or(0.0);
    }
    let wall_s = t0.elapsed().as_secs_f64();
    cluster.shutdown();
    ClusterRun {
        wall_s,
        gen_tokens,
        hit_tokens,
    }
}

/// Saturate one single-lane replica through prefix affinity while the
/// other idles; report how many of the burst requests the steal path
/// migrated. (Counts are timing-dependent — info, not gated.)
fn run_steal_scenario(work_per_token: usize) -> (usize, usize) {
    let ccfg = ClusterConfig {
        replicas: 2,
        routing: RoutingPolicy::Prefix,
        steal: true,
    };
    let cluster = Cluster::start(ccfg, move |_| {
        Ok(SimEngine::new(SimEngineConfig {
            lanes: 1,
            work_per_token,
            ..Default::default()
        }))
    });
    let workload = skewed_workload();
    let hot = workload[0].1.clone();
    let seed_resp = cluster
        .call_blocking(ServeRequest {
            id: 0,
            prompt: hot.clone(),
            width: 1,
            max_len: 224,
            temperature: 0.7,
            seed: 0,
            slo: None,
        })
        .expect("seed response");
    let seeded = seed_resp
        .get("replica_id")
        .and_then(Json::as_usize)
        .unwrap_or(0);
    let n = 12usize;
    let pending: Vec<_> = (1..=n as u64)
        .map(|i| {
            cluster.call(ServeRequest {
                id: i,
                prompt: format!("{hot}{i}"),
                width: 1,
                max_len: 224,
                temperature: 0.7,
                seed: i,
                slo: None,
            })
        })
        .collect();
    let mut migrated = 0usize;
    for rx in pending {
        let j = Json::parse(&rx.recv().expect("burst response")).unwrap();
        if j.get("replica_id").and_then(Json::as_usize) != Some(seeded) {
            migrated += 1;
        }
    }
    cluster.shutdown();
    (migrated, n)
}

/// Run the cluster scenarios, print them, assert the acceptance
/// invariant, and return (gated, info) metric maps.
fn cluster_scenarios() -> (Json, Json) {
    println!("\n# cluster routing: 4 sim replicas, skewed repeated-prefix workload");
    // per-token spin chosen so prefill dominates decode: skipped
    // prefill tokens translate into wall-clock, not channel noise
    let work = 6000usize;
    let mut gated = Json::obj();
    let mut info = Json::obj();
    let mut runs: Vec<(RoutingPolicy, ClusterRun)> = Vec::new();
    for routing in [
        RoutingPolicy::Prefix,
        RoutingPolicy::LeastLoaded,
        RoutingPolicy::RoundRobin,
    ] {
        let r = run_cluster_policy(routing, work);
        println!(
            "routing {:<12}  wall {:>7.3}s  {:>8.0} gen-tokens  {:>9.1} tokens/s  \
             prefix_hit_tokens {:>6.0}",
            routing.name(),
            r.wall_s,
            r.gen_tokens,
            r.tokens_per_s(),
            r.hit_tokens,
        );
        // gen totals are seed-determined and identical across policies;
        // hit totals are exact for content-determined placements
        // (prefix: affinity; round-robin: cycling). least-loaded
        // placement races on load snapshots -> info only.
        gated = gated.set(
            &format!("cluster.{}.gen_tokens", routing.name()),
            r.gen_tokens,
        );
        if routing != RoutingPolicy::LeastLoaded {
            gated = gated.set(
                &format!("cluster.{}.prefix_hit_tokens", routing.name()),
                r.hit_tokens,
            );
        } else {
            info = info.set(
                &format!("cluster.{}.prefix_hit_tokens", routing.name()),
                r.hit_tokens,
            );
        }
        info = info.set(
            &format!("cluster.{}.tokens_per_s", routing.name()),
            r.tokens_per_s(),
        );
        runs.push((routing, r));
    }
    let prefix = &runs[0].1;
    let rr = &runs[2].1;
    println!(
        "prefix vs round-robin: {:.2}x tokens/s, +{:.0} prefix_hit_tokens",
        prefix.tokens_per_s() / rr.tokens_per_s().max(1e-9),
        prefix.hit_tokens - rr.hit_tokens,
    );
    // the issue's acceptance invariant, asserted on every smoke run
    assert!(
        prefix.hit_tokens > rr.hit_tokens,
        "prefix routing must out-hit round-robin \
         ({} vs {})",
        prefix.hit_tokens,
        rr.hit_tokens
    );
    assert!(
        prefix.tokens_per_s() > rr.tokens_per_s(),
        "prefix routing must out-run round-robin \
         ({:.1} vs {:.1} tokens/s)",
        prefix.tokens_per_s(),
        rr.tokens_per_s()
    );
    info = info.set(
        "cluster.prefix_vs_rr.speedup",
        prefix.tokens_per_s() / rr.tokens_per_s().max(1e-9),
    );
    gated = gated.set(
        "cluster.prefix_vs_rr.hit_advantage",
        prefix.hit_tokens - rr.hit_tokens,
    );

    let (migrated, total) = run_steal_scenario(1200);
    println!(
        "work stealing: {migrated}/{total} burst requests migrated off the hot replica"
    );
    info = info.set("steal.migrated_requests", migrated);
    info = info.set("steal.total_requests", total);
    (gated, info)
}

// ----------------------------------------------------------------------
// Tracing overhead (flight recorder on vs off — runs without artifacts)
// ----------------------------------------------------------------------

/// Push the 24-request skewed workload through one sim engine with the
/// flight recorder at `trace_events` capacity; return (wall seconds,
/// events recorded, events dropped).
fn run_traced(trace_events: usize) -> (f64, u64, u64) {
    let mut engine = SimEngine::new(SimEngineConfig {
        lanes: 2,
        prefix_cache: false,
        trace_events,
        ..Default::default()
    });
    let t0 = Instant::now();
    for (id, prompt) in skewed_workload() {
        engine
            .submit(&GenRequest {
                prompt,
                width: 1,
                max_len: 224,
                temperature: 0.7,
                seed: id,
            })
            .expect("submit");
    }
    engine.drain().expect("drain");
    (
        t0.elapsed().as_secs_f64(),
        engine.tracer().recorded(),
        engine.tracer().dropped(),
    )
}

/// Traced-vs-untraced leg, asserting the observability contract: zero
/// events when disabled, and — with width 1 and the prefix cache off,
/// where no COW/dequant/evict batches occur — exactly the four
/// lifecycle events (submit/admit/first_token/finish) per request when
/// enabled. Event totals are seed-independent constants, so they are
/// gated; the wall-clock ratio is timing noise at this scale and is
/// reported as info.
fn tracing_overhead(mut gated: Json, mut info: Json) -> (Json, Json) {
    println!("\n# tracing overhead: 24 requests through one sim engine");
    let (off_s, off_events, _) = run_traced(0);
    let (on_s, on_events, on_dropped) = run_traced(4096);
    println!(
        "untraced {off_s:>8.4}s   traced {on_s:>8.4}s   ratio {:.3}x   \
         events {on_events} (dropped {on_dropped})",
        on_s / off_s.max(1e-9)
    );
    gated = gated
        .set("trace.disabled.events", off_events)
        .set("trace.enabled.events", on_events)
        .set("trace.enabled.dropped", on_dropped);
    info = info
        .set("trace.disabled.wall_s", off_s)
        .set("trace.enabled.wall_s", on_s)
        .set("trace.overhead_ratio", on_s / off_s.max(1e-9));
    (gated, info)
}

// ----------------------------------------------------------------------
// Tiered prefix cache (cold tier — runs without artifacts)
// ----------------------------------------------------------------------

/// One cold-tier cell: the zipf-skewed workload through a single sim
/// engine whose hot prefix budget (4 pages) sits far below the ~18-page
/// working set of the three system preambles. `cold_tier_bytes == 0` is
/// the hot-only baseline; otherwise every page `trim` would evict is
/// demoted to a q4 cold block instead and promoted back (one
/// dequant-on-upload, not a prefill) when the next same-system request
/// arrives, with overflow past the cold RAM budget spilled under
/// `spill_dir` rather than dropped. Sequential submission makes every
/// hit total exact. Returns (prefix hit tokens incl. promoted, cold hit
/// tokens, steady-state mean TTFT ms, spilled-bytes high-water mark).
fn run_cold_cell(cold_tier_bytes: usize, spill_dir: Option<PathBuf>) -> (f64, f64, f64, f64) {
    let mut engine = SimEngine::new(SimEngineConfig {
        lanes: 2,
        geom: Geometry {
            slots: 640,
            ..SimEngineConfig::default().geom
        },
        prefix_cache_pages: 4,
        cold_tier_bytes,
        work_per_token: 6000,
        ..Default::default()
    });
    if let Some(dir) = spill_dir {
        std::fs::create_dir_all(&dir).expect("create spill dir");
        engine.set_spill_dir(dir);
    }
    let mut hit_tokens = 0.0;
    let mut ttfts: Vec<f64> = Vec::new();
    let mut spilled_hw = 0.0f64;
    for (id, prompt) in skewed_workload() {
        engine
            .submit(&GenRequest {
                prompt,
                width: 1,
                max_len: 224,
                temperature: 0.7,
                seed: id,
            })
            .expect("submit");
        for done in engine.drain().expect("drain") {
            hit_tokens += done
                .result
                .chains
                .iter()
                .map(|c| c.stats.prefix_hit_tokens as f64)
                .sum::<f64>();
            ttfts.push(done.timing.ttft_ms);
        }
        spilled_hw = spilled_hw.max(engine.metrics.gauge("kv.spilled_bytes").get());
    }
    let cold_hit_tokens = engine.metrics.counter("kv.cold_hit_tokens").get();
    // the first request can never hit; steady state is the rest
    let steady_ttft = if ttfts.len() > 1 {
        ttfts[1..].iter().sum::<f64>() / (ttfts.len() - 1) as f64
    } else {
        0.0
    };
    (hit_tokens, cold_hit_tokens, steady_ttft, spilled_hw)
}

/// Hot-only vs hot+cold at the same hot budget: the cold tier must
/// recover strictly more prefix hit tokens from pages the hot pool
/// alone would have dropped (the issue's acceptance invariant, asserted
/// on every smoke run), and the spill dir must come back empty once the
/// engine drops. Hit/cold-token totals are deterministic but depend on
/// radix trim order, so — like the SLO sweep — the baseline pins
/// presence (null) until refreshed from a CI artifact; the boolean
/// invariant is gated exactly.
fn cold_tier_scenario(mut gated: Json, mut info: Json) -> (Json, Json) {
    println!("\n# tiered prefix cache: hot-only vs hot+cold at the same 4-page hot budget");
    let (hot_hits, hot_cold, hot_ttft, _) = run_cold_cell(0, None);
    let spill = std::env::temp_dir().join(format!("hyperscale-bench-spill-{}", std::process::id()));
    let (tier_hits, tier_cold, tier_ttft, spilled_hw) = run_cold_cell(4096, Some(spill.clone()));
    println!(
        "hot-only  prefix_hit_tokens {hot_hits:>6.0}  cold_hit_tokens {hot_cold:>6.0}  \
         steady TTFT {hot_ttft:>7.2} ms"
    );
    println!(
        "hot+cold  prefix_hit_tokens {tier_hits:>6.0}  cold_hit_tokens {tier_cold:>6.0}  \
         steady TTFT {tier_ttft:>7.2} ms  spilled high-water {spilled_hw:.0} B"
    );
    // the engine dropped inside run_cold_cell: ColdTier's Drop must
    // have deleted every .kvspill file it wrote
    let leftovers = std::fs::read_dir(&spill)
        .map(|d| d.count())
        .unwrap_or(0);
    assert_eq!(leftovers, 0, "spill dir must be empty after the engine drops");
    let _ = std::fs::remove_dir(&spill);
    assert_eq!(hot_cold, 0.0, "hot-only cell must never touch the cold tier");
    assert!(
        tier_cold > 0.0,
        "the 4-page hot budget must force hits through promotion"
    );
    assert!(
        spilled_hw > 0.0,
        "the 4 KiB cold budget must overflow to disk on this workload"
    );
    assert!(
        tier_hits > hot_hits,
        "hot+cold at the same hot budget must recover strictly more \
         prefix hit tokens than hot-only ({tier_hits} vs {hot_hits})"
    );
    gated = gated
        .set("prefix.cold.hot_only_hit_tokens", hot_hits)
        .set("prefix.cold.tiered_hit_tokens", tier_hits)
        .set("prefix.cold_hit_tokens", tier_cold)
        .set("prefix.cold.tiered_beats_hot_only", 1u64);
    info = info
        .set("prefix.cold.hot_only_steady_ttft_ms", hot_ttft)
        .set("prefix.cold.tiered_steady_ttft_ms", tier_ttft)
        .set("prefix.cold.spilled_bytes_high_water", spilled_hw);
    (gated, info)
}

// ----------------------------------------------------------------------
// SLO leg (virtual time — runs without artifacts; separate document)
// ----------------------------------------------------------------------

/// Seed shared by the workload golden tests and
/// `tools/seed_bench_slo.py`: one stream, three mirrors.
const SLO_SEED: u64 = 0x510_AD;

fn slo_workload(arrival: ArrivalKind, requests: usize, mean_gap_ns: u64) -> WorkloadConfig {
    WorkloadConfig {
        arrival,
        mean_gap_ns,
        ..WorkloadConfig::new(requests, SLO_SEED)
    }
}

/// SLO scenarios, all in virtual time: per-arrival draw totals, the
/// q4-vs-f32 admission delta, EDF+admission vs FCFS under overload,
/// and the 64–512-replica sweep. Asserts both issue acceptance
/// invariants (EDF beats FCFS on goodput-under-SLO; q4 admits strictly
/// more than f32 at the same byte capacity) and returns (gated, info)
/// for `BENCH_slo.json`.
fn slo_scenarios() -> (Json, Json) {
    let mut gated = Json::obj();
    let mut info = Json::obj();

    // Draw totals per arrival process: seeded constants mirrored
    // bit-for-bit by tools/seed_bench_slo.py (a drift in draw order or
    // RNG use shows up here and in workload.rs goldens first).
    println!("\n# SLO workload: per-arrival draw totals (4096 requests, seed {SLO_SEED:#x})");
    for arrival in ArrivalKind::ALL {
        let reqs = generate_mixed_workload(&slo_workload(arrival, 4096, 1_250_000));
        let prompt: u64 = reqs.iter().map(|r| r.prompt_tokens as u64).sum();
        let gen: u64 = reqs.iter().map(|r| r.gen_tokens as u64).sum();
        let by_class =
            |class: RequestClass| reqs.iter().filter(|r| r.class == class).count() as u64;
        let (chat, long, vote) = (
            by_class(RequestClass::Chat),
            by_class(RequestClass::LongContext),
            by_class(RequestClass::Voting),
        );
        println!(
            "arrival {:<8} prompt-tokens {prompt:>7}  gen-tokens {gen:>7}  \
             chat {chat:>4}  long_context {long:>4}  voting {vote:>4}",
            arrival.name()
        );
        let k = |m: &str| format!("workload.{}.{m}", arrival.name());
        gated = gated
            .set(&k("prompt_tokens"), prompt)
            .set(&k("gen_tokens"), gen)
            .set(&k("chat"), chat)
            .set(&k("long_context"), long)
            .set(&k("voting"), vote);
    }

    // Admission at equal byte capacity: uniform arrivals make the
    // decision stream integer-exact (seeder-mirrored). Capacity is
    // dtype-independent; q4 demand is ~7x smaller, so the same pool
    // must admit strictly more load — the hyper-scaling dividend.
    println!("\n# SLO admission: q4 vs f32 at byte_capacity(1, 1)");
    let uniform = slo_workload(ArrivalKind::Uniform, 4096, 1_250_000);
    let stream = slo_requests(&generate_mixed_workload(&uniform));
    let capacity = byte_capacity(1, 1);
    let mut accepted_by_dtype: Vec<u64> = Vec::new();
    for dtype in [KvDtype::F32, KvDtype::Q4] {
        let cost = CostModel::default_for(dtype, AllocatorKind::Uniform);
        let mut ctl = AdmissionController::new(capacity, cost);
        for r in &stream {
            ctl.offer(r.sim.arrival_ns, r.sim.prompt_tokens, r.sim.gen_tokens);
        }
        println!(
            "dtype {:<4}  accepted {:>5}  queued {:>5}  rejected {:>5}",
            dtype.name(),
            ctl.accepted(),
            ctl.queued(),
            ctl.rejected()
        );
        let k = |m: &str| format!("admission.uniform.{}.{m}", dtype.name());
        gated = gated
            .set(&k("accepted"), ctl.accepted())
            .set(&k("queued"), ctl.queued())
            .set(&k("rejected"), ctl.rejected());
        accepted_by_dtype.push(ctl.accepted());
    }
    assert!(
        accepted_by_dtype[1] > accepted_by_dtype[0],
        "q4 must admit strictly more than f32 at the same byte capacity \
         ({} vs {})",
        accepted_by_dtype[1],
        accepted_by_dtype[0]
    );
    gated = gated.set("slo.q4_admits_more_than_f32", 1u64);

    // EDF + admission vs FCFS/open on an overloaded stream: arrivals
    // outpace service ~9x, so FCFS queues explode and nearly every
    // completion misses its deadline, while admission keeps the
    // accepted set schedulable and EDF spends lanes on requests that
    // can still make it.
    println!("\n# SLO scheduling: EDF+admission vs FCFS under overload (4 replicas x 2 lanes)");
    let cfg = TimeflowConfig::new(4, 2, RoutingPolicy::RoundRobin);
    let overload =
        slo_requests(&generate_mixed_workload(&slo_workload(ArrivalKind::Poisson, 2048, 100_000)));
    let edf = simulate_slo(&cfg, &overload, &SloPolicy::edf_admitted(4, 2));
    let fcfs = simulate_slo(&cfg, &overload, &SloPolicy::fcfs_open(4, 2));
    println!(
        "edf+admission {:>10.0} goodput-tokens/s   fcfs/open {:>10.0} goodput-tokens/s   \
         ({:.1}x)",
        edf.slo_goodput_tokens_per_s,
        fcfs.slo_goodput_tokens_per_s,
        edf.slo_goodput_tokens_per_s / fcfs.slo_goodput_tokens_per_s.max(1e-9)
    );
    assert!(
        edf.slo_goodput_tokens_per_s > fcfs.slo_goodput_tokens_per_s,
        "EDF + admission must beat FCFS on goodput under SLO \
         ({:.0} vs {:.0} tokens/s)",
        edf.slo_goodput_tokens_per_s,
        fcfs.slo_goodput_tokens_per_s
    );
    gated = gated.set("slo.edf_beats_fcfs", 1u64);
    info = info
        .set("slo.overload.edf.goodput_tokens_per_s", edf.slo_goodput_tokens_per_s)
        .set("slo.overload.fcfs.goodput_tokens_per_s", fcfs.slo_goodput_tokens_per_s);

    // Hyperscale sweep: virtual-time TTFT tails + goodput at 64–512
    // replicas, arrival rate scaled with the fleet. Deterministic, but
    // not seeder-computable — baselines pin presence (null), CI pins
    // byte-identity of the sim elsewhere.
    println!("\n# SLO sweep: 64-512 replicas x 4 lanes, poisson arrivals (virtual time)");
    for replicas in [64usize, 128, 256, 512] {
        let cfg = TimeflowConfig::new(replicas, 4, RoutingPolicy::RoundRobin);
        let mean_gap_ns = 4_000_000 / replicas as u64;
        let reqs = slo_requests(&generate_mixed_workload(&slo_workload(
            ArrivalKind::Poisson,
            8192,
            mean_gap_ns,
        )));
        let rep = simulate_slo(&cfg, &reqs, &SloPolicy::edf_admitted(replicas, 4));
        println!(
            "r{replicas:<4} ttft p50 {:>9.3} ms  p99 {:>9.3} ms  p999 {:>9.3} ms  \
             goodput {:>10.0} tokens/s",
            rep.ttft_p50_ns / 1e6,
            rep.ttft_p99_ns / 1e6,
            rep.ttft_p999_ns / 1e6,
            rep.slo_goodput_tokens_per_s
        );
        let k = |m: &str| format!("sweep.r{replicas}.{m}");
        gated = gated
            .set(&k("ttft_p50_ns"), rep.ttft_p50_ns)
            .set(&k("ttft_p99_ns"), rep.ttft_p99_ns)
            .set(&k("ttft_p999_ns"), rep.ttft_p999_ns)
            .set(&k("goodput_tokens_per_s"), rep.slo_goodput_tokens_per_s);
    }
    (gated, info)
}

fn main() -> hyperscale::Result<()> {
    let args = Args::from_env();
    let artifacts = args.get_str("artifacts", "artifacts");
    let iters = args.get_usize("iters", 3)?;
    let smoke = args.flag("smoke");

    if !smoke {
        engine_benches(artifacts, iters)?;
    }
    let (gated, info) = cluster_scenarios();
    let (gated, info) = tracing_overhead(gated, info);
    let (gated, info) = cold_tier_scenario(gated, info);

    if let Some(path) = args.get("out") {
        let report = Json::obj()
            .set("bench", "serve")
            .set("schema", 1u64)
            .set("smoke", smoke)
            .set("gated", gated)
            .set("info", info);
        std::fs::write(path, report.to_string())?;
        println!("wrote {path}");
    }

    let (slo_gated, slo_info) = slo_scenarios();
    if let Some(path) = args.get("slo-out") {
        let report = Json::obj()
            .set("bench", "slo")
            .set("schema", 1u64)
            .set("smoke", smoke)
            .set("gated", slo_gated)
            .set("info", slo_info);
        std::fs::write(path, report.to_string())?;
        println!("wrote {path}");
    }
    Ok(())
}

// ----------------------------------------------------------------------
// Engine benches (need AOT artifacts; skipped under --smoke)
// ----------------------------------------------------------------------

fn engine_benches(artifacts: &str, iters: usize) -> hyperscale::Result<()> {
    println!("# bench_serve — engine throughput (8 lanes, W=2, gsm8k prompts)");

    for (name, policy, variant, cr) in [
        ("vanilla", PolicyKind::Vanilla, "base", 1.0),
        ("dms_cr4", PolicyKind::Dms, "dms_w16_cr4", 4.0),
        ("dms_cr8", PolicyKind::Dms, "dms_w16_cr8", 8.0),
        ("quest_cr4", PolicyKind::Quest, "base", 4.0),
    ] {
        let mut engine = match Engine::new(EngineConfig {
            artifacts: artifacts.into(),
            variant: variant.into(),
            policy,
            cr,
            temperature: 0.7,
            // keep the policy comparison pure: repeated iterations must
            // not hit prefixes retained by earlier ones
            prefix_cache: false,
            ..Default::default()
        }) {
            Ok(e) => e,
            Err(e) => {
                println!("skip {name}: {e:#}");
                continue;
            }
        };
        let reqs = requests(6, 2, 144);
        let mut gen_tokens = 0f64;
        let mut reads = 0f64;
        let r = bench(&format!("serve_{name}"), 1, iters, || {
            let (results, _) = engine.run(&reqs).expect("run");
            gen_tokens = results
                .iter()
                .flat_map(|r| &r.chains)
                .map(|c| c.stats.gen_tokens as f64)
                .sum();
            reads = results.iter().map(|r| r.total_reads()).sum();
        });
        r.print_throughput(gen_tokens, "gen-tokens");
        println!(
            "      KV reads per generated token: {:.1}",
            reads / gen_tokens.max(1.0)
        );
    }

    // ------------------------------------------------------------------
    // Dynamic admission vs per-request static batches, equal cache
    // budget (same engine, same slots, same policy). "static" replays
    // the pre-refactor server: one engine.run per request, so a W=2
    // request occupies 2 of 8 lanes and the rest idle. "dynamic"
    // submits every request into one continuous-batching session.
    // ------------------------------------------------------------------
    println!("\n# dynamic admission vs static per-request batches");
    for (name, policy, variant, cr) in [
        ("dms_cr4", PolicyKind::Dms, "dms_w16_cr4", 4.0),
        ("vanilla", PolicyKind::Vanilla, "base", 1.0),
    ] {
        let mut engine = match Engine::new(EngineConfig {
            artifacts: artifacts.into(),
            variant: variant.into(),
            policy,
            cr,
            temperature: 0.7,
            // the static run must not seed prefix hits for the dynamic
            // run — admission packing is the variable under test
            prefix_cache: false,
            ..Default::default()
        }) {
            Ok(e) => e,
            Err(e) => {
                println!("skip {name}: {e:#}");
                continue;
            }
        };
        let reqs = requests(12, 2, 144);

        let mut static_tokens = 0f64;
        let sw = Instant::now();
        for req in &reqs {
            let (results, _) = engine.run(std::slice::from_ref(req)).expect("run");
            static_tokens += results
                .iter()
                .flat_map(|r| &r.chains)
                .map(|c| c.stats.gen_tokens as f64)
                .sum::<f64>();
        }
        let static_s = sw.elapsed().as_secs_f64();

        let mut dynamic_tokens = 0f64;
        let sw = Instant::now();
        let mut session = engine.begin_session();
        for req in &reqs {
            engine.submit(&mut session, req).expect("submit");
        }
        while !engine.is_idle(&session) {
            for done in engine.tick(&mut session).expect("tick") {
                dynamic_tokens += done.timing.gen_tokens as f64;
            }
        }
        let dynamic_s = sw.elapsed().as_secs_f64();

        let st = static_tokens / static_s.max(1e-9);
        let dt = dynamic_tokens / dynamic_s.max(1e-9);
        println!(
            "{name:<10} static  {static_s:>8.3}s  {st:>10.1} gen-tokens/s"
        );
        println!(
            "{name:<10} dynamic {dynamic_s:>8.3}s  {dt:>10.1} gen-tokens/s   speedup {:.2}x",
            dt / st.max(1e-9)
        );
    }

    // ------------------------------------------------------------------
    // Radix prefix cache: repeated-system-prompt workload. The same
    // prompt hits the engine 10 times (arriving one per tick, as from
    // independent clients); with the prefix cache on, every request
    // after the first starts prefill at the divergence point. Reported:
    // prefill tokens skipped (hit rate), mean TTFT with/without the
    // cache, and whether the token streams stayed identical.
    // ------------------------------------------------------------------
    println!("\n# prefix cache: repeated-system-prompt workload");
    let mut texts_by_mode: Vec<Vec<String>> = Vec::new();
    for prefix_cache in [false, true] {
        let mut engine = match Engine::new(EngineConfig {
            artifacts: artifacts.into(),
            variant: "base".into(),
            policy: PolicyKind::Vanilla,
            cr: 1.0,
            temperature: 0.7,
            prefix_cache,
            ..Default::default()
        }) {
            Ok(e) => e,
            Err(e) => {
                println!("skip prefix-cache bench: {e:#}");
                break;
            }
        };
        // a system-style preamble (64-symbol vocabulary only) shared by
        // every request, long enough to span several KV pages
        let question = hyperscale::tasks::gen_problem("gsm8k", 11, 0).prompt;
        let prompt = format!(
            "system: you are a careful math solver. think step by step \
             and answer with the final number only.|{question}"
        );
        let mut session = engine.begin_session();
        let mut ttfts: Vec<f64> = Vec::new();
        let mut hit_tokens = 0f64;
        let mut prompt_tokens = 0f64;
        let mut texts: Vec<String> = Vec::new();
        // requests arrive one after another (the repeated-system-prompt
        // pattern the prefix cache targets), so each can hit the pages
        // its predecessor retained
        for i in 0..10u64 {
            let req = GenRequest {
                prompt: prompt.clone(),
                width: 1,
                max_len: 144,
                temperature: 0.7,
                seed: i,
            };
            engine.submit(&mut session, &req).expect("submit");
            while !engine.is_idle(&session) {
                for done in engine.tick(&mut session).expect("tick") {
                    for c in &done.result.chains {
                        hit_tokens += c.stats.prefix_hit_tokens as f64;
                        prompt_tokens += c.stats.prompt_tokens as f64;
                        texts.push(c.text.clone());
                    }
                    ttfts.push(done.timing.ttft_ms);
                }
            }
        }
        let mean_ttft = ttfts.iter().sum::<f64>() / ttfts.len().max(1) as f64;
        // the first request can never hit; report the steady-state too
        let rest_ttft = if ttfts.len() > 1 {
            ttfts[1..].iter().sum::<f64>() / (ttfts.len() - 1) as f64
        } else {
            mean_ttft
        };
        println!(
            "prefix_cache={prefix_cache:<5}  prefill tokens skipped {:>6.0}/{:>6.0} ({:>5.1}%)  \
             mean TTFT {mean_ttft:>7.2} ms  steady-state TTFT {rest_ttft:>7.2} ms",
            hit_tokens,
            prompt_tokens,
            100.0 * hit_tokens / prompt_tokens.max(1.0),
        );
        texts_by_mode.push(texts);
    }
    if texts_by_mode.len() == 2 {
        let identical = texts_by_mode[0] == texts_by_mode[1];
        println!("identical output streams with/without prefix cache: {identical}");
        assert!(identical, "prefix-cache reuse changed a token stream");
    }
    Ok(())
}
