// Shared lint config for non-lib targets (benches/tests/examples are
// separate crates, so the crate-wide allows in rust/src/lib.rs do not
// reach them): the same flat-layout indexing idiom applies here, and
// vec! payloads deliberately mirror the engine's heap buffers.
// Correctness lints stay on — CI denies all remaining warnings via
// `cargo clippy --all-targets -- -D warnings`.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::manual_div_ceil,
    clippy::uninlined_format_args,
    clippy::useless_vec
)]

//! Compression-policy overhead bench: per-step host cost of each
//! eviction policy at a realistic cache occupancy (paper §2.2 claims
//! "minimal computational overhead" for the heuristics — verify ours).

use hyperscale::compress::{build_policy, PolicyKind, StepView, WriteAction};
use hyperscale::kvcache::{CacheStore, Geometry};
use hyperscale::util::benchkit::bench;

fn main() {
    println!("# bench_policies — host-side per-step policy cost");
    let g = Geometry {
        layers: 4,
        kv_heads: 2,
        slots: 320,
        head_dim: 16,
        page_size: 16,
    };
    let lh = g.lh();
    let alpha = vec![0.6f32; lh];
    let attn: Vec<f32> = (0..lh * g.slots).map(|i| (i % 97) as f32 / 97.0).collect();
    let attn_self = vec![0.1f32; lh];

    for kind in [
        PolicyKind::Vanilla,
        PolicyKind::Dms,
        PolicyKind::DmsImmediate,
        PolicyKind::Tova,
        PolicyKind::H2o,
        PolicyKind::Quest,
        PolicyKind::Dmc,
        PolicyKind::Window,
    ] {
        let mut cache = CacheStore::new(g, 1);
        let mut policy = build_policy(kind, 4.0, 160, 16, g.page_size);
        let k = vec![0.5f32; g.head_dim];
        let v = vec![0.5f32; g.head_dim];
        let mut pos = 0usize;
        let mut actions: Vec<WriteAction> = Vec::new();
        let mut written = vec![None; lh];
        let r = bench(&format!("policy_{}", kind.name()), 20, 300, || {
            cache.apply_due_evictions(0, pos);
            policy.write_actions(&alpha, g.layers, g.kv_heads, &mut actions);
            for l in 0..g.layers {
                for h in 0..g.kv_heads {
                    let i = l * g.kv_heads + h;
                    written[i] = None;
                    match actions[i] {
                        WriteAction::Merge => {
                            cache.merge_into_last(0, l, h, &k, &v);
                        }
                        WriteAction::Append => {
                            if let Some(s) = cache.alloc_slot(0, l, h) {
                                cache.write(0, l, h, s, pos, &k, &v);
                                written[i] = Some(s);
                            }
                        }
                    }
                }
            }
            let view = StepView {
                lane: 0,
                pos,
                alpha: &alpha,
                attn: &attn,
                attn_self: &attn_self,
                written: &written,
            };
            policy.post_write(&mut cache, &view);
            pos += 1;
            if pos % 280 == 0 {
                cache.reset_lane(0);
                pos = 0;
            }
        });
        r.print();
    }
}
