// Shared lint config for non-lib targets (benches/tests/examples are
// separate crates, so the crate-wide allows in rust/src/lib.rs do not
// reach them): the same flat-layout indexing idiom applies here, and
// vec! payloads deliberately mirror the engine's heap buffers.
// Correctness lints stay on — CI denies all remaining warnings via
// `cargo clippy --all-targets -- -D warnings`.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::manual_div_ceil,
    clippy::uninlined_format_args,
    clippy::useless_vec
)]

//! Compression-policy overhead bench: per-step host cost of each
//! eviction policy at a realistic cache occupancy (paper §2.2 claims
//! "minimal computational overhead" for the heuristics — verify ours),
//! now swept over every budget allocator.
//!
//! `--smoke` runs a deterministic policy × allocator grid (fixed step
//! count, synthetic attention made of exact multiples of 2⁻⁵) and
//! emits the perf-regression JSON (`--out BENCH_policies.json`) that
//! CI diffs against `tools/bench_baselines/BENCH_policies.json` (see
//! `tools/bench_compare.py`). Gated metrics: deterministic occupancy
//! counters — final live tokens, per-head min/max, live fraction, and
//! each plan's conserved total — gate by value; wall-clock eviction
//! throughput (tokens/s) is machine-dependent, so it gates
//! *structurally* (null baseline entries: the metric must exist and
//! be numeric). The seeded baseline comes from
//! `tools/seed_bench_policies.py`, which mirrors the synthetic loop
//! exactly and emits the null throughput entries alongside the pinned
//! counters.

use std::time::Instant;

use hyperscale::compress::{
    build_allocator, build_policy, build_policy_planned, AllocatorKind, AttnStats,
    BudgetPlan, PolicyKind, StepView, WriteAction,
};
use hyperscale::kvcache::{CacheStore, Geometry};
use hyperscale::util::benchkit::bench;
use hyperscale::util::{Args, Json};

const ALL_POLICIES: [PolicyKind; 8] = [
    PolicyKind::Vanilla,
    PolicyKind::Dms,
    PolicyKind::DmsImmediate,
    PolicyKind::Tova,
    PolicyKind::H2o,
    PolicyKind::Quest,
    PolicyKind::Dmc,
    PolicyKind::Window,
];

fn smoke_geom() -> Geometry {
    Geometry {
        layers: 4,
        kv_heads: 2,
        slots: 320,
        head_dim: 16,
        page_size: 16,
    }
}

/// One engine-shaped policy step: due evictions, write-actions,
/// append/merge (merge falls back to append when nothing merged yet,
/// as the engine does), post_write.
fn policy_step(
    cache: &mut CacheStore,
    policy: &mut Box<dyn hyperscale::compress::Policy>,
    pos: usize,
    alpha: &[f32],
    attn: &[f32],
    attn_self: &[f32],
    written: &mut [Option<usize>],
    actions: &mut Vec<WriteAction>,
    k: &[f32],
    v: &[f32],
) {
    let g = cache.geom;
    cache.apply_due_evictions(0, pos);
    policy.write_actions(alpha, g.layers, g.kv_heads, actions);
    for l in 0..g.layers {
        for h in 0..g.kv_heads {
            let i = l * g.kv_heads + h;
            written[i] = None;
            let append = match actions[i] {
                WriteAction::Merge => !cache.merge_into_last(0, l, h, k, v),
                WriteAction::Append => true,
            };
            if append {
                if let Some(s) = cache.alloc_slot(0, l, h) {
                    cache.write(0, l, h, s, pos, k, v);
                    written[i] = Some(s);
                }
            }
        }
    }
    let view = StepView {
        lane: 0,
        pos,
        alpha,
        attn,
        attn_self,
        written,
    };
    policy.post_write(cache, &view);
}

/// Deterministic smoke grid: every policy under every allocator's plan
/// for a fixed number of steps. Returns (gated, info) metric maps.
fn smoke() -> (Json, Json) {
    const STEPS: usize = 120;
    let g = smoke_geom();
    let lh = g.lh();
    let per_head = 40usize;
    let global = per_head * lh;

    // synthetic inputs: exact multiples of 2⁻⁵ so the Python seeder
    // reproduces every f64 accumulation bit-for-bit
    let alpha = vec![0.6f32; lh];
    let attn: Vec<f32> = (0..lh * g.slots)
        .map(|i| ((i % 97) as f32) * 0.03125)
        .collect();
    let attn_self = vec![0.25f32; lh];

    // one observation seeds the adaptive allocator's statistics
    let mut stats = AttnStats::new();
    stats.observe_attn(g.layers, g.kv_heads, g.slots, &attn, &attn_self);

    let mut gated = Json::obj();
    let info = Json::obj();
    println!("# bench_policies --smoke — policy × allocator occupancy grid");
    for alloc in AllocatorKind::all() {
        let plan = build_allocator(alloc).plan(g.layers, g.kv_heads, global, Some(&stats));
        assert_eq!(
            plan.total(g.layers, g.kv_heads),
            global,
            "{} plan must conserve the global budget",
            alloc.name()
        );
        gated = gated.set(
            &format!("plan.{}.tokens", alloc.name()),
            plan.total(g.layers, g.kv_heads) as f64,
        );
        for kind in ALL_POLICIES {
            let mut cache = CacheStore::new(g, 1);
            let mut policy = build_policy_planned(kind, plan.clone(), 16, g.page_size);
            let k = vec![0.5f32; g.head_dim];
            let v = vec![0.5f32; g.head_dim];
            let mut actions: Vec<WriteAction> = Vec::new();
            let mut written = vec![None; lh];
            let t0 = Instant::now();
            for pos in 0..STEPS {
                policy_step(
                    &mut cache,
                    &mut policy,
                    pos,
                    &alpha,
                    &attn,
                    &attn_self,
                    &mut written,
                    &mut actions,
                    &k,
                    &v,
                );
            }
            let wall = t0.elapsed().as_secs_f64().max(1e-9);
            let per_lh: Vec<usize> = (0..lh).map(|i| cache.live_count_lh(0, i)).collect();
            let live: usize = per_lh.iter().sum();
            let min_lh = per_lh.iter().copied().min().unwrap_or(0);
            let max_lh = per_lh.iter().copied().max().unwrap_or(0);
            let fraction = live as f64 / (lh * g.slots) as f64;
            // budgeted policies must sit within the plan everywhere
            if matches!(
                kind,
                PolicyKind::Tova | PolicyKind::H2o | PolicyKind::Window
            ) {
                assert_eq!(cache.plan_overflow(0, &plan), 0, "{:?} overflow", kind);
            }
            let key = |m: &str| format!("policy.{}.{}.{m}", kind.name(), alloc.name());
            gated = gated
                .set(&key("live_tokens"), live as f64)
                .set(&key("live_min_lh"), min_lh as f64)
                .set(&key("live_max_lh"), max_lh as f64)
                .set(&key("live_fraction"), fraction)
                // eviction throughput: machine-dependent, so the
                // baseline pins it at null (structural gate) — a
                // policy that stops emitting it fails CI even though
                // its wall-clock value is never compared
                .set(&key("tokens_per_s"), STEPS as f64 / wall);
            println!(
                "{:<14} {:<8}  live {live:>4} (lh {min_lh}..{max_lh}, {:.4} frac)  {:>9.0} tok/s",
                kind.name(),
                alloc.name(),
                fraction,
                STEPS as f64 / wall
            );
        }
    }
    (gated, info)
}

/// Wall-clock overhead bench (original shape), now also exercising the
/// planned path: the uniform plan is the legacy scalar budget.
fn overhead_bench() {
    println!("# bench_policies — host-side per-step policy cost");
    let g = smoke_geom();
    let lh = g.lh();
    let alpha = vec![0.6f32; lh];
    let attn: Vec<f32> = (0..lh * g.slots).map(|i| (i % 97) as f32 / 97.0).collect();
    let attn_self = vec![0.1f32; lh];

    for kind in ALL_POLICIES {
        let mut cache = CacheStore::new(g, 1);
        let mut policy = build_policy(kind, 4.0, 160, 16, g.page_size);
        let k = vec![0.5f32; g.head_dim];
        let v = vec![0.5f32; g.head_dim];
        let mut pos = 0usize;
        let mut actions: Vec<WriteAction> = Vec::new();
        let mut written = vec![None; lh];
        let r = bench(&format!("policy_{}", kind.name()), 20, 300, || {
            policy_step(
                &mut cache,
                &mut policy,
                pos,
                &alpha,
                &attn,
                &attn_self,
                &mut written,
                &mut actions,
                &k,
                &v,
            );
            pos += 1;
            if pos % 280 == 0 {
                cache.reset_lane(0);
                pos = 0;
            }
        });
        r.print();
    }

    // per-allocator enforcement cost on the budgeted policies: how
    // much a non-uniform plan changes the hot-loop price
    println!("\n# planned enforcement cost (tova, per allocator)");
    let mut stats = AttnStats::new();
    stats.observe_attn(g.layers, g.kv_heads, g.slots, &attn, &attn_self);
    for alloc in AllocatorKind::all() {
        let plan: BudgetPlan =
            build_allocator(alloc).plan(g.layers, g.kv_heads, 40 * lh, Some(&stats));
        let mut cache = CacheStore::new(g, 1);
        let mut policy = build_policy_planned(PolicyKind::Tova, plan, 16, g.page_size);
        let k = vec![0.5f32; g.head_dim];
        let v = vec![0.5f32; g.head_dim];
        let mut pos = 0usize;
        let mut actions: Vec<WriteAction> = Vec::new();
        let mut written = vec![None; lh];
        let r = bench(&format!("tova_{}", alloc.name()), 20, 300, || {
            policy_step(
                &mut cache,
                &mut policy,
                pos,
                &alpha,
                &attn,
                &attn_self,
                &mut written,
                &mut actions,
                &k,
                &v,
            );
            pos += 1;
            if pos % 280 == 0 {
                cache.reset_lane(0);
                pos = 0;
            }
        });
        r.print();
    }
}

fn main() -> hyperscale::Result<()> {
    let args = Args::from_env();
    let smoke_mode = args.flag("smoke");

    if !smoke_mode {
        overhead_bench();
    }
    let (gated, info) = if smoke_mode {
        smoke()
    } else {
        (Json::obj(), Json::obj())
    };

    if let Some(path) = args.get("out") {
        let report = Json::obj()
            .set("bench", "policies")
            .set("schema", 1u64)
            .set("smoke", smoke_mode)
            .set("gated", gated)
            .set("info", info);
        std::fs::write(path, report.to_string())?;
        println!("wrote {path}");
    }
    Ok(())
}
