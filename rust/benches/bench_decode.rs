// Shared lint config for non-lib targets (benches/tests/examples are
// separate crates, so the crate-wide allows in rust/src/lib.rs do not
// reach them): the same flat-layout indexing idiom applies here, and
// vec! payloads deliberately mirror the engine's heap buffers.
// Correctness lints stay on — CI denies all remaining warnings via
// `cargo clippy --all-targets -- -D warnings`.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::manual_div_ceil,
    clippy::uninlined_format_args,
    clippy::useless_vec
)]

//! Decode-step latency/throughput bench (the L3 hot path).
//!
//! Measures the end-to-end decode step (literal upload + XLA execute +
//! output download + policy work) for the Pallas and fused-jnp
//! executable variants and both slot bucket sizes — the data behind the
//! §Perf log in EXPERIMENTS.md.

use hyperscale::compress::PolicyKind;
use hyperscale::config::EngineConfig;
use hyperscale::engine::{Engine, GenRequest};
use hyperscale::util::benchkit::bench;
use hyperscale::util::Args;

fn engine(artifacts: &str, jnp: bool, slots: usize) -> hyperscale::Result<Engine> {
    Engine::new(EngineConfig {
        artifacts: artifacts.into(),
        variant: "dms_w16_cr4".into(),
        policy: PolicyKind::Dms,
        cr: 4.0,
        temperature: 0.7,
        slots,
        use_jnp_decode: jnp,
        ..Default::default()
    })
}

fn main() -> hyperscale::Result<()> {
    let args = Args::from_env();
    let artifacts = args.get_str("artifacts", "artifacts");
    let iters = args.get_usize("iters", 3)?;
    println!("# bench_decode — full-batch generation steps (8 lanes)");

    for (name, jnp, slots) in [
        ("decode_pallas_s320", false, 320usize),
        ("decode_jnp_s320", true, 320),
        ("decode_pallas_s192", false, 192),
    ] {
        let mut eng = match engine(artifacts, jnp, slots) {
            Ok(e) => e,
            Err(e) => {
                println!("skip {name}: {e:#}");
                continue;
            }
        };
        // 8 concurrent chains, ~64 decode steps each
        let reqs: Vec<GenRequest> = (0..8)
            .map(|i| GenRequest {
                prompt: hyperscale::tasks::gen_problem("aime", 3, i).prompt,
                width: 1,
                max_len: 120,
                temperature: 0.7,
                seed: i,
            })
            .collect();
        let mut steps = 0u64;
        let r = bench(name, 1, iters, || {
            let (_, stats) = eng.run(&reqs).expect("run");
            steps = stats.decode_steps + stats.prefill_chunks;
            stats.decode_steps
        });
        r.print();
        println!(
            "      per-step: {:.3} ms over ~{} steps/iter ({} tokens/s at batch 8)",
            r.mean_s * 1e3 / steps.max(1) as f64,
            steps,
            (steps as f64 * 8.0 / r.mean_s) as u64
        );
    }
    Ok(())
}
