// Shared lint config for non-lib targets (benches/tests/examples are
// separate crates, so the crate-wide allows in rust/src/lib.rs do not
// reach them): the same flat-layout indexing idiom applies here, and
// vec! payloads deliberately mirror the engine's heap buffers.
// Correctness lints stay on — CI denies all remaining warnings via
// `cargo clippy --all-targets -- -D warnings`.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::manual_div_ceil,
    clippy::uninlined_format_args,
    clippy::useless_vec
)]

//! End-to-end engine tests over the real AOT artifacts + PJRT runtime.
//! These are skipped (with a notice) when `artifacts/` hasn't been
//! built. Each test builds its own engine; PJRT compilation is cached
//! per-process by the Runtime only within one engine, so tests stay in
//! the same binary to amortize nothing but still run in minutes.

use std::path::PathBuf;

use hyperscale::compress::PolicyKind;
use hyperscale::config::EngineConfig;
use hyperscale::engine::{Engine, FinishReason, GenRequest};
use hyperscale::tasks::{extract_answer, gen_problem};

fn artifacts() -> Option<PathBuf> {
    let p = PathBuf::from(
        std::env::var("HS_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("skipping: artifacts not built");
        None
    }
}

fn engine(policy: PolicyKind, variant: &str, cr: f64) -> Option<Engine> {
    let artifacts = artifacts()?;
    Some(
        Engine::new(EngineConfig {
            artifacts,
            variant: variant.into(),
            policy,
            cr,
            temperature: 0.0,
            ..Default::default()
        })
        .expect("engine"),
    )
}

#[test]
fn greedy_generation_is_deterministic() {
    let Some(mut eng) = engine(PolicyKind::Vanilla, "base", 1.0) else {
        return;
    };
    let req = GenRequest {
        prompt: gen_problem("math", 1, 0).prompt,
        width: 1,
        max_len: 120,
        temperature: 0.0,
        seed: 0,
    };
    let a = eng.generate(req.clone()).unwrap();
    let b = eng.generate(req).unwrap();
    assert_eq!(a.chains[0].text, b.chains[0].text);
    assert!(!a.chains[0].text.is_empty());
}

#[test]
fn parallel_chains_fork_and_match_greedy() {
    let Some(mut eng) = engine(PolicyKind::Vanilla, "base", 1.0) else {
        return;
    };
    let res = eng
        .generate(GenRequest {
            prompt: gen_problem("math", 1, 0).prompt,
            width: 4,
            max_len: 120,
            temperature: 0.0,
            seed: 3,
        })
        .unwrap();
    assert_eq!(res.chains.len(), 4);
    // greedy chains from a forked prefix must be identical
    for c in &res.chains[1..] {
        assert_eq!(c.text, res.chains[0].text);
    }
    // at least one sibling reused the leader's prefill
    assert!(res.chains.iter().any(|c| c.stats.forked_prefill));
}

#[test]
fn batched_requests_match_single_requests() {
    let Some(mut eng) = engine(PolicyKind::Vanilla, "base", 1.0) else {
        return;
    };
    let reqs: Vec<GenRequest> = (0..4)
        .map(|i| GenRequest {
            prompt: gen_problem("gsm8k", 5, i).prompt,
            width: 1,
            max_len: 160,
            temperature: 0.0,
            seed: i,
        })
        .collect();
    let (batched, _) = eng.run(&reqs).unwrap();
    for (i, req) in reqs.iter().enumerate() {
        let single = eng.generate(req.clone()).unwrap();
        assert_eq!(
            single.chains[0].text, batched[i].chains[0].text,
            "lane isolation violated for request {i}"
        );
    }
}

#[test]
fn dms_compresses_and_still_generates() {
    let Some(mut eng) = engine(PolicyKind::Dms, "dms_w16_cr4", 4.0) else {
        return;
    };
    let res = eng
        .generate(GenRequest {
            prompt: gen_problem("gsm8k", 2, 1).prompt,
            width: 1,
            max_len: 192,
            temperature: 0.0,
            seed: 0,
        })
        .unwrap();
    let c = &res.chains[0];
    assert!(c.stats.achieved_cr() > 1.2, "CR {}", c.stats.achieved_cr());
    assert!(c.stats.gen_tokens > 0);
    assert!(c.stats.peak_tokens <= c.stats.prompt_tokens as f64 + c.stats.gen_tokens as f64);
}

#[test]
fn tova_budget_bounds_peak_memory() {
    let Some(mut eng) = engine(PolicyKind::Tova, "base", 4.0) else {
        return;
    };
    let res = eng
        .generate(GenRequest {
            prompt: gen_problem("gsm8k", 2, 1).prompt,
            width: 1,
            max_len: 160,
            temperature: 0.0,
            seed: 0,
        })
        .unwrap();
    // budget = 160/4 = 40 tokens per head (+1 transient for the step)
    assert!(
        res.chains[0].stats.peak_tokens <= 41.0,
        "peak {}",
        res.chains[0].stats.peak_tokens
    );
}

#[test]
fn quest_reduces_reads_but_not_memory() {
    let Some(mut eng) = engine(PolicyKind::Vanilla, "base", 1.0) else {
        return;
    };
    // page selection only pays off once the live cache exceeds the page
    // budget — use a long-context prompt (the Quest regime).
    let p = hyperscale::tasks::gen_niah_with_fillers(9, 1, 8);
    let req = GenRequest {
        prompt: p.prompt,
        width: 1,
        max_len: 260,
        temperature: 0.0,
        seed: 0,
    };
    let vanilla = eng.generate(req.clone()).unwrap();
    eng.set_policy(PolicyKind::Quest, 4.0).unwrap();
    let quest = eng.generate(req).unwrap();
    let (v, q) = (&vanilla.chains[0].stats, &quest.chains[0].stats);
    // restricted attention changes the trajectory (and thus length), so
    // compare reads per decode step, not totals.
    let v_per = v.decode_reads / v.gen_tokens.max(1) as f64;
    let q_per = q.decode_reads / q.gen_tokens.max(1) as f64;
    assert!(
        q_per < v_per,
        "quest reads/token {q_per:.1} !< vanilla {v_per:.1}"
    );
    // quest never evicts: everything it saw stays resident
    let q_seen = (q.prompt_tokens + q.gen_tokens) as f64;
    assert!(
        q.peak_tokens >= q_seen * 0.9,
        "quest peak {} < seen {q_seen}",
        q.peak_tokens
    );
}

#[test]
fn overflow_is_reported_not_crashed() {
    let Some(artifacts) = artifacts() else { return };
    let mut eng = Engine::new(EngineConfig {
        artifacts,
        variant: "base".into(),
        policy: PolicyKind::Vanilla,
        cr: 1.0,
        temperature: 0.9,
        top_k: 0,
        ..Default::default()
    })
    .unwrap();
    // force a chain that cannot stop before max_len: long prompt + high
    // temperature makes early termination unlikely but not guaranteed;
    // run a few seeds and only require that nothing panics and that
    // every finish reason is valid.
    let p = gen_problem("aime", 4, 0);
    let (results, _) = eng
        .run(&[GenRequest {
            prompt: p.prompt,
            width: 3,
            max_len: 96,
            temperature: 1.2,
            seed: 11,
        }])
        .unwrap();
    for c in &results[0].chains {
        assert!(matches!(
            c.finish,
            FinishReason::Stop | FinishReason::Length | FinishReason::Overflow
        ));
        assert!(c.stats.gen_tokens <= 96);
    }
}

#[test]
fn extractable_answers_survive_the_full_stack() {
    let Some(mut eng) = engine(PolicyKind::Vanilla, "base", 1.0) else {
        return;
    };
    let p = gen_problem("niah", 1, 2);
    let max_len = p.prompt.len() + 16;
    let res = eng
        .generate(GenRequest {
            prompt: p.prompt.clone(),
            width: 1,
            max_len,
            temperature: 0.0,
            seed: 0,
        })
        .unwrap();
    // NIAH answers are short; the model should at least produce an
    // extractable A:<digit> answer through the whole stack.
    let ans = extract_answer(&res.chains[0].text);
    assert!(ans.is_some(), "no answer in {:?}", res.chains[0].text);
}
