// Shared lint config for non-lib targets (benches/tests/examples are
// separate crates, so the crate-wide allows in rust/src/lib.rs do not
// reach them): the same flat-layout indexing idiom applies here, and
// vec! payloads deliberately mirror the engine's heap buffers.
// Correctness lints stay on — CI denies all remaining warnings via
// `cargo clippy --all-targets -- -D warnings`.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::manual_div_ceil,
    clippy::uninlined_format_args,
    clippy::useless_vec
)]

//! Cross-language generator pinning: the Rust task generators must
//! reproduce `artifacts/tasks_golden.json` byte-for-byte (written by
//! the Python side during `make artifacts`).

use std::path::PathBuf;

use hyperscale::tasks::gen_problem;
use hyperscale::tokenizer::Tokenizer;
use hyperscale::util::Json;

fn artifacts() -> PathBuf {
    PathBuf::from(std::env::var("HS_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()))
}

fn golden() -> Option<Json> {
    let path = artifacts().join("tasks_golden.json");
    if !path.exists() {
        eprintln!("skipping: {} not built", path.display());
        return None;
    }
    Some(Json::parse_file(&path).expect("parse golden"))
}

#[test]
fn generators_match_python_byte_for_byte() {
    let Some(golden) = golden() else { return };
    let obj = golden.as_obj().expect("golden is an object");
    assert!(!obj.is_empty());
    let mut checked = 0;
    for (suite, rows) in obj {
        for (i, row) in rows.as_arr().unwrap().iter().enumerate() {
            let p = gen_problem(suite, 42, i as u64);
            assert_eq!(
                p.prompt,
                row.get("prompt").unwrap().as_str().unwrap(),
                "{suite}[{i}] prompt"
            );
            assert_eq!(
                p.solution,
                row.get("solution").unwrap().as_str().unwrap(),
                "{suite}[{i}] solution"
            );
            assert_eq!(
                p.answer,
                row.get("answer").unwrap().as_str().unwrap(),
                "{suite}[{i}] answer"
            );
            checked += 1;
        }
    }
    assert!(checked >= 9 * 3, "checked {checked} golden rows");
}

#[test]
fn vocab_matches_manifest() {
    let path = artifacts().join("manifest.json");
    if !path.exists() {
        eprintln!("skipping: manifest not built");
        return;
    }
    let m = Json::parse_file(&path).unwrap();
    let vocab: Vec<String> = m
        .get("vocab")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_str().unwrap().to_string())
        .collect();
    Tokenizer::new().check_manifest_vocab(&vocab).unwrap();
}

#[test]
fn golden_texts_are_tokenizable() {
    let Some(golden) = golden() else { return };
    let tok = Tokenizer::new();
    for (_, rows) in golden.as_obj().unwrap() {
        for row in rows.as_arr().unwrap() {
            let text = format!(
                "{}{}",
                row.get("prompt").unwrap().as_str().unwrap(),
                row.get("solution").unwrap().as_str().unwrap()
            );
            let ids = tok.encode(&text).expect("in-vocab");
            assert_eq!(tok.decode(&ids), text);
        }
    }
}
