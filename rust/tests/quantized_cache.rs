// Shared lint config for non-lib targets (benches/tests/examples are
// separate crates, so the crate-wide allows in rust/src/lib.rs do not
// reach them): the same flat-layout indexing idiom applies here, and
// vec! payloads deliberately mirror the engine's heap buffers.
// Correctness lints stay on — CI denies all remaining warnings via
// `cargo clippy --all-targets -- -D warnings`.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::manual_div_ceil,
    clippy::uninlined_format_args,
    clippy::useless_vec
)]

//! Quantized KV payload properties (docs/NUMERICS.md):
//!
//! * round-trip error bounds per dtype through the full store path
//!   (write → export/quantize → restore/dequantize);
//! * bit-exact COW semantics on quantized shared pages — a sibling's
//!   eviction must never perturb another consumer's dequantized view,
//!   and every consumer of one pool entry sees identical bytes;
//! * prefix-cache restore equivalence between f32 and quantized
//!   stores (metadata exact, payload within the documented bound,
//!   requantize-once on re-export);
//! * decode-stream divergence on a simulated smooth-readout executor:
//!   quantized-vs-f32 top-1 token agreement ≥ 99% (q8 and q4), backed
//!   by a measured logit-perturbation-vs-margin guarantee.

use hyperscale::kvcache::{CacheStore, Geometry, KvDtype, SlotState};
use hyperscale::util::SplitMix64;

fn geom() -> Geometry {
    Geometry {
        layers: 2,
        kv_heads: 2,
        slots: 128,
        head_dim: 8,
        page_size: 8,
    }
}

/// Per-slot payload: varies along the head dim (0.37 step — the row
/// spread the quantization scale derives from) and with position.
fn payload(pos: usize, hd: usize, v_shift: f32) -> Vec<f32> {
    (0..hd)
        .map(|d| 0.1 + 0.37 * d as f32 + 0.05 * pos as f32 + v_shift)
        .collect()
}

/// Identity-layout prefill of `n` tokens on `lane`.
fn prefill(c: &mut CacheStore, lane: usize, n: usize) {
    let g = c.geom;
    for pos in 0..n {
        let k = payload(pos, g.head_dim, 0.0);
        let v = payload(pos, g.head_dim, 0.25);
        for l in 0..g.layers {
            for h in 0..g.kv_heads {
                let s = c.alloc_slot(lane, l, h).unwrap();
                c.write(lane, l, h, s, pos, &k, &v);
            }
        }
    }
}

/// Documented per-element bound for `payload`-shaped rows: half the
/// quantization step over the zero-anchored row range. These rows are
/// all-positive, so the anchored range is the row maximum:
/// `0.1 + shift + 0.37·(hd−1) + 0.05·pos`, with pos ≤ 15 and
/// shift ≤ 0.25 in every bounded check below.
fn error_bound(dtype: KvDtype, hd: usize) -> f32 {
    let hi = 0.1 + 0.25 + 0.37 * (hd - 1) as f32 + 0.05 * 15.0;
    let qmax = match dtype {
        KvDtype::F32 => return 0.0,
        KvDtype::Q8 => 255.0,
        KvDtype::Q4 => 15.0,
    };
    hi / (2.0 * qmax) + 1e-5
}

/// Export the first `pages` pages of lane 0 and restore them into
/// `dst`, returning the pool handles (one caller reference each left
/// with the mapping — i.e. fully consumed).
fn export_restore(c: &mut CacheStore, pages: usize, dst: usize) -> Vec<u64> {
    let ids: Vec<u64> = (0..pages).map(|p| c.export_page(0, p)).collect();
    c.recycle_lane(0);
    c.map_prefix_pages(dst, &ids);
    c.materialize_pending();
    ids
}

#[test]
fn roundtrip_error_bounds_per_dtype() {
    let g = geom();
    for dtype in [KvDtype::F32, KvDtype::Q8, KvDtype::Q4] {
        let mut c = CacheStore::with_dtype(g, 2, dtype);
        prefill(&mut c, 0, 16);
        export_restore(&mut c, 2, 1);
        let bound = error_bound(dtype, g.head_dim);
        for pos in 0..16 {
            let k_ref = payload(pos, g.head_dim, 0.0);
            let v_ref = payload(pos, g.head_dim, 0.25);
            for l in 0..g.layers {
                for h in 0..g.kv_heads {
                    assert_eq!(c.slot_pos(1, l, h, pos), Some(pos), "{dtype}");
                    let k = c.k_at(1, l, h, pos);
                    let v = c.v_at(1, l, h, pos);
                    for d in 0..g.head_dim {
                        assert!(
                            (k[d] - k_ref[d]).abs() <= bound,
                            "{dtype}: k error {} > bound {bound}",
                            (k[d] - k_ref[d]).abs()
                        );
                        assert!(
                            (v[d] - v_ref[d]).abs() <= bound,
                            "{dtype}: v error {} > bound {bound}",
                            (v[d] - v_ref[d]).abs()
                        );
                    }
                    if dtype == KvDtype::F32 {
                        assert_eq!(k, &k_ref[..], "f32 restores must be exact");
                    }
                }
            }
        }
        c.recycle_lane(1);
        assert_eq!(c.pool_pages(), 0);
    }
}

/// Snapshot every observable byte of one lane.
fn lane_view(c: &CacheStore, lane: usize) -> Vec<(SlotState, f32, Vec<f32>, Vec<f32>)> {
    let g = c.geom;
    let mut out = Vec::new();
    for l in 0..g.layers {
        for h in 0..g.kv_heads {
            for s in 0..g.slots {
                out.push((
                    c.slot_state(lane, l, h, s),
                    c.mask_value(lane, l, h, s),
                    c.k_at(lane, l, h, s).to_vec(),
                    c.v_at(lane, l, h, s).to_vec(),
                ));
            }
        }
    }
    out
}

#[test]
fn sibling_eviction_cannot_perturb_quantized_shared_views() {
    // Two consumers of one quantized pool entry: a mutation by one
    // must leave the other's dequantized view bit-identical.
    let g = geom();
    let mut c = CacheStore::with_dtype(g, 3, KvDtype::Q8);
    prefill(&mut c, 0, 8); // one full page
    let ids: Vec<u64> = vec![c.export_page(0, 0)];
    c.recycle_lane(0);
    c.retain_page(ids[0]); // second consumer's reference
    c.map_prefix_pages(1, &ids);
    c.map_prefix_pages(2, &ids);
    c.materialize_pending();

    let before = lane_view(&c, 1);
    assert_eq!(before, lane_view(&c, 2), "one entry, identical views");

    // lane 2 (the "sibling") evicts and overwrites inside the shared
    // page; lane 1's bytes must not move at all
    c.evict(2, 0, 0, 3);
    let s = c.alloc_slot(2, 0, 0).unwrap();
    c.write(2, 0, 0, s, 99, &payload(99, g.head_dim, 0.0), &payload(99, g.head_dim, 0.25));
    assert_eq!(lane_view(&c, 1), before, "sibling mutation leaked into lane 1");
    assert!(c.slot_pos(1, 0, 0, 3).is_some(), "lane 1 keeps the evicted slot");

    // a third consumer mapping the same entry later still sees the
    // original dequantized bytes (dequantization is deterministic and
    // the entry was never re-encoded)
    c.recycle_lane(2);
    c.retain_page(ids[0]);
    c.map_prefix_pages(2, &ids);
    c.materialize_pending();
    assert_eq!(lane_view(&c, 2), before, "re-restore must be bit-identical");

    c.recycle_lane(1);
    c.recycle_lane(2);
    assert_eq!(c.pool_pages(), 0, "no leaked entries");
    assert_eq!(c.pool_refs(), 0);
}

#[test]
fn leader_eviction_publishes_one_snapshot_for_all_cow_siblings() {
    // Borrowed (fork) payloads quantize exactly once, at the COW
    // publish the leader's mutation forces; every sibling then decodes
    // the same snapshot.
    let g = geom();
    let mut c = CacheStore::with_dtype(g, 3, KvDtype::Q8);
    prefill(&mut c, 0, 8);
    c.fork_lane_cow(0, 1);
    c.fork_lane_cow(0, 2);

    // the leader's policy evicts inside the shared page before the
    // siblings ever materialized → publish boundary (quantization)
    c.evict(0, 0, 0, 3);
    assert_eq!(c.cow_published(), 1);
    c.materialize_pending();

    // siblings: identical dequantized views, pristine metadata, and
    // payload within the q8 bound of the original
    assert_eq!(lane_view(&c, 1), lane_view(&c, 2));
    assert!(c.slot_pos(1, 0, 0, 3).is_some());
    assert!(c.slot_pos(0, 0, 0, 3).is_none(), "leader took its eviction");
    let bound = error_bound(KvDtype::Q8, g.head_dim);
    for pos in 0..8 {
        let k_ref = payload(pos, g.head_dim, 0.0);
        let k = c.k_at(1, 0, 0, pos);
        for d in 0..g.head_dim {
            assert!((k[d] - k_ref[d]).abs() <= bound);
        }
    }
    // the leader's own region never went through the codec
    for pos in 0..8 {
        if pos == 3 {
            continue;
        }
        assert_eq!(c.k_at(0, 0, 0, pos), &payload(pos, g.head_dim, 0.0)[..]);
    }
    for lane in 0..3 {
        c.recycle_lane(lane);
    }
    assert_eq!(c.pool_pages(), 0);
}

#[test]
fn prefix_restore_equivalence_and_requantize_once() {
    let g = geom();
    let mut f = CacheStore::new(g, 2); // f32 reference
    let mut q = CacheStore::with_dtype(g, 2, KvDtype::Q8);
    prefill(&mut f, 0, 16);
    prefill(&mut q, 0, 16);
    let ids_f = export_restore(&mut f, 2, 1);
    let ids_q = export_restore(&mut q, 2, 1);

    // metadata and mask restore identically regardless of payload dtype
    for l in 0..g.layers {
        for h in 0..g.kv_heads {
            assert_eq!(f.live_count(1, l, h), q.live_count(1, l, h));
            for s in 0..g.slots {
                assert_eq!(f.slot_state(1, l, h, s), q.slot_state(1, l, h, s));
                assert_eq!(f.mask_value(1, l, h, s), q.mask_value(1, l, h, s));
            }
        }
    }
    // quantization engaged: the q8 view differs from f32 somewhere...
    let total_diff: f32 = (0..16)
        .map(|s| {
            (f.k_at(1, 0, 0, s)[1] - q.k_at(1, 0, 0, s)[1]).abs()
                + (f.v_at(1, 0, 0, s)[1] - q.v_at(1, 0, 0, s)[1]).abs()
        })
        .sum();
    assert!(total_diff > 0.0, "q8 restore should be inexact on this payload");
    // ...but stays inside the documented bound (checked fully in
    // roundtrip_error_bounds_per_dtype)

    // requantize-once: re-exporting the restored (still clean) pages
    // must hand back the SAME pool entries, not re-encoded copies
    for (i, &id) in ids_q.iter().enumerate() {
        let again = q.export_page(1, i);
        assert_eq!(again, id, "re-export must reuse the pool entry");
        q.release_page(again);
    }
    let _ = (ids_f, ids_q);
    f.recycle_lane(1);
    q.recycle_lane(1);
    assert_eq!(q.pool_pages(), 0);
}

// ----------------------------------------------------------------------
// Cold tier: the second lossy boundary (docs/NUMERICS.md)
// ----------------------------------------------------------------------

/// Demote one retained page through the store into a cold tier of
/// `cold_dtype`, promote it back, and restore it into `dst`. Returns
/// the lane view for comparison.
fn demote_promote_restore(
    c: &mut CacheStore,
    cold: &mut hyperscale::kvcache::ColdTier,
    id: u64,
    key: &[u32],
    dst: usize,
) -> Vec<(SlotState, f32, Vec<f32>, Vec<f32>)> {
    let (page, data) = c.demote_page(id).expect("sole owner demotes");
    cold.admit(key, page, data);
    let (page, data) = cold.promote(key).expect("cold hit");
    let new_id = c.adopt_cold_page(page, data);
    c.map_prefix_pages(dst, &[new_id]);
    c.materialize_pending();
    lane_view(c, dst)
}

/// Cold restores meet the documented per-dtype bound on an f32 hot
/// store: an f32 cold tier is bit-exact, q8/q4 stay within the same
/// half-step bound the hot quantized stores are held to.
#[test]
fn cold_tier_roundtrip_error_bounds_per_dtype() {
    use hyperscale::kvcache::ColdTier;
    let g = geom();
    for cold_dtype in [KvDtype::F32, KvDtype::Q8, KvDtype::Q4] {
        let mut c = CacheStore::new(g, 2); // exact hot payloads
        prefill(&mut c, 0, g.page_size);
        let reference = lane_view(&c, 0);
        let id = c.export_page(0, 0);
        c.recycle_lane(0);
        let mut cold = ColdTier::new(1 << 20, cold_dtype, None, g.head_dim);
        let restored = demote_promote_restore(&mut c, &mut cold, id, &[7, 7, 7], 1);
        assert_eq!(cold.hits(), 1);
        let bound = error_bound(cold_dtype, g.head_dim);
        for (r, o) in reference.iter().zip(&restored) {
            // metadata and masks cross the boundary exactly
            assert_eq!(r.0, o.0, "{cold_dtype}: slot state must be exact");
            assert_eq!(r.1, o.1, "{cold_dtype}: mask must be exact");
            for (x, y) in r.2.iter().zip(&o.2).chain(r.3.iter().zip(&o.3)) {
                assert!(
                    (x - y).abs() <= bound,
                    "{cold_dtype}: cold restore error {} > bound {bound}",
                    (x - y).abs()
                );
            }
        }
        if cold_dtype == KvDtype::F32 {
            assert_eq!(reference, restored, "f32 cold tier must be bit-exact");
        }
        c.recycle_lane(1);
        assert_eq!(c.pool_pages(), 0, "{cold_dtype}: no leaked pool entries");
        assert_eq!(c.pool_refs(), 0);
    }
}

/// Demote → promote → demote → promote through the store never
/// re-encodes: the second restore is bit-identical to the first, so
/// cycles cannot compound the (single, documented) demotion error.
#[test]
fn cold_demote_promote_cycles_do_not_compound_error() {
    use hyperscale::kvcache::ColdTier;
    let g = geom();
    let mut c = CacheStore::new(g, 2);
    prefill(&mut c, 0, g.page_size);
    let id = c.export_page(0, 0);
    c.recycle_lane(0);
    let mut cold = ColdTier::new(1 << 20, KvDtype::Q4, None, g.head_dim);

    let first = demote_promote_restore(&mut c, &mut cold, id, &[3], 1);

    // requantize-once carries over: re-exporting the promoted (clean)
    // page reuses the pool entry, so the second demotion hands the
    // cold tier the very same q4 block — admitted verbatim.
    let again = c.export_page(1, 0);
    c.recycle_lane(1);
    let second = demote_promote_restore(&mut c, &mut cold, again, &[3], 1);
    assert_eq!(
        first, second,
        "a demote/promote cycle must be bit-stable after the first demotion"
    );
    assert_eq!(cold.hits(), 2);

    c.recycle_lane(1);
    assert_eq!(c.pool_pages(), 0);
    assert_eq!(c.pool_refs(), 0);
}

// ----------------------------------------------------------------------
// Edge rows: non-finite, subnormal, and single-element payloads
// ----------------------------------------------------------------------

/// Non-finite and subnormal rows quantize without panics and decode to
/// the documented values: NaN → exactly 0.0, ±inf → saturated to the
/// row's representable extremes, rows with no finite values → all
/// zeros, and finite elements stay inside the half-step bound. No
/// NaN/inf ever leaks into a dequantized view.
#[test]
fn edge_rows_round_trip_without_panics_within_bounds() {
    use hyperscale::kvcache::QuantBlock;
    let rl = 6;
    let rows: Vec<[f32; 6]> = vec![
        [1.0, f32::NAN, -2.0, 0.5, 0.0, 1.5],           // NaN amid spread
        [0.25, f32::INFINITY, 1.0, 0.75, 0.5, 0.125],   // +inf amid spread
        [f32::NEG_INFINITY, -0.5, -1.0, -0.25, 0.0, -2.0], // −inf amid spread
        [f32::NAN; 6],                                  // no finite values
        [f32::INFINITY; 6],                             // no finite values
        [2.5, f32::INFINITY, 2.5, f32::NAN, 2.5, 2.5],  // constant + junk
        [-1.75, f32::INFINITY, -1.75, -1.75, f32::NEG_INFINITY, -1.75],
        [0.0, 1.0e-41, -1.0e-41, 7.0e-40, 0.0, -3.0e-40], // subnormal spread
    ];
    let src: Vec<f32> = rows.iter().flatten().copied().collect();
    for dtype in [KvDtype::Q8, KvDtype::Q4] {
        let b = QuantBlock::quantize(dtype, rows.len(), rl, &src);
        let mut out = vec![0f32; rows.len() * rl];
        b.dequantize_rows_into(0, rows.len(), &mut out);
        assert!(
            out.iter().all(|y| y.is_finite()),
            "{dtype}: non-finite value leaked into a dequantized view"
        );
        for (r, row) in rows.iter().enumerate() {
            let dec = &out[r * rl..(r + 1) * rl];
            let finite: Vec<f32> = row.iter().copied().filter(|x| x.is_finite()).collect();
            if finite.is_empty() {
                assert!(
                    dec.iter().all(|&y| y == 0.0),
                    "{dtype}: row {r} has no finite values and must decode to zeros"
                );
                continue;
            }
            let step = b.row_scale(r).abs();
            let lo = finite.iter().copied().fold(f32::INFINITY, f32::min).min(0.0);
            let hi = finite.iter().copied().fold(f32::NEG_INFINITY, f32::max).max(0.0);
            for (d, (&x, &y)) in row.iter().zip(dec).enumerate() {
                if x.is_nan() {
                    assert_eq!(y, 0.0, "{dtype}: row {r} elem {d}: NaN must decode to 0.0");
                } else if x == f32::INFINITY {
                    assert!(
                        y >= hi - step * 0.5001 - 1e-6,
                        "{dtype}: row {r} elem {d}: +inf must saturate high (got {y})"
                    );
                } else if x == f32::NEG_INFINITY {
                    assert!(
                        y <= lo + step * 0.5001 + 1e-6,
                        "{dtype}: row {r} elem {d}: −inf must saturate low (got {y})"
                    );
                } else {
                    assert!(
                        (x - y).abs() <= step * 0.5001 + 1e-6,
                        "{dtype}: row {r} elem {d}: |{x} − {y}| exceeds half-step {step}"
                    );
                }
            }
        }
    }
}

/// Single-element rows are constant rows by construction and must
/// round-trip exactly — including zero, negative, and subnormal
/// values (the degenerate `q ≡ 1` encoding stores the value itself).
#[test]
fn single_element_rows_round_trip_exactly() {
    use hyperscale::kvcache::QuantBlock;
    let vals = [0.0f32, 3.25, -1.5, 1.0e-41, -7.0e-40, f32::MIN_POSITIVE];
    for dtype in [KvDtype::Q8, KvDtype::Q4] {
        let b = QuantBlock::quantize(dtype, vals.len(), 1, &vals);
        let mut out = vec![0f32; vals.len()];
        b.dequantize_rows_into(0, vals.len(), &mut out);
        assert_eq!(
            &out[..],
            &vals[..],
            "{dtype}: single-element rows must be exact"
        );
    }
}

/// A subnormal row spread hits the `f32::MIN_POSITIVE` step floor:
/// the scale is a normal float, the decode is finite, and the error
/// stays within the floored half-step.
#[test]
fn subnormal_spreads_use_floored_normal_scale() {
    use hyperscale::kvcache::QuantBlock;
    let src = [0.0f32, 1.0e-41, 2.0e-41, -1.0e-41];
    for dtype in [KvDtype::Q8, KvDtype::Q4] {
        let b = QuantBlock::quantize(dtype, 1, 4, &src);
        let s = b.row_scale(0);
        assert!(
            s >= f32::MIN_POSITIVE && s.is_normal(),
            "{dtype}: subnormal spread must floor the step to a normal scale"
        );
        let mut out = [0f32; 4];
        b.dequantize_rows_into(0, 1, &mut out);
        for (x, y) in src.iter().zip(&out) {
            assert!(y.is_finite());
            assert!(
                (x - y).abs() <= s * 0.5001 + f32::MIN_POSITIVE,
                "{dtype}: |{x} − {y}| exceeds floored half-step {s}"
            );
        }
    }
}

// ----------------------------------------------------------------------
// Simulated-executor decode-stream divergence
// ----------------------------------------------------------------------

const SIM_VOCAB: usize = 16;

fn weight(t: usize, l: usize, h: usize, s: usize, d: usize) -> f32 {
    let seed = 0x9E37u64
        ^ ((t as u64) << 40)
        ^ ((l as u64) << 32)
        ^ ((h as u64) << 24)
        ^ ((s as u64) << 8)
        ^ d as u64;
    (SplitMix64::new(seed).f64() * 2.0 - 1.0) as f32
}

/// Smooth readout executor: logits are an integer rank permutation
/// (pos-derived) plus a bounded, 1-Lipschitz projection of the lane's
/// live K payload. Rank gaps are ≥ 1 − 2·0.25 = 0.5, while a payload
/// perturbation of ε moves each logit by ≤ 0.25·ε — so the top-1
/// token flips only if dequantization error exceeds the margin, which
/// the test measures and asserts against.
fn sim_logits(c: &CacheStore, lane: usize, pos: usize) -> Vec<f32> {
    let g = c.geom;
    let mut perm: Vec<usize> = (0..SIM_VOCAB).collect();
    SplitMix64::new(0x5EED ^ pos as u64).shuffle(&mut perm);
    (0..SIM_VOCAB)
        .map(|t| {
            let mut acc = 0.0f64;
            let mut n = 0u64;
            for l in 0..g.layers {
                for h in 0..g.kv_heads {
                    for s in 0..g.slots {
                        if c.slot_pos(lane, l, h, s).is_none() {
                            continue;
                        }
                        for (d, &kd) in c.k_at(lane, l, h, s).iter().enumerate() {
                            acc += (weight(t, l, h, s, d) * kd) as f64;
                            n += 1;
                        }
                    }
                }
            }
            let mean = if n == 0 { 0.0 } else { acc / n as f64 };
            let squash = mean / (1.0 + mean.abs()); // (-1, 1), 1-Lipschitz
            perm[t] as f32 + 0.25 * squash as f32
        })
        .collect()
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0
}

/// Gap between the two largest values.
fn top2_gap(xs: &[f32]) -> f32 {
    let mut best = f32::NEG_INFINITY;
    let mut second = f32::NEG_INFINITY;
    for &x in xs {
        if x > best {
            second = best;
            best = x;
        } else if x > second {
            second = x;
        }
    }
    best - second
}

#[test]
fn quantized_decode_stream_divergence_is_bounded() {
    let g = geom();
    let (prompt, steps) = (16usize, 100usize);
    for dtype in [KvDtype::Q8, KvDtype::Q4] {
        let mut f = CacheStore::new(g, 2);
        let mut q = CacheStore::with_dtype(g, 2, dtype);
        prefill(&mut f, 0, prompt);
        prefill(&mut q, 0, prompt);
        export_restore(&mut f, prompt / g.page_size, 1);
        export_restore(&mut q, prompt / g.page_size, 1);

        let mut agree = 0usize;
        let mut max_delta = 0f32;
        let mut min_gap = f32::INFINITY;
        for step in 0..steps {
            let pos = prompt + step;
            let lf = sim_logits(&f, 1, pos);
            let lq = sim_logits(&q, 1, pos);
            if argmax(&lf) == argmax(&lq) {
                agree += 1;
            }
            for (a, b) in lf.iter().zip(&lq) {
                max_delta = max_delta.max((a - b).abs());
            }
            min_gap = min_gap.min(top2_gap(&lf));
            // decode writes are position-derived and identical in both
            // stores: divergence measured here is payload precision,
            // not a cascading trajectory difference
            let k = payload(pos, g.head_dim, 0.0);
            let v = payload(pos, g.head_dim, 0.25);
            for l in 0..g.layers {
                for h in 0..g.kv_heads {
                    let sf = f.alloc_slot(1, l, h).unwrap();
                    f.write(1, l, h, sf, pos, &k, &v);
                    let sq = q.alloc_slot(1, l, h).unwrap();
                    q.write(1, l, h, sq, pos, &k, &v);
                }
            }
        }
        let agreement = agree as f64 / steps as f64;
        // the margin guarantee that makes ≥99% structural, not lucky:
        // measured logit perturbation stays below half the smallest
        // top-2 margin of the reference stream
        assert!(
            2.0 * max_delta < min_gap,
            "{dtype}: perturbation {max_delta} vs min margin {min_gap}"
        );
        assert!(
            agreement >= 0.99,
            "{dtype}: top-1 agreement {agreement} < 0.99 \
             (max |Δlogit| {max_delta}, min top-2 gap {min_gap})"
        );
        assert!(max_delta > 0.0, "{dtype}: no divergence measured at all");
    }
}
