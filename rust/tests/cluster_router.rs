// Shared lint config for non-lib targets (benches/tests/examples are
// separate crates, so the crate-wide allows in rust/src/lib.rs do not
// reach them): the same flat-layout indexing idiom applies here, and
// vec! payloads deliberately mirror the engine's heap buffers.
// Correctness lints stay on — CI denies all remaining warnings via
// `cargo clippy --all-targets -- -D warnings`.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::manual_div_ceil,
    clippy::uninlined_format_args,
    clippy::useless_vec
)]

//! Cluster/router invariants, driven end-to-end over [`SimEngine`]
//! replicas (real scheduler + KV cache + prefix indexes; deterministic
//! fake model — no artifacts needed, so these run everywhere CI does).
//!
//! The three load-bearing properties:
//!
//! 1. **Affinity pays**: on a repeated-system-prompt workload, prefix
//!    routing produces strictly more `prefix_hit_tokens` than
//!    round-robin — the whole point of replica-aware admission.
//! 2. **Stealing drains**: when affinity saturates one replica while
//!    another sits idle, queued (never-installed) requests migrate and
//!    complete on the idle replica.
//! 3. **Cluster-of-1 is transparent**: routing through the cluster
//!    changes *where* a request runs, never *what* it generates —
//!    token streams are bit-identical to driving the engine directly.
//!
//! Stores honor `KV_DTYPE` (the q8 CI leg), so the cluster paths —
//! prefix retention, COW forks, steal-time reference release — are
//! exercised over quantized pool payloads too.

use hyperscale::compress::{build_policy, PolicyKind};
use hyperscale::config::{ClusterConfig, RoutingPolicy};
use hyperscale::engine::{
    AdmissionPolicy, ChainState, GenRequest, Phase, Scheduler, SchedulerConfig, SimEngine,
    SimEngineConfig,
};
use hyperscale::kvcache::KvDtype;
use hyperscale::server::{Cluster, ServeRequest};
use hyperscale::util::{Json, SplitMix64};
use std::collections::BTreeSet;
use std::sync::Arc;

/// Base seed for the randomized property tests below; `PROP_SEED`
/// (decimal or 0x-hex) lets the CI seed-matrix leg re-run them under
/// several fixed seeds.
fn prop_seed() -> u64 {
    match std::env::var("PROP_SEED") {
        Ok(s) => {
            let s = s.trim();
            let parsed = match s.strip_prefix("0x") {
                Some(hex) => u64::from_str_radix(hex, 16),
                None => s.parse(),
            };
            parsed.unwrap_or_else(|_| panic!("PROP_SEED must be an integer, got {s:?}"))
        }
        Err(_) => 0xC1_0575,
    }
}

/// Replica factory: sim engines with `lanes` lanes each, pool payloads
/// under the env-selected dtype (f32 normally, q8 on the CI leg).
fn sim_factory(
    lanes: usize,
    work_per_token: usize,
) -> impl Fn(usize) -> hyperscale::Result<SimEngine> + Clone + Send + 'static {
    move |_i| {
        Ok(SimEngine::new(SimEngineConfig {
            lanes,
            kv_dtype: KvDtype::from_env(),
            work_per_token,
            ..Default::default()
        }))
    }
}

fn sreq(id: u64, prompt: &str, seed: u64) -> ServeRequest {
    ServeRequest {
        id,
        prompt: prompt.into(),
        width: 1,
        max_len: 160,
        temperature: 0.7,
        seed,
        slo: None,
    }
}

/// A repeated-system-prompt workload item: a long shared preamble
/// (spanning several 16-token KV pages) + a short per-request tail.
fn system_prompt(sys: usize, q: usize) -> String {
    format!(
        "system {sys}: you are a careful solver, reason step by step, \
         be brief, answer with one number.|Q{q}"
    )
}

fn field_f64(j: &Json, key: &str) -> f64 {
    j.get(key).and_then(Json::as_f64).unwrap_or_else(|| {
        panic!("response missing numeric field '{key}': {:?}", j.to_string())
    })
}

fn field_usize(j: &Json, key: &str) -> usize {
    j.get(key).and_then(Json::as_usize).unwrap_or_else(|| {
        panic!("response missing integer field '{key}': {:?}", j.to_string())
    })
}

/// Run the skewed repeated-prefix workload sequentially (deterministic:
/// each request completes before the next is routed) and report
/// (total prefix_hit_tokens, replica id per request, per-sys replicas).
fn run_repeated_prefix(routing: RoutingPolicy) -> (f64, Vec<usize>) {
    let ccfg = ClusterConfig {
        replicas: 4,
        routing,
        steal: false, // isolate routing; stealing is tested separately
    };
    let cluster = Cluster::start(ccfg, sim_factory(2, 0));
    let mut hit_tokens = 0.0;
    let mut replicas = Vec::new();
    // skew: 12 of 16 requests share system prompt 0; the rest are
    // distinct one-off prompts (the traffic prefix routing must not
    // let pollute the hot replica's affinity)
    let mut id = 0u64;
    for round in 0..4 {
        for _ in 0..3 {
            let j = cluster
                .call_blocking(sreq(id, &system_prompt(0, id as usize), id))
                .expect("response");
            assert!(j.get("error").is_none(), "error: {}", j.to_string());
            hit_tokens += field_f64(&j, "prefix_hit_tokens");
            replicas.push(field_usize(&j, "replica_id"));
            id += 1;
        }
        let one_off =
            format!("one-off request number {round} with its own long and unshared text body");
        let j = cluster
            .call_blocking(sreq(id, &one_off, id))
            .expect("response");
        assert!(j.get("error").is_none());
        hit_tokens += field_f64(&j, "prefix_hit_tokens");
        replicas.push(field_usize(&j, "replica_id"));
        id += 1;
    }
    cluster.shutdown();
    (hit_tokens, replicas)
}

#[test]
fn prefix_affinity_beats_round_robin_on_repeated_prompts() {
    let (hits_prefix, replicas_prefix) = run_repeated_prefix(RoutingPolicy::Prefix);
    let (hits_rr, replicas_rr) = run_repeated_prefix(RoutingPolicy::RoundRobin);

    // the affinity invariant: every hot-prompt repeat lands on the
    // replica that already holds the prefix (indices 0..2, 4..6, ... in
    // submission order are the hot requests)
    let hot_replicas: Vec<usize> = replicas_prefix
        .iter()
        .enumerate()
        .filter(|(i, _)| i % 4 != 3)
        .map(|(_, &r)| r)
        .collect();
    assert!(
        hot_replicas.windows(2).all(|w| w[0] == w[1]),
        "prefix routing scattered the hot prompt: {hot_replicas:?}"
    );
    // round-robin, by construction, cycles regardless of content
    assert_eq!(replicas_rr[..4], [0, 1, 2, 3]);

    // the payoff invariant: affinity converts repeats into prefix-cache
    // hits that content-blind cycling cannot
    assert!(
        hits_prefix > hits_rr,
        "prefix routing must out-hit round-robin \
         (prefix {hits_prefix} vs round-robin {hits_rr})"
    );
    // and the hot prompt hits from its second occurrence on
    assert!(
        hits_prefix >= 11.0 * 16.0,
        "11 repeats x >=1 page expected, got {hits_prefix}"
    );
}

#[test]
fn work_stealing_drains_a_saturated_replica() {
    let ccfg = ClusterConfig {
        replicas: 2,
        routing: RoutingPolicy::Prefix,
        steal: true,
    };
    // single-lane replicas with inflated per-token cost: affinity
    // piles a burst onto replica 0 and its queue is worth stealing
    let cluster = Cluster::start(ccfg, sim_factory(1, 400));

    // seed affinity for the hot prompt on replica 0
    let j = cluster
        .call_blocking(sreq(0, &system_prompt(0, 0), 0))
        .expect("seed response");
    let seeded = field_usize(&j, "replica_id");

    // burst: 12 same-prefix requests submitted without waiting — all
    // are routed to the seeded replica by affinity, saturating its one
    // lane while the other replica idles
    let pending: Vec<_> = (1..=12u64)
        .map(|i| cluster.call(sreq(i, &system_prompt(0, i as usize), i)))
        .collect();
    let mut served_by: Vec<usize> = Vec::new();
    for rx in pending {
        let j = Json::parse(&rx.recv().expect("burst response")).unwrap();
        assert!(j.get("error").is_none(), "error: {}", j.to_string());
        served_by.push(field_usize(&j, "replica_id"));
    }
    let stats = cluster.stats().expect("stats");
    let m = stats
        .get("cluster_metrics")
        .and_then(Json::as_str)
        .expect("cluster metrics")
        .to_string();
    cluster.shutdown();

    // stealing happened and the idle replica actually served work
    assert!(
        served_by.iter().any(|&r| r != seeded),
        "no request migrated off the saturated replica: {served_by:?}"
    );
    assert!(
        m.contains("cluster.steal_ops"),
        "steal counters missing from metrics:\n{m}"
    );
    // every burst request was answered exactly once (completeness)
    assert_eq!(served_by.len(), 12);
}

#[test]
fn cluster_of_one_streams_bit_exact_vs_single_engine_path() {
    let spec: Vec<(String, u64)> = (0..8u64)
        .map(|i| (system_prompt((i % 2) as usize, (i % 3) as usize), 40 + i))
        .collect();

    // reference: drive one sim engine directly, all requests upfront
    let mut direct = SimEngine::new(SimEngineConfig {
        kv_dtype: KvDtype::from_env(),
        ..Default::default()
    });
    let tickets: Vec<u64> = spec
        .iter()
        .map(|(prompt, seed)| {
            direct
                .submit(&GenRequest {
                    prompt: prompt.clone(),
                    width: 1,
                    max_len: 160,
                    temperature: 0.7,
                    seed: *seed,
                })
                .expect("submit")
        })
        .collect();
    let done = direct.drain().expect("drain");
    let mut reference: Vec<String> = Vec::new();
    for t in &tickets {
        let d = done.iter().find(|d| d.ticket == *t).unwrap();
        reference.push(d.result.chains[0].text.clone());
    }

    // cluster of one: same requests, submitted concurrently (arrival
    // interleaving differs from the direct run — streams must not)
    let ccfg = ClusterConfig {
        replicas: 1,
        routing: RoutingPolicy::Prefix,
        steal: true,
    };
    let cluster = Cluster::start(ccfg, sim_factory(4, 0));
    let pending: Vec<_> = spec
        .iter()
        .enumerate()
        .map(|(i, (prompt, seed))| cluster.call(sreq(i as u64, prompt, *seed)))
        .collect();
    for (i, rx) in pending.into_iter().enumerate() {
        let j = Json::parse(&rx.recv().expect("response")).unwrap();
        assert_eq!(field_usize(&j, "replica_id"), 0);
        let texts = match j.get("texts") {
            Some(Json::Arr(a)) => a
                .iter()
                .map(|t| t.as_str().unwrap().to_string())
                .collect::<Vec<_>>(),
            other => panic!("bad texts field: {other:?}"),
        };
        assert_eq!(texts.len(), 1);
        assert_eq!(
            texts[0], reference[i],
            "request {i}: cluster-of-1 altered the token stream"
        );
    }
    cluster.shutdown();
}

#[test]
fn round_robin_cycles_replicas_in_arrival_order() {
    let ccfg = ClusterConfig {
        replicas: 3,
        routing: RoutingPolicy::RoundRobin,
        steal: false,
    };
    let cluster = Cluster::start(ccfg, sim_factory(2, 0));
    let mut replicas = Vec::new();
    for i in 0..6u64 {
        let j = cluster
            .call_blocking(sreq(i, &format!("distinct prompt number {i} padded out"), i))
            .expect("response");
        replicas.push(field_usize(&j, "replica_id"));
    }
    cluster.shutdown();
    assert_eq!(replicas, vec![0, 1, 2, 0, 1, 2]);
}

// ----------------------------------------------------------------------
// The steal-only-queued rule, at the scheduler layer
// ----------------------------------------------------------------------

fn sched_req(width: usize, max_len: usize, seed: u64) -> GenRequest {
    GenRequest {
        prompt: String::new(),
        width,
        max_len,
        temperature: 0.5,
        seed,
    }
}

/// A [`GenRequest`] for driving a [`SimEngine`] directly.
fn req_for(prompt: &str, width: usize, seed: u64) -> GenRequest {
    GenRequest {
        prompt: prompt.into(),
        width,
        max_len: 160,
        temperature: 0.7,
        seed,
    }
}

fn policy(max_len: usize) -> Box<dyn hyperscale::compress::Policy> {
    build_policy(PolicyKind::Vanilla, 1.0, max_len, 4, 8)
}

#[test]
fn drain_queued_takes_only_fresh_whole_requests_youngest_first() {
    let mut s = Scheduler::new(1, SchedulerConfig::default());
    let ids = Arc::new(vec![1u32; 4]);
    let t0 = s.submit(&sched_req(1, 24, 1), ids.clone());
    let t1 = s.submit(&sched_req(1, 24, 2), ids.clone());
    let t2 = s.submit(&sched_req(1, 24, 3), ids.clone());
    // install t0's chain on the only lane: it is no longer stealable
    let p = s.next_admission().unwrap();
    assert_eq!(p.ticket, t0);
    s.install(0, ChainState::new(p, policy(24), 0));
    assert_eq!(s.stealable_requests(), 2);
    let drained = s.drain_queued(10);
    let tickets: Vec<u64> = drained.iter().map(|(t, _)| *t).collect();
    assert_eq!(tickets, vec![t2, t1], "youngest queued requests go first");
    assert_eq!(s.queue_depth(), 0);
    assert_eq!(s.active_lanes(), 1, "the installed chain stays put");
}

#[test]
fn drain_queued_never_takes_partially_installed_width_requests() {
    let mut s = Scheduler::new(1, SchedulerConfig::default());
    let ids = Arc::new(vec![1u32; 4]);
    let t = s.submit(&sched_req(3, 24, 7), ids);
    // leader admitted; two wait_fork siblings remain queued
    let p = s.next_admission().unwrap();
    s.install(0, ChainState::new(p, policy(24), 0));
    assert_eq!(s.queue_depth(), 2);
    assert_eq!(
        s.stealable_requests(),
        0,
        "a request with an installed leader owns lane state"
    );
    assert!(s.drain_queued(10).is_empty());
    let _ = t;
}

/// Randomized schedules of submit / install / preempt / drain: every
/// drained request is *fresh* (whole, never installed, never resumed),
/// no ticket migrates twice, and chains are conserved at every step —
/// the migration-safety contract [`Scheduler::drain_queued`] documents,
/// checked far beyond the hand-built scenarios above.
#[test]
fn drain_queued_is_safe_under_randomized_schedules() {
    let mut rng = SplitMix64::new(prop_seed() ^ 0x57EA_1);
    for scenario in 0..4 {
        let lanes = 1 + rng.below(3);
        let mut s = Scheduler::new(lanes, SchedulerConfig::default());
        let ids = Arc::new(vec![1u32; 8]);
        let mut submitted_chains = 0usize;
        let mut drained_chains = 0usize;
        // tickets that ever owned lane state (installed, and therefore
        // possibly preempted): these must never migrate afterwards
        let mut touched: BTreeSet<u64> = BTreeSet::new();
        let mut drained_tickets: BTreeSet<u64> = BTreeSet::new();
        for step in 0..250 {
            match rng.below(5) {
                // submit dominates so queues build real depth
                0 | 1 => {
                    let width = 1 + rng.below(3);
                    submitted_chains += width;
                    s.submit(&sched_req(width, 24, step as u64), ids.clone());
                }
                2 => {
                    if let Some(lane) = s.idle_lane() {
                        if let Some(p) = s.next_admission() {
                            touched.insert(p.ticket);
                            s.install(lane, ChainState::new(p, policy(24), 0));
                        }
                    }
                }
                3 => {
                    let lane = rng.below(lanes);
                    if s.lane(lane).is_some() {
                        s.preempt(lane);
                    }
                }
                _ => {
                    let eligible = s.stealable_requests();
                    let max = 1 + rng.below(3);
                    let drained = s.drain_queued(max);
                    assert_eq!(
                        drained.len(),
                        max.min(eligible),
                        "scenario {scenario} step {step}: drain must take \
                         exactly min(max, stealable)"
                    );
                    for (t, chains) in &drained {
                        assert!(
                            drained_tickets.insert(*t),
                            "ticket {t} migrated twice"
                        );
                        assert!(
                            !touched.contains(t),
                            "ticket {t} owned lane state yet migrated"
                        );
                        assert!(
                            chains.iter().all(|c| c.resume.is_none()),
                            "ticket {t}: a resumed chain migrated"
                        );
                        for (k, c) in chains.iter().enumerate() {
                            assert_eq!(c.ticket, *t);
                            assert_eq!(
                                c.chain_idx, k,
                                "ticket {t} migrated with chains missing/reordered"
                            );
                            assert_eq!(c.wait_fork, k > 0, "fork roles must survive");
                        }
                        drained_chains += chains.len();
                    }
                }
            }
            assert_eq!(
                submitted_chains,
                s.queue_depth() + s.active_lanes() + drained_chains,
                "scenario {scenario} step {step}: chains leaked or duplicated"
            );
        }
    }
}

/// Prefix-cache pool references held by queued requests are released
/// exactly once when the requests are drained for migration: the pool
/// ref count returns to its pre-submit baseline (zero releases would
/// leak; a second release panics inside the pool).
#[test]
fn drained_prefix_refs_balance_to_baseline() {
    let mut rng = SplitMix64::new(prop_seed() ^ 0xBA1A);
    for _ in 0..4 {
        let mut e = SimEngine::new(SimEngineConfig {
            lanes: 1,
            ..Default::default()
        });
        // seed the prefix index with the shared preamble, then park a
        // request on the only lane so later submissions stay queued
        e.submit(&req_for(&system_prompt(0, 0), 1, 1)).unwrap();
        e.drain().unwrap();
        e.submit(&req_for(&system_prompt(0, 1), 1, 2)).unwrap();
        e.tick().unwrap();
        let baseline = e.pool_refs();

        let n = 2 + rng.below(3);
        for k in 0..n {
            let width = 1 + rng.below(2);
            e.submit(&req_for(&system_prompt(0, 100 + k), width, 3 + k as u64))
                .unwrap();
        }
        assert!(
            e.pool_refs() > baseline,
            "queued prefix hits must hold pool references (vacuous test)"
        );
        assert_eq!(e.stealable_requests(), n);

        let stolen = e.drain_queued(n);
        assert_eq!(stolen.len(), n);
        assert_eq!(
            e.pool_refs(),
            baseline,
            "drained requests must release their prefix refs exactly once"
        );
        // the parked request is untouched and still completes cleanly
        assert_eq!(e.drain().unwrap().len(), 1);
    }
}

/// A replica that dies at construction never loses or duplicates a
/// request: every submission is answered exactly once (served by a
/// live replica, or an explicit error if it raced the death notice),
/// and no success is attributed to the dead replica.
#[test]
fn dead_replica_answers_every_request_exactly_once() {
    let mut rng = SplitMix64::new(prop_seed() ^ 0xD1E);
    let ccfg = ClusterConfig {
        replicas: 3,
        routing: *rng.choice(&[
            RoutingPolicy::Prefix,
            RoutingPolicy::LeastLoaded,
            RoutingPolicy::RoundRobin,
        ]),
        steal: true,
    };
    let cluster = Cluster::start(ccfg, move |i: usize| {
        if i == 1 {
            anyhow::bail!("injected construction failure");
        }
        Ok(SimEngine::new(SimEngineConfig {
            lanes: 2,
            kv_dtype: KvDtype::from_env(),
            ..Default::default()
        }))
    });

    let n = 24u64;
    let pending: Vec<_> = (0..n)
        .map(|i| {
            // mix hot (shared-prefix) and one-off prompts
            let prompt = if i % 3 == 0 {
                system_prompt(0, i as usize)
            } else {
                format!("unique prompt {i} with enough text to span pages")
            };
            (i, cluster.call(sreq(i, &prompt, i)))
        })
        .collect();

    let mut successes = 0usize;
    for (id, rx) in pending {
        let line = rx
            .recv_timeout(std::time::Duration::from_secs(60))
            .unwrap_or_else(|_| panic!("request {id} was lost (no response)"));
        let j = Json::parse(&line).expect("response parses");
        if j.get("error").is_none() {
            let replica = field_usize(&j, "replica_id");
            assert_ne!(replica, 1, "request {id} claims the dead replica served it");
            successes += 1;
        }
        assert!(
            rx.try_recv().is_err(),
            "request {id} was answered more than once"
        );
    }
    cluster.shutdown();
    // round-robin cycles three ways, so at worst a third of the
    // requests raced the death notice into explicit errors
    assert!(
        successes >= (2 * n as usize) / 3,
        "only {successes}/{n} requests served by live replicas"
    );
}

#[test]
fn drain_queued_never_takes_resumed_chains() {
    let mut s = Scheduler::new(1, SchedulerConfig::default());
    let ids = Arc::new(vec![1u32; 4]);
    let _t = s.submit(&sched_req(1, 24, 9), ids);
    let p = s.next_admission().unwrap();
    let mut chain = ChainState::new(p, policy(24), 0);
    // fake mid-decode progress, then preempt: the re-queued chain
    // carries resume state and must not migrate (its RNG stream and
    // generated tokens belong with this engine's recompute path)
    chain.phase = Phase::Decode;
    chain.cur_token = 5;
    chain.pos = 4;
    s.install(0, chain);
    s.preempt(0);
    assert_eq!(s.queue_depth(), 1);
    assert_eq!(s.stealable_requests(), 0);
    assert!(s.drain_queued(10).is_empty());
}

/// Regression: shortest-first used to break equal-`max_len` ties on
/// queue *position*, which steals and preemption re-queues permute —
/// two same-seed replicas could admit identical workloads in different
/// orders. Ties now break on ticket (then chain index). This scenario
/// permutes the queue both ways (a steal takes the youngest two, a
/// preemption re-queues the oldest at the *back*) and asserts the
/// admitted order is still exactly ticket order, twice.
#[test]
fn shortest_first_ties_break_on_ticket_despite_queue_permutation() {
    let run = || -> Vec<u64> {
        let cfg = SchedulerConfig {
            admission: AdmissionPolicy::ShortestFirst,
            ..SchedulerConfig::default()
        };
        let mut s = Scheduler::new(1, cfg);
        let ids = Arc::new(vec![1u32; 4]);
        let tickets: Vec<u64> = (0..8)
            .map(|i| s.submit(&sched_req(1, 24, i), ids.clone()))
            .collect();
        // permutation 1: steal the two youngest requests
        let stolen: Vec<u64> = s.drain_queued(2).into_iter().map(|(t, _)| t).collect();
        assert_eq!(stolen, vec![tickets[7], tickets[6]]);
        // permutation 2: admit the winner, then preempt it so it
        // re-enters the queue at the back — position now disagrees
        // with ticket order for the remaining six
        let p = s.next_admission().unwrap();
        assert_eq!(p.ticket, tickets[0], "lowest ticket wins the tie");
        s.install(0, ChainState::new(p, policy(24), 0));
        s.preempt(0);
        let mut admitted = Vec::new();
        while let Some(p) = s.next_admission() {
            admitted.push(p.ticket);
        }
        admitted
    };
    let first = run();
    let second = run();
    assert_eq!(first, tickets_in_order(&first), "ticket order, not queue order");
    assert_eq!(first, second, "same-seed runs admit identically");
}

/// The submitted tickets of `first`, sorted ascending — shortest-first
/// with equal lengths must admit in exactly this order.
fn tickets_in_order(tickets: &[u64]) -> Vec<u64> {
    let mut sorted = tickets.to_vec();
    sorted.sort_unstable();
    sorted
}
