// Shared lint config for non-lib targets (benches/tests/examples are
// separate crates, so the crate-wide allows in rust/src/lib.rs do not
// reach them): the same flat-layout indexing idiom applies here, and
// vec! payloads deliberately mirror the engine's heap buffers.
// Correctness lints stay on — CI denies all remaining warnings via
// `cargo clippy --all-targets -- -D warnings`.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::manual_div_ceil,
    clippy::uninlined_format_args,
    clippy::useless_vec
)]

//! Property tests for the discrete-event timing simulator
//! (`engine::timeflow`): event-queue invariants that must hold for
//! *every* seed and configuration, checked over randomized
//! configurations derived from a base seed.
//!
//! The base seed comes from `PROP_SEED` (decimal or 0x-hex) so the CI
//! seed-matrix leg can re-run the whole suite under several fixed
//! seeds; unset, it defaults to a fixed value for day-to-day runs.

use std::collections::HashMap;

use hyperscale::config::RoutingPolicy;
use hyperscale::engine::timeflow::{
    simulate, Arrival, ReplicaFailure, SimReport, Stage, TimeflowConfig, WorkloadSpec,
};
use hyperscale::util::SplitMix64;

/// Base seed for randomized property tests (see module docs).
fn prop_seed() -> u64 {
    match std::env::var("PROP_SEED") {
        Ok(s) => {
            let s = s.trim();
            let parsed = match s.strip_prefix("0x") {
                Some(hex) => u64::from_str_radix(hex, 16),
                None => s.parse(),
            };
            parsed.unwrap_or_else(|_| panic!("PROP_SEED must be an integer, got {s:?}"))
        }
        Err(_) => 0xDEFA_0175,
    }
}

/// A randomized-but-seeded simulator configuration + workload.
fn random_scenario(rng: &mut SplitMix64) -> (TimeflowConfig, WorkloadSpec) {
    let routing = *rng.choice(&[
        RoutingPolicy::Prefix,
        RoutingPolicy::LeastLoaded,
        RoutingPolicy::RoundRobin,
    ]);
    let replicas = 2 + rng.below(4); // 2..=5
    let lanes = 1 + rng.below(3); // 1..=3
    let mut cfg = TimeflowConfig::new(replicas, lanes, routing);
    cfg.steal = rng.below(2) == 0;
    cfg.prefix_cache = rng.below(2) == 0;
    cfg.record_trace = true;

    let mut spec = WorkloadSpec::new(128 + rng.below(256), rng.next_u64());
    spec.arrival = *rng.choice(&[Arrival::Uniform, Arrival::Poisson, Arrival::Bursty]);
    // from well under to well over modeled capacity
    spec.mean_gap_ns = 50_000 + rng.below(4_000_000) as u64;
    spec.n_prompts = 1 + rng.below(48);
    (cfg, spec)
}

/// Invariant: completion cycle stamps are monotone non-decreasing in
/// the order the simulator retires requests.
fn assert_monotone_completions(rep: &SimReport) {
    assert!(
        rep.completions.windows(2).all(|w| w[0].0 <= w[1].0),
        "completions must be monotone in cycle time"
    );
    assert_eq!(rep.completions.len(), rep.completed);
    if let Some(&(last, _)) = rep.completions.last() {
        assert_eq!(last, rep.span_ns, "span is the last completion stamp");
    }
}

/// Invariant: per request, stages run strictly in pipeline order and
/// no stage starts before its predecessor completes (or before the
/// request arrives).
fn assert_stage_order(rep: &SimReport, reqs_arrival: impl Fn(usize) -> u64) {
    let mut per_req: HashMap<usize, Vec<_>> = HashMap::new();
    for s in &rep.trace {
        assert!(s.start_ns <= s.end_ns);
        per_req.entry(s.req).or_default().push(*s);
    }
    for (req, spans) in per_req {
        assert!(
            spans[0].start_ns >= reqs_arrival(req),
            "req {req}: first stage before arrival"
        );
        for w in spans.windows(2) {
            assert!(
                w[1].start_ns >= w[0].end_ns,
                "req {req}: stage {:?} started at {} before {:?} ended at {}",
                w[1].stage,
                w[1].start_ns,
                w[0].stage,
                w[0].end_ns
            );
            assert!(
                w[1].stage > w[0].stage,
                "req {req}: pipeline order violated ({:?} after {:?})",
                w[1].stage,
                w[0].stage
            );
        }
        // dequant (when present) leads, prefill precedes any decode
        let stages: Vec<Stage> = spans.iter().map(|s| s.stage).collect();
        assert!(stages.contains(&Stage::Prefill), "req {req}: never prefilled");
    }
}

#[test]
fn completions_monotone_across_random_scenarios() {
    let mut rng = SplitMix64::new(prop_seed());
    for round in 0..6 {
        let (cfg, spec) = random_scenario(&mut rng);
        let rep = simulate(&cfg, &spec);
        assert_eq!(
            rep.completed, spec.requests,
            "round {round} [{}]: all requests complete without failures",
            rep.label
        );
        assert_monotone_completions(&rep);
    }
}

#[test]
fn no_stage_runs_before_its_predecessor_completes() {
    let mut rng = SplitMix64::new(prop_seed() ^ 0x5AFE);
    for _ in 0..6 {
        let (cfg, spec) = random_scenario(&mut rng);
        let reqs = hyperscale::engine::timeflow::generate_workload(&spec);
        let rep = hyperscale::engine::timeflow::simulate_requests(&cfg, &reqs);
        assert_stage_order(&rep, |i| reqs[i].arrival_ns);
    }
}

#[test]
fn same_seed_yields_bit_identical_histograms() {
    let mut rng = SplitMix64::new(prop_seed() ^ 0xB17);
    for _ in 0..4 {
        let (cfg, spec) = random_scenario(&mut rng);
        let a = simulate(&cfg, &spec);
        let b = simulate(&cfg, &spec);
        for hist in [
            "sim.ttft_ns",
            "sim.queue_wait_ns",
            "sim.latency_ns",
            "sim.stage.prefill_ns",
            "sim.stage.decode_ns",
            "sim.stage.dequant_ns",
        ] {
            assert_eq!(
                a.registry.histogram_samples(hist),
                b.registry.histogram_samples(hist),
                "[{}] histogram {hist} diverged between identical runs",
                a.label
            );
        }
        assert_eq!(a.completions, b.completions);
        assert_eq!(a.ttft_p99_ns.to_bits(), b.ttft_p99_ns.to_bits());
        assert_eq!(a.tokens_per_s.to_bits(), b.tokens_per_s.to_bits());
        assert_eq!(a.stolen, b.stolen);
    }
}

#[test]
fn replica_death_never_loses_or_duplicates_requests() {
    let mut rng = SplitMix64::new(prop_seed() ^ 0xDEAD);
    for _ in 0..6 {
        let (mut cfg, spec) = random_scenario(&mut rng);
        cfg.failure = Some(ReplicaFailure {
            replica: rng.below(cfg.replicas),
            at_ns: spec.mean_gap_ns * rng.below(spec.requests) as u64,
        });
        let rep = simulate(&cfg, &spec);
        assert_eq!(
            rep.completed + rep.failed,
            spec.requests,
            "[{}] death must conserve requests",
            rep.label
        );
        // only work holding a lane at death can fail
        assert!(rep.failed <= cfg.lanes);
        let mut ids: Vec<usize> = rep.completions.iter().map(|&(_, r)| r).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), rep.completed, "a request completed twice");
    }
}

#[test]
fn queue_wait_only_under_contention() {
    // a closed-form sanity anchor: generous arrival gaps mean zero
    // queue wait, so end-to-end latency is exactly service time
    let mut cfg = TimeflowConfig::new(2, 1, RoutingPolicy::RoundRobin);
    cfg.steal = false;
    cfg.prefix_cache = false;
    cfg.record_trace = true;
    let mut spec = WorkloadSpec::new(64, prop_seed());
    spec.arrival = Arrival::Uniform;
    spec.mean_gap_ns = 40_000_000; // ≫ worst-case service
    let rep = simulate(&cfg, &spec);
    let waits = rep.registry.histogram_samples("sim.queue_wait_ns");
    assert!(waits.iter().all(|&w| w == 0.0), "uncontended ⇒ no waiting");
    assert!(rep.utilization < 0.5, "mostly idle cluster");
}
