// Shared lint config for non-lib targets (benches/tests/examples are
// separate crates, so the crate-wide allows in rust/src/lib.rs do not
// reach them): the same flat-layout indexing idiom applies here, and
// vec! payloads deliberately mirror the engine's heap buffers.
// Correctness lints stay on — CI denies all remaining warnings via
// `cargo clippy --all-targets -- -D warnings`.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::manual_div_ceil,
    clippy::uninlined_format_args,
    clippy::useless_vec
)]

//! Property tests for the discrete-event timing simulator
//! (`engine::timeflow`): event-queue invariants that must hold for
//! *every* seed and configuration, checked over randomized
//! configurations derived from a base seed.
//!
//! The base seed comes from `PROP_SEED` (decimal or 0x-hex) so the CI
//! seed-matrix leg can re-run the whole suite under several fixed
//! seeds; unset, it defaults to a fixed value for day-to-day runs.

use std::collections::HashMap;

use hyperscale::config::RoutingPolicy;
use hyperscale::engine::timeflow::{
    simulate, Arrival, ReplicaFailure, SimReport, SimRequest, Stage, TimeflowConfig,
    WorkloadSpec,
};
use hyperscale::engine::{
    generate_mixed_workload, simulate_slo, slo_requests, ArrivalKind, SloPolicy, SloRequest,
    SloTier, WorkloadConfig,
};
use hyperscale::util::SplitMix64;

/// Base seed for randomized property tests (see module docs).
fn prop_seed() -> u64 {
    match std::env::var("PROP_SEED") {
        Ok(s) => {
            let s = s.trim();
            let parsed = match s.strip_prefix("0x") {
                Some(hex) => u64::from_str_radix(hex, 16),
                None => s.parse(),
            };
            parsed.unwrap_or_else(|_| panic!("PROP_SEED must be an integer, got {s:?}"))
        }
        Err(_) => 0xDEFA_0175,
    }
}

/// A randomized-but-seeded simulator configuration + workload.
fn random_scenario(rng: &mut SplitMix64) -> (TimeflowConfig, WorkloadSpec) {
    let routing = *rng.choice(&[
        RoutingPolicy::Prefix,
        RoutingPolicy::LeastLoaded,
        RoutingPolicy::RoundRobin,
    ]);
    let replicas = 2 + rng.below(4); // 2..=5
    let lanes = 1 + rng.below(3); // 1..=3
    let mut cfg = TimeflowConfig::new(replicas, lanes, routing);
    cfg.steal = rng.below(2) == 0;
    cfg.prefix_cache = rng.below(2) == 0;
    cfg.record_trace = true;

    let mut spec = WorkloadSpec::new(128 + rng.below(256), rng.next_u64());
    spec.arrival = *rng.choice(&[Arrival::Uniform, Arrival::Poisson, Arrival::Bursty]);
    // from well under to well over modeled capacity
    spec.mean_gap_ns = 50_000 + rng.below(4_000_000) as u64;
    spec.n_prompts = 1 + rng.below(48);
    (cfg, spec)
}

/// Invariant: completion cycle stamps are monotone non-decreasing in
/// the order the simulator retires requests.
fn assert_monotone_completions(rep: &SimReport) {
    assert!(
        rep.completions.windows(2).all(|w| w[0].0 <= w[1].0),
        "completions must be monotone in cycle time"
    );
    assert_eq!(rep.completions.len(), rep.completed);
    if let Some(&(last, _)) = rep.completions.last() {
        assert_eq!(last, rep.span_ns, "span is the last completion stamp");
    }
}

/// Invariant: per request, stages run strictly in pipeline order and
/// no stage starts before its predecessor completes (or before the
/// request arrives).
fn assert_stage_order(rep: &SimReport, reqs_arrival: impl Fn(usize) -> u64) {
    let mut per_req: HashMap<usize, Vec<_>> = HashMap::new();
    for s in &rep.trace {
        assert!(s.start_ns <= s.end_ns);
        per_req.entry(s.req).or_default().push(*s);
    }
    for (req, spans) in per_req {
        assert!(
            spans[0].start_ns >= reqs_arrival(req),
            "req {req}: first stage before arrival"
        );
        for w in spans.windows(2) {
            assert!(
                w[1].start_ns >= w[0].end_ns,
                "req {req}: stage {:?} started at {} before {:?} ended at {}",
                w[1].stage,
                w[1].start_ns,
                w[0].stage,
                w[0].end_ns
            );
            assert!(
                w[1].stage > w[0].stage,
                "req {req}: pipeline order violated ({:?} after {:?})",
                w[1].stage,
                w[0].stage
            );
        }
        // dequant (when present) leads, prefill precedes any decode
        let stages: Vec<Stage> = spans.iter().map(|s| s.stage).collect();
        assert!(stages.contains(&Stage::Prefill), "req {req}: never prefilled");
    }
}

#[test]
fn completions_monotone_across_random_scenarios() {
    let mut rng = SplitMix64::new(prop_seed());
    for round in 0..6 {
        let (cfg, spec) = random_scenario(&mut rng);
        let rep = simulate(&cfg, &spec);
        assert_eq!(
            rep.completed, spec.requests,
            "round {round} [{}]: all requests complete without failures",
            rep.label
        );
        assert_monotone_completions(&rep);
    }
}

#[test]
fn no_stage_runs_before_its_predecessor_completes() {
    let mut rng = SplitMix64::new(prop_seed() ^ 0x5AFE);
    for _ in 0..6 {
        let (cfg, spec) = random_scenario(&mut rng);
        let reqs = hyperscale::engine::timeflow::generate_workload(&spec);
        let rep = hyperscale::engine::timeflow::simulate_requests(&cfg, &reqs);
        assert_stage_order(&rep, |i| reqs[i].arrival_ns);
    }
}

#[test]
fn same_seed_yields_bit_identical_histograms() {
    let mut rng = SplitMix64::new(prop_seed() ^ 0xB17);
    for _ in 0..4 {
        let (cfg, spec) = random_scenario(&mut rng);
        let a = simulate(&cfg, &spec);
        let b = simulate(&cfg, &spec);
        for hist in [
            "sim.ttft_ns",
            "sim.queue_wait_ns",
            "sim.latency_ns",
            "sim.stage.prefill_ns",
            "sim.stage.decode_ns",
            "sim.stage.dequant_ns",
        ] {
            assert_eq!(
                a.registry.histogram_samples(hist),
                b.registry.histogram_samples(hist),
                "[{}] histogram {hist} diverged between identical runs",
                a.label
            );
        }
        assert_eq!(a.completions, b.completions);
        assert_eq!(a.ttft_p99_ns.to_bits(), b.ttft_p99_ns.to_bits());
        assert_eq!(a.tokens_per_s.to_bits(), b.tokens_per_s.to_bits());
        assert_eq!(a.stolen, b.stolen);
    }
}

#[test]
fn replica_death_never_loses_or_duplicates_requests() {
    let mut rng = SplitMix64::new(prop_seed() ^ 0xDEAD);
    for _ in 0..6 {
        let (mut cfg, spec) = random_scenario(&mut rng);
        cfg.failure = Some(ReplicaFailure {
            replica: rng.below(cfg.replicas),
            at_ns: spec.mean_gap_ns * rng.below(spec.requests) as u64,
        });
        let rep = simulate(&cfg, &spec);
        assert_eq!(
            rep.completed + rep.failed,
            spec.requests,
            "[{}] death must conserve requests",
            rep.label
        );
        // only work holding a lane at death can fail
        assert!(rep.failed <= cfg.lanes);
        let mut ids: Vec<usize> = rep.completions.iter().map(|&(_, r)| r).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), rep.completed, "a request completed twice");
    }
}

#[test]
fn queue_wait_only_under_contention() {
    // a closed-form sanity anchor: generous arrival gaps mean zero
    // queue wait, so end-to-end latency is exactly service time
    let mut cfg = TimeflowConfig::new(2, 1, RoutingPolicy::RoundRobin);
    cfg.steal = false;
    cfg.prefix_cache = false;
    cfg.record_trace = true;
    let mut spec = WorkloadSpec::new(64, prop_seed());
    spec.arrival = Arrival::Uniform;
    spec.mean_gap_ns = 40_000_000; // ≫ worst-case service
    let rep = simulate(&cfg, &spec);
    let waits = rep.registry.histogram_samples("sim.queue_wait_ns");
    assert!(waits.iter().all(|&w| w == 0.0), "uncontended ⇒ no waiting");
    assert!(rep.utilization < 0.5, "mostly idle cluster");
}

// ----------------------------------------------------------------------
// SLO schedulability anchors (closed-form; see docs/TESTING.md)
// ----------------------------------------------------------------------

/// Closed-form schedulability bound: with 40 ms uniform gaps over
/// 2x2 lanes, the worst-case f32 service time (a 768-prompt/96-token
/// long-context request: 768 x 17 339 + 96 x 150 136 ≈ 27.7 ms) fits
/// inside one inter-arrival gap, and even a width-4 voting fan-out
/// (4 chat-sized chains, round-robined two per replica) finds an idle
/// lane — so no chain ever queues. Worst TTFT (prefill + first decode
/// ≈ 13.5 ms for long-context/Batch, ≈ 1.8 ms for chat/Interactive)
/// sits under every tier's TTFT deadline, and worst e2e (≈ 27.7 ms)
/// under every e2e deadline. Peak admission commitment (≤ 3 live
/// arrivals x ≤ 864 tokens) stays under the 4096-token capacity. The
/// admitted set is therefore *everything*, and everything meets every
/// deadline — for any seed.
#[test]
fn uncontended_admitted_set_meets_every_deadline() {
    let mut wcfg = WorkloadConfig::new(256, prop_seed());
    wcfg.arrival = ArrivalKind::Uniform;
    wcfg.mean_gap_ns = 40_000_000;
    let reqs = slo_requests(&generate_mixed_workload(&wcfg));
    let mut cfg = TimeflowConfig::new(2, 2, RoutingPolicy::RoundRobin);
    cfg.steal = false;
    cfg.prefix_cache = false;
    let mut rep = simulate_slo(&cfg, &reqs, &SloPolicy::edf_admitted(2, 2));
    assert_eq!(rep.completed, reqs.len());
    assert_eq!(
        rep.registry.counter("serve.slo_accepted").get(),
        reqs.len() as f64,
        "uncontended load must be admitted outright"
    );
    for c in [
        "serve.slo_queued",
        "serve.slo_rejected",
        "serve.slo_ttft_miss",
        "serve.slo_deadline_miss",
    ] {
        assert_eq!(rep.registry.counter(c).get(), 0.0, "{c} must stay zero uncontended");
    }
    assert_eq!(
        rep.registry.counter("serve.slo_goodput_tokens").get(),
        rep.gen_tokens as f64,
        "every generated token counts as goodput when no deadline misses"
    );
}

/// Hand-verifiable overload: 20 requests of 32 prompt + 16 gen tokens
/// (service 32 x 17 339 + 16 x 150 136 = 2 957 024 ns each) hit one
/// f32 lane at t = 0 — ten Batch (e2e 2.5 s) submitted first, ten
/// Interactive (e2e 50 ms) behind them.
///
/// * FCFS serves in arrival order: the k-th completion lands at
///   k x 2.957 ms, so Interactive requests finish 11th–20th at
///   32.5–59.1 ms. 16 x 2.957 = 47.3 ≤ 50 < 17 x 2.957, so exactly
///   the last four Interactive requests miss: 16 met, 4 missed.
/// * EDF: the first Batch arrival grabs the idle lane before any
///   competition exists, then every Interactive deadline (50 ms)
///   sorts ahead of Batch (2.5 s): Interactive finishes 2nd–11th by
///   11 x 2.957 = 32.5 ms < 50 ms, and every Batch request still
///   lands by 59.1 ms ≪ 2.5 s — 20 met, 0 missed. Admission changes
///   nothing here (20 x 48 = 960 tokens ≤ the 1024-token capacity),
///   isolating the EDF win.
#[test]
fn edf_beats_fcfs_on_deadline_met_count_under_overload() {
    let mut reqs: Vec<SloRequest> = Vec::new();
    for i in 0..20 {
        let tier = if i < 10 { SloTier::Batch } else { SloTier::Interactive };
        reqs.push(SloRequest::stamp(
            SimRequest {
                arrival_ns: 0,
                prompt_id: i,
                prompt_tokens: 32,
                gen_tokens: 16,
            },
            tier,
        ));
    }
    let mut cfg = TimeflowConfig::new(1, 1, RoutingPolicy::RoundRobin);
    cfg.steal = false;
    cfg.prefix_cache = false;

    let mut edf = simulate_slo(&cfg, &reqs, &SloPolicy::edf_admitted(1, 1));
    let mut fcfs = simulate_slo(&cfg, &reqs, &SloPolicy::fcfs_open(1, 1));
    assert_eq!(edf.completed, 20, "admission must not reject the 960-token burst");
    assert_eq!(fcfs.completed, 20);

    let edf_miss = edf.registry.counter("serve.slo_deadline_miss").get();
    let fcfs_miss = fcfs.registry.counter("serve.slo_deadline_miss").get();
    assert_eq!(edf_miss, 0.0, "EDF meets every deadline in the worked example");
    assert_eq!(fcfs_miss, 4.0, "FCFS misses exactly the last four Interactive e2es");
    let edf_met = edf.completed as f64 - edf_miss;
    let fcfs_met = fcfs.completed as f64 - fcfs_miss;
    assert!(
        edf_met > fcfs_met,
        "EDF must strictly beat FCFS on deadline-met count ({edf_met} vs {fcfs_met})"
    );
    assert!(
        edf.slo_goodput_tokens_per_s > fcfs.slo_goodput_tokens_per_s,
        "the deadline-met margin must show up as goodput"
    );
}
