// Shared lint config for non-lib targets (benches/tests/examples are
// separate crates, so the crate-wide allows in rust/src/lib.rs do not
// reach them): the same flat-layout indexing idiom applies here, and
// vec! payloads deliberately mirror the engine's heap buffers.
// Correctness lints stay on — CI denies all remaining warnings via
// `cargo clippy --all-targets -- -D warnings`.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::manual_div_ceil,
    clippy::uninlined_format_args,
    clippy::useless_vec
)]

//! Budget-plan regression suite.
//!
//! Three contracts of the per-(layer, head) plan refactor:
//!
//! 1. **Conservation** — every allocator's plan sums to the App. F.1
//!    global budget (per-head rounding resolved exactly).
//! 2. **Uniform bit-exactness** — under a uniform plan, the
//!    head-granular enforcement loops reproduce the pre-plan policy
//!    zoo *bit-exactly*: local copies of the legacy coupled TOVA/H2O
//!    eviction (head-0 `live_count` probing, all-head slot eviction,
//!    layer-wide cumulative scores) and the legacy scalar window trim
//!    are driven side-by-side with the new policies over a
//!    cache-state-derived pseudo-model; token streams and lane state
//!    must match byte-for-byte, for all 8 policies.
//! 3. **Per-head enforcement** — non-uniform plans hold for *every*
//!    (layer, head) pair after decode (the old head-0 probe enforced
//!    only head 0's count), and COW forks + prefix-cache restores stay
//!    bit-exact when the enforcing plan is non-uniform.
//!
//! Everything here pins f32 pool payloads: the memcpy-fork reference
//! never touches the pool, so fork-mode byte equality is an f32-only
//! contract (quantized COW exactness is covered by
//! `tests/quantized_cache.rs`).

use hyperscale::compress::{
    build_allocator, build_policy, build_policy_planned, AllocatorKind, AttnStats,
    BudgetPlan, Policy, PolicyKind, StepView, WriteAction,
};
use hyperscale::kvcache::{CacheStore, Geometry, KvDtype};
use hyperscale::util::SplitMix64;

fn geom(slots: usize) -> Geometry {
    Geometry {
        layers: 2,
        kv_heads: 2,
        slots,
        head_dim: 4,
        page_size: 8,
    }
}

fn store(g: Geometry, lanes: usize) -> CacheStore {
    CacheStore::with_dtype(g, lanes, KvDtype::F32)
}

// ----------------------------------------------------------------------
// Pseudo-model harness (mirrors tests/property_coordinator.rs): logits
// are a pure function of the lane's observable cache state, so any
// divergence in eviction decisions changes the token stream.
// ----------------------------------------------------------------------

fn cache_logits(c: &CacheStore, lane: usize, pos: usize) -> Vec<f32> {
    let g = c.geom;
    let mut acc = 0x9E37_79B9_7F4A_7C15u64 ^ (pos as u64);
    for l in 0..g.layers {
        for h in 0..g.kv_heads {
            for s in 0..g.slots {
                if let Some(p) = c.slot_pos(lane, l, h, s) {
                    let kbits = c.k_at(lane, l, h, s)[0].to_bits() as u64;
                    acc = acc
                        .wrapping_mul(0x0100_0000_01B3)
                        .wrapping_add(kbits ^ ((s as u64) << 32) ^ p as u64);
                    acc ^= (c.mask_value(lane, l, h, s).to_bits() as u64).rotate_left(17);
                }
            }
        }
    }
    let mut r = SplitMix64::new(acc);
    (0..16).map(|_| r.f64() as f32).collect()
}

/// Deterministic per-(lane, pos) α/attention streams shared by both
/// sides of every comparison.
fn step_inputs(g: Geometry, lane: usize, pos: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let lh = g.lh();
    let mut rng = SplitMix64::new(0xA11CE ^ ((lane as u64) << 40) ^ pos as u64);
    let alpha: Vec<f32> = (0..lh).map(|_| rng.f64() as f32).collect();
    let attn: Vec<f32> = (0..lh * g.slots).map(|_| rng.f64() as f32).collect();
    let attn_self: Vec<f32> = (0..lh).map(|_| rng.f64() as f32).collect();
    (alpha, attn, attn_self)
}

/// One simulated decode step through a `Policy` (engine write path:
/// due evictions, write-actions, append/merge, post_write).
fn drive_policy_step(
    c: &mut CacheStore,
    lane: usize,
    policy: &mut Box<dyn Policy>,
    pos: usize,
) -> u32 {
    let g = c.geom;
    let lh = g.lh();
    let logits = cache_logits(c, lane, pos);
    let tok = logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0 as u32;
    let (alpha, attn, attn_self) = step_inputs(g, lane, pos);
    c.apply_due_evictions(lane, pos);
    let mut actions: Vec<WriteAction> = Vec::new();
    policy.write_actions(&alpha, g.layers, g.kv_heads, &mut actions);
    let payload: Vec<f32> = (0..g.head_dim)
        .map(|d| tok as f32 + d as f32 + pos as f32 * 0.25)
        .collect();
    let mut written = vec![None; lh];
    for l in 0..g.layers {
        for h in 0..g.kv_heads {
            let i = l * g.kv_heads + h;
            written[i] = None;
            let append = match actions[i] {
                WriteAction::Merge => !c.merge_into_last(lane, l, h, &payload, &payload),
                WriteAction::Append => true,
            };
            if append {
                if let Some(s) = c.alloc_slot(lane, l, h) {
                    c.write(lane, l, h, s, pos, &payload, &payload);
                    written[i] = Some(s);
                }
            }
        }
    }
    policy.post_write(
        c,
        &StepView {
            lane,
            pos,
            alpha: &alpha,
            attn: &attn,
            attn_self: &attn_self,
            written: &written,
        },
    );
    tok
}

/// One simulated decode step whose eviction enforcement is a legacy
/// (pre-plan) implementation; writes are plain appends, exactly what
/// the budgeted training-free policies do.
fn drive_legacy_step<F>(c: &mut CacheStore, lane: usize, pos: usize, enforce: F) -> u32
where
    F: FnOnce(&mut CacheStore, &StepView<'_>),
{
    let g = c.geom;
    let lh = g.lh();
    let logits = cache_logits(c, lane, pos);
    let tok = logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0 as u32;
    let (alpha, attn, attn_self) = step_inputs(g, lane, pos);
    c.apply_due_evictions(lane, pos);
    let payload: Vec<f32> = (0..g.head_dim)
        .map(|d| tok as f32 + d as f32 + pos as f32 * 0.25)
        .collect();
    let mut written = vec![None; lh];
    for l in 0..g.layers {
        for h in 0..g.kv_heads {
            let i = l * g.kv_heads + h;
            written[i] = None;
            if let Some(s) = c.alloc_slot(lane, l, h) {
                c.write(lane, l, h, s, pos, &payload, &payload);
                written[i] = Some(s);
            }
        }
    }
    enforce(
        c,
        &StepView {
            lane,
            pos,
            alpha: &alpha,
            attn: &attn,
            attn_self: &attn_self,
            written: &written,
        },
    );
    tok
}

fn prefill_identity(c: &mut CacheStore, lane: usize, n: usize) {
    let g = c.geom;
    for pos in 0..n {
        let payload: Vec<f32> =
            (0..g.head_dim).map(|d| pos as f32 + d as f32 * 0.5).collect();
        for l in 0..g.layers {
            for h in 0..g.kv_heads {
                let s = c.alloc_slot(lane, l, h).unwrap();
                c.write(lane, l, h, s, pos, &payload, &payload);
            }
        }
    }
}

fn assert_lane_state_equal(
    a: &CacheStore,
    b: &CacheStore,
    lane_a: usize,
    lane_b: usize,
    ctx: &str,
) {
    let g = a.geom;
    for l in 0..g.layers {
        for h in 0..g.kv_heads {
            assert_eq!(
                a.live_count(lane_a, l, h),
                b.live_count(lane_b, l, h),
                "{ctx}: live desync at ({l},{h})"
            );
            for s in 0..g.slots {
                assert_eq!(
                    a.slot_state(lane_a, l, h, s),
                    b.slot_state(lane_b, l, h, s),
                    "{ctx}: meta desync at ({l},{h},{s})"
                );
                assert_eq!(
                    a.mask_value(lane_a, l, h, s),
                    b.mask_value(lane_b, l, h, s),
                    "{ctx}: mask desync at ({l},{h},{s})"
                );
                assert_eq!(
                    a.k_at(lane_a, l, h, s),
                    b.k_at(lane_b, l, h, s),
                    "{ctx}: k desync at ({l},{h},{s})"
                );
                assert_eq!(
                    a.v_at(lane_a, l, h, s),
                    b.v_at(lane_b, l, h, s),
                    "{ctx}: v desync at ({l},{h},{s})"
                );
            }
        }
    }
}

// ----------------------------------------------------------------------
// Legacy (pre-plan) enforcement, frozen verbatim: head-0 probing,
// all-head coupled eviction, layer-wide cumulative scores.
// ----------------------------------------------------------------------

/// Pre-plan sliding-window trim: scalar budget, per-head oldest-first.
fn legacy_trim_to_window(cache: &mut CacheStore, lane: usize, budget: usize) {
    let g = cache.geom;
    for l in 0..g.layers {
        for h in 0..g.kv_heads {
            let mut live = cache.live_slots(lane, l, h);
            if live.len() <= budget {
                continue;
            }
            live.sort_by_key(|&(_, pos)| pos);
            let n_evict = live.len() - budget;
            for &(slot, _) in live.iter().take(n_evict) {
                cache.evict(lane, l, h, slot);
            }
        }
    }
}

/// Pre-plan TOVA: `while live_count(lane, l, 0) > budget`, evict the
/// argmin layer-summed-attention slot on ALL heads.
struct LegacyTova {
    budget: usize,
}

impl LegacyTova {
    fn post_write(&mut self, cache: &mut CacheStore, view: &StepView<'_>) {
        let g = cache.geom;
        let s = g.slots;
        for l in 0..g.layers {
            while cache.live_count(view.lane, l, 0) > self.budget {
                let mut best_slot = None;
                let mut best_score = f32::INFINITY;
                for (slot, pos) in cache.live_slots(view.lane, l, 0) {
                    if pos == view.pos {
                        continue;
                    }
                    let mut score = 0.0f32;
                    for h in 0..g.kv_heads {
                        score += view.attn[(l * g.kv_heads + h) * s + slot];
                    }
                    if score < best_score {
                        best_score = score;
                        best_slot = Some(slot);
                    }
                }
                let Some(slot) = best_slot else { break };
                for h in 0..g.kv_heads {
                    cache.evict(view.lane, l, h, slot);
                }
            }
        }
    }
}

/// Pre-plan H2O: layer-wide cumulative scores (`cum[l, slot]`), head-0
/// probing, all-head coupled eviction, score reset on eviction.
struct LegacyH2o {
    budget: usize,
    recent: usize,
    cum: Vec<f32>,
}

impl LegacyH2o {
    fn new(budget: usize) -> Self {
        Self {
            budget,
            recent: budget / 2,
            cum: Vec::new(),
        }
    }

    fn post_write(&mut self, cache: &mut CacheStore, view: &StepView<'_>) {
        let g = cache.geom;
        if self.cum.len() != g.layers * g.slots {
            self.cum = vec![0.0; g.layers * g.slots];
        }
        for l in 0..g.layers {
            for slot in 0..g.slots {
                let mut mass = 0.0f32;
                for h in 0..g.kv_heads {
                    mass += view.attn[(l * g.kv_heads + h) * g.slots + slot];
                }
                self.cum[l * g.slots + slot] += mass;
            }
        }
        for l in 0..g.layers {
            while cache.live_count(view.lane, l, 0) > self.budget {
                let cutoff = view.pos.saturating_sub(self.recent);
                let mut best = None;
                let mut best_score = f32::INFINITY;
                let mut oldest: Option<(usize, usize)> = None;
                for (slot, pos) in cache.live_slots(view.lane, l, 0) {
                    if oldest.map(|(_, p)| pos < p).unwrap_or(true) {
                        oldest = Some((slot, pos));
                    }
                    if pos >= cutoff {
                        continue;
                    }
                    let score = self.cum[l * g.slots + slot];
                    if score < best_score {
                        best_score = score;
                        best = Some(slot);
                    }
                }
                let slot = match best.or(oldest.map(|(s, _)| s)) {
                    Some(s) => s,
                    None => break,
                };
                for h in 0..g.kv_heads {
                    cache.evict(view.lane, l, h, slot);
                }
                self.cum[l * g.slots + slot] = 0.0;
            }
        }
    }
}

// ----------------------------------------------------------------------
// 1. Conservation
// ----------------------------------------------------------------------

#[test]
fn every_allocator_conserves_the_global_budget() {
    let mut stats = AttnStats::new();
    let g = geom(32);
    for pos in 0..6 {
        let (_, attn, attn_self) = step_inputs(g, 0, pos);
        stats.observe_attn(g.layers, g.kv_heads, g.slots, &attn, &attn_self);
    }
    for kind in AllocatorKind::all() {
        let alloc = build_allocator(kind);
        for layers in [1usize, 2, 4] {
            for kv_heads in [1usize, 2, 3] {
                for per_head in [1usize, 7, 40, 113] {
                    let n = layers * kv_heads;
                    let global = per_head * n;
                    let st = if (layers, kv_heads) == (g.layers, g.kv_heads) {
                        Some(&stats)
                    } else {
                        None
                    };
                    let plan = alloc.plan(layers, kv_heads, global, st);
                    assert_eq!(
                        plan.total(layers, kv_heads),
                        global,
                        "{kind:?} leaked budget at {layers}x{kv_heads}x{per_head}"
                    );
                    assert!(plan.min_budget() >= 1, "{kind:?} starved a head");
                }
            }
        }
    }
}

// ----------------------------------------------------------------------
// 2. Uniform bit-exactness vs the legacy coupled implementations
// ----------------------------------------------------------------------

#[test]
fn uniform_tova_bit_exact_vs_legacy_coupled_eviction() {
    let g = geom(64);
    let (prompt, steps, budget) = (19usize, 30usize, 10usize);
    let mut legacy_store = store(g, 1);
    let mut new_store = store(g, 1);
    prefill_identity(&mut legacy_store, 0, prompt);
    prefill_identity(&mut new_store, 0, prompt);

    // CR chosen so the App. F.1 rule yields exactly `budget`
    let mut policy = build_policy(PolicyKind::Tova, 160.0 / budget as f64, 160, 4, 8);
    let mut legacy = LegacyTova { budget };
    legacy_trim_to_window(&mut legacy_store, 0, budget);
    policy.post_prefill(&mut new_store, 0, prompt);
    assert_lane_state_equal(&legacy_store, &new_store, 0, 0, "tova post-prefill");

    for step in 0..steps {
        let pos = prompt + step;
        let t_legacy =
            drive_legacy_step(&mut legacy_store, 0, pos, |c, v| legacy.post_write(c, v));
        let t_new = drive_policy_step(&mut new_store, 0, &mut policy, pos);
        assert_eq!(t_legacy, t_new, "tova stream diverged at step {step}");
    }
    assert_lane_state_equal(&legacy_store, &new_store, 0, 0, "tova final state");
}

#[test]
fn uniform_h2o_bit_exact_vs_legacy_coupled_eviction() {
    let g = geom(64);
    let (prompt, steps, budget) = (19usize, 30usize, 10usize);
    let mut legacy_store = store(g, 1);
    let mut new_store = store(g, 1);
    prefill_identity(&mut legacy_store, 0, prompt);
    prefill_identity(&mut new_store, 0, prompt);

    let mut policy = build_policy(PolicyKind::H2o, 160.0 / budget as f64, 160, 4, 8);
    let mut legacy = LegacyH2o::new(budget);
    legacy_trim_to_window(&mut legacy_store, 0, budget);
    policy.post_prefill(&mut new_store, 0, prompt);
    assert_lane_state_equal(&legacy_store, &new_store, 0, 0, "h2o post-prefill");

    for step in 0..steps {
        let pos = prompt + step;
        let t_legacy =
            drive_legacy_step(&mut legacy_store, 0, pos, |c, v| legacy.post_write(c, v));
        let t_new = drive_policy_step(&mut new_store, 0, &mut policy, pos);
        assert_eq!(t_legacy, t_new, "h2o stream diverged at step {step}");
    }
    assert_lane_state_equal(&legacy_store, &new_store, 0, 0, "h2o final state");
}

#[test]
fn uniform_window_bit_exact_vs_legacy_scalar_trim() {
    let g = geom(64);
    let (prompt, steps, budget) = (19usize, 30usize, 10usize);
    let mut legacy_store = store(g, 1);
    let mut new_store = store(g, 1);
    prefill_identity(&mut legacy_store, 0, prompt);
    prefill_identity(&mut new_store, 0, prompt);

    let mut policy = build_policy(PolicyKind::Window, 160.0 / budget as f64, 160, 4, 8);
    legacy_trim_to_window(&mut legacy_store, 0, budget);
    policy.post_prefill(&mut new_store, 0, prompt);

    for step in 0..steps {
        let pos = prompt + step;
        let t_legacy = drive_legacy_step(&mut legacy_store, 0, pos, |c, _| {
            legacy_trim_to_window(c, 0, budget)
        });
        let t_new = drive_policy_step(&mut new_store, 0, &mut policy, pos);
        assert_eq!(t_legacy, t_new, "window stream diverged at step {step}");
    }
    assert_lane_state_equal(&legacy_store, &new_store, 0, 0, "window final state");
}

/// The engine's uniform allocator produces a shaped per-head plan with
/// equal entries; the legacy constructor produces the shape-free
/// uniform plan. The two must drive identical streams for all 8
/// policies — this is the `--allocator uniform` admission-path
/// regression.
#[test]
fn shaped_uniform_plan_matches_legacy_constructor_across_all_policies() {
    use PolicyKind as PK;
    for kind in [
        PK::Vanilla,
        PK::Dms,
        PK::DmsImmediate,
        PK::Tova,
        PK::H2o,
        PK::Dmc,
        PK::Window,
        PK::Quest,
    ] {
        let g = geom(64);
        let (prompt, steps, window) = (19usize, 25usize, 4usize);
        let mut a = store(g, 1);
        let mut b = store(g, 1);
        prefill_identity(&mut a, 0, prompt);
        prefill_identity(&mut b, 0, prompt);

        // legacy constructor: uniform shape-free plan at budget 40
        let mut pol_a = build_policy(kind, 4.0, 160, window, g.page_size);
        // engine path: the uniform allocator's shaped plan
        let plan = build_allocator(AllocatorKind::Uniform).plan(
            g.layers,
            g.kv_heads,
            40 * g.lh(),
            None,
        );
        assert_eq!(plan.uniform_budget(), Some(40));
        let mut pol_b = build_policy_planned(kind, plan, window, g.page_size);
        assert_eq!(pol_a.quest_pages(), pol_b.quest_pages());

        pol_a.post_prefill(&mut a, 0, prompt);
        pol_b.post_prefill(&mut b, 0, prompt);
        for step in 0..steps {
            let pos = prompt + step;
            let ta = drive_policy_step(&mut a, 0, &mut pol_a, pos);
            let tb = drive_policy_step(&mut b, 0, &mut pol_b, pos);
            assert_eq!(ta, tb, "{kind:?} stream diverged at step {step}");
        }
        assert_lane_state_equal(&a, &b, 0, 0, &format!("{kind:?} final state"));
    }
}

// ----------------------------------------------------------------------
// 3. Per-head enforcement + sharing under non-uniform plans
// ----------------------------------------------------------------------

/// Regression for the head-0 probing bug: with per-head budgets, the
/// budget must hold for EVERY (layer, head) after decode — the legacy
/// loop checked head 0's live count only and would have left heads
/// with smaller budgets over-full forever.
#[test]
fn nonuniform_budgets_hold_for_every_head_after_decode() {
    let g = geom(64);
    let plan = BudgetPlan::per_head(2, 2, vec![12, 5, 9, 3]);
    for kind in [PolicyKind::Tova, PolicyKind::H2o, PolicyKind::Window] {
        let mut c = store(g, 1);
        let prompt = 19usize;
        prefill_identity(&mut c, 0, prompt);
        let mut policy = build_policy_planned(kind, plan.clone(), 4, g.page_size);
        policy.post_prefill(&mut c, 0, prompt);
        for step in 0..30usize {
            let pos = prompt + step;
            drive_policy_step(&mut c, 0, &mut policy, pos);
            for l in 0..g.layers {
                for h in 0..g.kv_heads {
                    assert!(
                        c.live_count(0, l, h) <= plan.budget(l, h),
                        "{kind:?}: head ({l},{h}) exceeded its budget {} at step {step}: {}",
                        plan.budget(l, h),
                        c.live_count(0, l, h)
                    );
                }
            }
            assert_eq!(c.plan_overflow(0, &plan), 0, "{kind:?} plan overflow");
        }
        // the small heads actually run AT their budgets (enforcement
        // bites beyond head 0, which the legacy probe never checked)
        assert_eq!(c.live_count(0, 0, 1), 5, "{kind:?} head (0,1)");
        assert_eq!(c.live_count(0, 1, 1), 3, "{kind:?} head (1,1)");
        assert!(c.live_count(0, 0, 0) > c.live_count(0, 0, 1));
    }
}

/// COW forks must stay bit-exact against the legacy memcpy fork when
/// the enforcing plan is non-uniform (per-head evictions land on
/// shared pages head-by-head).
#[test]
fn cow_fork_streams_bit_exact_under_nonuniform_plans() {
    for kind in [PolicyKind::Tova, PolicyKind::H2o, PolicyKind::Window] {
        let g = geom(64);
        let (prompt, steps) = (19usize, 25usize);
        let plan = build_allocator(AllocatorKind::Pyramid).plan(
            g.layers,
            g.kv_heads,
            10 * g.lh(),
            None,
        );
        assert!(!plan.is_uniform(), "pyramid plan must be non-uniform");
        let mk = || build_policy_planned(kind, plan.clone(), 4, g.page_size);

        let mut a = store(g, 2);
        let mut b = store(g, 2);
        prefill_identity(&mut a, 0, prompt);
        prefill_identity(&mut b, 0, prompt);
        a.fork_lane(0, 1); // legacy deep copy
        b.fork_lane_cow(0, 1); // COW refcount bump

        let mut pol_a = [mk(), mk()];
        let mut pol_b = [mk(), mk()];
        for lane in 0..2 {
            pol_a[lane].post_prefill(&mut a, lane, prompt);
        }
        b.materialize_pending();
        for lane in 0..2 {
            pol_b[lane].post_prefill(&mut b, lane, prompt);
        }
        for step in 0..steps {
            let pos = prompt + step;
            b.materialize_pending();
            for lane in 0..2 {
                let ta = drive_policy_step(&mut a, lane, &mut pol_a[lane], pos);
                let tb = drive_policy_step(&mut b, lane, &mut pol_b[lane], pos);
                assert_eq!(ta, tb, "{kind:?} lane {lane} diverged at step {step}");
            }
        }
        b.materialize_pending();
        for lane in 0..2 {
            assert_lane_state_equal(&a, &b, lane, lane, &format!("{kind:?} lane {lane}"));
        }
    }
}

/// A prompt restored from the prefix cache must continue bit-exactly
/// under a non-uniform plan: restore the retained pages into a fresh
/// lane, then drive the same planned policy on both the original and
/// the restored lane — identical streams, identical state.
#[test]
fn prefix_restore_bit_exact_under_nonuniform_plans() {
    let g = geom(64);
    let prompt = 17usize; // 2 clean pages of 8, 1 token to re-prefill
    let plan = build_allocator(AllocatorKind::Pyramid).plan(
        g.layers,
        g.kv_heads,
        10 * g.lh(),
        None,
    );

    // cold reference: straight prefill on lane 0
    let mut cold = store(g, 1);
    prefill_identity(&mut cold, 0, prompt);

    // warm path: prefill, export the clean prefix, recycle, restore
    // into the (now clean) lane, re-prefill the divergence tail. The
    // same lane index is reused so the deterministic per-(lane, pos)
    // α/attention streams match the cold reference exactly.
    let mut warm = store(g, 1);
    prefill_identity(&mut warm, 0, prompt);
    let n_pages = warm.clean_prefix_pages(0, prompt);
    assert_eq!(n_pages, 2);
    let ids: Vec<u64> = (0..n_pages).map(|p| warm.export_page(0, p)).collect();
    warm.recycle_lane(0);
    for &id in &ids {
        warm.retain_page(id);
    }
    warm.map_prefix_pages(0, &ids);
    warm.materialize_pending();
    // re-prefill tokens past the restored prefix (position 16)
    let payload: Vec<f32> = (0..g.head_dim).map(|d| 16.0 + d as f32 * 0.5).collect();
    for l in 0..g.layers {
        for h in 0..g.kv_heads {
            let s = warm.alloc_slot(0, l, h).unwrap();
            assert_eq!(s, 16, "restore resumes at the divergence point");
            warm.write(0, l, h, s, 16, &payload, &payload);
        }
    }
    assert_lane_state_equal(&cold, &warm, 0, 0, "restored prefix");

    let mut pol_cold = build_policy_planned(PolicyKind::Tova, plan.clone(), 4, g.page_size);
    let mut pol_warm = build_policy_planned(PolicyKind::Tova, plan, 4, g.page_size);
    pol_cold.post_prefill(&mut cold, 0, prompt);
    pol_warm.post_prefill(&mut warm, 0, prompt);
    for step in 0..25usize {
        let pos = prompt + step;
        warm.materialize_pending();
        let t_cold = drive_policy_step(&mut cold, 0, &mut pol_cold, pos);
        let t_warm = drive_policy_step(&mut warm, 0, &mut pol_warm, pos);
        assert_eq!(t_cold, t_warm, "restored stream diverged at step {step}");
    }
    assert_lane_state_equal(&cold, &warm, 0, 0, "post-decode restored lane");
    // release the index references so the pool drains
    warm.recycle_lane(0);
    for id in ids {
        warm.release_page(id);
    }
    assert_eq!(warm.pool_pages(), 0);
}
