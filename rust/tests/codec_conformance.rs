// Shared lint config for non-lib targets (benches/tests/examples are
// separate crates, so the crate-wide allows in rust/src/lib.rs do not
// reach them): the same flat-layout indexing idiom applies here, and
// vec! payloads deliberately mirror the engine's heap buffers.
// Correctness lints stay on — CI denies all remaining warnings via
// `cargo clippy --all-targets -- -D warnings`.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::manual_div_ceil,
    clippy::uninlined_format_args,
    clippy::useless_vec
)]

//! Codec conformance: the production [`VectorizedCodec`] is pinned
//! **bit-identical** to the frozen [`ScalarCodec`] reference (see the
//! codec contract in `docs/NUMERICS.md`):
//!
//! * identical code bytes, scale bit patterns, and zero-points on
//!   encode, across dtypes × geometries (hd16, hd64, odd row lengths
//!   for the q4 nibble tail, single-element rows) on randomized
//!   payloads — seeded by `PROP_SEED` like the other property suites;
//! * identical f32 bit patterns on decode of the same blocks;
//! * the same identity on the NaN / ±inf / subnormal edge-row matrix
//!   (the PR-6 non-finite contract), where the vectorized encoder's
//!   checked slow path takes over;
//! * at store level: the fused encode-on-publish / dequant-on-upload
//!   paths (chunked per-(layer, head) [`KvBlock::write_rows_from`] /
//!   `read_rows_into`, no staging copies) restore views bit-identical
//!   to the legacy copy-through pipeline (gather whole page → one
//!   [`QuantBlock::quantize`] → decode → copy).

use hyperscale::kvcache::{
    CacheStore, Codec, Geometry, KvDtype, QuantBlock, ScalarCodec, VectorizedCodec,
};
use hyperscale::util::SplitMix64;

/// Base seed for randomized property tests (see module docs).
fn prop_seed() -> u64 {
    match std::env::var("PROP_SEED") {
        Ok(s) => {
            let s = s.trim();
            let parsed = match s.strip_prefix("0x") {
                Some(hex) => u64::from_str_radix(hex, 16),
                None => s.parse(),
            };
            parsed.unwrap_or_else(|_| panic!("PROP_SEED must be an integer, got {s:?}"))
        }
        Err(_) => 0xDEFA_0175,
    }
}

/// Random payload with realistic spread; occasionally exact zeros and
/// exact-constant rows so the degenerate encodings are hit too.
fn random_rows(rng: &mut SplitMix64, rows: usize, row_len: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(rows * row_len);
    for _ in 0..rows {
        match rng.below(8) {
            0 => out.extend((0..row_len).map(|_| 0.0f32)),
            1 => {
                let c = (rng.f64() * 4.0 - 2.0) as f32;
                out.extend((0..row_len).map(|_| c));
            }
            _ => {
                for _ in 0..row_len {
                    out.push((rng.f64() * 8.0 - 4.0) as f32);
                }
            }
        }
    }
    out
}

/// Assert both codecs encode `src` to byte-identical blocks and decode
/// those blocks to bit-identical f32.
fn assert_bit_identical(dtype: KvDtype, rows: usize, row_len: usize, src: &[f32], ctx: &str) {
    let a = QuantBlock::quantize_with(&ScalarCodec, dtype, rows, row_len, src);
    let b = QuantBlock::quantize_with(&VectorizedCodec, dtype, rows, row_len, src);
    assert_eq!(a.codes(), b.codes(), "{ctx} {dtype}: code bytes diverge");
    for r in 0..rows {
        assert_eq!(
            a.row_scale(r).to_bits(),
            b.row_scale(r).to_bits(),
            "{ctx} {dtype}: row {r} scale bits diverge"
        );
        assert_eq!(a.row_zp(r), b.row_zp(r), "{ctx} {dtype}: row {r} zero-point diverges");
    }
    // decode the scalar-encoded block with both decoders: the byte
    // streams are equal, so this pins the decode side independently
    let stride = dtype.row_code_bytes(row_len);
    let scales: Vec<f32> = (0..rows).map(|r| a.row_scale(r)).collect();
    let zps: Vec<u8> = (0..rows).map(|r| a.row_zp(r)).collect();
    let mut dec_s = vec![0f32; rows * row_len];
    let mut dec_v = vec![0f32; rows * row_len];
    assert_eq!(a.codes().len(), rows * stride);
    ScalarCodec.decode_rows_into(dtype, rows, row_len, a.codes(), &scales, &zps, &mut dec_s);
    VectorizedCodec.decode_rows_into(dtype, rows, row_len, a.codes(), &scales, &zps, &mut dec_v);
    let bits = |xs: &[f32]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&dec_s), bits(&dec_v), "{ctx} {dtype}: decoded f32 bits diverge");
}

#[test]
fn random_payloads_are_bit_identical_across_geometries() {
    let base = prop_seed();
    // (rows, row_len): page-shaped hd16/hd64, the odd-row-length q4
    // nibble tail, single-element rows, and a LANES-straddling width
    let geometries = [(64, 16), (32, 64), (5, 7), (9, 1), (11, 13), (3, 9)];
    for (case, &(rows, row_len)) in geometries.iter().enumerate() {
        let mut rng = SplitMix64::new(base ^ (case as u64).wrapping_mul(0x9E37_79B9));
        for dtype in [KvDtype::Q8, KvDtype::Q4] {
            let src = random_rows(&mut rng, rows, row_len);
            assert_bit_identical(dtype, rows, row_len, &src, &format!("{rows}x{row_len}"));
        }
    }
}

#[test]
fn edge_rows_are_bit_identical() {
    // the PR-6 non-finite matrix: NaN / ±inf amid spread, rows with no
    // finite values, constant rows with junk, subnormal spreads — the
    // exact rows docs/NUMERICS.md defines decode semantics for
    let rl = 6;
    let rows: Vec<[f32; 6]> = vec![
        [1.0, f32::NAN, -2.0, 0.5, 0.0, 1.5],
        [0.25, f32::INFINITY, 1.0, 0.75, 0.5, 0.125],
        [f32::NEG_INFINITY, -0.5, -1.0, -0.25, 0.0, -2.0],
        [f32::NAN; 6],
        [f32::INFINITY; 6],
        [f32::NEG_INFINITY; 6],
        [2.5, f32::INFINITY, 2.5, f32::NAN, 2.5, 2.5],
        [-1.75, f32::INFINITY, -1.75, -1.75, f32::NEG_INFINITY, -1.75],
        [0.0, 1.0e-41, -1.0e-41, 7.0e-40, 0.0, -3.0e-40],
        [f32::MIN_POSITIVE; 6],
        [0.0, -0.0, 0.0, -0.0, 0.0, -0.0],
    ];
    let src: Vec<f32> = rows.iter().flatten().copied().collect();
    for dtype in [KvDtype::Q8, KvDtype::Q4] {
        assert_bit_identical(dtype, rows.len(), rl, &src, "edge");
    }
    // the q4 nibble tail with an edge value as the odd trailing element
    let odd = [1.0f32, f32::NAN, -2.0, 0.5, f32::INFINITY];
    for dtype in [KvDtype::Q8, KvDtype::Q4] {
        assert_bit_identical(dtype, 1, 5, &odd, "odd-tail");
    }
}

/// Blocks that interleave NaN-free rows (the vectorized encoder's
/// branch-free fast path) with NaN-carrying rows (its checked slow
/// path) must still match the reference row for row — the path switch
/// is per-row and must never bleed across rows.
#[test]
fn interleaved_nan_rows_switch_paths_without_divergence() {
    let mut rng = SplitMix64::new(prop_seed() ^ 0xA55A_F00D);
    let (rows, row_len) = (24usize, 16usize);
    for dtype in [KvDtype::Q8, KvDtype::Q4] {
        let mut src = random_rows(&mut rng, rows, row_len);
        for r in 0..rows {
            if r % 3 == 1 {
                // poison one element of every third row
                src[r * row_len + rng.below(row_len)] = f32::NAN;
            }
        }
        assert_bit_identical(dtype, rows, row_len, &src, "interleaved-nan");
    }
}

// ----------------------------------------------------------------------
// Store level: fused publish/upload vs the legacy copy-through path
// ----------------------------------------------------------------------

fn geom() -> Geometry {
    Geometry {
        layers: 2,
        kv_heads: 2,
        slots: 64,
        head_dim: 16,
        page_size: 8,
    }
}

/// Identity-layout prefill of `n` tokens on `lane`, position-derived
/// payloads.
fn prefill(c: &mut CacheStore, lane: usize, n: usize) {
    let g = c.geom;
    for pos in 0..n {
        let k: Vec<f32> = (0..g.head_dim)
            .map(|d| (pos as f32) * 0.31 + (d as f32) * 0.07 - 1.5)
            .collect();
        let v: Vec<f32> = k.iter().map(|x| x * 0.5 + 0.125).collect();
        for l in 0..g.layers {
            for h in 0..g.kv_heads {
                let s = c.alloc_slot(lane, l, h).unwrap();
                c.write(lane, l, h, s, pos, &k, &v);
            }
        }
    }
}

/// Gather the raw f32 rows of one lane page in pool-snapshot order
/// ((layer, head)-major, then slot within the page) — exactly what the
/// legacy publish path staged into a scratch vec before quantizing.
fn gather_page(c: &CacheStore, lane: usize, page: usize, value_side: bool) -> Vec<f32> {
    let g = c.geom;
    let ps = g.page_size;
    let mut out = Vec::with_capacity(g.lh() * ps * g.head_dim);
    for l in 0..g.layers {
        for h in 0..g.kv_heads {
            for s in page * ps..(page + 1) * ps {
                let row = if value_side {
                    c.v_at(lane, l, h, s)
                } else {
                    c.k_at(lane, l, h, s)
                };
                out.extend_from_slice(row);
            }
        }
    }
    out
}

/// The fused publish (chunked per-(l, h) encode straight from lane
/// f32, no staging vec) and the fused upload (decode straight into the
/// lane region, no staging vec) must restore views bit-identical to
/// the legacy pipeline: gather page → whole-block quantize → decode →
/// copy. Row independence of the codec is what makes the chunked
/// encode equivalent; this pins it through the real store entry
/// points.
#[test]
fn fused_publish_and_upload_match_copy_through_pipeline() {
    let g = geom();
    for dtype in [KvDtype::Q8, KvDtype::Q4] {
        let mut c = CacheStore::with_dtype(g, 2, dtype);
        prefill(&mut c, 0, 2 * g.page_size); // two full pages
        // an eviction hole mid-page: publish gathers raw rows
        // regardless of slot state, on both the old and new paths
        c.evict(0, 0, 1, 3);

        // legacy reference, built BEFORE export mutates anything:
        // gather → one whole-block quantize → decode
        let ps = g.page_size;
        let rows = g.lh() * ps;
        let mut reference = Vec::new(); // per (page, side): decoded f32
        for page in 0..2 {
            for side in [false, true] {
                let staged = gather_page(&c, 0, page, side);
                let block = QuantBlock::quantize(dtype, rows, g.head_dim, &staged);
                let mut dec = vec![0f32; rows * g.head_dim];
                block.dequantize_rows_into(0, rows, &mut dec);
                reference.push(dec);
            }
        }

        // the real store path: fused encode on export, fused decode on
        // materialize
        let ids: Vec<u64> = (0..2).map(|p| c.export_page(0, p)).collect();
        c.recycle_lane(0);
        c.map_prefix_pages(1, &ids);
        c.materialize_pending();

        for page in 0..2 {
            for (si, side) in [false, true].iter().enumerate() {
                let dec = &reference[page * 2 + si];
                for l in 0..g.layers {
                    for h in 0..g.kv_heads {
                        for s in page * ps..(page + 1) * ps {
                            let lh_i = l * g.kv_heads + h;
                            let r = lh_i * ps + (s - page * ps);
                            let want = &dec[r * g.head_dim..(r + 1) * g.head_dim];
                            let got = if *side {
                                c.v_at(1, l, h, s)
                            } else {
                                c.k_at(1, l, h, s)
                            };
                            let side_name = if *side { "v" } else { "k" };
                            assert_eq!(
                                got.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                                want.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                                "{dtype}: fused {side_name} restore diverges from the \
                                 copy-through pipeline at (l {l}, h {h}, slot {s})"
                            );
                        }
                    }
                }
            }
        }
        c.recycle_lane(1);
        assert_eq!(c.pool_pages(), 0);
    }
}

/// The f32 store's fused copy path is exact end to end (no codec in
/// the loop): restored bytes equal the original lane bytes.
#[test]
fn fused_f32_restore_is_exact() {
    let g = geom();
    let mut c = CacheStore::new(g, 2);
    prefill(&mut c, 0, g.page_size);
    let before = gather_page(&c, 0, 0, false);
    let before_v = gather_page(&c, 0, 0, true);
    let id = c.export_page(0, 0);
    c.recycle_lane(0);
    c.map_prefix_pages(1, &[id]);
    c.materialize_pending();
    assert_eq!(gather_page(&c, 1, 0, false), before);
    assert_eq!(gather_page(&c, 1, 0, true), before_v);
    c.recycle_lane(1);
}
