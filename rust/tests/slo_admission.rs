// Shared lint config for non-lib targets (benches/tests/examples are
// separate crates, so the crate-wide allows in rust/src/lib.rs do not
// reach them): the same flat-layout indexing idiom applies here, and
// vec! payloads deliberately mirror the engine's heap buffers.
// Correctness lints stay on — CI denies all remaining warnings via
// `cargo clippy --all-targets -- -D warnings`.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::manual_div_ceil,
    clippy::uninlined_format_args,
    clippy::useless_vec
)]

//! Property tests for SLO-aware admission control and EDF dispatch
//! (`engine::slo`, `engine::workload`, the scheduler's
//! `AdmissionPolicy::Edf`, and `timeflow::simulate_slo`): invariants
//! that must hold for *every* seed, checked over randomized streams
//! derived from a base seed.
//!
//! The load-bearing properties:
//!
//! 1. **Conservation**: at every offer, accepted + queued + rejected
//!    equals requests submitted — the controller never loses or
//!    double-counts a request, and the end-to-end sim settles every
//!    arrival (rejects included).
//! 2. **Utilization cap**: the accepted set's analytic utilization
//!    never exceeds 1, at every step of every stream.
//! 3. **EDF dispatch order**: the scheduler pops pending chains in
//!    `(deadline, ticket, chain_idx)` order, with unstamped requests
//!    (deadline `u64::MAX`) sorting last.
//! 4. **No cross-tier inversion**: preemption never victimizes a lane
//!    serving a stricter tier than the strictest pending beneficiary.
//! 5. **Determinism**: same-seed workload streams and SLO sim runs are
//!    bit-identical, trace dumps included.
//!
//! The base seed comes from `PROP_SEED` (decimal or 0x-hex) so the CI
//! seed-matrix leg can re-run the whole suite under several fixed
//! seeds; unset, it defaults to a fixed value for day-to-day runs.

use std::sync::Arc;

use hyperscale::compress::{build_policy, AllocatorKind, PolicyKind};
use hyperscale::config::RoutingPolicy;
use hyperscale::engine::{
    generate_mixed_workload, simulate_slo, slo_requests, AdmissionController, AdmissionPolicy,
    ArrivalKind, ChainState, CostModel, GenRequest, Scheduler, SchedulerConfig, SloPolicy,
    SloTier, TimeflowConfig, WorkloadConfig,
};
use hyperscale::kvcache::KvDtype;
use hyperscale::util::SplitMix64;

/// Base seed for randomized property tests (see module docs).
fn prop_seed() -> u64 {
    match std::env::var("PROP_SEED") {
        Ok(s) => {
            let s = s.trim();
            let parsed = match s.strip_prefix("0x") {
                Some(hex) => u64::from_str_radix(hex, 16),
                None => s.parse(),
            };
            parsed.unwrap_or_else(|_| panic!("PROP_SEED must be an integer, got {s:?}"))
        }
        Err(_) => 0x5105_EED,
    }
}

fn sched_req(width: usize, max_len: usize, seed: u64) -> GenRequest {
    GenRequest {
        prompt: String::new(),
        width,
        max_len,
        temperature: 0.5,
        seed,
    }
}

fn policy(max_len: usize) -> Box<dyn hyperscale::compress::Policy> {
    build_policy(PolicyKind::Vanilla, 1.0, max_len, 4, 8)
}

fn edf_scheduler(lanes: usize, watermark: Option<f64>) -> Scheduler {
    Scheduler::new(
        lanes,
        SchedulerConfig {
            admission: AdmissionPolicy::Edf,
            preempt_watermark: watermark,
        },
    )
}

/// A randomized-but-seeded mixed workload config.
fn random_workload(rng: &mut SplitMix64) -> WorkloadConfig {
    let mut cfg = WorkloadConfig::new(128 + rng.below(384), rng.next_u64());
    cfg.arrival = *rng.choice(&ArrivalKind::ALL);
    // from well under to well over modeled capacity
    cfg.mean_gap_ns = 20_000 + rng.below(2_000_000) as u64;
    cfg.n_prompts = 1 + rng.below(48);
    cfg
}

// ----------------------------------------------------------------------
// Controller-level: conservation + utilization cap at every step
// ----------------------------------------------------------------------

#[test]
fn admission_conserves_and_caps_utilization_at_every_step() {
    let mut rng = SplitMix64::new(prop_seed() ^ 0xAD);
    for scenario in 0..8 {
        let dtype = *rng.choice(&[KvDtype::F32, KvDtype::Q8, KvDtype::Q4]);
        let cost = CostModel::default_for(dtype, AllocatorKind::Uniform);
        let capacity = cost.kv_bytes_per_token * (64 + rng.below(4096)) as u64;
        let mut ctl = AdmissionController::new(capacity, cost);
        let mut now = 0u64;
        for step in 0..400u64 {
            now += rng.below(500_000) as u64; // nondecreasing arrivals
            let prompt = 1 + rng.below(768);
            let gen = 1 + rng.below(96);
            ctl.offer(now, prompt, gen);
            assert_eq!(
                ctl.offered(),
                step + 1,
                "scenario {scenario} step {step}: offers lost or duplicated"
            );
            assert_eq!(
                ctl.accepted() + ctl.queued() + ctl.rejected(),
                ctl.offered(),
                "scenario {scenario} step {step}: decisions must partition offers"
            );
            assert!(
                ctl.utilization() <= 1.0,
                "scenario {scenario} step {step}: utilization {} > 1",
                ctl.utilization()
            );
        }
        assert!(ctl.accepted() > 0, "scenario {scenario}: nothing admitted (vacuous)");
    }
}

#[test]
fn quantized_demand_admits_at_least_as_much_on_every_stream() {
    // the hyper-scaling dividend as a property: at the same byte
    // capacity, a strictly smaller per-token demand can never admit
    // *less* of the same stream (same windows, smaller bytes)
    let mut rng = SplitMix64::new(prop_seed() ^ 0xD1F1);
    for _ in 0..6 {
        let wcfg = random_workload(&mut rng);
        let reqs = slo_requests(&generate_mixed_workload(&wcfg));
        let f32_cost = CostModel::default_for(KvDtype::F32, AllocatorKind::Uniform);
        let q4_cost = CostModel::default_for(KvDtype::Q4, AllocatorKind::Uniform);
        let capacity = f32_cost.kv_bytes_per_token * (256 + rng.below(2048)) as u64;
        let mut f32_ctl = AdmissionController::new(capacity, f32_cost);
        let mut q4_ctl = AdmissionController::new(capacity, q4_cost);
        for r in &reqs {
            f32_ctl.offer(r.sim.arrival_ns, r.sim.prompt_tokens, r.sim.gen_tokens);
            q4_ctl.offer(r.sim.arrival_ns, r.sim.prompt_tokens, r.sim.gen_tokens);
        }
        assert!(
            q4_ctl.accepted() >= f32_ctl.accepted(),
            "[{}] q4 admitted {} < f32 {} at equal capacity",
            wcfg.arrival.name(),
            q4_ctl.accepted(),
            f32_ctl.accepted()
        );
    }
}

// ----------------------------------------------------------------------
// Scheduler-level: EDF dispatch order + cross-tier preemption rule
// ----------------------------------------------------------------------

#[test]
fn edf_admission_pops_in_deadline_then_ticket_order() {
    let mut rng = SplitMix64::new(prop_seed() ^ 0xEDF);
    for scenario in 0..6 {
        let mut s = edf_scheduler(1, None);
        let ids = Arc::new(vec![1u32; 4]);
        let n = 8 + rng.below(24);
        let mut expect: Vec<(u64, u64)> = Vec::new();
        for i in 0..n {
            let t = s.submit(&sched_req(1, 24, i as u64), ids.clone());
            // a quarter stay unstamped: deadline u64::MAX, sorted last
            let deadline = if rng.below(4) == 0 {
                u64::MAX
            } else {
                let tier = *rng.choice(&SloTier::ALL);
                let d = rng.below(1_000_000) as u64 * 1_000;
                s.assign_slo(t, tier, d);
                d
            };
            expect.push((deadline, t));
        }
        expect.sort_unstable();
        let mut got = Vec::new();
        while let Some(p) = s.next_admission() {
            got.push((p.deadline_ns, p.ticket));
        }
        assert_eq!(
            got, expect,
            "scenario {scenario}: EDF must dispatch by (deadline, ticket)"
        );
    }
}

#[test]
fn preemption_never_victimizes_a_stricter_tier() {
    // seed-dependent scenarios may legitimately decline to preempt
    // (EDF's would-benefit check); the deterministic anchors in
    // `cross_tier_preemption_only_flows_downward` keep the property
    // non-vacuous under every seed.
    let mut rng = SplitMix64::new(prop_seed() ^ 0x9EE);
    for scenario in 0..12 {
        let lanes = 1 + rng.below(3);
        let mut s = edf_scheduler(lanes, Some(0.5));
        let ids = Arc::new(vec![1u32; 4]);
        // fill every lane with a random-tier chain (tier read back from
        // the popped pending chain, since EDF reorders the queue)
        let mut lane_tiers: Vec<SloTier> = Vec::new();
        for lane in 0..lanes {
            let t = s.submit(&sched_req(1, 24, lane as u64), ids.clone());
            s.assign_slo(t, *rng.choice(&SloTier::ALL), 10_000 + rng.below(1 << 20) as u64);
            let p = s.next_admission().unwrap();
            let tier = p.tier;
            s.install(lane, ChainState::new(p, policy(24), 0));
            lane_tiers.push(tier);
        }
        // queue pending beneficiaries; unstamped ones default Standard
        let n = 1 + rng.below(6);
        let mut pending_tiers: Vec<SloTier> = Vec::new();
        for i in 0..n {
            let t = s.submit(&sched_req(1, 24, 100 + i as u64), ids.clone());
            if rng.below(4) != 0 {
                let tier = *rng.choice(&SloTier::ALL);
                s.assign_slo(t, tier, rng.below(1 << 21) as u64);
                pending_tiers.push(tier);
            } else {
                pending_tiers.push(SloTier::Standard);
            }
        }
        let strictest = *pending_tiers.iter().min().unwrap();
        if let Some(lane) = s.maybe_preempt(1.0) {
            assert!(
                lane_tiers[lane] >= strictest,
                "scenario {scenario}: preempted a {:?} lane to benefit a {strictest:?} \
                 beneficiary",
                lane_tiers[lane]
            );
        }
    }
}

#[test]
fn cross_tier_preemption_only_flows_downward() {
    // deterministic anchors for the tier rule, independent of seed
    let ids = Arc::new(vec![1u32; 4]);

    // batch on the lane, interactive waiting: the batch lane yields
    let mut s = edf_scheduler(1, Some(0.5));
    let t = s.submit(&sched_req(1, 24, 1), ids.clone());
    s.assign_slo(t, SloTier::Batch, 2_500_000_000);
    let p = s.next_admission().unwrap();
    s.install(0, ChainState::new(p, policy(24), 0));
    let t = s.submit(&sched_req(1, 24, 2), ids.clone());
    s.assign_slo(t, SloTier::Interactive, 50_000_000);
    assert_eq!(
        s.maybe_preempt(1.0),
        Some(0),
        "an interactive arrival must preempt the batch lane"
    );

    // interactive on the lane, batch waiting: never preempted
    let mut s = edf_scheduler(1, Some(0.5));
    let t = s.submit(&sched_req(1, 24, 1), ids.clone());
    s.assign_slo(t, SloTier::Interactive, 50_000_000);
    let p = s.next_admission().unwrap();
    s.install(0, ChainState::new(p, policy(24), 0));
    let t = s.submit(&sched_req(1, 24, 2), ids.clone());
    s.assign_slo(t, SloTier::Batch, 2_500_000_000);
    assert_eq!(
        s.maybe_preempt(1.0),
        None,
        "a batch arrival must never preempt an interactive lane"
    );
    assert_eq!(s.preemptions(), 0);
}

// ----------------------------------------------------------------------
// End-to-end: sim conservation + same-seed bit-identity
// ----------------------------------------------------------------------

#[test]
fn sim_settles_every_arrival_rejects_included() {
    let mut rng = SplitMix64::new(prop_seed() ^ 0x51AD);
    for scenario in 0..4 {
        let wcfg = random_workload(&mut rng);
        let reqs = slo_requests(&generate_mixed_workload(&wcfg));
        let replicas = 1 + rng.below(4);
        let lanes = 1 + rng.below(3);
        let cfg = TimeflowConfig::new(replicas, lanes, RoutingPolicy::RoundRobin);
        let mut rep = simulate_slo(&cfg, &reqs, &SloPolicy::edf_admitted(replicas, lanes));
        let accepted = rep.registry.counter("serve.slo_accepted").get();
        let queued = rep.registry.counter("serve.slo_queued").get();
        let rejected = rep.registry.counter("serve.slo_rejected").get();
        assert_eq!(
            accepted + queued + rejected,
            reqs.len() as f64,
            "scenario {scenario} [{}]: admission decisions must cover every arrival",
            rep.label
        );
        assert_eq!(
            rep.completed as f64 + rejected,
            reqs.len() as f64,
            "scenario {scenario} [{}]: rejects settle, everything else completes",
            rep.label
        );
        // goodput never counts more tokens than were generated
        let good = rep.registry.counter("serve.slo_goodput_tokens").get();
        assert!(
            good <= rep.gen_tokens as f64,
            "scenario {scenario}: goodput {good} > generated {}",
            rep.gen_tokens
        );
    }
}

#[test]
fn same_seed_slo_streams_and_sims_are_bit_identical() {
    let mut rng = SplitMix64::new(prop_seed() ^ 0xB175);
    for scenario in 0..4 {
        let wcfg = random_workload(&mut rng);
        let a = generate_mixed_workload(&wcfg);
        let b = generate_mixed_workload(&wcfg);
        assert_eq!(a, b, "scenario {scenario}: workload stream diverged");

        let reqs = slo_requests(&a);
        let mut cfg = TimeflowConfig::new(2, 2, RoutingPolicy::RoundRobin);
        cfg.record_trace = true;
        let policy = SloPolicy::edf_admitted(2, 2);
        let ra = simulate_slo(&cfg, &reqs, &policy);
        let rb = simulate_slo(&cfg, &reqs, &policy);
        assert_eq!(ra.completions, rb.completions, "scenario {scenario}");
        assert_eq!(
            ra.slo_goodput_tokens_per_s.to_bits(),
            rb.slo_goodput_tokens_per_s.to_bits(),
            "scenario {scenario}"
        );
        assert_eq!(
            ra.chrome_trace_json(),
            rb.chrome_trace_json(),
            "scenario {scenario} [{}]: trace dumps diverged between identical runs",
            ra.label
        );
    }
}
