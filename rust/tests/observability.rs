// Shared lint config for non-lib targets (benches/tests/examples are
// separate crates, so the crate-wide allows in rust/src/lib.rs do not
// reach them): the same flat-layout indexing idiom applies here, and
// vec! payloads deliberately mirror the engine's heap buffers.
// Correctness lints stay on — CI denies all remaining warnings via
// `cargo clippy --all-targets -- -D warnings`.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::manual_div_ceil,
    clippy::uninlined_format_args,
    clippy::useless_vec
)]

//! Observability integration tests (`docs/OBSERVABILITY.md`): the
//! flight recorder's determinism contract, the trace JSON schema
//! round-trip, per-request memory-read pricing, and the Prometheus
//! exposition grammar for both the single-registry and the merged
//! multi-replica renderings.
//!
//! The base seed comes from `PROP_SEED` (decimal or 0x-hex) so the CI
//! seed-matrix leg can re-run the whole suite under several fixed
//! seeds; unset, it defaults to a fixed value for day-to-day runs.

use std::collections::BTreeMap;

use hyperscale::config::RoutingPolicy;
use hyperscale::engine::timeflow::{simulate, TimeflowConfig, WorkloadSpec};
use hyperscale::engine::{GenRequest, SimEngine, SimEngineConfig};
use hyperscale::metrics::prometheus_merge;
use hyperscale::trace::{chrome_trace_json, Stamped, TraceEvent};
use hyperscale::util::{Json, SplitMix64};

/// Base seed for randomized property tests (see module docs).
fn prop_seed() -> u64 {
    match std::env::var("PROP_SEED") {
        Ok(s) => {
            let s = s.trim();
            let parsed = match s.strip_prefix("0x") {
                Some(hex) => u64::from_str_radix(hex, 16),
                None => s.parse(),
            };
            parsed.unwrap_or_else(|_| panic!("PROP_SEED must be an integer, got {s:?}"))
        }
        Err(_) => 0xDEFA_0175,
    }
}

/// A seeded request mix: random prompt bodies, widths 1–2.
fn sim_workload(rng: &mut SplitMix64, n: usize) -> Vec<GenRequest> {
    (0..n)
        .map(|_| {
            let body: String = (0..(8 + rng.below(24)))
                .map(|_| (b'a' + rng.below(26) as u8) as char)
                .collect();
            GenRequest {
                prompt: format!("Q:{body}|T:"),
                width: 1 + rng.below(2),
                max_len: 96,
                temperature: 0.7,
                seed: rng.next_u64(),
            }
        })
        .collect()
}

/// Run `reqs` through a traced 2-lane sim engine; trace ids are
/// `1000 + submission index` (the client-visible id convention).
fn run_traced(reqs: &[GenRequest]) -> SimEngine {
    let mut e = SimEngine::new(SimEngineConfig {
        lanes: 2,
        trace_events: 4096,
        ..Default::default()
    });
    for (i, r) in reqs.iter().enumerate() {
        e.submit_traced(r, Some(1000 + i as u64)).expect("submit");
    }
    e.drain().expect("drain");
    e
}

/// Minimal Prometheus text-exposition (0.0.4) grammar check: every
/// family has exactly one `# TYPE` line, every sample line references
/// a declared family (directly or via `_sum` / `_count`), and every
/// sample value parses as a float.
fn assert_valid_exposition(text: &str) {
    let mut families: BTreeMap<&str, &str> = BTreeMap::new();
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let name = it.next().expect("family name");
            let kind = it.next().expect("family kind");
            assert!(
                matches!(kind, "counter" | "gauge" | "summary" | "histogram"),
                "unknown family kind in {line:?}"
            );
            assert!(
                families.insert(name, kind).is_none(),
                "duplicate TYPE line for {name}"
            );
        } else if !line.starts_with('#') && !line.is_empty() {
            let name_end = line.find(|c| c == '{' || c == ' ').unwrap_or(line.len());
            let name = &line[..name_end];
            let base = name
                .strip_suffix("_sum")
                .or_else(|| name.strip_suffix("_count"))
                .unwrap_or(name);
            assert!(
                families.contains_key(base) || families.contains_key(name),
                "sample {name} has no TYPE line"
            );
            let value = line.rsplit(' ').next().unwrap();
            assert!(
                value.parse::<f64>().is_ok(),
                "sample value does not parse in {line:?}"
            );
        }
    }
    assert!(!families.is_empty(), "empty exposition");
}

#[test]
fn same_seed_sim_engine_trace_streams_are_bit_identical() {
    let base = prop_seed();
    for case in 0..4u64 {
        let mk = || {
            let mut rng = SplitMix64::new(base ^ case.wrapping_mul(0x9E37_79B9));
            let reqs = sim_workload(&mut rng, 6);
            run_traced(&reqs)
        };
        let (a, b) = (mk(), mk());
        let (ea, eb) = (a.tracer().events(), b.tracer().events());
        assert!(!ea.is_empty(), "case {case}: no events recorded");
        assert_eq!(ea, eb, "case {case}: same seed must yield same stream");
        // and the serialized dump is byte-identical, which is what the
        // CI double-run asserts with cmp
        assert_eq!(
            chrome_trace_json(&[(0, ea)]),
            chrome_trace_json(&[(0, eb)]),
            "case {case}"
        );
    }
}

#[test]
fn recorded_stream_round_trips_through_json() {
    let mut rng = SplitMix64::new(prop_seed());
    let e = run_traced(&sim_workload(&mut rng, 6));
    let events = e.tracer().events();
    assert!(!events.is_empty());
    for s in &events {
        let line = s.to_json().to_string();
        let back = Stamped::from_json(&Json::parse(&line).expect("valid JSON"))
            .unwrap_or_else(|| panic!("unparseable event line: {line}"));
        assert_eq!(&back, s);
    }
}

#[test]
fn every_request_finishes_with_priced_reads() {
    let mut rng = SplitMix64::new(prop_seed() ^ 0x0B5E);
    let reqs = sim_workload(&mut rng, 6);
    let e = run_traced(&reqs);
    let bpt = e.kv_bytes_per_token();
    assert!(bpt > 0.0);
    for i in 0..reqs.len() as u64 {
        let evs = e.trace_events_for(1000 + i);
        let names: Vec<&str> = evs.iter().map(|s| s.event.name()).collect();
        assert_eq!(names.first().copied(), Some("submit"), "req {i}: {names:?}");
        assert_eq!(names.last().copied(), Some("finish"), "req {i}: {names:?}");
        match evs.last().unwrap().event {
            TraceEvent::Finish {
                read_tokens,
                read_bytes,
                ..
            } => {
                assert!(read_tokens > 0.0, "req {i} read nothing");
                // priced with the same multiplication the engine uses
                assert_eq!(read_bytes, read_tokens * bpt, "req {i}");
            }
            ref other => panic!("req {i}: expected finish, got {other:?}"),
        }
    }
}

#[test]
fn prometheus_exposition_is_well_formed_and_merges() {
    let mut rng = SplitMix64::new(prop_seed() ^ 0x9305);
    let e = run_traced(&sim_workload(&mut rng, 4));
    let text = e.metrics.prometheus(None);
    assert_valid_exposition(&text);
    for family in ["kv_read_tokens", "kv_read_bytes", "serve_kv_read_tokens"] {
        assert!(
            text.contains(&format!("# TYPE {family}")),
            "missing family {family} in exposition"
        );
    }
    // the merged multi-replica rendering must stay grammatical: one
    // TYPE line per family, every sample labeled with its replica
    let blocks = vec![
        ("0".to_string(), e.metrics.to_json()),
        ("1".to_string(), e.metrics.to_json()),
    ];
    let merged = prometheus_merge("replica", &blocks);
    assert_valid_exposition(&merged);
    assert!(merged.contains("replica=\"0\"") && merged.contains("replica=\"1\""));
}

#[test]
fn timeflow_same_seed_chrome_dump_is_byte_identical() {
    let mut cfg = TimeflowConfig::new(3, 2, RoutingPolicy::Prefix);
    cfg.record_trace = true;
    let spec = WorkloadSpec::new(256, prop_seed());
    let a = simulate(&cfg, &spec).chrome_trace_json();
    let b = simulate(&cfg, &spec).chrome_trace_json();
    assert_eq!(a, b, "sim time makes the dump a pure function of the seed");
    let j = Json::parse(&a).expect("valid JSON");
    assert!(!j.get("traceEvents").unwrap().as_arr().unwrap().is_empty());
}
