//! Property-based tests on coordinator invariants (hand-rolled
//! generators — proptest is unavailable offline). Random operation
//! sequences against the paged KV cache and the eviction policies must
//! preserve the structural invariants the engine relies on.

use hyperscale::compress::{build_policy, PolicyKind, StepView, WriteAction};
use hyperscale::kvcache::{CacheStore, Geometry, SlotState};
use hyperscale::util::SplitMix64;

fn geom(slots: usize) -> Geometry {
    Geometry {
        layers: 2,
        kv_heads: 2,
        slots,
        head_dim: 4,
        page_size: 8,
    }
}

/// live-count bookkeeping == mask zeros == allocator occupancy.
fn check_consistency(c: &CacheStore, b: usize) {
    let g = c.geom;
    for l in 0..g.layers {
        for h in 0..g.kv_heads {
            let live = c.live_count(b, l, h);
            let mask_live = (0..g.slots)
                .filter(|&s| c.mask_value(b, l, h, s) == 0.0)
                .count();
            let meta_live = (0..g.slots)
                .filter(|&s| matches!(c.slot_state(b, l, h, s), SlotState::Live { .. }))
                .count();
            assert_eq!(live, mask_live, "mask desync at ({l},{h})");
            assert_eq!(live, meta_live, "meta desync at ({l},{h})");
        }
    }
}

#[test]
fn random_alloc_write_evict_sequences_stay_consistent() {
    for seed in 0..20u64 {
        let mut rng = SplitMix64::new(seed);
        let g = geom(32);
        let mut c = CacheStore::new(g, 2);
        let k = vec![1.0f32; g.head_dim];
        let v = vec![2.0f32; g.head_dim];
        for step in 0..300 {
            let b = rng.below(2);
            let l = rng.below(g.layers);
            let h = rng.below(g.kv_heads);
            match rng.below(5) {
                0 | 1 => {
                    if let Some(s) = c.alloc_slot(b, l, h) {
                        c.write(b, l, h, s, step, &k, &v);
                        if rng.below(3) == 0 {
                            c.schedule_eviction(b, l, h, s, step + rng.below(8));
                        }
                    }
                }
                2 => {
                    let live = c.live_slots(b, l, h);
                    if !live.is_empty() {
                        let (s, _) = live[rng.below(live.len())];
                        c.evict(b, l, h, s);
                    }
                }
                3 => c.apply_due_evictions(b, step),
                _ => {
                    c.merge_into_last(b, l, h, &k, &v);
                }
            }
            if step % 37 == 0 {
                check_consistency(&c, 0);
                check_consistency(&c, 1);
            }
        }
        check_consistency(&c, 0);
        check_consistency(&c, 1);
    }
}

#[test]
fn due_evictions_never_leave_overdue_entries() {
    let mut rng = SplitMix64::new(7);
    let g = geom(32);
    let mut c = CacheStore::new(g, 1);
    let k = vec![0.0f32; 4];
    for pos in 0..200usize {
        for l in 0..g.layers {
            for h in 0..g.kv_heads {
                c.apply_due_evictions(0, pos);
                if let Some(s) = c.alloc_slot(0, l, h) {
                    c.write(0, l, h, s, pos, &k, &k);
                    if rng.below(2) == 0 {
                        c.schedule_eviction(0, l, h, s, pos + 4);
                    }
                }
                // invariant: nothing live has evict_at <= pos
                for s in 0..g.slots {
                    if let SlotState::Live { evict_at, .. } = c.slot_state(0, l, h, s) {
                        assert!(
                            evict_at == u32::MAX || evict_at > pos as u32,
                            "overdue entry at pos {pos}"
                        );
                    }
                }
            }
        }
        if c.live_count(0, 0, 0) > 24 {
            c.reset_lane(0);
        }
    }
}

#[test]
fn fork_lane_is_deep_copy() {
    for seed in 0..10u64 {
        let mut rng = SplitMix64::new(seed);
        let g = geom(32);
        let mut c = CacheStore::new(g, 2);
        let mut payload = vec![0.0f32; 4];
        for pos in 0..rng.below(20) + 1 {
            payload[0] = pos as f32;
            for l in 0..g.layers {
                for h in 0..g.kv_heads {
                    if let Some(s) = c.alloc_slot(0, l, h) {
                        c.write(0, l, h, s, pos, &payload, &payload);
                    }
                }
            }
        }
        c.fork_lane(0, 1);
        // identical observable state
        for l in 0..g.layers {
            for h in 0..g.kv_heads {
                assert_eq!(c.live_count(0, l, h), c.live_count(1, l, h));
                for s in 0..g.slots {
                    assert_eq!(
                        c.mask_value(0, l, h, s),
                        c.mask_value(1, l, h, s)
                    );
                    assert_eq!(c.k_at(0, l, h, s), c.k_at(1, l, h, s));
                }
            }
        }
        // divergence after fork does not leak back
        let live = c.live_slots(1, 0, 0);
        if let Some(&(s, _)) = live.first() {
            c.evict(1, 0, 0, s);
            assert_eq!(c.live_count(0, 0, 0), live.len());
        }
        check_consistency(&c, 0);
        check_consistency(&c, 1);
    }
}

#[test]
fn budget_policies_never_exceed_budget() {
    for (kind, budget) in [
        (PolicyKind::Tova, 10usize),
        (PolicyKind::H2o, 10),
        (PolicyKind::Window, 10),
    ] {
        let mut rng = SplitMix64::new(11);
        let g = geom(64);
        let mut c = CacheStore::new(g, 1);
        // CR chosen so build_policy yields exactly `budget`
        let mut policy = build_policy(kind, 160.0 / budget as f64, 160, 4, 8);
        assert_eq!(policy.budget(), Some(budget));
        let k = vec![0.1f32; 4];
        let lh = g.lh();
        let alpha = vec![0.0f32; lh];
        let attn: Vec<f32> = (0..lh * g.slots)
            .map(|_| rng.f64() as f32)
            .collect();
        let attn_self = vec![0.0f32; lh];
        let mut actions: Vec<WriteAction> = Vec::new();
        let mut written = vec![None; lh];
        for pos in 0..50usize {
            c.apply_due_evictions(0, pos);
            policy.write_actions(&alpha, g.layers, g.kv_heads, &mut actions);
            for l in 0..g.layers {
                for h in 0..g.kv_heads {
                    let i = l * g.kv_heads + h;
                    written[i] = c.alloc_slot(0, l, h);
                    if let Some(s) = written[i] {
                        c.write(0, l, h, s, pos, &k, &k);
                    }
                }
            }
            policy.post_write(
                &mut c,
                &StepView {
                    lane: 0,
                    pos,
                    alpha: &alpha,
                    attn: &attn,
                    attn_self: &attn_self,
                    written: &written,
                },
            );
            for l in 0..g.layers {
                for h in 0..g.kv_heads {
                    assert!(
                        c.live_count(0, l, h) <= budget,
                        "{:?} exceeded budget at pos {pos}",
                        kind
                    );
                }
            }
        }
        check_consistency(&c, 0);
    }
}

#[test]
fn dms_policy_respects_window_exactly() {
    let g = geom(64);
    let mut c = CacheStore::new(g, 1);
    let window = 6usize;
    let mut policy = build_policy(PolicyKind::Dms, 4.0, 160, window, 8);
    let k = vec![0.0f32; 4];
    let lh = g.lh();
    let attn = vec![0.0f32; lh * g.slots];
    let mut actions: Vec<WriteAction> = Vec::new();
    let mut written = vec![None; lh];
    // evict-all alphas: every token scheduled out after `window`
    let alpha = vec![1.0f32; lh];
    for pos in 0..30usize {
        c.apply_due_evictions(0, pos);
        policy.write_actions(&alpha, g.layers, g.kv_heads, &mut actions);
        for l in 0..g.layers {
            for h in 0..g.kv_heads {
                let i = l * g.kv_heads + h;
                written[i] = c.alloc_slot(0, l, h);
                if let Some(s) = written[i] {
                    c.write(0, l, h, s, pos, &k, &k);
                }
            }
        }
        policy.post_write(
            &mut c,
            &StepView {
                lane: 0,
                pos,
                alpha: &alpha,
                attn: &attn,
                attn_self: &attn,
                written: &written,
            },
        );
        // steady state: exactly min(pos+1, window) tokens live
        let expect = (pos + 1).min(window);
        assert_eq!(c.live_count(0, 0, 0), expect, "pos {pos}");
    }
}

#[test]
fn dmc_merges_keep_cache_flat() {
    let g = geom(32);
    let mut c = CacheStore::new(g, 1);
    let mut policy = build_policy(PolicyKind::Dmc, 4.0, 160, 16, 8);
    let lh = g.lh();
    let mut actions: Vec<WriteAction> = Vec::new();
    let mut written = vec![None; lh];
    let k = vec![1.0f32; 4];
    // alternate merge/append decisions
    for pos in 0..40usize {
        let a = if pos % 2 == 0 { 0.9 } else { 0.1 };
        let alpha = vec![a; lh];
        policy.write_actions(&alpha, g.layers, g.kv_heads, &mut actions);
        for l in 0..g.layers {
            for h in 0..g.kv_heads {
                let i = l * g.kv_heads + h;
                written[i] = None;
                match actions[i] {
                    WriteAction::Merge => {
                        if !c.merge_into_last(0, l, h, &k, &k) {
                            let s = c.alloc_slot(0, l, h).unwrap();
                            c.write(0, l, h, s, pos, &k, &k);
                        }
                    }
                    WriteAction::Append => {
                        let s = c.alloc_slot(0, l, h).unwrap();
                        c.write(0, l, h, s, pos, &k, &k);
                        written[i] = Some(s);
                    }
                }
            }
        }
    }
    // half the tokens merged → about half the entries
    let live = c.live_count(0, 0, 0);
    assert!(live <= 21 && live >= 19, "live {live}");
    check_consistency(&c, 0);
}
