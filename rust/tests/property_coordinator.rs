// Shared lint config for non-lib targets (benches/tests/examples are
// separate crates, so the crate-wide allows in rust/src/lib.rs do not
// reach them): the same flat-layout indexing idiom applies here, and
// vec! payloads deliberately mirror the engine's heap buffers.
// Correctness lints stay on — CI denies all remaining warnings via
// `cargo clippy --all-targets -- -D warnings`.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::manual_div_ceil,
    clippy::uninlined_format_args,
    clippy::useless_vec
)]

//! Property-based tests on coordinator invariants (hand-rolled
//! generators — proptest is unavailable offline). Random operation
//! sequences against the paged KV cache and the eviction policies must
//! preserve the structural invariants the engine relies on; a
//! simulated executor drives the continuous-batching scheduler to check
//! admission ordering, lane recycling, fork promotion, preemption
//! resume, and that concurrent admission leaves per-chain token streams
//! identical to sequential runs.

use std::sync::Arc;

use hyperscale::compress::{build_policy, PolicyKind, StepView, WriteAction};
use hyperscale::engine::{
    AdmissionPolicy, ChainResult, ChainState, CompletedRequest, FinishReason, GenRequest,
    Phase, Scheduler, SchedulerConfig,
};
use hyperscale::kvcache::{CacheStore, Geometry, KvDtype, SlotState};
use hyperscale::util::SplitMix64;

fn geom(slots: usize) -> Geometry {
    Geometry {
        layers: 2,
        kv_heads: 2,
        slots,
        head_dim: 4,
        page_size: 8,
    }
}

/// Store constructor honoring the `KV_DTYPE` test-harness env knob:
/// the q8 CI leg re-runs this suite with quantized pool payloads, so
/// every COW publish / prefix export / restore below also exercises
/// the quantize/dequant boundary. Dtype never affects lane-local
/// metadata or refcounts — only pool payload encoding — so every
/// invariant here must hold under any dtype.
fn store(g: Geometry, lanes: usize) -> CacheStore {
    CacheStore::with_dtype(g, lanes, KvDtype::from_env())
}

/// live-count bookkeeping == mask zeros == allocator occupancy.
fn check_consistency(c: &CacheStore, b: usize) {
    let g = c.geom;
    for l in 0..g.layers {
        for h in 0..g.kv_heads {
            let live = c.live_count(b, l, h);
            let mask_live = (0..g.slots)
                .filter(|&s| c.mask_value(b, l, h, s) == 0.0)
                .count();
            let meta_live = (0..g.slots)
                .filter(|&s| matches!(c.slot_state(b, l, h, s), SlotState::Live { .. }))
                .count();
            assert_eq!(live, mask_live, "mask desync at ({l},{h})");
            assert_eq!(live, meta_live, "meta desync at ({l},{h})");
        }
    }
}

#[test]
fn random_alloc_write_evict_sequences_stay_consistent() {
    for seed in 0..20u64 {
        let mut rng = SplitMix64::new(seed);
        let g = geom(32);
        let mut c = store(g, 2);
        let k = vec![1.0f32; g.head_dim];
        let v = vec![2.0f32; g.head_dim];
        for step in 0..300 {
            let b = rng.below(2);
            let l = rng.below(g.layers);
            let h = rng.below(g.kv_heads);
            match rng.below(5) {
                0 | 1 => {
                    if let Some(s) = c.alloc_slot(b, l, h) {
                        c.write(b, l, h, s, step, &k, &v);
                        if rng.below(3) == 0 {
                            c.schedule_eviction(b, l, h, s, step + rng.below(8));
                        }
                    }
                }
                2 => {
                    let live = c.live_slots(b, l, h);
                    if !live.is_empty() {
                        let (s, _) = live[rng.below(live.len())];
                        c.evict(b, l, h, s);
                    }
                }
                3 => c.apply_due_evictions(b, step),
                _ => {
                    c.merge_into_last(b, l, h, &k, &v);
                }
            }
            if step % 37 == 0 {
                check_consistency(&c, 0);
                check_consistency(&c, 1);
            }
        }
        check_consistency(&c, 0);
        check_consistency(&c, 1);
    }
}

#[test]
fn due_evictions_never_leave_overdue_entries() {
    let mut rng = SplitMix64::new(7);
    let g = geom(32);
    let mut c = store(g, 1);
    let k = vec![0.0f32; 4];
    for pos in 0..200usize {
        for l in 0..g.layers {
            for h in 0..g.kv_heads {
                c.apply_due_evictions(0, pos);
                if let Some(s) = c.alloc_slot(0, l, h) {
                    c.write(0, l, h, s, pos, &k, &k);
                    if rng.below(2) == 0 {
                        c.schedule_eviction(0, l, h, s, pos + 4);
                    }
                }
                // invariant: nothing live has evict_at <= pos
                for s in 0..g.slots {
                    if let SlotState::Live { evict_at, .. } = c.slot_state(0, l, h, s) {
                        assert!(
                            evict_at == u32::MAX || evict_at > pos as u32,
                            "overdue entry at pos {pos}"
                        );
                    }
                }
            }
        }
        if c.live_count(0, 0, 0) > 24 {
            c.reset_lane(0);
        }
    }
}

#[test]
fn fork_lane_is_deep_copy() {
    for seed in 0..10u64 {
        let mut rng = SplitMix64::new(seed);
        let g = geom(32);
        let mut c = store(g, 2);
        let mut payload = vec![0.0f32; 4];
        for pos in 0..rng.below(20) + 1 {
            payload[0] = pos as f32;
            for l in 0..g.layers {
                for h in 0..g.kv_heads {
                    if let Some(s) = c.alloc_slot(0, l, h) {
                        c.write(0, l, h, s, pos, &payload, &payload);
                    }
                }
            }
        }
        c.fork_lane(0, 1);
        // identical observable state
        for l in 0..g.layers {
            for h in 0..g.kv_heads {
                assert_eq!(c.live_count(0, l, h), c.live_count(1, l, h));
                for s in 0..g.slots {
                    assert_eq!(
                        c.mask_value(0, l, h, s),
                        c.mask_value(1, l, h, s)
                    );
                    assert_eq!(c.k_at(0, l, h, s), c.k_at(1, l, h, s));
                }
            }
        }
        // divergence after fork does not leak back
        let live = c.live_slots(1, 0, 0);
        if let Some(&(s, _)) = live.first() {
            c.evict(1, 0, 0, s);
            assert_eq!(c.live_count(0, 0, 0), live.len());
        }
        check_consistency(&c, 0);
        check_consistency(&c, 1);
    }
}

#[test]
fn budget_policies_never_exceed_budget() {
    for (kind, budget) in [
        (PolicyKind::Tova, 10usize),
        (PolicyKind::H2o, 10),
        (PolicyKind::Window, 10),
    ] {
        let mut rng = SplitMix64::new(11);
        let g = geom(64);
        let mut c = store(g, 1);
        // CR chosen so build_policy yields exactly `budget` (as a
        // uniform plan — the legacy scalar rule, bit-exact)
        let mut policy = build_policy(kind, 160.0 / budget as f64, 160, 4, 8);
        assert_eq!(
            policy.plan().and_then(|p| p.uniform_budget()),
            Some(budget)
        );
        let k = vec![0.1f32; 4];
        let lh = g.lh();
        let alpha = vec![0.0f32; lh];
        let attn: Vec<f32> = (0..lh * g.slots)
            .map(|_| rng.f64() as f32)
            .collect();
        let attn_self = vec![0.0f32; lh];
        let mut actions: Vec<WriteAction> = Vec::new();
        let mut written = vec![None; lh];
        for pos in 0..50usize {
            c.apply_due_evictions(0, pos);
            policy.write_actions(&alpha, g.layers, g.kv_heads, &mut actions);
            for l in 0..g.layers {
                for h in 0..g.kv_heads {
                    let i = l * g.kv_heads + h;
                    written[i] = c.alloc_slot(0, l, h);
                    if let Some(s) = written[i] {
                        c.write(0, l, h, s, pos, &k, &k);
                    }
                }
            }
            policy.post_write(
                &mut c,
                &StepView {
                    lane: 0,
                    pos,
                    alpha: &alpha,
                    attn: &attn,
                    attn_self: &attn_self,
                    written: &written,
                },
            );
            for l in 0..g.layers {
                for h in 0..g.kv_heads {
                    assert!(
                        c.live_count(0, l, h) <= budget,
                        "{:?} exceeded budget at pos {pos}",
                        kind
                    );
                }
            }
        }
        check_consistency(&c, 0);
    }
}

#[test]
fn dms_policy_respects_window_exactly() {
    let g = geom(64);
    let mut c = store(g, 1);
    let window = 6usize;
    let mut policy = build_policy(PolicyKind::Dms, 4.0, 160, window, 8);
    let k = vec![0.0f32; 4];
    let lh = g.lh();
    let attn = vec![0.0f32; lh * g.slots];
    let mut actions: Vec<WriteAction> = Vec::new();
    let mut written = vec![None; lh];
    // evict-all alphas: every token scheduled out after `window`
    let alpha = vec![1.0f32; lh];
    for pos in 0..30usize {
        c.apply_due_evictions(0, pos);
        policy.write_actions(&alpha, g.layers, g.kv_heads, &mut actions);
        for l in 0..g.layers {
            for h in 0..g.kv_heads {
                let i = l * g.kv_heads + h;
                written[i] = c.alloc_slot(0, l, h);
                if let Some(s) = written[i] {
                    c.write(0, l, h, s, pos, &k, &k);
                }
            }
        }
        policy.post_write(
            &mut c,
            &StepView {
                lane: 0,
                pos,
                alpha: &alpha,
                attn: &attn,
                attn_self: &attn,
                written: &written,
            },
        );
        // steady state: exactly min(pos+1, window) tokens live
        let expect = (pos + 1).min(window);
        assert_eq!(c.live_count(0, 0, 0), expect, "pos {pos}");
    }
}

#[test]
fn dmc_merges_keep_cache_flat() {
    let g = geom(32);
    let mut c = store(g, 1);
    let mut policy = build_policy(PolicyKind::Dmc, 4.0, 160, 16, 8);
    let lh = g.lh();
    let mut actions: Vec<WriteAction> = Vec::new();
    let mut written = vec![None; lh];
    let k = vec![1.0f32; 4];
    // alternate merge/append decisions
    for pos in 0..40usize {
        let a = if pos % 2 == 0 { 0.9 } else { 0.1 };
        let alpha = vec![a; lh];
        policy.write_actions(&alpha, g.layers, g.kv_heads, &mut actions);
        for l in 0..g.layers {
            for h in 0..g.kv_heads {
                let i = l * g.kv_heads + h;
                written[i] = None;
                match actions[i] {
                    WriteAction::Merge => {
                        if !c.merge_into_last(0, l, h, &k, &k) {
                            let s = c.alloc_slot(0, l, h).unwrap();
                            c.write(0, l, h, s, pos, &k, &k);
                        }
                    }
                    WriteAction::Append => {
                        let s = c.alloc_slot(0, l, h).unwrap();
                        c.write(0, l, h, s, pos, &k, &k);
                        written[i] = Some(s);
                    }
                }
            }
        }
    }
    // half the tokens merged → about half the entries
    let live = c.live_count(0, 0, 0);
    assert!(live <= 21 && live >= 19, "live {live}");
    check_consistency(&c, 0);
}

// ----------------------------------------------------------------------
// Continuous-batching scheduler properties (simulated executor)
// ----------------------------------------------------------------------

/// Deterministic fake model: logits depend only on the position, so a
/// chain's token stream is a pure function of its own sampler (seed)
/// and positions — independent of lane assignment, admission order, and
/// batch composition. Any scheduler-induced difference in output is a
/// cross-chain state leak.
fn sim_logits(pos: usize) -> Vec<f32> {
    let mut r = SplitMix64::new(0xC0FFEE ^ (pos as u64).wrapping_mul(0x9E37));
    (0..16).map(|_| r.f64() as f32).collect()
}

/// Token 0 terminates a simulated chain (stands in for EOS).
const SIM_EOS: u32 = 0;

fn sim_policy(max_len: usize) -> Box<dyn hyperscale::compress::Policy> {
    build_policy(PolicyKind::Vanilla, 1.0, max_len, 4, 8)
}

/// The engine's tick loop with the executor stubbed out: prefill
/// completes instantly and decode samples from `sim_logits`. Exercises
/// the real `Scheduler` exactly as `Engine::tick` does.
struct Sim {
    sched: Scheduler,
    admitted_order: Vec<u64>,
    lanes_used: Vec<usize>,
    done: Vec<CompletedRequest>,
}

impl Sim {
    fn new(lanes: usize, cfg: SchedulerConfig) -> Self {
        Self {
            sched: Scheduler::new(lanes, cfg),
            admitted_order: Vec::new(),
            lanes_used: Vec::new(),
            done: Vec::new(),
        }
    }

    fn submit(
        &mut self,
        width: usize,
        prompt_len: usize,
        max_len: usize,
        temperature: f64,
        seed: u64,
    ) -> u64 {
        let req = GenRequest {
            prompt: String::new(),
            width,
            max_len,
            temperature,
            seed,
        };
        self.sched.submit(&req, Arc::new(vec![1u32; prompt_len]))
    }

    fn admit(&mut self) {
        while let Some(lane) = self.sched.idle_lane() {
            let Some(p) = self.sched.next_admission() else { break };
            self.admitted_order.push(p.ticket);
            self.lanes_used.push(lane);
            let policy = sim_policy(p.max_len);
            self.sched.install(lane, ChainState::new(p, policy, 0));
        }
    }

    fn tick(&mut self) {
        self.admit();
        let n = self.sched.n_lanes();
        // prefill: completes instantly, then forks waiting siblings
        for lane in 0..n {
            let leader = {
                let Some(a) = self.sched.lane_mut(lane) else { continue };
                let Phase::Prefill { .. } = a.phase else { continue };
                let len = a.prefill_ids.len();
                a.pos = len;
                a.phase = Phase::Decode;
                let resumed = a.resume_token.is_some();
                let tok = match a.resume_token.take() {
                    Some(t) => t,
                    None => a.sampler.sample(&sim_logits(len - 1)),
                };
                a.cur_token = tok;
                (a.ticket, tok, len, resumed)
            };
            let (ticket, tok, pos, resumed) = leader;
            self.sched.note_first_token(ticket);
            // as in the engine: a resumed chain's cache holds generated
            // tokens, so siblings never fork from it (they promote)
            if resumed {
                continue;
            }
            loop {
                let Some(dst) = self.sched.idle_lane() else { break };
                let Some(p) = self.sched.take_fork_sibling(ticket) else { break };
                self.admitted_order.push(p.ticket);
                self.lanes_used.push(dst);
                let policy = sim_policy(p.max_len);
                self.sched
                    .install(dst, ChainState::forked(p, policy, 0, tok, pos));
            }
        }
        // decode: one token per decoding lane
        for lane in 0..n {
            let finish = {
                let Some(a) = self.sched.lane_mut(lane) else { continue };
                if !matches!(a.phase, Phase::Decode) {
                    continue;
                }
                let tok = a.sampler.sample(&sim_logits(a.pos));
                a.gen_ids.push(a.cur_token);
                a.pos += 1;
                a.cur_token = tok;
                if tok == SIM_EOS {
                    Some(FinishReason::Stop)
                } else if a.pos + 1 >= a.max_len {
                    a.gen_ids.push(tok);
                    Some(FinishReason::Length)
                } else {
                    None
                }
            };
            if let Some(reason) = finish {
                let c = self.sched.take(lane).unwrap();
                let mut stats = c.stats;
                stats.gen_tokens = c.gen_ids.len();
                let result = ChainResult {
                    text: format!("{:?}", c.gen_ids),
                    finish: reason,
                    stats,
                };
                if let Some(done) = self.sched.complete(c.ticket, c.chain_idx, result) {
                    self.done.push(done);
                }
            }
        }
    }

    fn run_to_completion(&mut self) {
        let mut ticks = 0;
        while self.sched.has_work() {
            self.tick();
            ticks += 1;
            assert!(ticks < 10_000, "scheduler failed to drain");
        }
    }
}

#[test]
fn fcfs_admission_preserves_submission_order() {
    let mut sim = Sim::new(2, SchedulerConfig::default());
    let tickets: Vec<u64> = (0..6).map(|i| sim.submit(1, 4, 16, 0.0, i)).collect();
    sim.run_to_completion();
    assert_eq!(sim.admitted_order, tickets, "FCFS must admit in arrival order");
    assert_eq!(sim.done.len(), 6);
}

#[test]
fn shortest_first_admission_orders_by_budget() {
    let cfg = SchedulerConfig {
        admission: AdmissionPolicy::ShortestFirst,
        preempt_watermark: None,
    };
    let mut sim = Sim::new(1, cfg);
    let t_long = sim.submit(1, 4, 40, 0.0, 1);
    let t_short = sim.submit(1, 4, 12, 0.0, 2);
    let t_mid = sim.submit(1, 4, 20, 0.0, 3);
    sim.run_to_completion();
    assert_eq!(sim.admitted_order, vec![t_short, t_mid, t_long]);
}

#[test]
fn lanes_recycle_to_queued_chains() {
    let mut sim = Sim::new(2, SchedulerConfig::default());
    for i in 0..5 {
        sim.submit(1, 4, 12, 0.0, i);
    }
    sim.run_to_completion();
    assert_eq!(sim.done.len(), 5);
    assert_eq!(sim.admitted_order.len(), 5);
    // every admission landed on a real lane and both lanes were reused
    assert!(sim.lanes_used.iter().all(|&l| l < 2));
    assert!(sim.lanes_used.contains(&0) && sim.lanes_used.contains(&1));
    assert_eq!(sim.sched.active_lanes(), 0, "all lanes returned idle");
}

#[test]
fn fork_siblings_share_leader_prefill() {
    let mut sim = Sim::new(3, SchedulerConfig::default());
    sim.submit(3, 4, 16, 0.0, 5);
    sim.run_to_completion();
    assert_eq!(sim.done.len(), 1);
    let chains = &sim.done[0].result.chains;
    assert_eq!(chains.len(), 3);
    let forked = chains.iter().filter(|c| c.stats.forked_prefill).count();
    assert_eq!(forked, 2, "both siblings fork from the leader");
    // greedy chains from a forked prefix match the leader exactly
    assert_eq!(chains[0].text, chains[1].text);
    assert_eq!(chains[1].text, chains[2].text);
}

#[test]
fn stranded_fork_siblings_are_promoted() {
    // width 3 on a single lane: no idle lane ever exists while the
    // leader runs, so the siblings must be promoted to self-prefill
    // once the leader retires.
    let mut sim = Sim::new(1, SchedulerConfig::default());
    let t = sim.submit(3, 4, 12, 0.5, 9);
    sim.run_to_completion();
    assert_eq!(sim.done.len(), 1);
    assert_eq!(sim.done[0].result.chains.len(), 3);
    assert_eq!(sim.admitted_order, vec![t, t, t]);
    let forked = sim.done[0]
        .result
        .chains
        .iter()
        .filter(|c| c.stats.forked_prefill)
        .count();
    assert_eq!(forked, 0, "promoted siblings prefill by themselves");
}

#[test]
fn concurrent_admission_matches_sequential_tokens() {
    // Per-chain token streams are a pure function of (seed, positions);
    // if lane sharing, admission order, or recycling leaked any state
    // across chains, the streams would differ between schedules.
    let spec: Vec<(usize, usize, f64, u64)> = (0..8)
        .map(|i| (4 + (i % 3), 20 + (i % 5), 0.7, 100 + i as u64))
        .collect();

    // sequential: each request alone on a single-lane scheduler
    let mut sequential: Vec<String> = Vec::new();
    for &(plen, mlen, temp, seed) in &spec {
        let mut sim = Sim::new(1, SchedulerConfig::default());
        sim.submit(1, plen, mlen, temp, seed);
        sim.run_to_completion();
        assert_eq!(sim.done.len(), 1);
        sequential.push(sim.done[0].result.chains[0].text.clone());
    }

    // concurrent: all eight requests share three lanes, submitted upfront
    let mut sim = Sim::new(3, SchedulerConfig::default());
    let tickets: Vec<u64> = spec
        .iter()
        .map(|&(p, m, t, s)| sim.submit(1, p, m, t, s))
        .collect();
    sim.run_to_completion();
    assert_eq!(sim.done.len(), 8);
    for (i, t) in tickets.iter().enumerate() {
        let done = sim.done.iter().find(|d| d.ticket == *t).unwrap();
        assert_eq!(done.result.chains[0].text, sequential[i], "request {i}");
    }

    // staggered submission (requests arrive while others run) must
    // produce the same streams too
    let mut sim = Sim::new(3, SchedulerConfig::default());
    let mut tickets = Vec::new();
    for &(p, m, t, s) in &spec {
        tickets.push(sim.submit(1, p, m, t, s));
        sim.tick();
    }
    sim.run_to_completion();
    assert_eq!(sim.done.len(), 8);
    for (i, t) in tickets.iter().enumerate() {
        let done = sim.done.iter().find(|d| d.ticket == *t).unwrap();
        assert_eq!(done.result.chains[0].text, sequential[i], "staggered request {i}");
    }
}

#[test]
fn preemption_requeues_and_resumes_exactly() {
    // reference: the request runs alone, never preempted
    let mut r = Sim::new(1, SchedulerConfig::default());
    r.submit(1, 4, 24, 0.7, 42);
    r.run_to_completion();
    let reference = r.done[0].result.chains[0].text.clone();

    let cfg = SchedulerConfig {
        admission: AdmissionPolicy::Fcfs,
        preempt_watermark: Some(0.5),
    };
    let mut sim = Sim::new(1, cfg);
    let t0 = sim.submit(1, 4, 24, 0.7, 42);
    let t1 = sim.submit(1, 4, 12, 0.7, 43);
    // let request 0 decode a few tokens, request 1 starves in the queue
    sim.tick();
    sim.tick();
    sim.tick();
    // cache pressure above the watermark with a waiting chain and no
    // idle lane → the running chain is preempted
    let lane = sim.sched.maybe_preempt(0.9);
    assert_eq!(lane, Some(0));
    assert_eq!(sim.sched.preemptions(), 1);
    assert_eq!(sim.sched.queue_depth(), 2);
    // below the watermark nothing happens
    assert_eq!(sim.sched.maybe_preempt(0.1), None);

    sim.run_to_completion();
    assert_eq!(sim.done.len(), 2);
    // the preempted chain yielded its turn: the short request finishes first
    assert_eq!(sim.done[0].ticket, t1);
    assert_eq!(sim.done[1].ticket, t0);
    // and resumes to exactly the tokens of the unpreempted run
    assert_eq!(sim.done[1].result.chains[0].text, reference);
}

// ----------------------------------------------------------------------
// Copy-on-write fork equivalence & pool refcount invariants
// ----------------------------------------------------------------------

/// Pseudo-model whose logits are a pure function of the lane's
/// *observable* cache state (positions, key payloads, mask). Any COW
/// corruption — a sibling seeing a leader's eviction, a stale
/// materialization, a mask desync — changes the token stream.
fn cache_logits(c: &CacheStore, lane: usize, pos: usize) -> Vec<f32> {
    let g = c.geom;
    let mut acc = 0x9E37_79B9_7F4A_7C15u64 ^ (pos as u64);
    for l in 0..g.layers {
        for h in 0..g.kv_heads {
            for s in 0..g.slots {
                if let Some(p) = c.slot_pos(lane, l, h, s) {
                    let kbits = c.k_at(lane, l, h, s)[0].to_bits() as u64;
                    acc = acc
                        .wrapping_mul(0x0100_0000_01B3)
                        .wrapping_add(kbits ^ ((s as u64) << 32) ^ p as u64);
                    acc ^= (c.mask_value(lane, l, h, s).to_bits() as u64).rotate_left(17);
                }
            }
        }
    }
    let mut r = SplitMix64::new(acc);
    (0..16).map(|_| r.f64() as f32).collect()
}

/// One simulated decode step, mirroring the engine's write path:
/// due evictions, policy write-actions, append/merge, post_write.
fn drive_chain_step(
    c: &mut CacheStore,
    lane: usize,
    policy: &mut Box<dyn hyperscale::compress::Policy>,
    pos: usize,
) -> u32 {
    let g = c.geom;
    let lh = g.lh();
    let logits = cache_logits(c, lane, pos);
    let tok = logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0 as u32;
    // α/attention streams are deterministic in (lane, pos) so sibling
    // lanes diverge (exercising COW breaks) but the two fork modes see
    // identical inputs
    let mut rng = SplitMix64::new(0xA11CE ^ ((lane as u64) << 40) ^ pos as u64);
    let alpha: Vec<f32> = (0..lh).map(|_| rng.f64() as f32).collect();
    let attn: Vec<f32> = (0..lh * g.slots).map(|_| rng.f64() as f32).collect();
    let attn_self: Vec<f32> = (0..lh).map(|_| rng.f64() as f32).collect();
    c.apply_due_evictions(lane, pos);
    let mut actions: Vec<WriteAction> = Vec::new();
    policy.write_actions(&alpha, g.layers, g.kv_heads, &mut actions);
    let payload: Vec<f32> = (0..g.head_dim)
        .map(|d| tok as f32 + d as f32 + pos as f32 * 0.25)
        .collect();
    let mut written = vec![None; lh];
    for l in 0..g.layers {
        for h in 0..g.kv_heads {
            let i = l * g.kv_heads + h;
            written[i] = None;
            let append = match actions[i] {
                WriteAction::Merge => !c.merge_into_last(lane, l, h, &payload, &payload),
                WriteAction::Append => true,
            };
            if append {
                if let Some(s) = c.alloc_slot(lane, l, h) {
                    c.write(lane, l, h, s, pos, &payload, &payload);
                    written[i] = Some(s);
                }
            }
        }
    }
    policy.post_write(
        c,
        &StepView {
            lane,
            pos,
            alpha: &alpha,
            attn: &attn,
            attn_self: &attn_self,
            written: &written,
        },
    );
    tok
}

fn prefill_identity(c: &mut CacheStore, lane: usize, n: usize) {
    let g = c.geom;
    for pos in 0..n {
        let payload: Vec<f32> = (0..g.head_dim).map(|d| pos as f32 + d as f32 * 0.5).collect();
        for l in 0..g.layers {
            for h in 0..g.kv_heads {
                let s = c.alloc_slot(lane, l, h).unwrap();
                c.write(lane, l, h, s, pos, &payload, &payload);
            }
        }
    }
}

fn assert_lane_state_equal(a: &CacheStore, b: &CacheStore, lane: usize, ctx: &str) {
    let g = a.geom;
    for l in 0..g.layers {
        for h in 0..g.kv_heads {
            assert_eq!(
                a.live_count(lane, l, h),
                b.live_count(lane, l, h),
                "{ctx}: live desync at ({l},{h})"
            );
            for s in 0..g.slots {
                assert_eq!(
                    a.slot_state(lane, l, h, s),
                    b.slot_state(lane, l, h, s),
                    "{ctx}: meta desync at ({l},{h},{s})"
                );
                assert_eq!(
                    a.mask_value(lane, l, h, s),
                    b.mask_value(lane, l, h, s),
                    "{ctx}: mask desync at ({l},{h},{s})"
                );
                assert_eq!(
                    a.k_at(lane, l, h, s),
                    b.k_at(lane, l, h, s),
                    "{ctx}: k desync at ({l},{h},{s})"
                );
                assert_eq!(
                    a.v_at(lane, l, h, s),
                    b.v_at(lane, l, h, s),
                    "{ctx}: v desync at ({l},{h},{s})"
                );
            }
        }
    }
}

#[test]
fn cow_fork_streams_bit_exact_vs_full_copy_across_policies() {
    use hyperscale::compress::PolicyKind as PK;
    for kind in [
        PK::Vanilla,
        PK::Dms,
        PK::DmsImmediate,
        PK::Tova,
        PK::H2o,
        PK::Dmc,
        PK::Window,
        PK::Quest,
    ] {
        let g = geom(64);
        let (prompt, steps, max_len, window) = (19usize, 25usize, 64usize, 4usize);
        let mk = || build_policy(kind, 4.0, max_len, window, g.page_size);

        // store A forks the sibling by full-lane memcpy, store B by
        // COW refcount bump; everything else is identical. Pinned to
        // f32 regardless of KV_DTYPE: the memcpy fork never touches
        // the pool, while a COW break under q8/q4 publishes a lossy
        // snapshot — byte-equality between the two fork modes is an
        // f32-only contract (quantized COW exactness is covered by
        // tests/quantized_cache.rs instead).
        let mut a = CacheStore::with_dtype(g, 2, KvDtype::F32);
        let mut b = CacheStore::with_dtype(g, 2, KvDtype::F32);
        prefill_identity(&mut a, 0, prompt);
        prefill_identity(&mut b, 0, prompt);
        a.fork_lane(0, 1);
        b.fork_lane_cow(0, 1);

        let mut pol_a = [mk(), mk()];
        let mut pol_b = [mk(), mk()];
        let mut stream_a: Vec<Vec<u32>> = vec![Vec::new(), Vec::new()];
        let mut stream_b: Vec<Vec<u32>> = vec![Vec::new(), Vec::new()];
        for step in 0..steps {
            let pos = prompt + step;
            // the engine materializes shared pages once per tick
            b.materialize_pending();
            for lane in 0..2 {
                stream_a[lane].push(drive_chain_step(&mut a, lane, &mut pol_a[lane], pos));
                stream_b[lane].push(drive_chain_step(&mut b, lane, &mut pol_b[lane], pos));
            }
        }
        assert_eq!(
            stream_a, stream_b,
            "{kind:?}: COW fork changed a token stream"
        );
        b.materialize_pending();
        for lane in 0..2 {
            assert_lane_state_equal(&a, &b, lane, &format!("{kind:?} lane {lane}"));
            check_consistency(&b, lane);
        }
    }
}

#[test]
fn cow_pool_refcounts_balance_under_random_lifecycle() {
    for seed in 0..12u64 {
        let mut rng = SplitMix64::new(0xBEEF ^ seed);
        let g = geom(32);
        let lanes = 4usize;
        let mut c = store(g, lanes);
        let mut active = vec![false; lanes];
        let mut held: Vec<u64> = Vec::new();
        let payload = vec![0.25f32; g.head_dim];

        let check_refs = |c: &CacheStore, held: &Vec<u64>| {
            let mapped: usize = (0..lanes).map(|b| c.shared_pages(b)).sum();
            assert_eq!(
                c.pool_refs(),
                mapped + held.len(),
                "pool refs != lane mappings + held handles"
            );
        };

        for _ in 0..300 {
            let lane = rng.below(lanes);
            match rng.below(7) {
                0 => {
                    // (re)start a lane with a fresh identity prefill
                    if !active[lane] {
                        prefill_identity(&mut c, lane, 1 + rng.below(16));
                        active[lane] = true;
                    }
                }
                1 => {
                    // COW-fork into an idle lane
                    if active[lane] {
                        if let Some(dst) = (0..lanes).find(|&d| !active[d]) {
                            c.fork_lane_cow(lane, dst);
                            active[dst] = true;
                        }
                    }
                }
                2 => {
                    // policy-style eviction (may break a share)
                    if active[lane] {
                        let live = c.live_slots(lane, 0, 0);
                        if !live.is_empty() {
                            let (s, _) = live[rng.below(live.len())];
                            c.evict(lane, 0, 0, s);
                        }
                    }
                }
                3 => {
                    // decode-style write (may break a share)
                    if active[lane] {
                        if let Some(s) = c.alloc_slot(lane, 0, 1) {
                            c.write(lane, 0, 1, s, 99, &payload, &payload);
                        }
                    }
                }
                4 => {
                    // retire / preempt: recycle the lane
                    if active[lane] {
                        c.recycle_lane(lane);
                        active[lane] = false;
                    }
                }
                5 => {
                    // prefix retention: export a full clean page
                    if active[lane] && c.clean_prefix_pages(lane, g.page_size + 1) > 0 {
                        held.push(c.export_page(lane, 0));
                    }
                }
                _ => {
                    // index release or prefix-hit mapping of a held page
                    if let Some(id) = held.pop() {
                        let target = (0..lanes).find(|&d| !active[d]);
                        match target {
                            Some(dst) if rng.below(2) == 0 => {
                                c.map_prefix_pages(dst, &[id]);
                                active[dst] = true;
                            }
                            _ => c.release_page(id),
                        }
                    }
                }
            }
            check_refs(&c, &held);
        }
        // drain everything: no entry may survive
        c.materialize_pending();
        for lane in 0..lanes {
            c.recycle_lane(lane);
        }
        for id in held.drain(..) {
            c.release_page(id);
        }
        assert_eq!(c.pool_pages(), 0, "seed {seed}: leaked pool pages");
        assert_eq!(c.pool_refs(), 0);
    }
}

#[test]
fn cold_tier_demote_promote_spill_keeps_refcounts_balanced() {
    // Extends the lifecycle property with the cold tier's traffic:
    // demotion removes a page from the pool entirely (its payload
    // moves into the tier), promotion re-inserts it as a fresh
    // owner-referenced entry, and spill/reload happens transparently
    // under a deliberately tiny RAM budget. The pool-ref balance
    // (refs == lane mappings + held handles) must hold at every step —
    // cold entries are *outside* the pool and contribute zero refs.
    use hyperscale::kvcache::ColdTier;
    let dir = std::env::temp_dir().join(format!("hyperscale-coldprop-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    for seed in 0..8u64 {
        let mut rng = SplitMix64::new(0xC01D ^ seed);
        let g = geom(32);
        let lanes = 4usize;
        let mut c = store(g, lanes);
        // ~2 pages resident; the rest of the cold set spills to disk
        let page_bytes = g.layers * g.kv_heads * g.page_size * g.head_dim * 8;
        let mut cold = ColdTier::new(2 * page_bytes, KvDtype::Q4, Some(dir.clone()), g.head_dim);
        let mut active = vec![false; lanes];
        let mut held: Vec<u64> = Vec::new();
        let mut cold_keys: Vec<Vec<u32>> = Vec::new();
        let mut key_seq = 0u32;

        let check_refs = |c: &CacheStore, held: &Vec<u64>| {
            let mapped: usize = (0..lanes).map(|b| c.shared_pages(b)).sum();
            assert_eq!(
                c.pool_refs(),
                mapped + held.len(),
                "pool refs != lane mappings + held handles"
            );
        };

        for _ in 0..250 {
            let lane = rng.below(lanes);
            match rng.below(6) {
                0 => {
                    if !active[lane] {
                        prefill_identity(&mut c, lane, 1 + rng.below(16));
                        active[lane] = true;
                    }
                }
                1 => {
                    // retain a clean page for later demotion
                    if active[lane] && c.clean_prefix_pages(lane, g.page_size + 1) > 0 {
                        held.push(c.export_page(lane, 0));
                    }
                }
                2 => {
                    // retire the lane (drops its mapping refs)
                    if active[lane] {
                        c.recycle_lane(lane);
                        active[lane] = false;
                    }
                }
                3 => {
                    // demote a held page: the handle is consumed either
                    // way; the payload enters the tier only when ours
                    // was the final reference
                    if let Some(id) = held.pop() {
                        if let Some((page, data)) = c.demote_page(id) {
                            key_seq += 1;
                            cold.admit(&[key_seq], page, data);
                            cold_keys.push(vec![key_seq]);
                        }
                    }
                }
                4 => {
                    // promote a cold entry (may reload from disk) and
                    // either hold the adopted handle or map it
                    if !cold_keys.is_empty() {
                        let key = cold_keys.swap_remove(rng.below(cold_keys.len()));
                        // with a spill dir configured, over-budget
                        // entries spill rather than evict, so every
                        // admitted key is promotable
                        let (page, data) = cold.promote(&key).expect("spilled, not evicted");
                        let id = c.adopt_cold_page(page, data);
                        match (0..lanes).find(|&d| !active[d]) {
                            Some(dst) if rng.below(2) == 0 => {
                                c.map_prefix_pages(dst, &[id]);
                                c.materialize_pending();
                                active[dst] = true;
                            }
                            _ => held.push(id),
                        }
                    }
                }
                _ => {
                    // release a held handle without demoting
                    if let Some(id) = held.pop() {
                        c.release_page(id);
                    }
                }
            }
            check_refs(&c, &held);
            // resident bytes never exceed budget; anything past it is
            // spilled, never silently dropped while entries exist
            assert!(
                cold.resident_bytes() <= 2 * page_bytes,
                "seed {seed}: cold budget overrun"
            );
        }
        // drain: pool and tier both empty out with no leaks
        c.materialize_pending();
        for lane in 0..lanes {
            c.recycle_lane(lane);
        }
        for id in held.drain(..) {
            c.release_page(id);
        }
        cold.clear();
        assert_eq!(c.pool_pages(), 0, "seed {seed}: leaked pool pages");
        assert_eq!(c.pool_refs(), 0);
        assert_eq!(cold.spilled_bytes(), 0, "seed {seed}: spill bytes leak");
        assert!(cold.is_empty());
    }
    // every spill file is gone once the tiers are cleared
    assert_eq!(
        std::fs::read_dir(&dir).unwrap().count(),
        0,
        "spill files leaked"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}
