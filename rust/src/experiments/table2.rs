//! Table 2: NIAH / VT context-length extrapolation. Contexts scale to
//! {0.75×, 1×, 2×} of the retrofit context (the paper's 3K/4K/8K at a
//! 4K retrofit length; ours is 160 → {120, 160, 320}-slot prompts).

use std::path::Path;

use anyhow::Result;

use super::evalrun::Harness;
use crate::analysis::tables::{pct, Table};
use crate::compress::PolicyKind;
use crate::config::EngineConfig;
use crate::engine::{aggregate, GenRequest};
use crate::tasks::{gen_niah_with_fillers, Problem};
use crate::util::Json;

/// Filler counts targeting ~120/160/300-token NIAH prompts.
const NIAH_FILLERS: [(usize, &str); 3] = [(4, "0.75x"), (6, "1x"), (12, "2x")];

fn vt_problem(seed: u64, index: u64, scale: usize) -> Problem {
    // scale the noise band by regenerating with more noise statements;
    // the variable pool has 20 letters, so noise is capped at what the
    // chain leaves available.
    let mut rng = crate::tasks::problem_rng(seed, index);
    let n_chain = 3 + rng.below(4);
    let n_noise = scale.min(20 - n_chain - 1);
    crate::tasks::gen_vt(&mut rng, n_chain, n_noise)
}

pub fn run_table2(artifacts: &Path, n_problems: usize) -> Result<()> {
    let cfg = EngineConfig {
        temperature: 0.0,
        ..EngineConfig::paper_fidelity(artifacts)
    };
    let mut harness = Harness::new(cfg)?;
    let methods = [
        PolicyKind::Vanilla,
        PolicyKind::Tova,
        PolicyKind::H2o,
        PolicyKind::Quest,
        PolicyKind::Dmc,
        PolicyKind::Dms,
    ];
    let mut json_rows = Vec::new();
    println!("\n## Table 2 (context-length extrapolation, NIAH/VT)\n");
    for &cr in &[2.0f64, 3.0, 4.0] {
        let mut t = Table::new(&[
            "method", "niah 0.75x", "niah 1x", "niah 2x", "vt 0.75x", "vt 1x", "vt 2x",
        ]);
        for &policy in &methods {
            if policy == PolicyKind::Vanilla && cr != 2.0 {
                continue;
            }
            let variant = match policy {
                PolicyKind::Dms => format!("dms_w16_cr{}", cr as usize),
                PolicyKind::Dmc => {
                    if cr >= 4.0 {
                        "dmc".into()
                    } else {
                        format!("dmc_cr{}", cr as usize)
                    }
                }
                _ => "base".to_string(),
            };
            let eff_cr = if policy == PolicyKind::Vanilla { 1.0 } else { cr };
            harness.engine_mut().set_variant(&variant)?;
            harness.engine_mut().set_policy(policy, eff_cr)?;

            let mut cells = vec![if policy == PolicyKind::Vanilla {
                "vanilla (CR1)".into()
            } else {
                policy.name().to_string()
            }];
            // NIAH at three context scales
            for (fillers, _) in NIAH_FILLERS {
                let acc = eval_problems(&mut harness, n_problems, |i| {
                    gen_niah_with_fillers(91, i, fillers)
                })?;
                cells.push(pct(acc));
                json_rows.push(
                    Json::obj()
                        .set("cr", eff_cr)
                        .set("method", policy.name())
                        .set("task", "niah")
                        .set("fillers", fillers)
                        .set("accuracy", acc),
                );
            }
            // VT at three noise scales
            for noise in [4usize, 8, 20] {
                let acc = eval_problems(&mut harness, n_problems, |i| {
                    vt_problem(92, i, noise)
                })?;
                cells.push(pct(acc));
                json_rows.push(
                    Json::obj()
                        .set("cr", eff_cr)
                        .set("method", policy.name())
                        .set("task", "vt")
                        .set("noise", noise)
                        .set("accuracy", acc),
                );
            }
            t.row(cells);
        }
        println!("### CR {cr}×\n\n{}", t.markdown());
    }
    super::write_report(artifacts, "table2", &Json::Arr(json_rows))?;
    Ok(())
}

fn eval_problems(
    harness: &mut Harness,
    n: usize,
    gen: impl Fn(u64) -> Problem,
) -> Result<f64> {
    let slots = harness.engine_mut().geometry().slots;
    let mut correct = 0usize;
    let mut total = 0usize;
    let mut requests = Vec::new();
    let mut golds = Vec::new();
    for i in 0..n as u64 {
        let p = gen(i);
        let need = p.prompt.len() + 10;
        if need > slots {
            continue;
        }
        requests.push(GenRequest {
            prompt: p.prompt.clone(),
            width: 1,
            max_len: (need + 8).min(slots),
            temperature: 0.0,
            seed: i,
        });
        golds.push((p.task.clone(), p.answer.clone()));
    }
    // requests have differing max_len; run one by one batched in groups
    let engine = harness.engine_mut();
    let (results, _) = engine.run(&requests)?;
    for (res, (task, gold)) in results.iter().zip(&golds) {
        if aggregate(task, &res.texts(), gold) {
            correct += 1;
        }
        total += 1;
    }
    Ok(if total == 0 {
        0.0
    } else {
        correct as f64 / total as f64
    })
}
