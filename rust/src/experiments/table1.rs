//! Table 1 (+ Table 4 std devs, Table 3 base-model sanity): the broader
//! task battery across methods × CR ∈ {2, 3, 4}, W = 1.

use std::path::Path;

use anyhow::Result;

use super::evalrun::{EvalSpec, Harness};
use crate::analysis::tables::{pct, Table};
use crate::compress::PolicyKind;
use crate::config::EngineConfig;
use crate::util::Json;

const TASKS: [&str; 5] = ["gsm8k", "mmlu", "hellaswag", "niah", "vt"];

fn variant_for(policy: PolicyKind, cr: f64) -> String {
    match policy {
        PolicyKind::Dms => format!("dms_w16_cr{}", cr as usize),
        PolicyKind::Dmc => {
            if cr >= 4.0 {
                "dmc".to_string()
            } else {
                format!("dmc_cr{}", cr as usize)
            }
        }
        _ => "base".to_string(),
    }
}

/// Binomial standard deviation of an accuracy estimate (Table 4).
fn std_dev(acc: f64, n: usize) -> f64 {
    if n == 0 {
        return 0.0;
    }
    (acc * (1.0 - acc) / n as f64).sqrt()
}

pub fn run_table1(artifacts: &Path, n_problems: usize, base_only: bool) -> Result<()> {
    let cfg = EngineConfig {
        temperature: 0.0, // zero-shot greedy, like the harness evals
        ..EngineConfig::paper_fidelity(artifacts)
    };
    let mut harness = Harness::new(cfg)?;
    let methods: &[PolicyKind] = if base_only {
        // Table 3 analog: base (non-instruct) model sanity — vanilla,
        // DMS, Quest, DMC at CR4/CR8 handled by the points driver.
        &[PolicyKind::Vanilla, PolicyKind::Dms, PolicyKind::Quest, PolicyKind::Dmc]
    } else {
        &[
            PolicyKind::Vanilla,
            PolicyKind::H2o,
            PolicyKind::Tova,
            PolicyKind::Quest,
            PolicyKind::Dmc,
            PolicyKind::Dms,
        ]
    };

    let mut json_rows = Vec::new();
    println!("\n## Table 1 (broader battery; CR 2/3/4, W=1, greedy)\n");
    for &cr in &[2.0f64, 3.0, 4.0] {
        let mut t = Table::new(&["method", "gsm8k", "mmlu", "hellaswag", "niah", "vt"]);
        for &policy in methods {
            if policy == PolicyKind::Vanilla && cr != 2.0 {
                continue; // vanilla has no CR axis; print once
            }
            let mut cells = vec![if policy == PolicyKind::Vanilla {
                "vanilla (CR1)".to_string()
            } else {
                policy.name().to_string()
            }];
            for task in TASKS {
                let mut spec = EvalSpec::new(task, policy, cr);
                spec.variant = variant_for(policy, cr);
                spec.temperature = 0.0;
                spec.n_problems = n_problems;
                spec.max_len = 192;
                if policy == PolicyKind::Vanilla {
                    spec.cr = 1.0;
                }
                let out = harness.eval(&spec)?;
                cells.push(format!(
                    "{}±{}",
                    pct(out.accuracy),
                    pct(std_dev(out.accuracy, out.n_problems))
                ));
                json_rows.push(
                    Json::obj()
                        .set("cr", cr)
                        .set("method", policy.name())
                        .set("task", task)
                        .set("accuracy", out.accuracy)
                        .set("std", std_dev(out.accuracy, out.n_problems))
                        .set("n", out.n_problems),
                );
            }
            t.row(cells);
        }
        println!("### CR {cr}×\n\n{}", t.markdown());
    }
    super::write_report(
        artifacts,
        if base_only { "table3" } else { "table1" },
        &Json::Arr(json_rows),
    )?;
    Ok(())
}
