//! Accuracy-vs-bits: how quantized KV page payloads (q8/q4) trade
//! host bytes-per-cached-token against end-task accuracy.
//!
//! Quantization only touches pool-owned payloads (COW snapshots and
//! prefix-retained pages; see docs/NUMERICS.md), so a cache-cold run
//! is bit-identical across dtypes. The experiment therefore runs every
//! request set **twice** per dtype with the prefix cache enabled: the
//! cold pass prefills from scratch (and retains clean prompt pages,
//! quantized at export), the warm pass restores those pages through
//! dequant-on-upload — the path where precision can move accuracy.
//! Reported per dtype: bytes/token (whole model), cold/warm accuracy,
//! prefix tokens restored, cumulative dequant time, mean KV reads on
//! the byte axis, and the fraction of warm streams identical to the
//! f32 engine's (greedy decoding, so any difference is payload
//! precision, not sampling noise).
//!
//! This is intentionally *not* paper-fidelity: the paper's figures pin
//! `kv_dtype: f32` + no prefix cache (`EngineConfig::paper_fidelity`);
//! this driver measures the serving-mode extension.

use std::path::Path;

use anyhow::Result;

use crate::analysis::tables::{num, pct, Table};
use crate::config::EngineConfig;
use crate::engine::{aggregate, Engine, GenRequest, GenResult};
use crate::kvcache::KvDtype;
use crate::scaling::kv_bytes_per_token;
use crate::tasks::gen_problem;
use crate::util::Json;

const TASK: &str = "math";
const MAX_LEN: usize = 160;
const SEED: u64 = 17;

fn build_requests(n_problems: usize) -> (Vec<GenRequest>, Vec<String>) {
    let mut requests = Vec::new();
    let mut golds = Vec::new();
    let mut idx = 0u64;
    while requests.len() < n_problems && idx < n_problems as u64 * 20 {
        let p = gen_problem(TASK, SEED, idx);
        idx += 1;
        if p.prompt.len() + 24 > MAX_LEN {
            continue;
        }
        requests.push(GenRequest {
            prompt: p.prompt.clone(),
            width: 1,
            max_len: MAX_LEN,
            temperature: 0.0, // greedy: divergence is payload-driven only
            seed: SEED.wrapping_mul(31).wrapping_add(idx),
        });
        golds.push(p.answer);
    }
    (requests, golds)
}

fn accuracy(results: &[GenResult], golds: &[String]) -> f64 {
    let correct = results
        .iter()
        .zip(golds)
        .filter(|(r, gold)| aggregate(TASK, &r.texts(), gold))
        .count();
    correct as f64 / results.len().max(1) as f64
}

pub fn run_quant_bits(artifacts: &Path, n_problems: usize) -> Result<()> {
    let (requests, golds) = build_requests(n_problems);
    if requests.is_empty() {
        anyhow::bail!("no {TASK} problems fit max_len {MAX_LEN}");
    }

    println!("\n## Accuracy vs payload bits (prefix-cache warm restores)\n");
    let mut t = Table::new(&[
        "kv_dtype",
        "B/token",
        "cold acc",
        "warm acc",
        "hit toks",
        "dequant ms",
        "byte reads",
        "agree f32",
    ]);
    let mut json_rows = Vec::new();
    let mut f32_warm_texts: Vec<Vec<String>> = Vec::new();

    for dtype in [KvDtype::F32, KvDtype::Q8, KvDtype::Q4] {
        let cfg = EngineConfig {
            kv_dtype: dtype,
            prefix_cache: true,
            ..EngineConfig::paper_fidelity(artifacts)
        };
        let mut engine = Engine::new(cfg)?;
        let geom = engine.geometry();
        let bytes_per_token = kv_bytes_per_token(dtype, geom.layers, geom.kv_heads, geom.head_dim);

        let (cold, _) = engine.run(&requests)?;
        let (warm, warm_stats) = engine.run(&requests)?;

        let warm_texts: Vec<Vec<String>> = warm
            .iter()
            .map(|r| r.texts().iter().map(|s| s.to_string()).collect())
            .collect();
        if dtype == KvDtype::F32 {
            f32_warm_texts = warm_texts.clone();
        }
        let agree = warm_texts
            .iter()
            .zip(&f32_warm_texts)
            .filter(|(a, b)| a == b)
            .count() as f64
            / warm_texts.len() as f64;

        let mean_reads: f64 =
            warm.iter().map(GenResult::total_reads).sum::<f64>() / warm.len() as f64;
        let dequant_ms = engine.metrics.gauge("kv.dequant_us").get() / 1000.0;
        let cold_acc = accuracy(&cold, &golds);
        let warm_acc = accuracy(&warm, &golds);

        t.row(vec![
            dtype.name().to_string(),
            num(bytes_per_token),
            pct(cold_acc),
            pct(warm_acc),
            format!("{}", warm_stats.prefix_hit_tokens),
            num(dequant_ms),
            num(mean_reads * bytes_per_token),
            pct(agree),
        ]);
        json_rows.push(
            Json::obj()
                .set("kv_dtype", dtype.name())
                .set("bytes_per_token", bytes_per_token)
                .set("cold_accuracy", cold_acc)
                .set("warm_accuracy", warm_acc)
                .set("prefix_hit_tokens", warm_stats.prefix_hit_tokens as f64)
                .set("dequant_ms", dequant_ms)
                .set("mean_byte_reads", mean_reads * bytes_per_token)
                .set("warm_stream_agreement_vs_f32", agree),
        );
    }
    println!("{}", t.markdown());
    println!(
        "(cold passes are dtype-invariant by construction; warm passes \
         restore quantized prefix pages — see docs/NUMERICS.md)"
    );

    super::write_report(artifacts, "quant_bits", &Json::Arr(json_rows))?;
    Ok(())
}
