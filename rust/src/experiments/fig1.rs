//! Figure 1: average absolute Pareto improvement of DMS over vanilla
//! per task — the headline summary, computed from the Fig. 3/4 sweep
//! report (Tables 5/6 margins averaged per task).

use std::path::Path;

use anyhow::{anyhow, Result};

use super::pareto_exp::ParetoReport;
use super::reports_dir;
use crate::analysis::tables::Table;
use crate::scaling::margin;
use crate::util::Json;

pub fn run_fig1(artifacts: &Path) -> Result<()> {
    let path = reports_dir(artifacts).join("pareto.json");
    let j = Json::parse_file(&path)
        .map_err(|e| anyhow!("run `hyperscale exp fig3` first ({e})"))?;
    let report =
        ParetoReport::from_json(&j).ok_or_else(|| anyhow!("bad pareto.json"))?;

    println!("\n## Figure 1 (avg DMS improvement over vanilla, same KV budget)\n");
    let mut t = Table::new(&["task", "Δ accuracy (reads frontier)", "Δ accuracy (memory frontier)"]);
    let mut json_rows = Vec::new();
    for task in report.tasks() {
        let by = |peak: bool| {
            let d = report.frontier_of(&task, "dms", peak);
            let v = report.frontier_of(&task, "vanilla", peak);
            margin(&d, &v)
        };
        let fmt = |m: Option<f64>| {
            m.map(|x| format!("{:+.1}", 100.0 * x))
                .unwrap_or_else(|| "NA".into())
        };
        let (r, p) = (by(false), by(true));
        t.row(vec![task.clone(), fmt(r), fmt(p)]);
        json_rows.push(
            Json::obj()
                .set("task", task.as_str())
                .set("reads_margin", r.unwrap_or(f64::NAN))
                .set("memory_margin", p.unwrap_or(f64::NAN)),
        );
    }
    println!("{}", t.markdown());
    super::write_report(artifacts, "fig1", &Json::Arr(json_rows))?;
    Ok(())
}
