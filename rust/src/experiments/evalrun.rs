//! Shared evaluation harness: run one (task, method, L-W-CR) point
//! through the engine and score it.

use anyhow::Result;

use crate::compress::PolicyKind;
use crate::config::EngineConfig;
use crate::engine::{aggregate, Engine, GenRequest};
use crate::tasks::gen_problem;

/// One evaluation point specification.
#[derive(Clone, Debug)]
pub struct EvalSpec {
    pub task: String,
    pub policy: PolicyKind,
    /// Model variant tag; empty → policy default for the CR.
    pub variant: String,
    pub max_len: usize,
    pub width: usize,
    pub cr: f64,
    pub n_problems: usize,
    pub temperature: f64,
    pub seed: u64,
}

impl EvalSpec {
    pub fn new(task: &str, policy: PolicyKind, cr: f64) -> Self {
        Self {
            task: task.to_string(),
            policy,
            variant: String::new(),
            max_len: 160,
            width: 1,
            cr,
            n_problems: 12,
            temperature: 0.7,
            seed: 17,
        }
    }

    pub fn variant_tag(&self) -> String {
        if self.variant.is_empty() {
            self.policy.default_variant(self.cr).to_string()
        } else {
            self.variant.clone()
        }
    }

    pub fn label(&self) -> String {
        format!(
            "{}-{}-{} {} {}",
            self.max_len,
            self.width,
            self.cr,
            self.policy.name(),
            self.task
        )
    }
}

/// Scored outcome of one evaluation point.
#[derive(Clone, Debug)]
pub struct EvalOutcome {
    pub accuracy: f64,
    /// mean per-problem total KV reads (sum over the W chains).
    pub mean_reads: f64,
    /// `mean_reads` priced in bytes — token reads × the engine's
    /// full-model KV bytes per token under the serving dtype. The
    /// denominator of the paper's accuracy-per-memory-read frontier.
    pub mean_read_bytes: f64,
    /// mean per-problem peak tokens (sum over concurrent chains).
    pub mean_peak: f64,
    /// mean achieved compression ratio across chains.
    pub mean_achieved_cr: f64,
    pub n_problems: usize,
    /// mean generated tokens per chain.
    pub mean_gen_tokens: f64,
    pub wall_s: f64,
}

/// Engine pool that reuses one engine across points (the runtime caches
/// compiled executables and weights; only policy/variant switch).
pub struct Harness {
    engine: Engine,
}

impl Harness {
    pub fn new(cfg: EngineConfig) -> Result<Self> {
        Ok(Self {
            engine: Engine::new(cfg)?,
        })
    }

    pub fn engine_mut(&mut self) -> &mut Engine {
        &mut self.engine
    }

    /// Evaluate one point. Problems are generated deterministically
    /// from (task, seed); skipped if the prompt doesn't fit max_len.
    pub fn eval(&mut self, spec: &EvalSpec) -> Result<EvalOutcome> {
        self.engine.set_variant(&spec.variant_tag())?;
        self.engine.set_policy(spec.policy, spec.cr)?;
        let t0 = std::time::Instant::now();

        let mut requests = Vec::new();
        let mut golds = Vec::new();
        let mut idx = 0u64;
        while requests.len() < spec.n_problems {
            let p = gen_problem(&spec.task, spec.seed, idx);
            idx += 1;
            // prompt + <bos> + a little generation room must fit
            if p.prompt.len() + 24 > spec.max_len {
                if idx > spec.n_problems as u64 * 20 {
                    break; // task simply doesn't fit this budget
                }
                continue;
            }
            requests.push(GenRequest {
                prompt: p.prompt.clone(),
                width: spec.width,
                max_len: spec.max_len,
                temperature: if spec.width > 1 {
                    spec.temperature.max(0.3)
                } else {
                    spec.temperature
                },
                seed: spec.seed.wrapping_mul(31).wrapping_add(idx),
            });
            golds.push(p.answer);
        }
        if requests.is_empty() {
            return Ok(EvalOutcome {
                accuracy: 0.0,
                mean_reads: 0.0,
                mean_read_bytes: 0.0,
                mean_peak: 0.0,
                mean_achieved_cr: 1.0,
                n_problems: 0,
                mean_gen_tokens: 0.0,
                wall_s: 0.0,
            });
        }

        let (results, _stats) = self.engine.run(&requests)?;
        let mut correct = 0usize;
        let mut reads = 0.0;
        let mut peak = 0.0;
        let mut crs = 0.0;
        let mut gen_tokens = 0.0;
        let mut chains = 0usize;
        for (res, gold) in results.iter().zip(&golds) {
            let texts = res.texts();
            if aggregate(&spec.task, &texts, gold) {
                correct += 1;
            }
            reads += res.total_reads();
            peak += res.total_peak_tokens();
            for c in &res.chains {
                crs += c.stats.achieved_cr();
                gen_tokens += c.stats.gen_tokens as f64;
                chains += 1;
            }
        }
        let n = results.len() as f64;
        Ok(EvalOutcome {
            accuracy: correct as f64 / n,
            mean_reads: reads / n,
            mean_read_bytes: (reads / n) * self.engine.kv_bytes_per_token(),
            mean_peak: peak / n,
            mean_achieved_cr: crs / chains.max(1) as f64,
            n_problems: results.len(),
            mean_gen_tokens: gen_tokens / chains.max(1) as f64,
            wall_s: t0.elapsed().as_secs_f64(),
        })
    }
}

/// One-shot convenience used by tests and the CLI.
pub fn eval_point(cfg: EngineConfig, spec: &EvalSpec) -> Result<EvalOutcome> {
    Harness::new(cfg)?.eval(spec)
}
