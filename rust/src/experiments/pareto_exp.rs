//! Figures 3 & 4 + Tables 5/6: the inference-time hyper-scaling sweep.
//!
//! One sweep over methods × L-W-CR configurations × tasks collects
//! (accuracy, KV reads, peak tokens) per point; Fig. 3 plots accuracy
//! vs reads, Fig. 4 accuracy vs peak memory, and Tables 5/6 integrate
//! the frontier margins (App. E).

use std::path::Path;

use anyhow::Result;

use super::evalrun::{EvalSpec, Harness};
use crate::analysis::tables::{num, pct, Table};
use crate::compress::PolicyKind;
use crate::config::EngineConfig;
use crate::scaling::{frontier, margin, Frontier, ScalePoint};
use crate::util::Json;

/// All measured points of the sweep.
pub struct ParetoReport {
    /// (task, policy-name, L-W-CR label, accuracy, reads, peak)
    pub rows: Vec<(String, String, String, f64, f64, f64)>,
}

impl ParetoReport {
    /// Frontier of `policy` on `task` along reads (fig3) or peak (fig4).
    pub fn frontier_of(&self, task: &str, policy: &str, by_peak: bool) -> Frontier {
        let pts: Vec<ScalePoint> = self
            .rows
            .iter()
            .filter(|(t, p, ..)| t == task && p == policy)
            .map(|(_, _, label, acc, reads, peak)| ScalePoint {
                budget: if by_peak { *peak } else { *reads },
                accuracy: *acc,
                label: label.clone(),
            })
            .collect();
        frontier(&pts)
    }

    pub fn tasks(&self) -> Vec<String> {
        let mut v: Vec<String> = self.rows.iter().map(|r| r.0.clone()).collect();
        v.sort();
        v.dedup();
        v
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.rows
                .iter()
                .map(|(t, p, l, a, r, m)| {
                    Json::obj()
                        .set("task", t.as_str())
                        .set("policy", p.as_str())
                        .set("config", l.as_str())
                        .set("accuracy", *a)
                        .set("reads", *r)
                        .set("peak", *m)
                })
                .collect(),
        )
    }

    pub fn from_json(j: &Json) -> Option<Self> {
        let mut rows = Vec::new();
        for item in j.as_arr()? {
            rows.push((
                item.get("task")?.as_str()?.to_string(),
                item.get("policy")?.as_str()?.to_string(),
                item.get("config")?.as_str()?.to_string(),
                item.get("accuracy")?.as_f64()?,
                item.get("reads")?.as_f64()?,
                item.get("peak")?.as_f64()?,
            ));
        }
        Some(Self { rows })
    }
}

/// The scaled-down L-W-CR grid (see DESIGN.md §2). `full` widens it.
fn grid(policy: PolicyKind, full: bool) -> Vec<(usize, usize, f64)> {
    let lens: &[usize] = if full { &[96, 160, 256] } else { &[96, 192] };
    let widths: &[usize] = if full { &[1, 2, 4, 8] } else { &[1, 4] };
    let crs: &[f64] = match policy {
        PolicyKind::Vanilla => &[1.0],
        PolicyKind::Dms => &[4.0, 8.0],
        _ => &[4.0],
    };
    let mut out = Vec::new();
    for &l in lens {
        for &w in widths {
            for &cr in crs {
                out.push((l, w, cr));
            }
        }
    }
    out
}

/// Run the sweep. Methods follow the paper's figures: DMS + vanilla +
/// Quest (reads frontier) + TOVA (memory frontier).
pub fn run_pareto(
    artifacts: &Path,
    tasks: &[String],
    n_problems: usize,
    full: bool,
) -> Result<ParetoReport> {
    let cfg = EngineConfig::paper_fidelity(artifacts);
    let mut harness = Harness::new(cfg)?;
    let methods = [
        PolicyKind::Vanilla,
        PolicyKind::Dms,
        PolicyKind::Quest,
        PolicyKind::Tova,
    ];
    let mut rows = Vec::new();
    for task in tasks {
        for &policy in &methods {
            for (l, w, cr) in grid(policy, full) {
                let mut spec = EvalSpec::new(task, policy, cr);
                spec.max_len = l;
                spec.width = w;
                spec.n_problems = n_problems;
                let out = harness.eval(&spec)?;
                if out.n_problems == 0 {
                    continue;
                }
                crate::info!(
                    "{task} {} {}-{}-{}: acc {:.2} reads {:.0} peak {:.0} ({:.1}s)",
                    policy.name(),
                    l,
                    w,
                    cr,
                    out.accuracy,
                    out.mean_reads,
                    out.mean_peak,
                    out.wall_s
                );
                rows.push((
                    task.clone(),
                    policy.name().to_string(),
                    format!("{l}-{w}-{cr}"),
                    out.accuracy,
                    out.mean_reads,
                    out.mean_peak,
                ));
            }
        }
    }
    let report = ParetoReport { rows };
    super::write_report(artifacts, "pareto", &report.to_json())?;
    print_pareto_tables(&report);
    Ok(report)
}

/// Render Fig. 3/4 frontiers + Tables 5/6 margins as markdown.
pub fn print_pareto_tables(report: &ParetoReport) {
    for by_peak in [false, true] {
        let (fig, t_no, base) = if by_peak {
            ("Figure 4 (accuracy vs peak tokens)", "Table 6", "tova")
        } else {
            ("Figure 3 (accuracy vs KV reads)", "Table 5", "quest")
        };
        println!("\n## {fig}\n");
        for task in report.tasks() {
            println!("### {task}\n");
            let mut t = Table::new(&["policy", "frontier (budget→acc%)"]);
            for policy in ["vanilla", "dms", base] {
                let f = report.frontier_of(&task, policy, by_peak);
                let desc = f
                    .points
                    .iter()
                    .map(|p| format!("{}:{}→{}", p.label, num(p.budget), pct(p.accuracy)))
                    .collect::<Vec<_>>()
                    .join("  ");
                t.row(vec![policy.to_string(), desc]);
            }
            println!("{}", t.markdown());
        }
        println!("\n## {t_no} (App. E average frontier margins)\n");
        let mut t = Table::new(&["task", "DMS vs Vanilla", &format!("DMS vs {base}"),
                                 &format!("{base} vs Vanilla")]);
        for task in report.tasks() {
            let f_dms = report.frontier_of(&task, "dms", by_peak);
            let f_van = report.frontier_of(&task, "vanilla", by_peak);
            let f_base = report.frontier_of(&task, base, by_peak);
            let fmt = |m: Option<f64>| match m {
                Some(x) => format!("{:+.1}", 100.0 * x),
                None => "NA".to_string(),
            };
            t.row(vec![
                task.clone(),
                fmt(margin(&f_dms, &f_van)),
                fmt(margin(&f_dms, &f_base)),
                fmt(margin(&f_base, &f_van)),
            ]);
        }
        println!("{}", t.markdown());
    }
}
