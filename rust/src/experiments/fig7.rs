//! Figure 7 (App. G): percentage of step latency attributable to KV
//! cache reads, across batch sizes, sequence lengths, and CRs —
//! reproduced analytically with the paper's exact constants.

use std::path::Path;

use anyhow::Result;

use crate::analysis::latency_model::{LatencyModel, LlamaClass, H100};
use crate::analysis::tables::Table;
use crate::util::Json;

pub fn run_fig7(artifacts: &Path) -> Result<()> {
    let classes = [
        ("Llama 3.1 8B", LlamaClass::Llama8B),
        ("Qwen-R1 1.5B", LlamaClass::Qwen1_5B),
        ("Qwen-R1 7B", LlamaClass::Qwen7B),
        ("Qwen-R1 32B", LlamaClass::Qwen32B),
    ];
    let batches = [1usize, 8, 64, 256];
    let seqs = [1024usize, 4096, 8192, 16384, 32768];
    let mut json_rows = Vec::new();
    println!("\n## Figure 7 (% of step latency from KV cache reads, H100)\n");
    for (name, class) in classes {
        let m = LatencyModel::preset(class);
        for cr in [1.0f64, 4.0, 8.0] {
            println!("### {name}, CR {cr}×\n");
            let mut t = Table::new(&["batch \\ seq", "1K", "4K", "8K", "16K", "32K"]);
            for &b in &batches {
                let mut cells = vec![b.to_string()];
                for &s in &seqs {
                    let f = m.kv_latency_fraction(&H100, b as f64, s as f64, cr);
                    cells.push(format!("{:.1}", 100.0 * f));
                    json_rows.push(
                        Json::obj()
                            .set("model", name)
                            .set("cr", cr)
                            .set("batch", b)
                            .set("seq", s)
                            .set("kv_fraction", f),
                    );
                }
                t.row(cells);
            }
            println!("{}", t.markdown());
        }
    }
    super::write_report(artifacts, "fig7", &Json::Arr(json_rows))?;
    Ok(())
}
