//! Figure 5: retrofit ablations.
//! Left — delayed vs immediate eviction across windows and CRs.
//! Right — data efficiency: accuracy vs retrofit tokens, DMS vs DMC.
//!
//! The underlying numbers come from the retrofit snapshots evaluated at
//! build time (`artifacts/fig5_data.json`, produced by aot.py — that is
//! where training lives); this driver renders the two panels and adds
//! the Rust-engine endpoint check at CR4 for each variant.

use std::path::Path;

use anyhow::{anyhow, Result};

use super::evalrun::{EvalSpec, Harness};
use crate::analysis::tables::{pct, Table};
use crate::compress::PolicyKind;
use crate::config::EngineConfig;
use crate::util::Json;

pub fn run_fig5(artifacts: &Path, n_problems: usize) -> Result<()> {
    let data = Json::parse_file(&artifacts.join("fig5_data.json"))
        .map_err(|e| anyhow!("fig5_data.json missing (run make artifacts): {e}"))?;

    println!("\n## Figure 5 left (GSM8K 0-shot: delayed vs immediate eviction)\n");
    let mut t = Table::new(&["variant", "CR2", "CR3", "CR4"]);
    for variant in ["dms_w4", "dms_w16", "dms_imm_w4", "dms_imm_w16"] {
        let mut cells = vec![variant.to_string()];
        for cr in [2.0, 3.0, 4.0] {
            let acc = data
                .get("delayed_vs_immediate")
                .and_then(Json::as_arr)
                .and_then(|rows| {
                    rows.iter().find(|r| {
                        r.get("variant").and_then(Json::as_str) == Some(variant)
                            && r.get("cr").and_then(|x| x.as_f64()) == Some(cr)
                    })
                })
                .and_then(|r| r.get("acc").and_then(|x| x.as_f64()));
            cells.push(acc.map(pct).unwrap_or_else(|| "-".into()));
        }
        t.row(cells);
    }
    println!("{}", t.markdown());

    println!("\n## Figure 5 right (data efficiency: accuracy vs retrofit tokens)\n");
    let mut t = Table::new(&["variant", "step", "tokens", "CR", "gsm8k acc%"]);
    if let Some(rows) = data.get("data_efficiency").and_then(Json::as_arr) {
        for r in rows {
            t.row(vec![
                r.get("variant").and_then(Json::as_str).unwrap_or("-").into(),
                format!("{}", r.get("step").and_then(|x| x.as_i64()).unwrap_or(0)),
                format!("{}", r.get("tokens").and_then(|x| x.as_i64()).unwrap_or(0)),
                format!("{:.1}", r.get("cr").and_then(|x| x.as_f64()).unwrap_or(0.0)),
                r.get("acc")
                    .and_then(|x| x.as_f64())
                    .map(pct)
                    .unwrap_or_else(|| "-".into()),
            ]);
        }
    }
    println!("{}", t.markdown());

    // endpoint cross-check through the full Rust inference stack
    println!("\n### Engine endpoint check (CR4 variants on gsm8k, greedy)\n");
    let cfg = EngineConfig {
        temperature: 0.0,
        ..EngineConfig::paper_fidelity(artifacts)
    };
    let mut harness = Harness::new(cfg)?;
    let mut t = Table::new(&["variant", "policy", "acc%", "achieved CR"]);
    for (variant, policy) in [
        ("base", PolicyKind::Vanilla),
        ("dms_w16_cr4", PolicyKind::Dms),
        ("dms_imm_w16", PolicyKind::DmsImmediate),
        ("dmc", PolicyKind::Dmc),
    ] {
        let mut spec = EvalSpec::new("gsm8k", policy, 4.0);
        spec.variant = variant.to_string();
        spec.temperature = 0.0;
        spec.n_problems = n_problems;
        let out = harness.eval(&spec)?;
        t.row(vec![
            variant.into(),
            policy.name().into(),
            pct(out.accuracy),
            format!("{:.2}", out.mean_achieved_cr),
        ]);
    }
    println!("{}", t.markdown());
    Ok(())
}
