//! Allocator sweep: accuracy-per-byte of uniform vs pyramid vs
//! adaptive per-(layer, head) budget plans on the golden tasks.
//!
//! For each allocator, every budgeted training-free policy (TOVA, H2O,
//! window) runs the same task points over a CR × L grid; by plan
//! conservation all allocators spend the **same global budget** per
//! point, so any accuracy difference is purely the *shape* of the
//! plan. Two byte axes are reported:
//!
//! * `plan B` — the plan-aggregate footprint
//!   ([`plan_kv_bytes`](crate::scaling::plan_kv_bytes)): identical
//!   across allocators by construction (the conservation check);
//! * `peak B` — measured peak resident tokens × bytes/token: what the
//!   chains actually held, the budget axis of the Pareto extraction.
//!
//! The sweep ends with per-allocator Pareto frontiers over
//! (peak bytes, accuracy) and the App. E average margin of each
//! non-uniform allocator over uniform.
//!
//! This is intentionally *not* paper-fidelity in one respect: the
//! paper's tables pin the uniform App. F.1 budget
//! (`EngineConfig::paper_fidelity`); this driver measures the
//! non-uniform extension. Everything else (no prefix cache, f32
//! payloads) follows the fidelity pins.

use std::path::Path;

use anyhow::Result;

use crate::analysis::tables::{num, pct, Table};
use crate::compress::{build_allocator, AllocatorKind, PolicyKind};
use crate::config::EngineConfig;
use crate::scaling::{frontier, kv_bytes_per_token, margin, plan_kv_bytes, Frontier, ScalePoint};
use crate::util::Json;

use super::{EvalSpec, Harness};

const TASK: &str = "math";

pub fn run_alloc_sweep(artifacts: &Path, n_problems: usize) -> Result<()> {
    let policies = [PolicyKind::Tova, PolicyKind::H2o, PolicyKind::Window];
    let crs = [4.0f64, 8.0];
    let lens = [96usize, 160];

    println!("\n## Allocator sweep — accuracy per byte, {TASK} ({n_problems} problems)\n");
    let mut t = Table::new(&[
        "allocator", "policy", "CR", "L", "acc", "plan B", "peak B", "reads B",
    ]);
    let mut outcomes: Vec<(AllocatorKind, Frontier)> = Vec::new();
    let mut json_rows = Vec::new();

    for alloc in AllocatorKind::all() {
        let cfg = EngineConfig {
            allocator: alloc,
            ..EngineConfig::paper_fidelity(artifacts)
        };
        let mut harness = Harness::new(cfg)?;
        let geom = harness.engine_mut().geometry();
        let dtype = harness.engine_mut().cfg.kv_dtype;
        let bytes_per_token =
            kv_bytes_per_token(dtype, geom.layers, geom.kv_heads, geom.head_dim);
        let mut points = Vec::new();
        for policy in policies {
            for cr in crs {
                for max_len in lens {
                    let mut spec = EvalSpec::new(TASK, policy, cr);
                    spec.max_len = max_len;
                    spec.n_problems = n_problems;
                    let out = harness.eval(&spec)?;
                    if out.n_problems == 0 {
                        continue;
                    }
                    // the admission-time plan, rebuilt with the same
                    // budget derivation the engine uses (variant
                    // window is the clamp floor; eval just loaded the
                    // point's variant). Adaptive re-plans from live
                    // stats later; totals are conserved either way —
                    // which is exactly the point.
                    let window = harness.engine_mut().variant_window();
                    let per_head =
                        crate::compress::per_head_budget(cr, max_len, window);
                    let plan = build_allocator(alloc).plan(
                        geom.layers,
                        geom.kv_heads,
                        per_head * geom.lh(),
                        None,
                    );
                    let plan_bytes = plan_kv_bytes(
                        &plan,
                        geom.layers,
                        geom.kv_heads,
                        dtype,
                        geom.head_dim,
                    );
                    let peak_bytes = out.mean_peak * bytes_per_token;
                    let reads_bytes = out.mean_reads * bytes_per_token;
                    let label = format!("{}-{}-{}", max_len, policy.name(), cr);
                    t.row(vec![
                        alloc.name().to_string(),
                        policy.name().to_string(),
                        format!("{cr}"),
                        format!("{max_len}"),
                        pct(out.accuracy),
                        num(plan_bytes),
                        num(peak_bytes),
                        num(reads_bytes),
                    ]);
                    json_rows.push(
                        Json::obj()
                            .set("allocator", alloc.name())
                            .set("policy", policy.name())
                            .set("cr", cr)
                            .set("max_len", max_len as f64)
                            .set("accuracy", out.accuracy)
                            .set("plan_bytes", plan_bytes)
                            .set(
                                "plan_effective_cr",
                                plan.effective_cr(max_len, geom.layers, geom.kv_heads),
                            )
                            .set("peak_bytes", peak_bytes)
                            .set("reads_bytes", reads_bytes),
                    );
                    points.push(ScalePoint {
                        budget: peak_bytes,
                        accuracy: out.accuracy,
                        label,
                    });
                }
            }
        }
        outcomes.push((alloc, frontier(&points)));
    }
    println!("{}", t.markdown());

    // Pareto extraction + App. E margins vs the uniform baseline
    println!("### Pareto frontiers (peak bytes → accuracy)\n");
    for (alloc, front) in &outcomes {
        let pts: Vec<String> = front
            .points
            .iter()
            .map(|p| format!("({:.0} B, {:.2})", p.budget, p.accuracy))
            .collect();
        println!("- {}: {}", alloc.name(), pts.join(" → "));
    }
    let uniform = outcomes[0].1.clone();
    let mut margins = Json::obj();
    for (alloc, front) in outcomes.iter().skip(1) {
        match margin(front, &uniform) {
            Some(m) => {
                println!(
                    "margin({} − uniform) = {:+.4} accuracy over the common byte range",
                    alloc.name(),
                    m
                );
                margins = margins.set(alloc.name(), m);
            }
            None => println!(
                "margin({} − uniform): NA (disjoint byte ranges)",
                alloc.name()
            ),
        }
    }

    let report = Json::obj()
        .set("points", Json::Arr(json_rows))
        .set("margins_vs_uniform", margins);
    super::write_report(artifacts, "alloc_sweep", &report)?;
    Ok(())
}
