//! Tables 7/8/9: direct point comparisons at fixed L, W = 1 — vanilla
//! vs DMS CR4 vs Quest CR4 (Table 7), vs TOVA CR4 (Table 8), and
//! vanilla vs DMS CR8 (Table 9).

use std::path::Path;

use anyhow::Result;

use super::evalrun::{EvalSpec, Harness};
use crate::analysis::tables::{pct, Table};
use crate::compress::PolicyKind;
use crate::config::EngineConfig;
use crate::util::Json;

const TASKS: [&str; 4] = ["aime", "math", "gpqa", "lcb"];

pub fn run_points(artifacts: &Path, n_problems: usize) -> Result<()> {
    let cfg = EngineConfig::paper_fidelity(artifacts);
    let mut harness = Harness::new(cfg)?;

    let mut json_rows = Vec::new();
    let mut eval = |task: &str, policy: PolicyKind, cr: f64, variant: &str,
                    max_len: usize, harness: &mut Harness|
     -> Result<f64> {
        let mut spec = EvalSpec::new(task, policy, cr);
        if !variant.is_empty() {
            spec.variant = variant.to_string();
        }
        spec.max_len = max_len;
        spec.width = 1;
        spec.temperature = 0.0;
        spec.n_problems = n_problems;
        let out = harness.eval(&spec)?;
        json_rows.push(
            Json::obj()
                .set("task", task)
                .set("policy", policy.name())
                .set("cr", cr)
                .set("max_len", max_len)
                .set("accuracy", out.accuracy),
        );
        Ok(out.accuracy)
    };

    // Tables 7/8: vanilla vs {DMS, Quest, TOVA} at CR4
    println!("\n## Tables 7/8 (fixed L, W=1, CR4 point comparisons)\n");
    let mut t = Table::new(&["task", "L", "vanilla", "DMS CR4", "Quest CR4", "TOVA CR4"]);
    for task in TASKS {
        let max_len = if task == "lcb" { 160 } else { 192 };
        let v = eval(task, PolicyKind::Vanilla, 1.0, "base", max_len, &mut harness)?;
        let d = eval(task, PolicyKind::Dms, 4.0, "dms_w16_cr4", max_len, &mut harness)?;
        let q = eval(task, PolicyKind::Quest, 4.0, "base", max_len, &mut harness)?;
        let o = eval(task, PolicyKind::Tova, 4.0, "base", max_len, &mut harness)?;
        t.row(vec![
            task.to_string(),
            max_len.to_string(),
            pct(v),
            pct(d),
            pct(q),
            pct(o),
        ]);
    }
    println!("{}", t.markdown());

    // Table 9: vanilla vs DMS CR8
    println!("\n## Table 9 (vanilla vs DMS CR8)\n");
    let mut t = Table::new(&["task", "L", "vanilla", "DMS CR8"]);
    for task in TASKS {
        let max_len = if task == "lcb" { 160 } else { 192 };
        let v = eval(task, PolicyKind::Vanilla, 1.0, "base", max_len, &mut harness)?;
        let d = eval(task, PolicyKind::Dms, 8.0, "dms_w16_cr8", max_len, &mut harness)?;
        t.row(vec![task.to_string(), max_len.to_string(), pct(v), pct(d)]);
    }
    println!("{}", t.markdown());

    super::write_report(artifacts, "points", &Json::Arr(json_rows))?;
    Ok(())
}
