//! Figure 6: learned compression behaviour of DMS.
//! Left — measured CR as a function of generated-sequence position.
//! Right — per-(layer, head) retention (percentage of tokens kept).

use std::path::Path;

use anyhow::Result;

use crate::analysis::tables::{num, Table};
use crate::compress::PolicyKind;
use crate::config::EngineConfig;
use crate::engine::{Engine, GenRequest};
use crate::tasks::gen_problem;
use crate::util::Json;

pub fn run_fig6(artifacts: &Path, n_problems: usize) -> Result<()> {
    let mut engine = Engine::new(EngineConfig {
        variant: "dms_w16_cr4".into(),
        policy: PolicyKind::Dms,
        cr: 4.0,
        temperature: 0.7,
        ..EngineConfig::paper_fidelity(artifacts)
    })?;

    // collect eviction decisions per position bucket + per-head retention
    let geom = engine.geometry();
    let lh = geom.lh();
    let bucket = 16usize;
    let mut decided = vec![0u64; 20]; // evictions per bucket
    let mut seen = vec![0u64; 20];    // decisions per bucket
    let mut retained: Vec<(u64, u64)> = vec![(0, 0); lh];

    for task in ["math", "aime", "gpqa"] {
        let mut requests = Vec::new();
        for i in 0..n_problems as u64 {
            let p = gen_problem(task, 55, i);
            if p.prompt.len() + 24 > 256 {
                continue;
            }
            requests.push(GenRequest {
                prompt: p.prompt,
                width: 1,
                max_len: 256,
                temperature: 0.7,
                seed: i,
            });
        }
        let (results, _) = engine.run(&requests)?;
        for r in results {
            for c in r.chains {
                let start = c.stats.prompt_tokens;
                for (i, &e) in c.stats.evictions_per_pos.iter().enumerate() {
                    let b = ((start + i) / bucket).min(19);
                    decided[b] += e as u64;
                    seen[b] += lh as u64;
                }
                for (i, &(live, total)) in c.stats.retained_per_lh.iter().enumerate() {
                    retained[i].0 += live as u64;
                    retained[i].1 += total as u64;
                }
            }
        }
    }

    println!("\n## Figure 6 left (measured CR vs sequence position, DMS CR4)\n");
    let mut t = Table::new(&["position bucket", "evict rate", "local CR"]);
    let mut json_rows = Vec::new();
    for b in 0..20 {
        if seen[b] == 0 {
            continue;
        }
        let rate = decided[b] as f64 / seen[b] as f64;
        let cr = 1.0 / (1.0 - rate).max(1e-3);
        t.row(vec![
            format!("{}-{}", b * bucket, (b + 1) * bucket),
            format!("{:.3}", rate),
            num(cr),
        ]);
        json_rows.push(
            Json::obj()
                .set("bucket", b)
                .set("evict_rate", rate)
                .set("local_cr", cr),
        );
    }
    println!("{}", t.markdown());

    println!("\n## Figure 6 right (retained tokens per layer/head, % kept)\n");
    let mut t = Table::new(&["layer", "head", "kept %"]);
    let mut per_lh = Vec::new();
    for l in 0..geom.layers {
        for h in 0..geom.kv_heads {
            let (live, total) = retained[l * geom.kv_heads + h];
            let kept = if total == 0 {
                1.0
            } else {
                live as f64 / total as f64
            };
            t.row(vec![
                l.to_string(),
                h.to_string(),
                format!("{:.1}", 100.0 * kept),
            ]);
            per_lh.push(
                Json::obj()
                    .set("layer", l)
                    .set("head", h)
                    .set("kept", kept),
            );
        }
    }
    println!("{}", t.markdown());
    super::write_report(
        artifacts,
        "fig6",
        &Json::obj()
            .set("cr_vs_position", Json::Arr(json_rows))
            .set("retention", Json::Arr(per_lh)),
    )?;
    Ok(())
}
