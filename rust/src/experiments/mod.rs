//! Experiment drivers — one per paper figure/table (DESIGN.md §4).
//!
//! Every driver prints the regenerated rows/series in markdown and
//! writes a JSON record under `reports/` for EXPERIMENTS.md.

mod alloc_sweep;
mod evalrun;
mod fig1;
mod fig5;
mod fig6;
mod fig7;
mod pareto_exp;
mod points;
mod quant_bits;
mod table1;
mod table2;

pub use alloc_sweep::run_alloc_sweep;
pub use evalrun::{eval_point, EvalOutcome, EvalSpec, Harness};
pub use fig1::run_fig1;
pub use fig5::run_fig5;
pub use fig6::run_fig6;
pub use fig7::run_fig7;
pub use pareto_exp::{run_pareto, ParetoReport};
pub use points::run_points;
pub use quant_bits::run_quant_bits;
pub use table1::run_table1;
pub use table2::run_table2;

use std::path::{Path, PathBuf};

use crate::util::Json;

/// Where JSON experiment records land.
pub fn reports_dir(artifacts: &Path) -> PathBuf {
    let dir = artifacts
        .parent()
        .unwrap_or_else(|| Path::new("."))
        .join("reports");
    let _ = std::fs::create_dir_all(&dir);
    dir
}

/// Write a JSON report file.
pub fn write_report(artifacts: &Path, name: &str, json: &Json) -> crate::Result<PathBuf> {
    let path = reports_dir(artifacts).join(format!("{name}.json"));
    std::fs::write(&path, json.to_pretty())?;
    crate::info!("report -> {}", path.display());
    Ok(path)
}
