//! `hyperscale` CLI — leader entrypoint.
//!
//! Subcommands:
//!   gen        generate from a prompt (quick smoke)
//!   eval       evaluate one (task, policy, L-W-CR) point
//!   exp <id>   regenerate a paper figure/table (fig1 fig3 fig4 fig5
//!              fig6 fig7 table1 table2 table7 — see DESIGN.md §4)
//!   serve      run the TCP line-JSON server
//!   sim        discrete-event cluster timing simulation (no artifacts)
//!   inspect    print manifest/artifact info
//!   selftest   load artifacts and run a tiny end-to-end generation

use std::path::PathBuf;

use hyperscale::compress::PolicyKind;
use hyperscale::config::{ClusterConfig, EngineConfig};
use hyperscale::engine::{Engine, GenRequest};
use hyperscale::experiments as exp;
use hyperscale::util::{log, Args};
use hyperscale::{info, Result};

fn main() {
    let args = Args::from_env();
    if args.flag("debug") {
        log::set_level(3);
    }
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> &'static str {
    "usage: hyperscale <gen|eval|exp|serve|sim|inspect|selftest> [options]\n\
     common options: --artifacts DIR --variant TAG --policy NAME --cr X\n\
                     --kv-dtype f32|q8|q4 (pool payload precision)\n\
                     --allocator uniform|pyramid|adaptive (per-head KV budgets)\n\
                     --replan-interval N (adaptive re-plan cadence)\n\
                     --cold-tier-bytes N (cold-tier budget for demoted prefix\n\
                     pages; 0 = off) --cold-dtype f32|q8|q4 --spill-dir DIR\n\
       gen      --prompt 'Q:1+2=?\\nT:' [--width W] [--max-len L] [--temp T]\n\
       eval     --task math [--width W] [--max-len L] [--n N]\n\
       exp      fig1|fig3|fig4|fig5|fig6|fig7|table1|table2|table7|quant|alloc\n\
                [--n N] [--full]\n\
       serve    [--addr 127.0.0.1:7333] [--no-prefix-cache] [--prefix-pages N]\n\
                [--replicas N] [--routing prefix|least-loaded|round-robin]\n\
                [--no-steal] [--trace] [--trace-events N]\n\
                [--trace-out FILE] [--prom-out FILE]\n\
       sim      [--replicas N] [--lanes N] [--requests N] [--seed S]\n\
                [--routing ...] [--no-steal] [--arrival uniform|poisson|bursty|diurnal]\n\
                [--mean-gap-us X] [--prompts N] [--fail-replica I --fail-at-ms T]\n\
                [--cold-prompts N] (per-replica cold-tier capacity in prompts)\n\
                [--trace-out FILE] [--metrics]\n\
                [--slo] (mixed chat/long-context/voting workload under EDF +\n\
                admission control; --slo-fcfs for the FCFS/open baseline)\n\
       inspect  | selftest"
}

fn engine_cfg(args: &Args) -> Result<EngineConfig> {
    EngineConfig::default().with_args(args)
}

fn dispatch(args: &Args) -> Result<()> {
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("");
    match cmd {
        "gen" => cmd_gen(args),
        "eval" => cmd_eval(args),
        "exp" => cmd_exp(args),
        "serve" => {
            let mut cfg = engine_cfg(args)?;
            let ccfg = ClusterConfig::default().with_args(args)?;
            // asking for a trace dump implies tracing
            if args.get("trace-out").is_some() && cfg.trace_events == 0 {
                cfg.trace_events = hyperscale::trace::DEFAULT_CAPACITY;
            }
            let addr = args.get_str("addr", "127.0.0.1:7333");
            let opts = hyperscale::server::ServeOpts {
                trace_out: args.get("trace-out").map(PathBuf::from),
                prom_out: args.get("prom-out").map(PathBuf::from),
            };
            if ccfg.replicas > 1 {
                hyperscale::server::serve_cluster_with(cfg, ccfg, addr, opts)
            } else {
                hyperscale::server::serve_with(cfg, addr, opts)
            }
        }
        "sim" => cmd_sim(args),
        "inspect" => cmd_inspect(args),
        "selftest" => cmd_selftest(args),
        _ => {
            println!("{}", usage());
            Ok(())
        }
    }
}

fn cmd_gen(args: &Args) -> Result<()> {
    let mut cfg = engine_cfg(args)?;
    // convenience: picking a DMS/DMC policy implies its default variant
    if args.get("variant").is_none() && cfg.policy != PolicyKind::Vanilla {
        cfg.variant = cfg.policy.default_variant(cfg.cr).to_string();
    }
    let mut engine = Engine::new(cfg)?;
    let prompt = args
        .get("prompt")
        .map(|s| s.replace("\\n", "\n"))
        .unwrap_or_else(|| "Q:7+5-3=?\nT:".to_string());
    let req = GenRequest {
        prompt,
        width: args.get_usize("width", 1)?,
        max_len: args.get_usize("max-len", 160)?,
        temperature: args.get_f64("temp", 0.0)?,
        seed: args.get_usize("seed", 0)? as u64,
    };
    let res = engine.generate(req)?;
    for (i, c) in res.chains.iter().enumerate() {
        println!(
            "chain {i}: {:?} ({:?}, reads {:.0}, peak {:.1}, CR {:.2})",
            c.text,
            c.finish,
            c.stats.total_reads(),
            c.stats.peak_tokens,
            c.stats.achieved_cr()
        );
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let cfg = engine_cfg(args)?;
    let policy = cfg.policy;
    let cr = cfg.cr;
    let mut spec = exp::EvalSpec::new(args.get_str("task", "math"), policy, cr);
    spec.max_len = args.get_usize("max-len", 160)?;
    spec.width = args.get_usize("width", 1)?;
    spec.n_problems = args.get_usize("n", 12)?;
    spec.temperature = args.get_f64("temp", 0.7)?;
    if let Some(v) = args.get("variant") {
        spec.variant = v.to_string();
    }
    let out = exp::eval_point(cfg, &spec)?;
    println!(
        "{}: acc {:.3} reads {:.0} ({:.2} MB) peak {:.1} CR {:.2} gen {:.0} tok \
         ({} problems, {:.1}s)",
        spec.label(),
        out.accuracy,
        out.mean_reads,
        out.mean_read_bytes / 1e6,
        out.mean_peak,
        out.mean_achieved_cr,
        out.mean_gen_tokens,
        out.n_problems,
        out.wall_s
    );
    Ok(())
}

fn cmd_exp(args: &Args) -> Result<()> {
    let artifacts = PathBuf::from(args.get_str("artifacts", "artifacts"));
    let n = args.get_usize("n", 12)?;
    let full = args.flag("full");
    let which = args.positional.get(1).map(|s| s.as_str()).unwrap_or("");
    match which {
        "fig1" => exp::run_fig1(&artifacts),
        "fig3" | "fig4" | "pareto" => {
            let tasks = hyperscale::config::parse_tasks(
                args.get("tasks"),
                &["math", "aime", "gpqa", "lcb"],
            )?;
            exp::run_pareto(&artifacts, &tasks, n, full).map(|_| ())
        }
        "fig5" => exp::run_fig5(&artifacts, n),
        "fig6" => exp::run_fig6(&artifacts, n),
        "fig7" => exp::run_fig7(&artifacts),
        "table1" => exp::run_table1(&artifacts, n, args.flag("base")),
        "table2" => exp::run_table2(&artifacts, n),
        "table7" | "table8" | "table9" | "points" => exp::run_points(&artifacts, n),
        "quant" => exp::run_quant_bits(&artifacts, n),
        "alloc" | "allocators" => exp::run_alloc_sweep(&artifacts, n),
        other => anyhow::bail!("unknown experiment '{other}'\n{}", usage()),
    }
}

fn cmd_sim(args: &Args) -> Result<()> {
    use hyperscale::engine::slo::SloPolicy;
    use hyperscale::engine::timeflow::{
        simulate, simulate_slo, Arrival, ReplicaFailure, TimeflowConfig, WorkloadSpec,
    };
    use hyperscale::engine::workload::{generate_mixed_workload, slo_requests, WorkloadConfig};

    let ccfg = ClusterConfig::default().with_args(args)?;
    let ecfg = engine_cfg(args)?;
    let lanes = args.get_usize("lanes", 4)?;
    let mut cfg = TimeflowConfig::new(ccfg.replicas.max(1), lanes, ccfg.routing)
        .with_kv(ecfg.kv_dtype, ecfg.allocator);
    cfg.steal = ccfg.steal;
    cfg.cold_retain_prompts = args.get_usize("cold-prompts", 0)?;
    let trace_out = args.get("trace-out").map(PathBuf::from);
    cfg.record_trace = trace_out.is_some();
    if args.get("fail-at-ms").is_some() {
        cfg.failure = Some(ReplicaFailure {
            replica: args.get_usize("fail-replica", 0)?,
            at_ns: (args.get_f64("fail-at-ms", 0.0)? * 1e6) as u64,
        });
    }

    let requests = args.get_usize("requests", 100_000)?;
    let seed = args.get_usize("seed", 0)? as u64;
    let mean_gap_ns = (args.get_f64("mean-gap-us", 1250.0)? * 1e3) as u64;
    let n_prompts = args.get_usize("prompts", 64)?;
    let arrival_name = args.get_str("arrival", "poisson");
    let slo = args.flag("slo") || args.flag("slo-fcfs");

    let wall = std::time::Instant::now();
    let mut rep = if slo {
        let mut wcfg = WorkloadConfig::new(requests, seed);
        wcfg.arrival = arrival_name.parse()?;
        wcfg.mean_gap_ns = mean_gap_ns;
        wcfg.n_prompts = n_prompts;
        let reqs = slo_requests(&generate_mixed_workload(&wcfg));
        let policy = if args.flag("slo-fcfs") {
            SloPolicy::fcfs_open(cfg.replicas, cfg.lanes)
        } else {
            SloPolicy::edf_admitted(cfg.replicas, cfg.lanes)
        };
        simulate_slo(&cfg, &reqs, &policy)
    } else {
        let mut spec = WorkloadSpec::new(requests, seed);
        spec.arrival = arrival_name.parse::<Arrival>()?;
        spec.mean_gap_ns = mean_gap_ns;
        spec.n_prompts = n_prompts;
        simulate(&cfg, &spec)
    };
    let wall_s = wall.elapsed().as_secs_f64();
    println!(
        "sim [{}] replicas={} lanes={} arrival={} requests={}",
        rep.label, cfg.replicas, cfg.lanes, arrival_name, rep.requests
    );
    println!(
        "  completed {} failed {} stolen {} gen_tokens {}",
        rep.completed, rep.failed, rep.stolen, rep.gen_tokens
    );
    println!(
        "  ttft p50 {:.1}us p99 {:.1}us p999 {:.1}us | {:.0} tok/s | util {:.1}% | span {:.1}ms",
        rep.ttft_p50_ns / 1e3,
        rep.ttft_p99_ns / 1e3,
        rep.ttft_p999_ns / 1e3,
        rep.tokens_per_s,
        rep.utilization * 100.0,
        rep.span_ns as f64 / 1e6
    );
    if slo {
        let accepted = rep.registry.counter("serve.slo_accepted").get();
        let queued = rep.registry.counter("serve.slo_queued").get();
        let rejected = rep.registry.counter("serve.slo_rejected").get();
        let ttft_miss = rep.registry.counter("serve.slo_ttft_miss").get();
        let e2e_miss = rep.registry.counter("serve.slo_deadline_miss").get();
        println!(
            "  slo: accepted {accepted:.0} queued {queued:.0} rejected {rejected:.0} | \
             ttft_miss {ttft_miss:.0} e2e_miss {e2e_miss:.0} | goodput {:.0} tok/s",
            rep.slo_goodput_tokens_per_s
        );
    }
    println!("  simulated in {wall_s:.2}s wall");
    if let Some(path) = trace_out {
        std::fs::write(&path, rep.chrome_trace_json())?;
        println!(
            "  trace: {} stage spans -> {} (sim time; same seed => byte-identical)",
            rep.trace.len(),
            path.display()
        );
    }
    if args.flag("metrics") {
        print!("{}", rep.registry.report());
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let cfg = engine_cfg(args)?;
    let rt = hyperscale::runtime::Runtime::open(&cfg.artifacts)?;
    let m = &rt.manifest;
    println!(
        "model: d={} layers={} q_heads={} kv_heads={} head_dim={} vocab={}",
        m.config.d_model,
        m.config.n_layers,
        m.config.n_q_heads,
        m.config.n_kv_heads,
        m.config.head_dim,
        m.config.vocab
    );
    println!("variants:");
    for (name, v) in &m.variants {
        println!(
            "  {name:16} weights={} mode={} window={} immediate={}",
            v.weights, v.alpha_mode, v.window, v.immediate
        );
    }
    println!("executables:");
    for (name, e) in &m.executables {
        println!(
            "  {name:24} kind={} batch={} slots={} chunk={} pallas={}",
            e.kind, e.batch, e.slots, e.chunk, e.pallas
        );
    }
    Ok(())
}

fn cmd_selftest(args: &Args) -> Result<()> {
    let cfg = engine_cfg(args)?;
    let mut engine = Engine::new(cfg)?;
    let p = hyperscale::tasks::gen_problem("math", 1, 0);
    info!("prompt: {:?} gold: {}", p.prompt, p.answer);
    let res = engine.generate(GenRequest {
        prompt: p.prompt.clone(),
        width: 1,
        max_len: 120,
        temperature: 0.0,
        seed: 0,
    })?;
    let text = &res.chains[0].text;
    info!("generated: {text:?}");
    let ans = hyperscale::tasks::extract_answer(text);
    println!(
        "selftest: generated {} tokens, answer {:?} (gold {}), reads {:.0}",
        res.chains[0].stats.gen_tokens,
        ans,
        p.answer,
        res.total_reads()
    );
    Ok(())
}
