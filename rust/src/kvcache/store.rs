//! The cache store: flat executor-layout arrays + per-slot metadata.

use super::paged::PageAllocator;

pub const NEG_INF: f32 = -1e9;

/// Cache geometry (matches the exported executables).
#[derive(Clone, Copy, Debug)]
pub struct Geometry {
    pub layers: usize,
    pub kv_heads: usize,
    pub slots: usize,
    pub head_dim: usize,
    pub page_size: usize,
}

impl Geometry {
    pub fn pages(&self) -> usize {
        self.slots / self.page_size
    }
    /// (layer, kv-head) pair count.
    pub fn lh(&self) -> usize {
        self.layers * self.kv_heads
    }
}

/// Slot lifecycle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SlotState {
    Free,
    Live {
        /// Token position this slot holds (RoPE already applied).
        pos: u32,
        /// Scheduled eviction position (DMS delayed eviction), if any.
        evict_at: u32, // u32::MAX = none
        /// DMC merge count (number of tokens averaged into this slot).
        merges: u16,
    },
}

const NO_EVICT: u32 = u32::MAX;

/// Host-authoritative cache for all lanes of one executor.
pub struct CacheStore {
    pub geom: Geometry,
    pub batch: usize,
    /// f32[L, B, H, S, hd]
    k: Vec<f32>,
    /// f32[L, B, H, S, hd]
    v: Vec<f32>,
    /// f32[L, B, H, S] additive mask (0 live / NEG_INF dead)
    mask: Vec<f32>,
    /// f32[L, B, H, P, hd] Quest page bounds
    pmin: Vec<f32>,
    pmax: Vec<f32>,
    /// per (b, l, h): slot metadata + allocator
    meta: Vec<Vec<SlotState>>,
    alloc: Vec<PageAllocator>,
    live: Vec<usize>,
    /// most recently written live slot per (b, l, h) (DMC merge target)
    last_written: Vec<Option<usize>>,
}

impl CacheStore {
    pub fn new(geom: Geometry, batch: usize) -> Self {
        let n_lbh = batch * geom.lh();
        let kv_len = geom.layers * batch * geom.kv_heads * geom.slots * geom.head_dim;
        let pm_len = geom.layers * batch * geom.kv_heads * geom.pages() * geom.head_dim;
        Self {
            geom,
            batch,
            k: vec![0.0; kv_len],
            v: vec![0.0; kv_len],
            mask: vec![NEG_INF; geom.layers * batch * geom.kv_heads * geom.slots],
            pmin: vec![0.0; pm_len],
            pmax: vec![0.0; pm_len],
            meta: (0..n_lbh).map(|_| vec![SlotState::Free; geom.slots]).collect(),
            alloc: (0..n_lbh)
                .map(|_| PageAllocator::new(geom.slots, geom.page_size))
                .collect(),
            live: vec![0; n_lbh],
            last_written: vec![None; n_lbh],
        }
    }

    // ---------------- index helpers ----------------

    #[inline]
    fn lbh(&self, b: usize, l: usize, h: usize) -> usize {
        (b * self.geom.layers + l) * self.geom.kv_heads + h
    }

    #[inline]
    fn kv_base(&self, b: usize, l: usize, h: usize, s: usize) -> usize {
        let g = &self.geom;
        (((l * self.batch + b) * g.kv_heads + h) * g.slots + s) * g.head_dim
    }

    #[inline]
    fn mask_idx(&self, b: usize, l: usize, h: usize, s: usize) -> usize {
        let g = &self.geom;
        ((l * self.batch + b) * g.kv_heads + h) * g.slots + s
    }

    #[inline]
    fn page_base(&self, b: usize, l: usize, h: usize, p: usize) -> usize {
        let g = &self.geom;
        (((l * self.batch + b) * g.kv_heads + h) * g.pages() + p) * g.head_dim
    }

    // ---------------- raw views for the executor ----------------

    pub fn k_slice(&self) -> &[f32] {
        &self.k
    }
    pub fn v_slice(&self) -> &[f32] {
        &self.v
    }
    pub fn mask_slice(&self) -> &[f32] {
        &self.mask
    }
    pub fn pmin_slice(&self) -> &[f32] {
        &self.pmin
    }
    pub fn pmax_slice(&self) -> &[f32] {
        &self.pmax
    }

    // ---------------- slot ops ----------------

    pub fn alloc_slot(&mut self, b: usize, l: usize, h: usize) -> Option<usize> {
        let i = self.lbh(b, l, h);
        self.alloc[i].alloc()
    }

    /// Write a token's (k, v) into `slot` and mark it live.
    pub fn write(
        &mut self,
        b: usize,
        l: usize,
        h: usize,
        slot: usize,
        pos: usize,
        k: &[f32],
        v: &[f32],
    ) {
        let hd = self.geom.head_dim;
        debug_assert_eq!(k.len(), hd);
        let base = self.kv_base(b, l, h, slot);
        self.k[base..base + hd].copy_from_slice(k);
        self.v[base..base + hd].copy_from_slice(v);
        let mi = self.mask_idx(b, l, h, slot);
        self.mask[mi] = 0.0;
        let i = self.lbh(b, l, h);
        if !self.alloc[i].is_used(slot) {
            // caller may write into a pre-chosen slot (prefill fork);
            // claim it in the allocator bitmap.
            // PageAllocator has no direct claim API; emulate via scan.
            self.claim_slot(i, slot);
        }
        if !matches!(self.meta[i][slot], SlotState::Live { .. }) {
            self.live[i] += 1;
        }
        self.meta[i][slot] = SlotState::Live {
            pos: pos as u32,
            evict_at: NO_EVICT,
            merges: 0,
        };
        self.last_written[i] = Some(slot);
        self.update_page_bounds(b, l, h, slot, k);
    }

    fn claim_slot(&mut self, lbh: usize, slot: usize) {
        // allocate-until-hit then free the extras — slots are claimed
        // out of order only during fork/restore paths, which are rare.
        let mut extras = Vec::new();
        loop {
            match self.alloc[lbh].alloc() {
                Some(s) if s == slot => break,
                Some(s) => extras.push(s),
                None => break,
            }
        }
        for s in extras {
            self.alloc[lbh].free(s);
        }
    }

    fn update_page_bounds(&mut self, b: usize, l: usize, h: usize, slot: usize, k: &[f32]) {
        let page = slot / self.geom.page_size;
        let base = self.page_base(b, l, h, page);
        let i = self.lbh(b, l, h);
        // first key in page initializes the bounds
        let page_first = (page * self.geom.page_size..(page + 1) * self.geom.page_size)
            .filter(|&s| matches!(self.meta[i][s], SlotState::Live { .. }))
            .count()
            == 1;
        for (d, &kd) in k.iter().enumerate() {
            if page_first {
                self.pmin[base + d] = kd;
                self.pmax[base + d] = kd;
            } else {
                if kd < self.pmin[base + d] {
                    self.pmin[base + d] = kd;
                }
                if kd > self.pmax[base + d] {
                    self.pmax[base + d] = kd;
                }
            }
        }
    }

    /// DMC: merge (k, v) into the most recently written live slot via
    /// running weighted average. Falls back to no-op if none exists.
    pub fn merge_into_last(&mut self, b: usize, l: usize, h: usize, k: &[f32], v: &[f32]) -> bool {
        let i = self.lbh(b, l, h);
        let Some(slot) = self.last_written[i] else {
            return false;
        };
        let SlotState::Live { pos, evict_at, merges } = self.meta[i][slot] else {
            return false;
        };
        let n = merges as f32 + 1.0;
        let base = self.kv_base(b, l, h, slot);
        let hd = self.geom.head_dim;
        for d in 0..hd {
            self.k[base + d] = (self.k[base + d] * n + k[d]) / (n + 1.0);
            self.v[base + d] = (self.v[base + d] * n + v[d]) / (n + 1.0);
        }
        self.meta[i][slot] = SlotState::Live {
            pos,
            evict_at,
            merges: merges + 1,
        };
        let kk: Vec<f32> = self.k[base..base + hd].to_vec();
        self.update_page_bounds(b, l, h, slot, &kk);
        true
    }

    pub fn evict(&mut self, b: usize, l: usize, h: usize, slot: usize) {
        let i = self.lbh(b, l, h);
        if matches!(self.meta[i][slot], SlotState::Live { .. }) {
            self.meta[i][slot] = SlotState::Free;
            self.alloc[i].free(slot);
            self.live[i] -= 1;
            let mi = self.mask_idx(b, l, h, slot);
            self.mask[mi] = NEG_INF;
            if self.last_written[i] == Some(slot) {
                self.last_written[i] = None;
            }
        }
    }

    /// DMS delayed eviction: mark `slot` to be evicted at `evict_at`.
    pub fn schedule_eviction(&mut self, b: usize, l: usize, h: usize, slot: usize, evict_at: usize) {
        let i = self.lbh(b, l, h);
        if let SlotState::Live { pos, merges, .. } = self.meta[i][slot] {
            self.meta[i][slot] = SlotState::Live {
                pos,
                evict_at: evict_at as u32,
                merges,
            };
        }
    }

    /// Execute pending evictions whose time has come (pos >= evict_at).
    pub fn apply_due_evictions(&mut self, b: usize, pos: usize) {
        for l in 0..self.geom.layers {
            for h in 0..self.geom.kv_heads {
                let i = self.lbh(b, l, h);
                for s in 0..self.geom.slots {
                    if let SlotState::Live { evict_at, .. } = self.meta[i][s] {
                        if evict_at != NO_EVICT && pos as u32 >= evict_at {
                            self.evict(b, l, h, s);
                        }
                    }
                }
            }
        }
    }

    // ---------------- queries ----------------

    pub fn live_count(&self, b: usize, l: usize, h: usize) -> usize {
        self.live[self.lbh(b, l, h)]
    }

    /// Live tokens in token units: mean over (layer, head) pairs.
    pub fn live_tokens(&self, b: usize) -> f64 {
        let mut total = 0usize;
        for l in 0..self.geom.layers {
            for h in 0..self.geom.kv_heads {
                total += self.live[self.lbh(b, l, h)];
            }
        }
        total as f64 / self.geom.lh() as f64
    }

    pub fn allocated_pages(&self, b: usize, l: usize, h: usize) -> usize {
        self.alloc[self.lbh(b, l, h)].allocated_pages()
    }

    /// Fraction of this lane's slot capacity that is live (mean over
    /// the lane's (layer, head) pairs, in [0, 1]).
    pub fn lane_live_fraction(&self, b: usize) -> f64 {
        self.live_tokens(b) / self.geom.slots as f64
    }

    /// Fraction of the whole store's slot capacity that is live, across
    /// all lanes — the cache-pressure signal the scheduler's preemption
    /// watermark compares against.
    pub fn live_fraction(&self) -> f64 {
        let total: usize = self.live.iter().sum();
        total as f64 / (self.batch * self.geom.lh() * self.geom.slots) as f64
    }

    pub fn slot_state(&self, b: usize, l: usize, h: usize, s: usize) -> SlotState {
        self.meta[self.lbh(b, l, h)][s]
    }

    pub fn slot_pos(&self, b: usize, l: usize, h: usize, s: usize) -> Option<usize> {
        match self.meta[self.lbh(b, l, h)][s] {
            SlotState::Live { pos, .. } => Some(pos as usize),
            SlotState::Free => None,
        }
    }

    pub fn mask_value(&self, b: usize, l: usize, h: usize, s: usize) -> f32 {
        self.mask[self.mask_idx(b, l, h, s)]
    }

    pub fn k_at(&self, b: usize, l: usize, h: usize, s: usize) -> &[f32] {
        let base = self.kv_base(b, l, h, s);
        &self.k[base..base + self.geom.head_dim]
    }

    pub fn v_at(&self, b: usize, l: usize, h: usize, s: usize) -> &[f32] {
        let base = self.kv_base(b, l, h, s);
        &self.v[base..base + self.geom.head_dim]
    }

    pub fn pmin_at(&self, b: usize, l: usize, h: usize, p: usize) -> &[f32] {
        let base = self.page_base(b, l, h, p);
        &self.pmin[base..base + self.geom.head_dim]
    }

    pub fn pmax_at(&self, b: usize, l: usize, h: usize, p: usize) -> &[f32] {
        let base = self.page_base(b, l, h, p);
        &self.pmax[base..base + self.geom.head_dim]
    }

    /// Live slots of (b, l, h) with their positions (for policy evictors).
    pub fn live_slots(&self, b: usize, l: usize, h: usize) -> Vec<(usize, usize)> {
        let i = self.lbh(b, l, h);
        (0..self.geom.slots)
            .filter_map(|s| match self.meta[i][s] {
                SlotState::Live { pos, .. } => Some((s, pos as usize)),
                SlotState::Free => None,
            })
            .collect()
    }

    // ---------------- lane lifecycle ----------------

    /// Retire a lane mid-run: clear its state and return the number of
    /// slots handed back to the allocator. This is what turns a
    /// finished (or preempted) chain's compressed footprint directly
    /// into admission capacity for the next queued chain.
    pub fn recycle_lane(&mut self, b: usize) -> usize {
        let lh = self.geom.lh();
        let freed: usize = self.live[b * lh..(b + 1) * lh].iter().sum();
        self.reset_lane(b);
        freed
    }

    pub fn reset_lane(&mut self, b: usize) {
        for l in 0..self.geom.layers {
            for h in 0..self.geom.kv_heads {
                let i = self.lbh(b, l, h);
                self.meta[i].iter_mut().for_each(|m| *m = SlotState::Free);
                self.alloc[i].reset();
                self.live[i] = 0;
                self.last_written[i] = None;
                for s in 0..self.geom.slots {
                    let mi = self.mask_idx(b, l, h, s);
                    self.mask[mi] = NEG_INF;
                }
                let pb = self.page_base(b, l, h, 0);
                let plen = self.geom.pages() * self.geom.head_dim;
                self.pmin[pb..pb + plen].iter_mut().for_each(|x| *x = 0.0);
                self.pmax[pb..pb + plen].iter_mut().for_each(|x| *x = 0.0);
            }
        }
    }

    /// Copy lane `src`'s full cache state into lane `dst` (prefix
    /// sharing for parallel chains: prefill once, fork W−1 times).
    pub fn fork_lane(&mut self, src: usize, dst: usize) {
        assert_ne!(src, dst);
        let g = self.geom;
        for l in 0..g.layers {
            for h in 0..g.kv_heads {
                let sb = self.kv_base(src, l, h, 0);
                let db = self.kv_base(dst, l, h, 0);
                let n = g.slots * g.head_dim;
                self.k.copy_within(sb..sb + n, db);
                self.v.copy_within(sb..sb + n, db);
                let smi = self.mask_idx(src, l, h, 0);
                let dmi = self.mask_idx(dst, l, h, 0);
                self.mask.copy_within(smi..smi + g.slots, dmi);
                let spb = self.page_base(src, l, h, 0);
                let dpb = self.page_base(dst, l, h, 0);
                let pn = g.pages() * g.head_dim;
                self.pmin.copy_within(spb..spb + pn, dpb);
                self.pmax.copy_within(spb..spb + pn, dpb);
                let si = self.lbh(src, l, h);
                let di = self.lbh(dst, l, h);
                let src_meta = self.meta[si].clone();
                self.meta[di] = src_meta;
                let src_alloc = self.alloc[si].clone();
                self.alloc[di].clone_from_other(&src_alloc);
                self.live[di] = self.live[si];
                self.last_written[di] = self.last_written[si];
            }
        }
    }
}
