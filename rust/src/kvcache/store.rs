//! The cache store: flat executor-layout arrays + per-slot metadata,
//! with copy-on-write page sharing across lanes.
//!
//! The flat `k/v/mask/pmin/pmax` arrays are the executor's input view
//! and are re-uploaded every step; a lane's region of them is therefore
//! only a *materialized view* of the lane's logical cache. Ownership of
//! content shared between lanes (fork-siblings referencing a leader's
//! prefill, prefix-cache hits referencing retained pages) lives in the
//! [`PagePool`]: `page_map[lane][page]` marks a page of the lane's slot
//! space as shared, and every mutating operation (`write`, `evict`,
//! `merge_into_last`) first detaches the lane from the shared entry —
//! publishing a pristine snapshot into the pool if the lane was the
//! payload borrower — before touching the bytes. Payload copies into a
//! sharer's region are deferred to [`CacheStore::materialize_pending`],
//! which the engine runs once per tick before calling the executor, so
//! forking W siblings is pure metadata work.
//!
//! # Quantized page payloads and the requantize-once rule
//!
//! The store carries a [`KvDtype`]: pool-owned payloads (COW snapshots
//! and prefix-retained pages) store K/V as per-row q8/q4 blocks with
//! scale/zero-point metadata instead of raw f32 (see [`super::quant`]).
//! Lane regions of the flat arrays stay f32 — they are the executor's
//! ABI — so the store is a two-tier memory: a cheap quantized pool
//! behind exact f32 working views.
//!
//! Where the precision boundary sits (the full contract lives in
//! `docs/NUMERICS.md`):
//!
//! * **Quantize exactly once**, when a page's pristine f32 bytes enter
//!   the pool: a COW publish (`ensure_private` / `release_lane_pages`
//!   on a borrowed payload with other references) or a prefix export
//!   (`export_page`). Both encode from the owning lane's f32 region —
//!   *fused*: `snapshot_page` encodes each (layer, head) run of rows
//!   straight from the lane's region into the snapshot's buffers
//!   (no staging f32 copy), recycling retired snapshot boxes from the
//!   pool's spare arena. Buffer acquisition is timed separately from
//!   the codec ([`CacheStore::alloc_us`] vs
//!   [`CacheStore::dequant_us`]), so the bench baselines measure the
//!   codec, not the allocator.
//! * **Dequantize on upload**: `materialize_pending` /
//!   `materialize_page` decode owned payloads into the consuming
//!   lane's f32 region — the bytes the executor uploads next tick.
//!   Decoding is deterministic and side-effect-free; the cumulative
//!   cost is tracked in [`CacheStore::dequant_us`].
//! * **Never requantize a shared page.** A lane that mutates its view
//!   of an *owned* page detaches without publishing (the pool already
//!   holds the authoritative snapshot), and `export_page` reuses the
//!   existing pool entry whenever the lane's metadata still matches it
//!   — so a logical page is encoded once and its code lattice never
//!   drifts, no matter how many forks, restores, and sibling
//!   evictions it survives.
//! * Lane-to-lane materialization of *borrowed* payloads is an exact
//!   f32 memcpy: sibling forks whose leader never retires or mutates
//!   pay zero precision cost.

use std::time::Instant;

use super::cow::{PageData, PageId, PagePool, Payload};
use super::paged::PageAllocator;
use super::quant::{KvBlock, KvDtype};

pub const NEG_INF: f32 = -1e9;

/// Cache geometry (matches the exported executables).
#[derive(Clone, Copy, Debug)]
pub struct Geometry {
    pub layers: usize,
    pub kv_heads: usize,
    pub slots: usize,
    pub head_dim: usize,
    pub page_size: usize,
}

impl Geometry {
    pub fn pages(&self) -> usize {
        self.slots / self.page_size
    }
    /// (layer, kv-head) pair count.
    pub fn lh(&self) -> usize {
        self.layers * self.kv_heads
    }
}

/// Slot lifecycle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SlotState {
    Free,
    Live {
        /// Token position this slot holds (RoPE already applied).
        pos: u32,
        /// Scheduled eviction position (DMS delayed eviction), if any.
        evict_at: u32, // u32::MAX = none
        /// DMC merge count (number of tokens averaged into this slot).
        merges: u16,
    },
}

pub(super) const NO_EVICT: u32 = u32::MAX;

/// Per-lane cache events accumulated since the last
/// [`CacheStore::drain_tick_events`] call — the flight recorder's
/// eviction/merge/COW/dequant batches (one `TraceEvent` per nonzero
/// lane per tick). Only populated while event tracking is on
/// ([`CacheStore::set_event_tracking`]), so the untraced hot path pays
/// a single branch per op.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LaneTickEvents {
    /// Slots evicted (immediate or due delayed evictions).
    pub evictions: u64,
    /// DMC merges into the last-written slot.
    pub merges: u64,
    /// Distinct (layer, head) cells touched by evictions/merges.
    pub lh_touched: u64,
    /// Pages snapshotted into the pool by COW breaks.
    pub cow_published: u64,
    /// Pool payloads decoded into the lane's region
    /// (dequant-on-upload; exact memcpy for f32).
    pub dequant_pages: u64,
}

impl LaneTickEvents {
    /// Whether anything happened on the lane this tick.
    pub fn any(&self) -> bool {
        self.evictions + self.merges + self.cow_published + self.dequant_pages > 0
    }
}

/// Host-authoritative cache for all lanes of one executor.
pub struct CacheStore {
    pub geom: Geometry,
    pub batch: usize,
    /// f32[L, B, H, S, hd]
    k: Vec<f32>,
    /// f32[L, B, H, S, hd]
    v: Vec<f32>,
    /// f32[L, B, H, S] additive mask (0 live / NEG_INF dead)
    mask: Vec<f32>,
    /// f32[L, B, H, P, hd] Quest page bounds
    pmin: Vec<f32>,
    pmax: Vec<f32>,
    /// per (b, l, h): slot metadata + allocator
    meta: Vec<Vec<SlotState>>,
    alloc: Vec<PageAllocator>,
    live: Vec<usize>,
    /// most recently written live slot per (b, l, h) (DMC merge target)
    last_written: Vec<Option<usize>>,
    /// Shared-page registry (copy-on-write ownership).
    pool: PagePool,
    /// per lane, per page: the pool entry this page is shared through.
    page_map: Vec<Vec<Option<PageId>>>,
    /// per lane, per page: payload not yet copied into this lane's
    /// region of the flat arrays.
    pending_fill: Vec<Vec<bool>>,
    pending_count: Vec<usize>,
    /// Pages snapshotted into the pool by copy-on-write breaks.
    cow_published: u64,
    /// Storage format of pool-owned page payloads (lane regions of the
    /// flat arrays are always f32 — the executor ABI).
    kv_dtype: KvDtype,
    /// Cumulative nanoseconds spent decoding pool payloads into lane
    /// regions (the dequant-on-upload cost; `kv.dequant_us`).
    dequant_ns: u64,
    /// Cumulative nanoseconds spent acquiring snapshot buffers at the
    /// publish boundary — arena reuse or fresh allocation, but never
    /// codec work (`kv.alloc_us`).
    alloc_ns: u64,
    /// Per-lane conservative flag: `true` when the lane *may* hold a
    /// scheduled (DMS delayed) eviction, `false` only when it
    /// definitely holds none — lets `apply_due_evictions` skip its
    /// full metadata scan on the (common) lanes that never schedule.
    sched_evictions: Vec<bool>,
    /// Flight-recorder hooks: per-lane event counters drained by the
    /// engine once per tick. Off by default (zero-cost contract).
    track_events: bool,
    tick_events: Vec<LaneTickEvents>,
    /// Epoch marks over (lane, layer, head) cells backing the
    /// `lh_touched` distinct count without per-tick allocation.
    lh_mark: Vec<u32>,
    tick_epoch: u32,
}

impl CacheStore {
    /// Store with exact f32 pool payloads (every pre-quantization
    /// call site; bit-identical to the original store).
    pub fn new(geom: Geometry, batch: usize) -> Self {
        Self::with_dtype(geom, batch, KvDtype::F32)
    }

    /// Store whose pool-owned payloads are encoded under `kv_dtype`.
    pub fn with_dtype(geom: Geometry, batch: usize, kv_dtype: KvDtype) -> Self {
        let n_lbh = batch * geom.lh();
        let kv_len = geom.layers * batch * geom.kv_heads * geom.slots * geom.head_dim;
        let pm_len = geom.layers * batch * geom.kv_heads * geom.pages() * geom.head_dim;
        Self {
            geom,
            batch,
            k: vec![0.0; kv_len],
            v: vec![0.0; kv_len],
            mask: vec![NEG_INF; geom.layers * batch * geom.kv_heads * geom.slots],
            pmin: vec![0.0; pm_len],
            pmax: vec![0.0; pm_len],
            meta: (0..n_lbh).map(|_| vec![SlotState::Free; geom.slots]).collect(),
            alloc: (0..n_lbh)
                .map(|_| PageAllocator::new(geom.slots, geom.page_size))
                .collect(),
            live: vec![0; n_lbh],
            last_written: vec![None; n_lbh],
            pool: PagePool::new(),
            page_map: (0..batch).map(|_| vec![None; geom.pages()]).collect(),
            pending_fill: (0..batch).map(|_| vec![false; geom.pages()]).collect(),
            pending_count: vec![0; batch],
            cow_published: 0,
            kv_dtype,
            dequant_ns: 0,
            alloc_ns: 0,
            sched_evictions: vec![false; batch],
            track_events: false,
            tick_events: vec![LaneTickEvents::default(); batch],
            lh_mark: vec![0; n_lbh],
            tick_epoch: 1,
        }
    }

    /// Enable (or disable) per-tick event accounting for the flight
    /// recorder. The engine turns this on iff its tracer is enabled.
    pub fn set_event_tracking(&mut self, on: bool) {
        self.track_events = on;
    }

    /// Take this tick's per-lane event batches (nonzero lanes only,
    /// ascending) and reset the accumulators. Returns nothing when
    /// tracking is off.
    pub fn drain_tick_events(&mut self) -> Vec<(usize, LaneTickEvents)> {
        if !self.track_events {
            return Vec::new();
        }
        self.tick_epoch = self.tick_epoch.wrapping_add(1);
        if self.tick_epoch == 0 {
            // epoch wrapped: stale marks could alias the new epoch
            self.lh_mark.iter_mut().for_each(|m| *m = 0);
            self.tick_epoch = 1;
        }
        let mut out = Vec::new();
        for (lane, ev) in self.tick_events.iter_mut().enumerate() {
            if ev.any() {
                out.push((lane, *ev));
            }
            *ev = LaneTickEvents::default();
        }
        out
    }

    /// Count an eviction/merge against its (layer, head) cell, once per
    /// cell per tick.
    #[inline]
    fn mark_cell_touched(&mut self, b: usize, l: usize, h: usize) {
        let i = self.lbh(b, l, h);
        if self.lh_mark[i] != self.tick_epoch {
            self.lh_mark[i] = self.tick_epoch;
            self.tick_events[b].lh_touched += 1;
        }
    }

    // ---------------- index helpers ----------------

    #[inline]
    fn lbh(&self, b: usize, l: usize, h: usize) -> usize {
        (b * self.geom.layers + l) * self.geom.kv_heads + h
    }

    #[inline]
    fn kv_base(&self, b: usize, l: usize, h: usize, s: usize) -> usize {
        let g = &self.geom;
        (((l * self.batch + b) * g.kv_heads + h) * g.slots + s) * g.head_dim
    }

    #[inline]
    fn mask_idx(&self, b: usize, l: usize, h: usize, s: usize) -> usize {
        let g = &self.geom;
        ((l * self.batch + b) * g.kv_heads + h) * g.slots + s
    }

    #[inline]
    fn page_base(&self, b: usize, l: usize, h: usize, p: usize) -> usize {
        let g = &self.geom;
        (((l * self.batch + b) * g.kv_heads + h) * g.pages() + p) * g.head_dim
    }

    // ---------------- raw views for the executor ----------------

    pub fn k_slice(&self) -> &[f32] {
        &self.k
    }
    pub fn v_slice(&self) -> &[f32] {
        &self.v
    }
    pub fn mask_slice(&self) -> &[f32] {
        &self.mask
    }
    pub fn pmin_slice(&self) -> &[f32] {
        &self.pmin
    }
    pub fn pmax_slice(&self) -> &[f32] {
        &self.pmax
    }

    // ---------------- slot ops ----------------

    pub fn alloc_slot(&mut self, b: usize, l: usize, h: usize) -> Option<usize> {
        let i = self.lbh(b, l, h);
        self.alloc[i].alloc()
    }

    /// Write a token's (k, v) into `slot` and mark it live.
    pub fn write(
        &mut self,
        b: usize,
        l: usize,
        h: usize,
        slot: usize,
        pos: usize,
        k: &[f32],
        v: &[f32],
    ) {
        self.ensure_private(b, slot / self.geom.page_size);
        let hd = self.geom.head_dim;
        debug_assert_eq!(k.len(), hd);
        let base = self.kv_base(b, l, h, slot);
        self.k[base..base + hd].copy_from_slice(k);
        self.v[base..base + hd].copy_from_slice(v);
        let mi = self.mask_idx(b, l, h, slot);
        self.mask[mi] = 0.0;
        let i = self.lbh(b, l, h);
        // caller may write into a pre-chosen slot (restore paths);
        // claim it in the allocator bitmap.
        self.alloc[i].claim(slot);
        if !matches!(self.meta[i][slot], SlotState::Live { .. }) {
            self.live[i] += 1;
        }
        self.meta[i][slot] = SlotState::Live {
            pos: pos as u32,
            evict_at: NO_EVICT,
            merges: 0,
        };
        self.last_written[i] = Some(slot);
        self.update_page_bounds(b, l, h, slot, k);
    }

    fn update_page_bounds(&mut self, b: usize, l: usize, h: usize, slot: usize, k: &[f32]) {
        let page = slot / self.geom.page_size;
        let base = self.page_base(b, l, h, page);
        let i = self.lbh(b, l, h);
        // first key in page initializes the bounds
        let page_first = (page * self.geom.page_size..(page + 1) * self.geom.page_size)
            .filter(|&s| matches!(self.meta[i][s], SlotState::Live { .. }))
            .count()
            == 1;
        for (d, &kd) in k.iter().enumerate() {
            if page_first {
                self.pmin[base + d] = kd;
                self.pmax[base + d] = kd;
            } else {
                if kd < self.pmin[base + d] {
                    self.pmin[base + d] = kd;
                }
                if kd > self.pmax[base + d] {
                    self.pmax[base + d] = kd;
                }
            }
        }
    }

    /// DMC: merge (k, v) into the most recently written live slot via
    /// running weighted average. Falls back to no-op if none exists.
    pub fn merge_into_last(&mut self, b: usize, l: usize, h: usize, k: &[f32], v: &[f32]) -> bool {
        let i = self.lbh(b, l, h);
        let Some(slot) = self.last_written[i] else {
            return false;
        };
        let SlotState::Live { pos, evict_at, merges } = self.meta[i][slot] else {
            return false;
        };
        self.ensure_private(b, slot / self.geom.page_size);
        let n = merges as f32 + 1.0;
        let base = self.kv_base(b, l, h, slot);
        let hd = self.geom.head_dim;
        for d in 0..hd {
            self.k[base + d] = (self.k[base + d] * n + k[d]) / (n + 1.0);
            self.v[base + d] = (self.v[base + d] * n + v[d]) / (n + 1.0);
        }
        self.meta[i][slot] = SlotState::Live {
            pos,
            evict_at,
            merges: merges + 1,
        };
        let kk: Vec<f32> = self.k[base..base + hd].to_vec();
        self.update_page_bounds(b, l, h, slot, &kk);
        if self.track_events {
            self.tick_events[b].merges += 1;
            self.mark_cell_touched(b, l, h);
        }
        true
    }

    pub fn evict(&mut self, b: usize, l: usize, h: usize, slot: usize) {
        let i = self.lbh(b, l, h);
        if !matches!(self.meta[i][slot], SlotState::Live { .. }) {
            return;
        }
        // an eviction decision on a shared page must never mutate a
        // sibling's (or the prefix cache's) view: detach first.
        self.ensure_private(b, slot / self.geom.page_size);
        self.meta[i][slot] = SlotState::Free;
        self.alloc[i].free(slot);
        self.live[i] -= 1;
        let mi = self.mask_idx(b, l, h, slot);
        self.mask[mi] = NEG_INF;
        if self.last_written[i] == Some(slot) {
            self.last_written[i] = None;
        }
        if self.track_events {
            self.tick_events[b].evictions += 1;
            self.mark_cell_touched(b, l, h);
        }
    }

    /// DMS delayed eviction: mark `slot` to be evicted at `evict_at`.
    /// Metadata-only (per-lane), so it needs no COW break; the eviction
    /// itself goes through [`CacheStore::evict`] when due.
    pub fn schedule_eviction(&mut self, b: usize, l: usize, h: usize, slot: usize, evict_at: usize) {
        let i = self.lbh(b, l, h);
        if let SlotState::Live { pos, merges, .. } = self.meta[i][slot] {
            self.meta[i][slot] = SlotState::Live {
                pos,
                evict_at: evict_at as u32,
                merges,
            };
            self.sched_evictions[b] = true;
        }
    }

    /// Execute pending evictions whose time has come (pos >= evict_at).
    ///
    /// Runs every step for every lane, so it carries a fast path: the
    /// `sched_evictions` flag conservatively tracks whether the lane
    /// may hold a scheduled eviction at all, and the full O(L·H·S)
    /// metadata scan only runs (and re-arms or clears the flag) when
    /// it does. Non-DMS policies therefore pay one branch per step.
    pub fn apply_due_evictions(&mut self, b: usize, pos: usize) {
        if !self.sched_evictions[b] {
            return;
        }
        let mut remaining = false;
        for l in 0..self.geom.layers {
            for h in 0..self.geom.kv_heads {
                let i = self.lbh(b, l, h);
                for s in 0..self.geom.slots {
                    if let SlotState::Live { evict_at, .. } = self.meta[i][s] {
                        if evict_at == NO_EVICT {
                            continue;
                        }
                        if pos as u32 >= evict_at {
                            self.evict(b, l, h, s);
                        } else {
                            remaining = true;
                        }
                    }
                }
            }
        }
        self.sched_evictions[b] = remaining;
    }

    // ---------------- queries ----------------

    pub fn live_count(&self, b: usize, l: usize, h: usize) -> usize {
        self.live[self.lbh(b, l, h)]
    }

    /// Live token count of a flat (layer × kv_heads + head) cell.
    pub fn live_count_lh(&self, b: usize, lh: usize) -> usize {
        debug_assert!(lh < self.geom.lh());
        self.live[b * self.geom.lh() + lh]
    }

    /// Per-(layer, head) live counts of `lane` — the occupancy view
    /// for budget-plan tooling, tests, and debugging (the `kv.plan_*`
    /// gauges consume the summed [`CacheStore::plan_overflow`] form
    /// instead).
    pub fn lane_occupancy(&self, b: usize) -> Vec<usize> {
        let lh = self.geom.lh();
        self.live[b * lh..(b + 1) * lh].to_vec()
    }

    /// Plan-aware overflow accounting: tokens of `lane` above each
    /// (layer, head)'s planned budget, summed. Zero when every head is
    /// within its budget — the invariant head-granular enforcement
    /// maintains after every `post_write`.
    pub fn plan_overflow(&self, b: usize, plan: &crate::compress::BudgetPlan) -> usize {
        let g = self.geom;
        let mut over = 0usize;
        for l in 0..g.layers {
            for h in 0..g.kv_heads {
                let live = self.live[self.lbh(b, l, h)];
                over += live.saturating_sub(plan.budget(l, h));
            }
        }
        over
    }

    /// Live tokens in token units: mean over (layer, head) pairs.
    pub fn live_tokens(&self, b: usize) -> f64 {
        let mut total = 0usize;
        for l in 0..self.geom.layers {
            for h in 0..self.geom.kv_heads {
                total += self.live[self.lbh(b, l, h)];
            }
        }
        total as f64 / self.geom.lh() as f64
    }

    pub fn allocated_pages(&self, b: usize, l: usize, h: usize) -> usize {
        self.alloc[self.lbh(b, l, h)].allocated_pages()
    }

    /// Fraction of this lane's slot capacity that is live (mean over
    /// the lane's (layer, head) pairs, in [0, 1]).
    pub fn lane_live_fraction(&self, b: usize) -> f64 {
        self.live_tokens(b) / self.geom.slots as f64
    }

    /// Fraction of the whole store's slot capacity that is live, across
    /// all lanes — the cache-pressure signal the scheduler's preemption
    /// watermark compares against.
    pub fn live_fraction(&self) -> f64 {
        let total: usize = self.live.iter().sum();
        total as f64 / (self.batch * self.geom.lh() * self.geom.slots) as f64
    }

    pub fn slot_state(&self, b: usize, l: usize, h: usize, s: usize) -> SlotState {
        self.meta[self.lbh(b, l, h)][s]
    }

    pub fn slot_pos(&self, b: usize, l: usize, h: usize, s: usize) -> Option<usize> {
        match self.meta[self.lbh(b, l, h)][s] {
            SlotState::Live { pos, .. } => Some(pos as usize),
            SlotState::Free => None,
        }
    }

    pub fn mask_value(&self, b: usize, l: usize, h: usize, s: usize) -> f32 {
        self.mask[self.mask_idx(b, l, h, s)]
    }

    pub fn k_at(&self, b: usize, l: usize, h: usize, s: usize) -> &[f32] {
        let base = self.kv_base(b, l, h, s);
        &self.k[base..base + self.geom.head_dim]
    }

    pub fn v_at(&self, b: usize, l: usize, h: usize, s: usize) -> &[f32] {
        let base = self.kv_base(b, l, h, s);
        &self.v[base..base + self.geom.head_dim]
    }

    pub fn pmin_at(&self, b: usize, l: usize, h: usize, p: usize) -> &[f32] {
        let base = self.page_base(b, l, h, p);
        &self.pmin[base..base + self.geom.head_dim]
    }

    pub fn pmax_at(&self, b: usize, l: usize, h: usize, p: usize) -> &[f32] {
        let base = self.page_base(b, l, h, p);
        &self.pmax[base..base + self.geom.head_dim]
    }

    /// Live slots of (b, l, h) with their positions (for policy evictors).
    pub fn live_slots(&self, b: usize, l: usize, h: usize) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        self.live_slots_into(b, l, h, &mut out);
        out
    }

    /// [`CacheStore::live_slots`] into a caller-supplied buffer
    /// (cleared first, ascending slot order). The policy hot loops
    /// reuse one scratch vector across every (layer, head) cell
    /// instead of allocating per cell per step.
    pub fn live_slots_into(&self, b: usize, l: usize, h: usize, out: &mut Vec<(usize, usize)>) {
        out.clear();
        let i = self.lbh(b, l, h);
        for (s, m) in self.meta[i].iter().enumerate() {
            if let SlotState::Live { pos, .. } = *m {
                out.push((s, pos as usize));
            }
        }
    }

    // ---------------- lane lifecycle ----------------

    /// Retire a lane mid-run: clear its state and return the number of
    /// slots handed back to the allocator. This is what turns a
    /// finished (or preempted) chain's compressed footprint directly
    /// into admission capacity for the next queued chain.
    pub fn recycle_lane(&mut self, b: usize) -> usize {
        let lh = self.geom.lh();
        let freed: usize = self.live[b * lh..(b + 1) * lh].iter().sum();
        self.reset_lane(b);
        freed
    }

    pub fn reset_lane(&mut self, b: usize) {
        self.release_lane_pages(b);
        self.sched_evictions[b] = false;
        for l in 0..self.geom.layers {
            for h in 0..self.geom.kv_heads {
                let i = self.lbh(b, l, h);
                self.meta[i].iter_mut().for_each(|m| *m = SlotState::Free);
                self.alloc[i].reset();
                self.live[i] = 0;
                self.last_written[i] = None;
                for s in 0..self.geom.slots {
                    let mi = self.mask_idx(b, l, h, s);
                    self.mask[mi] = NEG_INF;
                }
                let pb = self.page_base(b, l, h, 0);
                let plen = self.geom.pages() * self.geom.head_dim;
                self.pmin[pb..pb + plen].iter_mut().for_each(|x| *x = 0.0);
                self.pmax[pb..pb + plen].iter_mut().for_each(|x| *x = 0.0);
            }
        }
    }

    /// Copy lane `src`'s full cache state into lane `dst` by whole-lane
    /// memcpy.
    ///
    /// **Test-reference-only.** This is the legacy O(S·hd) fork the
    /// engine used before the COW page pool; the serving path forks
    /// exclusively through [`CacheStore::fork_lane_cow`]. It is kept
    /// (and must stay behaviorally frozen) because the property suite
    /// validates COW forks bit-exactly against it
    /// (`tests/property_coordinator.rs::cow_fork_streams_bit_exact_vs_full_copy_across_policies`)
    /// and `bench_kvcache` uses it as the cost baseline. Do not call it
    /// from engine code.
    pub fn fork_lane(&mut self, src: usize, dst: usize) {
        assert_ne!(src, dst);
        // a full-copy fork overwrites dst wholesale: drop any sharing
        // first so pool bookkeeping stays exact.
        self.release_lane_pages(dst);
        let g = self.geom;
        for l in 0..g.layers {
            for h in 0..g.kv_heads {
                let sb = self.kv_base(src, l, h, 0);
                let db = self.kv_base(dst, l, h, 0);
                let n = g.slots * g.head_dim;
                self.k.copy_within(sb..sb + n, db);
                self.v.copy_within(sb..sb + n, db);
                let smi = self.mask_idx(src, l, h, 0);
                let dmi = self.mask_idx(dst, l, h, 0);
                self.mask.copy_within(smi..smi + g.slots, dmi);
                let spb = self.page_base(src, l, h, 0);
                let dpb = self.page_base(dst, l, h, 0);
                let pn = g.pages() * g.head_dim;
                self.pmin.copy_within(spb..spb + pn, dpb);
                self.pmax.copy_within(spb..spb + pn, dpb);
                let si = self.lbh(src, l, h);
                let di = self.lbh(dst, l, h);
                let src_meta = self.meta[si].clone();
                self.meta[di] = src_meta;
                let src_alloc = self.alloc[si].clone();
                self.alloc[di].clone_from_other(&src_alloc);
                self.live[di] = self.live[si];
                self.last_written[di] = self.last_written[si];
            }
        }
        // dst's metadata is now a verbatim copy of src's, scheduled
        // evictions included
        self.sched_evictions[dst] = self.sched_evictions[src];
        // src pages may be lazily shared with other lanes; dst's copy is
        // private, but any pages src itself still needs to fill must be
        // resolved into dst too.
        for p in 0..g.pages() {
            if self.pending_fill[src][p] {
                // dst copied src's unmaterialized region: fill both.
                self.materialize_page(src, p);
                self.copy_page_between_lanes(src, dst, p);
            }
        }
    }

    // ------------------------------------------------------------------
    // Copy-on-write sharing
    // ------------------------------------------------------------------

    /// Share lane `src`'s live pages with (clean) lane `dst` via
    /// refcount bumps — no payload memcpy. Metadata (slot states,
    /// allocator occupancy, live counts) is cloned eagerly so the
    /// scheduler sees `dst` fully populated; payload lands in `dst`'s
    /// region of the flat arrays at the next
    /// [`CacheStore::materialize_pending`]. Returns the number of pages
    /// shared.
    pub fn fork_lane_cow(&mut self, src: usize, dst: usize) -> usize {
        assert_ne!(src, dst);
        let g = self.geom;
        let ps = g.page_size;
        debug_assert!(
            (0..g.layers)
                .all(|l| (0..g.kv_heads).all(|h| self.live[self.lbh(dst, l, h)] == 0)),
            "fork_lane_cow requires a clean destination lane"
        );
        let mut shared = 0usize;
        for p in 0..g.pages() {
            let any_used = (0..g.layers).any(|l| {
                (0..g.kv_heads)
                    .any(|h| self.alloc[self.lbh(src, l, h)].page_used_count(p) > 0)
            });
            if !any_used {
                continue;
            }
            let id = match self.page_map[src][p] {
                Some(id) => id,
                None => {
                    let id = self.pool.adopt_borrowed(src, p);
                    self.page_map[src][p] = Some(id);
                    id
                }
            };
            self.pool.retain(id);
            self.page_map[dst][p] = Some(id);
            if !self.pending_fill[dst][p] {
                self.pending_fill[dst][p] = true;
                self.pending_count[dst] += 1;
            }
            shared += 1;
            // eager metadata clone for this page
            for l in 0..g.layers {
                for h in 0..g.kv_heads {
                    let si = self.lbh(src, l, h);
                    let di = self.lbh(dst, l, h);
                    for s in p * ps..(p + 1) * ps {
                        let m = self.meta[si][s];
                        if matches!(m, SlotState::Live { .. }) {
                            self.live[di] += 1;
                            self.alloc[di].claim(s);
                        }
                        self.meta[di][s] = m;
                    }
                }
            }
        }
        for l in 0..g.layers {
            for h in 0..g.kv_heads {
                let si = self.lbh(src, l, h);
                let di = self.lbh(dst, l, h);
                self.last_written[di] = self.last_written[si];
            }
        }
        // dst inherited src's slot metadata, so it may now carry src's
        // scheduled evictions (conservative: true means "may hold")
        if self.sched_evictions[src] {
            self.sched_evictions[dst] = true;
        }
        shared
    }

    /// Map retained prefix pages (Owned pool snapshots) into a clean
    /// lane, consuming one caller-held reference per page. Metadata is
    /// restored eagerly; payload follows at the next
    /// [`CacheStore::materialize_pending`].
    pub fn map_prefix_pages(&mut self, lane: usize, ids: &[PageId]) {
        let g = self.geom;
        let ps = g.page_size;
        // restored snapshots can carry scheduled evictions (a DMS
        // lane's published page); re-arm the lane's flag if any do
        let mut sched = false;
        for &id in ids {
            let p = self.pool.page_index(id);
            debug_assert!(
                self.page_map[lane][p].is_none(),
                "prefix page {p} double-mapped on lane {lane}"
            );
            self.page_map[lane][p] = Some(id);
            if !self.pending_fill[lane][p] {
                self.pending_fill[lane][p] = true;
                self.pending_count[lane] += 1;
            }
            let Payload::Owned(data) = self.pool.payload(id) else {
                panic!("prefix page {id} not owned by the pool");
            };
            for l in 0..g.layers {
                for h in 0..g.kv_heads {
                    let lh_i = l * g.kv_heads + h;
                    let i = (lane * g.layers + l) * g.kv_heads + h;
                    for j in 0..ps {
                        let m = data.meta[lh_i * ps + j];
                        let s = p * ps + j;
                        if let SlotState::Live { evict_at, .. } = m {
                            self.live[i] += 1;
                            self.alloc[i].claim(s);
                            if evict_at != NO_EVICT {
                                sched = true;
                            }
                        }
                        self.meta[i][s] = m;
                    }
                }
            }
        }
        if sched {
            self.sched_evictions[lane] = true;
        }
    }

    /// Copy every pending shared page's payload into its lane's region
    /// of the flat arrays. The engine runs this once per tick, before
    /// the executor reads the arrays; mutation guards also trigger it
    /// per page, so correctness never depends on the batching.
    pub fn materialize_pending(&mut self) {
        for b in 0..self.batch {
            if self.pending_count[b] == 0 {
                continue;
            }
            for p in 0..self.geom.pages() {
                if self.pending_fill[b][p] {
                    self.materialize_page(b, p);
                }
            }
        }
    }

    /// Pages still awaiting materialization on `lane`.
    pub fn pending_pages(&self, lane: usize) -> usize {
        self.pending_count[lane]
    }

    fn materialize_page(&mut self, b: usize, page: usize) {
        if !self.pending_fill[b][page] {
            return;
        }
        self.pending_fill[b][page] = false;
        self.pending_count[b] -= 1;
        let Some(id) = self.page_map[b][page] else {
            unreachable!("pending page without mapping");
        };
        let borrowed_src = match self.pool.payload(id) {
            Payload::Borrowed { lane } => Some(*lane),
            Payload::Owned(_) => None,
        };
        match borrowed_src {
            Some(src) => {
                debug_assert_ne!(src, b, "borrower cannot be pending");
                self.copy_page_between_lanes(src, b, page);
            }
            None => self.copy_page_from_pool(id, b, page),
        }
    }

    /// Page-granular region copy src → dst (payload + mask + bounds).
    fn copy_page_between_lanes(&mut self, src: usize, dst: usize, page: usize) {
        let g = self.geom;
        let (ps, hd) = (g.page_size, g.head_dim);
        for l in 0..g.layers {
            for h in 0..g.kv_heads {
                let sb = self.kv_base(src, l, h, page * ps);
                let db = self.kv_base(dst, l, h, page * ps);
                self.k.copy_within(sb..sb + ps * hd, db);
                self.v.copy_within(sb..sb + ps * hd, db);
                let smi = self.mask_idx(src, l, h, page * ps);
                let dmi = self.mask_idx(dst, l, h, page * ps);
                self.mask.copy_within(smi..smi + ps, dmi);
                let spb = self.page_base(src, l, h, page);
                let dpb = self.page_base(dst, l, h, page);
                self.pmin.copy_within(spb..spb + hd, dpb);
                self.pmax.copy_within(spb..spb + hd, dpb);
            }
        }
    }

    /// Decode one pool-owned page into lane `b`'s region of the flat
    /// arrays — the dequant-on-upload step for quantized payloads, an
    /// exact memcpy for f32 ones. Deterministic either way: restoring
    /// the same entry twice yields bit-identical lane bytes.
    fn copy_page_from_pool(&mut self, id: PageId, b: usize, page: usize) {
        let g = self.geom;
        let (ps, hd) = (g.page_size, g.head_dim);
        // region-index math as pure local closures: no allocation, and
        // no `&self` method borrow while the pool payload is borrowed
        // below (the codec decodes straight into the lane region —
        // fused dequant-on-upload, no intermediate buffer)
        let batch = self.batch;
        let (heads, slots, pages) = (g.kv_heads, g.slots, g.pages());
        let kv_base = |l: usize, h: usize| (((l * batch + b) * heads + h) * slots + page * ps) * hd;
        let mask_base = |l: usize, h: usize| ((l * batch + b) * heads + h) * slots + page * ps;
        let bounds_base = |l: usize, h: usize| (((l * batch + b) * heads + h) * pages + page) * hd;
        let t0 = Instant::now();
        let Payload::Owned(data) = self.pool.payload(id) else {
            unreachable!("copy_page_from_pool on borrowed payload");
        };
        for l in 0..g.layers {
            for h in 0..g.kv_heads {
                let lh_i = l * g.kv_heads + h;
                let kb = kv_base(l, h);
                data.k
                    .read_rows_into(lh_i * ps, ps, hd, &mut self.k[kb..kb + ps * hd]);
                data.v
                    .read_rows_into(lh_i * ps, ps, hd, &mut self.v[kb..kb + ps * hd]);
                let mb = mask_base(l, h);
                self.mask[mb..mb + ps].copy_from_slice(&data.mask[lh_i * ps..(lh_i + 1) * ps]);
                let pb = bounds_base(l, h);
                self.pmin[pb..pb + hd].copy_from_slice(&data.pmin[lh_i * hd..(lh_i + 1) * hd]);
                self.pmax[pb..pb + hd].copy_from_slice(&data.pmax[lh_i * hd..(lh_i + 1) * hd]);
            }
        }
        self.dequant_ns += t0.elapsed().as_nanos() as u64;
        if self.track_events {
            self.tick_events[b].dequant_pages += 1;
        }
    }

    /// Snapshot one token page of `lane`'s region into pool-owned
    /// form, encoding the K/V payload under the store's [`KvDtype`].
    /// This is the publish boundary — the single point where a
    /// payload's (only) quantization happens.
    ///
    /// The encode is *fused*: each (layer, head) run of `page_size`
    /// rows is encoded straight from the lane's region of the flat
    /// arrays into the snapshot's blocks via
    /// [`KvBlock::write_rows_from`] — no staging f32 copy. Rows encode
    /// independently, so the chunked order is bit-identical to the old
    /// gather-then-quantize path. Snapshot buffers come from the
    /// pool's spare arena when one is available; acquisition time is
    /// accounted in [`CacheStore::alloc_us`], never in the codec's
    /// [`CacheStore::dequant_us`].
    fn snapshot_page(&mut self, lane: usize, page: usize) -> Box<PageData> {
        let g = self.geom;
        let (ps, hd) = (g.page_size, g.head_dim);
        let lh = g.lh();
        let rows = lh * ps;
        let t0 = Instant::now();
        let mut data = match self.pool.take_spare() {
            Some(mut d) => {
                // same store, same geometry: only the blocks need a
                // reshape (they keep their buffer capacity)
                d.k.reshape(self.kv_dtype, rows, hd);
                d.v.reshape(self.kv_dtype, rows, hd);
                debug_assert_eq!(d.mask.len(), rows, "spare from another geometry");
                debug_assert_eq!(d.meta.len(), rows);
                debug_assert_eq!(d.pmin.len(), lh * hd);
                d
            }
            None => Box::new(PageData {
                k: KvBlock::zeroed(self.kv_dtype, rows, hd),
                v: KvBlock::zeroed(self.kv_dtype, rows, hd),
                mask: vec![NEG_INF; rows],
                meta: vec![SlotState::Free; rows],
                pmin: vec![0.0; lh * hd],
                pmax: vec![0.0; lh * hd],
            }),
        };
        self.alloc_ns += t0.elapsed().as_nanos() as u64;
        for l in 0..g.layers {
            for h in 0..g.kv_heads {
                let lh_i = l * g.kv_heads + h;
                let kb = self.kv_base(lane, l, h, page * ps);
                data.k
                    .write_rows_from(lh_i * ps, ps, hd, &self.k[kb..kb + ps * hd]);
                data.v
                    .write_rows_from(lh_i * ps, ps, hd, &self.v[kb..kb + ps * hd]);
                let mb = self.mask_idx(lane, l, h, page * ps);
                data.mask[lh_i * ps..(lh_i + 1) * ps].copy_from_slice(&self.mask[mb..mb + ps]);
                let i = self.lbh(lane, l, h);
                data.meta[lh_i * ps..(lh_i + 1) * ps]
                    .copy_from_slice(&self.meta[i][page * ps..(page + 1) * ps]);
                let pb = self.page_base(lane, l, h, page);
                data.pmin[lh_i * hd..(lh_i + 1) * hd].copy_from_slice(&self.pmin[pb..pb + hd]);
                data.pmax[lh_i * hd..(lh_i + 1) * hd].copy_from_slice(&self.pmax[pb..pb + hd]);
            }
        }
        data
    }

    /// COW guard: before lane `b` mutates anything in `page`, detach it
    /// from any shared entry. If `b` is the payload borrower and other
    /// references remain, the pristine bytes are snapshotted into the
    /// pool first so every other sharer's view survives the mutation.
    #[inline]
    fn ensure_private(&mut self, b: usize, page: usize) {
        if self.page_map[b][page].is_none() {
            return;
        }
        self.detach_page(b, page);
    }

    fn detach_page(&mut self, b: usize, page: usize) {
        // the lane's region must hold the bytes before it diverges
        self.materialize_page(b, page);
        let id = self.page_map[b][page].take().expect("detach of unshared page");
        // requantize-once: a publish happens only when `b` holds the
        // sole pristine copy (borrowed payload). Detaching from an
        // *owned* entry never re-encodes — the pool already holds the
        // authoritative (possibly quantized) snapshot, so a lane that
        // mutates its dequantized view can never perturb, or lossily
        // re-encode, what other sharers see.
        if self.pool.refs(id) > 1 && self.pool.is_borrowed_from(id, b) {
            let snap = self.snapshot_page(b, page);
            self.pool.publish(id, snap);
            self.cow_published += 1;
            if self.track_events {
                self.tick_events[b].cow_published += 1;
            }
        }
        self.pool.release(id);
    }

    /// Drop every shared-page reference `b` holds (lane retirement),
    /// publishing borrowed payloads that other references still need.
    fn release_lane_pages(&mut self, b: usize) {
        for p in 0..self.geom.pages() {
            let Some(id) = self.page_map[b][p].take() else {
                continue;
            };
            if self.pending_fill[b][p] {
                self.pending_fill[b][p] = false;
                self.pending_count[b] -= 1;
            } else if self.pool.refs(id) > 1 && self.pool.is_borrowed_from(id, b) {
                let snap = self.snapshot_page(b, p);
                self.pool.publish(id, snap);
                self.cow_published += 1;
                if self.track_events {
                    self.tick_events[b].cow_published += 1;
                }
            }
            self.pool.release(id);
        }
    }

    // ---------------- prefix retention ----------------

    /// Longest clean page-aligned prompt prefix of `lane`, in pages. A
    /// page is clean when every slot across every (layer, head) is live
    /// with identity position (`pos == slot`), no scheduled eviction,
    /// and no DMC merges — i.e. the page is byte-identical to what
    /// prefilling those tokens produces, untouched by any compression
    /// decision. The count is capped below the full prompt so a reusing
    /// request always has at least one token to prefill (the token
    /// whose logits seed sampling).
    pub fn clean_prefix_pages(&self, lane: usize, prompt_len: usize) -> usize {
        let ps = self.geom.page_size;
        if prompt_len == 0 {
            return 0;
        }
        let max_pages = (prompt_len - 1) / ps;
        let mut n = 0;
        'pages: for p in 0..max_pages {
            for l in 0..self.geom.layers {
                for h in 0..self.geom.kv_heads {
                    let i = self.lbh(lane, l, h);
                    for s in p * ps..(p + 1) * ps {
                        match self.meta[i][s] {
                            SlotState::Live {
                                pos,
                                evict_at: NO_EVICT,
                                merges: 0,
                            } if pos as usize == s => {}
                            _ => break 'pages,
                        }
                    }
                }
            }
            n = p + 1;
        }
        n
    }

    /// Export page `page` of `lane` as a pool-owned snapshot for the
    /// prefix index, returning a handle with one reference held for the
    /// caller. Reuses the existing pool entry when the lane already
    /// shares the page and the snapshot still matches the lane's state
    /// — which is also the requantize-once guarantee for prefix
    /// retention: a page that was restored from a quantized snapshot
    /// and re-exported hands back the *same* entry, never a re-encoded
    /// (and thus drifted) copy of its dequantized view.
    pub fn export_page(&mut self, lane: usize, page: usize) -> PageId {
        // the lane's region must hold the bytes we snapshot
        self.materialize_page(lane, page);
        if let Some(id) = self.page_map[lane][page] {
            if matches!(self.pool.payload(id), Payload::Borrowed { .. }) {
                // lane's region is materialized; its bytes are the
                // authoritative shared payload
                let snap = self.snapshot_page(lane, page);
                self.pool.publish(id, snap);
            } else if !self.owned_matches_lane(id, lane, page) {
                // the snapshot predates lane-local metadata drift:
                // index a fresh copy of the lane's current clean state
                let snap = self.snapshot_page(lane, page);
                return self.pool.insert_owned(snap, page);
            }
            self.pool.retain(id);
            id
        } else {
            let snap = self.snapshot_page(lane, page);
            self.pool.insert_owned(snap, page)
        }
    }

    /// Whether an Owned snapshot's slot metadata equals the lane's.
    fn owned_matches_lane(&self, id: PageId, lane: usize, page: usize) -> bool {
        let g = self.geom;
        let ps = g.page_size;
        let Payload::Owned(data) = self.pool.payload(id) else {
            return false;
        };
        for l in 0..g.layers {
            for h in 0..g.kv_heads {
                let lh_i = l * g.kv_heads + h;
                let i = (lane * g.layers + l) * g.kv_heads + h;
                if data.meta[lh_i * ps..(lh_i + 1) * ps]
                    != self.meta[i][page * ps..(page + 1) * ps]
                {
                    return false;
                }
            }
        }
        true
    }

    /// Add one pool reference (pending prefix-hit chains hold pages
    /// alive while queued).
    pub fn retain_page(&mut self, id: PageId) {
        self.pool.retain(id);
    }

    /// Drop one pool reference.
    ///
    /// # Panics
    /// Panics on double-free (see [`PagePool::release`]).
    pub fn release_page(&mut self, id: PageId) {
        self.pool.release(id);
    }

    /// Drop the prefix index's reference to `id` and, when that was the
    /// final reference to an Owned snapshot, hand the payload out as
    /// `(slot_page, data)` for cold-tier demotion instead of freeing
    /// it. `None` means the page stays alive elsewhere (a lane still
    /// shares it, or the payload was borrowed) — the trim proceeds,
    /// only the cold copy is forgone, and no reference leaks either
    /// way (see [`PagePool::release_take`]).
    pub fn demote_page(&mut self, id: PageId) -> Option<(usize, Box<PageData>)> {
        self.pool.release_take(id)
    }

    /// Re-home a promoted cold block as a pool-owned snapshot at
    /// slot-space page `page`, returning a handle carrying one
    /// reference for the caller (the prefix index). The block is
    /// stored **verbatim** — restores decode its code lattice through
    /// the ordinary dequant-on-upload path
    /// ([`CacheStore::copy_page_from_pool`] dispatches on the block's
    /// own dtype), so promotion never re-encodes.
    pub fn adopt_cold_page(&mut self, page: usize, data: Box<PageData>) -> PageId {
        self.pool.insert_owned(data, page)
    }

    /// K+V payload bytes of one pool entry's snapshot (0 for borrowed
    /// payloads, which cost the pool nothing). Summed over the prefix
    /// index's pages for the `kv.prefix_retained_bytes` gauge.
    pub fn page_payload_bytes(&self, id: PageId) -> usize {
        match self.pool.payload(id) {
            Payload::Owned(data) => data.payload_bytes(),
            Payload::Borrowed { .. } => 0,
        }
    }

    // ---------------- pool introspection ----------------

    /// Live pool entries (shared and retained pages).
    pub fn pool_pages(&self) -> usize {
        self.pool.len()
    }

    /// Outstanding pool references across all entries.
    pub fn pool_refs(&self) -> usize {
        self.pool.total_refs()
    }

    /// Whether `page` of `lane` is currently shared through the pool.
    pub fn page_shared(&self, lane: usize, page: usize) -> bool {
        self.page_map[lane][page].is_some()
    }

    /// Pages this lane shares through the pool.
    pub fn shared_pages(&self, lane: usize) -> usize {
        self.page_map[lane].iter().filter(|m| m.is_some()).count()
    }

    /// COW snapshots published since construction.
    pub fn cow_published(&self) -> u64 {
        self.cow_published
    }

    // ---------------- quantization accounting ----------------

    /// Storage format of pool-owned page payloads.
    pub fn kv_dtype(&self) -> KvDtype {
        self.kv_dtype
    }

    /// Cumulative microseconds spent decoding pool payloads into lane
    /// regions (the `kv.dequant_us` gauge; includes the memcpy cost of
    /// f32 restores, which share the same path).
    pub fn dequant_us(&self) -> f64 {
        self.dequant_ns as f64 / 1_000.0
    }

    /// Cumulative microseconds spent acquiring snapshot buffers at the
    /// publish boundary (spare-arena reuse or fresh allocation — the
    /// `kv.alloc_us` gauge). Never includes codec work, which
    /// [`CacheStore::dequant_us`] and the bench encode legs measure.
    pub fn alloc_us(&self) -> f64 {
        self.alloc_ns as f64 / 1_000.0
    }

    /// Retired snapshot boxes currently parked in the pool's spare
    /// arena, awaiting reuse by the next publish.
    pub fn pool_spare_pages(&self) -> usize {
        self.pool.spare_pages()
    }

    /// Host bytes of K+V payload currently held by pool-owned
    /// snapshots (codes + quant metadata; borrowed payloads cost the
    /// pool nothing).
    pub fn pool_payload_bytes(&self) -> usize {
        self.pool.owned_payload_bytes()
    }

    /// Pool entries whose payload is an owned snapshot.
    pub fn pool_owned_pages(&self) -> usize {
        self.pool.owned_pages()
    }

    /// Nominal K+V payload bytes one cached token costs per
    /// (layer, KV-head) pair under the store's dtype — `8·hd` for f32,
    /// `2·(hd + 5)` for q8, `2·(⌈hd/2⌉ + 5)` for q4. Reported as the
    /// `kv.bytes_per_token` gauge.
    pub fn payload_bytes_per_token(&self) -> f64 {
        2.0 * self.kv_dtype.row_payload_bytes(self.geom.head_dim) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> CacheStore {
        CacheStore::new(
            Geometry {
                layers: 2,
                kv_heads: 2,
                slots: 16,
                head_dim: 4,
                page_size: 4,
            },
            2,
        )
    }

    #[test]
    fn tick_events_only_accumulate_when_tracking_is_on() {
        let mut c = small();
        let s = c.alloc_slot(0, 0, 0).unwrap();
        c.write(0, 0, 0, s, 0, &[1.0; 4], &[1.0; 4]);
        c.evict(0, 0, 0, s);
        assert!(c.drain_tick_events().is_empty(), "tracking off by default");

        c.set_event_tracking(true);
        let s = c.alloc_slot(0, 0, 0).unwrap();
        c.write(0, 0, 0, s, 0, &[1.0; 4], &[1.0; 4]);
        assert!(c.merge_into_last(0, 0, 0, &[2.0; 4], &[2.0; 4]));
        c.evict(0, 0, 0, s);
        let s1 = c.alloc_slot(0, 1, 1).unwrap();
        c.write(0, 1, 1, s1, 0, &[1.0; 4], &[1.0; 4]);
        c.evict(0, 1, 1, s1);
        let ev = c.drain_tick_events();
        assert_eq!(ev.len(), 1, "only the touched lane reports");
        let (lane, e) = ev[0];
        assert_eq!(lane, 0);
        assert_eq!(e.evictions, 2);
        assert_eq!(e.merges, 1);
        assert_eq!(e.lh_touched, 2, "distinct (layer, head) cells, not ops");
        assert!(c.drain_tick_events().is_empty(), "drain resets the tick");
    }
}
