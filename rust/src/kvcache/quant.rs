//! Quantized KV page payloads: q8 / q4 storage blocks with per-row
//! scale/zero-point metadata.
//!
//! The paper's hyper-scaling argument treats compression ratio as a
//! budget multiplier: every factor saved on the KV cache converts into
//! more generated or parallel tokens at the same memory cost. Eviction
//! (DMS/TOVA/H2O) supplies the *sparsity* axis; this module supplies
//! the orthogonal *numeric-precision* axis (KVComp-style lossy
//! compression), so an 8× eviction ratio compounds with a ~4× payload
//! shrink into ~32× effective compression of pool-resident state.
//!
//! ## Layout
//!
//! A pooled KV page holds, per (layer, KV-head) pair, `page_size` rows
//! of `head_dim` f32 values (one row per token slot). A [`QuantBlock`]
//! stores those rows with **per-row, zero-anchored affine
//! quantization** (the row's representable interval is extended to
//! include 0, so the u8 zero-point always lands inside `[0, qmax]` and
//! zero values encode exactly):
//!
//! ```text
//! x ≈ scale · (q − zero_point)        q ∈ [0, 255] (q8) / [0, 15] (q4)
//! lo = min(min_row, 0)   hi = max(max_row, 0)
//! scale      = (hi − lo) / qmax                    (f32, one per row)
//! zero_point = round(−lo / scale)                  (u8, one per row)
//! ```
//!
//! Constant rows use a degenerate exact code (`scale = value`,
//! `q ≡ 1`); all-zero rows (unwritten slots) encode as `scale = 0`.
//! q4 codes are nibble-packed two per byte. Per-row metadata costs
//! 5 bytes (f32 scale + u8 zero-point), so for `head_dim = hd` the
//! payload shrinks from `4·hd` to `hd + 5` bytes per row at q8
//! (≥ 3× for hd ≥ 16) and `⌈hd/2⌉ + 5` at q4 (≈ 5–7×).
//!
//! ## Numerics contract (see `docs/NUMERICS.md`)
//!
//! * Quantization is **lossy** with per-element error ≤ `|scale|/2`
//!   for finite elements over the zero-anchored row range (constant
//!   and all-zero rows round-trip exactly, up to one float rounding of
//!   `scale·q` for constant rows — exactly zero error in the `q ≡ 1`
//!   encoding). The step is floored at `f32::MIN_POSITIVE`, so a
//!   subnormal row spread never produces a denormal (or zero) scale;
//!   such rows still satisfy the half-step bound.
//! * **Non-finite elements never panic and take defined codes**: NaN
//!   decodes to exactly `0.0`; `±inf` saturate to the row's
//!   representable extremes (`lo`/`hi` anchor of an affine row, the
//!   nearer of `{0, value}` in a constant row). The range scan sees
//!   finite values only, so one stray NaN/inf cannot widen or poison a
//!   row's code lattice; rows with *no* finite values decode entirely
//!   to `0.0`.
//! * Dequantization is **deterministic and exact** over the code
//!   lattice: the same block dequantizes to bit-identical f32 forever.
//! * Blocks are produced exactly once, at page publish/export
//!   boundaries ([`CacheStore`](super::CacheStore) never re-quantizes
//!   a shared page — see the requantize-once rule in the store docs).
//!
//! ## Round-trip example
//!
//! ```
//! use hyperscale::kvcache::{KvDtype, QuantBlock};
//!
//! // two rows of four values each
//! let src = [0.0f32, 0.5, 1.0, 2.0, -1.0, -0.25, 0.25, 1.0];
//! let block = QuantBlock::quantize(KvDtype::Q8, 2, 4, &src);
//!
//! let mut out = [0.0f32; 8];
//! block.dequantize_rows_into(0, 2, &mut out);
//! for (x, y) in src.iter().zip(&out) {
//!     // per-element error is bounded by half the row's quant step
//!     assert!((x - y).abs() <= 2.0 / 255.0 * 0.5 + 1e-6);
//! }
//! // storage: 8 code bytes + 2 × (4-byte scale + 1-byte zero-point)
//! assert_eq!(block.payload_bytes(), 8 + 2 * 5);
//! ```

use std::fmt;
use std::str::FromStr;

use anyhow::bail;

/// Storage format of KV page payloads held by the
/// [`PagePool`](super::PagePool).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvDtype {
    /// Full-precision f32 (exact; 4 bytes/element).
    F32,
    /// 8-bit affine quantization (per-row scale/zero-point).
    Q8,
    /// 4-bit affine quantization, nibble-packed.
    Q4,
}

impl KvDtype {
    /// Human-readable name, matching the CLI/config spelling.
    pub fn name(&self) -> &'static str {
        match self {
            KvDtype::F32 => "f32",
            KvDtype::Q8 => "q8",
            KvDtype::Q4 => "q4",
        }
    }

    /// Code bits per element.
    pub fn bits(&self) -> u32 {
        match self {
            KvDtype::F32 => 32,
            KvDtype::Q8 => 8,
            KvDtype::Q4 => 4,
        }
    }

    /// Whether payloads of this dtype go through quantize/dequantize.
    pub fn is_quantized(&self) -> bool {
        !matches!(self, KvDtype::F32)
    }

    /// Largest code value (`qmax`); 0 for f32 (unused).
    fn qmax(&self) -> u32 {
        match self {
            KvDtype::F32 => 0,
            KvDtype::Q8 => 255,
            KvDtype::Q4 => 15,
        }
    }

    /// Code bytes one row of `row_len` elements occupies (excluding
    /// scale/zero-point metadata).
    fn row_code_bytes(&self, row_len: usize) -> usize {
        match self {
            KvDtype::F32 => row_len * 4,
            KvDtype::Q8 => row_len,
            KvDtype::Q4 => row_len.div_ceil(2),
        }
    }

    /// Default dtype taken from the `KV_DTYPE` environment variable
    /// (`f32` when unset or unparsable). This is the **test harness**
    /// knob: CI runs the tier-1 suite a second time with `KV_DTYPE=q8`
    /// so every store-lifecycle test also exercises the quantized
    /// publish/restore paths. Production configuration goes through
    /// `--kv-dtype` / `EngineConfig::kv_dtype`, never this.
    pub fn from_env() -> Self {
        std::env::var("KV_DTYPE")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(KvDtype::F32)
    }

    /// Host bytes one stored row of `row_len` elements occupies,
    /// including per-row scale/zero-point metadata for the quantized
    /// formats. This is the number the `kv.bytes_per_token` gauge and
    /// the Pareto byte-axis rescale are built from.
    pub fn row_payload_bytes(&self, row_len: usize) -> usize {
        match self {
            KvDtype::F32 => row_len * 4,
            // codes + f32 scale + u8 zero-point
            _ => self.row_code_bytes(row_len) + 5,
        }
    }
}

impl fmt::Display for KvDtype {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl FromStr for KvDtype {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Ok(match s {
            "f32" | "fp32" | "float32" => KvDtype::F32,
            "q8" | "int8" => KvDtype::Q8,
            "q4" | "int4" => KvDtype::Q4,
            other => bail!("unknown kv dtype '{other}' (expected f32, q8, or q4)"),
        })
    }
}

/// Decode one affine code: `scale · (q − zero_point)`. Shared by the
/// page codec below and the checkpoint loader
/// (`runtime::parse_tensors`) so the convention lives in one place.
#[inline]
pub fn dequant_code(q: u8, scale: f32, zp: f32) -> f32 {
    scale * (q as f32 - zp)
}

/// Extract element `i` from a low-nibble-first packed q4 code stream
/// (the packing convention of [`QuantBlock`] and q4 checkpoint
/// tensors).
#[inline]
pub fn unpack_q4(codes: &[u8], i: usize) -> u8 {
    (codes[i / 2] >> ((i % 2) * 4)) & 0x0F
}

/// A quantized block of `rows × row_len` values (see module docs for
/// the per-row affine scheme and the error bound).
#[derive(Clone, Debug)]
pub struct QuantBlock {
    dtype: KvDtype,
    rows: usize,
    row_len: usize,
    /// Packed codes, `rows × row_stride` bytes.
    data: Vec<u8>,
    /// Per-row scale (may be negative for constant negative rows).
    scale: Vec<f32>,
    /// Per-row zero-point in the quantized domain.
    zp: Vec<u8>,
}

impl QuantBlock {
    /// Quantize `src` (length `rows × row_len`) into a block.
    ///
    /// # Panics
    /// Panics if `dtype` is [`KvDtype::F32`] (nothing to quantize) or
    /// if `src` has the wrong length.
    pub fn quantize(dtype: KvDtype, rows: usize, row_len: usize, src: &[f32]) -> Self {
        assert!(dtype.is_quantized(), "QuantBlock requires q8/q4");
        assert_eq!(src.len(), rows * row_len, "source length mismatch");
        let qmax = dtype.qmax() as f32;
        let stride = dtype.row_code_bytes(row_len);
        let mut data = vec![0u8; rows * stride];
        let mut scale = vec![0f32; rows];
        let mut zp = vec![0u8; rows];
        for r in 0..rows {
            let xs = &src[r * row_len..(r + 1) * row_len];
            // the range scan sees finite values only: a NaN or ±inf
            // element must not poison the whole row's code lattice
            let mut lo = f32::INFINITY;
            let mut hi = f32::NEG_INFINITY;
            for &x in xs {
                if x.is_finite() {
                    lo = lo.min(x);
                    hi = hi.max(x);
                }
            }
            // constant rows take a degenerate exact encoding; varying
            // rows anchor the representable interval at zero so the
            // u8 zero-point is always in range (and zeros are exact)
            #[derive(Clone, Copy)]
            enum Enc {
                Zero,
                Const { s: f32 },
                Affine { s: f32, z: f32 },
            }
            let enc = if lo > hi {
                // no finite value in the row (all NaN/±inf): nothing
                // to anchor a lattice to — everything decodes to 0.0
                Enc::Zero
            } else if hi > lo {
                let (lo0, hi0) = (lo.min(0.0), hi.max(0.0));
                // the MIN_POSITIVE floor keeps a subnormal (or
                // underflowed-to-zero) spread from producing a
                // denormal step: x/s and −lo0/s stay finite, and the
                // half-step error bound still holds (the true spread
                // is below the floored step)
                let s = ((hi0 - lo0) / qmax).max(f32::MIN_POSITIVE);
                let z = (-lo0 / s).round().clamp(0.0, qmax);
                scale[r] = s;
                zp[r] = z as u8;
                Enc::Affine { s, z }
            } else if lo == 0.0 {
                // all-zero row (unwritten slots): exact zero codes
                Enc::Zero
            } else {
                // constant non-zero row: scale·(1 − 0) == value, exact
                scale[r] = lo;
                Enc::Const { s: lo }
            };
            let row = &mut data[r * stride..(r + 1) * stride];
            for (d, &x) in xs.iter().enumerate() {
                // non-finite elements take defined codes: NaN decodes
                // to exactly 0.0, ±inf saturate to the row's
                // representable extremes
                let q = match enc {
                    Enc::Zero => 0u8,
                    Enc::Const { s } => {
                        if x.is_finite() {
                            1u8
                        } else if x.is_nan() {
                            0u8 // decodes to exactly 0.0
                        } else if (x > 0.0) == (s > 0.0) {
                            1u8 // ±inf saturates toward the value…
                        } else {
                            0u8 // …or toward 0.0, whichever is nearer
                        }
                    }
                    Enc::Affine { s, z } => {
                        if x.is_nan() {
                            z as u8 // the exact-zero code
                        } else {
                            (x / s + z).round().clamp(0.0, qmax) as u8
                        }
                    }
                };
                match dtype {
                    KvDtype::Q8 => row[d] = q,
                    KvDtype::Q4 => row[d / 2] |= q << ((d % 2) * 4),
                    KvDtype::F32 => unreachable!(),
                }
            }
        }
        Self {
            dtype,
            rows,
            row_len,
            data,
            scale,
            zp,
        }
    }

    /// Dequantize rows `[row0, row0 + n_rows)` into `out` (length
    /// `n_rows × row_len`). Deterministic: identical output on every
    /// call.
    pub fn dequantize_rows_into(&self, row0: usize, n_rows: usize, out: &mut [f32]) {
        assert!(row0 + n_rows <= self.rows, "row range out of bounds");
        assert_eq!(out.len(), n_rows * self.row_len, "output length mismatch");
        let stride = self.dtype.row_code_bytes(self.row_len);
        for i in 0..n_rows {
            let r = row0 + i;
            let s = self.scale[r];
            let z = self.zp[r] as f32;
            let row = &self.data[r * stride..(r + 1) * stride];
            let dst = &mut out[i * self.row_len..(i + 1) * self.row_len];
            for (d, y) in dst.iter_mut().enumerate() {
                let q = match self.dtype {
                    KvDtype::Q8 => row[d],
                    KvDtype::Q4 => unpack_q4(row, d),
                    KvDtype::F32 => unreachable!(),
                };
                *y = dequant_code(q, s, z);
            }
        }
    }

    /// Storage format of this block.
    pub fn dtype(&self) -> KvDtype {
        self.dtype
    }

    /// Row count.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Elements per row.
    pub fn row_len(&self) -> usize {
        self.row_len
    }

    /// Quantization step of one row: for varying rows the per-element
    /// round-trip error is bounded by `|scale|/2`; for constant rows
    /// `scale` holds the (exactly reproduced) value itself.
    pub fn row_scale(&self, row: usize) -> f32 {
        self.scale[row]
    }

    /// Host bytes this block occupies (codes + scale/zero-point).
    pub fn payload_bytes(&self) -> usize {
        self.data.len() + self.scale.len() * 4 + self.zp.len()
    }
}

/// A KV payload block: either exact f32 or a quantized [`QuantBlock`].
///
/// This is the storage type behind [`PageData`](super::PageData) —
/// every pool-owned page's K and V live in one of these.
#[derive(Clone, Debug)]
pub enum KvBlock {
    /// Exact f32 payload (`rows × row_len` values).
    F32(Vec<f32>),
    /// Quantized payload with per-row scale/zero-point.
    Quant(QuantBlock),
}

impl KvBlock {
    /// Encode `data` (length `rows × row_len`) under `dtype`. For
    /// [`KvDtype::F32`] the vector is stored as-is (exact, zero cost);
    /// otherwise it is quantized — this is the *single* lossy step of a
    /// payload's lifetime (requantize-once rule).
    pub fn from_f32(dtype: KvDtype, rows: usize, row_len: usize, data: Vec<f32>) -> Self {
        debug_assert_eq!(data.len(), rows * row_len);
        match dtype {
            KvDtype::F32 => KvBlock::F32(data),
            _ => KvBlock::Quant(QuantBlock::quantize(dtype, rows, row_len, &data)),
        }
    }

    /// Decode rows `[row0, row0 + n_rows)` into `out`. Exact copy for
    /// f32 payloads; deterministic dequantization otherwise.
    pub fn read_rows_into(&self, row0: usize, n_rows: usize, row_len: usize, out: &mut [f32]) {
        match self {
            KvBlock::F32(data) => {
                out.copy_from_slice(&data[row0 * row_len..(row0 + n_rows) * row_len]);
            }
            KvBlock::Quant(q) => {
                debug_assert_eq!(q.row_len(), row_len);
                q.dequantize_rows_into(row0, n_rows, out);
            }
        }
    }

    /// Decode the whole block to f32.
    pub fn to_f32(&self) -> Vec<f32> {
        match self {
            KvBlock::F32(data) => data.clone(),
            KvBlock::Quant(q) => {
                let mut out = vec![0f32; q.rows() * q.row_len()];
                q.dequantize_rows_into(0, q.rows(), &mut out);
                out
            }
        }
    }

    /// Host bytes this payload occupies.
    pub fn payload_bytes(&self) -> usize {
        match self {
            KvBlock::F32(data) => data.len() * 4,
            KvBlock::Quant(q) => q.payload_bytes(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Pseudo-random but deterministic row values.
    fn row_values(rows: usize, row_len: usize, seed: u64) -> Vec<f32> {
        let mut state = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1;
        (0..rows * row_len)
            .map(|_| {
                state = state
                    .wrapping_mul(6364136223846793005)
                    .wrapping_add(1442695040888963407);
                ((state >> 33) as f64 / (1u64 << 31) as f64 - 0.5) as f32 * 4.0
            })
            .collect()
    }

    fn roundtrip_bound(dtype: KvDtype, rows: usize, row_len: usize) {
        let src = row_values(rows, row_len, 7 + dtype.bits() as u64);
        let b = QuantBlock::quantize(dtype, rows, row_len, &src);
        let mut out = vec![0f32; rows * row_len];
        b.dequantize_rows_into(0, rows, &mut out);
        for r in 0..rows {
            let bound = b.row_scale(r).abs() * 0.5001 + 1e-6;
            for d in 0..row_len {
                let (x, y) = (src[r * row_len + d], out[r * row_len + d]);
                assert!(
                    (x - y).abs() <= bound,
                    "{dtype}: row {r} elem {d}: |{x} - {y}| > {bound}"
                );
            }
        }
    }

    #[test]
    fn q8_roundtrip_within_half_step() {
        roundtrip_bound(KvDtype::Q8, 13, 16);
    }

    #[test]
    fn q4_roundtrip_within_half_step() {
        roundtrip_bound(KvDtype::Q4, 13, 16);
    }

    #[test]
    fn q4_handles_odd_row_length() {
        roundtrip_bound(KvDtype::Q4, 5, 7);
    }

    #[test]
    fn constant_and_zero_rows_are_exact() {
        for dtype in [KvDtype::Q8, KvDtype::Q4] {
            // zero row, positive constant, negative constant
            let src = [0.0f32, 0.0, 0.0, 2.5, 2.5, 2.5, -1.75, -1.75, -1.75];
            let b = QuantBlock::quantize(dtype, 3, 3, &src);
            let mut out = [0f32; 9];
            b.dequantize_rows_into(0, 3, &mut out);
            assert_eq!(&src[..], &out[..], "{dtype}: constant rows must round-trip");
        }
    }

    #[test]
    fn dequantization_is_deterministic() {
        let src = row_values(8, 12, 42);
        let b = QuantBlock::quantize(KvDtype::Q8, 8, 12, &src);
        let mut a = vec![0f32; 8 * 12];
        let mut c = vec![0f32; 8 * 12];
        b.dequantize_rows_into(0, 8, &mut a);
        b.dequantize_rows_into(0, 8, &mut c);
        assert_eq!(a, c);
        // and a re-encode of the same source yields identical codes
        let b2 = QuantBlock::quantize(KvDtype::Q8, 8, 12, &src);
        let mut d = vec![0f32; 8 * 12];
        b2.dequantize_rows_into(0, 8, &mut d);
        assert_eq!(a, d);
    }

    #[test]
    fn partial_row_reads_match_full_reads() {
        let src = row_values(10, 6, 3);
        let b = QuantBlock::quantize(KvDtype::Q4, 10, 6, &src);
        let mut full = vec![0f32; 60];
        b.dequantize_rows_into(0, 10, &mut full);
        let mut part = vec![0f32; 18];
        b.dequantize_rows_into(4, 3, &mut part);
        assert_eq!(&full[24..42], &part[..]);
    }

    #[test]
    fn payload_bytes_hit_compression_targets() {
        // hd = 16: f32 64 B/row, q8 21 B/row (3.05×), q4 13 B/row (4.9×)
        let hd = 16;
        let f32_bytes = KvDtype::F32.row_payload_bytes(hd);
        let q8_bytes = KvDtype::Q8.row_payload_bytes(hd);
        let q4_bytes = KvDtype::Q4.row_payload_bytes(hd);
        assert_eq!(f32_bytes, 64);
        assert_eq!(q8_bytes, 21);
        assert_eq!(q4_bytes, 13);
        assert!(
            f32_bytes as f64 / q8_bytes as f64 >= 3.0,
            "q8 must shrink host bytes-per-token ≥ 3×"
        );
        assert!(f32_bytes as f64 / q4_bytes as f64 >= 4.5);
        // a block's actual accounting matches the nominal figure
        let src = row_values(4, hd, 1);
        let b = QuantBlock::quantize(KvDtype::Q8, 4, hd, &src);
        assert_eq!(b.payload_bytes(), 4 * q8_bytes);
    }

    #[test]
    fn kvblock_f32_is_exact_and_cheap() {
        let src = row_values(3, 5, 9);
        let b = KvBlock::from_f32(KvDtype::F32, 3, 5, src.clone());
        assert_eq!(b.to_f32(), src);
        assert_eq!(b.payload_bytes(), src.len() * 4);
        let mut out = vec![0f32; 5];
        b.read_rows_into(1, 1, 5, &mut out);
        assert_eq!(&out[..], &src[5..10]);
    }

    #[test]
    fn dtype_parsing_roundtrip() {
        for d in [KvDtype::F32, KvDtype::Q8, KvDtype::Q4] {
            assert_eq!(d.name().parse::<KvDtype>().unwrap(), d);
        }
        assert!("bf16".parse::<KvDtype>().is_err());
    }
}
