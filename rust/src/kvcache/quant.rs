//! Quantized KV page payloads: q8 / q4 storage blocks with per-row
//! scale/zero-point metadata.
//!
//! The paper's hyper-scaling argument treats compression ratio as a
//! budget multiplier: every factor saved on the KV cache converts into
//! more generated or parallel tokens at the same memory cost. Eviction
//! (DMS/TOVA/H2O) supplies the *sparsity* axis; this module supplies
//! the orthogonal *numeric-precision* axis (KVComp-style lossy
//! compression), so an 8× eviction ratio compounds with a ~4× payload
//! shrink into ~32× effective compression of pool-resident state.
//!
//! ## Layout
//!
//! A pooled KV page holds, per (layer, KV-head) pair, `page_size` rows
//! of `head_dim` f32 values (one row per token slot). A [`QuantBlock`]
//! stores those rows with **per-row, zero-anchored affine
//! quantization** (the row's representable interval is extended to
//! include 0, so the u8 zero-point always lands inside `[0, qmax]` and
//! zero values encode exactly):
//!
//! ```text
//! x ≈ scale · (q − zero_point)        q ∈ [0, 255] (q8) / [0, 15] (q4)
//! lo = min(min_row, 0)   hi = max(max_row, 0)
//! scale      = (hi − lo) / qmax                    (f32, one per row)
//! zero_point = round(−lo / scale)                  (u8, one per row)
//! ```
//!
//! Constant rows use a degenerate exact code (`scale = value`,
//! `q ≡ 1`); all-zero rows (unwritten slots) encode as `scale = 0`.
//! q4 codes are nibble-packed two per byte. Per-row metadata costs
//! 5 bytes (f32 scale + u8 zero-point), so for `head_dim = hd` the
//! payload shrinks from `4·hd` to `hd + 5` bytes per row at q8
//! (≥ 3× for hd ≥ 16) and `⌈hd/2⌉ + 5` at q4 (≈ 5–7×).
//!
//! ## The [`Codec`] trait
//!
//! The byte-level encode/decode lives behind the [`Codec`] trait:
//! `encode_rows_into` / `decode_rows_into` operate on caller-supplied
//! code/scale/zero-point buffers so the hot paths (page publish in
//! [`CacheStore::export_page`](super::CacheStore::export_page), fused
//! dequant-on-upload in page restore) can recycle buffers instead of
//! allocating per page. Two implementations share the interface:
//!
//! * [`ScalarCodec`] — the **frozen reference**: a verbatim port of the
//!   original per-element encoder/decoder. It is deliberately naive
//!   (per-element dispatch, bit-shift nibble unpacking) and must never
//!   be "optimized": it is the conformance oracle.
//! * [`VectorizedCodec`] — the production codec: chunked min/max range
//!   scans, a branch-free encode loop for NaN-free rows, nibble
//!   pack/unpack via pair writes and a 256-entry lookup table. The
//!   `codec_conformance` test suite pins it **bit-identical** to
//!   [`ScalarCodec`] on every dtype × geometry, including NaN / ±inf /
//!   subnormal rows.
//!
//! [`QuantBlock::quantize`] / [`QuantBlock::dequantize_rows_into`]
//! remain as thin wrappers over the vectorized codec (they own the
//! buffers); in-place variants ([`QuantBlock::encode_rows_from`],
//! [`KvBlock::write_rows_from`], [`KvBlock::reshape`]) power the
//! arena-recycled publish path.
//!
//! ## Numerics contract (see `docs/NUMERICS.md`)
//!
//! * Quantization is **lossy** with per-element error ≤ `|scale|/2`
//!   for finite elements over the zero-anchored row range (constant
//!   and all-zero rows round-trip exactly, up to one float rounding of
//!   `scale·q` for constant rows — exactly zero error in the `q ≡ 1`
//!   encoding). The step is floored at `f32::MIN_POSITIVE`, so a
//!   subnormal row spread never produces a denormal (or zero) scale;
//!   such rows still satisfy the half-step bound.
//! * **Non-finite elements never panic and take defined codes**: NaN
//!   decodes to exactly `0.0`; `±inf` saturate to the row's
//!   representable extremes (`lo`/`hi` anchor of an affine row, the
//!   nearer of `{0, value}` in a constant row). The range scan sees
//!   finite values only, so one stray NaN/inf cannot widen or poison a
//!   row's code lattice; rows with *no* finite values decode entirely
//!   to `0.0`.
//! * Dequantization is **deterministic and exact** over the code
//!   lattice: the same block dequantizes to bit-identical f32 forever.
//! * Blocks are produced exactly once, at page publish/export
//!   boundaries ([`CacheStore`](super::CacheStore) never re-quantizes
//!   a shared page — see the requantize-once rule in the store docs).
//!
//! ## Round-trip example
//!
//! ```
//! use hyperscale::kvcache::{KvDtype, QuantBlock};
//!
//! // two rows of four values each
//! let src = [0.0f32, 0.5, 1.0, 2.0, -1.0, -0.25, 0.25, 1.0];
//! let block = QuantBlock::quantize(KvDtype::Q8, 2, 4, &src);
//!
//! let mut out = [0.0f32; 8];
//! block.dequantize_rows_into(0, 2, &mut out);
//! for (x, y) in src.iter().zip(&out) {
//!     // per-element error is bounded by half the row's quant step
//!     assert!((x - y).abs() <= 2.0 / 255.0 * 0.5 + 1e-6);
//! }
//! // storage: 8 code bytes + 2 × (4-byte scale + 1-byte zero-point)
//! assert_eq!(block.payload_bytes(), 8 + 2 * 5);
//! ```

use std::fmt;
use std::str::FromStr;

use anyhow::bail;

/// Storage format of KV page payloads held by the
/// [`PagePool`](super::PagePool).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvDtype {
    /// Full-precision f32 (exact; 4 bytes/element).
    F32,
    /// 8-bit affine quantization (per-row scale/zero-point).
    Q8,
    /// 4-bit affine quantization, nibble-packed.
    Q4,
}

impl KvDtype {
    /// Human-readable name, matching the CLI/config spelling.
    pub fn name(&self) -> &'static str {
        match self {
            KvDtype::F32 => "f32",
            KvDtype::Q8 => "q8",
            KvDtype::Q4 => "q4",
        }
    }

    /// Code bits per element.
    pub fn bits(&self) -> u32 {
        match self {
            KvDtype::F32 => 32,
            KvDtype::Q8 => 8,
            KvDtype::Q4 => 4,
        }
    }

    /// Whether payloads of this dtype go through quantize/dequantize.
    pub fn is_quantized(&self) -> bool {
        !matches!(self, KvDtype::F32)
    }

    /// Largest code value (`qmax`); 0 for f32 (unused).
    fn qmax(&self) -> u32 {
        match self {
            KvDtype::F32 => 0,
            KvDtype::Q8 => 255,
            KvDtype::Q4 => 15,
        }
    }

    /// Code bytes one row of `row_len` elements occupies (excluding
    /// scale/zero-point metadata). This is the per-row stride of the
    /// code buffers the [`Codec`] trait operates on.
    pub fn row_code_bytes(&self, row_len: usize) -> usize {
        match self {
            KvDtype::F32 => row_len * 4,
            KvDtype::Q8 => row_len,
            KvDtype::Q4 => row_len.div_ceil(2),
        }
    }

    /// Default dtype taken from the `KV_DTYPE` environment variable
    /// (`f32` when unset or unparsable). This is the **test harness**
    /// knob: CI runs the tier-1 suite a second time with `KV_DTYPE=q8`
    /// so every store-lifecycle test also exercises the quantized
    /// publish/restore paths. Production configuration goes through
    /// `--kv-dtype` / `EngineConfig::kv_dtype`, never this.
    pub fn from_env() -> Self {
        std::env::var("KV_DTYPE")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(KvDtype::F32)
    }

    /// Host bytes one stored row of `row_len` elements occupies,
    /// including per-row scale/zero-point metadata for the quantized
    /// formats. This is the number the `kv.bytes_per_token` gauge and
    /// the Pareto byte-axis rescale are built from.
    pub fn row_payload_bytes(&self, row_len: usize) -> usize {
        match self {
            KvDtype::F32 => row_len * 4,
            // codes + f32 scale + u8 zero-point
            _ => self.row_code_bytes(row_len) + 5,
        }
    }
}

impl fmt::Display for KvDtype {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl FromStr for KvDtype {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Ok(match s {
            "f32" | "fp32" | "float32" => KvDtype::F32,
            "q8" | "int8" => KvDtype::Q8,
            "q4" | "int4" => KvDtype::Q4,
            other => bail!("unknown kv dtype '{other}' (expected f32, q8, or q4)"),
        })
    }
}

/// Decode one affine code: `scale · (q − zero_point)`. This is the
/// single-element convention anchor shared by the [`ScalarCodec`]
/// reference and the checkpoint loader (`runtime::parse_tensors`);
/// the page hot paths go through [`Codec`] row decodes instead.
#[inline]
pub fn dequant_code(q: u8, scale: f32, zp: f32) -> f32 {
    scale * (q as f32 - zp)
}

/// Extract element `i` from a low-nibble-first packed q4 code stream
/// (the packing convention of [`QuantBlock`] and q4 checkpoint
/// tensors). Like [`dequant_code`] this survives as the convention
/// anchor for the checkpoint loader and the scalar reference codec.
#[inline]
pub fn unpack_q4(codes: &[u8], i: usize) -> u8 {
    (codes[i / 2] >> ((i % 2) * 4)) & 0x0F
}

/// `(low, high)` nibble of every packed q4 byte — the vectorized
/// decoder trades the per-element shift/mask of [`unpack_q4`] for one
/// table load per byte.
const Q4_NIBBLES: [[u8; 2]; 256] = {
    let mut t = [[0u8; 2]; 256];
    let mut i = 0;
    while i < 256 {
        t[i] = [(i & 0x0F) as u8, (i >> 4) as u8];
        i += 1;
    }
    t
};

/// Row-oriented quantization codec over caller-supplied buffers.
///
/// `codes` is `rows × dtype.row_code_bytes(row_len)` bytes; `scale`
/// and `zp` hold one entry per row. Implementations must fully
/// overwrite the row ranges they are given (including the scale and
/// zero-point of degenerate rows), so recycled buffers never leak
/// stale bytes — the arena publish path depends on this.
///
/// Every implementation must produce **bit-identical** output to
/// [`ScalarCodec`] (the frozen reference): identical code bytes,
/// scales, and zero-points on encode; identical f32 bit patterns on
/// decode. The `codec_conformance` integration suite enforces this.
pub trait Codec {
    /// Implementation name for bench labels and diagnostics.
    fn name(&self) -> &'static str;

    /// Encode `rows × row_len` f32 values from `src` into
    /// `codes`/`scale`/`zp`.
    ///
    /// # Panics
    /// Panics if `dtype` is [`KvDtype::F32`] or any buffer length
    /// disagrees with `rows`/`row_len`.
    fn encode_rows_into(
        &self,
        dtype: KvDtype,
        rows: usize,
        row_len: usize,
        src: &[f32],
        codes: &mut [u8],
        scale: &mut [f32],
        zp: &mut [u8],
    );

    /// Decode `rows × row_len` values from `codes`/`scale`/`zp` into
    /// `out`. Deterministic: identical output on every call.
    ///
    /// # Panics
    /// Panics if `dtype` is [`KvDtype::F32`] or any buffer length
    /// disagrees with `rows`/`row_len`.
    fn decode_rows_into(
        &self,
        dtype: KvDtype,
        rows: usize,
        row_len: usize,
        codes: &[u8],
        scale: &[f32],
        zp: &[u8],
        out: &mut [f32],
    );
}

/// Shared buffer-shape validation for [`Codec`] implementations.
fn check_codec_args(
    dtype: KvDtype,
    rows: usize,
    row_len: usize,
    codes_len: usize,
    scale_len: usize,
    zp_len: usize,
    f32_len: usize,
) {
    assert!(dtype.is_quantized(), "Codec requires q8/q4");
    assert_eq!(f32_len, rows * row_len, "f32-side length mismatch");
    assert_eq!(
        codes_len,
        rows * dtype.row_code_bytes(row_len),
        "code buffer length mismatch"
    );
    assert_eq!(scale_len, rows, "scale buffer length mismatch");
    assert_eq!(zp_len, rows, "zero-point buffer length mismatch");
}

/// The frozen scalar reference codec: a verbatim port of the original
/// per-element quantizer/dequantizer. **Do not optimize this type** —
/// it exists so [`VectorizedCodec`] has a bit-exact oracle to be
/// tested (and benched) against.
pub struct ScalarCodec;

impl Codec for ScalarCodec {
    fn name(&self) -> &'static str {
        "scalar-ref"
    }

    fn encode_rows_into(
        &self,
        dtype: KvDtype,
        rows: usize,
        row_len: usize,
        src: &[f32],
        codes: &mut [u8],
        scale: &mut [f32],
        zp: &mut [u8],
    ) {
        check_codec_args(dtype, rows, row_len, codes.len(), scale.len(), zp.len(), src.len());
        let qmax = dtype.qmax() as f32;
        let stride = dtype.row_code_bytes(row_len);
        for r in 0..rows {
            let xs = &src[r * row_len..(r + 1) * row_len];
            // the range scan sees finite values only: a NaN or ±inf
            // element must not poison the whole row's code lattice
            let mut lo = f32::INFINITY;
            let mut hi = f32::NEG_INFINITY;
            for &x in xs {
                if x.is_finite() {
                    lo = lo.min(x);
                    hi = hi.max(x);
                }
            }
            // the original quantizer wrote into freshly zeroed
            // buffers; reproduce that on recycled ones (q4 packing
            // below uses |=)
            scale[r] = 0.0;
            zp[r] = 0;
            let row = &mut codes[r * stride..(r + 1) * stride];
            row.fill(0);
            // constant rows take a degenerate exact encoding; varying
            // rows anchor the representable interval at zero so the
            // u8 zero-point is always in range (and zeros are exact)
            #[derive(Clone, Copy)]
            enum Enc {
                Zero,
                Const { s: f32 },
                Affine { s: f32, z: f32 },
            }
            let enc = if lo > hi {
                // no finite value in the row (all NaN/±inf): nothing
                // to anchor a lattice to — everything decodes to 0.0
                Enc::Zero
            } else if hi > lo {
                let (lo0, hi0) = (lo.min(0.0), hi.max(0.0));
                // the MIN_POSITIVE floor keeps a subnormal (or
                // underflowed-to-zero) spread from producing a
                // denormal step: x/s and −lo0/s stay finite, and the
                // half-step error bound still holds (the true spread
                // is below the floored step)
                let s = ((hi0 - lo0) / qmax).max(f32::MIN_POSITIVE);
                let z = (-lo0 / s).round().clamp(0.0, qmax);
                scale[r] = s;
                zp[r] = z as u8;
                Enc::Affine { s, z }
            } else if lo == 0.0 {
                // all-zero row (unwritten slots): exact zero codes
                Enc::Zero
            } else {
                // constant non-zero row: scale·(1 − 0) == value, exact
                scale[r] = lo;
                Enc::Const { s: lo }
            };
            for (d, &x) in xs.iter().enumerate() {
                // non-finite elements take defined codes: NaN decodes
                // to exactly 0.0, ±inf saturate to the row's
                // representable extremes
                let q = match enc {
                    Enc::Zero => 0u8,
                    Enc::Const { s } => {
                        if x.is_finite() {
                            1u8
                        } else if x.is_nan() {
                            0u8 // decodes to exactly 0.0
                        } else if (x > 0.0) == (s > 0.0) {
                            1u8 // ±inf saturates toward the value…
                        } else {
                            0u8 // …or toward 0.0, whichever is nearer
                        }
                    }
                    Enc::Affine { s, z } => {
                        if x.is_nan() {
                            z as u8 // the exact-zero code
                        } else {
                            (x / s + z).round().clamp(0.0, qmax) as u8
                        }
                    }
                };
                match dtype {
                    KvDtype::Q8 => row[d] = q,
                    KvDtype::Q4 => row[d / 2] |= q << ((d % 2) * 4),
                    KvDtype::F32 => unreachable!(),
                }
            }
        }
    }

    fn decode_rows_into(
        &self,
        dtype: KvDtype,
        rows: usize,
        row_len: usize,
        codes: &[u8],
        scale: &[f32],
        zp: &[u8],
        out: &mut [f32],
    ) {
        check_codec_args(dtype, rows, row_len, codes.len(), scale.len(), zp.len(), out.len());
        let stride = dtype.row_code_bytes(row_len);
        for r in 0..rows {
            let s = scale[r];
            let z = zp[r] as f32;
            let row = &codes[r * stride..(r + 1) * stride];
            let dst = &mut out[r * row_len..(r + 1) * row_len];
            for (d, y) in dst.iter_mut().enumerate() {
                let q = match dtype {
                    KvDtype::Q8 => row[d],
                    KvDtype::Q4 => unpack_q4(row, d),
                    KvDtype::F32 => unreachable!(),
                };
                *y = dequant_code(q, s, z);
            }
        }
    }
}

/// Accumulator width of the chunked range scan. Eight f32 lanes match
/// one AVX register; the min/max reductions are exact lattice ops, so
/// the chunked reduction order is bit-identical to a sequential scan.
const LANES: usize = 8;

/// Finite-only range scan of one row: `(lo, hi, has_nan)`.
///
/// Non-finite elements are masked to the identity of the reduction
/// (`+inf` for min, `−inf` for max) instead of branched over, so the
/// loop stays straight-line for the autovectorizer. `has_nan` gates
/// the branch-free encode fast path: ±inf saturates correctly through
/// the encode clamp, but NaN needs the per-element checked path.
#[inline]
fn range_scan(xs: &[f32]) -> (f32, f32, bool) {
    let mut lo = [f32::INFINITY; LANES];
    let mut hi = [f32::NEG_INFINITY; LANES];
    let mut nan = [false; LANES];
    let mut it = xs.chunks_exact(LANES);
    for c in it.by_ref() {
        for (j, &x) in c.iter().enumerate() {
            let fin = x.is_finite();
            lo[j] = lo[j].min(if fin { x } else { f32::INFINITY });
            hi[j] = hi[j].max(if fin { x } else { f32::NEG_INFINITY });
            nan[j] |= x.is_nan();
        }
    }
    let mut l = f32::INFINITY;
    let mut h = f32::NEG_INFINITY;
    let mut n = false;
    for j in 0..LANES {
        l = l.min(lo[j]);
        h = h.max(hi[j]);
        n |= nan[j];
    }
    for &x in it.remainder() {
        if x.is_finite() {
            l = l.min(x);
            h = h.max(x);
        }
        n |= x.is_nan();
    }
    (l, h, n)
}

/// One affine code with the NaN check the slow path needs. The
/// arithmetic is the *exact* expression of the scalar reference —
/// IEEE division, `round`, `clamp`, saturating cast — so fast and
/// checked paths produce identical bytes.
#[inline]
fn q_affine_checked(x: f32, s: f32, z: f32, qmax: f32) -> u8 {
    if x.is_nan() {
        z as u8 // the exact-zero code
    } else {
        (x / s + z).round().clamp(0.0, qmax) as u8
    }
}

/// One constant-row code (`q ≡ 1` for finite values; non-finite
/// elements saturate toward the value or 0, NaN → 0).
#[inline]
fn q_const(x: f32, s: f32) -> u8 {
    if x.is_finite() {
        1
    } else if x.is_nan() {
        0
    } else if (x > 0.0) == (s > 0.0) {
        1
    } else {
        0
    }
}

/// The production codec: chunked range scans, branch-free affine
/// encode for NaN-free rows, pair-packed q4 writes and LUT-based q4
/// decode. Pinned bit-identical to [`ScalarCodec`] by the
/// `codec_conformance` suite; used by every [`QuantBlock`] wrapper and
/// by [`CacheStore`](super::CacheStore)'s fused publish/upload paths.
pub struct VectorizedCodec;

impl VectorizedCodec {
    /// Branch-free affine encode of a NaN-free row. ±inf saturates to
    /// `{0, qmax}` through the clamp exactly as in the reference, so
    /// only NaN forces the checked path.
    #[inline]
    fn encode_affine_fast(dtype: KvDtype, xs: &[f32], s: f32, z: f32, qmax: f32, row: &mut [u8]) {
        match dtype {
            KvDtype::Q8 => {
                for (q, &x) in row.iter_mut().zip(xs) {
                    *q = (x / s + z).round().clamp(0.0, qmax) as u8;
                }
            }
            KvDtype::Q4 => {
                let pairs = xs.len() / 2;
                for (b, px) in row[..pairs].iter_mut().zip(xs.chunks_exact(2)) {
                    let q0 = (px[0] / s + z).round().clamp(0.0, qmax) as u8;
                    let q1 = (px[1] / s + z).round().clamp(0.0, qmax) as u8;
                    // full-byte write (low nibble first): no |= into
                    // stale bytes, so recycled buffers need no zeroing
                    *b = q0 | (q1 << 4);
                }
                if xs.len() % 2 == 1 {
                    row[pairs] = (xs[xs.len() - 1] / s + z).round().clamp(0.0, qmax) as u8;
                }
            }
            KvDtype::F32 => unreachable!(),
        }
    }

    /// Affine encode of a row containing at least one NaN.
    #[inline]
    fn encode_affine_checked(
        dtype: KvDtype,
        xs: &[f32],
        s: f32,
        z: f32,
        qmax: f32,
        row: &mut [u8],
    ) {
        match dtype {
            KvDtype::Q8 => {
                for (q, &x) in row.iter_mut().zip(xs) {
                    *q = q_affine_checked(x, s, z, qmax);
                }
            }
            KvDtype::Q4 => {
                let pairs = xs.len() / 2;
                for (b, px) in row[..pairs].iter_mut().zip(xs.chunks_exact(2)) {
                    *b = q_affine_checked(px[0], s, z, qmax)
                        | (q_affine_checked(px[1], s, z, qmax) << 4);
                }
                if xs.len() % 2 == 1 {
                    row[pairs] = q_affine_checked(xs[xs.len() - 1], s, z, qmax);
                }
            }
            KvDtype::F32 => unreachable!(),
        }
    }

    /// Constant-row encode (`q ∈ {0, 1}`).
    #[inline]
    fn encode_const(dtype: KvDtype, xs: &[f32], s: f32, row: &mut [u8]) {
        match dtype {
            KvDtype::Q8 => {
                for (q, &x) in row.iter_mut().zip(xs) {
                    *q = q_const(x, s);
                }
            }
            KvDtype::Q4 => {
                let pairs = xs.len() / 2;
                for (b, px) in row[..pairs].iter_mut().zip(xs.chunks_exact(2)) {
                    *b = q_const(px[0], s) | (q_const(px[1], s) << 4);
                }
                if xs.len() % 2 == 1 {
                    row[pairs] = q_const(xs[xs.len() - 1], s);
                }
            }
            KvDtype::F32 => unreachable!(),
        }
    }
}

impl Codec for VectorizedCodec {
    fn name(&self) -> &'static str {
        "vectorized"
    }

    fn encode_rows_into(
        &self,
        dtype: KvDtype,
        rows: usize,
        row_len: usize,
        src: &[f32],
        codes: &mut [u8],
        scale: &mut [f32],
        zp: &mut [u8],
    ) {
        check_codec_args(dtype, rows, row_len, codes.len(), scale.len(), zp.len(), src.len());
        let qmax = dtype.qmax() as f32;
        let stride = dtype.row_code_bytes(row_len);
        for r in 0..rows {
            let xs = &src[r * row_len..(r + 1) * row_len];
            let row = &mut codes[r * stride..(r + 1) * stride];
            let (lo, hi, has_nan) = range_scan(xs);
            // every row fully overwrites its metadata so recycled
            // buffers never leak stale scales into degenerate rows
            scale[r] = 0.0;
            zp[r] = 0;
            if lo > hi {
                // no finite value: everything decodes to 0.0
                row.fill(0);
            } else if hi > lo {
                let (lo0, hi0) = (lo.min(0.0), hi.max(0.0));
                let s = ((hi0 - lo0) / qmax).max(f32::MIN_POSITIVE);
                let z = (-lo0 / s).round().clamp(0.0, qmax);
                scale[r] = s;
                zp[r] = z as u8;
                if has_nan {
                    Self::encode_affine_checked(dtype, xs, s, z, qmax, row);
                } else {
                    Self::encode_affine_fast(dtype, xs, s, z, qmax, row);
                }
            } else if lo == 0.0 {
                // all-zero row (unwritten slots): exact zero codes
                row.fill(0);
            } else {
                scale[r] = lo;
                Self::encode_const(dtype, xs, lo, row);
            }
        }
    }

    fn decode_rows_into(
        &self,
        dtype: KvDtype,
        rows: usize,
        row_len: usize,
        codes: &[u8],
        scale: &[f32],
        zp: &[u8],
        out: &mut [f32],
    ) {
        check_codec_args(dtype, rows, row_len, codes.len(), scale.len(), zp.len(), out.len());
        let stride = dtype.row_code_bytes(row_len);
        for r in 0..rows {
            let s = scale[r];
            let z = zp[r] as f32;
            let row = &codes[r * stride..(r + 1) * stride];
            let dst = &mut out[r * row_len..(r + 1) * row_len];
            match dtype {
                KvDtype::Q8 => {
                    for (y, &q) in dst.iter_mut().zip(row) {
                        *y = s * (q as f32 - z);
                    }
                }
                KvDtype::Q4 => {
                    let pairs = row_len / 2;
                    for (ys, &b) in dst.chunks_exact_mut(2).zip(&row[..pairs]) {
                        let [q0, q1] = Q4_NIBBLES[b as usize];
                        ys[0] = s * (q0 as f32 - z);
                        ys[1] = s * (q1 as f32 - z);
                    }
                    if row_len % 2 == 1 {
                        dst[row_len - 1] = s * ((row[pairs] & 0x0F) as f32 - z);
                    }
                }
                KvDtype::F32 => unreachable!(),
            }
        }
    }
}

/// A quantized block of `rows × row_len` values (see module docs for
/// the per-row affine scheme and the error bound).
#[derive(Clone, Debug)]
pub struct QuantBlock {
    dtype: KvDtype,
    rows: usize,
    row_len: usize,
    /// Packed codes, `rows × row_stride` bytes.
    data: Vec<u8>,
    /// Per-row scale (may be negative for constant negative rows).
    scale: Vec<f32>,
    /// Per-row zero-point in the quantized domain.
    zp: Vec<u8>,
}

impl QuantBlock {
    /// Quantize `src` (length `rows × row_len`) into a block using the
    /// production [`VectorizedCodec`].
    ///
    /// # Panics
    /// Panics if `dtype` is [`KvDtype::F32`] (nothing to quantize) or
    /// if `src` has the wrong length.
    pub fn quantize(dtype: KvDtype, rows: usize, row_len: usize, src: &[f32]) -> Self {
        Self::quantize_with(&VectorizedCodec, dtype, rows, row_len, src)
    }

    /// Quantize `src` with an explicit [`Codec`] implementation (the
    /// conformance tests and benches pass [`ScalarCodec`] here).
    pub fn quantize_with<C: Codec + ?Sized>(
        codec: &C,
        dtype: KvDtype,
        rows: usize,
        row_len: usize,
        src: &[f32],
    ) -> Self {
        assert!(dtype.is_quantized(), "QuantBlock requires q8/q4");
        assert_eq!(src.len(), rows * row_len, "source length mismatch");
        let mut b = Self::zeroed(dtype, rows, row_len);
        codec.encode_rows_into(dtype, rows, row_len, src, &mut b.data, &mut b.scale, &mut b.zp);
        b
    }

    /// An all-zero block (decodes to `0.0` everywhere — the unwritten
    /// slot encoding), ready for in-place [`Self::encode_rows_from`].
    pub fn zeroed(dtype: KvDtype, rows: usize, row_len: usize) -> Self {
        assert!(dtype.is_quantized(), "QuantBlock requires q8/q4");
        Self {
            dtype,
            rows,
            row_len,
            data: vec![0u8; rows * dtype.row_code_bytes(row_len)],
            scale: vec![0f32; rows],
            zp: vec![0u8; rows],
        }
    }

    /// Re-shape this block in place, keeping buffer capacity (the
    /// arena-recycled publish path). Contents of rows not subsequently
    /// rewritten via [`Self::encode_rows_from`] are unspecified.
    pub fn reshape(&mut self, dtype: KvDtype, rows: usize, row_len: usize) {
        assert!(dtype.is_quantized(), "QuantBlock requires q8/q4");
        self.dtype = dtype;
        self.rows = rows;
        self.row_len = row_len;
        self.data.resize(rows * dtype.row_code_bytes(row_len), 0);
        self.scale.resize(rows, 0.0);
        self.zp.resize(rows, 0);
    }

    /// Encode rows `[row0, row0 + n_rows)` in place from `src` (length
    /// `n_rows × row_len`) via the [`VectorizedCodec`]. This is the
    /// fused publish path: fresh lane f32 goes straight into the
    /// block's recycled buffers, with no staging copy. Each row is
    /// encoded independently, so chunked per-(layer, head) encodes are
    /// bit-identical to one whole-block [`Self::quantize`].
    pub fn encode_rows_from(&mut self, row0: usize, n_rows: usize, src: &[f32]) {
        assert!(row0 + n_rows <= self.rows, "row range out of bounds");
        let stride = self.dtype.row_code_bytes(self.row_len);
        VectorizedCodec.encode_rows_into(
            self.dtype,
            n_rows,
            self.row_len,
            src,
            &mut self.data[row0 * stride..(row0 + n_rows) * stride],
            &mut self.scale[row0..row0 + n_rows],
            &mut self.zp[row0..row0 + n_rows],
        );
    }

    /// Dequantize rows `[row0, row0 + n_rows)` into `out` (length
    /// `n_rows × row_len`). Deterministic: identical output on every
    /// call.
    pub fn dequantize_rows_into(&self, row0: usize, n_rows: usize, out: &mut [f32]) {
        assert!(row0 + n_rows <= self.rows, "row range out of bounds");
        assert_eq!(out.len(), n_rows * self.row_len, "output length mismatch");
        let stride = self.dtype.row_code_bytes(self.row_len);
        VectorizedCodec.decode_rows_into(
            self.dtype,
            n_rows,
            self.row_len,
            &self.data[row0 * stride..(row0 + n_rows) * stride],
            &self.scale[row0..row0 + n_rows],
            &self.zp[row0..row0 + n_rows],
            out,
        );
    }

    /// Storage format of this block.
    pub fn dtype(&self) -> KvDtype {
        self.dtype
    }

    /// Row count.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Elements per row.
    pub fn row_len(&self) -> usize {
        self.row_len
    }

    /// Quantization step of one row: for varying rows the per-element
    /// round-trip error is bounded by `|scale|/2`; for constant rows
    /// `scale` holds the (exactly reproduced) value itself.
    pub fn row_scale(&self, row: usize) -> f32 {
        self.scale[row]
    }

    /// Zero-point of one row (0 for degenerate rows).
    pub fn row_zp(&self, row: usize) -> u8 {
        self.zp[row]
    }

    /// Packed code bytes (`rows × row_code_bytes`) — exposed so the
    /// bit-identity suites can compare blocks byte-for-byte.
    pub fn codes(&self) -> &[u8] {
        &self.data
    }

    /// Per-row scales (`rows` entries) — with [`Self::codes`] and
    /// [`Self::zps`] the complete serialized form of a block.
    pub fn scales(&self) -> &[f32] {
        &self.scale
    }

    /// Per-row zero-points (`rows` entries).
    pub fn zps(&self) -> &[u8] {
        &self.zp
    }

    /// Reassemble a block from previously serialized parts
    /// ([`Self::codes`] / [`Self::scales`] / [`Self::zps`]) **without
    /// re-encoding**. This is how the cold-tier spill path round-trips
    /// blocks through disk bit-exactly: the code lattice is moved
    /// verbatim, so deserialization is never a lossy step and the
    /// requantize-once rule survives a spill/reload cycle.
    ///
    /// # Panics
    /// Panics if `dtype` is [`KvDtype::F32`] or any buffer length
    /// disagrees with `rows`/`row_len`.
    pub fn from_raw(
        dtype: KvDtype,
        rows: usize,
        row_len: usize,
        data: Vec<u8>,
        scale: Vec<f32>,
        zp: Vec<u8>,
    ) -> Self {
        assert!(dtype.is_quantized(), "QuantBlock requires q8/q4");
        assert_eq!(data.len(), rows * dtype.row_code_bytes(row_len), "code length mismatch");
        assert_eq!(scale.len(), rows, "scale length mismatch");
        assert_eq!(zp.len(), rows, "zero-point length mismatch");
        Self { dtype, rows, row_len, data, scale, zp }
    }

    /// Host bytes this block occupies (codes + scale/zero-point).
    pub fn payload_bytes(&self) -> usize {
        self.data.len() + self.scale.len() * 4 + self.zp.len()
    }
}

/// A KV payload block: either exact f32 or a quantized [`QuantBlock`].
///
/// This is the storage type behind [`PageData`](super::PageData) —
/// every pool-owned page's K and V live in one of these.
#[derive(Clone, Debug)]
pub enum KvBlock {
    /// Exact f32 payload (`rows × row_len` values).
    F32(Vec<f32>),
    /// Quantized payload with per-row scale/zero-point.
    Quant(QuantBlock),
}

impl KvBlock {
    /// Encode `data` (length `rows × row_len`) under `dtype`. For
    /// [`KvDtype::F32`] the vector is stored as-is (exact, zero cost);
    /// otherwise it is quantized — this is the *single* lossy step of a
    /// payload's lifetime (requantize-once rule).
    pub fn from_f32(dtype: KvDtype, rows: usize, row_len: usize, data: Vec<f32>) -> Self {
        debug_assert_eq!(data.len(), rows * row_len);
        match dtype {
            KvDtype::F32 => KvBlock::F32(data),
            _ => KvBlock::Quant(QuantBlock::quantize(dtype, rows, row_len, &data)),
        }
    }

    /// An all-zero block of the given shape (decodes/reads as `0.0`
    /// everywhere), ready for in-place [`Self::write_rows_from`].
    pub fn zeroed(dtype: KvDtype, rows: usize, row_len: usize) -> Self {
        match dtype {
            KvDtype::F32 => KvBlock::F32(vec![0f32; rows * row_len]),
            _ => KvBlock::Quant(QuantBlock::zeroed(dtype, rows, row_len)),
        }
    }

    /// Re-shape this block in place, recycling buffer capacity when
    /// the dtype matches the current variant (the arena publish path).
    /// Contents of rows not subsequently rewritten via
    /// [`Self::write_rows_from`] are unspecified.
    pub fn reshape(&mut self, dtype: KvDtype, rows: usize, row_len: usize) {
        match (self, dtype) {
            (KvBlock::F32(data), KvDtype::F32) => data.resize(rows * row_len, 0.0),
            (KvBlock::Quant(q), d) if d.is_quantized() => q.reshape(d, rows, row_len),
            (slot, d) => *slot = KvBlock::zeroed(d, rows, row_len),
        }
    }

    /// Write rows `[row0, row0 + n_rows)` in place from `src` (length
    /// `n_rows × row_len`): a straight copy for f32 payloads, a fused
    /// [`VectorizedCodec`] encode otherwise. This is the single lossy
    /// step of the publish path (requantize-once rule) — row
    /// independence makes chunked per-(layer, head) writes
    /// bit-identical to encoding the whole block at once.
    pub fn write_rows_from(&mut self, row0: usize, n_rows: usize, row_len: usize, src: &[f32]) {
        debug_assert_eq!(src.len(), n_rows * row_len);
        match self {
            KvBlock::F32(data) => {
                data[row0 * row_len..(row0 + n_rows) * row_len].copy_from_slice(src);
            }
            KvBlock::Quant(q) => {
                debug_assert_eq!(q.row_len(), row_len);
                q.encode_rows_from(row0, n_rows, src);
            }
        }
    }

    /// Decode rows `[row0, row0 + n_rows)` into `out`. Exact copy for
    /// f32 payloads; deterministic dequantization otherwise.
    pub fn read_rows_into(&self, row0: usize, n_rows: usize, row_len: usize, out: &mut [f32]) {
        match self {
            KvBlock::F32(data) => {
                out.copy_from_slice(&data[row0 * row_len..(row0 + n_rows) * row_len]);
            }
            KvBlock::Quant(q) => {
                debug_assert_eq!(q.row_len(), row_len);
                q.dequantize_rows_into(row0, n_rows, out);
            }
        }
    }

    /// Decode the whole block to f32.
    pub fn to_f32(&self) -> Vec<f32> {
        match self {
            KvBlock::F32(data) => data.clone(),
            KvBlock::Quant(q) => {
                let mut out = vec![0f32; q.rows() * q.row_len()];
                q.dequantize_rows_into(0, q.rows(), &mut out);
                out
            }
        }
    }

    /// Host bytes this payload occupies.
    pub fn payload_bytes(&self) -> usize {
        match self {
            KvBlock::F32(data) => data.len() * 4,
            KvBlock::Quant(q) => q.payload_bytes(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Pseudo-random but deterministic row values.
    fn row_values(rows: usize, row_len: usize, seed: u64) -> Vec<f32> {
        let mut state = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1;
        (0..rows * row_len)
            .map(|_| {
                state = state
                    .wrapping_mul(6364136223846793005)
                    .wrapping_add(1442695040888963407);
                ((state >> 33) as f64 / (1u64 << 31) as f64 - 0.5) as f32 * 4.0
            })
            .collect()
    }

    fn roundtrip_bound(dtype: KvDtype, rows: usize, row_len: usize) {
        let src = row_values(rows, row_len, 7 + dtype.bits() as u64);
        let b = QuantBlock::quantize(dtype, rows, row_len, &src);
        let mut out = vec![0f32; rows * row_len];
        b.dequantize_rows_into(0, rows, &mut out);
        for r in 0..rows {
            let bound = b.row_scale(r).abs() * 0.5001 + 1e-6;
            for d in 0..row_len {
                let (x, y) = (src[r * row_len + d], out[r * row_len + d]);
                assert!(
                    (x - y).abs() <= bound,
                    "{dtype}: row {r} elem {d}: |{x} - {y}| > {bound}"
                );
            }
        }
    }

    #[test]
    fn q8_roundtrip_within_half_step() {
        roundtrip_bound(KvDtype::Q8, 13, 16);
    }

    #[test]
    fn q4_roundtrip_within_half_step() {
        roundtrip_bound(KvDtype::Q4, 13, 16);
    }

    #[test]
    fn q4_handles_odd_row_length() {
        roundtrip_bound(KvDtype::Q4, 5, 7);
    }

    #[test]
    fn constant_and_zero_rows_are_exact() {
        for dtype in [KvDtype::Q8, KvDtype::Q4] {
            // zero row, positive constant, negative constant
            let src = [0.0f32, 0.0, 0.0, 2.5, 2.5, 2.5, -1.75, -1.75, -1.75];
            let b = QuantBlock::quantize(dtype, 3, 3, &src);
            let mut out = [0f32; 9];
            b.dequantize_rows_into(0, 3, &mut out);
            assert_eq!(&src[..], &out[..], "{dtype}: constant rows must round-trip");
        }
    }

    #[test]
    fn dequantization_is_deterministic() {
        let src = row_values(8, 12, 42);
        let b = QuantBlock::quantize(KvDtype::Q8, 8, 12, &src);
        let mut a = vec![0f32; 8 * 12];
        let mut c = vec![0f32; 8 * 12];
        b.dequantize_rows_into(0, 8, &mut a);
        b.dequantize_rows_into(0, 8, &mut c);
        assert_eq!(a, c);
        // and a re-encode of the same source yields identical codes
        let b2 = QuantBlock::quantize(KvDtype::Q8, 8, 12, &src);
        let mut d = vec![0f32; 8 * 12];
        b2.dequantize_rows_into(0, 8, &mut d);
        assert_eq!(a, d);
    }

    #[test]
    fn partial_row_reads_match_full_reads() {
        let src = row_values(10, 6, 3);
        let b = QuantBlock::quantize(KvDtype::Q4, 10, 6, &src);
        let mut full = vec![0f32; 60];
        b.dequantize_rows_into(0, 10, &mut full);
        let mut part = vec![0f32; 18];
        b.dequantize_rows_into(4, 3, &mut part);
        assert_eq!(&full[24..42], &part[..]);
    }

    #[test]
    fn scalar_and_vectorized_blocks_are_bit_identical() {
        // the full cross-geometry × edge-row matrix lives in the
        // codec_conformance integration suite; this is the in-module
        // smoke version
        for dtype in [KvDtype::Q8, KvDtype::Q4] {
            let src = row_values(9, 13, 77);
            let a = QuantBlock::quantize_with(&ScalarCodec, dtype, 9, 13, &src);
            let b = QuantBlock::quantize_with(&VectorizedCodec, dtype, 9, 13, &src);
            assert_eq!(a.codes(), b.codes(), "{dtype}: codes diverge");
            for r in 0..9 {
                assert_eq!(a.row_scale(r).to_bits(), b.row_scale(r).to_bits());
                assert_eq!(a.row_zp(r), b.row_zp(r));
            }
        }
    }

    #[test]
    fn in_place_encode_matches_whole_block_quantize() {
        let src = row_values(6, 16, 5);
        for dtype in [KvDtype::Q8, KvDtype::Q4] {
            let whole = QuantBlock::quantize(dtype, 6, 16, &src);
            // recycled block: reshape from a different geometry, then
            // encode in two chunks
            let mut b = QuantBlock::zeroed(dtype, 2, 9);
            b.reshape(dtype, 6, 16);
            b.encode_rows_from(0, 4, &src[..4 * 16]);
            b.encode_rows_from(4, 2, &src[4 * 16..]);
            assert_eq!(whole.codes(), b.codes(), "{dtype}: chunked encode diverges");
        }
    }

    #[test]
    fn payload_bytes_hit_compression_targets() {
        // hd = 16: f32 64 B/row, q8 21 B/row (3.05×), q4 13 B/row (4.9×)
        let hd = 16;
        let f32_bytes = KvDtype::F32.row_payload_bytes(hd);
        let q8_bytes = KvDtype::Q8.row_payload_bytes(hd);
        let q4_bytes = KvDtype::Q4.row_payload_bytes(hd);
        assert_eq!(f32_bytes, 64);
        assert_eq!(q8_bytes, 21);
        assert_eq!(q4_bytes, 13);
        assert!(
            f32_bytes as f64 / q8_bytes as f64 >= 3.0,
            "q8 must shrink host bytes-per-token ≥ 3×"
        );
        assert!(f32_bytes as f64 / q4_bytes as f64 >= 4.5);
        // a block's actual accounting matches the nominal figure
        let src = row_values(4, hd, 1);
        let b = QuantBlock::quantize(KvDtype::Q8, 4, hd, &src);
        assert_eq!(b.payload_bytes(), 4 * q8_bytes);
    }

    #[test]
    fn kvblock_f32_is_exact_and_cheap() {
        let src = row_values(3, 5, 9);
        let b = KvBlock::from_f32(KvDtype::F32, 3, 5, src.clone());
        assert_eq!(b.to_f32(), src);
        assert_eq!(b.payload_bytes(), src.len() * 4);
        let mut out = vec![0f32; 5];
        b.read_rows_into(1, 1, 5, &mut out);
        assert_eq!(&out[..], &src[5..10]);
    }

    #[test]
    fn kvblock_write_rows_matches_from_f32() {
        let src = row_values(8, 16, 21);
        for dtype in [KvDtype::F32, KvDtype::Q8, KvDtype::Q4] {
            let whole = KvBlock::from_f32(dtype, 8, 16, src.clone());
            let mut b = KvBlock::zeroed(dtype, 8, 16);
            // chunked in-place writes, as the fused publish path does
            b.write_rows_from(0, 3, 16, &src[..3 * 16]);
            b.write_rows_from(3, 5, 16, &src[3 * 16..]);
            assert_eq!(
                whole.to_f32(),
                b.to_f32(),
                "{dtype}: fused write path diverges from from_f32"
            );
        }
    }

    #[test]
    fn from_raw_round_trips_serialized_parts_bit_exactly() {
        for dtype in [KvDtype::Q8, KvDtype::Q4] {
            let src = row_values(7, 11, 31);
            let b = QuantBlock::quantize(dtype, 7, 11, &src);
            let rebuilt = QuantBlock::from_raw(
                dtype,
                7,
                11,
                b.codes().to_vec(),
                b.scales().to_vec(),
                b.zps().to_vec(),
            );
            assert_eq!(b.codes(), rebuilt.codes());
            let (mut a, mut c) = (vec![0f32; 77], vec![0f32; 77]);
            b.dequantize_rows_into(0, 7, &mut a);
            rebuilt.dequantize_rows_into(0, 7, &mut c);
            assert_eq!(
                a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                c.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "{dtype}: from_raw must decode bit-identically"
            );
        }
    }

    #[test]
    fn dtype_parsing_roundtrip() {
        for d in [KvDtype::F32, KvDtype::Q8, KvDtype::Q4] {
            assert_eq!(d.name().parse::<KvDtype>().unwrap(), d);
        }
        assert!("bf16".parse::<KvDtype>().is_err());
    }
}
