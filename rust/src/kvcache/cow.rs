//! Copy-on-write page pool: refcounted ownership of shared KV pages.
//!
//! The executor ABI is fixed — every step uploads dense per-lane arrays
//! `k/v: [L, B, H, S, hd]` — so a lane's *region* of the flat arrays is
//! only a materialized view. Ownership of shared content lives here:
//! a [`PagePool`] entry represents one **token page** (all layers and
//! KV-heads of `page_size` consecutive slots) that more than one owner
//! references. Owners are lane mappings (`CacheStore::page_map`) and
//! the radix prefix index; each holds one reference.
//!
//! A page's payload is in one of two states:
//!
//! * [`Payload::Borrowed`] — the bytes still live in the borrowing
//!   lane's region of the flat arrays (the common fork case: siblings
//!   reference the leader's prefill pages with zero copies);
//! * [`Payload::Owned`] — the pool holds its own snapshot
//!   ([`PageData`]), taken the moment the borrowing lane was about to
//!   diverge (copy-on-write) or retire (prefix retention).
//!
//! The COW rule enforced by `CacheStore`'s mutation guards: **any**
//! mutation of a shared page — a token write, a DMS/TOVA/H2O eviction,
//! a DMC merge — first detaches the mutating lane from the entry, and
//! if that lane was the payload borrower with other references
//! outstanding, publishes a pristine snapshot into the pool first.
//! Compression decisions therefore can never reach through a shared
//! prefix into a sibling's view.
//!
//! Releasing a reference that is not held panics: a double-free of a
//! KV page is a cache-corruption bug, never recoverable bookkeeping.
//!
//! ## Snapshot arena
//!
//! Owned payloads are boxed ([`Payload::Owned`] holds a
//! `Box<PageData>`), and the pool keeps a small freelist of retired
//! snapshot boxes: when the last reference to an owned entry is
//! released, its payload drops into the spare list (capped at
//! [`MAX_SPARE_PAGES`]) instead of the allocator, and the next publish
//! reclaims it via [`PagePool::take_spare`] +
//! [`KvBlock::reshape`](super::KvBlock::reshape). Publish/recycle
//! churn — every COW detach, prefix export, and lane retirement —
//! therefore reuses a handful of steady-state buffers instead of
//! allocating six vectors per page.
//!
//! ## Payload storage format
//!
//! Owned payloads carry their K/V as [`KvBlock`]s: exact f32, or
//! per-row q8/q4 quantized blocks with scale/zero-point metadata (see
//! [`super::quant`] and `docs/NUMERICS.md`). The store quantizes
//! exactly once, at the publish/export boundary where a page enters
//! the pool; the pool itself never re-encodes a payload, so a shared
//! page's code lattice — and therefore every consumer's dequantized
//! view — is stable for the entry's whole lifetime.
//!
//! ## Lifecycle example
//!
//! ```
//! use hyperscale::kvcache::{KvBlock, KvDtype, PageData, PagePool, Payload, SlotState};
//!
//! let mut pool = PagePool::new();
//!
//! // a fork registers the leader's page as borrowed (zero-copy)...
//! let id = pool.adopt_borrowed(/*lane=*/ 0, /*page=*/ 3);
//! pool.retain(id); // ...and the sibling takes its reference
//! assert_eq!(pool.refs(id), 2);
//! assert!(pool.is_borrowed_from(id, 0));
//!
//! // before the leader mutates (or retires), the pristine bytes are
//! // published into the pool — quantized here at q8, the single lossy
//! // step of the payload's lifetime
//! let snap = Box::new(PageData {
//!     k: KvBlock::from_f32(KvDtype::Q8, 2, 4, vec![1.0; 8]),
//!     v: KvBlock::from_f32(KvDtype::Q8, 2, 4, vec![2.0; 8]),
//!     mask: vec![0.0; 2],
//!     meta: vec![SlotState::Free; 2],
//!     pmin: vec![0.0; 4],
//!     pmax: vec![0.0; 4],
//! });
//! pool.publish(id, snap);
//! assert!(matches!(pool.payload(id), Payload::Owned(_)));
//! assert!(pool.owned_payload_bytes() > 0);
//!
//! // both owners release; the entry is freed on the last reference
//! assert!(!pool.release(id));
//! assert!(pool.release(id));
//! assert!(pool.is_empty());
//! // ...and the retired snapshot's buffers await the next publish
//! assert!(pool.take_spare().is_some());
//! ```

use std::collections::BTreeMap;

use super::quant::KvBlock;
use super::store::SlotState;

/// Opaque handle to a pooled page.
pub type PageId = u64;

/// Snapshot of one token page across all (layer, KV-head) pairs.
#[derive(Clone, Debug)]
pub struct PageData {
    /// K payload, `lh × page_size` rows of `hd` values (f32 or
    /// quantized — see [`KvBlock`]).
    pub k: KvBlock,
    /// V payload, same shape as `k`.
    pub v: KvBlock,
    /// f32[lh, page_size] additive mask.
    pub mask: Vec<f32>,
    /// Slot metadata per (lh, page_size).
    pub meta: Vec<SlotState>,
    /// f32[lh, hd] Quest page bounds.
    pub pmin: Vec<f32>,
    /// f32[lh, hd] Quest page bounds.
    pub pmax: Vec<f32>,
}

impl PageData {
    /// Host bytes of the K+V payload (codes + quant metadata; excludes
    /// the slot mask/meta/bounds sidecar, which is precision-invariant).
    pub fn payload_bytes(&self) -> usize {
        self.k.payload_bytes() + self.v.payload_bytes()
    }
}

/// Where a pooled page's bytes currently live.
#[derive(Debug)]
pub enum Payload {
    /// Still resident in `lane`'s region of the flat arrays.
    Borrowed {
        /// The lane whose region holds the authoritative bytes.
        lane: usize,
    },
    /// Snapshotted into the pool (survives lane recycling).
    Owned(Box<PageData>),
}

#[derive(Debug)]
struct Entry {
    payload: Payload,
    /// Outstanding references: lane mappings + pending-chain holds +
    /// prefix-index retention.
    refs: usize,
    /// Page index within the slot space (identical in every mapper:
    /// shared pages are position-aligned).
    page: usize,
}

/// Cap on the snapshot freelist: enough to absorb a burst of COW
/// publishes between restores without pinning unbounded memory.
pub const MAX_SPARE_PAGES: usize = 32;

/// Refcounted registry of shared pages (see module docs).
#[derive(Debug, Default)]
pub struct PagePool {
    entries: BTreeMap<PageId, Entry>,
    next_id: PageId,
    /// Retired owned snapshots awaiting reuse (the snapshot arena).
    spares: Vec<Box<PageData>>,
}

impl PagePool {
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of live pool entries (shared or retained pages).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Total references outstanding across all entries.
    pub fn total_refs(&self) -> usize {
        self.entries.values().map(|e| e.refs).sum()
    }

    /// Entries whose payload is an owned snapshot (vs still borrowed
    /// from a lane's region of the flat arrays).
    pub fn owned_pages(&self) -> usize {
        self.entries
            .values()
            .filter(|e| matches!(e.payload, Payload::Owned(_)))
            .count()
    }

    /// Host bytes of K+V payload held by owned snapshots — the number
    /// quantization shrinks (borrowed payloads cost the pool nothing).
    pub fn owned_payload_bytes(&self) -> usize {
        self.entries
            .values()
            .map(|e| match &e.payload {
                Payload::Owned(d) => d.payload_bytes(),
                Payload::Borrowed { .. } => 0,
            })
            .sum()
    }

    /// Register a page whose payload stays borrowed from `lane`'s
    /// region, with one reference (the borrower's own mapping).
    pub fn adopt_borrowed(&mut self, lane: usize, page: usize) -> PageId {
        self.insert(Payload::Borrowed { lane }, page)
    }

    /// Register an owned snapshot with one reference (the caller's).
    pub fn insert_owned(&mut self, data: Box<PageData>, page: usize) -> PageId {
        self.insert(Payload::Owned(data), page)
    }

    /// Take a retired snapshot box for reuse (arena path): the caller
    /// reshapes its blocks in place and overwrites every field before
    /// publishing it back. `None` when the freelist is empty.
    pub fn take_spare(&mut self) -> Option<Box<PageData>> {
        self.spares.pop()
    }

    /// Snapshot boxes currently waiting on the freelist.
    pub fn spare_pages(&self) -> usize {
        self.spares.len()
    }

    fn insert(&mut self, payload: Payload, page: usize) -> PageId {
        let id = self.next_id;
        self.next_id += 1;
        self.entries.insert(
            id,
            Entry {
                payload,
                refs: 1,
                page,
            },
        );
        id
    }

    /// Add one reference.
    ///
    /// # Panics
    /// Panics if `id` is not a live entry.
    pub fn retain(&mut self, id: PageId) {
        self.entries
            .get_mut(&id)
            .unwrap_or_else(|| panic!("retain of dead page {id}"))
            .refs += 1;
    }

    /// Drop one reference; the entry is freed when the count reaches
    /// zero. Returns true when this release freed the entry.
    ///
    /// # Panics
    /// Panics if `id` is not a live entry — releasing a page that was
    /// already freed is a double-free.
    pub fn release(&mut self, id: PageId) -> bool {
        let e = self
            .entries
            .get_mut(&id)
            .unwrap_or_else(|| panic!("double-free of page {id}"));
        e.refs -= 1;
        if e.refs == 0 {
            // reclaim the snapshot's buffers into the arena instead of
            // freeing them — the next publish reshapes them in place
            if let Some(Entry {
                payload: Payload::Owned(data),
                ..
            }) = self.entries.remove(&id)
            {
                if self.spares.len() < MAX_SPARE_PAGES {
                    self.spares.push(data);
                }
            }
            true
        } else {
            false
        }
    }

    /// Drop one reference and, when this release frees the entry,
    /// hand its owned snapshot to the caller instead of the spare
    /// arena — the cold-tier demote path, which moves the payload into
    /// a separate budget rather than dropping it. Returns
    /// `Some((page_index, data))` only when this release freed an
    /// entry whose payload was [`Payload::Owned`]; a freed
    /// still-borrowed entry (nothing snapshotted to demote) and a
    /// still-referenced entry both return `None`.
    ///
    /// # Panics
    /// Panics if `id` is not a live entry (double-free), exactly like
    /// [`Self::release`].
    pub fn release_take(&mut self, id: PageId) -> Option<(usize, Box<PageData>)> {
        let e = self
            .entries
            .get_mut(&id)
            .unwrap_or_else(|| panic!("double-free of page {id}"));
        e.refs -= 1;
        if e.refs == 0 {
            let e = self.entries.remove(&id).unwrap();
            if let Payload::Owned(data) = e.payload {
                return Some((e.page, data));
            }
        }
        None
    }

    /// Current reference count (0 for unknown ids).
    pub fn refs(&self, id: PageId) -> usize {
        self.entries.get(&id).map(|e| e.refs).unwrap_or(0)
    }

    /// The slot-space page index this entry restores into.
    pub fn page_index(&self, id: PageId) -> usize {
        self.entries[&id].page
    }

    /// Whether the payload is still borrowed from `lane`.
    pub fn is_borrowed_from(&self, id: PageId, lane: usize) -> bool {
        matches!(
            self.entries.get(&id).map(|e| &e.payload),
            Some(Payload::Borrowed { lane: l }) if *l == lane
        )
    }

    /// Payload view for materialization.
    pub fn payload(&self, id: PageId) -> &Payload {
        &self.entries[&id].payload
    }

    /// Promote a borrowed payload to an owned snapshot (COW publish).
    pub fn publish(&mut self, id: PageId, data: Box<PageData>) {
        let e = self.entries.get_mut(&id).expect("publish of dead page");
        debug_assert!(
            matches!(e.payload, Payload::Borrowed { .. }),
            "publish of already-owned page"
        );
        e.payload = Payload::Owned(data);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::KvDtype;

    fn data() -> Box<PageData> {
        Box::new(PageData {
            k: KvBlock::from_f32(KvDtype::F32, 2, 4, vec![1.0; 8]),
            v: KvBlock::from_f32(KvDtype::F32, 2, 4, vec![2.0; 8]),
            mask: vec![0.0; 2],
            meta: vec![SlotState::Free; 2],
            pmin: vec![0.0; 4],
            pmax: vec![0.0; 4],
        })
    }

    #[test]
    fn refcount_lifecycle() {
        let mut p = PagePool::new();
        let id = p.adopt_borrowed(0, 3);
        assert_eq!(p.refs(id), 1);
        assert_eq!(p.page_index(id), 3);
        p.retain(id);
        assert_eq!(p.refs(id), 2);
        assert!(!p.release(id));
        assert!(p.release(id));
        assert!(p.is_empty());
    }

    #[test]
    #[should_panic(expected = "double-free")]
    fn double_free_panics() {
        let mut p = PagePool::new();
        let id = p.insert_owned(data(), 0);
        p.release(id);
        p.release(id);
    }

    #[test]
    #[should_panic(expected = "dead page")]
    fn retain_after_free_panics() {
        let mut p = PagePool::new();
        let id = p.insert_owned(data(), 0);
        p.release(id);
        p.retain(id);
    }

    #[test]
    fn publish_promotes_borrowed() {
        let mut p = PagePool::new();
        let id = p.adopt_borrowed(2, 0);
        assert!(p.is_borrowed_from(id, 2));
        p.publish(id, data());
        assert!(!p.is_borrowed_from(id, 2));
        match p.payload(id) {
            Payload::Owned(d) => assert_eq!(d.k.to_f32()[0], 1.0),
            Payload::Borrowed { .. } => panic!("still borrowed"),
        }
    }

    #[test]
    fn owned_accounting_tracks_payload_bytes() {
        let mut p = PagePool::new();
        let b = p.adopt_borrowed(0, 0);
        assert_eq!(p.owned_pages(), 0);
        assert_eq!(p.owned_payload_bytes(), 0, "borrowed payloads are free");
        let o = p.insert_owned(data(), 1);
        assert_eq!(p.owned_pages(), 1);
        // 8 f32 K + 8 f32 V
        assert_eq!(p.owned_payload_bytes(), 16 * 4);
        p.release(o);
        assert_eq!(p.owned_payload_bytes(), 0);
        p.release(b);
    }

    #[test]
    fn released_snapshots_feed_the_spare_arena() {
        let mut p = PagePool::new();
        assert!(p.take_spare().is_none());
        let o = p.insert_owned(data(), 0);
        assert_eq!(p.spare_pages(), 0, "live entries are not spares");
        assert!(p.release(o));
        assert_eq!(p.spare_pages(), 1);
        let spare = p.take_spare().expect("retired snapshot reclaimed");
        assert_eq!(spare.mask.len(), 2, "buffers survive intact");
        assert!(p.take_spare().is_none());
        // borrowed entries have no snapshot to reclaim
        let b = p.adopt_borrowed(0, 1);
        p.release(b);
        assert_eq!(p.spare_pages(), 0);
    }

    #[test]
    fn release_take_hands_over_the_final_snapshot() {
        let mut p = PagePool::new();
        let id = p.insert_owned(data(), 4);
        p.retain(id);
        // not the last reference: nothing taken, entry still live
        assert!(p.release_take(id).is_none());
        assert_eq!(p.refs(id), 1);
        // last reference: the snapshot moves out instead of sparing
        let (page, snap) = p.release_take(id).expect("owned payload taken");
        assert_eq!(page, 4);
        assert_eq!(snap.k.to_f32()[0], 1.0);
        assert!(p.is_empty());
        assert_eq!(p.spare_pages(), 0, "taken payloads never hit the arena");
        // borrowed entries free with nothing to take
        let b = p.adopt_borrowed(0, 1);
        assert!(p.release_take(b).is_none());
        assert!(p.is_empty());
    }

    #[test]
    #[should_panic(expected = "double-free")]
    fn release_take_double_free_panics() {
        let mut p = PagePool::new();
        let id = p.insert_owned(data(), 0);
        p.release(id);
        p.release_take(id);
    }

    #[test]
    fn spare_arena_is_capped() {
        let mut p = PagePool::new();
        let ids: Vec<PageId> = (0..MAX_SPARE_PAGES + 5)
            .map(|i| p.insert_owned(data(), i))
            .collect();
        for id in ids {
            p.release(id);
        }
        assert_eq!(p.spare_pages(), MAX_SPARE_PAGES);
    }
}
