//! Host-authoritative paged KV cache (paper §3.3) with copy-on-write
//! prefix sharing.
//!
//! The physical cache is laid out exactly as the decode executable's
//! inputs expect — `k/v: [L, B, H, S, hd]`, `mask: [L, B, H, S]`,
//! Quest page bounds `[L, B, H, P, hd]` — so uploading a step's inputs
//! is a straight memcpy. On top of the flat arrays sits a paged
//! allocator: each (lane, layer, KV-head) owns S slots grouped into
//! pages of `page_size`, mirroring PagedAttention with pages allocated
//! to individual attention heads (the layout §3.3 calls for). Evicted
//! slots are simply overwritten by incoming tokens (keys carry RoPE, so
//! position travels with the payload).
//!
//! Cache *ownership* is a separate layer (see [`cow`]): pages shared
//! between lanes — fork-siblings referencing a leader's prefill,
//! prefix-cache hits referencing pages retained from completed
//! requests — live in a refcounted [`PagePool`], and every mutation of
//! a shared page copies-on-write first. The [`prefix`] module indexes
//! retained pages by token ids (a radix tree with page-quantized
//! edges) so repeated prompts prefill only from the divergence point.
//!
//! Cache *precision* is a third layer (see [`quant`]): pool-owned
//! payloads are stored under the store's [`KvDtype`] — exact f32, or
//! per-row q8/q4 blocks with scale/zero-point metadata — quantized
//! exactly once at the publish/export boundary and dequantized into a
//! lane's f32 region on upload. The full numerics contract (what is
//! exact, what is lossy, the requantize-once rule, divergence bounds)
//! is in `docs/NUMERICS.md`.
//!
//! End-to-end: write a prompt page, retain it quantized, restore it
//! into a fresh lane within the quantization error bound:
//!
//! ```
//! use hyperscale::kvcache::{CacheStore, Geometry, KvDtype};
//!
//! let geom = Geometry {
//!     layers: 1, kv_heads: 1, slots: 16, head_dim: 4, page_size: 8,
//! };
//! let mut store = CacheStore::with_dtype(geom, 2, KvDtype::Q8);
//! // prefill one full page on lane 0 (identity slot layout)
//! for pos in 0..8 {
//!     let s = store.alloc_slot(0, 0, 0).unwrap();
//!     let k = [pos as f32 * 0.3; 4];
//!     store.write(0, 0, 0, s, pos, &k, &k);
//! }
//! // publish boundary: the page is quantized here, exactly once
//! let id = store.export_page(0, 0);
//! assert!(store.pool_payload_bytes() > 0);
//! store.recycle_lane(0);
//!
//! // restore into lane 1: metadata exact, payload dequantized
//! store.map_prefix_pages(1, &[id]);
//! store.materialize_pending();
//! assert_eq!(store.live_count(1, 0, 0), 8);
//! let k5 = store.k_at(1, 0, 0, 5)[0];
//! assert!((k5 - 1.5).abs() <= 0.3 * 7.0 / 255.0, "bounded error");
//! store.recycle_lane(1);
//! ```

pub mod cold;
pub mod cow;
pub mod prefix;
pub mod quant;

mod paged;
mod store;

pub use cold::ColdTier;
pub use cow::{PageData, PageId, PagePool, Payload};
pub use paged::PageAllocator;
pub use prefix::{PrefixHit, RadixPrefixIndex};
pub use quant::{Codec, KvBlock, KvDtype, QuantBlock, ScalarCodec, VectorizedCodec};
pub use store::{CacheStore, Geometry, LaneTickEvents, SlotState, NEG_INF};

#[cfg(test)]
mod tests {
    use super::*;

    fn geom() -> Geometry {
        Geometry {
            layers: 2,
            kv_heads: 2,
            slots: 32,
            head_dim: 4,
            page_size: 8,
        }
    }

    #[test]
    fn write_then_mask_live() {
        let g = geom();
        let mut c = CacheStore::new(g, 2);
        let k = vec![1.0; g.head_dim];
        let v = vec![2.0; g.head_dim];
        let slot = c.alloc_slot(0, 0, 0).unwrap();
        c.write(0, 0, 0, slot, 5, &k, &v);
        assert_eq!(c.live_count(0, 0, 0), 1);
        assert_eq!(c.slot_pos(0, 0, 0, slot), Some(5));
        // mask flipped to live
        let m = c.mask_value(0, 0, 0, slot);
        assert_eq!(m, 0.0);
        // k payload landed
        assert_eq!(c.k_at(0, 0, 0, slot)[0], 1.0);
    }

    #[test]
    fn evict_frees_and_masks() {
        let g = geom();
        let mut c = CacheStore::new(g, 1);
        let slot = c.alloc_slot(0, 0, 0).unwrap();
        c.write(0, 0, 0, slot, 0, &[0.0; 4], &[0.0; 4]);
        c.evict(0, 0, 0, slot);
        assert_eq!(c.live_count(0, 0, 0), 0);
        assert!(c.mask_value(0, 0, 0, slot) <= NEG_INF);
        // slot is reusable
        assert_eq!(c.alloc_slot(0, 0, 0), Some(slot));
    }

    #[test]
    fn delayed_eviction_fires_on_due_position() {
        let g = geom();
        let mut c = CacheStore::new(g, 1);
        let slot = c.alloc_slot(0, 0, 0).unwrap();
        c.write(0, 0, 0, slot, 3, &[0.0; 4], &[0.0; 4]);
        c.schedule_eviction(0, 0, 0, slot, 3 + 4); // window 4
        c.apply_due_evictions(0, 6);
        assert_eq!(c.live_count(0, 0, 0), 1, "not due yet");
        c.apply_due_evictions(0, 7);
        assert_eq!(c.live_count(0, 0, 0), 0, "due at pos 7");
    }

    #[test]
    fn merge_updates_running_average() {
        let g = geom();
        let mut c = CacheStore::new(g, 1);
        let slot = c.alloc_slot(0, 0, 0).unwrap();
        c.write(0, 0, 0, slot, 0, &[2.0; 4], &[4.0; 4]);
        c.merge_into_last(0, 0, 0, &[4.0; 4], &[8.0; 4]);
        // (2*1 + 4)/2 = 3 ; (4*1 + 8)/2 = 6
        assert_eq!(c.k_at(0, 0, 0, slot)[0], 3.0);
        assert_eq!(c.v_at(0, 0, 0, slot)[0], 6.0);
        c.merge_into_last(0, 0, 0, &[6.0; 4], &[9.0; 4]);
        // (3*2 + 6)/3 = 4 ; (6*2 + 9)/3 = 7
        assert_eq!(c.k_at(0, 0, 0, slot)[0], 4.0);
        assert_eq!(c.v_at(0, 0, 0, slot)[0], 7.0);
        assert_eq!(c.live_count(0, 0, 0), 1);
    }

    #[test]
    fn fork_copies_payload_and_meta() {
        let g = geom();
        let mut c = CacheStore::new(g, 2);
        for i in 0..3u32 {
            for l in 0..g.layers {
                for h in 0..g.kv_heads {
                    let s = c.alloc_slot(0, l, h).unwrap();
                    c.write(0, l, h, s, i as usize, &[i as f32; 4], &[1.0; 4]);
                }
            }
        }
        c.fork_lane(0, 1);
        assert_eq!(c.live_count(1, 0, 0), 3);
        assert_eq!(c.k_at(1, 0, 0, 2)[0], 2.0);
        // forked lane evolves independently
        c.evict(1, 0, 0, 0);
        assert_eq!(c.live_count(0, 0, 0), 3);
        assert_eq!(c.live_count(1, 0, 0), 2);
    }

    #[test]
    fn live_tokens_averages_heads() {
        let g = geom();
        let mut c = CacheStore::new(g, 1);
        // one layer-head gets 2 tokens, others 0
        for pos in 0..2 {
            let s = c.alloc_slot(0, 0, 0).unwrap();
            c.write(0, 0, 0, s, pos, &[0.0; 4], &[0.0; 4]);
        }
        // 2 live in 1 of 4 (l,h) pairs => 0.5 token-units
        assert!((c.live_tokens(0) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn page_metadata_tracks_bounds() {
        let g = geom();
        let mut c = CacheStore::new(g, 1);
        let s = c.alloc_slot(0, 0, 0).unwrap();
        c.write(0, 0, 0, s, 0, &[-3.0, 5.0, 0.0, 0.0], &[0.0; 4]);
        let s2 = c.alloc_slot(0, 0, 0).unwrap();
        c.write(0, 0, 0, s2, 1, &[1.0, 2.0, 0.0, 0.0], &[0.0; 4]);
        let page = 0;
        let pm = c.pmin_at(0, 0, 0, page);
        let px = c.pmax_at(0, 0, 0, page);
        assert_eq!(pm[0], -3.0);
        assert_eq!(px[0], 1.0);
        assert_eq!(px[1], 5.0);
    }

    #[test]
    fn recycle_lane_returns_freed_slots() {
        let g = geom();
        let mut c = CacheStore::new(g, 2);
        for pos in 0..3 {
            for l in 0..g.layers {
                for h in 0..g.kv_heads {
                    let s = c.alloc_slot(0, l, h).unwrap();
                    c.write(0, l, h, s, pos, &[0.0; 4], &[0.0; 4]);
                }
            }
        }
        // 3 tokens in each of the lane's 4 (l,h) pairs
        let freed = c.recycle_lane(0);
        assert_eq!(freed, 3 * g.lh());
        assert_eq!(c.live_count(0, 0, 0), 0);
        // slots immediately allocatable again
        assert!(c.alloc_slot(0, 0, 0).is_some());
    }

    #[test]
    fn live_fractions_track_occupancy() {
        let g = geom();
        let mut c = CacheStore::new(g, 2);
        assert_eq!(c.live_fraction(), 0.0);
        for pos in 0..4 {
            for l in 0..g.layers {
                for h in 0..g.kv_heads {
                    let s = c.alloc_slot(0, l, h).unwrap();
                    c.write(0, l, h, s, pos, &[0.0; 4], &[0.0; 4]);
                }
            }
        }
        // lane 0 holds 4 of its 32 slots per pair; lane 1 empty
        assert!((c.lane_live_fraction(0) - 4.0 / 32.0).abs() < 1e-9);
        assert!((c.lane_live_fraction(1)).abs() < 1e-9);
        assert!((c.live_fraction() - 4.0 / 64.0).abs() < 1e-9);
    }

    #[test]
    fn per_lh_occupancy_and_plan_overflow() {
        use crate::compress::BudgetPlan;
        let g = geom();
        let mut c = CacheStore::new(g, 1);
        // layer 0 head 0 gets 4 tokens, layer 1 head 1 gets 2
        for pos in 0..4 {
            let s = c.alloc_slot(0, 0, 0).unwrap();
            c.write(0, 0, 0, s, pos, &[0.0; 4], &[0.0; 4]);
        }
        for pos in 0..2 {
            let s = c.alloc_slot(0, 1, 1).unwrap();
            c.write(0, 1, 1, s, pos, &[0.0; 4], &[0.0; 4]);
        }
        assert_eq!(c.live_count_lh(0, 0), 4);
        assert_eq!(c.live_count_lh(0, 3), 2);
        assert_eq!(c.lane_occupancy(0), vec![4, 0, 0, 2]);
        // plan with budget 3 everywhere: only the 4-token head overflows
        let plan = BudgetPlan::uniform(3);
        assert_eq!(c.plan_overflow(0, &plan), 1);
        // per-head plan that covers the occupancy exactly
        let plan = BudgetPlan::per_head(2, 2, vec![4, 0, 0, 2]);
        assert_eq!(c.plan_overflow(0, &plan), 0);
    }

    #[test]
    fn slots_exhaust_then_none() {
        let g = geom();
        let mut c = CacheStore::new(g, 1);
        for i in 0..g.slots {
            let s = c.alloc_slot(0, 1, 1).unwrap();
            c.write(0, 1, 1, s, i, &[0.0; 4], &[0.0; 4]);
        }
        assert!(c.alloc_slot(0, 1, 1).is_none());
    }

    // ------------------------------------------------------------------
    // Copy-on-write sharing
    // ------------------------------------------------------------------

    /// Prefill-shaped writes: token `pos` lands in slot `pos` of every
    /// (l, h) — identity layout, payload tagged with `pos`.
    fn prefill(c: &mut CacheStore, lane: usize, n: usize) {
        let g = c.geom;
        for pos in 0..n {
            for l in 0..g.layers {
                for h in 0..g.kv_heads {
                    let s = c.alloc_slot(lane, l, h).unwrap();
                    c.write(lane, l, h, s, pos, &[pos as f32; 4], &[0.5; 4]);
                }
            }
        }
    }

    fn assert_lanes_equal(c: &CacheStore, a: usize, b: usize) {
        let g = c.geom;
        for l in 0..g.layers {
            for h in 0..g.kv_heads {
                assert_eq!(c.live_count(a, l, h), c.live_count(b, l, h));
                for s in 0..g.slots {
                    assert_eq!(c.slot_state(a, l, h, s), c.slot_state(b, l, h, s));
                    assert_eq!(c.mask_value(a, l, h, s), c.mask_value(b, l, h, s));
                    assert_eq!(c.k_at(a, l, h, s), c.k_at(b, l, h, s));
                    assert_eq!(c.v_at(a, l, h, s), c.v_at(b, l, h, s));
                }
                for p in 0..g.pages() {
                    assert_eq!(c.pmin_at(a, l, h, p), c.pmin_at(b, l, h, p));
                    assert_eq!(c.pmax_at(a, l, h, p), c.pmax_at(b, l, h, p));
                }
            }
        }
    }

    #[test]
    fn cow_fork_matches_full_copy_after_materialize() {
        let g = geom();
        let mut c = CacheStore::new(g, 3);
        prefill(&mut c, 0, 11);
        c.fork_lane(0, 1); // reference: legacy deep copy
        let shared = c.fork_lane_cow(0, 2); // COW: metadata only
        assert_eq!(shared, 2, "11 tokens span 2 pages of 8");
        assert!(c.pending_pages(2) > 0);
        c.materialize_pending();
        assert_eq!(c.pending_pages(2), 0);
        assert_lanes_equal(&c, 1, 2);
        assert_lanes_equal(&c, 0, 2);
    }

    #[test]
    fn cow_fork_is_metadata_only_until_materialized() {
        let g = geom();
        let mut c = CacheStore::new(g, 2);
        prefill(&mut c, 0, 10);
        c.fork_lane_cow(0, 1);
        // metadata visible immediately (scheduler relies on it)
        assert_eq!(c.live_count(1, 0, 0), 10);
        assert_eq!(c.slot_pos(1, 0, 0, 7), Some(7));
        // pool holds one entry per shared page, two refs each
        assert_eq!(c.pool_pages(), 2);
        assert_eq!(c.pool_refs(), 4);
        assert_eq!(c.shared_pages(0), 2);
        assert_eq!(c.shared_pages(1), 2);
    }

    #[test]
    fn cow_on_evict_preserves_sibling_view() {
        let g = geom();
        let mut c = CacheStore::new(g, 2);
        prefill(&mut c, 0, 8);
        c.fork_lane_cow(0, 1);
        // the leader's compression policy evicts from the shared page
        // BEFORE the sibling ever materialized it
        c.evict(0, 0, 0, 3);
        assert_eq!(c.cow_published(), 1, "eviction broke the share");
        assert_eq!(c.live_count(0, 0, 0), 7);
        c.materialize_pending();
        // sibling's view is the pristine pre-eviction state
        assert_eq!(c.live_count(1, 0, 0), 8);
        assert_eq!(c.mask_value(1, 0, 0, 3), 0.0);
        assert_eq!(c.k_at(1, 0, 0, 3)[0], 3.0);
        // and the leader's own view took the eviction
        assert!(c.mask_value(0, 0, 0, 3) <= NEG_INF);
    }

    #[test]
    fn cow_on_write_diverges_only_the_writer() {
        let g = geom();
        let mut c = CacheStore::new(g, 2);
        prefill(&mut c, 0, 6); // slots 0..5 of page 0
        c.fork_lane_cow(0, 1);
        c.materialize_pending();
        // sibling writes its own token into the shared partial page
        let s = c.alloc_slot(1, 0, 0).unwrap();
        assert_eq!(s, 6);
        c.write(1, 0, 0, s, 6, &[9.0; 4], &[9.0; 4]);
        assert_eq!(c.live_count(1, 0, 0), 7);
        assert_eq!(c.live_count(0, 0, 0), 6, "leader untouched");
        assert!(!c.page_shared(1, 0), "writer detached from the share");
        assert!(c.page_shared(0, 0), "leader still owns the pool entry");
    }

    #[test]
    fn pool_drains_after_all_lanes_recycle() {
        let g = geom();
        let mut c = CacheStore::new(g, 4);
        prefill(&mut c, 0, 15);
        for dst in 1..4 {
            c.fork_lane_cow(0, dst);
        }
        assert!(c.pool_pages() > 0);
        // retire in arbitrary order, with the borrower first (forces a
        // publish so the survivors keep their view)
        c.recycle_lane(0);
        c.materialize_pending();
        assert_eq!(c.live_count(2, 0, 0), 15);
        c.recycle_lane(2);
        c.recycle_lane(1);
        c.recycle_lane(3);
        assert_eq!(c.pool_pages(), 0, "no leaked pool entries");
        assert_eq!(c.pool_refs(), 0);
    }

    #[test]
    fn borrower_recycle_publishes_for_survivors() {
        let g = geom();
        let mut c = CacheStore::new(g, 2);
        prefill(&mut c, 0, 9);
        c.fork_lane_cow(0, 1);
        // leader retires before the sibling ever materialized
        c.recycle_lane(0);
        c.materialize_pending();
        assert_eq!(c.live_count(1, 0, 0), 9);
        assert_eq!(c.k_at(1, 0, 0, 8)[0], 8.0);
        c.recycle_lane(1);
        assert_eq!(c.pool_pages(), 0);
    }

    #[test]
    #[should_panic(expected = "double-free")]
    fn double_release_of_exported_page_panics() {
        let g = geom();
        let mut c = CacheStore::new(g, 1);
        prefill(&mut c, 0, 8);
        let id = c.export_page(0, 0);
        c.release_page(id);
        c.release_page(id);
    }

    // ------------------------------------------------------------------
    // Prefix retention
    // ------------------------------------------------------------------

    #[test]
    fn clean_prefix_requires_identity_and_no_compression_marks() {
        let g = geom();
        let mut c = CacheStore::new(g, 1);
        prefill(&mut c, 0, 20);
        // 20-token prompt: pages 0 and 1 full and clean; cap is
        // (20-1)/8 = 2 pages
        assert_eq!(c.clean_prefix_pages(0, 20), 2);
        // an eviction in page 0 dirties the prefix from page 0 on
        c.evict(0, 1, 1, 2);
        assert_eq!(c.clean_prefix_pages(0, 20), 0);
    }

    #[test]
    fn clean_prefix_stops_at_scheduled_eviction() {
        let g = geom();
        let mut c = CacheStore::new(g, 1);
        prefill(&mut c, 0, 20);
        c.schedule_eviction(0, 0, 0, 9, 100); // pending DMS decision in page 1
        assert_eq!(c.clean_prefix_pages(0, 20), 1);
    }

    #[test]
    fn exported_prefix_restores_bit_exact_into_fresh_lane() {
        let g = geom();
        let mut c = CacheStore::new(g, 2);
        prefill(&mut c, 0, 17);
        let n = c.clean_prefix_pages(0, 17);
        assert_eq!(n, 2);
        let ids: Vec<PageId> = (0..n).map(|p| c.export_page(0, p)).collect();
        c.recycle_lane(0);
        // restore into a different, clean lane: the mapping consumes
        // its own reference, the export reference stands for the index
        for &id in &ids {
            c.retain_page(id);
        }
        c.map_prefix_pages(1, &ids);
        c.materialize_pending();
        for l in 0..g.layers {
            for h in 0..g.kv_heads {
                assert_eq!(c.live_count(1, l, h), 16);
                for s in 0..16 {
                    assert_eq!(c.slot_pos(1, l, h, s), Some(s));
                    assert_eq!(c.k_at(1, l, h, s)[0], s as f32);
                    assert_eq!(c.mask_value(1, l, h, s), 0.0);
                }
            }
        }
        // prefill continues exactly at the divergence point
        assert_eq!(c.alloc_slot(1, 0, 0), Some(16));
        // index drops its references → pool drains once the lane does
        c.recycle_lane(1);
        for id in ids {
            c.release_page(id);
        }
        assert_eq!(c.pool_pages(), 0);
    }

    #[test]
    fn prefix_restore_write_does_not_corrupt_retained_page() {
        let g = geom();
        let mut c = CacheStore::new(g, 2);
        prefill(&mut c, 0, 9);
        let id = c.export_page(0, 0);
        c.recycle_lane(0);
        c.map_prefix_pages(1, &[id]);
        c.retain_page(id); // stand-in for the index's reference
        c.materialize_pending();
        // the restored lane evicts inside the retained page (policy)
        c.evict(1, 0, 0, 0);
        assert!(!c.page_shared(1, 0), "mutation detached the lane");
        // a second consumer still sees the pristine snapshot
        c.recycle_lane(1);
        c.map_prefix_pages(0, &[id]);
        c.materialize_pending();
        assert_eq!(c.live_count(0, 0, 0), 8);
        assert_eq!(c.k_at(0, 0, 0, 0)[0], 0.0);
        assert_eq!(c.mask_value(0, 0, 0, 0), 0.0);
        c.recycle_lane(0);
        assert_eq!(c.pool_pages(), 0);
    }
}
