//! Host-authoritative paged KV cache (paper §3.3).
//!
//! The physical cache is laid out exactly as the decode executable's
//! inputs expect — `k/v: [L, B, H, S, hd]`, `mask: [L, B, H, S]`,
//! Quest page bounds `[L, B, H, P, hd]` — so uploading a step's inputs
//! is a straight memcpy. On top of the flat arrays sits a paged
//! allocator: each (lane, layer, KV-head) owns S slots grouped into
//! pages of `page_size`, mirroring PagedAttention with pages allocated
//! to individual attention heads (the layout §3.3 calls for). Evicted
//! slots are simply overwritten by incoming tokens (keys carry RoPE, so
//! position travels with the payload).

mod paged;
mod store;

pub use paged::PageAllocator;
pub use store::{CacheStore, Geometry, SlotState, NEG_INF};

#[cfg(test)]
mod tests {
    use super::*;

    fn geom() -> Geometry {
        Geometry {
            layers: 2,
            kv_heads: 2,
            slots: 32,
            head_dim: 4,
            page_size: 8,
        }
    }

    #[test]
    fn write_then_mask_live() {
        let g = geom();
        let mut c = CacheStore::new(g, 2);
        let k = vec![1.0; g.head_dim];
        let v = vec![2.0; g.head_dim];
        let slot = c.alloc_slot(0, 0, 0).unwrap();
        c.write(0, 0, 0, slot, 5, &k, &v);
        assert_eq!(c.live_count(0, 0, 0), 1);
        assert_eq!(c.slot_pos(0, 0, 0, slot), Some(5));
        // mask flipped to live
        let m = c.mask_value(0, 0, 0, slot);
        assert_eq!(m, 0.0);
        // k payload landed
        assert_eq!(c.k_at(0, 0, 0, slot)[0], 1.0);
    }

    #[test]
    fn evict_frees_and_masks() {
        let g = geom();
        let mut c = CacheStore::new(g, 1);
        let slot = c.alloc_slot(0, 0, 0).unwrap();
        c.write(0, 0, 0, slot, 0, &[0.0; 4], &[0.0; 4]);
        c.evict(0, 0, 0, slot);
        assert_eq!(c.live_count(0, 0, 0), 0);
        assert!(c.mask_value(0, 0, 0, slot) <= NEG_INF);
        // slot is reusable
        assert_eq!(c.alloc_slot(0, 0, 0), Some(slot));
    }

    #[test]
    fn delayed_eviction_fires_on_due_position() {
        let g = geom();
        let mut c = CacheStore::new(g, 1);
        let slot = c.alloc_slot(0, 0, 0).unwrap();
        c.write(0, 0, 0, slot, 3, &[0.0; 4], &[0.0; 4]);
        c.schedule_eviction(0, 0, 0, slot, 3 + 4); // window 4
        c.apply_due_evictions(0, 6);
        assert_eq!(c.live_count(0, 0, 0), 1, "not due yet");
        c.apply_due_evictions(0, 7);
        assert_eq!(c.live_count(0, 0, 0), 0, "due at pos 7");
    }

    #[test]
    fn merge_updates_running_average() {
        let g = geom();
        let mut c = CacheStore::new(g, 1);
        let slot = c.alloc_slot(0, 0, 0).unwrap();
        c.write(0, 0, 0, slot, 0, &[2.0; 4], &[4.0; 4]);
        c.merge_into_last(0, 0, 0, &[4.0; 4], &[8.0; 4]);
        // (2*1 + 4)/2 = 3 ; (4*1 + 8)/2 = 6
        assert_eq!(c.k_at(0, 0, 0, slot)[0], 3.0);
        assert_eq!(c.v_at(0, 0, 0, slot)[0], 6.0);
        c.merge_into_last(0, 0, 0, &[6.0; 4], &[9.0; 4]);
        // (3*2 + 6)/3 = 4 ; (6*2 + 9)/3 = 7
        assert_eq!(c.k_at(0, 0, 0, slot)[0], 4.0);
        assert_eq!(c.v_at(0, 0, 0, slot)[0], 7.0);
        assert_eq!(c.live_count(0, 0, 0), 1);
    }

    #[test]
    fn fork_copies_payload_and_meta() {
        let g = geom();
        let mut c = CacheStore::new(g, 2);
        for i in 0..3u32 {
            for l in 0..g.layers {
                for h in 0..g.kv_heads {
                    let s = c.alloc_slot(0, l, h).unwrap();
                    c.write(0, l, h, s, i as usize, &[i as f32; 4], &[1.0; 4]);
                }
            }
        }
        c.fork_lane(0, 1);
        assert_eq!(c.live_count(1, 0, 0), 3);
        assert_eq!(c.k_at(1, 0, 0, 2)[0], 2.0);
        // forked lane evolves independently
        c.evict(1, 0, 0, 0);
        assert_eq!(c.live_count(0, 0, 0), 3);
        assert_eq!(c.live_count(1, 0, 0), 2);
    }

    #[test]
    fn live_tokens_averages_heads() {
        let g = geom();
        let mut c = CacheStore::new(g, 1);
        // one layer-head gets 2 tokens, others 0
        for pos in 0..2 {
            let s = c.alloc_slot(0, 0, 0).unwrap();
            c.write(0, 0, 0, s, pos, &[0.0; 4], &[0.0; 4]);
        }
        // 2 live in 1 of 4 (l,h) pairs => 0.5 token-units
        assert!((c.live_tokens(0) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn page_metadata_tracks_bounds() {
        let g = geom();
        let mut c = CacheStore::new(g, 1);
        let s = c.alloc_slot(0, 0, 0).unwrap();
        c.write(0, 0, 0, s, 0, &[-3.0, 5.0, 0.0, 0.0], &[0.0; 4]);
        let s2 = c.alloc_slot(0, 0, 0).unwrap();
        c.write(0, 0, 0, s2, 1, &[1.0, 2.0, 0.0, 0.0], &[0.0; 4]);
        let page = 0;
        let pm = c.pmin_at(0, 0, 0, page);
        let px = c.pmax_at(0, 0, 0, page);
        assert_eq!(pm[0], -3.0);
        assert_eq!(px[0], 1.0);
        assert_eq!(px[1], 5.0);
    }

    #[test]
    fn recycle_lane_returns_freed_slots() {
        let g = geom();
        let mut c = CacheStore::new(g, 2);
        for pos in 0..3 {
            for l in 0..g.layers {
                for h in 0..g.kv_heads {
                    let s = c.alloc_slot(0, l, h).unwrap();
                    c.write(0, l, h, s, pos, &[0.0; 4], &[0.0; 4]);
                }
            }
        }
        // 3 tokens in each of the lane's 4 (l,h) pairs
        let freed = c.recycle_lane(0);
        assert_eq!(freed, 3 * g.lh());
        assert_eq!(c.live_count(0, 0, 0), 0);
        // slots immediately allocatable again
        assert!(c.alloc_slot(0, 0, 0).is_some());
    }

    #[test]
    fn live_fractions_track_occupancy() {
        let g = geom();
        let mut c = CacheStore::new(g, 2);
        assert_eq!(c.live_fraction(), 0.0);
        for pos in 0..4 {
            for l in 0..g.layers {
                for h in 0..g.kv_heads {
                    let s = c.alloc_slot(0, l, h).unwrap();
                    c.write(0, l, h, s, pos, &[0.0; 4], &[0.0; 4]);
                }
            }
        }
        // lane 0 holds 4 of its 32 slots per pair; lane 1 empty
        assert!((c.lane_live_fraction(0) - 4.0 / 32.0).abs() < 1e-9);
        assert!((c.lane_live_fraction(1)).abs() < 1e-9);
        assert!((c.live_fraction() - 4.0 / 64.0).abs() < 1e-9);
    }

    #[test]
    fn slots_exhaust_then_none() {
        let g = geom();
        let mut c = CacheStore::new(g, 1);
        for i in 0..g.slots {
            let s = c.alloc_slot(0, 1, 1).unwrap();
            c.write(0, 1, 1, s, i, &[0.0; 4], &[0.0; 4]);
        }
        assert!(c.alloc_slot(0, 1, 1).is_none());
    }
}
