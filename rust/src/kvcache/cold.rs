//! Cold tier of the prefix cache: a bounded compressed store for
//! demoted prefix pages, with optional disk spill.
//!
//! The radix prefix index LRU-trims warm pages when the hot pool
//! budget overflows; before this module, trimmed pages were freed
//! outright and a later hit on the same prompt paid a full re-prefill.
//! The [`ColdTier`] instead *demotes* them: the page's payload is
//! re-encoded once into the configured cold dtype (q4 by default —
//! KVComp-style error-bounded lossy compression is a good fit for cold
//! KV blocks), stored under a separate byte budget, and optionally
//! spilled to disk files past a RAM budget. A later lookup that misses
//! the hot index but covers a cold key *promotes* the block back into
//! the page pool, where the ordinary dequant-on-upload restore path
//! prices the decode — a cold hit costs one dequant, not a prefill.
//!
//! ## The second lossy boundary
//!
//! Demotion is the **only** new lossy step (see the "second lossy
//! boundary" section of `docs/NUMERICS.md`):
//!
//! * a hot page whose payload dtype already equals the cold dtype is
//!   moved **verbatim** — codes, scales, zero-points untouched;
//! * otherwise the payload is decoded once and re-encoded into the
//!   cold dtype — deliberate, documented, at most once per residency;
//! * promotion **never re-encodes**: the cold block itself becomes the
//!   pool payload, and restores decode its lattice directly. A
//!   re-demotion of a promoted page finds the dtypes equal and moves
//!   the block verbatim, so demote/promote cycles cannot compound
//!   error.
//! * spill/reload serializes the code lattice byte-for-byte
//!   ([`QuantBlock::from_raw`](super::QuantBlock::from_raw)), so disk
//!   residency is exact.
//!
//! Keys are full covering token-id prefixes (the radix index's
//! page-quantized edge labels, accumulated root→leaf), held in a
//! `BTreeMap` so iteration — and therefore eviction under the budget —
//! is deterministic. Within the budget, eviction is LRU by an integer
//! clock bumped on insert and hit.

use std::collections::BTreeMap;
use std::fs;
use std::io::{Read, Write};
use std::path::PathBuf;
use std::time::Instant;

use super::cow::PageData;
use super::quant::{KvBlock, KvDtype, QuantBlock};
use super::store::SlotState;

/// One demoted page: the compressed snapshot (resident or spilled)
/// plus the slot-space page index it restores into.
#[derive(Debug)]
struct ColdEntry {
    /// In-RAM payload; `None` while spilled to disk.
    data: Option<Box<PageData>>,
    /// Slot-space page index (`PagePool` entry metadata).
    page: usize,
    /// K+V payload bytes of the snapshot (same resident or spilled).
    bytes: usize,
    /// LRU stamp: higher = more recently used.
    stamp: u64,
    /// Spill file, when the payload has been written out.
    file: Option<PathBuf>,
}

/// Bounded compressed store for demoted prefix pages (see module docs).
#[derive(Debug, Default)]
pub struct ColdTier {
    entries: BTreeMap<Vec<u32>, ColdEntry>,
    /// RAM budget for resident cold payload bytes; 0 disables the tier.
    budget_bytes: usize,
    /// Storage dtype cold payloads are demoted into.
    dtype: KvDtype,
    /// Spill directory; when `None`, over-budget blocks are evicted
    /// instead of spilled.
    spill_dir: Option<PathBuf>,
    /// Quantization row length (the geometry's `head_dim`): f32
    /// payloads are re-encoded per `row_len`-wide row, matching the
    /// store's own per-row scale/zero-point granularity so the cold
    /// error bound is the documented per-dtype bound, not a
    /// page-global one.
    row_len: usize,
    /// Resident (in-RAM) cold payload bytes.
    resident_bytes: usize,
    /// Bytes currently held in spill files.
    spilled_bytes: usize,
    /// LRU clock.
    clock: u64,
    /// Monotonic spill-file name counter (names must be unique for the
    /// tier's lifetime — keys can be re-demoted after promotion).
    file_seq: u64,
    /// Cumulative microseconds spent promoting (spill reload + any
    /// demote-time transcode), for the `kv.cold_promote_us` gauge.
    promote_us: u64,
    /// Cold lookups that found a covering entry.
    hits: u64,
}

impl ColdTier {
    /// A disabled tier (budget 0): every demote is dropped on the
    /// floor, every lookup misses.
    pub fn disabled() -> Self {
        Self {
            dtype: KvDtype::Q4,
            row_len: 1,
            ..Self::default()
        }
    }

    /// A tier holding up to `budget_bytes` of resident compressed
    /// payload under `dtype`, spilling overflow to `spill_dir` when
    /// one is given (evicting it otherwise). `row_len` is the
    /// geometry's `head_dim` — the per-row quantization granularity
    /// for payloads that arrive as f32.
    pub fn new(
        budget_bytes: usize,
        dtype: KvDtype,
        spill_dir: Option<PathBuf>,
        row_len: usize,
    ) -> Self {
        assert!(row_len > 0, "row_len must be positive");
        Self {
            budget_bytes,
            dtype,
            spill_dir,
            row_len,
            ..Self::default()
        }
    }

    /// Whether demotions are accepted at all.
    pub fn enabled(&self) -> bool {
        self.budget_bytes > 0
    }

    /// Storage dtype cold payloads are demoted into.
    pub fn dtype(&self) -> KvDtype {
        self.dtype
    }

    /// Live entries (resident + spilled).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Resident (in-RAM) cold payload bytes — the `kv.cold_tier_bytes`
    /// gauge.
    pub fn resident_bytes(&self) -> usize {
        self.resident_bytes
    }

    /// Bytes currently held in spill files — the `kv.spilled_bytes`
    /// gauge.
    pub fn spilled_bytes(&self) -> usize {
        self.spilled_bytes
    }

    /// Cold lookups that found a covering entry — the `kv.cold_hits`
    /// counter.
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Cumulative promote-side microseconds (spill reload + demote
    /// transcode) — the `kv.cold_promote_us` gauge.
    pub fn promote_us(&self) -> u64 {
        self.promote_us
    }

    /// Demote one trimmed prefix page into the tier, keyed by its full
    /// covering token prefix. The payload is re-encoded into the cold
    /// dtype **only** when its stored dtype differs (the second lossy
    /// boundary); a payload already at the cold dtype — in particular
    /// a previously promoted cold block being re-demoted — moves
    /// verbatim, so cycles never compound error. No-op when the tier
    /// is disabled; a re-demotion under an existing key replaces the
    /// entry.
    pub fn admit(&mut self, key: &[u32], page: usize, data: Box<PageData>) {
        if !self.enabled() {
            return;
        }
        let t0 = Instant::now();
        let data = self.transcode(data);
        self.promote_us += t0.elapsed().as_micros() as u64;
        let bytes = data.payload_bytes();
        if let Some(old) = self.entries.remove(key) {
            self.forget(old);
        }
        self.clock += 1;
        self.entries.insert(
            key.to_vec(),
            ColdEntry {
                data: Some(data),
                page,
                bytes,
                stamp: self.clock,
                file: None,
            },
        );
        self.resident_bytes += bytes;
        self.enforce_budget();
    }

    /// Whether a covering entry exists for `key` (no promotion, no LRU
    /// bump) — admission-control probes use this.
    pub fn contains(&self, key: &[u32]) -> bool {
        self.entries.contains_key(key)
    }

    /// Take the entry covering `key` out of the tier for promotion
    /// back into the page pool: `(page_index, data)`. Spilled entries
    /// are reloaded from disk (bit-exact); the block is **never**
    /// re-encoded. Returns `None` on a miss.
    pub fn promote(&mut self, key: &[u32]) -> Option<(usize, Box<PageData>)> {
        let entry = self.entries.remove(key)?;
        let t0 = Instant::now();
        let data = match entry.data {
            Some(data) => {
                self.resident_bytes -= entry.bytes;
                data
            }
            None => {
                let path = entry.file.as_ref().expect("spilled entry without file");
                let data = read_spill(path, entry.bytes);
                self.spilled_bytes -= entry.bytes;
                let _ = fs::remove_file(path);
                data
            }
        };
        self.promote_us += t0.elapsed().as_micros() as u64;
        self.hits += 1;
        Some((entry.page, data))
    }

    /// Drop every entry and delete every spill file.
    pub fn clear(&mut self) {
        let entries = std::mem::take(&mut self.entries);
        for (_, e) in entries {
            self.forget(e);
        }
        debug_assert_eq!(self.resident_bytes, 0);
        debug_assert_eq!(self.spilled_bytes, 0);
    }

    /// Release one entry's accounting (and spill file, if any).
    fn forget(&mut self, e: ColdEntry) {
        if e.data.is_some() {
            self.resident_bytes -= e.bytes;
        } else {
            self.spilled_bytes -= e.bytes;
        }
        if let Some(path) = e.file {
            let _ = fs::remove_file(&path);
        }
    }

    /// Re-encode `data` into the cold dtype, decoding at most once.
    /// Payloads already at the cold dtype move verbatim.
    fn transcode(&self, data: Box<PageData>) -> Box<PageData> {
        let needs = |b: &KvBlock| match (b, self.dtype) {
            (KvBlock::F32(_), KvDtype::F32) => false,
            (KvBlock::Quant(q), d) => q.dtype() != d,
            (KvBlock::F32(_), _) => true,
        };
        if !needs(&data.k) && !needs(&data.v) {
            return data;
        }
        let recode = |b: &KvBlock| -> KvBlock {
            let (rows, row_len) = match b {
                KvBlock::F32(v) => (v.len() / self.row_len, self.row_len),
                KvBlock::Quant(q) => (q.rows(), q.row_len()),
            };
            KvBlock::from_f32(self.dtype, rows, row_len, b.to_f32())
        };
        let mut data = data;
        data.k = recode(&data.k);
        data.v = recode(&data.v);
        data
    }

    /// Evict or spill LRU resident entries until the RAM budget holds.
    fn enforce_budget(&mut self) {
        while self.resident_bytes > self.budget_bytes {
            // LRU over resident entries only (spilled ones cost no RAM)
            let victim = self
                .entries
                .iter()
                .filter(|(_, e)| e.data.is_some())
                .min_by_key(|(_, e)| e.stamp)
                .map(|(k, _)| k.clone());
            let Some(key) = victim else { break };
            if let Some(dir) = self.spill_dir.clone() {
                let e = self.entries.get_mut(&key).unwrap();
                let data = e.data.take().unwrap();
                self.file_seq += 1;
                let path = dir.join(format!("cold-{:08}.kvspill", self.file_seq));
                write_spill(&path, &data);
                e.file = Some(path);
                self.resident_bytes -= e.bytes;
                self.spilled_bytes += e.bytes;
            } else {
                let e = self.entries.remove(&key).unwrap();
                self.forget(e);
            }
        }
    }
}

impl Drop for ColdTier {
    fn drop(&mut self) {
        // spill files must never outlive the tier
        self.clear();
    }
}

// ---------------------------------------------------------------------
// Spill serialization: deterministic little-endian layout.
//
//   header:  magic "KVSP", u32 version,
//            per-block (k, v): u8 dtype tag, u64 rows, u64 row_len
//   blocks:  f32   → raw LE f32 values
//            q8/q4 → codes bytes, scales LE f32, zero-points
//   sidecar: mask LE f32, meta u32 states, pmin/pmax LE f32
//
// Quantized blocks round-trip their code lattice verbatim (never
// re-encoded), so a spill/reload cycle is bit-exact.
// ---------------------------------------------------------------------

const SPILL_MAGIC: &[u8; 4] = b"KVSP";
const SPILL_VERSION: u32 = 1;

fn dtype_tag(d: KvDtype) -> u8 {
    match d {
        KvDtype::F32 => 0,
        KvDtype::Q8 => 1,
        KvDtype::Q4 => 2,
    }
}

fn tag_dtype(t: u8) -> KvDtype {
    match t {
        0 => KvDtype::F32,
        1 => KvDtype::Q8,
        2 => KvDtype::Q4,
        other => panic!("corrupt spill file: dtype tag {other}"),
    }
}

fn put_u64(out: &mut Vec<u8>, x: u64) {
    out.extend_from_slice(&x.to_le_bytes());
}

fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_block(out: &mut Vec<u8>, b: &KvBlock) {
    match b {
        KvBlock::F32(v) => {
            out.push(dtype_tag(KvDtype::F32));
            put_u64(out, 1);
            put_u64(out, v.len() as u64);
            put_f32s(out, v);
        }
        KvBlock::Quant(q) => {
            out.push(dtype_tag(q.dtype()));
            put_u64(out, q.rows() as u64);
            put_u64(out, q.row_len() as u64);
            out.extend_from_slice(q.codes());
            put_f32s(out, q.scales());
            out.extend_from_slice(q.zps());
        }
    }
}

fn write_spill(path: &PathBuf, data: &PageData) {
    let mut out = Vec::new();
    out.extend_from_slice(SPILL_MAGIC);
    out.extend_from_slice(&SPILL_VERSION.to_le_bytes());
    put_block(&mut out, &data.k);
    put_block(&mut out, &data.v);
    put_u64(&mut out, data.mask.len() as u64);
    put_f32s(&mut out, &data.mask);
    put_u64(&mut out, data.meta.len() as u64);
    for m in &data.meta {
        put_slot_state(&mut out, m);
    }
    put_u64(&mut out, data.pmin.len() as u64);
    put_f32s(&mut out, &data.pmin);
    put_f32s(&mut out, &data.pmax);
    let mut f = fs::File::create(path)
        .unwrap_or_else(|e| panic!("cold spill create {}: {e}", path.display()));
    f.write_all(&out)
        .unwrap_or_else(|e| panic!("cold spill write {}: {e}", path.display()));
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> &'a [u8] {
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        s
    }

    fn u8(&mut self) -> u8 {
        self.take(1)[0]
    }

    fn u32(&mut self) -> u32 {
        u32::from_le_bytes(self.take(4).try_into().unwrap())
    }

    fn u64(&mut self) -> u64 {
        u64::from_le_bytes(self.take(8).try_into().unwrap())
    }

    fn f32s(&mut self, n: usize) -> Vec<f32> {
        (0..n)
            .map(|_| f32::from_le_bytes(self.take(4).try_into().unwrap()))
            .collect()
    }
}

fn read_block(c: &mut Cursor) -> KvBlock {
    let dtype = tag_dtype(c.u8());
    let rows = c.u64() as usize;
    let row_len = c.u64() as usize;
    match dtype {
        KvDtype::F32 => KvBlock::F32(c.f32s(rows * row_len)),
        d => {
            let codes = c.take(rows * d.row_code_bytes(row_len)).to_vec();
            let scales = c.f32s(rows);
            let zps = c.take(rows).to_vec();
            KvBlock::Quant(QuantBlock::from_raw(d, rows, row_len, codes, scales, zps))
        }
    }
}

fn read_spill(path: &PathBuf, expect_bytes: usize) -> Box<PageData> {
    let mut buf = Vec::new();
    fs::File::open(path)
        .and_then(|mut f| f.read_to_end(&mut buf))
        .unwrap_or_else(|e| panic!("cold spill read {}: {e}", path.display()));
    let mut c = Cursor { buf: &buf, pos: 0 };
    assert_eq!(c.take(4), SPILL_MAGIC, "corrupt spill file (magic)");
    assert_eq!(c.u32(), SPILL_VERSION, "corrupt spill file (version)");
    let k = read_block(&mut c);
    let v = read_block(&mut c);
    let n_mask = c.u64() as usize;
    let mask = c.f32s(n_mask);
    let n_meta = c.u64() as usize;
    let meta = (0..n_meta).map(|_| read_slot_state(&mut c)).collect();
    let n_bounds = c.u64() as usize;
    let pmin = c.f32s(n_bounds);
    let pmax = c.f32s(n_bounds);
    let data = Box::new(PageData {
        k,
        v,
        mask,
        meta,
        pmin,
        pmax,
    });
    debug_assert_eq!(data.payload_bytes(), expect_bytes, "spill byte accounting");
    data
}

fn put_slot_state(out: &mut Vec<u8>, s: &SlotState) {
    match s {
        SlotState::Free => {
            out.push(0);
            out.extend_from_slice(&0u32.to_le_bytes());
            out.extend_from_slice(&0u32.to_le_bytes());
            out.extend_from_slice(&0u16.to_le_bytes());
        }
        SlotState::Live {
            pos,
            evict_at,
            merges,
        } => {
            out.push(1);
            out.extend_from_slice(&pos.to_le_bytes());
            out.extend_from_slice(&evict_at.to_le_bytes());
            out.extend_from_slice(&merges.to_le_bytes());
        }
    }
}

fn read_slot_state(c: &mut Cursor) -> SlotState {
    let tag = c.u8();
    let pos = c.u32();
    let evict_at = c.u32();
    let merges = u16::from_le_bytes(c.take(2).try_into().unwrap());
    match tag {
        0 => SlotState::Free,
        1 => SlotState::Live {
            pos,
            evict_at,
            merges,
        },
        other => panic!("corrupt spill file: slot tag {other}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn page(seed: f32, dtype: KvDtype) -> Box<PageData> {
        let vals: Vec<f32> = (0..32).map(|i| seed + i as f32 * 0.25).collect();
        Box::new(PageData {
            k: KvBlock::from_f32(dtype, 8, 4, vals.clone()),
            v: KvBlock::from_f32(dtype, 8, 4, vals),
            mask: vec![0.0; 8],
            meta: (0..8u32)
                .map(|i| SlotState::Live {
                    pos: i,
                    evict_at: u32::MAX,
                    merges: 0,
                })
                .collect(),
            pmin: vec![-seed; 8],
            pmax: vec![seed; 8],
        })
    }

    #[test]
    fn disabled_tier_drops_demotions() {
        let mut t = ColdTier::disabled();
        t.admit(&[1, 2, 3], 0, page(1.0, KvDtype::F32));
        assert!(t.is_empty());
        assert!(t.promote(&[1, 2, 3]).is_none());
    }

    #[test]
    fn admit_transcodes_once_and_promote_returns_verbatim() {
        let mut t = ColdTier::new(1 << 20, KvDtype::Q4, None, 4);
        t.admit(&[5, 6], 2, page(1.0, KvDtype::F32));
        assert_eq!(t.len(), 1);
        assert!(t.resident_bytes() > 0);
        let (pg, data) = t.promote(&[5, 6]).expect("hit");
        assert_eq!(pg, 2);
        let KvBlock::Quant(q) = &data.k else {
            panic!("demote must have encoded to q4")
        };
        assert_eq!(q.dtype(), KvDtype::Q4);
        assert_eq!(t.hits(), 1);
        assert_eq!(t.resident_bytes(), 0);
        // sidecar moved exactly
        assert_eq!(
            data.meta[3],
            SlotState::Live {
                pos: 3,
                evict_at: u32::MAX,
                merges: 0
            }
        );
        assert_eq!(data.pmax[0], 1.0);
    }

    #[test]
    fn re_demotion_of_cold_dtype_block_is_verbatim() {
        let mut t = ColdTier::new(1 << 20, KvDtype::Q4, None, 4);
        t.admit(&[9], 0, page(2.0, KvDtype::F32));
        let (_, data) = t.promote(&[9]).unwrap();
        let codes_before = match &data.k {
            KvBlock::Quant(q) => q.codes().to_vec(),
            _ => unreachable!(),
        };
        let decoded_before = data.k.to_f32();
        // demote the promoted block again: same dtype → verbatim move
        t.admit(&[9], 0, data);
        let (_, again) = t.promote(&[9]).unwrap();
        let codes_after = match &again.k {
            KvBlock::Quant(q) => q.codes().to_vec(),
            _ => unreachable!(),
        };
        assert_eq!(codes_before, codes_after, "re-demotion must not re-encode");
        assert_eq!(
            decoded_before
                .iter()
                .map(|x| x.to_bits())
                .collect::<Vec<_>>(),
            again.k.to_f32().iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        );
    }

    #[test]
    fn budget_without_spill_dir_evicts_lru() {
        // each q4 page here is 8 rows × (2 codes + 5 meta) × 2 (K+V)
        let one = page(1.0, KvDtype::Q4).payload_bytes();
        let mut t = ColdTier::new(2 * one, KvDtype::Q4, None, 4);
        t.admit(&[1], 0, page(1.0, KvDtype::Q4));
        t.admit(&[2], 1, page(2.0, KvDtype::Q4));
        t.admit(&[3], 2, page(3.0, KvDtype::Q4));
        assert_eq!(t.len(), 2, "budget holds two pages");
        assert!(t.promote(&[1]).is_none(), "LRU entry evicted");
        assert!(t.contains(&[2]) && t.contains(&[3]));
        assert!(t.resident_bytes() <= 2 * one);
    }

    #[test]
    fn over_budget_blocks_spill_and_reload_bit_exactly() {
        let dir = std::env::temp_dir().join(format!("coldtier-test-{}", std::process::id()));
        fs::create_dir_all(&dir).unwrap();
        let one = page(1.0, KvDtype::Q4).payload_bytes();
        let mut t = ColdTier::new(one, KvDtype::Q4, Some(dir.clone()), 4);
        t.admit(&[1], 0, page(1.0, KvDtype::F32));
        let hot_decode = {
            let e = t.promote(&[1]).unwrap().1;
            let d = e.k.to_f32();
            t.admit(&[1], 0, e);
            d
        };
        // second admit pushes the LRU entry to disk
        t.admit(&[2], 1, page(2.0, KvDtype::F32));
        assert!(t.spilled_bytes() > 0, "over-budget block spilled");
        assert_eq!(t.resident_bytes(), one);
        let n_files = fs::read_dir(&dir).unwrap().count();
        assert_eq!(n_files, 1);
        // reload is bit-exact vs the pre-spill decode
        let (_, back) = t.promote(&[1]).expect("spilled entry promotes");
        assert_eq!(
            hot_decode.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            back.k.to_f32().iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "spill round-trip must be bit-exact"
        );
        assert_eq!(t.spilled_bytes(), 0);
        assert_eq!(fs::read_dir(&dir).unwrap().count(), 0, "spill file removed");
        // clear() deletes the remaining entries' files too
        t.admit(&[3], 0, page(3.0, KvDtype::F32));
        t.admit(&[4], 1, page(4.0, KvDtype::F32));
        assert!(t.spilled_bytes() > 0);
        t.clear();
        assert_eq!(fs::read_dir(&dir).unwrap().count(), 0, "clear removes files");
        assert_eq!(t.spilled_bytes() + t.resident_bytes(), 0);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn drop_removes_spill_files() {
        let dir = std::env::temp_dir().join(format!("coldtier-drop-{}", std::process::id()));
        fs::create_dir_all(&dir).unwrap();
        {
            let mut t = ColdTier::new(1, KvDtype::Q4, Some(dir.clone()), 4);
            t.admit(&[1], 0, page(1.0, KvDtype::F32));
            assert!(t.spilled_bytes() > 0, "tiny budget spills immediately");
            assert!(fs::read_dir(&dir).unwrap().count() > 0);
        }
        assert_eq!(fs::read_dir(&dir).unwrap().count(), 0, "Drop cleans up");
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn hits_bump_lru_stamps() {
        let one = page(1.0, KvDtype::Q4).payload_bytes();
        let mut t = ColdTier::new(2 * one, KvDtype::Q4, None, 4);
        t.admit(&[1], 0, page(1.0, KvDtype::Q4));
        t.admit(&[2], 1, page(2.0, KvDtype::Q4));
        // touch [1] by promote + re-admit (the engine's promote path)
        let (pg, d) = t.promote(&[1]).unwrap();
        t.admit(&[1], pg, d);
        // now [2] is LRU: a third admit evicts it, not [1]
        t.admit(&[3], 2, page(3.0, KvDtype::Q4));
        assert!(t.contains(&[1]));
        assert!(!t.contains(&[2]));
    }
}
