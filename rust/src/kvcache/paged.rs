//! Page-granular slot allocator for one (lane, layer, KV-head).
//!
//! Slots are grouped into pages of `page_size`. Allocation prefers
//! partially-used pages (first fit) so the working set stays compact —
//! the PagedAttention property that lets evicted slots be overwritten
//! without fragmenting whole pages.

/// Allocator over `slots` physical slots in pages of `page_size`.
#[derive(Clone, Debug)]
pub struct PageAllocator {
    page_size: usize,
    /// used[s] — slot occupancy bitmap.
    used: Vec<bool>,
    /// per-page used-slot count.
    page_used: Vec<u16>,
}

impl PageAllocator {
    pub fn new(slots: usize, page_size: usize) -> Self {
        assert!(slots % page_size == 0, "slots must be page-aligned");
        Self {
            page_size,
            used: vec![false; slots],
            page_used: vec![0; slots / page_size],
        }
    }

    pub fn reset(&mut self) {
        self.used.iter_mut().for_each(|u| *u = false);
        self.page_used.iter_mut().for_each(|c| *c = 0);
    }

    /// Allocate one slot: first fit within partially-used pages, then
    /// the first empty page.
    pub fn alloc(&mut self) -> Option<usize> {
        // pass 1: partially used pages
        for (p, &cnt) in self.page_used.iter().enumerate() {
            if cnt > 0 && (cnt as usize) < self.page_size {
                let base = p * self.page_size;
                for s in base..base + self.page_size {
                    if !self.used[s] {
                        self.used[s] = true;
                        self.page_used[p] += 1;
                        return Some(s);
                    }
                }
            }
        }
        // pass 2: first empty page
        for (p, &cnt) in self.page_used.iter().enumerate() {
            if cnt == 0 {
                let s = p * self.page_size;
                self.used[s] = true;
                self.page_used[p] = 1;
                return Some(s);
            }
        }
        None
    }

    pub fn free(&mut self, slot: usize) {
        if self.used[slot] {
            self.used[slot] = false;
            self.page_used[slot / self.page_size] -= 1;
        }
    }

    pub fn is_used(&self, slot: usize) -> bool {
        self.used[slot]
    }

    pub fn used_slots(&self) -> usize {
        self.page_used.iter().map(|&c| c as usize).sum()
    }

    /// Number of pages with at least one used slot.
    pub fn allocated_pages(&self) -> usize {
        self.page_used.iter().filter(|&&c| c > 0).count()
    }

    pub fn capacity(&self) -> usize {
        self.used.len()
    }

    pub fn clone_from_other(&mut self, other: &PageAllocator) {
        self.used.copy_from_slice(&other.used);
        self.page_used.copy_from_slice(&other.page_used);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fills_page_before_opening_new() {
        let mut a = PageAllocator::new(32, 8);
        let s0 = a.alloc().unwrap();
        assert_eq!(s0, 0);
        for _ in 0..7 {
            a.alloc().unwrap();
        }
        assert_eq!(a.allocated_pages(), 1);
        let s8 = a.alloc().unwrap();
        assert_eq!(s8, 8);
        assert_eq!(a.allocated_pages(), 2);
    }

    #[test]
    fn reuses_freed_slot_in_partial_page() {
        let mut a = PageAllocator::new(32, 8);
        for _ in 0..9 {
            a.alloc().unwrap();
        }
        a.free(3);
        // next alloc goes back into page 0's hole, not a fresh page
        assert_eq!(a.alloc(), Some(3));
        assert_eq!(a.allocated_pages(), 2);
    }

    #[test]
    fn page_becomes_free_when_emptied() {
        let mut a = PageAllocator::new(16, 8);
        let s = a.alloc().unwrap();
        assert_eq!(a.allocated_pages(), 1);
        a.free(s);
        assert_eq!(a.allocated_pages(), 0);
        assert_eq!(a.used_slots(), 0);
    }

    #[test]
    fn exhausts_at_capacity() {
        let mut a = PageAllocator::new(16, 8);
        for _ in 0..16 {
            assert!(a.alloc().is_some());
        }
        assert!(a.alloc().is_none());
        assert_eq!(a.used_slots(), 16);
    }

    #[test]
    fn double_free_is_noop() {
        let mut a = PageAllocator::new(16, 8);
        let s = a.alloc().unwrap();
        a.free(s);
        a.free(s);
        assert_eq!(a.used_slots(), 0);
    }
}
