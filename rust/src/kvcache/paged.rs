//! Page-granular slot allocator for one (lane, layer, KV-head).
//!
//! Slots are grouped into pages of `page_size`. Allocation prefers
//! partially-used pages (first fit) so the working set stays compact —
//! the PagedAttention property that lets evicted slots be overwritten
//! without fragmenting whole pages.
//!
//! Free-page bookkeeping is kept in two ordered sets (partially-used
//! and empty page indices), so `alloc` is O(log P + page_size) instead
//! of the former O(slots) two-pass scan; page order is preserved
//! (lowest partial page first, then lowest empty page), which keeps the
//! allocation sequence — and therefore every downstream test and token
//! stream — identical to the linear-scan allocator.
//!
//! This allocator tracks slot *occupancy* only; page payload buffers
//! live in the COW pool, which arena-recycles retired snapshot boxes
//! through its spare list (see [`super::cow::PagePool::take_spare`])
//! so publish/recycle churn never hits the global allocator.

use std::collections::BTreeSet;

/// Allocator over `slots` physical slots in pages of `page_size`.
#[derive(Clone, Debug)]
pub struct PageAllocator {
    page_size: usize,
    /// used[s] — slot occupancy bitmap.
    used: Vec<bool>,
    /// per-page used-slot count.
    page_used: Vec<u16>,
    /// pages with 0 < used < page_size, ascending.
    partial: BTreeSet<usize>,
    /// pages with used == 0, ascending.
    empty: BTreeSet<usize>,
}

impl PageAllocator {
    pub fn new(slots: usize, page_size: usize) -> Self {
        assert!(slots % page_size == 0, "slots must be page-aligned");
        let pages = slots / page_size;
        Self {
            page_size,
            used: vec![false; slots],
            page_used: vec![0; pages],
            partial: BTreeSet::new(),
            empty: (0..pages).collect(),
        }
    }

    pub fn reset(&mut self) {
        self.used.iter_mut().for_each(|u| *u = false);
        self.page_used.iter_mut().for_each(|c| *c = 0);
        self.partial.clear();
        self.empty = (0..self.page_used.len()).collect();
    }

    /// Re-file page `p` into the partial/empty sets after a count change.
    fn refile(&mut self, p: usize) {
        let cnt = self.page_used[p] as usize;
        if cnt == 0 {
            self.partial.remove(&p);
            self.empty.insert(p);
        } else if cnt < self.page_size {
            self.empty.remove(&p);
            self.partial.insert(p);
        } else {
            self.partial.remove(&p);
            self.empty.remove(&p);
        }
    }

    /// Allocate one slot: first fit within the lowest partially-used
    /// page, then the lowest empty page. Amortized O(1) in `slots`.
    pub fn alloc(&mut self) -> Option<usize> {
        if let Some(&p) = self.partial.iter().next() {
            let base = p * self.page_size;
            for s in base..base + self.page_size {
                if !self.used[s] {
                    self.used[s] = true;
                    self.page_used[p] += 1;
                    self.refile(p);
                    return Some(s);
                }
            }
            unreachable!("partial page {p} had no free slot");
        }
        if let Some(&p) = self.empty.iter().next() {
            let s = p * self.page_size;
            self.used[s] = true;
            self.page_used[p] = 1;
            self.refile(p);
            return Some(s);
        }
        None
    }

    /// Claim a specific slot (fork / prefix-restore paths that must
    /// reproduce another lane's exact slot layout). No-op if used.
    pub fn claim(&mut self, slot: usize) {
        if !self.used[slot] {
            self.used[slot] = true;
            let p = slot / self.page_size;
            self.page_used[p] += 1;
            self.refile(p);
        }
    }

    pub fn free(&mut self, slot: usize) {
        if self.used[slot] {
            self.used[slot] = false;
            let p = slot / self.page_size;
            self.page_used[p] -= 1;
            self.refile(p);
        }
    }

    pub fn is_used(&self, slot: usize) -> bool {
        self.used[slot]
    }

    pub fn used_slots(&self) -> usize {
        self.page_used.iter().map(|&c| c as usize).sum()
    }

    /// Used-slot count of one page.
    pub fn page_used_count(&self, page: usize) -> usize {
        self.page_used[page] as usize
    }

    /// Number of pages with at least one used slot.
    pub fn allocated_pages(&self) -> usize {
        self.page_used.len() - self.empty.len()
    }

    pub fn capacity(&self) -> usize {
        self.used.len()
    }

    pub fn clone_from_other(&mut self, other: &PageAllocator) {
        self.used.copy_from_slice(&other.used);
        self.page_used.copy_from_slice(&other.page_used);
        self.partial = other.partial.clone();
        self.empty = other.empty.clone();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fills_page_before_opening_new() {
        let mut a = PageAllocator::new(32, 8);
        let s0 = a.alloc().unwrap();
        assert_eq!(s0, 0);
        for _ in 0..7 {
            a.alloc().unwrap();
        }
        assert_eq!(a.allocated_pages(), 1);
        let s8 = a.alloc().unwrap();
        assert_eq!(s8, 8);
        assert_eq!(a.allocated_pages(), 2);
    }

    #[test]
    fn reuses_freed_slot_in_partial_page() {
        let mut a = PageAllocator::new(32, 8);
        for _ in 0..9 {
            a.alloc().unwrap();
        }
        a.free(3);
        // next alloc goes back into page 0's hole, not a fresh page
        assert_eq!(a.alloc(), Some(3));
        assert_eq!(a.allocated_pages(), 2);
    }

    #[test]
    fn page_becomes_free_when_emptied() {
        let mut a = PageAllocator::new(16, 8);
        let s = a.alloc().unwrap();
        assert_eq!(a.allocated_pages(), 1);
        a.free(s);
        assert_eq!(a.allocated_pages(), 0);
        assert_eq!(a.used_slots(), 0);
    }

    #[test]
    fn exhausts_at_capacity() {
        let mut a = PageAllocator::new(16, 8);
        for _ in 0..16 {
            assert!(a.alloc().is_some());
        }
        assert!(a.alloc().is_none());
        assert_eq!(a.used_slots(), 16);
    }

    #[test]
    fn double_free_is_noop() {
        let mut a = PageAllocator::new(16, 8);
        let s = a.alloc().unwrap();
        a.free(s);
        a.free(s);
        assert_eq!(a.used_slots(), 0);
    }

    #[test]
    fn claim_specific_slot_then_alloc_fills_around_it() {
        let mut a = PageAllocator::new(16, 8);
        a.claim(3);
        assert!(a.is_used(3));
        assert_eq!(a.allocated_pages(), 1);
        // first-fit returns the lower holes of the now-partial page
        assert_eq!(a.alloc(), Some(0));
        assert_eq!(a.alloc(), Some(1));
        assert_eq!(a.alloc(), Some(2));
        assert_eq!(a.alloc(), Some(4));
        // claiming an already-used slot is a no-op
        a.claim(3);
        assert_eq!(a.used_slots(), 5);
    }

    #[test]
    fn matches_linear_scan_order_under_random_ops() {
        // the set-based allocator must produce exactly the sequence of
        // the old two-pass scan: lowest partial page first, then lowest
        // empty page, first free slot within the page.
        let mut a = PageAllocator::new(64, 8);
        let mut reference: Vec<bool> = vec![false; 64];
        let mut rng = 0x1234_5678_u64;
        let mut next = |m: usize| {
            rng = rng.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            (rng >> 33) as usize % m
        };
        for _ in 0..500 {
            if next(3) == 0 {
                let s = next(64);
                a.free(s);
                reference[s] = false;
            } else {
                // reference: first free slot in lowest partial page, else
                // first slot of lowest empty page
                let ref_pick = {
                    let page_cnt = |p: usize| {
                        reference[p * 8..(p + 1) * 8].iter().filter(|&&u| u).count()
                    };
                    let mut pick = None;
                    for p in 0..8 {
                        let c = page_cnt(p);
                        if c > 0 && c < 8 {
                            pick = (p * 8..(p + 1) * 8).find(|&s| !reference[s]);
                            break;
                        }
                    }
                    if pick.is_none() {
                        for p in 0..8 {
                            if page_cnt(p) == 0 {
                                pick = Some(p * 8);
                                break;
                            }
                        }
                    }
                    pick
                };
                let got = a.alloc();
                assert_eq!(got, ref_pick);
                if let Some(s) = got {
                    reference[s] = true;
                }
            }
        }
    }
}
