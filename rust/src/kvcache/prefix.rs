//! Radix prefix index: token-id → retained KV pages.
//!
//! Completed requests leave their clean prompt pages behind in the
//! [`PagePool`](super::cow::PagePool) (see
//! `CacheStore::clean_prefix_pages` / `export_page`); this index maps
//! token-id prefixes to those pages so the scheduler can admit a
//! repeated prompt with its prefill started at the divergence point.
//!
//! The tree is a radix tree over token ids with **page-quantized
//! edges**: every edge label is a whole number of `page_size`-token
//! pages, because a KV page is the unit of reuse — two prompts that
//! diverge mid-page cannot share that page's cache, so finer splits
//! would index unusable state. Each edge carries one [`PageId`] per
//! label page and an LRU stamp; `trim` releases least-recently-used
//! leaf edges until the retained-page budget holds.
//!
//! Reference discipline: `insert` stores handles produced by the
//! caller-supplied provider (which must hand over one reference per
//! page); `trim` / `release_all` return the handles they dropped so the
//! caller can release the pool references. The index never touches the
//! pool directly — it is a pure data structure over opaque ids, which
//! keeps it unit-testable without a `CacheStore`.

use super::cow::PageId;

/// A prefix-cache match: pages to map and the token count they cover.
#[derive(Clone, Debug, Default)]
pub struct PrefixHit {
    /// Pool pages covering `tokens` leading tokens, in order.
    pub pages: Vec<PageId>,
    /// Matched token count (multiple of `page_size`, strictly shorter
    /// than the looked-up prompt).
    pub tokens: usize,
}

#[derive(Debug)]
struct Edge {
    /// Token ids covered (len is a multiple of `page_size`).
    label: Vec<u32>,
    /// One retained page per `page_size` tokens of `label`.
    pages: Vec<PageId>,
    /// LRU stamp of the last walk through this edge.
    stamp: u64,
    children: Vec<Edge>,
}

impl Edge {
    fn count_pages(&self) -> usize {
        self.pages.len() + self.children.iter().map(Edge::count_pages).sum::<usize>()
    }

    fn drain_pages(self, out: &mut Vec<PageId>) {
        out.extend(self.pages);
        for c in self.children {
            c.drain_pages(out);
        }
    }
}

/// The radix prefix index (see module docs).
#[derive(Debug)]
pub struct RadixPrefixIndex {
    page_size: usize,
    roots: Vec<Edge>,
    clock: u64,
    retained: usize,
}

impl RadixPrefixIndex {
    pub fn new(page_size: usize) -> Self {
        assert!(page_size > 0);
        Self {
            page_size,
            roots: Vec::new(),
            clock: 0,
            retained: 0,
        }
    }

    /// Pages currently retained by the index.
    pub fn pages_retained(&self) -> usize {
        self.retained
    }

    /// Longest indexed page-aligned prefix of `ids`, capped one page
    /// short of covering the whole prompt (a reusing request must keep
    /// at least one token to prefill — its logits seed sampling).
    pub fn lookup(&mut self, ids: &[u32]) -> PrefixHit {
        let ps = self.page_size;
        if ids.is_empty() {
            return PrefixHit::default();
        }
        let max_pages = (ids.len() - 1) / ps;
        self.clock += 1;
        let mut pages = Vec::new();
        lookup_rec(&mut self.roots, ids, ps, self.clock, max_pages, &mut pages);
        let tokens = pages.len() * ps;
        PrefixHit { pages, tokens }
    }

    /// Length in tokens of the longest indexed page-aligned prefix of
    /// `ids`, under the same one-page-short cap as [`Self::lookup`] —
    /// but **read-only**: no pool references are taken and LRU stamps
    /// are left untouched, so callers (the cluster router scores
    /// replica affinity with this) can probe as often as they like
    /// without perturbing eviction order or reference counts.
    pub fn best_hit_len(&self, ids: &[u32]) -> usize {
        let ps = self.page_size;
        if ids.is_empty() {
            return 0;
        }
        let max_pages = (ids.len() - 1) / ps;
        let mut pages = 0usize;
        let mut edges = &self.roots[..];
        let mut rest = ids;
        'walk: while pages < max_pages && rest.len() >= ps {
            let Some(edge) = edges.iter().find(|e| e.label[..ps] == rest[..ps]) else {
                break;
            };
            let mut m = 0usize;
            while m < edge.pages.len()
                && pages < max_pages
                && (m + 1) * ps <= rest.len()
                && edge.label[m * ps..(m + 1) * ps] == rest[m * ps..(m + 1) * ps]
            {
                pages += 1;
                m += 1;
            }
            if m < edge.pages.len() {
                break 'walk; // diverged (or capped) mid-edge
            }
            rest = &rest[m * ps..];
            edges = &edge.children[..];
        }
        pages * ps
    }

    /// Index the page-aligned prefix `ids` (its length must be a
    /// multiple of `page_size`). For every page not already present,
    /// `provide(page_index)` is called with the slot-space page number
    /// and must return a pool handle carrying one reference for the
    /// index. Already-indexed pages are left untouched (and their LRU
    /// stamps refreshed), so repeat insertion is cheap and never
    /// double-retains.
    pub fn insert(&mut self, ids: &[u32], mut provide: impl FnMut(usize) -> PageId) {
        let ps = self.page_size;
        assert!(ids.len() % ps == 0, "prefix must be page-aligned");
        self.clock += 1;
        self.retained += insert_rec(
            &mut self.roots,
            ids,
            0,
            ps,
            self.clock,
            &mut provide,
        );
    }

    /// Release least-recently-used leaf edges until at most
    /// `max_pages` pages stay retained. Returns the dropped handles;
    /// the caller must release their pool references.
    pub fn trim(&mut self, max_pages: usize) -> Vec<PageId> {
        let mut dropped = Vec::new();
        self.trim_with(max_pages, |_, id| dropped.push(id));
        dropped
    }

    /// [`Self::trim`] with a demotion hook: `demote(key, id)` is called
    /// for every dropped page, in strict LRU leaf order (oldest leaf
    /// first, pages of a leaf in label order). `key` is the page's full
    /// covering token prefix from the root — the handle a cold tier
    /// needs to index the demoted block so a later prompt can find it
    /// again. The callback owns releasing (or re-homing) the pool
    /// reference each dropped handle carries.
    pub fn trim_with(&mut self, max_pages: usize, mut demote: impl FnMut(&[u32], PageId)) {
        let ps = self.page_size;
        let mut key = Vec::new();
        while self.retained > max_pages {
            let Some((ancestors, edge)) = pop_lru_leaf(&mut self.roots) else {
                break;
            };
            self.retained -= edge.pages.len();
            for (i, &id) in edge.pages.iter().enumerate() {
                key.clear();
                key.extend_from_slice(&ancestors);
                key.extend_from_slice(&edge.label[..(i + 1) * ps]);
                demote(&key, id);
            }
        }
    }

    /// Visit every retained page handle (pre-order). The engine sums
    /// pool payload bytes over this walk for `kv.prefix_retained_bytes`.
    pub fn for_each_page(&self, mut f: impl FnMut(PageId)) {
        fn rec(edges: &[Edge], f: &mut impl FnMut(PageId)) {
            for e in edges {
                for &p in &e.pages {
                    f(p);
                }
                rec(&e.children, f);
            }
        }
        rec(&self.roots, &mut f);
    }

    /// Drop the whole index (policy/variant switch invalidates every
    /// retained page). Returns all handles for release.
    pub fn release_all(&mut self) -> Vec<PageId> {
        let mut out = Vec::with_capacity(self.retained);
        for e in std::mem::take(&mut self.roots) {
            e.drain_pages(&mut out);
        }
        self.retained = 0;
        out
    }

    /// Recount retained pages from the tree (test/debug invariant).
    pub fn recount(&self) -> usize {
        self.roots.iter().map(Edge::count_pages).sum()
    }
}

fn lookup_rec(
    edges: &mut [Edge],
    ids: &[u32],
    ps: usize,
    clock: u64,
    max_pages: usize,
    out: &mut Vec<PageId>,
) {
    if out.len() >= max_pages || ids.len() < ps {
        return;
    }
    let Some(edge) = edges.iter_mut().find(|e| e.label[..ps] == ids[..ps]) else {
        return;
    };
    edge.stamp = clock;
    let mut m = 0usize;
    while m < edge.pages.len()
        && out.len() < max_pages
        && (m + 1) * ps <= ids.len()
        && edge.label[m * ps..(m + 1) * ps] == ids[m * ps..(m + 1) * ps]
    {
        out.push(edge.pages[m]);
        m += 1;
    }
    if m == edge.pages.len() {
        lookup_rec(&mut edge.children, &ids[m * ps..], ps, clock, max_pages, out);
    }
}

/// Returns the number of pages newly added under `edges`.
fn insert_rec<F: FnMut(usize) -> PageId>(
    edges: &mut Vec<Edge>,
    ids: &[u32],
    page0: usize,
    ps: usize,
    clock: u64,
    provide: &mut F,
) -> usize {
    if ids.is_empty() {
        return 0;
    }
    let Some(pos) = edges.iter().position(|e| e.label[..ps] == ids[..ps]) else {
        // no matching child: append the whole remainder as a leaf
        let pages: Vec<PageId> = (0..ids.len() / ps).map(|i| provide(page0 + i)).collect();
        let added = pages.len();
        edges.push(Edge {
            label: ids.to_vec(),
            pages,
            stamp: clock,
            children: Vec::new(),
        });
        return added;
    };
    let edge = &mut edges[pos];
    let old_stamp = edge.stamp;
    edge.stamp = clock;
    // pages of this edge matching the remaining ids
    let mut m = 0usize;
    while m < edge.pages.len()
        && (m + 1) * ps <= ids.len()
        && edge.label[m * ps..(m + 1) * ps] == ids[m * ps..(m + 1) * ps]
    {
        m += 1;
    }
    if m < edge.pages.len() {
        // diverged mid-edge: split at the page boundary
        let tail_label = edge.label.split_off(m * ps);
        let tail_pages = edge.pages.split_off(m);
        let tail_children = std::mem::take(&mut edge.children);
        edge.children.push(Edge {
            label: tail_label,
            pages: tail_pages,
            stamp: old_stamp,
            children: tail_children,
        });
    }
    insert_rec(
        &mut edges[pos].children,
        &ids[m * ps..],
        page0 + m,
        ps,
        clock,
        provide,
    )
}

/// Remove the leaf edge with the smallest stamp anywhere under `edges`,
/// returning the concatenated ancestor labels alongside it (so the
/// leaf's pages can be keyed by their full covering token prefix).
fn pop_lru_leaf(edges: &mut Vec<Edge>) -> Option<(Vec<u32>, Edge)> {
    fn min_leaf_stamp(edges: &[Edge]) -> Option<u64> {
        edges
            .iter()
            .filter_map(|e| {
                if e.children.is_empty() {
                    Some(e.stamp)
                } else {
                    min_leaf_stamp(&e.children)
                }
            })
            .min()
    }
    fn remove_leaf(edges: &mut Vec<Edge>, stamp: u64, prefix: &mut Vec<u32>) -> Option<Edge> {
        if let Some(i) = edges
            .iter()
            .position(|e| e.children.is_empty() && e.stamp == stamp)
        {
            return Some(edges.remove(i));
        }
        for e in edges.iter_mut() {
            prefix.extend_from_slice(&e.label);
            if let Some(found) = remove_leaf(&mut e.children, stamp, prefix) {
                return Some(found);
            }
            prefix.truncate(prefix.len() - e.label.len());
        }
        None
    }
    let stamp = min_leaf_stamp(edges)?;
    let mut prefix = Vec::new();
    let leaf = remove_leaf(edges, stamp, &mut prefix)?;
    Some((prefix, leaf))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::Cell;

    /// Unique-id provider: records requested page indices, returns
    /// sequentially unique handles starting at 1000.
    struct Prov {
        seq: Cell<PageId>,
        calls: std::cell::RefCell<Vec<usize>>,
    }

    impl Prov {
        fn new() -> Self {
            Self {
                seq: Cell::new(1000),
                calls: std::cell::RefCell::new(Vec::new()),
            }
        }
        fn f(&self) -> impl FnMut(usize) -> PageId + '_ {
            |p| {
                self.calls.borrow_mut().push(p);
                let id = self.seq.get();
                self.seq.set(id + 1);
                id
            }
        }
        fn calls(&self) -> Vec<usize> {
            self.calls.borrow().clone()
        }
    }

    #[test]
    fn insert_then_lookup_full_pages() {
        let mut idx = RadixPrefixIndex::new(4);
        let p = Prov::new();
        idx.insert(&[1, 2, 3, 4, 5, 6, 7, 8], p.f());
        assert_eq!(p.calls(), vec![0, 1]);
        assert_eq!(idx.pages_retained(), 2);
        // prompt repeating the prefix + one extra token matches both pages
        let hit = idx.lookup(&[1, 2, 3, 4, 5, 6, 7, 8, 9]);
        assert_eq!(hit.tokens, 8);
        assert_eq!(hit.pages, vec![1000, 1001]);
        // a prompt that IS exactly the prefix only matches one page
        // (at least one token must remain to prefill)
        let hit = idx.lookup(&[1, 2, 3, 4, 5, 6, 7, 8]);
        assert_eq!(hit.tokens, 4);
        // divergence in the second page stops the match there
        let hit = idx.lookup(&[1, 2, 3, 4, 9, 9, 9, 9, 9]);
        assert_eq!(hit.tokens, 4);
        assert_eq!(hit.pages, vec![1000]);
        // no match at all
        let hit = idx.lookup(&[9, 9, 9, 9, 9]);
        assert_eq!(hit.tokens, 0);
    }

    #[test]
    fn shared_prefix_splits_edge_at_page_boundary() {
        let mut idx = RadixPrefixIndex::new(2);
        let p = Prov::new();
        idx.insert(&[1, 2, 3, 4, 5, 6], p.f()); // pages 1000..=1002
        idx.insert(&[1, 2, 3, 4, 9, 9], p.f()); // shares 2, adds 1003
        // only the diverging page is provided anew, at page index 2
        assert_eq!(p.calls(), vec![0, 1, 2, 2]);
        assert_eq!(idx.pages_retained(), 4);
        assert_eq!(idx.recount(), 4);
        let hit = idx.lookup(&[1, 2, 3, 4, 9, 9, 7]);
        assert_eq!(hit.pages, vec![1000, 1001, 1003]);
        assert_eq!(hit.tokens, 6);
        let hit = idx.lookup(&[1, 2, 3, 4, 5, 6, 7]);
        assert_eq!(hit.pages, vec![1000, 1001, 1002]);
    }

    #[test]
    fn reinsert_provides_nothing_new() {
        let mut idx = RadixPrefixIndex::new(2);
        let p = Prov::new();
        idx.insert(&[1, 2, 3, 4], p.f());
        idx.insert(&[1, 2, 3, 4], p.f());
        assert_eq!(p.calls(), vec![0, 1], "repeat insert must not re-provide");
        assert_eq!(idx.pages_retained(), 2);
        // extending an indexed prefix only provides the suffix
        idx.insert(&[1, 2, 3, 4, 5, 6], p.f());
        assert_eq!(p.calls(), vec![0, 1, 2]);
        assert_eq!(idx.pages_retained(), 3);
    }

    #[test]
    fn trim_releases_lru_leaves_first() {
        let mut idx = RadixPrefixIndex::new(2);
        let p = Prov::new();
        idx.insert(&[1, 1, 2, 2], p.f()); // 1000, 1001
        idx.insert(&[7, 7, 8, 8], p.f()); // 1002, 1003
        // touch the first prefix so the second becomes LRU
        let _ = idx.lookup(&[1, 1, 2, 2, 3]);
        let dropped = idx.trim(2);
        assert_eq!(idx.pages_retained(), 2);
        assert_eq!(idx.recount(), 2);
        // the untouched [7,7,8,8] chain was dropped
        assert_eq!(dropped, vec![1002, 1003]);
        assert_eq!(idx.lookup(&[7, 7, 8, 8, 9]).tokens, 0);
        assert_eq!(idx.lookup(&[1, 1, 2, 2, 3]).tokens, 4);
    }

    #[test]
    fn trim_on_split_tree_drops_deep_leaf() {
        let mut idx = RadixPrefixIndex::new(2);
        let p = Prov::new();
        idx.insert(&[1, 1, 2, 2, 3, 3], p.f()); // 1000..=1002
        idx.insert(&[1, 1, 2, 2, 9, 9], p.f()); // splits, adds 1003
        // refresh the second branch; the [3,3] tail is now LRU
        let _ = idx.lookup(&[1, 1, 2, 2, 9, 9, 0]);
        let dropped = idx.trim(3);
        assert_eq!(dropped, vec![1002]);
        assert_eq!(idx.lookup(&[1, 1, 2, 2, 3, 3, 0]).tokens, 4);
        assert_eq!(idx.lookup(&[1, 1, 2, 2, 9, 9, 0]).tokens, 6);
        assert_eq!(idx.recount(), idx.pages_retained());
    }

    #[test]
    fn release_all_returns_every_page() {
        let mut idx = RadixPrefixIndex::new(2);
        let p = Prov::new();
        idx.insert(&[1, 1, 2, 2, 3, 3], p.f());
        idx.insert(&[1, 1, 9, 9], p.f());
        let n = idx.pages_retained();
        let all = idx.release_all();
        assert_eq!(all.len(), n);
        assert_eq!(idx.pages_retained(), 0);
        assert_eq!(idx.lookup(&[1, 1, 2, 2, 3]).tokens, 0);
    }

    #[test]
    fn best_hit_len_matches_lookup_without_side_effects() {
        let mut idx = RadixPrefixIndex::new(2);
        let p = Prov::new();
        idx.insert(&[1, 1, 2, 2, 3, 3], p.f()); // 3 pages
        idx.insert(&[1, 1, 2, 2, 9, 9], p.f()); // splits, 4th page
        for ids in [
            vec![1u32, 1, 2, 2, 3, 3, 7],
            vec![1, 1, 2, 2, 9, 9, 7],
            vec![1, 1, 2, 2],
            vec![1, 1],
            vec![9, 9, 9],
            vec![],
        ] {
            let probe = idx.best_hit_len(&ids);
            let hit = idx.lookup(&ids);
            assert_eq!(probe, hit.tokens, "probe/lookup disagree on {ids:?}");
        }
        // probing never retains or drops pages
        assert_eq!(idx.pages_retained(), 4);
        assert_eq!(idx.recount(), 4);
    }

    #[test]
    fn best_hit_len_does_not_refresh_lru() {
        let mut idx = RadixPrefixIndex::new(2);
        let p = Prov::new();
        idx.insert(&[1, 1, 2, 2], p.f()); // 1000, 1001 (older)
        idx.insert(&[7, 7, 8, 8], p.f()); // 1002, 1003
        // a read-only probe of the older prefix must NOT protect it
        assert_eq!(idx.best_hit_len(&[1, 1, 2, 2, 3]), 4);
        let dropped = idx.trim(2);
        assert_eq!(dropped, vec![1000, 1001], "probe refreshed the LRU stamp");
    }

    #[test]
    fn trim_with_hands_each_page_its_covering_prefix() {
        let mut idx = RadixPrefixIndex::new(2);
        let p = Prov::new();
        idx.insert(&[1, 1, 2, 2, 3, 3], p.f()); // 1000..=1002
        idx.insert(&[1, 1, 2, 2, 9, 9], p.f()); // splits, adds 1003
        let mut demoted = Vec::new();
        idx.trim_with(0, |key, id| demoted.push((key.to_vec(), id)));
        assert_eq!(idx.pages_retained(), 0);
        assert_eq!(demoted.len(), 4);
        // every key is the page's full root-anchored token prefix
        let keys: std::collections::HashMap<PageId, Vec<u32>> =
            demoted.iter().map(|(k, id)| (*id, k.clone())).collect();
        assert_eq!(keys[&1000], vec![1, 1]);
        assert_eq!(keys[&1001], vec![1, 1, 2, 2]);
        assert_eq!(keys[&1002], vec![1, 1, 2, 2, 3, 3]);
        assert_eq!(keys[&1003], vec![1, 1, 2, 2, 9, 9]);
    }

    /// The satellite property: under arbitrary insert/lookup
    /// interleavings, `trim_with` demotes in LRU leaf order. Because a
    /// walk stamps parents with (at least) their children's clock and a
    /// split hands the tail its original stamp, `parent.stamp >=
    /// child.stamp` always holds — so the popped leaf-stamp sequence
    /// must be non-decreasing, every page must be demoted exactly once,
    /// and each key must equal the page's covering prefix.
    #[test]
    fn trim_with_demotion_order_is_lru_under_random_interleavings() {
        use crate::util::SplitMix64;

        for seed in 0..12u64 {
            let ps = 2usize;
            let mut rng = SplitMix64::new(0xC01D_CAFE ^ seed);
            let mut idx = RadixPrefixIndex::new(ps);
            let p = Prov::new();
            for _ in 0..160 {
                // small alphabet so prefixes collide and edges split
                let n_pages = 1 + rng.below(4);
                let ids: Vec<u32> = (0..n_pages * ps).map(|_| rng.below(3) as u32).collect();
                if rng.below(2) == 0 {
                    idx.insert(&ids, p.f());
                } else {
                    let mut probe = ids;
                    probe.push(7); // lookups refresh LRU stamps
                    let _ = idx.lookup(&probe);
                }
            }
            // pre-trim walk (white-box): page id -> (covering key, edge stamp)
            fn walk(
                edges: &[Edge],
                prefix: &[u32],
                ps: usize,
                out: &mut std::collections::HashMap<PageId, (Vec<u32>, u64)>,
            ) {
                for e in edges {
                    for (i, &id) in e.pages.iter().enumerate() {
                        let mut key = prefix.to_vec();
                        key.extend_from_slice(&e.label[..(i + 1) * ps]);
                        assert!(out.insert(id, (key, e.stamp)).is_none());
                    }
                    let mut deeper = prefix.to_vec();
                    deeper.extend_from_slice(&e.label);
                    walk(&e.children, &deeper, ps, out);
                }
            }
            let mut expect = std::collections::HashMap::new();
            walk(&idx.roots, &[], ps, &mut expect);
            let total = idx.pages_retained();
            assert_eq!(expect.len(), total);

            // trim halfway first, then to zero: both legs must demote in
            // LRU order and cover every page exactly once overall
            let mut demoted: Vec<(Vec<u32>, PageId)> = Vec::new();
            idx.trim_with(total / 2, |k, id| demoted.push((k.to_vec(), id)));
            assert!(idx.pages_retained() <= total / 2);
            assert_eq!(idx.recount(), idx.pages_retained());
            let after_half = demoted.len();
            assert_eq!(after_half, total - idx.pages_retained());
            idx.trim_with(0, |k, id| demoted.push((k.to_vec(), id)));
            assert_eq!(idx.pages_retained(), 0);
            assert_eq!(demoted.len(), total, "every page demoted exactly once");

            let mut last_stamp = 0u64;
            let mut seen = std::collections::HashSet::new();
            for (key, id) in &demoted {
                assert!(seen.insert(*id), "page {id} demoted twice (seed {seed})");
                let (want_key, stamp) = &expect[id];
                assert_eq!(key, want_key, "wrong covering prefix for {id} (seed {seed})");
                assert!(
                    *stamp >= last_stamp,
                    "demotion left LRU order: stamp {stamp} after {last_stamp} (seed {seed})"
                );
                last_stamp = *stamp;
            }
        }
    }

    #[test]
    fn for_each_page_visits_exactly_the_retained_pages() {
        let mut idx = RadixPrefixIndex::new(2);
        let p = Prov::new();
        idx.insert(&[1, 1, 2, 2, 3, 3], p.f());
        idx.insert(&[1, 1, 9, 9], p.f());
        let mut visited = Vec::new();
        idx.for_each_page(|id| visited.push(id));
        visited.sort_unstable();
        assert_eq!(visited, vec![1000, 1001, 1002, 1003]);
        assert_eq!(visited.len(), idx.pages_retained());
    }

    #[test]
    fn lookup_respects_prompt_length_cap() {
        let mut idx = RadixPrefixIndex::new(4);
        let p = Prov::new();
        idx.insert(&[1, 2, 3, 4, 5, 6, 7, 8], p.f());
        // 6-token prompt: only one full page fits under the cap
        let hit = idx.lookup(&[1, 2, 3, 4, 5, 6]);
        assert_eq!(hit.tokens, 4);
        // 4-token prompt: the cap forbids any hit
        let hit = idx.lookup(&[1, 2, 3, 4]);
        assert_eq!(hit.tokens, 0);
    }
}
