//! Line-JSON protocol types.

use anyhow::{anyhow, Result};

use crate::util::Json;

/// Parsed generation request.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeRequest {
    pub id: u64,
    pub prompt: String,
    pub width: usize,
    pub max_len: usize,
    pub temperature: f64,
    pub seed: u64,
}

/// Response payload.
#[derive(Clone, Debug)]
pub struct ServeResponse {
    pub id: u64,
    pub texts: Vec<String>,
    pub answer: Option<String>,
    pub reads: f64,
    pub peak_tokens: f64,
    pub latency_ms: f64,
    pub error: Option<String>,
}

impl ServeResponse {
    pub fn error(id: u64, msg: &str) -> Self {
        Self {
            id,
            texts: Vec::new(),
            answer: None,
            reads: 0.0,
            peak_tokens: 0.0,
            latency_ms: 0.0,
            error: Some(msg.to_string()),
        }
    }
}

pub fn parse_request(j: &Json) -> Result<ServeRequest> {
    Ok(ServeRequest {
        id: j.get("id").and_then(Json::as_i64).unwrap_or(0) as u64,
        prompt: j
            .req("prompt")?
            .as_str()
            .ok_or_else(|| anyhow!("prompt must be a string"))?
            .to_string(),
        width: j.get("width").and_then(Json::as_usize).unwrap_or(1).max(1),
        max_len: j.get("max_len").and_then(Json::as_usize).unwrap_or(160),
        temperature: j
            .get("temperature")
            .and_then(|x| x.as_f64())
            .unwrap_or(0.7),
        seed: j.get("seed").and_then(Json::as_i64).unwrap_or(0) as u64,
    })
}

pub fn render_response(r: &ServeResponse) -> String {
    let mut j = Json::obj().set("id", r.id);
    if let Some(err) = &r.error {
        return j.set("error", err.as_str()).to_string();
    }
    j = j.set(
        "texts",
        Json::Arr(r.texts.iter().map(|t| Json::Str(t.clone())).collect()),
    );
    j = match &r.answer {
        Some(a) => j.set("answer", a.as_str()),
        None => j.set("answer", Json::Null),
    };
    j.set("reads", r.reads)
        .set("peak_tokens", r.peak_tokens)
        .set("latency_ms", r.latency_ms)
        .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_request() {
        let j = Json::parse(
            r#"{"id": 7, "prompt": "Q:1+1=?\nT:", "width": 4,
                "max_len": 96, "temperature": 0.5, "seed": 9}"#,
        )
        .unwrap();
        let r = parse_request(&j).unwrap();
        assert_eq!(r.id, 7);
        assert_eq!(r.width, 4);
        assert_eq!(r.max_len, 96);
        assert_eq!(r.prompt, "Q:1+1=?\nT:");
    }

    #[test]
    fn defaults_applied() {
        let j = Json::parse(r#"{"prompt": "x"}"#).unwrap();
        let r = parse_request(&j).unwrap();
        assert_eq!(r.width, 1);
        assert_eq!(r.max_len, 160);
    }

    #[test]
    fn missing_prompt_errors() {
        let j = Json::parse(r#"{"id": 1}"#).unwrap();
        assert!(parse_request(&j).is_err());
    }

    #[test]
    fn response_roundtrip() {
        let r = ServeResponse {
            id: 3,
            texts: vec!["A:4\n".into()],
            answer: Some("4".into()),
            reads: 120.5,
            peak_tokens: 33.0,
            latency_ms: 12.0,
            error: None,
        };
        let s = render_response(&r);
        let j = Json::parse(&s).unwrap();
        assert_eq!(j.get("answer").unwrap().as_str(), Some("4"));
        assert_eq!(j.get("reads").unwrap().as_f64(), Some(120.5));
    }

    #[test]
    fn error_response() {
        let r = ServeResponse::error(1, "boom");
        let j = Json::parse(&render_response(&r)).unwrap();
        assert_eq!(j.get("error").unwrap().as_str(), Some("boom"));
    }
}
