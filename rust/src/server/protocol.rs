//! Line-JSON protocol types.
//!
//! One JSON object per line in both directions. Requests carry the
//! prompt plus sampling/budget knobs; responses carry the generated
//! chains, the majority-vote answer, the paper's §5.1 efficiency
//! numbers (KV reads, peak tokens), and — since the continuous-batching
//! server — per-request serving timings (queueing delay, TTFT,
//! end-to-end latency, generation throughput).
//!
//! Every inbound line decodes to one typed [`Command`] via
//! [`parse_command`] — control verbs (`{"cmd": ...}`) and generation
//! requests parse in a single place, so unknown commands and malformed
//! fields produce uniform error lines no matter which front end
//! (single engine or cluster) is serving. A parsed [`ServeRequest`]
//! maps to the engine's typed submission with
//! [`ServeRequest::submit_spec`].

use anyhow::{anyhow, Result};

use crate::engine::{GenRequest, RequestTiming, SloTier, SubmitSpec};
use crate::util::Json;

/// Parsed generation request.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeRequest {
    /// Client-chosen request id, echoed back in the response.
    pub id: u64,
    /// Prompt text.
    pub prompt: String,
    /// Parallel chains (parallel-scaling width W).
    pub width: usize,
    /// Max total tokens per chain (prompt + generation).
    pub max_len: usize,
    /// Sampling temperature.
    pub temperature: f64,
    /// Base RNG seed; chain i uses seed + i.
    pub seed: u64,
    /// SLO tier (`"interactive"`, `"standard"`, `"batch"`). `None`
    /// means no deadline accounting for this request.
    pub slo: Option<SloTier>,
}

impl ServeRequest {
    /// The typed engine submission this wire request describes: the
    /// generation payload plus the flight-recorder key (the
    /// client-chosen `id`) and SLO tier, assembled in one place for
    /// every serving front end (`Backend::submit` takes exactly this).
    pub fn submit_spec(&self) -> SubmitSpec {
        SubmitSpec {
            request: GenRequest {
                prompt: self.prompt.clone(),
                width: self.width,
                max_len: self.max_len,
                temperature: self.temperature,
                seed: self.seed,
            },
            trace_id: Some(self.id),
            slo: self.slo,
        }
    }
}

/// One decoded inbound protocol line.
#[derive(Clone, Debug, PartialEq)]
pub enum Command {
    /// A generation request (any line without a `"cmd"` field).
    Submit(ServeRequest),
    /// `{"cmd": "stats"}` — metrics dump.
    Stats,
    /// `{"cmd": "trace", "request_id": N}` — flight-recorder slice.
    Trace { request_id: u64 },
    /// `{"cmd": "shutdown"}`.
    Shutdown,
}

/// Decode one parsed JSON line into its typed [`Command`]. Unknown
/// `cmd` verbs and malformed request fields both surface here, so the
/// client handler renders every protocol error the same way.
pub fn parse_command(j: &Json) -> Result<Command> {
    if let Some(cmd) = j.get("cmd").and_then(Json::as_str) {
        return match cmd {
            "shutdown" => Ok(Command::Shutdown),
            "stats" => Ok(Command::Stats),
            "trace" => Ok(Command::Trace {
                request_id: j.get("request_id").and_then(Json::as_i64).unwrap_or(0) as u64,
            }),
            other => Err(anyhow!("unknown cmd '{other}'")),
        };
    }
    Ok(Command::Submit(parse_request(j)?))
}

/// One outbound protocol line that is not a rendered [`ServeResponse`]
/// (those go through [`render_response`]): acknowledgements and
/// protocol-level errors, typed so front ends never hand-build the
/// JSON shape inline.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    /// `{"ok": true}` — e.g. the shutdown acknowledgement.
    Ok,
    /// `{"error": ...}` — bad JSON, unknown command, malformed request.
    Error(String),
}

/// Render a control/error [`Response`] as one JSON line.
pub fn render_line(r: &Response) -> String {
    match r {
        Response::Ok => Json::obj().set("ok", true).to_string(),
        Response::Error(msg) => Json::obj().set("error", msg.as_str()).to_string(),
    }
}

/// Response payload.
#[derive(Clone, Debug)]
pub struct ServeResponse {
    /// Echo of the request id.
    pub id: u64,
    /// Generated text per chain.
    pub texts: Vec<String>,
    /// Majority-vote answer across chains, if any chain answered.
    pub answer: Option<String>,
    /// Total KV reads across chains (token units).
    pub reads: f64,
    /// `reads` priced in bytes: token reads × full-model KV bytes per
    /// token under the serving dtype — the denominator of the paper's
    /// accuracy-per-memory-read frontier, computed server-side so
    /// clients never re-derive geometry (see docs/OBSERVABILITY.md).
    pub kv_read_bytes: f64,
    /// Summed peak live tokens across concurrent chains.
    pub peak_tokens: f64,
    /// End-to-end latency: submission to last chain finished.
    pub latency_ms: f64,
    /// Queueing delay before the first chain got a lane.
    pub queue_ms: f64,
    /// Time to the request's first sampled token.
    pub ttft_ms: f64,
    /// Generation throughput of this request (tokens per second).
    pub tokens_per_s: f64,
    /// Tokens generated across all chains of the request (the
    /// numerator of `tokens_per_s` — lets clients and the routing
    /// benches aggregate throughput without re-tokenizing texts).
    pub gen_tokens: f64,
    /// Prompt tokens restored from the radix prefix cache instead of
    /// being prefilled, summed across chains.
    pub prefix_hit_tokens: f64,
    /// Storage format of pool-owned KV payloads that served this
    /// request (`f32`, `q8`, or `q4` — see docs/NUMERICS.md), so
    /// clients can attribute precision effects.
    pub kv_dtype: String,
    /// Budget allocator that shaped the request's per-(layer, head)
    /// KV budget plans (`uniform`, `pyramid`, or `adaptive`), so
    /// clients can attribute accuracy/footprint effects of
    /// non-uniform plans.
    pub allocator: String,
    /// Engine replica that served the request (0 on the single-engine
    /// path; the cluster router's assignment otherwise), so clients —
    /// and the routing benches/tests — can attribute cache affinity.
    pub replica_id: usize,
    /// Error message (all other payload fields are omitted when set).
    pub error: Option<String>,
}

impl ServeResponse {
    /// An error response for request `id`.
    pub fn error(id: u64, msg: &str) -> Self {
        Self {
            id,
            texts: Vec::new(),
            answer: None,
            reads: 0.0,
            kv_read_bytes: 0.0,
            peak_tokens: 0.0,
            latency_ms: 0.0,
            queue_ms: 0.0,
            ttft_ms: 0.0,
            tokens_per_s: 0.0,
            gen_tokens: 0.0,
            prefix_hit_tokens: 0.0,
            kv_dtype: String::new(),
            allocator: String::new(),
            replica_id: 0,
            error: Some(msg.to_string()),
        }
    }

    /// Copy the scheduler's per-request timings into the response.
    pub fn with_timing(mut self, t: &RequestTiming) -> Self {
        self.latency_ms = t.e2e_ms;
        self.queue_ms = t.queue_ms;
        self.ttft_ms = t.ttft_ms;
        self.tokens_per_s = t.tokens_per_s();
        self.gen_tokens = t.gen_tokens as f64;
        self
    }
}

/// Parse a request object (`prompt` is the only required field).
pub fn parse_request(j: &Json) -> Result<ServeRequest> {
    Ok(ServeRequest {
        id: j.get("id").and_then(Json::as_i64).unwrap_or(0) as u64,
        prompt: j
            .req("prompt")?
            .as_str()
            .ok_or_else(|| anyhow!("prompt must be a string"))?
            .to_string(),
        width: j.get("width").and_then(Json::as_usize).unwrap_or(1).max(1),
        max_len: j.get("max_len").and_then(Json::as_usize).unwrap_or(160),
        temperature: j
            .get("temperature")
            .and_then(|x| x.as_f64())
            .unwrap_or(0.7),
        seed: j.get("seed").and_then(Json::as_i64).unwrap_or(0) as u64,
        slo: match j.get("slo").and_then(Json::as_str) {
            Some(s) => Some(s.parse()?),
            None => None,
        },
    })
}

/// Render a response as one JSON line (no trailing newline).
pub fn render_response(r: &ServeResponse) -> String {
    let mut j = Json::obj().set("id", r.id);
    if let Some(err) = &r.error {
        return j.set("error", err.as_str()).to_string();
    }
    j = j.set(
        "texts",
        Json::Arr(r.texts.iter().map(|t| Json::Str(t.clone())).collect()),
    );
    j = match &r.answer {
        Some(a) => j.set("answer", a.as_str()),
        None => j.set("answer", Json::Null),
    };
    j.set("reads", r.reads)
        .set("kv_read_bytes", r.kv_read_bytes)
        .set("peak_tokens", r.peak_tokens)
        .set("latency_ms", r.latency_ms)
        .set("queue_ms", r.queue_ms)
        .set("ttft_ms", r.ttft_ms)
        .set("tokens_per_s", r.tokens_per_s)
        .set("gen_tokens", r.gen_tokens)
        .set("prefix_hit_tokens", r.prefix_hit_tokens)
        .set("kv_dtype", r.kv_dtype.as_str())
        .set("allocator", r.allocator.as_str())
        .set("replica_id", r.replica_id as u64)
        .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_request() {
        let j = Json::parse(
            r#"{"id": 7, "prompt": "Q:1+1=?\nT:", "width": 4,
                "max_len": 96, "temperature": 0.5, "seed": 9}"#,
        )
        .unwrap();
        let r = parse_request(&j).unwrap();
        assert_eq!(r.id, 7);
        assert_eq!(r.width, 4);
        assert_eq!(r.max_len, 96);
        assert_eq!(r.prompt, "Q:1+1=?\nT:");
    }

    #[test]
    fn defaults_applied() {
        let j = Json::parse(r#"{"prompt": "x"}"#).unwrap();
        let r = parse_request(&j).unwrap();
        assert_eq!(r.width, 1);
        assert_eq!(r.max_len, 160);
        assert_eq!(r.slo, None);
    }

    #[test]
    fn slo_tier_parses_and_rejects_unknown() {
        let j = Json::parse(r#"{"prompt": "x", "slo": "interactive"}"#).unwrap();
        let r = parse_request(&j).unwrap();
        assert_eq!(r.slo, Some(SloTier::Interactive));
        let bad = Json::parse(r#"{"prompt": "x", "slo": "platinum"}"#).unwrap();
        assert!(parse_request(&bad).is_err());
    }

    #[test]
    fn missing_prompt_errors() {
        let j = Json::parse(r#"{"id": 1}"#).unwrap();
        assert!(parse_request(&j).is_err());
    }

    #[test]
    fn response_roundtrip() {
        let r = ServeResponse {
            id: 3,
            texts: vec!["A:4\n".into()],
            answer: Some("4".into()),
            reads: 120.5,
            kv_read_bytes: 120.5 * 256.0,
            peak_tokens: 33.0,
            latency_ms: 12.0,
            queue_ms: 1.5,
            ttft_ms: 4.0,
            tokens_per_s: 80.0,
            gen_tokens: 40.0,
            prefix_hit_tokens: 16.0,
            kv_dtype: "q8".into(),
            allocator: "pyramid".into(),
            replica_id: 3,
            error: None,
        };
        let s = render_response(&r);
        let j = Json::parse(&s).unwrap();
        assert_eq!(j.get("answer").unwrap().as_str(), Some("4"));
        assert_eq!(j.get("reads").unwrap().as_f64(), Some(120.5));
        assert_eq!(j.get("kv_read_bytes").unwrap().as_f64(), Some(30848.0));
        assert_eq!(j.get("queue_ms").unwrap().as_f64(), Some(1.5));
        assert_eq!(j.get("ttft_ms").unwrap().as_f64(), Some(4.0));
        assert_eq!(j.get("tokens_per_s").unwrap().as_f64(), Some(80.0));
        assert_eq!(j.get("gen_tokens").unwrap().as_f64(), Some(40.0));
        assert_eq!(j.get("prefix_hit_tokens").unwrap().as_f64(), Some(16.0));
        assert_eq!(j.get("kv_dtype").unwrap().as_str(), Some("q8"));
        assert_eq!(j.get("allocator").unwrap().as_str(), Some("pyramid"));
        assert_eq!(j.get("replica_id").unwrap().as_usize(), Some(3));
    }

    #[test]
    fn timing_copied_into_response() {
        let t = RequestTiming {
            queue_ms: 2.0,
            ttft_ms: 5.0,
            e2e_ms: 500.0,
            gen_tokens: 100,
        };
        let r = ServeResponse::error(1, "placeholder");
        let mut r = r;
        r.error = None;
        let r = r.with_timing(&t);
        assert_eq!(r.latency_ms, 500.0);
        assert_eq!(r.queue_ms, 2.0);
        assert_eq!(r.ttft_ms, 5.0);
        assert!((r.tokens_per_s - 200.0).abs() < 1e-9);
        assert_eq!(r.gen_tokens, 100.0);
    }

    #[test]
    fn error_response() {
        let r = ServeResponse::error(1, "boom");
        let j = Json::parse(&render_response(&r)).unwrap();
        assert_eq!(j.get("error").unwrap().as_str(), Some("boom"));
    }

    #[test]
    fn commands_parse_to_typed_variants() {
        let cases = [
            (r#"{"cmd": "stats"}"#, Command::Stats),
            (r#"{"cmd": "shutdown"}"#, Command::Shutdown),
            (
                r#"{"cmd": "trace", "request_id": 9}"#,
                Command::Trace { request_id: 9 },
            ),
            (r#"{"cmd": "trace"}"#, Command::Trace { request_id: 0 }),
        ];
        for (line, want) in cases {
            let j = Json::parse(line).unwrap();
            assert_eq!(parse_command(&j).unwrap(), want);
        }
    }

    #[test]
    fn request_lines_parse_to_submit() {
        let j = Json::parse(r#"{"id": 4, "prompt": "x", "slo": "batch"}"#).unwrap();
        match parse_command(&j).unwrap() {
            Command::Submit(req) => {
                assert_eq!(req.id, 4);
                assert_eq!(req.slo, Some(SloTier::Batch));
            }
            other => panic!("expected Submit, got {other:?}"),
        }
    }

    #[test]
    fn unknown_cmd_and_bad_request_error_uniformly() {
        let j = Json::parse(r#"{"cmd": "reboot"}"#).unwrap();
        let err = parse_command(&j).unwrap_err();
        assert_eq!(err.to_string(), "unknown cmd 'reboot'");
        let j = Json::parse(r#"{"id": 1}"#).unwrap();
        assert!(parse_command(&j).is_err(), "missing prompt still errors");
    }

    #[test]
    fn submit_spec_carries_trace_id_and_slo() {
        let j = Json::parse(
            r#"{"id": 11, "prompt": "p", "width": 2, "seed": 5, "slo": "interactive"}"#,
        )
        .unwrap();
        let spec = parse_request(&j).unwrap().submit_spec();
        assert_eq!(spec.trace_id, Some(11));
        assert_eq!(spec.slo, Some(SloTier::Interactive));
        assert_eq!(spec.request.prompt, "p");
        assert_eq!(spec.request.width, 2);
        assert_eq!(spec.request.seed, 5);
    }

    #[test]
    fn control_lines_render() {
        assert_eq!(
            Json::parse(&render_line(&Response::Ok))
                .unwrap()
                .get("ok")
                .and_then(|j| j.as_bool()),
            Some(true)
        );
        let j = Json::parse(&render_line(&Response::Error("bad json: x".into()))).unwrap();
        assert_eq!(j.get("error").unwrap().as_str(), Some("bad json: x"));
    }
}
