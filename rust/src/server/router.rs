//! Cluster admission router: prefix-affinity scoring, load balancing,
//! and work-steal planning over N engine replicas.
//!
//! The router is a **pure decision core**: it owns no threads, no
//! channels, and no engines — [`super::cluster`] feeds it load
//! snapshots and asks three questions:
//!
//! * [`Router::route`] — which replica should admit this prompt?
//! * [`Router::note_routed`] — remember the decision (feeds affinity);
//! * [`Router::steal_plan`] — should queued work migrate, and where?
//!
//! ## Shadow prefix indexes
//!
//! Prefix-affinity routing needs "how much of this prompt's KV prefix
//! does replica *i* already hold?" without crossing into the engine
//! threads. The router therefore keeps one **shadow**
//! [`RadixPrefixIndex`] per replica, fed with the *byte* prefix of
//! every prompt it routes there, and scores candidates with the
//! read-only [`RadixPrefixIndex::best_hit_len`] probe (no references
//! taken, no LRU perturbation). The shadow is an optimistic predictor,
//! not a mirror: it is keyed on raw prompt bytes (the router has no
//! tokenizer), uses its own page granularity, and counts a prompt as
//! cached from the moment it is routed — before the replica finishes
//! the request and actually retains pages. Mispredictions are
//! harmless: the replica's own index decides the real
//! `prefix_hit_tokens`, and a cold replica merely prefills from
//! scratch, exactly as it would under load balancing. What matters is
//! that *equal prefixes converge on the same replica*, which only
//! requires the shadow to be self-consistent.
//!
//! Replica scoring follows the issue's (a)/(b)/(c) order: shadow hit
//! length dominates, live-lane occupancy + queue depth break ties, and
//! work stealing (planned here, executed by the cluster) is the escape
//! valve when affinity piles queued requests onto a hot replica while
//! others sit idle.

use crate::config::RoutingPolicy;
use crate::kvcache::RadixPrefixIndex;

/// Byte granularity of the shadow indexes. Prefix reuse below one KV
/// page is worthless to a replica, and typical system preambles span
/// hundreds of bytes, so a coarse page keeps the shadow tree shallow.
const SHADOW_PAGE_BYTES: usize = 16;

/// Retained-page budget per shadow index (LRU-trimmed). At 16 bytes
/// per page this tracks ~64 KiB of distinct routed prefixes per
/// replica — far beyond what a replica's real index retains.
const SHADOW_PAGES: usize = 4096;

/// One replica's occupancy snapshot, as last reported by its thread.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ReplicaLoad {
    /// Chains waiting in the replica's admission queue.
    pub queue_depth: usize,
    /// Lanes currently running a chain.
    pub active_lanes: usize,
    /// Requests admitted and not yet answered.
    pub inflight: usize,
    /// Whole queued requests eligible for `drain_queued` handoff.
    pub stealable: usize,
}

impl ReplicaLoad {
    /// Scalar congestion score used for tie-breaks and least-loaded
    /// routing: everything occupying or waiting for a lane.
    fn pressure(&self) -> usize {
        self.active_lanes + self.queue_depth
    }

    /// A replica with nothing running and nothing queued.
    pub fn is_idle(&self) -> bool {
        self.active_lanes == 0 && self.queue_depth == 0 && self.inflight == 0
    }
}

/// Outcome of a routing decision.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RouteDecision {
    /// Chosen replica id.
    pub replica: usize,
    /// Shadow-index hit length (bytes) on the chosen replica — > 0
    /// means the request was routed *by affinity*, not load.
    pub shadow_hit: usize,
}

impl RouteDecision {
    /// The decision as a flight-recorder event for request `req`
    /// (emitted by the cluster router when tracing is enabled).
    pub fn trace_event(&self, req: u64) -> crate::trace::TraceEvent {
        crate::trace::TraceEvent::Route {
            req,
            replica: self.replica,
            shadow_hit: self.shadow_hit,
        }
    }
}

/// A planned migration of queued requests (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StealPlan {
    /// Replica to drain (has stealable queued requests).
    pub from: usize,
    /// Idle replica the drained requests should be re-routed to.
    pub to: usize,
    /// Upper bound on requests to migrate in this round.
    pub max_requests: usize,
}

impl StealPlan {
    /// The plan as a flight-recorder event. `moved` records the drain
    /// cap, not the realized count — the donor reports actual drains
    /// through the requeue path's route events.
    pub fn trace_event(&self) -> crate::trace::TraceEvent {
        crate::trace::TraceEvent::Steal {
            from: self.from,
            to: self.to,
            moved: self.max_requests,
        }
    }
}

/// The admission router (see module docs).
pub struct Router {
    policy: RoutingPolicy,
    shadow: Vec<RadixPrefixIndex>,
    shadow_seq: u64,
    rr_next: usize,
}

impl Router {
    /// A router over `replicas` engine replicas.
    pub fn new(replicas: usize, policy: RoutingPolicy) -> Self {
        assert!(replicas > 0, "a cluster needs at least one replica");
        Self {
            policy,
            shadow: (0..replicas)
                .map(|_| RadixPrefixIndex::new(SHADOW_PAGE_BYTES))
                .collect(),
            shadow_seq: 0,
            rr_next: 0,
        }
    }

    /// Number of replicas routed over.
    pub fn replicas(&self) -> usize {
        self.shadow.len()
    }

    /// Active routing policy.
    pub fn policy(&self) -> RoutingPolicy {
        self.policy
    }

    /// Replica with the lowest congestion score (ties to the lowest
    /// id), optionally excluding one replica.
    fn least_loaded(loads: &[ReplicaLoad], exclude: Option<usize>) -> usize {
        (0..loads.len())
            .filter(|&i| Some(i) != exclude)
            .min_by_key(|&i| (loads[i].pressure(), loads[i].inflight, i))
            .expect("at least one candidate replica")
    }

    /// Shadow ids for a prompt: its raw bytes, truncated to whole
    /// shadow pages (sub-page tails can never be reused).
    fn shadow_ids(prompt: &str) -> Vec<u32> {
        let bytes = prompt.as_bytes();
        let n = (bytes.len() / SHADOW_PAGE_BYTES) * SHADOW_PAGE_BYTES;
        bytes[..n].iter().map(|&b| b as u32).collect()
    }

    /// Pick the replica that should admit `prompt` given the current
    /// per-replica loads (`loads.len()` must equal the replica count).
    pub fn route(&mut self, prompt: &str, loads: &[ReplicaLoad]) -> RouteDecision {
        assert_eq!(loads.len(), self.shadow.len());
        match self.policy {
            RoutingPolicy::RoundRobin => {
                let replica = self.rr_next % self.shadow.len();
                self.rr_next = self.rr_next.wrapping_add(1);
                RouteDecision {
                    replica,
                    shadow_hit: 0,
                }
            }
            RoutingPolicy::LeastLoaded => RouteDecision {
                replica: Self::least_loaded(loads, None),
                shadow_hit: 0,
            },
            RoutingPolicy::Prefix => {
                // ids is page-truncated, so best_hit_len's own
                // one-page-short cap is applied to a page-aligned
                // probe: a full shadow match still scores.
                let ids = Self::shadow_ids(prompt);
                let hits: Vec<usize> = self
                    .shadow
                    .iter()
                    .map(|s| s.best_hit_len(&ids))
                    .collect();
                let best = hits.iter().copied().max().unwrap_or(0);
                if best == 0 {
                    return RouteDecision {
                        replica: Self::least_loaded(loads, None),
                        shadow_hit: 0,
                    };
                }
                // among the replicas sharing the longest hit, prefer
                // the least congested
                let replica = (0..loads.len())
                    .filter(|&i| hits[i] == best)
                    .min_by_key(|&i| (loads[i].pressure(), i))
                    .unwrap();
                RouteDecision {
                    replica,
                    shadow_hit: best,
                }
            }
        }
    }

    /// Record that `prompt` was routed to `replica`, feeding the
    /// shadow affinity state. No-op under round-robin (affinity is
    /// deliberately ignored there) — the shadow trees would only burn
    /// memory.
    pub fn note_routed(&mut self, replica: usize, prompt: &str) {
        if self.policy == RoutingPolicy::RoundRobin {
            return;
        }
        let ids = Self::shadow_ids(prompt);
        if ids.is_empty() {
            return;
        }
        let shadow = &mut self.shadow[replica];
        self.shadow_seq += 1;
        let seq = self.shadow_seq << 16;
        let mut n = 0u64;
        shadow.insert(&ids, |_| {
            n += 1;
            seq | n // unique dummy handles; the shadow holds no pages
        });
        let _ = shadow.trim(SHADOW_PAGES);
    }

    /// Plan one queued-work migration: the most congested replica with
    /// stealable (never-installed) requests donates up to half of them
    /// to an idle replica. Returns `None` when no replica is idle, no
    /// replica has stealable work, or the donor would be the idle
    /// replica itself.
    pub fn steal_plan(&self, loads: &[ReplicaLoad]) -> Option<StealPlan> {
        assert_eq!(loads.len(), self.shadow.len());
        let to = (0..loads.len()).find(|&i| loads[i].is_idle())?;
        let from = (0..loads.len())
            .filter(|&i| i != to && loads[i].stealable > 0 && loads[i].queue_depth > 0)
            .max_by_key(|&i| (loads[i].queue_depth, loads[i].stealable))?;
        let max_requests = loads[from].stealable.div_ceil(2);
        Some(StealPlan {
            from,
            to,
            max_requests,
        })
    }

    /// Drop replica `replica`'s shadow state (after a drain the real
    /// index keeps its pages, so this is only for tests/diagnostics).
    #[cfg(test)]
    fn shadow_pages_retained(&self, replica: usize) -> usize {
        self.shadow[replica].pages_retained()
    }
}

/// Mask dead replicas out of a load snapshot before steal planning so
/// they never look idle (steal target) and never donate. This is the
/// degradation rule shared by the live cluster's router loop
/// ([`super::cluster`]) and the timeflow simulator
/// ([`crate::engine::timeflow`]).
pub fn mask_dead(loads: &mut [ReplicaLoad], dead: &[bool]) {
    debug_assert_eq!(loads.len(), dead.len());
    for (load, &d) in loads.iter_mut().zip(dead) {
        if d {
            load.stealable = 0;
            load.active_lanes = 1;
        }
    }
}

/// First live replica — the shared fallback target when a routing or
/// requeue decision lands on a dead replica. `None` means the whole
/// cluster is down.
pub fn first_alive(dead: &[bool]) -> Option<usize> {
    dead.iter().position(|&d| !d)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn loads(n: usize) -> Vec<ReplicaLoad> {
        vec![ReplicaLoad::default(); n]
    }

    /// A prompt long enough to span several shadow pages.
    fn prompt(tag: &str) -> String {
        format!("system: shared preamble padding out several shadow pages|{tag}")
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(3, RoutingPolicy::RoundRobin);
        let l = loads(3);
        let seq: Vec<usize> = (0..6).map(|_| r.route("p", &l).replica).collect();
        assert_eq!(seq, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_picks_minimum_pressure() {
        let mut r = Router::new(3, RoutingPolicy::LeastLoaded);
        let mut l = loads(3);
        l[0].active_lanes = 4;
        l[1].active_lanes = 1;
        l[1].queue_depth = 2;
        l[2].active_lanes = 2;
        assert_eq!(r.route("p", &l).replica, 2);
        // ties go to the lowest id
        l[2].active_lanes = 3;
        assert_eq!(r.route("p", &l).replica, 1);
    }

    #[test]
    fn prefix_affinity_sticks_to_the_noted_replica() {
        let mut r = Router::new(4, RoutingPolicy::Prefix);
        let l = loads(4);
        let p = prompt("q1");
        // cold: falls back to least-loaded (replica 0 on all-idle)
        let d = r.route(&p, &l);
        assert_eq!((d.replica, d.shadow_hit), (0, 0));
        r.note_routed(2, &p);
        // warm: the shared preamble pulls any same-prefix prompt to 2
        for tag in ["q1", "q2", "a much longer different question"] {
            let d = r.route(&prompt(tag), &l);
            assert_eq!(d.replica, 2, "tag {tag}");
            assert!(d.shadow_hit > 0);
        }
        // an unrelated prompt is load-balanced, not dragged to 2
        let d = r.route("completely different text without the preamble", &l);
        assert_eq!(d.shadow_hit, 0);
    }

    #[test]
    fn prefix_ties_break_by_load() {
        let mut r = Router::new(2, RoutingPolicy::Prefix);
        let p = prompt("x");
        r.note_routed(0, &p);
        r.note_routed(1, &p);
        let mut l = loads(2);
        l[0].active_lanes = 3;
        assert_eq!(r.route(&p, &l).replica, 1);
        l[1].queue_depth = 9;
        assert_eq!(r.route(&p, &l).replica, 0);
    }

    #[test]
    fn short_prompts_never_score_affinity() {
        let mut r = Router::new(2, RoutingPolicy::Prefix);
        r.note_routed(1, "short");
        assert_eq!(r.shadow_pages_retained(1), 0, "sub-page prefix not indexed");
        let d = r.route("short", &loads(2));
        assert_eq!(d.shadow_hit, 0);
    }

    #[test]
    fn shadow_stays_under_budget() {
        let mut r = Router::new(1, RoutingPolicy::Prefix);
        for i in 0..200 {
            let p = format!("{i:064}"); // 64 distinct bytes -> 4 pages
            r.note_routed(0, &p);
        }
        assert!(r.shadow_pages_retained(0) <= SHADOW_PAGES);
        assert!(r.shadow_pages_retained(0) > 0);
    }

    #[test]
    fn steal_plan_moves_from_hottest_to_idle() {
        let r = Router::new(3, RoutingPolicy::Prefix);
        let mut l = loads(3);
        // replica 0 saturated with queued work, 1 busy, 2 idle
        l[0].active_lanes = 4;
        l[0].queue_depth = 7;
        l[0].stealable = 5;
        l[0].inflight = 9;
        l[1].active_lanes = 2;
        l[1].inflight = 2;
        let plan = r.steal_plan(&l).expect("steal expected");
        assert_eq!(plan.from, 0);
        assert_eq!(plan.to, 2);
        assert_eq!(plan.max_requests, 3, "ceil(5/2)");
        // no idle replica -> no plan
        l[2].active_lanes = 1;
        assert!(r.steal_plan(&l).is_none());
        // idle replica but nothing stealable -> no plan
        l[2].active_lanes = 0;
        l[0].stealable = 0;
        assert!(r.steal_plan(&l).is_none());
    }

    #[test]
    fn dead_masking_blocks_donation_and_idleness() {
        let r = Router::new(3, RoutingPolicy::LeastLoaded);
        let mut l = loads(3);
        // replica 0 hot, replica 2 idle but dead
        l[0].queue_depth = 6;
        l[0].stealable = 6;
        l[0].active_lanes = 2;
        l[1].active_lanes = 1;
        let mut dead = vec![false, false, true];
        let mut view = l.clone();
        mask_dead(&mut view, &dead);
        assert!(
            r.steal_plan(&view).is_none(),
            "a dead replica must not be a steal target"
        );
        // a dead donor is likewise masked out
        dead = vec![true, false, false];
        l[2].active_lanes = 0;
        let mut view = l.clone();
        mask_dead(&mut view, &dead);
        assert!(r.steal_plan(&view).is_none());
        assert_eq!(first_alive(&dead), Some(1));
        assert_eq!(first_alive(&[true, true]), None);
        assert_eq!(first_alive(&[false, true]), Some(0));
    }

    #[test]
    fn steal_plan_never_self_steals() {
        let r = Router::new(1, RoutingPolicy::Prefix);
        let mut l = loads(1);
        l[0].stealable = 3;
        l[0].queue_depth = 3;
        // cluster of one: its only replica is both "idle" candidate
        // and donor; the donor filter excludes it
        assert!(r.steal_plan(&l).is_none());
    }
}
