//! Multi-replica engine cluster: N independent engines behind the
//! prefix-aware [`Router`], with a work-stealing fallback.
//!
//! ## Topology
//!
//! ```text
//!   clients (TCP, line-JSON)
//!        │  spawn_acceptor / Cluster::call
//!        ▼
//!   router thread ──────────────── owns Router (shadow prefix
//!        │      ▲                  indexes, load table) + cluster
//!        │      │ Status /         metrics registry
//!        │      │ Requeue
//!        ▼      │
//!   replica threads 0..N ───────── each owns ONE Backend: an engine
//!                                  with its own CacheStore, PagePool,
//!                                  and radix prefix index
//! ```
//!
//! **Replicas own their state outright.** A replica is a full engine:
//! its page pool, refcounts, and prefix index are single-threaded and
//! never shared across replicas — page handles are meaningless outside
//! the pool that minted them, and the PJRT state of a real engine is
//! not even `Send`. Sharding whole engines (rather than sharing one
//! cache) is what lets the cluster scale admission capacity linearly
//! while keeping every PR-2/PR-3 invariant (COW, requantize-once,
//! refcount balance) local to one thread. The price is that a prefix
//! cached on replica 2 is invisible to replica 3 — which is exactly
//! why routing is prefix-aware: the router's job is to make repeated
//! prefixes *land where their pages already are*.
//!
//! **Steal only what never ran.** The work-stealing fallback migrates
//! *queued* requests only — every chain still waiting, none installed
//! on a lane, none completed, none carrying preemption resume state
//! (`Scheduler::drain_queued` enforces this). An installed chain has
//! KV state resident in its replica's lane regions and pool; migrating
//! it would mean exporting pages across pools or recomputing silently.
//! A queued fresh request owns nothing but prefix-page references,
//! which the drain releases — so a steal is semantically a re-submit,
//! and the destination replica serves it bit-identically (streams are
//! a pure function of seed/prompt, never of the serving replica).
//! Timing fields restart on the destination (`queue_ms` measures the
//! queue it actually ran from).
//!
//! ## Message flow
//!
//! Replica threads report occupancy ([`ReplicaLoad`]) to the router
//! after any tick that changed it (and right before blocking idle).
//! The router scores admissions with those snapshots plus optimistic
//! in-flight bumps (a routed request raises the target's load
//! immediately, so bursts don't dogpile one replica between status
//! updates). When a status update shows one replica idle while another
//! has stealable queued requests, the router plans a steal
//! ([`Router::steal_plan`]), the donor drains and hands the requests
//! back (a `Requeue` message), and the router forwards them to the
//! planned idle replica, migrating their shadow-prefix affinity with
//! them.

use std::collections::HashMap;
use std::net::TcpListener;
use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::config::{ClusterConfig, EngineConfig};
use crate::engine::{CompletedRequest, Engine, Session, SimEngine, SubmitSpec};
use crate::metrics::{prometheus_merge, Registry};
use crate::trace::{Stamped, TraceEvent, Tracer};
use crate::util::Json;

use super::protocol::{render_response, ServeRequest, ServeResponse};
use super::router::{first_alive, mask_dead, ReplicaLoad, Router};
use super::{response_from, write_trace_dump, Dispatch, ServeOpts};

/// What the cluster needs from an engine replica. Implemented by
/// [`EngineBackend`] (the real PJRT engine) and by
/// [`SimEngine`](crate::engine::SimEngine) (deterministic fake model —
/// what tests and the smoke benches run, since real engines need AOT
/// artifacts). Backends are constructed *inside* their replica thread
/// (the real engine's PJRT state is not `Send`), so the cluster takes
/// a factory, not instances.
pub trait Backend {
    /// Tokenize, validate, and enqueue a request; returns its ticket.
    /// The [`SubmitSpec`] carries the client-visible trace id (keyed
    /// onto the backend's flight recorder) and the optional SLO tier
    /// (EDF ordering, tier-aware preemption, deadline misses accounted
    /// into the `serve.slo_*` metrics) alongside the request itself —
    /// one typed entrypoint instead of the old
    /// `submit`/`submit_traced`/`assign_slo` call sequence.
    fn submit(&mut self, spec: &SubmitSpec) -> Result<u64>;
    /// Advance one scheduler tick; returns finished requests.
    fn tick(&mut self) -> Result<Vec<CompletedRequest>>;
    /// Nothing running or queued.
    fn is_idle(&self) -> bool;
    /// Chains waiting for a lane.
    fn queue_depth(&self) -> usize;
    /// Lanes currently running a chain.
    fn active_lanes(&self) -> usize;
    /// Whole queued requests eligible for steal handoff.
    fn stealable_requests(&self) -> usize;
    /// Remove up to `max` fresh queued requests (releasing any prefix
    /// references they held); returns their tickets.
    fn drain_queued(&mut self, max: usize) -> Vec<u64>;
    /// Pool payload dtype name, echoed in responses.
    fn kv_dtype_name(&self) -> &'static str;
    /// Budget-allocator name, echoed in responses and stats (the
    /// per-replica plan summaries live in the `kv.plan_*` gauges of
    /// `metrics_report`).
    fn allocator_name(&self) -> &'static str;
    /// Metrics snapshot for the stats endpoint.
    fn metrics_report(&self) -> String;
    /// Structured metrics snapshot
    /// ([`Registry::to_json`](crate::metrics::Registry::to_json)) —
    /// the router merges these into one Prometheus exposition.
    fn metrics_json(&self) -> Json;
    /// Full-model KV bytes read per attended token (prices `reads`
    /// into `kv_read_bytes` on responses).
    fn kv_bytes_per_token(&self) -> f64;
    /// Whether the backend's flight recorder is enabled.
    fn tracing_enabled(&self) -> bool;
    /// Retained flight-recorder events, oldest first.
    fn trace_events(&self) -> Vec<Stamped>;
    /// Retained events of one client-visible request id.
    fn trace_events_for(&self, req: u64) -> Vec<Stamped>;
}

/// The real engine behind the [`Backend`] trait: an [`Engine`] plus
/// its dynamic-admission [`Session`].
pub struct EngineBackend {
    engine: Engine,
    session: Session,
}

impl EngineBackend {
    /// Open artifacts and start a serving session (one per replica).
    pub fn new(cfg: EngineConfig) -> Result<Self> {
        let engine = Engine::new(cfg)?;
        let session = engine.begin_session();
        Ok(Self { engine, session })
    }
}

impl Backend for EngineBackend {
    fn submit(&mut self, spec: &SubmitSpec) -> Result<u64> {
        self.engine.submit_spec(&mut self.session, spec)
    }
    fn tick(&mut self) -> Result<Vec<CompletedRequest>> {
        self.engine.tick(&mut self.session)
    }
    fn is_idle(&self) -> bool {
        self.engine.is_idle(&self.session)
    }
    fn queue_depth(&self) -> usize {
        self.session.queue_depth()
    }
    fn active_lanes(&self) -> usize {
        self.session.active_lanes()
    }
    fn stealable_requests(&self) -> usize {
        self.session.stealable_requests()
    }
    fn drain_queued(&mut self, max: usize) -> Vec<u64> {
        self.engine.drain_queued(&mut self.session, max)
    }
    fn kv_dtype_name(&self) -> &'static str {
        self.engine.cfg.kv_dtype.name()
    }
    fn allocator_name(&self) -> &'static str {
        self.engine.cfg.allocator.name()
    }
    fn metrics_report(&self) -> String {
        self.engine.metrics.report()
    }
    fn metrics_json(&self) -> Json {
        self.engine.metrics.to_json()
    }
    fn kv_bytes_per_token(&self) -> f64 {
        self.engine.kv_bytes_per_token()
    }
    fn tracing_enabled(&self) -> bool {
        self.engine.tracer().enabled()
    }
    fn trace_events(&self) -> Vec<Stamped> {
        self.engine.tracer().events()
    }
    fn trace_events_for(&self, req: u64) -> Vec<Stamped> {
        self.engine.trace_events_for(req)
    }
}

impl Backend for SimEngine {
    fn submit(&mut self, spec: &SubmitSpec) -> Result<u64> {
        SimEngine::submit_spec(self, spec)
    }
    fn tick(&mut self) -> Result<Vec<CompletedRequest>> {
        SimEngine::tick(self)
    }
    fn is_idle(&self) -> bool {
        SimEngine::is_idle(self)
    }
    fn queue_depth(&self) -> usize {
        SimEngine::queue_depth(self)
    }
    fn active_lanes(&self) -> usize {
        SimEngine::active_lanes(self)
    }
    fn stealable_requests(&self) -> usize {
        SimEngine::stealable_requests(self)
    }
    fn drain_queued(&mut self, max: usize) -> Vec<u64> {
        SimEngine::drain_queued(self, max)
    }
    fn kv_dtype_name(&self) -> &'static str {
        self.cfg.kv_dtype.name()
    }
    fn allocator_name(&self) -> &'static str {
        self.cfg.allocator.name()
    }
    fn metrics_report(&self) -> String {
        self.metrics.report()
    }
    fn metrics_json(&self) -> Json {
        self.metrics.to_json()
    }
    fn kv_bytes_per_token(&self) -> f64 {
        SimEngine::kv_bytes_per_token(self)
    }
    fn tracing_enabled(&self) -> bool {
        self.tracer().enabled()
    }
    fn trace_events(&self) -> Vec<Stamped> {
        self.tracer().events()
    }
    fn trace_events_for(&self, req: u64) -> Vec<Stamped> {
        SimEngine::trace_events_for(self, req)
    }
}

/// Router-thread inbox.
enum RouterMsg {
    /// A client request to route and forward.
    Client(ServeRequest, mpsc::Sender<String>),
    /// A stolen (drained) request handed back for re-routing; `to` is
    /// the idle replica the steal plan targeted (echoed by the donor).
    Requeue {
        to: usize,
        req: ServeRequest,
        reply: mpsc::Sender<String>,
    },
    /// A replica's occupancy snapshot.
    Status { replica: usize, load: ReplicaLoad },
    /// A replica died (engine construction or tick error).
    Dead { replica: usize },
    /// Aggregate stats request.
    Stats(mpsc::Sender<String>),
    /// Per-request flight-recorder query (`{"cmd": "trace"}`): merged
    /// across replicas plus the router's own routing decisions.
    Trace(u64, mpsc::Sender<String>),
    Shutdown,
}

/// Replica-thread inbox.
enum ReplicaMsg {
    Request(ServeRequest, mpsc::Sender<String>),
    /// Drain up to `max` queued requests and requeue them via the
    /// router, targeted at idle replica `to`.
    Steal { max: usize, to: usize },
    /// Per-replica stats block.
    Stats(mpsc::Sender<String>),
    /// Per-request flight-recorder slice.
    Trace(u64, mpsc::Sender<String>),
    /// Full observability dump (all trace events + structured metrics)
    /// for the shutdown `--trace-out` / `--prom-out` exports.
    Dump(mpsc::Sender<String>),
    Shutdown,
}

/// A running engine cluster. Created by [`Cluster::start`]; clients
/// enter through [`Cluster::call`] (tests/benches) or the TCP
/// acceptor ([`serve_cluster`]).
pub struct Cluster {
    tx: mpsc::Sender<RouterMsg>,
    router_thread: Option<JoinHandle<()>>,
    replica_threads: Vec<JoinHandle<()>>,
}

impl Cluster {
    /// Spawn `ccfg.replicas` replica threads (each building its own
    /// backend via `factory`, which runs *inside* the thread) plus the
    /// router thread.
    pub fn start<B, F>(ccfg: ClusterConfig, factory: F) -> Self
    where
        B: Backend + 'static,
        F: Fn(usize) -> Result<B> + Clone + Send + 'static,
    {
        Self::start_with(ccfg, 0, ServeOpts::default(), factory)
    }

    /// [`start`](Self::start) with a router-side flight recorder of
    /// `trace_events` capacity (0 = disabled) and observability dumps
    /// written when the cluster shuts down.
    pub fn start_with<B, F>(
        ccfg: ClusterConfig,
        trace_events: usize,
        opts: ServeOpts,
        factory: F,
    ) -> Self
    where
        B: Backend + 'static,
        F: Fn(usize) -> Result<B> + Clone + Send + 'static,
    {
        let n = ccfg.replicas.max(1);
        let (rtx, rrx) = mpsc::channel::<RouterMsg>();
        let mut replica_txs = Vec::with_capacity(n);
        let mut replica_threads = Vec::with_capacity(n);
        for i in 0..n {
            let (tx, rx) = mpsc::channel::<ReplicaMsg>();
            replica_txs.push(tx);
            let router = rtx.clone();
            let factory = factory.clone();
            replica_threads.push(std::thread::spawn(move || {
                match factory(i) {
                    Ok(backend) => replica_loop(i, backend, rx, router),
                    Err(e) => {
                        crate::warn_log!("replica {i} failed to start: {e:#}");
                        let _ = router.send(RouterMsg::Dead { replica: i });
                        // answer anything already routed here with errors
                        for msg in rx.iter() {
                            match msg {
                                ReplicaMsg::Request(req, reply) => {
                                    let resp = ServeResponse::error(
                                        req.id,
                                        &format!("replica {i} unavailable: {e:#}"),
                                    );
                                    let _ = reply.send(render_response(&resp));
                                }
                                ReplicaMsg::Stats(reply) => {
                                    let _ = reply.send(
                                        Json::obj()
                                            .set("replica", i as u64)
                                            .set("dead", true)
                                            .to_string(),
                                    );
                                }
                                ReplicaMsg::Trace(_, reply) => {
                                    let _ = reply.send(
                                        Json::obj()
                                            .set("replica", i as u64)
                                            .set("dead", true)
                                            .set("tracing", false)
                                            .set("events", Json::Arr(Vec::new()))
                                            .to_string(),
                                    );
                                }
                                ReplicaMsg::Dump(reply) => {
                                    let _ = reply.send(
                                        Json::obj()
                                            .set("replica", i as u64)
                                            .set("dead", true)
                                            .to_string(),
                                    );
                                }
                                ReplicaMsg::Steal { .. } => {}
                                ReplicaMsg::Shutdown => break,
                            }
                        }
                    }
                }
            }));
        }
        let router = Router::new(n, ccfg.routing);
        let tracer = Tracer::ring(trace_events);
        let router_thread = std::thread::spawn(move || {
            router_loop(router, ccfg, replica_txs, rrx, tracer, opts);
        });
        Self {
            tx: rtx,
            router_thread: Some(router_thread),
            replica_threads,
        }
    }

    /// Submit one request; the reply channel yields the rendered
    /// response line.
    pub fn call(&self, req: ServeRequest) -> mpsc::Receiver<String> {
        let (rtx, rrx) = mpsc::channel();
        let _ = self.tx.send(RouterMsg::Client(req, rtx));
        rrx
    }

    /// Submit one request and block for its parsed response.
    pub fn call_blocking(&self, req: ServeRequest) -> Result<Json> {
        let line = self
            .call(req)
            .recv()
            .map_err(|_| anyhow!("cluster dropped the request"))?;
        Json::parse(&line)
    }

    /// Aggregate cluster stats (cluster.* metrics + per-replica
    /// blocks), parsed.
    pub fn stats(&self) -> Result<Json> {
        let (rtx, rrx) = mpsc::channel();
        let _ = self.tx.send(RouterMsg::Stats(rtx));
        let line = rrx
            .recv()
            .map_err(|_| anyhow!("cluster dropped the stats request"))?;
        Json::parse(&line)
    }

    /// Per-request flight-recorder events, merged across replicas and
    /// the router, parsed (the `{"cmd": "trace"}` payload).
    pub fn trace(&self, request_id: u64) -> Result<Json> {
        let (rtx, rrx) = mpsc::channel();
        let _ = self.tx.send(RouterMsg::Trace(request_id, rtx));
        let line = rrx
            .recv()
            .map_err(|_| anyhow!("cluster dropped the trace request"))?;
        Json::parse(&line)
    }

    /// Dispatch handle for the TCP acceptor.
    fn dispatch(&self) -> ClusterDispatch {
        ClusterDispatch(self.tx.clone())
    }

    /// Ask every thread to stop and join them.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(RouterMsg::Shutdown);
        self.join();
    }

    /// Block until the cluster stops (a shutdown command arrived).
    pub fn wait(mut self) {
        self.join();
    }

    fn join(&mut self) {
        if let Some(h) = self.router_thread.take() {
            let _ = h.join();
        }
        for h in self.replica_threads.drain(..) {
            let _ = h.join();
        }
    }
}

/// Acceptor → router bridge.
#[derive(Clone)]
struct ClusterDispatch(mpsc::Sender<RouterMsg>);

impl Dispatch for ClusterDispatch {
    fn request(&self, req: ServeRequest, reply: mpsc::Sender<String>) {
        let _ = self.0.send(RouterMsg::Client(req, reply));
    }
    fn stats(&self, reply: mpsc::Sender<String>) {
        let _ = self.0.send(RouterMsg::Stats(reply));
    }
    fn trace(&self, request_id: u64, reply: mpsc::Sender<String>) {
        let _ = self.0.send(RouterMsg::Trace(request_id, reply));
    }
    fn shutdown(&self) {
        let _ = self.0.send(RouterMsg::Shutdown);
    }
}

/// Serve the line-JSON protocol from an engine cluster until a
/// shutdown command arrives. Every replica loads the same
/// `EngineConfig` (its own executors, cache, and prefix index).
pub fn serve_cluster(cfg: EngineConfig, ccfg: ClusterConfig, addr: &str) -> Result<()> {
    serve_cluster_with(cfg, ccfg, addr, ServeOpts::default())
}

/// [`serve_cluster`] with observability dumps written at shutdown: the
/// trace file groups events per replica (pid = replica id, the router
/// as the extra last pid) and the Prometheus file is a merged
/// exposition labelled `replica="i"` / `replica="router"`.
pub fn serve_cluster_with(
    cfg: EngineConfig,
    ccfg: ClusterConfig,
    addr: &str,
    opts: ServeOpts,
) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    crate::info!(
        "serving on {addr} with {} replicas ({} routing)",
        ccfg.replicas,
        ccfg.routing.name()
    );
    let trace_events = cfg.trace_events;
    let cluster = Cluster::start_with(ccfg, trace_events, opts, move |_i| {
        EngineBackend::new(cfg.clone())
    });
    let acceptor = super::spawn_acceptor(listener, cluster.dispatch());
    cluster.wait();
    drop(acceptor);
    Ok(())
}

// ----------------------------------------------------------------------
// Replica thread
// ----------------------------------------------------------------------

fn replica_loop<B: Backend>(
    replica: usize,
    mut backend: B,
    rx: mpsc::Receiver<ReplicaMsg>,
    router: mpsc::Sender<RouterMsg>,
) {
    let mut inflight: HashMap<u64, (ServeRequest, mpsc::Sender<String>)> = HashMap::new();
    let mut last_load: Option<ReplicaLoad> = None;
    let mut shutdown = false;

    // occupancy snapshot; sent only when it changed (ticks are cheap
    // and frequent — unconditional sends would flood the router)
    macro_rules! send_status {
        () => {{
            let load = ReplicaLoad {
                queue_depth: backend.queue_depth(),
                active_lanes: backend.active_lanes(),
                inflight: inflight.len(),
                stealable: backend.stealable_requests(),
            };
            if last_load != Some(load) {
                last_load = Some(load);
                let _ = router.send(RouterMsg::Status { replica, load });
            }
        }};
    }

    while !shutdown {
        if backend.is_idle() && inflight.is_empty() {
            send_status!(); // idle: make the replica a steal target
            match rx.recv() {
                Ok(msg) => {
                    if handle_replica_msg(
                        replica, &mut backend, &mut inflight, &router, msg,
                    ) {
                        break;
                    }
                }
                Err(_) => break,
            }
        }
        loop {
            match rx.try_recv() {
                Ok(msg) => {
                    if handle_replica_msg(
                        replica, &mut backend, &mut inflight, &router, msg,
                    ) {
                        shutdown = true;
                        break;
                    }
                }
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    shutdown = true;
                    break;
                }
            }
        }
        if shutdown {
            break;
        }
        match backend.tick() {
            Ok(completed) => {
                for done in completed {
                    if let Some((req, reply)) = inflight.remove(&done.ticket) {
                        let resp = response_from(
                            &req,
                            &done,
                            backend.kv_dtype_name(),
                            backend.allocator_name(),
                            replica,
                            backend.kv_bytes_per_token(),
                        );
                        let _ = reply.send(render_response(&resp));
                    }
                }
            }
            Err(e) => {
                // a replica failure downs this replica, not the cluster
                crate::warn_log!("replica {replica} engine error: {e:#}");
                for (_, (req, reply)) in inflight.drain() {
                    let resp = ServeResponse::error(req.id, &format!("{e:#}"));
                    let _ = reply.send(render_response(&resp));
                }
                let _ = router.send(RouterMsg::Dead { replica });
                return;
            }
        }
        send_status!();
    }
    // shutdown: in-flight requests are answered, not dropped
    for (_, (req, reply)) in inflight.drain() {
        let resp = ServeResponse::error(req.id, "server shutting down");
        let _ = reply.send(render_response(&resp));
    }
}

/// Handle one replica-inbox message; returns true on shutdown.
fn handle_replica_msg<B: Backend>(
    replica: usize,
    backend: &mut B,
    inflight: &mut HashMap<u64, (ServeRequest, mpsc::Sender<String>)>,
    router: &mpsc::Sender<RouterMsg>,
    msg: ReplicaMsg,
) -> bool {
    match msg {
        ReplicaMsg::Request(req, reply) => {
            match backend.submit(&req.submit_spec()) {
                Ok(ticket) => {
                    inflight.insert(ticket, (req, reply));
                }
                Err(e) => {
                    let resp = ServeResponse::error(req.id, &format!("{e:#}"));
                    let _ = reply.send(render_response(&resp));
                }
            }
            false
        }
        ReplicaMsg::Steal { max, to } => {
            for ticket in backend.drain_queued(max) {
                if let Some((req, reply)) = inflight.remove(&ticket) {
                    let _ = router.send(RouterMsg::Requeue { to, req, reply });
                }
            }
            false
        }
        ReplicaMsg::Stats(reply) => {
            let _ = reply.send(
                Json::obj()
                    .set("replica", replica as u64)
                    .set("active_lanes", backend.active_lanes())
                    .set("queue_depth", backend.queue_depth())
                    .set("inflight", inflight.len())
                    .set("kv_dtype", backend.kv_dtype_name())
                    .set("allocator", backend.allocator_name())
                    .set("metrics", backend.metrics_report())
                    .set("metrics_json", backend.metrics_json())
                    .to_string(),
            );
            false
        }
        ReplicaMsg::Trace(rid, reply) => {
            let events: Vec<Json> = backend
                .trace_events_for(rid)
                .iter()
                .map(Stamped::to_json)
                .collect();
            let _ = reply.send(
                Json::obj()
                    .set("replica", replica as u64)
                    .set("tracing", backend.tracing_enabled())
                    .set("events", Json::Arr(events))
                    .to_string(),
            );
            false
        }
        ReplicaMsg::Dump(reply) => {
            let events: Vec<Json> =
                backend.trace_events().iter().map(Stamped::to_json).collect();
            let _ = reply.send(
                Json::obj()
                    .set("replica", replica as u64)
                    .set("events", Json::Arr(events))
                    .set("metrics_json", backend.metrics_json())
                    .to_string(),
            );
            false
        }
        ReplicaMsg::Shutdown => true,
    }
}

// ----------------------------------------------------------------------
// Router thread
// ----------------------------------------------------------------------

fn router_loop(
    mut router: Router,
    ccfg: ClusterConfig,
    replicas: Vec<mpsc::Sender<ReplicaMsg>>,
    rx: mpsc::Receiver<RouterMsg>,
    mut tracer: Tracer,
    opts: ServeOpts,
) {
    let n = replicas.len();
    let mut loads = vec![ReplicaLoad::default(); n];
    let mut dead = vec![false; n];
    let mut metrics = Registry::default();
    metrics.gauge("cluster.replicas").set(n as f64);
    // the router's trace clock: wall ns from its own start anchor
    let epoch = Instant::now();

    // deliver a request to `replica`, bumping its load optimistically
    // so routing between status updates sees the pressure
    let deliver = |replica: usize,
                   req: ServeRequest,
                   reply: mpsc::Sender<String>,
                   loads: &mut [ReplicaLoad],
                   metrics: &mut Registry| {
        loads[replica].inflight += 1;
        loads[replica].queue_depth += req.width.max(1);
        loads[replica].stealable += 1;
        metrics.counter(&format!("cluster.routed.{replica}")).inc();
        let _ = replicas[replica].send(ReplicaMsg::Request(req, reply));
    };

    while let Ok(msg) = rx.recv() {
        match msg {
            RouterMsg::Client(req, reply) => {
                metrics.counter("cluster.requests").inc();
                let d = router.route(&req.prompt, &loads);
                // a dead replica cannot serve; degrade to any live one
                let target = if dead[d.replica] {
                    match first_alive(&dead) {
                        Some(t) => t,
                        None => {
                            let resp =
                                ServeResponse::error(req.id, "all replicas down");
                            let _ = reply.send(render_response(&resp));
                            continue;
                        }
                    }
                } else {
                    d.replica
                };
                if d.shadow_hit > 0 && target == d.replica {
                    metrics.counter("cluster.affinity_routed").inc();
                    metrics
                        .counter("cluster.shadow_hit_bytes")
                        .add(d.shadow_hit as f64);
                }
                if tracer.enabled() {
                    let ts = epoch.elapsed().as_nanos() as u64;
                    let ev = if target == d.replica {
                        d.trace_event(req.id)
                    } else {
                        // dead-replica fallback: the shadow hit did not land
                        TraceEvent::Route {
                            req: req.id,
                            replica: target,
                            shadow_hit: 0,
                        }
                    };
                    tracer.emit(ts, ev);
                }
                router.note_routed(target, &req.prompt);
                deliver(target, req, reply, &mut loads, &mut metrics);
            }
            RouterMsg::Requeue { to, req, reply } => {
                metrics.counter("cluster.stolen_requests").inc();
                // land on the planned idle replica; affinity migrates
                // with the request (note_routed on the target). If the
                // planned target died meanwhile, fall back to routing —
                // and never deliver to a dead replica: a dropped send
                // would leave the client waiting forever.
                let mut target = if dead[to] {
                    router.route(&req.prompt, &loads).replica
                } else {
                    to
                };
                if dead[target] {
                    match first_alive(&dead) {
                        Some(t) => target = t,
                        None => {
                            let resp =
                                ServeResponse::error(req.id, "all replicas down");
                            let _ = reply.send(render_response(&resp));
                            continue;
                        }
                    }
                }
                if tracer.enabled() {
                    let ts = epoch.elapsed().as_nanos() as u64;
                    tracer.emit(
                        ts,
                        TraceEvent::Route {
                            req: req.id,
                            replica: target,
                            shadow_hit: 0,
                        },
                    );
                }
                router.note_routed(target, &req.prompt);
                deliver(target, req, reply, &mut loads, &mut metrics);
            }
            RouterMsg::Status { replica, load } => {
                loads[replica] = load;
                metrics
                    .gauge("cluster.queue_depth")
                    .set(loads.iter().map(|l| l.queue_depth).sum::<usize>() as f64);
                metrics
                    .gauge("cluster.active_lanes")
                    .set(loads.iter().map(|l| l.active_lanes).sum::<usize>() as f64);
                metrics
                    .gauge("cluster.inflight")
                    .set(loads.iter().map(|l| l.inflight).sum::<usize>() as f64);
                if ccfg.steal {
                    // dead replicas must never look idle to the planner
                    let mut view = loads.clone();
                    mask_dead(&mut view, &dead);
                    if let Some(plan) = router.steal_plan(&view) {
                        metrics.counter("cluster.steal_ops").inc();
                        if tracer.enabled() {
                            let ts = epoch.elapsed().as_nanos() as u64;
                            tracer.emit(ts, plan.trace_event());
                        }
                        // optimistic: don't re-plan this donor until a
                        // fresh (post-drain) status arrives; a spurious
                        // duplicate steal is a harmless no-op drain
                        loads[plan.from].stealable = 0;
                        let _ = replicas[plan.from].send(ReplicaMsg::Steal {
                            max: plan.max_requests,
                            to: plan.to,
                        });
                    }
                }
            }
            RouterMsg::Dead { replica } => {
                dead[replica] = true;
                metrics.counter("cluster.replicas_dead").inc();
                if tracer.enabled() {
                    let ts = epoch.elapsed().as_nanos() as u64;
                    tracer.emit(ts, TraceEvent::ReplicaDead { replica });
                }
            }
            RouterMsg::Stats(reply) => {
                let mut blocks: Vec<Json> = Vec::new();
                for (i, tx) in replicas.iter().enumerate() {
                    if dead[i] {
                        blocks.push(
                            Json::obj().set("replica", i as u64).set("dead", true),
                        );
                        continue;
                    }
                    let (rtx, rrx) = mpsc::channel();
                    if tx.send(ReplicaMsg::Stats(rtx)).is_err() {
                        continue;
                    }
                    if let Ok(s) = rrx.recv_timeout(Duration::from_secs(5)) {
                        if let Ok(j) = Json::parse(&s) {
                            blocks.push(j);
                        }
                    }
                }
                // one valid merged exposition: replica-labelled samples
                // from every live block plus the router's cluster.*
                let mut prom_blocks: Vec<(String, Json)> = Vec::new();
                for b in &blocks {
                    let (Some(r), Some(mj)) = (
                        b.get("replica").and_then(Json::as_usize),
                        b.get("metrics_json"),
                    ) else {
                        continue;
                    };
                    prom_blocks.push((r.to_string(), mj.clone()));
                }
                prom_blocks.push(("router".to_string(), metrics.to_json()));
                let _ = reply.send(
                    Json::obj()
                        .set("replicas", n as u64)
                        .set("routing", ccfg.routing.name())
                        .set("cluster_metrics", metrics.report())
                        .set("cluster_metrics_json", metrics.to_json())
                        .set("prometheus", prometheus_merge("replica", &prom_blocks))
                        .set("replica_stats", Json::Arr(blocks))
                        .to_string(),
                );
            }
            RouterMsg::Trace(rid, reply) => {
                let mut tracing = tracer.enabled();
                let mut events: Vec<Json> = Vec::new();
                for (i, tx) in replicas.iter().enumerate() {
                    if dead[i] {
                        continue;
                    }
                    let (rtx, rrx) = mpsc::channel();
                    if tx.send(ReplicaMsg::Trace(rid, rtx)).is_err() {
                        continue;
                    }
                    let Ok(s) = rrx.recv_timeout(Duration::from_secs(5)) else {
                        continue;
                    };
                    let Ok(j) = Json::parse(&s) else { continue };
                    if j.get("tracing").and_then(Json::as_bool) == Some(true) {
                        tracing = true;
                    }
                    if let Some(arr) = j.get("events").and_then(Json::as_arr) {
                        events.extend(arr.iter().cloned());
                    }
                }
                events.extend(tracer.events_for(rid).iter().map(Stamped::to_json));
                let _ = reply.send(
                    Json::obj()
                        .set("request_id", rid)
                        .set("tracing", tracing)
                        .set("events", Json::Arr(events))
                        .to_string(),
                );
            }
            RouterMsg::Shutdown => {
                write_cluster_dumps(&opts, &tracer, &metrics, &replicas, &dead);
                break;
            }
        }
    }
    for tx in &replicas {
        let _ = tx.send(ReplicaMsg::Shutdown);
    }
}

/// Collect every live replica's flight recorder + metrics snapshot and
/// write the `--trace-out` (pid = replica id; the router as the extra
/// last pid) and `--prom-out` (merged exposition) files.
fn write_cluster_dumps(
    opts: &ServeOpts,
    tracer: &Tracer,
    metrics: &Registry,
    replicas: &[mpsc::Sender<ReplicaMsg>],
    dead: &[bool],
) {
    if opts.trace_out.is_none() && opts.prom_out.is_none() {
        return;
    }
    let mut groups: Vec<(usize, Vec<Stamped>)> = Vec::new();
    let mut prom_blocks: Vec<(String, Json)> = Vec::new();
    for (i, tx) in replicas.iter().enumerate() {
        if dead[i] {
            continue;
        }
        let (rtx, rrx) = mpsc::channel();
        if tx.send(ReplicaMsg::Dump(rtx)).is_err() {
            continue;
        }
        let Ok(s) = rrx.recv_timeout(Duration::from_secs(5)) else {
            continue;
        };
        let Ok(j) = Json::parse(&s) else { continue };
        if let Some(arr) = j.get("events").and_then(Json::as_arr) {
            groups.push((i, arr.iter().filter_map(Stamped::from_json).collect()));
        }
        if let Some(mj) = j.get("metrics_json") {
            prom_blocks.push((i.to_string(), mj.clone()));
        }
    }
    groups.push((replicas.len(), tracer.events()));
    write_trace_dump(&opts.trace_out, &groups);
    if let Some(path) = &opts.prom_out {
        prom_blocks.push(("router".to_string(), metrics.to_json()));
        match std::fs::write(path, prometheus_merge("replica", &prom_blocks)) {
            Ok(()) => crate::info!("wrote Prometheus exposition to {}", path.display()),
            Err(e) => crate::warn_log!("failed to write {}: {e}", path.display()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RoutingPolicy;
    use crate::engine::SimEngineConfig;

    fn sreq(id: u64, prompt: &str, seed: u64) -> ServeRequest {
        ServeRequest {
            id,
            prompt: prompt.into(),
            width: 1,
            max_len: 96,
            temperature: 0.7,
            seed,
            slo: None,
        }
    }

    #[test]
    fn cluster_serves_and_shuts_down() {
        let ccfg = ClusterConfig {
            replicas: 2,
            routing: RoutingPolicy::LeastLoaded,
            steal: true,
        };
        let cluster =
            Cluster::start(ccfg, |_| Ok(SimEngine::new(SimEngineConfig::default())));
        for i in 0..6u64 {
            let j = cluster
                .call_blocking(sreq(i, "Q:1+2=?|T:", i))
                .expect("response");
            assert_eq!(j.get("id").unwrap().as_usize(), Some(i as usize));
            assert!(j.get("error").is_none(), "unexpected error: {j:?}");
            assert!(j.get("replica_id").unwrap().as_usize().unwrap() < 2);
        }
        let stats = cluster.stats().expect("stats");
        assert_eq!(stats.get("replicas").unwrap().as_usize(), Some(2));
        let m = stats.get("cluster_metrics").unwrap().as_str().unwrap();
        assert!(m.contains("cluster.requests"));
        cluster.shutdown();
    }

    #[test]
    fn traced_cluster_prices_reads_and_merges_trace_events() {
        let ccfg = ClusterConfig {
            replicas: 2,
            routing: RoutingPolicy::LeastLoaded,
            steal: true,
        };
        let cluster = Cluster::start_with(ccfg, 4096, ServeOpts::default(), |_| {
            Ok(SimEngine::new(SimEngineConfig {
                trace_events: 4096,
                ..Default::default()
            }))
        });
        let j = cluster
            .call_blocking(sreq(71, "Q:1+2=?|T:", 3))
            .expect("response");
        let reads = j.get("reads").unwrap().as_f64().unwrap();
        let bytes = j.get("kv_read_bytes").unwrap().as_f64().unwrap();
        assert!(reads > 0.0 && bytes > reads, "bytes price tokens: {bytes}");
        // the trace view merges the serving replica's lifecycle events
        // with the router's route decision
        let t = cluster.trace(71).expect("trace");
        assert_eq!(t.get("tracing").unwrap().as_bool(), Some(true));
        let names: Vec<&str> = t
            .get("events")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|e| e.get("event").unwrap().as_str().unwrap())
            .collect();
        for expect in ["submit", "admit", "first_token", "finish", "route"] {
            assert!(names.contains(&expect), "missing {expect} in {names:?}");
        }
        // stats carries one merged exposition (single TYPE line per
        // family even with two replicas reporting the same metrics)
        let stats = cluster.stats().expect("stats");
        let prom = stats.get("prometheus").unwrap().as_str().unwrap();
        assert_eq!(prom.matches("# TYPE serve_requests counter").count(), 1);
        assert!(prom.contains("serve_requests{replica=\""));
        assert!(prom.contains("cluster_requests{replica=\"router\"}"));
        cluster.shutdown();
    }

    #[test]
    fn oversized_request_gets_an_error_reply() {
        let ccfg = ClusterConfig {
            replicas: 1,
            ..Default::default()
        };
        let cluster =
            Cluster::start(ccfg, |_| Ok(SimEngine::new(SimEngineConfig::default())));
        let mut req = sreq(9, "fine", 0);
        req.max_len = 100_000; // exceeds slot capacity
        let j = cluster.call_blocking(req).expect("reply");
        assert!(j.get("error").is_some());
        cluster.shutdown();
    }

    #[test]
    fn dead_replica_factory_degrades_to_errors_not_hangs() {
        let ccfg = ClusterConfig {
            replicas: 1,
            ..Default::default()
        };
        let cluster = Cluster::start(ccfg, |_| -> Result<SimEngine> {
            Err(anyhow!("no artifacts"))
        });
        let j = cluster.call_blocking(sreq(1, "hi", 0)).expect("reply");
        assert!(j.get("error").is_some());
        cluster.shutdown();
    }
}
