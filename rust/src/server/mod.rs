//! TCP line-JSON serving front end with dynamic admission.
//!
//! Protocol: one JSON object per line.
//!
//! Request:  {"id": 1, "prompt": "Q:1+2=?\nT:", "width": 4,
//!            "max_len": 160, "temperature": 0.7}
//! Response: {"id": 1, "texts": [...], "answer": "3",
//!            "reads": 1234.5, "peak_tokens": 88.0, "latency_ms": 42.1,
//!            "queue_ms": 1.3, "ttft_ms": 9.8, "tokens_per_s": 210.0}
//! Control:  {"cmd": "stats"} → metrics dump (human `metrics` text,
//!           structured `metrics_json`, Prometheus `prometheus` text);
//!           {"cmd": "trace", "request_id": N} → flight-recorder
//!           events for one request; {"cmd": "shutdown"}.
//!
//! Networking runs on std threads: an acceptor thread per listener and
//! one engine thread owning the (non-Send) PJRT state; requests flow
//! through mpsc channels (the offline environment has no tokio).
//!
//! The engine thread runs a continuous-batching loop over a single
//! [`Session`](crate::engine::Session): every incoming request is
//! *submitted* into the shared scheduler immediately (not queued behind
//! the previous request's whole batch), chains from different requests
//! share the executor's lanes, and each request is answered the moment
//! its last chain retires. Requests from concurrent connections
//! therefore overlap arbitrarily; responses carry the echoed `id` plus
//! queueing/TTFT timings so clients can attribute latency.
//!
//! With `--replicas N` (N > 1) the same protocol is served by an
//! **engine cluster** instead: N independent engine replicas behind a
//! prefix-aware router with a work-stealing fallback — see [`cluster`]
//! and [`router`]. Responses then carry the serving `replica_id`, and
//! `{"cmd": "stats"}` reports `cluster.*` metrics plus per-replica
//! `serve.*` blocks.

pub mod cluster;
pub mod protocol;
pub mod router;

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::mpsc;

use anyhow::Result;

use crate::config::EngineConfig;
use crate::engine::{majority_vote, CompletedRequest, Engine, Session};
use crate::trace::{chrome_trace_json, Stamped};
use crate::util::Json;

pub use cluster::{serve_cluster, Backend, Cluster, EngineBackend};
pub use protocol::{
    parse_command, parse_request, render_line, render_response, Command, Response,
    ServeRequest, ServeResponse,
};
pub use router::{first_alive, mask_dead, ReplicaLoad, RouteDecision, Router, StealPlan};

enum Msg {
    Request(ServeRequest, mpsc::Sender<String>),
    Stats(mpsc::Sender<String>),
    Trace(u64, mpsc::Sender<String>),
    Shutdown,
}

/// Observability outputs written when the server shuts down (the
/// `--trace-out` / `--prom-out` CLI flags; see docs/OBSERVABILITY.md).
#[derive(Clone, Debug, Default)]
pub struct ServeOpts {
    /// Write a Chrome trace-event JSON dump (Perfetto-loadable) here.
    pub trace_out: Option<PathBuf>,
    /// Write a Prometheus text exposition dump here.
    pub prom_out: Option<PathBuf>,
}

/// How the client-facing acceptor hands parsed protocol events to a
/// serving back end. Implemented by the single-engine loop below and
/// by the cluster router ([`cluster`]), so both share one
/// line-JSON client handler.
pub(crate) trait Dispatch: Clone + Send + 'static {
    fn request(&self, req: ServeRequest, reply: mpsc::Sender<String>);
    fn stats(&self, reply: mpsc::Sender<String>);
    fn trace(&self, request_id: u64, reply: mpsc::Sender<String>);
    fn shutdown(&self);
}

/// Single-engine dispatch: everything funnels into the engine thread.
#[derive(Clone)]
struct EngineDispatch(mpsc::Sender<Msg>);

impl Dispatch for EngineDispatch {
    fn request(&self, req: ServeRequest, reply: mpsc::Sender<String>) {
        let _ = self.0.send(Msg::Request(req, reply));
    }
    fn stats(&self, reply: mpsc::Sender<String>) {
        let _ = self.0.send(Msg::Stats(reply));
    }
    fn trace(&self, request_id: u64, reply: mpsc::Sender<String>) {
        let _ = self.0.send(Msg::Trace(request_id, reply));
    }
    fn shutdown(&self) {
        let _ = self.0.send(Msg::Shutdown);
    }
}

/// A request admitted to the engine, waiting for its completion.
struct Inflight {
    req: ServeRequest,
    reply: mpsc::Sender<String>,
}

/// Run the server until a shutdown command arrives. Binds `addr`
/// (e.g. "127.0.0.1:7333").
pub fn serve(cfg: EngineConfig, addr: &str) -> Result<()> {
    serve_with(cfg, addr, ServeOpts::default())
}

/// [`serve`] with observability outputs dumped at shutdown.
pub fn serve_with(cfg: EngineConfig, addr: &str, opts: ServeOpts) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    crate::info!("serving on {addr}");
    let (tx, rx) = mpsc::channel::<Msg>();

    // acceptor thread: parses lines, forwards to the engine thread
    let acceptor = spawn_acceptor(listener, EngineDispatch(tx.clone()));

    // engine loop (owns the PJRT client; must stay on this thread)
    let mut engine = Engine::new(cfg)?;
    let mut session = engine.begin_session();
    let mut inflight: HashMap<u64, Inflight> = HashMap::new();
    let mut shutdown = false;
    while !shutdown {
        // intake: block while idle, drain without blocking while busy
        if engine.is_idle(&session) && inflight.is_empty() {
            match rx.recv() {
                Ok(msg) => {
                    if handle_msg(&mut engine, &mut session, &mut inflight, msg) {
                        break;
                    }
                }
                Err(_) => break,
            }
        }
        loop {
            match rx.try_recv() {
                Ok(msg) => {
                    if handle_msg(&mut engine, &mut session, &mut inflight, msg) {
                        shutdown = true;
                        break;
                    }
                }
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    shutdown = true;
                    break;
                }
            }
        }
        if shutdown {
            break;
        }
        // advance every in-flight request by one scheduler tick
        match engine.tick(&mut session) {
            Ok(completed) => {
                for done in completed {
                    if let Some(inf) = inflight.remove(&done.ticket) {
                        let resp = response_from(
                            &inf.req,
                            &done,
                            engine.cfg.kv_dtype.name(),
                            engine.cfg.allocator.name(),
                            0,
                            engine.kv_bytes_per_token(),
                        );
                        let _ = inf.reply.send(render_response(&resp));
                    }
                }
            }
            Err(e) => {
                // engine failure is fatal for the server, but every
                // waiting client gets an error response instead of EOF
                reply_all_errors(&mut inflight, &format!("{e:#}"));
                return Err(e);
            }
        }
    }
    // shutdown: requests still in flight are answered, not dropped
    reply_all_errors(&mut inflight, "server shutting down");
    write_observability_dumps(&opts, engine.tracer().events(), &engine.metrics);
    drop(acceptor);
    Ok(())
}

/// Dump the flight recorder (Perfetto JSON) and a Prometheus text
/// exposition to the paths in `opts`, if any. Failures are logged, not
/// fatal — the serving work already succeeded. Shared with the cluster
/// shutdown path, which passes a merged multi-replica event list.
pub(crate) fn write_observability_dumps(
    opts: &ServeOpts,
    trace_groups: Vec<Stamped>,
    metrics: &crate::metrics::Registry,
) {
    write_trace_dump(&opts.trace_out, &[(0, trace_groups)]);
    if let Some(path) = &opts.prom_out {
        match std::fs::write(path, metrics.prometheus(None)) {
            Ok(()) => crate::info!("wrote Prometheus exposition to {}", path.display()),
            Err(e) => crate::warn_log!("failed to write {}: {e}", path.display()),
        }
    }
}

/// Dump Chrome trace-event JSON for per-replica event groups.
pub(crate) fn write_trace_dump(out: &Option<PathBuf>, groups: &[(usize, Vec<Stamped>)]) {
    let Some(path) = out else { return };
    match std::fs::write(path, chrome_trace_json(groups)) {
        Ok(()) => crate::info!("wrote trace-event dump to {}", path.display()),
        Err(e) => crate::warn_log!("failed to write {}: {e}", path.display()),
    }
}

/// Answer every in-flight request with an error payload (used on
/// shutdown and on fatal engine errors, so clients never see bare EOF).
fn reply_all_errors(inflight: &mut HashMap<u64, Inflight>, msg: &str) {
    for (_, inf) in inflight.drain() {
        let resp = ServeResponse::error(inf.req.id, msg);
        let _ = inf.reply.send(render_response(&resp));
    }
}

/// Handle one control/request message; returns true on shutdown.
fn handle_msg(
    engine: &mut Engine,
    session: &mut Session,
    inflight: &mut HashMap<u64, Inflight>,
    msg: Msg,
) -> bool {
    match msg {
        Msg::Request(req, reply) => {
            match engine.submit_spec(session, &req.submit_spec()) {
                Ok(ticket) => {
                    inflight.insert(ticket, Inflight { req, reply });
                }
                Err(e) => {
                    let resp = ServeResponse::error(req.id, &format!("{e:#}"));
                    let _ = reply.send(render_response(&resp));
                }
            }
            false
        }
        Msg::Stats(reply) => {
            let _ = reply.send(
                Json::obj()
                    .set("metrics", engine.metrics.report())
                    .set("metrics_json", engine.metrics.to_json())
                    .set("prometheus", engine.metrics.prometheus(None))
                    .set("active_lanes", session.active_lanes())
                    .set("queue_depth", session.queue_depth())
                    .set("kv_dtype", engine.cfg.kv_dtype.name())
                    .set("allocator", engine.cfg.allocator.name())
                    .set("trace_recorded", engine.tracer().recorded())
                    .set("trace_dropped", engine.tracer().dropped())
                    .to_string(),
            );
            false
        }
        Msg::Trace(rid, reply) => {
            let _ = reply.send(trace_response(
                rid,
                engine.tracer().enabled(),
                engine.trace_events_for(rid),
            ));
            false
        }
        Msg::Shutdown => true,
    }
}

/// Render the `{"cmd": "trace"}` reply for one request's events.
/// Shared with the cluster router, which merges per-replica slices.
pub(crate) fn trace_response(rid: u64, tracing: bool, events: Vec<Stamped>) -> String {
    Json::obj()
        .set("request_id", rid)
        .set("tracing", tracing)
        .set("events", Json::Arr(events.iter().map(Stamped::to_json).collect()))
        .to_string()
}

/// Build the response for a completed request. Shared with the
/// cluster's replica loops, which stamp their own `replica_id`.
pub(crate) fn response_from(
    req: &ServeRequest,
    done: &CompletedRequest,
    kv_dtype_name: &str,
    allocator_name: &str,
    replica_id: usize,
    kv_bytes_per_token: f64,
) -> ServeResponse {
    let res = &done.result;
    let texts: Vec<String> = res.chains.iter().map(|c| c.text.clone()).collect();
    let refs: Vec<&str> = texts.iter().map(|s| s.as_str()).collect();
    let vote = majority_vote(&refs);
    let prefix_hit_tokens: usize = res
        .chains
        .iter()
        .map(|c| c.stats.prefix_hit_tokens)
        .sum();
    ServeResponse {
        id: req.id,
        texts,
        answer: vote.answer,
        reads: res.total_reads(),
        kv_read_bytes: res.total_reads() * kv_bytes_per_token,
        peak_tokens: res.total_peak_tokens(),
        latency_ms: 0.0,
        queue_ms: 0.0,
        ttft_ms: 0.0,
        tokens_per_s: 0.0,
        prefix_hit_tokens: prefix_hit_tokens as f64,
        kv_dtype: kv_dtype_name.to_string(),
        allocator: allocator_name.to_string(),
        replica_id,
        error: None,
    }
    .with_timing(&done.timing)
}

/// Spawn the accept loop: one thread per client, each translating
/// line-JSON into `Dispatch` calls.
pub(crate) fn spawn_acceptor<D: Dispatch>(
    listener: TcpListener,
    dispatch: D,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        for stream in listener.incoming() {
            let Ok(stream) = stream else { continue };
            let d = dispatch.clone();
            std::thread::spawn(move || {
                let _ = handle_client(stream, d);
            });
        }
    })
}

fn handle_client<D: Dispatch>(stream: TcpStream, dispatch: D) -> Result<()> {
    let peer = stream.peer_addr()?;
    crate::debug!("client {peer}");
    let reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let json = match Json::parse(&line) {
            Ok(j) => j,
            Err(e) => {
                let resp = Response::Error(format!("bad json: {e}"));
                writeln!(writer, "{}", render_line(&resp))?;
                continue;
            }
        };
        // one typed decode point: control verbs and generation
        // requests — including the SubmitSpec fields (slo, trace id)
        // — parse in protocol.rs, and every malformed line answers
        // with the same error shape.
        match parse_command(&json) {
            Ok(Command::Shutdown) => {
                dispatch.shutdown();
                writeln!(writer, "{}", render_line(&Response::Ok))?;
                return Ok(());
            }
            Ok(Command::Stats) => {
                let (rtx, rrx) = mpsc::channel();
                dispatch.stats(rtx);
                if let Ok(s) = rrx.recv() {
                    writeln!(writer, "{s}")?;
                }
            }
            Ok(Command::Trace { request_id }) => {
                let (rtx, rrx) = mpsc::channel();
                dispatch.trace(request_id, rtx);
                if let Ok(s) = rrx.recv() {
                    writeln!(writer, "{s}")?;
                }
            }
            Ok(Command::Submit(req)) => {
                let (rtx, rrx) = mpsc::channel();
                dispatch.request(req, rtx);
                if let Ok(s) = rrx.recv() {
                    writeln!(writer, "{s}")?;
                }
            }
            Err(e) => {
                let resp = Response::Error(format!("{e:#}"));
                writeln!(writer, "{}", render_line(&resp))?;
            }
        }
    }
    Ok(())
}

/// Minimal blocking client for tests, benches, and examples.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    /// Connect to a running server.
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        Ok(Self {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
        })
    }

    /// Send one JSON line and block for the one-line reply.
    pub fn call(&mut self, req: &Json) -> Result<Json> {
        writeln!(self.writer, "{}", req.to_string())?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Ok(Json::parse(&line)?)
    }

    /// Ask the server to shut down.
    pub fn shutdown(&mut self) -> Result<()> {
        writeln!(self.writer, "{}", Json::obj().set("cmd", "shutdown").to_string())?;
        Ok(())
    }
}
