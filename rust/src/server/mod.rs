//! TCP line-JSON serving front end.
//!
//! Protocol: one JSON object per line.
//!
//! Request:  {"id": 1, "prompt": "Q:1+2=?\nT:", "width": 4,
//!            "max_len": 160, "temperature": 0.7}
//! Response: {"id": 1, "texts": [...], "answer": "3",
//!            "reads": 1234.5, "peak_tokens": 88.0, "latency_ms": 42.1}
//! Control:  {"cmd": "stats"} → metrics dump; {"cmd": "shutdown"}.
//!
//! Networking runs on std threads: an acceptor thread per listener and
//! one engine thread owning the (non-Send) PJRT state; requests flow
//! through mpsc channels (the offline environment has no tokio).

pub mod protocol;

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;
use std::time::Instant;

use anyhow::Result;

use crate::config::EngineConfig;
use crate::engine::{majority_vote, Engine, GenRequest};
use crate::util::Json;

pub use protocol::{parse_request, render_response, ServeRequest, ServeResponse};

enum Msg {
    Request(ServeRequest, mpsc::Sender<String>),
    Stats(mpsc::Sender<String>),
    Shutdown,
}

/// Run the server until a shutdown command arrives. Binds `addr`
/// (e.g. "127.0.0.1:7333").
pub fn serve(cfg: EngineConfig, addr: &str) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    crate::info!("serving on {addr}");
    let (tx, rx) = mpsc::channel::<Msg>();

    // acceptor thread: parses lines, forwards to the engine thread
    let atx = tx.clone();
    let acceptor = std::thread::spawn(move || {
        for stream in listener.incoming() {
            let Ok(stream) = stream else { continue };
            let tx = atx.clone();
            std::thread::spawn(move || {
                let _ = handle_client(stream, tx);
            });
        }
    });

    // engine loop (owns the PJRT client; must stay on this thread)
    let mut engine = Engine::new(cfg)?;
    loop {
        match rx.recv() {
            Ok(Msg::Request(req, reply)) => {
                let t0 = Instant::now();
                let resp = match run_request(&mut engine, &req) {
                    Ok(mut r) => {
                        r.latency_ms = t0.elapsed().as_secs_f64() * 1e3;
                        r
                    }
                    Err(e) => ServeResponse::error(req.id, &format!("{e:#}")),
                };
                let _ = reply.send(render_response(&resp));
            }
            Ok(Msg::Stats(reply)) => {
                let _ = reply.send(
                    Json::obj()
                        .set("metrics", engine.metrics.report())
                        .to_string(),
                );
            }
            Ok(Msg::Shutdown) | Err(_) => break,
        }
    }
    drop(acceptor);
    Ok(())
}

fn run_request(engine: &mut Engine, req: &ServeRequest) -> Result<ServeResponse> {
    let (results, _) = engine.run(&[GenRequest {
        prompt: req.prompt.clone(),
        width: req.width,
        max_len: req.max_len,
        temperature: req.temperature,
        seed: req.seed,
    }])?;
    let res = &results[0];
    let texts: Vec<String> = res.chains.iter().map(|c| c.text.clone()).collect();
    let refs: Vec<&str> = texts.iter().map(|s| s.as_str()).collect();
    let vote = majority_vote(&refs);
    Ok(ServeResponse {
        id: req.id,
        texts,
        answer: vote.answer,
        reads: res.total_reads(),
        peak_tokens: res.total_peak_tokens(),
        latency_ms: 0.0,
        error: None,
    })
}

fn handle_client(stream: TcpStream, tx: mpsc::Sender<Msg>) -> Result<()> {
    let peer = stream.peer_addr()?;
    crate::debug!("client {peer}");
    let reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let json = match Json::parse(&line) {
            Ok(j) => j,
            Err(e) => {
                writeln!(
                    writer,
                    "{}",
                    Json::obj().set("error", format!("bad json: {e}")).to_string()
                )?;
                continue;
            }
        };
        if let Some(cmd) = json.get("cmd").and_then(Json::as_str) {
            match cmd {
                "shutdown" => {
                    let _ = tx.send(Msg::Shutdown);
                    writeln!(writer, "{}", Json::obj().set("ok", true).to_string())?;
                    return Ok(());
                }
                "stats" => {
                    let (rtx, rrx) = mpsc::channel();
                    tx.send(Msg::Stats(rtx)).ok();
                    if let Ok(s) = rrx.recv() {
                        writeln!(writer, "{s}")?;
                    }
                    continue;
                }
                other => {
                    writeln!(
                        writer,
                        "{}",
                        Json::obj()
                            .set("error", format!("unknown cmd '{other}'"))
                            .to_string()
                    )?;
                    continue;
                }
            }
        }
        match parse_request(&json) {
            Ok(req) => {
                let (rtx, rrx) = mpsc::channel();
                tx.send(Msg::Request(req, rtx)).ok();
                if let Ok(s) = rrx.recv() {
                    writeln!(writer, "{s}")?;
                }
            }
            Err(e) => {
                writeln!(
                    writer,
                    "{}",
                    Json::obj().set("error", format!("{e:#}")).to_string()
                )?;
            }
        }
    }
    Ok(())
}

/// Minimal blocking client for tests, benches, and examples.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        Ok(Self {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
        })
    }

    pub fn call(&mut self, req: &Json) -> Result<Json> {
        writeln!(self.writer, "{}", req.to_string())?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Ok(Json::parse(&line)?)
    }

    pub fn shutdown(&mut self) -> Result<()> {
        writeln!(self.writer, "{}", Json::obj().set("cmd", "shutdown").to_string())?;
        Ok(())
    }
}
