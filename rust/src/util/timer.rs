//! Timing helpers for benches and the perf pass.

use std::time::Instant;

/// Simple stopwatch accumulating named segments.
#[derive(Debug, Default)]
pub struct Stopwatch {
    segments: Vec<(String, f64)>,
    current: Option<(String, Instant)>,
}

impl Stopwatch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Start (or switch to) a named segment.
    pub fn start(&mut self, name: &str) {
        self.stop();
        self.current = Some((name.to_string(), Instant::now()));
    }

    /// Stop the active segment, accumulating its elapsed time.
    pub fn stop(&mut self) {
        if let Some((name, t0)) = self.current.take() {
            let dt = t0.elapsed().as_secs_f64();
            if let Some(seg) = self.segments.iter_mut().find(|(n, _)| *n == name) {
                seg.1 += dt;
            } else {
                self.segments.push((name, dt));
            }
        }
    }

    pub fn totals(&self) -> &[(String, f64)] {
        &self.segments
    }

    pub fn total(&self) -> f64 {
        self.segments.iter().map(|(_, t)| t).sum()
    }

    pub fn report(&self) -> String {
        let total = self.total().max(1e-12);
        let mut out = String::new();
        for (name, t) in &self.segments {
            out.push_str(&format!(
                "{name:24} {t:9.3}s  {:5.1}%\n",
                100.0 * t / total
            ));
        }
        out
    }
}

/// Measure a closure's wall time; returns (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_segments() {
        let mut sw = Stopwatch::new();
        sw.start("a");
        std::thread::sleep(std::time::Duration::from_millis(5));
        sw.start("b");
        std::thread::sleep(std::time::Duration::from_millis(5));
        sw.start("a");
        std::thread::sleep(std::time::Duration::from_millis(5));
        sw.stop();
        let totals = sw.totals();
        assert_eq!(totals.len(), 2);
        let a = totals.iter().find(|(n, _)| n == "a").unwrap().1;
        let b = totals.iter().find(|(n, _)| n == "b").unwrap().1;
        assert!(a > b);
        assert!(sw.total() >= 0.015);
        assert!(sw.report().contains('%'));
    }
}
