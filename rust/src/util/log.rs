//! Leveled stderr logger with wall-clock offsets.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

use std::sync::OnceLock;

static LEVEL: AtomicU8 = AtomicU8::new(2); // 0=off 1=error 2=info 3=debug
static START: OnceLock<Instant> = OnceLock::new();

pub fn set_level(level: u8) {
    LEVEL.store(level, Ordering::Relaxed);
}

pub fn level() -> u8 {
    LEVEL.load(Ordering::Relaxed)
}

fn elapsed() -> f64 {
    START.get_or_init(Instant::now).elapsed().as_secs_f64()
}

pub fn log(lvl: u8, tag: &str, msg: std::fmt::Arguments) {
    if lvl <= level() {
        eprintln!("[{:8.2}s {tag}] {msg}", elapsed());
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::util::log::log(2, "info", format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        $crate::util::log::log(3, "debug", format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn_log {
    ($($arg:tt)*) => {
        $crate::util::log::log(1, "warn", format_args!($($arg)*))
    };
}
