//! Minimal benchmarking harness (criterion is unavailable offline).
//!
//! Used by the `rust/benches/*.rs` targets (all `harness = false`):
//! warms up, runs timed iterations, and prints mean / p50 / p95 /
//! throughput lines in a stable, grep-friendly format.

use std::time::Instant;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "bench {:<44} iters {:>4}  mean {:>10.3}ms  p50 {:>10.3}ms  p95 {:>10.3}ms",
            self.name,
            self.iters,
            self.mean_s * 1e3,
            self.p50_s * 1e3,
            self.p95_s * 1e3
        );
    }

    /// Print with a unit-per-second throughput derived from mean time.
    pub fn print_throughput(&self, units: f64, unit_name: &str) {
        println!(
            "bench {:<44} iters {:>4}  mean {:>10.3}ms  {:>12.1} {unit_name}/s",
            self.name,
            self.iters,
            self.mean_s * 1e3,
            units / self.mean_s
        );
    }
}

/// Time `f` for `iters` iterations after `warmup` untimed ones.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let p95_idx = ((times.len() as f64 * 0.95) as usize).min(times.len() - 1);
    BenchResult {
        name: name.to_string(),
        iters,
        mean_s: mean,
        p50_s: times[times.len() / 2],
        p95_s: times[p95_idx],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_times() {
        let r = bench("noop", 2, 10, || 1 + 1);
        assert_eq!(r.iters, 10);
        assert!(r.mean_s >= 0.0);
        assert!(r.p50_s <= r.p95_s + 1e-9);
    }
}
