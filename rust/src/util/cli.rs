//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Grammar: positional args, `--flag`, `--key value` or `--key=value`.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

/// Parsed command-line arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut iter = args.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{name} expects an integer: {e}")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{name} expects a number: {e}")),
        }
    }

    pub fn get_str<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn positional_flags_options() {
        let a = parse("exp fig3 --tasks math,aime --full --n 24 --temp=0.8");
        assert_eq!(a.positional, vec!["exp", "fig3"]);
        assert!(a.flag("full"));
        assert_eq!(a.get("tasks"), Some("math,aime"));
        assert_eq!(a.get_usize("n", 0).unwrap(), 24);
        assert_eq!(a.get_f64("temp", 0.0).unwrap(), 0.8);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("--fast --verbose");
        assert!(a.flag("fast") && a.flag("verbose"));
        assert!(a.options.is_empty());
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.get_usize("n", 7).unwrap(), 7);
        assert_eq!(a.get_str("variant", "base"), "base");
        assert!(a.get_usize("n", 0).is_ok());
    }

    #[test]
    fn bad_number_errors() {
        let a = parse("--n abc");
        assert!(a.get_usize("n", 0).is_err());
    }
}
