//! Substrate utilities built from scratch for the offline environment:
//! JSON, CLI parsing, deterministic RNG, logging, timing.

pub mod benchkit;
pub mod cli;
pub mod json;
pub mod log;
pub mod rng;
pub mod timer;

pub use cli::Args;
pub use json::Json;
pub use rng::SplitMix64;
pub use timer::Stopwatch;
