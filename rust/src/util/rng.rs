//! SplitMix64 — the deterministic RNG shared with the Python side
//! (`python/compile/tasks.py::SplitMix64`). Both implementations must
//! produce identical streams; `artifacts/tasks_golden.json` pins them.

/// SplitMix64 PRNG (Steele et al.), 64-bit state, full period 2^64.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)` via modulo — matches the Python mirror, which
    /// accepts the (negligible for n << 2^32) modulo bias in exchange
    /// for cross-language reproducibility.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Pick one element.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// In-place Fisher–Yates shuffle, iteration order identical to the
    /// Python mirror (`for i in range(len-1, 0, -1)`).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized weights (used by the sampler).
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_stream() {
        // First outputs for seed 0 — golden values from the reference
        // SplitMix64 (and the Python mirror).
        let mut r = SplitMix64::new(0);
        assert_eq!(r.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(r.next_u64(), 0x6E78_9E6A_A1B9_65F4);
        assert_eq!(r.next_u64(), 0x06C4_5D18_8009_454F);
    }

    #[test]
    fn below_is_in_range() {
        let mut r = SplitMix64::new(42);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = SplitMix64::new(7);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix64::new(3);
        let mut v: Vec<usize> = (0..20).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = SplitMix64::new(9);
        let mut counts = [0usize; 2];
        for _ in 0..2000 {
            counts[r.weighted(&[0.9, 0.1])] += 1;
        }
        assert!(counts[0] > counts[1] * 4);
    }
}
