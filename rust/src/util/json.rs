//! Minimal JSON parser/serializer (serde is unavailable offline).
//!
//! Supports the full JSON grammar needed by the artifact manifest, the
//! server protocol, and experiment reports: objects (insertion-ordered),
//! arrays, strings with escapes (incl. \uXXXX), numbers, bools, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value. Objects keep insertion order (Vec of pairs) so that
/// serialized output is stable and diffs are readable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    // ---------------- constructors ----------------

    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    pub fn set(mut self, key: &str, value: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut pairs) = self {
            pairs.push((key.to_string(), value.into()));
        }
        self
    }

    // ---------------- accessors ----------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// `get` that errors with the key name — for required fields.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(v) => Some(v),
            _ => None,
        }
    }

    /// Object keys as a map view (for tests / lookups).
    pub fn to_map(&self) -> BTreeMap<String, Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().cloned().collect(),
            _ => BTreeMap::new(),
        }
    }

    // ---------------- parsing ----------------

    pub fn parse(text: &str) -> Result<Json> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != bytes.len() {
            bail!("trailing data at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("read {}: {e}", path.display()))?;
        Json::parse(&text)
    }

    // ---------------- serialization ----------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_str(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    item.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push(']');
            }
            Json::Obj(pairs) if !pairs.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.fract() == 0.0 && x.abs() < 1e15 {
        let _ = write!(out, "{}", x as i64);
    } else {
        let _ = write!(out, "{x}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl From<Vec<Json>> for Json {
    fn from(x: Vec<Json>) -> Json {
        Json::Arr(x)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            _ => self.number(),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(pairs));
                }
                c => bail!("expected ',' or '}}' got '{}' at {}", c as char, self.i),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                c => bail!("expected ',' or ']' got '{}' at {}", c as char, self.i),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| anyhow!("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)?,
                                16,
                            )?;
                            self.i += 4;
                            // surrogate pairs
                            let ch = if (0xD800..0xDC00).contains(&code) {
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                {
                                    let hex2 = self
                                        .b
                                        .get(self.i + 2..self.i + 6)
                                        .ok_or_else(|| anyhow!("bad surrogate"))?;
                                    let low = u32::from_str_radix(
                                        std::str::from_utf8(hex2)?,
                                        16,
                                    )?;
                                    self.i += 6;
                                    0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00)
                                } else {
                                    bail!("lone high surrogate");
                                }
                            } else {
                                code
                            };
                            s.push(
                                char::from_u32(ch)
                                    .ok_or_else(|| anyhow!("bad codepoint"))?,
                            );
                        }
                        _ => bail!("bad escape at {}", self.i),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                c => {
                    // multi-byte UTF-8: re-decode from the raw bytes
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    let chunk = self
                        .b
                        .get(start..start + len)
                        .ok_or_else(|| anyhow!("bad utf8"))?;
                    s.push_str(std::str::from_utf8(chunk)?);
                    self.i = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| {
            anyhow!("bad number '{text}' at {start}: {e}")
        })?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": 2.5}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("b").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("b").unwrap().as_arr().unwrap()[2].as_str(),
            Some("x\ny")
        );
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn parses_unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
    }

    #[test]
    fn parses_utf8_bytes() {
        let v = Json::parse("\"héllo — ok\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo — ok"));
    }

    #[test]
    fn negative_and_exponent_numbers() {
        let v = Json::parse("[-1.5e3, 0.25, -7]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(-1500.0));
        assert_eq!(a[1].as_f64(), Some(0.25));
        assert_eq!(a[2].as_i64(), Some(-7));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn builder_and_pretty() {
        let v = Json::obj()
            .set("name", "dms")
            .set("cr", 4.0)
            .set("ok", true)
            .set("list", Json::Arr(vec![1usize.into(), 2usize.into()]));
        let p = v.to_pretty();
        assert!(p.contains("\"name\": \"dms\""));
        assert_eq!(Json::parse(&p).unwrap(), v);
    }

    #[test]
    fn roundtrip_manifest_like() {
        let src = r#"{"variants":{"dms_w16_cr4":{"weights":"w.bin","window":16,
            "immediate":false}},"vocab":["<pad>","a","\n"]}"#;
        let v = Json::parse(src).unwrap();
        let w = v
            .get("variants")
            .unwrap()
            .get("dms_w16_cr4")
            .unwrap()
            .get("window")
            .unwrap()
            .as_usize();
        assert_eq!(w, Some(16));
    }
}
