//! Dynamic Memory Sparsification — inference-side eviction executor
//! (paper §3.3, Fig. 2a).
//!
//! The retrofitted model outputs α per (layer, KV-head) for every new
//! token. Delayed mode (the paper's method): a token with α > 0.5 at
//! position t is *scheduled* for eviction at t + w and stays fully
//! attendable until then. Immediate mode (the §5.3 ablation): the
//! decision made at t evicts the token from position t − w right away.
//!
//! Knobs: eviction delay `window` w (from the model variant; 16 in the
//! exported retrofits) and the `immediate` ablation flag. The achieved
//! CR is learned, not configured. See `docs/POLICIES.md`.

use super::{Policy, PolicyKind, StepView};
use crate::kvcache::CacheStore;

pub struct DmsPolicy {
    window: usize,
    immediate: bool,
}

impl DmsPolicy {
    pub fn new(window: usize, immediate: bool) -> Self {
        Self { window, immediate }
    }

    pub fn window(&self) -> usize {
        self.window
    }
}

impl Policy for DmsPolicy {
    fn kind(&self) -> PolicyKind {
        if self.immediate {
            PolicyKind::DmsImmediate
        } else {
            PolicyKind::Dms
        }
    }

    fn post_write(&mut self, cache: &mut CacheStore, view: &StepView<'_>) {
        let g = cache.geom;
        for l in 0..g.layers {
            for h in 0..g.kv_heads {
                let i = l * g.kv_heads + h;
                let alpha = view.alpha.get(i).copied().unwrap_or(0.0);
                if alpha <= 0.5 {
                    continue;
                }
                if self.immediate {
                    // evict the token written `window` steps ago, now.
                    if view.pos >= self.window {
                        let target = view.pos - self.window;
                        if let Some((slot, _)) = cache
                            .live_slots(view.lane, l, h)
                            .into_iter()
                            .find(|&(_, p)| p == target)
                        {
                            cache.evict(view.lane, l, h, slot);
                        }
                    }
                } else if let Some(Some(slot)) = view.written.get(i) {
                    // delayed: this token leaves at pos + window.
                    cache.schedule_eviction(
                        view.lane,
                        l,
                        h,
                        *slot,
                        view.pos + self.window,
                    );
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::Geometry;

    fn store() -> CacheStore {
        CacheStore::new(
            Geometry {
                layers: 1,
                kv_heads: 1,
                slots: 16,
                head_dim: 2,
                page_size: 4,
            },
            1,
        )
    }

    fn write_token(c: &mut CacheStore, pos: usize) -> usize {
        let s = c.alloc_slot(0, 0, 0).unwrap();
        c.write(0, 0, 0, s, pos, &[0.0; 2], &[0.0; 2]);
        s
    }

    #[test]
    fn delayed_eviction_waits_for_window() {
        let mut c = store();
        let mut p = DmsPolicy::new(4, false);
        let s0 = write_token(&mut c, 0);
        p.post_write(
            &mut c,
            &StepView {
                lane: 0,
                pos: 0,
                alpha: &[0.9],
                attn: &[],
                attn_self: &[0.0],
                written: &[Some(s0)],
            },
        );
        // token survives positions 1..3
        for pos in 1..4 {
            c.apply_due_evictions(0, pos);
            assert_eq!(c.live_count(0, 0, 0), 1, "pos {pos}");
        }
        c.apply_due_evictions(0, 4);
        assert_eq!(c.live_count(0, 0, 0), 0);
    }

    #[test]
    fn low_alpha_keeps_token() {
        let mut c = store();
        let mut p = DmsPolicy::new(4, false);
        let s0 = write_token(&mut c, 0);
        p.post_write(
            &mut c,
            &StepView {
                lane: 0,
                pos: 0,
                alpha: &[0.2],
                attn: &[],
                attn_self: &[0.0],
                written: &[Some(s0)],
            },
        );
        c.apply_due_evictions(0, 100);
        assert_eq!(c.live_count(0, 0, 0), 1);
    }

    #[test]
    fn immediate_evicts_past_token() {
        let mut c = store();
        let mut p = DmsPolicy::new(2, true);
        for pos in 0..3 {
            let s = write_token(&mut c, pos);
            p.post_write(
                &mut c,
                &StepView {
                    lane: 0,
                    pos,
                    alpha: &[if pos == 2 { 0.9 } else { 0.1 }],
                    attn: &[],
                    attn_self: &[0.0],
                    written: &[Some(s)],
                },
            );
        }
        // α=1 at pos 2 with window 2 → token at pos 0 gone immediately
        assert_eq!(c.live_count(0, 0, 0), 2);
        assert!(c.slot_pos(0, 0, 0, 0).is_none());
    }
}
