//! Quest — query-aware page retrieval (Tang et al., 2024).
//!
//! Quest never evicts: the full cache stays resident (its memory cost),
//! but each step only *reads* the top-k pages per head, ranked by the
//! upper bound Σ_d max(q_d·min_d, q_d·max_d) computed from per-page
//! min/max key metadata. Page selection runs inside the decode HLO
//! (model.py); this policy only carries the page budget and the
//! metadata overhead accounting.
//!
//! Knobs: `budget_tokens` (App. F.1), rounded up to pages of
//! `page_size`. Reduces reads, not residency. See `docs/POLICIES.md`.

use super::{Policy, PolicyKind, StepView};
use crate::kvcache::CacheStore;

pub struct QuestPolicy {
    budget_tokens: usize,
    page_size: usize,
}

impl QuestPolicy {
    pub fn new(budget_tokens: usize, page_size: usize) -> Self {
        Self {
            budget_tokens,
            page_size,
        }
    }

    /// Memory/read overhead of the page representatives, in token
    /// equivalents per allocated page (a min and a max vector, each the
    /// size of one key).
    pub const META_TOKENS_PER_PAGE: f64 = 2.0;
}

impl Policy for QuestPolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::Quest
    }

    fn budget(&self) -> Option<usize> {
        // read budget, not a residency budget — nothing is evicted
        Some(self.budget_tokens)
    }

    fn quest_pages(&self) -> Option<usize> {
        Some((self.budget_tokens + self.page_size - 1) / self.page_size)
    }

    fn post_write(&mut self, _cache: &mut CacheStore, _view: &StepView<'_>) {
        // no eviction; page bounds are maintained by CacheStore::write.
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn page_budget_rounds_up() {
        let p = QuestPolicy::new(40, 16);
        assert_eq!(p.quest_pages(), Some(3));
        let p = QuestPolicy::new(48, 16);
        assert_eq!(p.quest_pages(), Some(3));
        let p = QuestPolicy::new(1, 16);
        assert_eq!(p.quest_pages(), Some(1));
    }

    #[test]
    fn never_evicts() {
        use crate::kvcache::{CacheStore, Geometry};
        let mut c = CacheStore::new(
            Geometry {
                layers: 1,
                kv_heads: 1,
                slots: 8,
                head_dim: 2,
                page_size: 4,
            },
            1,
        );
        for pos in 0..8 {
            let s = c.alloc_slot(0, 0, 0).unwrap();
            c.write(0, 0, 0, s, pos, &[0.0; 2], &[0.0; 2]);
        }
        let mut p = QuestPolicy::new(4, 4);
        p.post_write(
            &mut c,
            &StepView {
                lane: 0,
                pos: 8,
                alpha: &[0.0],
                attn: &[0.0; 8],
                attn_self: &[0.0],
                written: &[],
            },
        );
        assert_eq!(c.live_count(0, 0, 0), 8);
    }
}
