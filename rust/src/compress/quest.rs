//! Quest — query-aware page retrieval (Tang et al., 2024).
//!
//! Quest never evicts: the full cache stays resident (its memory cost),
//! but each step only *reads* the top-k pages per head, ranked by the
//! upper bound Σ_d max(q_d·min_d, q_d·max_d) computed from per-page
//! min/max key metadata. Page selection runs inside the decode HLO
//! (model.py); this policy only carries the page budget and the
//! metadata overhead accounting.
//!
//! Knobs: a [`BudgetPlan`] (uniform = App. F.1 tokens per head),
//! rounded up to pages of `page_size`. The decode executable takes a
//! single `k` for the whole batch, so a non-uniform plan is consumed
//! as its ceiling-mean per-head budget — head-granular page selection
//! would need an HLO change (documented limitation; the plan still
//! threads through for accounting and the `kv.plan_*` gauges).
//! Reduces reads, not residency. See `docs/POLICIES.md`.

use super::budget::BudgetPlan;
use super::{Policy, PolicyKind, StepView};
use crate::kvcache::CacheStore;

pub struct QuestPolicy {
    plan: BudgetPlan,
    page_size: usize,
}

impl QuestPolicy {
    pub fn new(plan: BudgetPlan, page_size: usize) -> Self {
        Self { plan, page_size }
    }

    /// Memory/read overhead of the page representatives, in token
    /// equivalents per allocated page (a min and a max vector, each the
    /// size of one key).
    pub const META_TOKENS_PER_PAGE: f64 = 2.0;

    /// Scalar per-head token read budget the page budget derives from:
    /// the plan's common budget when uniform, its ceiling-mean
    /// otherwise (the decode HLO takes one `k` per batch).
    fn budget_tokens(&self) -> usize {
        match &self.plan {
            BudgetPlan::Uniform { per_head } => *per_head,
            BudgetPlan::PerHead {
                layers, kv_heads, ..
            } => self.plan.mean_budget_ceil(*layers, *kv_heads),
        }
    }
}

impl Policy for QuestPolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::Quest
    }

    fn plan(&self) -> Option<&BudgetPlan> {
        // read budget, not a residency budget — nothing is evicted
        Some(&self.plan)
    }

    fn install_plan(&mut self, plan: BudgetPlan) {
        self.plan = plan;
    }

    fn quest_pages(&self) -> Option<usize> {
        let budget = self.budget_tokens();
        Some((budget + self.page_size - 1) / self.page_size)
    }

    fn post_write(&mut self, _cache: &mut CacheStore, _view: &StepView<'_>) {
        // no eviction; page bounds are maintained by CacheStore::write.
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn page_budget_rounds_up() {
        let p = QuestPolicy::new(BudgetPlan::uniform(40), 16);
        assert_eq!(p.quest_pages(), Some(3));
        let p = QuestPolicy::new(BudgetPlan::uniform(48), 16);
        assert_eq!(p.quest_pages(), Some(3));
        let p = QuestPolicy::new(BudgetPlan::uniform(1), 16);
        assert_eq!(p.quest_pages(), Some(1));
    }

    #[test]
    fn nonuniform_plan_reads_at_ceiling_mean() {
        // mean of (24, 56) = 40 → 3 pages of 16
        let p = QuestPolicy::new(BudgetPlan::per_head(1, 2, vec![24, 56]), 16);
        assert_eq!(p.quest_pages(), Some(3));
    }

    #[test]
    fn never_evicts() {
        use crate::kvcache::{CacheStore, Geometry};
        let mut c = CacheStore::new(
            Geometry {
                layers: 1,
                kv_heads: 1,
                slots: 8,
                head_dim: 2,
                page_size: 4,
            },
            1,
        );
        for pos in 0..8 {
            let s = c.alloc_slot(0, 0, 0).unwrap();
            c.write(0, 0, 0, s, pos, &[0.0; 2], &[0.0; 2]);
        }
        let mut p = QuestPolicy::new(BudgetPlan::uniform(4), 4);
        p.post_write(
            &mut c,
            &StepView {
                lane: 0,
                pos: 8,
                alpha: &[0.0],
                attn: &[0.0; 8],
                attn_self: &[0.0],
                written: &[],
            },
        );
        assert_eq!(c.live_count(0, 0, 0), 8);
    }
}
