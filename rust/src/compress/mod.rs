//! KV-cache compression policy zoo (paper §2.2/§2.3/§3.3).
//!
//! Policies operate host-side on the paged cache store:
//!
//! * [`dms`]     — Dynamic Memory Sparsification: α-driven **delayed**
//!   eviction (decision at t executes at t+w), plus the immediate-
//!   eviction ablation variant;
//! * [`tova`]    — evict the token with the lowest current attention;
//! * [`h2o`]     — Heavy-Hitter Oracle: cumulative attention + recent
//!   window, budget split half/half;
//! * [`quest`]   — no eviction; per-step top-k page retrieval using
//!   min/max page metadata (selection runs inside the decode HLO);
//! * [`dmc`]     — Dynamic Memory Compression baseline: α-driven merge
//!   into the most recent entry via weighted averaging;
//! * vanilla / sliding-window — trivial baselines.
//!
//! Budgeted policies enforce a per-(layer, KV-head) [`BudgetPlan`]
//! produced by a pluggable [`BudgetAllocator`] (see [`budget`]):
//! uniform plans reproduce the legacy scalar App. F.1 budget
//! bit-exactly; pyramid/adaptive plans open the non-uniform axis.

pub mod budget;
pub mod dmc;
pub mod dms;
pub mod h2o;
pub mod quest;
pub mod tova;
pub mod window;

use std::str::FromStr;

use anyhow::bail;

pub use budget::{
    apportion, build_allocator, AdaptiveAllocator, AllocatorKind, AttnStats,
    BudgetAllocator, BudgetPlan, PyramidAllocator, UniformAllocator,
};

use crate::kvcache::CacheStore;

/// Policy selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PolicyKind {
    Vanilla,
    Dms,
    DmsImmediate,
    Tova,
    H2o,
    Quest,
    Dmc,
    Window,
}

impl PolicyKind {
    pub fn name(&self) -> &'static str {
        match self {
            PolicyKind::Vanilla => "vanilla",
            PolicyKind::Dms => "dms",
            PolicyKind::DmsImmediate => "dms_immediate",
            PolicyKind::Tova => "tova",
            PolicyKind::H2o => "h2o",
            PolicyKind::Quest => "quest",
            PolicyKind::Dmc => "dmc",
            PolicyKind::Window => "window",
        }
    }

    /// Default model variant for this policy (training-free policies run
    /// on the base model; retrofitted ones need their own weights).
    pub fn default_variant(&self, cr: f64) -> &'static str {
        match self {
            PolicyKind::Dms => {
                if cr >= 8.0 {
                    "dms_w16_cr8"
                } else {
                    "dms_w16_cr4"
                }
            }
            PolicyKind::DmsImmediate => "dms_imm_w16",
            PolicyKind::Dmc => "dmc",
            _ => "base",
        }
    }
}

impl FromStr for PolicyKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Ok(match s {
            "vanilla" | "base" => PolicyKind::Vanilla,
            "dms" => PolicyKind::Dms,
            "dms_immediate" | "dms-immediate" => PolicyKind::DmsImmediate,
            "tova" => PolicyKind::Tova,
            "h2o" => PolicyKind::H2o,
            "quest" => PolicyKind::Quest,
            "dmc" => PolicyKind::Dmc,
            "window" => PolicyKind::Window,
            other => bail!("unknown policy '{other}'"),
        })
    }
}

/// What to do with the freshly produced (k, v) of the current token.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WriteAction {
    /// Allocate a slot and append (possibly scheduling later eviction).
    Append,
    /// DMC: merge into the most recently written live slot.
    Merge,
}

/// Per-step observation handed to policies after the executor ran.
pub struct StepView<'a> {
    /// Lane index inside the executor batch.
    pub lane: usize,
    /// Position (token index) of the token just processed.
    pub pos: usize,
    /// α per (layer, kv-head), sigmoid of the eviction logit.
    pub alpha: &'a [f32],
    /// Attention mass per (layer, kv-head, slot), group-summed.
    pub attn: &'a [f32],
    /// Attention mass the current token assigned to itself.
    pub attn_self: &'a [f32],
    /// Slot written for the current token per (layer, kv-head); None if
    /// the write was a DMC merge or overflowed.
    pub written: &'a [Option<usize>],
}

/// A compression policy instance (one per active chain).
pub trait Policy: Send {
    fn kind(&self) -> PolicyKind;

    /// The per-(layer, KV-head) budget plan this policy enforces
    /// (None = unbudgeted). Replaces the old scalar `budget()`: a
    /// [`BudgetPlan::Uniform`] plan reproduces the App. F.1 per-head
    /// rule (budget = (input_len + max_gen) / CR) bit-exactly, while
    /// non-uniform plans open the per-head budget axis.
    fn plan(&self) -> Option<&BudgetPlan> {
        None
    }

    /// Install a freshly allocated plan (admission, fork inheritance
    /// from the group leader, adaptive re-planning during decode).
    /// Enforcement picks the new budgets up on the next `post_write`.
    /// No-op for unbudgeted policies.
    fn install_plan(&mut self, plan: BudgetPlan) {
        let _ = plan;
    }

    /// Quest: number of pages to retrieve per head (None disables).
    fn quest_pages(&self) -> Option<usize> {
        None
    }

    /// Decide append-vs-merge per (layer, kv-head) for the new token.
    /// `alpha` is laid out [layers × kv_heads].
    fn write_actions(
        &mut self,
        alpha: &[f32],
        layers: usize,
        kv_heads: usize,
        out: &mut Vec<WriteAction>,
    ) {
        let _ = alpha;
        out.clear();
        out.resize(layers * kv_heads, WriteAction::Append);
    }

    /// Called after the new token was written (slot choices final).
    /// This is where DMS schedules delayed evictions and TOVA/H2O
    /// enforce their budgets.
    fn post_write(&mut self, cache: &mut CacheStore, view: &StepView<'_>);

    /// Called once after prefill finished for this lane (policies that
    /// enforce budgets trim the prompt cache here).
    fn post_prefill(&mut self, cache: &mut CacheStore, lane: usize, pos: usize) {
        let _ = (cache, lane, pos);
    }
}

/// Total order over `(score, slot)` eviction candidates: ascending
/// score, ties broken by slot index — exactly the sequence the legacy
/// per-(layer, head) min-scan loops produced (their strict `<` kept
/// the first, i.e. lowest-slot, minimum). Callers pre-filter
/// candidates to `score < f32::INFINITY` (the only scores the legacy
/// scans could ever select), so `partial_cmp` is total here.
pub(crate) fn score_slot_order(a: &(f32, usize), b: &(f32, usize)) -> std::cmp::Ordering {
    a.0.partial_cmp(&b.0)
        .expect("eviction candidates are NaN-filtered")
        .then(a.1.cmp(&b.1))
}

/// App. F.1 per-head budget: (input + max_gen) / CR, clamped so a
/// chain always keeps at least one DMS window of tokens.
pub fn per_head_budget(cr: f64, max_total_len: usize, window: usize) -> usize {
    ((max_total_len as f64 / cr).ceil() as usize).max(window.max(1))
}

/// Build a policy instance under the legacy uniform budget rule.
///
/// * `max_total_len` = prompt + max generation (the L budget), which
///   parameterizes the App. F.1 budget rule (input + max_gen) / CR.
/// * `window` is the DMS eviction delay (from the model variant).
///
/// Equivalent to [`build_policy_planned`] with a
/// [`BudgetPlan::Uniform`] plan at the App. F.1 per-head budget —
/// bit-exact with the pre-plan policy zoo.
pub fn build_policy(
    kind: PolicyKind,
    cr: f64,
    max_total_len: usize,
    window: usize,
    page_size: usize,
) -> Box<dyn Policy> {
    let budget = per_head_budget(cr, max_total_len, window);
    build_policy_planned(kind, BudgetPlan::uniform(budget), window, page_size)
}

/// Build a policy instance enforcing an explicit [`BudgetPlan`].
/// Unbudgeted policies (vanilla, DMS, DMC) ignore the plan — their
/// compression is learned, not allocated.
pub fn build_policy_planned(
    kind: PolicyKind,
    plan: BudgetPlan,
    window: usize,
    page_size: usize,
) -> Box<dyn Policy> {
    match kind {
        PolicyKind::Vanilla => Box::new(window::VanillaPolicy),
        PolicyKind::Window => Box::new(window::WindowPolicy::new(plan)),
        PolicyKind::Dms => Box::new(dms::DmsPolicy::new(window, false)),
        PolicyKind::DmsImmediate => Box::new(dms::DmsPolicy::new(window, true)),
        PolicyKind::Tova => Box::new(tova::TovaPolicy::new(plan)),
        PolicyKind::H2o => Box::new(h2o::H2oPolicy::new(plan)),
        PolicyKind::Quest => Box::new(quest::QuestPolicy::new(plan, page_size)),
        PolicyKind::Dmc => Box::new(dmc::DmcPolicy::new()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for kind in [
            PolicyKind::Vanilla,
            PolicyKind::Dms,
            PolicyKind::DmsImmediate,
            PolicyKind::Tova,
            PolicyKind::H2o,
            PolicyKind::Quest,
            PolicyKind::Dmc,
            PolicyKind::Window,
        ] {
            let parsed: PolicyKind = kind.name().parse().unwrap();
            assert_eq!(parsed, kind);
        }
        assert!("bogus".parse::<PolicyKind>().is_err());
    }

    #[test]
    fn budget_rule_matches_appendix_f1() {
        // budget = (input + max_gen) / CR = 160/4, as a uniform plan
        let p = build_policy(PolicyKind::Tova, 4.0, 160, 16, 16);
        let plan = p.plan().expect("tova is budgeted");
        assert_eq!(plan.uniform_budget(), Some(40));
        assert_eq!(per_head_budget(4.0, 160, 16), 40);
        // unbudgeted policies expose no plan and ignore installs
        let mut p = build_policy(PolicyKind::Dms, 4.0, 160, 16, 16);
        assert!(p.plan().is_none());
        p.install_plan(BudgetPlan::uniform(7));
        assert!(p.plan().is_none());
    }

    #[test]
    fn planned_policies_adopt_installed_plans() {
        let mut p = build_policy(PolicyKind::H2o, 4.0, 160, 16, 16);
        let plan = BudgetPlan::per_head(1, 2, vec![10, 70]);
        p.install_plan(plan.clone());
        assert_eq!(p.plan(), Some(&plan));
    }

    #[test]
    fn default_variants() {
        assert_eq!(PolicyKind::Dms.default_variant(4.0), "dms_w16_cr4");
        assert_eq!(PolicyKind::Dms.default_variant(8.0), "dms_w16_cr8");
        assert_eq!(PolicyKind::Quest.default_variant(4.0), "base");
    }
}
