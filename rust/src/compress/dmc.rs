//! DMC — Dynamic Memory Compression baseline (Nawrot et al., 2024).
//!
//! Per head, the model's α decides append-vs-merge: on merge, the new
//! (k, v) is accumulated into the most recent cache entry by running
//! weighted average (`CacheStore::merge_into_last`), so the cache does
//! not grow. No delayed window — that is precisely the training-
//! difficulty contrast with DMS the paper exploits.
//!
//! Knobs: none at inference — the merge rate (and thus CR) is learned.
//! See `docs/POLICIES.md`.

use super::{Policy, PolicyKind, StepView, WriteAction};
use crate::kvcache::CacheStore;

pub struct DmcPolicy {
    merges: u64,
    appends: u64,
}

impl DmcPolicy {
    pub fn new() -> Self {
        Self {
            merges: 0,
            appends: 0,
        }
    }

    /// Achieved compression ratio so far: tokens seen / entries kept.
    pub fn achieved_cr(&self) -> f64 {
        let kept = self.appends.max(1);
        (self.appends + self.merges) as f64 / kept as f64
    }
}

impl Default for DmcPolicy {
    fn default() -> Self {
        Self::new()
    }
}

impl Policy for DmcPolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::Dmc
    }

    fn write_actions(
        &mut self,
        alpha: &[f32],
        layers: usize,
        kv_heads: usize,
        out: &mut Vec<WriteAction>,
    ) {
        out.clear();
        for i in 0..layers * kv_heads {
            let a = alpha.get(i).copied().unwrap_or(0.0);
            if a > 0.5 {
                self.merges += 1;
                out.push(WriteAction::Merge);
            } else {
                self.appends += 1;
                out.push(WriteAction::Append);
            }
        }
    }

    fn post_write(&mut self, _cache: &mut CacheStore, _view: &StepView<'_>) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splits_actions_on_alpha() {
        let mut p = DmcPolicy::new();
        let mut out = Vec::new();
        p.write_actions(&[0.9, 0.1, 0.6, 0.4], 2, 2, &mut out);
        assert_eq!(
            out,
            vec![
                WriteAction::Merge,
                WriteAction::Append,
                WriteAction::Merge,
                WriteAction::Append
            ]
        );
    }

    #[test]
    fn achieved_cr_counts_merges() {
        let mut p = DmcPolicy::new();
        let mut out = Vec::new();
        // 4 decisions, 3 merges -> CR 4x on that head-step set
        p.write_actions(&[0.9, 0.9, 0.9, 0.1], 2, 2, &mut out);
        assert!((p.achieved_cr() - 4.0).abs() < 1e-9);
    }
}
