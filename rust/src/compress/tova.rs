//! TOVA — Token Omission Via Attention (Oren et al., 2024).
//!
//! At each step, any (layer, head) whose cache exceeds its planned
//! budget evicts the token with the lowest attention weight in the
//! *current* step, aggregated over the heads of the layer (§2.2:
//! i* = argmin_i Σ_h a_h(t)_i — the scoring is the reference paper's
//! layer-wide rule). **Enforcement** is head-granular: each (layer,
//! head) runs its own eviction loop against its own budget, so a
//! non-uniform [`BudgetPlan`] holds for every head — not just head 0,
//! which the pre-plan implementation probed while coupling all heads
//! to its eviction choice. Under a uniform plan the heads of a layer
//! stay in lockstep (identical live sets × identical layer-summed
//! scores ⇒ identical eviction sequences), which makes the uniform
//! path bit-exact with the legacy coupled eviction. Enforcement is a
//! single partial-select per (layer, head) over the layer's score
//! plane — O(live) per overflow instead of the legacy
//! O(evictions × live) rescan — choosing the exact same evicted set.
//!
//! Knobs: a [`BudgetPlan`] (uniform = App. F.1 (input + max_gen) / CR
//! per head). See `docs/POLICIES.md`.

use super::budget::BudgetPlan;
use super::{Policy, PolicyKind, StepView};
use crate::kvcache::CacheStore;

pub struct TovaPolicy {
    plan: BudgetPlan,
    /// Layer-summed score plane (one slot per entry), reused per layer.
    scores: Vec<f32>,
    /// Live-slot scratch for the batched eviction select.
    live: Vec<(usize, usize)>,
    /// `(score, slot)` eviction candidates, partial-selected per head.
    cand: Vec<(f32, usize)>,
}

impl TovaPolicy {
    pub fn new(plan: BudgetPlan) -> Self {
        Self {
            plan,
            scores: Vec::new(),
            live: Vec::new(),
            cand: Vec::new(),
        }
    }
}

impl Policy for TovaPolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::Tova
    }

    fn plan(&self) -> Option<&BudgetPlan> {
        Some(&self.plan)
    }

    fn install_plan(&mut self, plan: BudgetPlan) {
        self.plan = plan;
    }

    fn post_write(&mut self, cache: &mut CacheStore, view: &StepView<'_>) {
        let g = cache.geom;
        let s = g.slots;
        self.scores.resize(s, 0.0);
        for l in 0..g.layers {
            // layer-summed score (§2.2), hoisted once per layer: it is
            // a pure function of this step's attention view, invariant
            // across heads and evictions (same f32 summation order as
            // the per-candidate recompute, so choices are unchanged)
            for (slot, score) in self.scores.iter_mut().enumerate() {
                let mut sum = 0.0f32;
                for hh in 0..g.kv_heads {
                    sum += view.attn[(l * g.kv_heads + hh) * s + slot];
                }
                *score = sum;
            }
            for h in 0..g.kv_heads {
                let budget = self.plan.budget(l, h);
                let live = cache.live_count(view.lane, l, h);
                if live <= budget {
                    continue;
                }
                // Batched equivalent of the legacy per-eviction rescan:
                // the candidate set and its scores are fixed for the
                // whole overflow (scores are per-step, the current
                // token's exclusion is static, and evicted slots only
                // leave the set), so the evicted set is exactly the n
                // smallest candidates by (score, slot). The legacy
                // min-scan's strict `<` never selected NaN/+inf scores
                // (it stopped instead), hence the `< INFINITY` filter
                // and the min() against the candidate count.
                cache.live_slots_into(view.lane, l, h, &mut self.live);
                self.cand.clear();
                for &(slot, pos) in &self.live {
                    if pos == view.pos {
                        continue; // the token written this step has no score yet
                    }
                    let score = self.scores[slot];
                    if score < f32::INFINITY {
                        self.cand.push((score, slot));
                    }
                }
                let n_evict = (live - budget).min(self.cand.len());
                if n_evict == 0 {
                    continue;
                }
                if n_evict < self.cand.len() {
                    self.cand
                        .select_nth_unstable_by(n_evict, super::score_slot_order);
                }
                for &(_, slot) in self.cand.iter().take(n_evict) {
                    cache.evict(view.lane, l, h, slot);
                }
            }
        }
    }

    fn post_prefill(&mut self, cache: &mut CacheStore, lane: usize, _pos: usize) {
        // App. F.1: standard (dense) prefill until the budget is
        // reached, then switch to the eviction mechanism. Without
        // per-token prefill attention we trim recency-first, which is
        // the TOVA behaviour in the absence of scores (recent tokens
        // dominate attention).
        super::window::trim_to_plan_with(cache, lane, &self.plan, &mut self.live);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::Geometry;

    fn store() -> CacheStore {
        CacheStore::new(
            Geometry {
                layers: 1,
                kv_heads: 2,
                slots: 8,
                head_dim: 2,
                page_size: 4,
            },
            1,
        )
    }

    #[test]
    fn evicts_lowest_attention_token() {
        let mut c = store();
        // 4 live tokens in both heads
        for pos in 0..4 {
            for h in 0..2 {
                let s = c.alloc_slot(0, 0, h).unwrap();
                c.write(0, 0, h, s, pos, &[0.0; 2], &[0.0; 2]);
            }
        }
        let mut attn = vec![0.0f32; 2 * 8];
        // head 0 + head 1 scores: slot 2 has lowest combined mass
        for (slot, score) in [(0usize, 0.5f32), (1, 0.4), (2, 0.01), (3, 0.3)] {
            attn[slot] = score; // head 0
            attn[8 + slot] = score; // head 1
        }
        let mut p = TovaPolicy::new(BudgetPlan::uniform(3));
        p.post_write(
            &mut c,
            &StepView {
                lane: 0,
                pos: 3,
                alpha: &[0.0; 2],
                attn: &attn,
                attn_self: &[0.0; 2],
                written: &[],
            },
        );
        assert_eq!(c.live_count(0, 0, 0), 3);
        assert_eq!(c.live_count(0, 0, 1), 3);
        assert!(c.slot_pos(0, 0, 0, 2).is_none(), "slot 2 evicted");
        assert!(c.slot_pos(0, 0, 1, 2).is_none(), "head 1 evicted it too");
    }

    #[test]
    fn per_head_budgets_are_enforced_for_every_head() {
        let mut c = store();
        for pos in 0..6 {
            for h in 0..2 {
                let s = c.alloc_slot(0, 0, h).unwrap();
                c.write(0, 0, h, s, pos, &[0.0; 2], &[0.0; 2]);
            }
        }
        let attn: Vec<f32> = (0..2 * 8).map(|i| i as f32 * 0.0625).collect();
        // head 0 may keep 5 tokens, head 1 only 2
        let mut p = TovaPolicy::new(BudgetPlan::per_head(1, 2, vec![5, 2]));
        p.post_write(
            &mut c,
            &StepView {
                lane: 0,
                pos: 5,
                alpha: &[0.0; 2],
                attn: &attn,
                attn_self: &[0.0; 2],
                written: &[],
            },
        );
        assert_eq!(c.live_count(0, 0, 0), 5);
        assert_eq!(c.live_count(0, 0, 1), 2, "head 1's own budget holds");
    }

    #[test]
    fn current_token_is_protected() {
        let mut c = store();
        for pos in 0..3 {
            for h in 0..2 {
                let s = c.alloc_slot(0, 0, h).unwrap();
                c.write(0, 0, h, s, pos, &[0.0; 2], &[0.0; 2]);
            }
        }
        // zero attention everywhere: the just-written token (pos 2)
        // must survive; one of the others goes.
        let attn = vec![0.0f32; 2 * 8];
        let mut p = TovaPolicy::new(BudgetPlan::uniform(2));
        p.post_write(
            &mut c,
            &StepView {
                lane: 0,
                pos: 2,
                alpha: &[0.0; 2],
                attn: &attn,
                attn_self: &[0.0; 2],
                written: &[],
            },
        );
        assert_eq!(c.live_count(0, 0, 0), 2);
        let kept: Vec<usize> = c.live_slots(0, 0, 0).iter().map(|&(_, p)| p).collect();
        assert!(kept.contains(&2));
    }

    #[test]
    fn within_budget_no_eviction() {
        let mut c = store();
        for h in 0..2 {
            let s = c.alloc_slot(0, 0, h).unwrap();
            c.write(0, 0, h, s, 0, &[0.0; 2], &[0.0; 2]);
        }
        let attn = vec![0.1f32; 2 * 8];
        let mut p = TovaPolicy::new(BudgetPlan::uniform(4));
        p.post_write(
            &mut c,
            &StepView {
                lane: 0,
                pos: 0,
                alpha: &[0.0; 2],
                attn: &attn,
                attn_self: &[0.0; 2],
                written: &[],
            },
        );
        assert_eq!(c.live_count(0, 0, 0), 1);
    }
}
