//! TOVA — Token Omission Via Attention (Oren et al., 2024).
//!
//! At each step, if the per-head cache exceeds its budget, evict the
//! token with the lowest attention weight in the *current* step,
//! aggregated over the heads of each layer (§2.2: i* = argmin_i Σ_h
//! a_h(t)_i). Eviction is layer-wide: all KV heads of a layer drop the
//! same token, as in the reference implementation.
//!
//! Knobs: token `budget` per head (App. F.1). See `docs/POLICIES.md`.

use super::{Policy, PolicyKind, StepView};
use crate::kvcache::CacheStore;

pub struct TovaPolicy {
    budget: usize,
}

impl TovaPolicy {
    pub fn new(budget: usize) -> Self {
        Self { budget }
    }
}

impl Policy for TovaPolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::Tova
    }

    fn budget(&self) -> Option<usize> {
        Some(self.budget)
    }

    fn post_write(&mut self, cache: &mut CacheStore, view: &StepView<'_>) {
        let g = cache.geom;
        let s = g.slots;
        for l in 0..g.layers {
            // aggregate attention over the layer's KV heads
            while cache.live_count(view.lane, l, 0) > self.budget {
                let mut best_slot = None;
                let mut best_score = f32::INFINITY;
                for (slot, pos) in cache.live_slots(view.lane, l, 0) {
                    if pos == view.pos {
                        continue; // the token written this step has no score yet
                    }
                    let mut score = 0.0f32;
                    for h in 0..g.kv_heads {
                        score += view.attn[(l * g.kv_heads + h) * s + slot];
                    }
                    if score < best_score {
                        best_score = score;
                        best_slot = Some(slot);
                    }
                }
                let Some(slot) = best_slot else { break };
                for h in 0..g.kv_heads {
                    cache.evict(view.lane, l, h, slot);
                }
            }
        }
    }

    fn post_prefill(&mut self, cache: &mut CacheStore, lane: usize, _pos: usize) {
        // App. F.1: standard (dense) prefill until the budget is
        // reached, then switch to the eviction mechanism. Without
        // per-token prefill attention we trim recency-first, which is
        // the TOVA behaviour in the absence of scores (recent tokens
        // dominate attention).
        super::window::trim_to_window(cache, lane, self.budget);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::Geometry;

    fn store() -> CacheStore {
        CacheStore::new(
            Geometry {
                layers: 1,
                kv_heads: 2,
                slots: 8,
                head_dim: 2,
                page_size: 4,
            },
            1,
        )
    }

    #[test]
    fn evicts_lowest_attention_token() {
        let mut c = store();
        // 4 live tokens in both heads
        for pos in 0..4 {
            for h in 0..2 {
                let s = c.alloc_slot(0, 0, h).unwrap();
                c.write(0, 0, h, s, pos, &[0.0; 2], &[0.0; 2]);
            }
        }
        let mut attn = vec![0.0f32; 2 * 8];
        // head 0 + head 1 scores: slot 2 has lowest combined mass
        for (slot, score) in [(0usize, 0.5f32), (1, 0.4), (2, 0.01), (3, 0.3)] {
            attn[slot] = score; // head 0
            attn[8 + slot] = score; // head 1
        }
        let mut p = TovaPolicy::new(3);
        p.post_write(
            &mut c,
            &StepView {
                lane: 0,
                pos: 3,
                alpha: &[0.0; 2],
                attn: &attn,
                attn_self: &[0.0; 2],
                written: &[],
            },
        );
        assert_eq!(c.live_count(0, 0, 0), 3);
        assert_eq!(c.live_count(0, 0, 1), 3);
        assert!(c.slot_pos(0, 0, 0, 2).is_none(), "slot 2 evicted");
    }

    #[test]
    fn current_token_is_protected() {
        let mut c = store();
        for pos in 0..3 {
            for h in 0..2 {
                let s = c.alloc_slot(0, 0, h).unwrap();
                c.write(0, 0, h, s, pos, &[0.0; 2], &[0.0; 2]);
            }
        }
        // zero attention everywhere: the just-written token (pos 2)
        // must survive; one of the others goes.
        let attn = vec![0.0f32; 2 * 8];
        let mut p = TovaPolicy::new(2);
        p.post_write(
            &mut c,
            &StepView {
                lane: 0,
                pos: 2,
                alpha: &[0.0; 2],
                attn: &attn,
                attn_self: &[0.0; 2],
                written: &[],
            },
        );
        assert_eq!(c.live_count(0, 0, 0), 2);
        let kept: Vec<usize> = c.live_slots(0, 0, 0).iter().map(|&(_, p)| p).collect();
        assert!(kept.contains(&2));
    }

    #[test]
    fn within_budget_no_eviction() {
        let mut c = store();
        for h in 0..2 {
            let s = c.alloc_slot(0, 0, h).unwrap();
            c.write(0, 0, h, s, 0, &[0.0; 2], &[0.0; 2]);
        }
        let attn = vec![0.1f32; 2 * 8];
        let mut p = TovaPolicy::new(4);
        p.post_write(
            &mut c,
            &StepView {
                lane: 0,
                pos: 0,
                alpha: &[0.0; 2],
                attn: &attn,
                attn_self: &[0.0; 2],
                written: &[],
            },
        );
        assert_eq!(c.live_count(0, 0, 0), 1);
    }
}
