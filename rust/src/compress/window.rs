//! Trivial policies: vanilla (no compression) and a fixed sliding
//! window (evict everything older than the budget).
//!
//! Knobs: token `budget` per head for the window (App. F.1); vanilla
//! has none. See `docs/POLICIES.md`.

use super::{Policy, PolicyKind, StepView};
use crate::kvcache::CacheStore;

/// No compression; the original dense-attention model.
pub struct VanillaPolicy;

impl Policy for VanillaPolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::Vanilla
    }

    fn post_write(&mut self, _cache: &mut CacheStore, _view: &StepView<'_>) {}
}

/// Keep only the most recent `budget` tokens per head.
pub struct WindowPolicy {
    budget: usize,
}

impl WindowPolicy {
    pub fn new(budget: usize) -> Self {
        Self { budget }
    }
}

impl Policy for WindowPolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::Window
    }

    fn budget(&self) -> Option<usize> {
        Some(self.budget)
    }

    fn post_write(&mut self, cache: &mut CacheStore, view: &StepView<'_>) {
        trim_to_window(cache, view.lane, self.budget);
    }

    fn post_prefill(&mut self, cache: &mut CacheStore, lane: usize, _pos: usize) {
        trim_to_window(cache, lane, self.budget);
    }
}

/// Evict oldest-first down to `budget` live slots per (layer, head).
pub(crate) fn trim_to_window(cache: &mut CacheStore, lane: usize, budget: usize) {
    let g = cache.geom;
    for l in 0..g.layers {
        for h in 0..g.kv_heads {
            let mut live = cache.live_slots(lane, l, h);
            if live.len() <= budget {
                continue;
            }
            live.sort_by_key(|&(_, pos)| pos);
            let n_evict = live.len() - budget;
            for &(slot, _) in live.iter().take(n_evict) {
                cache.evict(lane, l, h, slot);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::Geometry;

    fn store() -> CacheStore {
        CacheStore::new(
            Geometry {
                layers: 1,
                kv_heads: 1,
                slots: 16,
                head_dim: 2,
                page_size: 4,
            },
            1,
        )
    }

    #[test]
    fn window_keeps_most_recent() {
        let mut c = store();
        for pos in 0..8 {
            let s = c.alloc_slot(0, 0, 0).unwrap();
            c.write(0, 0, 0, s, pos, &[pos as f32; 2], &[0.0; 2]);
        }
        trim_to_window(&mut c, 0, 3);
        assert_eq!(c.live_count(0, 0, 0), 3);
        let mut kept: Vec<usize> =
            c.live_slots(0, 0, 0).iter().map(|&(_, p)| p).collect();
        kept.sort_unstable();
        assert_eq!(kept, vec![5, 6, 7]);
    }

    #[test]
    fn vanilla_never_evicts() {
        let mut c = store();
        for pos in 0..8 {
            let s = c.alloc_slot(0, 0, 0).unwrap();
            c.write(0, 0, 0, s, pos, &[0.0; 2], &[0.0; 2]);
        }
        let mut p = VanillaPolicy;
        let view = StepView {
            lane: 0,
            pos: 8,
            alpha: &[0.0],
            attn: &[],
            attn_self: &[0.0],
            written: &[],
        };
        p.post_write(&mut c, &view);
        assert_eq!(c.live_count(0, 0, 0), 8);
    }
}
