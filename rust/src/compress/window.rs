//! Trivial policies: vanilla (no compression) and a fixed sliding
//! window (evict everything older than the budget).
//!
//! Knobs: a per-(layer, head) [`BudgetPlan`] for the window (uniform
//! plans reproduce the App. F.1 scalar budget); vanilla has none. See
//! `docs/POLICIES.md`.

use super::budget::BudgetPlan;
use super::{Policy, PolicyKind, StepView};
use crate::kvcache::CacheStore;

/// No compression; the original dense-attention model.
pub struct VanillaPolicy;

impl Policy for VanillaPolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::Vanilla
    }

    fn post_write(&mut self, _cache: &mut CacheStore, _view: &StepView<'_>) {}
}

/// Keep only the most recent `plan.budget(l, h)` tokens per head.
pub struct WindowPolicy {
    plan: BudgetPlan,
    /// Reusable live-slot scratch for the trim — one buffer for the
    /// policy's lifetime instead of one allocation per (layer, head)
    /// per step.
    scratch: Vec<(usize, usize)>,
}

impl WindowPolicy {
    pub fn new(plan: BudgetPlan) -> Self {
        Self {
            plan,
            scratch: Vec::new(),
        }
    }
}

impl Policy for WindowPolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::Window
    }

    fn plan(&self) -> Option<&BudgetPlan> {
        Some(&self.plan)
    }

    fn install_plan(&mut self, plan: BudgetPlan) {
        self.plan = plan;
    }

    fn post_write(&mut self, cache: &mut CacheStore, view: &StepView<'_>) {
        trim_to_plan_with(cache, view.lane, &self.plan, &mut self.scratch);
    }

    fn post_prefill(&mut self, cache: &mut CacheStore, lane: usize, _pos: usize) {
        trim_to_plan_with(cache, lane, &self.plan, &mut self.scratch);
    }
}

/// Evict oldest-first down to each (layer, head)'s planned budget
/// (a uniform plan reproduces the legacy scalar-window trim exactly).
pub(crate) fn trim_to_plan(cache: &mut CacheStore, lane: usize, plan: &BudgetPlan) {
    let mut scratch = Vec::new();
    trim_to_plan_with(cache, lane, plan, &mut scratch);
}

/// [`trim_to_plan`] with a caller-held scratch buffer, so per-step
/// trims reuse one allocation across every (layer, head).
///
/// Oldest-first means smallest `(pos, slot)`: the legacy trim's stable
/// `sort_by_key(pos)` broke position ties by scan order, which is
/// ascending slot — and since evictions commute, a partial select of
/// the same n-smallest set leaves the identical final cache state.
pub(crate) fn trim_to_plan_with(
    cache: &mut CacheStore,
    lane: usize,
    plan: &BudgetPlan,
    scratch: &mut Vec<(usize, usize)>,
) {
    let g = cache.geom;
    for l in 0..g.layers {
        for h in 0..g.kv_heads {
            let budget = plan.budget(l, h);
            cache.live_slots_into(lane, l, h, scratch);
            if scratch.len() <= budget {
                continue;
            }
            let n_evict = scratch.len() - budget;
            if n_evict < scratch.len() {
                scratch.select_nth_unstable_by_key(n_evict, |&(slot, pos)| (pos, slot));
            }
            for &(slot, _) in scratch.iter().take(n_evict) {
                cache.evict(lane, l, h, slot);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::Geometry;

    fn store() -> CacheStore {
        CacheStore::new(
            Geometry {
                layers: 1,
                kv_heads: 1,
                slots: 16,
                head_dim: 2,
                page_size: 4,
            },
            1,
        )
    }

    #[test]
    fn window_keeps_most_recent() {
        let mut c = store();
        for pos in 0..8 {
            let s = c.alloc_slot(0, 0, 0).unwrap();
            c.write(0, 0, 0, s, pos, &[pos as f32; 2], &[0.0; 2]);
        }
        trim_to_plan(&mut c, 0, &BudgetPlan::uniform(3));
        assert_eq!(c.live_count(0, 0, 0), 3);
        let mut kept: Vec<usize> =
            c.live_slots(0, 0, 0).iter().map(|&(_, p)| p).collect();
        kept.sort_unstable();
        assert_eq!(kept, vec![5, 6, 7]);
    }

    #[test]
    fn per_head_plan_trims_each_head_to_its_own_budget() {
        let mut c = CacheStore::new(
            Geometry {
                layers: 1,
                kv_heads: 2,
                slots: 16,
                head_dim: 2,
                page_size: 4,
            },
            1,
        );
        for pos in 0..8 {
            for h in 0..2 {
                let s = c.alloc_slot(0, 0, h).unwrap();
                c.write(0, 0, h, s, pos, &[0.0; 2], &[0.0; 2]);
            }
        }
        let plan = BudgetPlan::per_head(1, 2, vec![6, 2]);
        trim_to_plan(&mut c, 0, &plan);
        assert_eq!(c.live_count(0, 0, 0), 6);
        assert_eq!(c.live_count(0, 0, 1), 2);
        // head 1 kept its most recent two tokens
        let mut kept: Vec<usize> =
            c.live_slots(0, 0, 1).iter().map(|&(_, p)| p).collect();
        kept.sort_unstable();
        assert_eq!(kept, vec![6, 7]);
    }

    #[test]
    fn vanilla_never_evicts() {
        let mut c = store();
        for pos in 0..8 {
            let s = c.alloc_slot(0, 0, 0).unwrap();
            c.write(0, 0, 0, s, pos, &[0.0; 2], &[0.0; 2]);
        }
        let mut p = VanillaPolicy;
        let view = StepView {
            lane: 0,
            pos: 8,
            alpha: &[0.0],
            attn: &[],
            attn_self: &[0.0],
            written: &[],
        };
        p.post_write(&mut c, &view);
        assert_eq!(c.live_count(0, 0, 0), 8);
    }
}
