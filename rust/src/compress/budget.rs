//! Per-(layer, KV-head) budget plans and pluggable allocators.
//!
//! The paper's core observation is that eviction pressure should not be
//! uniform across heads — DMS wins because its decisions are *learned*
//! per (layer, head). This module makes budget allocation a first-class
//! axis for the training-free policies too: a [`BudgetPlan`] assigns
//! every (layer, KV-head) pair its own token budget, always summing to
//! the App. F.1 global budget
//!
//! ```text
//! global = ceil((input_len + max_gen) / CR) × layers × kv_heads
//! ```
//!
//! Plans are produced by pluggable [`BudgetAllocator`] strategies:
//!
//! * [`UniformAllocator`] — every head gets the same per-head budget.
//!   Bit-exact with the legacy scalar `budget()` rule (the default and
//!   the `paper_fidelity` pin).
//! * [`PyramidAllocator`] — depth-decayed, front-loaded layers (weight
//!   `layers − l`): early layers, whose keys feed every later block,
//!   keep more tokens (the PyramidKV/Keyformer observation that
//!   attention mass concentrates in shallow layers).
//! * [`AdaptiveAllocator`] — re-planned from per-head attention
//!   statistics accumulated during prefill and decode in a lane-local
//!   [`AttnStats`]: each head's weight is the *perplexity* of its
//!   attention distribution (the effective number of attended tokens),
//!   so diffuse heads keep large budgets and sharply-peaked heads give
//!   theirs up.
//!
//! Conservation invariant (property-tested): for any allocator, any
//! weights, `plan.total(layers, kv_heads) == global` whenever
//! `global ≥ layers × kv_heads`, and every cell gets at least the
//! allocator floor (per-head rounding is resolved by largest-remainder
//! apportionment with deterministic index tie-breaks).

use std::str::FromStr;

use anyhow::bail;

/// Per-(layer, KV-head) token budget map.
///
/// `Uniform` is shape-free — it broadcasts one per-head budget to any
/// geometry and is bit-exact with the pre-plan scalar budget rule.
/// `PerHead` carries explicit budgets laid out `[layers × kv_heads]`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum BudgetPlan {
    /// Every (layer, head) gets the same App. F.1 per-head budget.
    Uniform {
        /// Tokens each (layer, KV-head) pair may keep live.
        per_head: usize,
    },
    /// Explicit per-(layer, kv-head) budgets.
    PerHead {
        /// Layer count the plan was shaped for.
        layers: usize,
        /// KV-head count per layer.
        kv_heads: usize,
        /// Budgets, `[layers × kv_heads]`, row-major by layer.
        per_lh: Vec<usize>,
    },
}

impl BudgetPlan {
    /// Shape-free uniform plan (legacy scalar budget, exactly).
    pub fn uniform(per_head: usize) -> Self {
        BudgetPlan::Uniform { per_head }
    }

    /// Explicit plan over a `[layers × kv_heads]` budget vector.
    ///
    /// # Panics
    /// Panics when `per_lh.len() != layers * kv_heads`.
    pub fn per_head(layers: usize, kv_heads: usize, per_lh: Vec<usize>) -> Self {
        assert_eq!(per_lh.len(), layers * kv_heads, "plan shape mismatch");
        BudgetPlan::PerHead {
            layers,
            kv_heads,
            per_lh,
        }
    }

    /// Token budget of (layer `l`, KV-head `h`).
    #[inline]
    pub fn budget(&self, l: usize, h: usize) -> usize {
        match self {
            BudgetPlan::Uniform { per_head } => *per_head,
            BudgetPlan::PerHead {
                kv_heads, per_lh, ..
            } => per_lh[l * kv_heads + h],
        }
    }

    /// Whether every cell carries the same budget.
    pub fn is_uniform(&self) -> bool {
        self.uniform_budget().is_some()
    }

    /// The common per-head budget, if the plan is uniform.
    pub fn uniform_budget(&self) -> Option<usize> {
        match self {
            BudgetPlan::Uniform { per_head } => Some(*per_head),
            BudgetPlan::PerHead { per_lh, .. } => {
                let first = *per_lh.first()?;
                per_lh.iter().all(|&b| b == first).then_some(first)
            }
        }
    }

    /// Sum of budgets over a `(layers, kv_heads)` geometry — the global
    /// App. F.1 budget the plan conserves.
    pub fn total(&self, layers: usize, kv_heads: usize) -> usize {
        match self {
            BudgetPlan::Uniform { per_head } => per_head * layers * kv_heads,
            BudgetPlan::PerHead {
                layers: pl,
                kv_heads: ph,
                per_lh,
            } => {
                debug_assert_eq!((*pl, *ph), (layers, kv_heads), "plan shape mismatch");
                per_lh.iter().sum()
            }
        }
    }

    /// Smallest per-head budget in the plan.
    pub fn min_budget(&self) -> usize {
        match self {
            BudgetPlan::Uniform { per_head } => *per_head,
            BudgetPlan::PerHead { per_lh, .. } => {
                per_lh.iter().copied().min().unwrap_or(0)
            }
        }
    }

    /// Largest per-head budget in the plan.
    pub fn max_budget(&self) -> usize {
        match self {
            BudgetPlan::Uniform { per_head } => *per_head,
            BudgetPlan::PerHead { per_lh, .. } => {
                per_lh.iter().copied().max().unwrap_or(0)
            }
        }
    }

    /// Mean per-head budget, rounded up (what Quest's scalar page
    /// budget consumes — page selection runs inside the decode HLO,
    /// which takes one `k` for the whole batch).
    pub fn mean_budget_ceil(&self, layers: usize, kv_heads: usize) -> usize {
        let cells = (layers * kv_heads).max(1);
        self.total(layers, kv_heads).div_ceil(cells)
    }

    /// Effective compression ratio of the plan against a dense cache of
    /// `max_total_len` tokens per head.
    pub fn effective_cr(&self, max_total_len: usize, layers: usize, kv_heads: usize) -> f64 {
        let total = self.total(layers, kv_heads);
        if total == 0 {
            return 1.0;
        }
        (max_total_len * layers * kv_heads) as f64 / total as f64
    }
}

// ----------------------------------------------------------------------
// Lane-local attention statistics
// ----------------------------------------------------------------------

/// Per-(layer, KV-head) attention statistics accumulated over a chain's
/// lifetime — the adaptive allocator's input signal.
///
/// Two streams feed it:
///
/// * **decode** — [`AttnStats::observe_attn`] consumes the per-step
///   attention view the executor already returns (mass per slot plus
///   the self-attention term) and accumulates, per head, the total mass
///   and the Shannon entropy of the step's normalized distribution;
/// * **prefill** — [`AttnStats::observe_alpha`] consumes the retrofit's
///   per-position α (DMS variants export it chunk-wise) and accumulates
///   the keep-probability `1 − α` as retention mass.
///
/// The allocator weight of a head is its **attention perplexity**
/// `exp(mean entropy)` — the effective number of attended tokens. A
/// head that attends diffusely genuinely needs many resident tokens; a
/// sharply-peaked head can live on a small budget (the Keyformer
/// observation). Retention mass is the fallback weight when no decode
/// entropy has accumulated yet (and a diagnostic otherwise); note the
/// current zoo's budgeted policies run on the base model, which
/// exports no prefill α, so in practice the decode entropy signal
/// dominates adaptive plans.
///
/// Stats are lane-local and restart empty on admission; a preempted
/// chain re-accumulates after resume (re-planning is cheap and the
/// signal converges within a few decode steps).
#[derive(Clone, Debug, Default)]
pub struct AttnStats {
    layers: usize,
    kv_heads: usize,
    /// Cumulative attention mass per (layer, head).
    mass: Vec<f64>,
    /// Cumulative per-step Shannon entropy (nats) per (layer, head).
    entropy: Vec<f64>,
    /// Decode observations folded in.
    steps: u64,
}

impl AttnStats {
    /// Empty stats; shape latches on the first observation.
    pub fn new() -> Self {
        Self::default()
    }

    fn ensure(&mut self, layers: usize, kv_heads: usize) {
        if self.layers != layers || self.kv_heads != kv_heads {
            self.layers = layers;
            self.kv_heads = kv_heads;
            self.mass = vec![0.0; layers * kv_heads];
            self.entropy = vec![0.0; layers * kv_heads];
            self.steps = 0;
        }
    }

    /// Fold in one decode step's attention view (`attn` laid out
    /// `[layers × kv_heads × slots]`, `attn_self` `[layers × kv_heads]`).
    pub fn observe_attn(
        &mut self,
        layers: usize,
        kv_heads: usize,
        slots: usize,
        attn: &[f32],
        attn_self: &[f32],
    ) {
        self.ensure(layers, kv_heads);
        for lh in 0..layers * kv_heads {
            let row = &attn[lh * slots..(lh + 1) * slots];
            let self_mass = attn_self.get(lh).copied().unwrap_or(0.0) as f64;
            let mut total = self_mass;
            for &a in row {
                total += a as f64;
            }
            self.mass[lh] += total;
            if total > 0.0 {
                let mut h = 0.0f64;
                for &a in row {
                    let p = a as f64 / total;
                    if p > 0.0 {
                        h -= p * p.ln();
                    }
                }
                let p = self_mass / total;
                if p > 0.0 {
                    h -= p * p.ln();
                }
                self.entropy[lh] += h;
            }
        }
        self.steps += 1;
    }

    /// Fold in one prefill position's α vector (`[layers × kv_heads]`):
    /// the keep-probability `1 − α` accumulates as retention mass.
    /// Does not count as an entropy step (no attention view exists).
    pub fn observe_alpha(&mut self, layers: usize, kv_heads: usize, alpha: &[f32]) {
        self.ensure(layers, kv_heads);
        for lh in 0..layers * kv_heads {
            let a = alpha.get(lh).copied().unwrap_or(0.0) as f64;
            self.mass[lh] += (1.0 - a).max(0.0);
        }
    }

    /// Decode observations folded in so far.
    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Cumulative attention mass per (layer, head).
    pub fn mass(&self) -> &[f64] {
        &self.mass
    }

    /// Attention perplexity per (layer, head): `exp(mean entropy)` —
    /// the effective attended-token count driving adaptive plans.
    /// Empty (no decode steps) when stats carry no signal yet.
    pub fn perplexities(&self) -> Vec<f64> {
        if self.steps == 0 {
            return Vec::new();
        }
        self.entropy
            .iter()
            .map(|&e| (e / self.steps as f64).exp())
            .collect()
    }
}

// ----------------------------------------------------------------------
// Allocators
// ----------------------------------------------------------------------

/// Budget-allocator selector (`--allocator`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum AllocatorKind {
    /// Equal per-head budgets — bit-exact with the legacy scalar rule.
    #[default]
    Uniform,
    /// Depth-decayed budgets, front-loaded shallow layers.
    Pyramid,
    /// Re-planned from lane-local [`AttnStats`] perplexities.
    Adaptive,
}

impl AllocatorKind {
    /// CLI/config spelling.
    pub fn name(&self) -> &'static str {
        match self {
            AllocatorKind::Uniform => "uniform",
            AllocatorKind::Pyramid => "pyramid",
            AllocatorKind::Adaptive => "adaptive",
        }
    }

    /// All selectable allocators (sweep/bench iteration order).
    pub fn all() -> [AllocatorKind; 3] {
        [
            AllocatorKind::Uniform,
            AllocatorKind::Pyramid,
            AllocatorKind::Adaptive,
        ]
    }
}

impl FromStr for AllocatorKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Ok(match s {
            "uniform" => AllocatorKind::Uniform,
            "pyramid" => AllocatorKind::Pyramid,
            "adaptive" => AllocatorKind::Adaptive,
            other => bail!(
                "unknown allocator '{other}' (expected uniform, pyramid, or adaptive)"
            ),
        })
    }
}

/// A pluggable budget-allocation strategy: distribute the global
/// App. F.1 budget over `(layers, kv_heads)` cells.
pub trait BudgetAllocator: Send {
    /// Which strategy this is.
    fn kind(&self) -> AllocatorKind;

    /// Produce a plan whose budgets sum to exactly `global` (whenever
    /// `global ≥ layers × kv_heads`; see [`apportion`]). `stats` feeds
    /// signal-driven strategies; signal-free ones ignore it.
    fn plan(
        &self,
        layers: usize,
        kv_heads: usize,
        global: usize,
        stats: Option<&AttnStats>,
    ) -> BudgetPlan;
}

/// Fraction of the equal share every cell is guaranteed under the
/// non-uniform allocators (the floor keeps starved heads functional —
/// an empty head would break attention entirely).
pub const MIN_SHARE: f64 = 0.25;

fn floor_per_cell(global: usize, cells: usize) -> usize {
    let equal = global as f64 / cells as f64;
    (((MIN_SHARE * equal) as usize).max(1)).min(global / cells.max(1))
}

/// Largest-remainder apportionment of `global` tokens over weighted
/// cells with a guaranteed `min_per_cell` floor (clamped to the equal
/// share). Deterministic: fractional-part ties break by ascending cell
/// index. The result sums to exactly `global` whenever
/// `global ≥ min_per_cell × cells`.
pub fn apportion(global: usize, weights: &[f64], min_per_cell: usize) -> Vec<usize> {
    let n = weights.len();
    assert!(n > 0, "apportion over zero cells");
    let floor = min_per_cell.min(global / n);
    let rem = global - floor * n;
    let mut w: Vec<f64> = weights
        .iter()
        .map(|&x| if x.is_finite() && x > 0.0 { x } else { 0.0 })
        .collect();
    let total_w: f64 = w.iter().sum();
    if total_w <= 0.0 {
        w.iter_mut().for_each(|x| *x = 1.0);
    }
    let total_w: f64 = w.iter().sum();
    let quotas: Vec<f64> = w.iter().map(|&x| rem as f64 * x / total_w).collect();
    let mut base: Vec<usize> = quotas.iter().map(|&q| q as usize).collect();
    let mut assigned: usize = base.iter().sum();
    // float-error guard: truncation can only undershoot in exact
    // arithmetic, but quota sums may carry rounding; normalize both ways
    while assigned > rem {
        let i = (0..n).max_by_key(|&i| (base[i], std::cmp::Reverse(i))).unwrap();
        base[i] -= 1;
        assigned -= 1;
    }
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| {
        let fa = quotas[a] - base[a] as f64;
        let fb = quotas[b] - base[b] as f64;
        fb.partial_cmp(&fa).unwrap().then(a.cmp(&b))
    });
    for &i in order.iter().take(rem - assigned) {
        base[i] += 1;
    }
    base.iter_mut().for_each(|b| *b += floor);
    base
}

/// Equal per-head budgets; with `global` an exact multiple of the cell
/// count (how the engine always builds it) every cell gets exactly the
/// legacy scalar budget.
pub struct UniformAllocator;

impl BudgetAllocator for UniformAllocator {
    fn kind(&self) -> AllocatorKind {
        AllocatorKind::Uniform
    }

    fn plan(
        &self,
        layers: usize,
        kv_heads: usize,
        global: usize,
        _stats: Option<&AttnStats>,
    ) -> BudgetPlan {
        let n = layers * kv_heads;
        let per_lh = apportion(global, &vec![1.0; n], global / n.max(1));
        BudgetPlan::per_head(layers, kv_heads, per_lh)
    }
}

/// Depth-decayed budgets: layer `l` weighs `layers − l`, both heads of
/// a layer equally. Shallow layers — whose keys condition every later
/// block — keep the most tokens.
pub struct PyramidAllocator;

impl BudgetAllocator for PyramidAllocator {
    fn kind(&self) -> AllocatorKind {
        AllocatorKind::Pyramid
    }

    fn plan(
        &self,
        layers: usize,
        kv_heads: usize,
        global: usize,
        _stats: Option<&AttnStats>,
    ) -> BudgetPlan {
        let n = layers * kv_heads;
        let mut weights = Vec::with_capacity(n);
        for l in 0..layers {
            for _ in 0..kv_heads {
                weights.push((layers - l) as f64);
            }
        }
        let per_lh = apportion(global, &weights, floor_per_cell(global, n));
        BudgetPlan::per_head(layers, kv_heads, per_lh)
    }
}

/// Attention-statistics-driven budgets: each head weighs its attention
/// perplexity (effective attended-token count) from the lane's
/// [`AttnStats`]. Without signal (fresh chain, no decode steps yet) it
/// falls back to the uniform split — adaptive chains start uniform and
/// re-plan as statistics accrue.
pub struct AdaptiveAllocator;

impl BudgetAllocator for AdaptiveAllocator {
    fn kind(&self) -> AllocatorKind {
        AllocatorKind::Adaptive
    }

    fn plan(
        &self,
        layers: usize,
        kv_heads: usize,
        global: usize,
        stats: Option<&AttnStats>,
    ) -> BudgetPlan {
        let n = layers * kv_heads;
        // primary signal: attention perplexity from decode steps;
        // fallback: accumulated retention mass (prefill α feeds this),
        // for chains that carry α signal but no attention views yet;
        // no signal at all → the uniform split.
        let mut weights = stats
            .map(|s| s.perplexities())
            .filter(|w| w.len() == n)
            .unwrap_or_default();
        if weights.is_empty() {
            if let Some(s) = stats {
                if s.mass().len() == n && s.mass().iter().any(|&m| m > 0.0) {
                    weights = s.mass().to_vec();
                }
            }
        }
        if weights.is_empty() {
            return UniformAllocator.plan(layers, kv_heads, global, None);
        }
        let per_lh = apportion(global, &weights, floor_per_cell(global, n));
        BudgetPlan::per_head(layers, kv_heads, per_lh)
    }
}

/// Build an allocator instance.
pub fn build_allocator(kind: AllocatorKind) -> Box<dyn BudgetAllocator> {
    match kind {
        AllocatorKind::Uniform => Box::new(UniformAllocator),
        AllocatorKind::Pyramid => Box::new(PyramidAllocator),
        AllocatorKind::Adaptive => Box::new(AdaptiveAllocator),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocator_kind_parse_roundtrip() {
        for kind in AllocatorKind::all() {
            let parsed: AllocatorKind = kind.name().parse().unwrap();
            assert_eq!(parsed, kind);
        }
        assert!("zipf".parse::<AllocatorKind>().is_err());
        assert_eq!(AllocatorKind::default(), AllocatorKind::Uniform);
    }

    #[test]
    fn uniform_plan_matches_legacy_scalar() {
        let plan = UniformAllocator.plan(4, 2, 40 * 8, None);
        for l in 0..4 {
            for h in 0..2 {
                assert_eq!(plan.budget(l, h), 40);
            }
        }
        assert!(plan.is_uniform());
        assert_eq!(plan.uniform_budget(), Some(40));
        assert_eq!(plan.total(4, 2), 320);
    }

    #[test]
    fn shapeless_uniform_broadcasts() {
        let plan = BudgetPlan::uniform(13);
        assert_eq!(plan.budget(0, 0), 13);
        assert_eq!(plan.budget(7, 3), 13);
        assert_eq!(plan.total(3, 2), 78);
        assert_eq!(plan.min_budget(), 13);
        assert_eq!(plan.max_budget(), 13);
        assert_eq!(plan.mean_budget_ceil(3, 2), 13);
    }

    #[test]
    fn pyramid_front_loads_shallow_layers() {
        let plan = PyramidAllocator.plan(4, 2, 320, None);
        assert_eq!(plan.total(4, 2), 320, "conservation");
        // floor = 0.25 × 40 = 10; remainder 240 over weights 4:3:2:1
        assert_eq!(plan.budget(0, 0), 58);
        assert_eq!(plan.budget(1, 0), 46);
        assert_eq!(plan.budget(2, 0), 34);
        assert_eq!(plan.budget(3, 0), 22);
        assert_eq!(plan.budget(0, 0), plan.budget(0, 1), "heads equal per layer");
        assert!(plan.budget(0, 0) > plan.budget(3, 0));
        assert!(!plan.is_uniform());
    }

    #[test]
    fn adaptive_without_stats_falls_back_to_uniform() {
        let plan = AdaptiveAllocator.plan(2, 2, 100, None);
        assert_eq!(plan.total(2, 2), 100);
        assert_eq!(plan.budget(0, 0), 25);
        let empty = AttnStats::new();
        let plan = AdaptiveAllocator.plan(2, 2, 100, Some(&empty));
        assert_eq!(plan.budget(1, 1), 25);
    }

    #[test]
    fn adaptive_gives_diffuse_heads_more_budget() {
        let (layers, heads, slots) = (1usize, 2usize, 8usize);
        let mut stats = AttnStats::new();
        // head 0: all mass on one slot (zero entropy); head 1: spread
        let mut attn = vec![0.0f32; heads * slots];
        attn[0] = 1.0;
        for s in 0..slots {
            attn[slots + s] = 0.125;
        }
        for _ in 0..4 {
            stats.observe_attn(layers, heads, slots, &attn, &[0.0, 0.0]);
        }
        let plan = AdaptiveAllocator.plan(layers, heads, 64, Some(&stats));
        assert_eq!(plan.total(1, 2), 64);
        assert!(
            plan.budget(0, 1) > plan.budget(0, 0),
            "diffuse head must out-budget the peaked head: {:?} vs {:?}",
            plan.budget(0, 1),
            plan.budget(0, 0)
        );
        // floor: the peaked head still keeps ≥ 25% of the equal share
        assert!(plan.budget(0, 0) >= 8);
    }

    #[test]
    fn adaptive_falls_back_to_mass_without_entropy_signal() {
        // prefill α only, no decode steps: perplexities are empty and
        // the accumulated keep-mass (1 − α) drives the weights
        let mut stats = AttnStats::new();
        stats.observe_alpha(1, 2, &[0.9, 0.1]);
        assert_eq!(stats.steps(), 0);
        let plan = AdaptiveAllocator.plan(1, 2, 64, Some(&stats));
        assert_eq!(plan.total(1, 2), 64);
        assert!(
            plan.budget(0, 1) > plan.budget(0, 0),
            "the head retaining more mass gets the bigger budget"
        );
    }

    #[test]
    fn plans_conserve_global_budget_property() {
        let allocs: Vec<Box<dyn BudgetAllocator>> = vec![
            Box::new(UniformAllocator),
            Box::new(PyramidAllocator),
            Box::new(AdaptiveAllocator),
        ];
        let mut stats = AttnStats::new();
        let attn: Vec<f32> = (0..3 * 2 * 16).map(|i| (i % 7) as f32 * 0.25).collect();
        stats.observe_attn(3, 2, 16, &attn, &[0.5f32; 6]);
        for alloc in &allocs {
            for layers in 1..=4usize {
                for kv_heads in 1..=3usize {
                    for per_head in [1usize, 5, 17, 40] {
                        let n = layers * kv_heads;
                        let global = per_head * n;
                        let st = if (layers, kv_heads) == (3, 2) {
                            Some(&stats)
                        } else {
                            None
                        };
                        let plan = alloc.plan(layers, kv_heads, global, st);
                        assert_eq!(
                            plan.total(layers, kv_heads),
                            global,
                            "{:?} leaked budget at {layers}x{kv_heads}x{per_head}",
                            alloc.kind()
                        );
                        assert!(plan.min_budget() >= 1, "starved head");
                    }
                }
            }
        }
    }

    #[test]
    fn apportion_is_deterministic_and_exact() {
        let w = [1.0, 1.0, 1.0];
        assert_eq!(apportion(10, &w, 0), vec![4, 3, 3]);
        // zero/negative weights fall back to equal shares
        assert_eq!(apportion(9, &[0.0, -1.0, 0.0], 0), vec![3, 3, 3]);
        // floor is honored and clamped
        let out = apportion(8, &[100.0, 1.0], 3);
        assert_eq!(out.iter().sum::<usize>(), 8);
        assert!(out[1] >= 3);
    }

    #[test]
    fn effective_cr_reflects_plan_totals() {
        let plan = BudgetPlan::uniform(40);
        assert!((plan.effective_cr(160, 4, 2) - 4.0).abs() < 1e-12);
        let plan = BudgetPlan::per_head(1, 2, vec![20, 60]);
        assert!((plan.effective_cr(160, 1, 2) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn attn_stats_accumulate_mass_and_alpha() {
        let mut s = AttnStats::new();
        s.observe_attn(1, 1, 4, &[0.25, 0.25, 0.25, 0.25], &[0.0]);
        assert_eq!(s.steps(), 1);
        assert!((s.mass()[0] - 1.0).abs() < 1e-9);
        // uniform over 4 slots → perplexity 4
        assert!((s.perplexities()[0] - 4.0).abs() < 1e-6);
        s.observe_alpha(1, 1, &[0.25]);
        assert!((s.mass()[0] - 1.75).abs() < 1e-9);
        assert_eq!(s.steps(), 1, "alpha observations are not entropy steps");
    }
}
