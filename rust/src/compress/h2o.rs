//! H2O — Heavy-Hitter Oracle (Zhang et al., 2023).
//!
//! The budget is split evenly between a recent-token window and a
//! heavy-hitter set (App. F.1). Cumulative attention scores accumulate
//! each step; on overflow the lowest-cumulative non-recent token is
//! evicted.
//!
//! Scoring follows the reference layer-wide rule (mass summed over the
//! layer's KV heads, as in TOVA), but both the score table and the
//! **enforcement loop are head-granular**: `cum` is kept per (layer,
//! head, slot) — each head accumulates the layer-summed mass and
//! resets a slot's score only when *it* evicts that slot — so a
//! non-uniform [`BudgetPlan`] holds for every head. The pre-plan
//! implementation probed head 0's live count and evicted the same slot
//! across all heads; under a uniform plan the heads stay in lockstep
//! (identical live sets, scores, and reset history), making the
//! uniform path bit-exact with that legacy coupled eviction.
//! Enforcement is a two-phase partial-select per (layer, head) —
//! heavy-hitter candidates by (cum, slot), then oldest-first fallback
//! — evicting the exact set the legacy per-eviction rescan chose in
//! O(live) per overflow instead of O(evictions × live).
//!
//! Knobs: a [`BudgetPlan`] (uniform = App. F.1 (input + max_gen) / CR
//! per head); the recent window is each head's budget / 2. See
//! `docs/POLICIES.md`.

use super::budget::BudgetPlan;
use super::{Policy, PolicyKind, StepView};
use crate::kvcache::CacheStore;

pub struct H2oPolicy {
    plan: BudgetPlan,
    /// cumulative layer-summed attention per (layer, head, slot)
    cum: Vec<f32>,
    /// Live-slot scratch for the batched eviction select.
    live: Vec<(usize, usize)>,
    /// `(cum, slot)` heavy-hitter candidates, partial-selected per head.
    cand: Vec<(f32, usize)>,
}

impl H2oPolicy {
    pub fn new(plan: BudgetPlan) -> Self {
        Self {
            plan,
            cum: Vec::new(),
            live: Vec::new(),
            cand: Vec::new(),
        }
    }

    fn ensure(&mut self, layers: usize, kv_heads: usize, slots: usize) {
        if self.cum.len() != layers * kv_heads * slots {
            self.cum = vec![0.0; layers * kv_heads * slots];
        }
    }
}

impl Policy for H2oPolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::H2o
    }

    fn plan(&self) -> Option<&BudgetPlan> {
        Some(&self.plan)
    }

    fn install_plan(&mut self, plan: BudgetPlan) {
        self.plan = plan;
    }

    fn post_write(&mut self, cache: &mut CacheStore, view: &StepView<'_>) {
        let g = cache.geom;
        self.ensure(g.layers, g.kv_heads, g.slots);
        // accumulate this step's attention mass (summed over the
        // layer's KV heads, credited to every head's own score table)
        for l in 0..g.layers {
            for slot in 0..g.slots {
                let mut mass = 0.0f32;
                for h in 0..g.kv_heads {
                    mass += view.attn[(l * g.kv_heads + h) * g.slots + slot];
                }
                for h in 0..g.kv_heads {
                    self.cum[(l * g.kv_heads + h) * g.slots + slot] += mass;
                }
            }
        }
        for l in 0..g.layers {
            for h in 0..g.kv_heads {
                let budget = self.plan.budget(l, h);
                let recent = budget / 2;
                let cutoff = view.pos.saturating_sub(recent);
                let live_n = cache.live_count(view.lane, l, h);
                if live_n <= budget {
                    continue;
                }
                let mut n_evict = live_n - budget;
                let base = (l * g.kv_heads + h) * g.slots;
                // Batched equivalent of the legacy per-eviction rescan.
                // The loop preferred the lowest-(cum, slot) candidate
                // outside the recent window for as long as one existed
                // (its strict `<` never selected NaN/+inf scores),
                // then fell back to oldest-first over whatever was
                // left. Candidate scores are static across the
                // overflow (only *evicted* slots get reset), so the
                // evicted set is: phase 1, the k1 smallest (cum, slot)
                // candidates; phase 2, the remaining r smallest
                // (pos, slot) of the surviving live set.
                cache.live_slots_into(view.lane, l, h, &mut self.live);
                self.cand.clear();
                for &(slot, pos) in &self.live {
                    if pos >= cutoff {
                        continue;
                    }
                    let score = self.cum[base + slot];
                    if score < f32::INFINITY {
                        self.cand.push((score, slot));
                    }
                }
                let k1 = n_evict.min(self.cand.len());
                if k1 > 0 {
                    if k1 < self.cand.len() {
                        self.cand
                            .select_nth_unstable_by(k1, super::score_slot_order);
                    }
                    for &(_, slot) in self.cand.iter().take(k1) {
                        cache.evict(view.lane, l, h, slot);
                        self.cum[base + slot] = 0.0;
                    }
                    n_evict -= k1;
                }
                if n_evict > 0 {
                    // all candidates spent → oldest-first fallback
                    cache.live_slots_into(view.lane, l, h, &mut self.live);
                    let k2 = n_evict.min(self.live.len());
                    if k2 < self.live.len() {
                        self.live
                            .select_nth_unstable_by_key(k2, |&(slot, pos)| (pos, slot));
                    }
                    for &(slot, _) in self.live.iter().take(k2) {
                        cache.evict(view.lane, l, h, slot);
                        self.cum[base + slot] = 0.0;
                    }
                }
            }
        }
    }

    fn post_prefill(&mut self, cache: &mut CacheStore, lane: usize, _pos: usize) {
        // dense prefill until budget, then switch (App. F.1); without
        // prefill scores the heavy set starts from the recency prior.
        super::window::trim_to_plan_with(cache, lane, &self.plan, &mut self.live);
        // this path also runs at adaptive re-plans mid-decode: any
        // slot the trim freed must not carry its accumulated mass
        // into the token that later recycles it (the post_write
        // eviction path resets per-slot scores the same way). At
        // prefill end the table is still empty, so this is a no-op
        // there — the uniform legacy path is untouched.
        if !self.cum.is_empty() {
            let g = cache.geom;
            for l in 0..g.layers {
                for h in 0..g.kv_heads {
                    for s in 0..g.slots {
                        if cache.slot_pos(lane, l, h, s).is_none() {
                            self.cum[(l * g.kv_heads + h) * g.slots + s] = 0.0;
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::Geometry;

    fn store() -> CacheStore {
        CacheStore::new(
            Geometry {
                layers: 1,
                kv_heads: 1,
                slots: 8,
                head_dim: 2,
                page_size: 4,
            },
            1,
        )
    }

    fn fill(c: &mut CacheStore, n: usize) {
        for pos in 0..n {
            let s = c.alloc_slot(0, 0, 0).unwrap();
            c.write(0, 0, 0, s, pos, &[0.0; 2], &[0.0; 2]);
        }
    }

    #[test]
    fn evicts_lowest_cumulative_outside_recent() {
        let mut c = store();
        fill(&mut c, 5);
        let mut p = H2oPolicy::new(BudgetPlan::uniform(4)); // recent window = 2
        let mut attn = vec![0.0f32; 8];
        // slots 0..4 hold positions 0..4; pos cutoff = 5-2 = 3
        attn[0] = 0.9; // heavy hitter
        attn[1] = 0.05; // light — should be evicted
        attn[2] = 0.4;
        p.post_write(
            &mut c,
            &StepView {
                lane: 0,
                pos: 5,
                alpha: &[0.0],
                attn: &attn,
                attn_self: &[0.0],
                written: &[],
            },
        );
        assert_eq!(c.live_count(0, 0, 0), 4);
        assert!(c.slot_pos(0, 0, 0, 1).is_none());
        assert!(c.slot_pos(0, 0, 0, 0).is_some(), "heavy hitter kept");
    }

    #[test]
    fn recent_window_is_protected() {
        let mut c = store();
        fill(&mut c, 5);
        let mut p = H2oPolicy::new(BudgetPlan::uniform(4));
        let attn = vec![0.0f32; 8];
        p.post_write(
            &mut c,
            &StepView {
                lane: 0,
                pos: 4,
                alpha: &[0.0],
                attn: &attn,
                attn_self: &[0.0],
                written: &[],
            },
        );
        // positions >= 4-2=2 are protected; eviction hit pos 0 or 1
        let kept: Vec<usize> = c.live_slots(0, 0, 0).iter().map(|&(_, p)| p).collect();
        assert!(kept.contains(&2) && kept.contains(&3) && kept.contains(&4));
    }

    #[test]
    fn accumulates_across_steps() {
        let mut c = store();
        fill(&mut c, 3);
        let mut p = H2oPolicy::new(BudgetPlan::uniform(2)); // force eviction pressure
        let mut attn = vec![0.0f32; 8];
        attn[0] = 0.3;
        attn[1] = 0.2;
        attn[2] = 0.1;
        // two steps of accumulation then overflow
        p.post_write(
            &mut c,
            &StepView {
                lane: 0,
                pos: 3,
                alpha: &[0.0],
                attn: &attn,
                attn_self: &[0.0],
                written: &[],
            },
        );
        assert_eq!(c.live_count(0, 0, 0), 2);
    }

    #[test]
    fn per_head_budgets_and_score_tables_are_independent() {
        let mut c = CacheStore::new(
            Geometry {
                layers: 1,
                kv_heads: 2,
                slots: 8,
                head_dim: 2,
                page_size: 4,
            },
            1,
        );
        for pos in 0..6 {
            for h in 0..2 {
                let s = c.alloc_slot(0, 0, h).unwrap();
                c.write(0, 0, h, s, pos, &[0.0; 2], &[0.0; 2]);
            }
        }
        // head 0 may keep 6, head 1 only 2 — the old head-0 probe would
        // never have evicted anything here
        let mut p = H2oPolicy::new(BudgetPlan::per_head(1, 2, vec![6, 2]));
        let attn: Vec<f32> = (0..2 * 8).map(|i| (i % 5) as f32 * 0.125).collect();
        p.post_write(
            &mut c,
            &StepView {
                lane: 0,
                pos: 6,
                alpha: &[0.0; 2],
                attn: &attn,
                attn_self: &[0.0; 2],
                written: &[],
            },
        );
        assert_eq!(c.live_count(0, 0, 0), 6, "head 0 untouched");
        assert_eq!(c.live_count(0, 0, 1), 2, "head 1's own budget holds");
        // head 1's evictions reset only its own score rows
        let evicted: Vec<usize> = (0..8)
            .filter(|&s| c.slot_pos(0, 0, 1, s).is_none())
            .collect();
        assert_eq!(evicted.len(), 4);
        for s in evicted {
            assert_eq!(p.cum[8 + s], 0.0, "head 1 row reset");
            assert!(p.cum[s] > 0.0 || attn[s] + attn[8 + s] == 0.0, "head 0 rows kept");
        }
    }
}
