//! H2O — Heavy-Hitter Oracle (Zhang et al., 2023).
//!
//! The budget is split evenly between a recent-token window and a
//! heavy-hitter set (App. F.1). Cumulative attention scores accumulate
//! per slot each step; on overflow the lowest-cumulative non-recent
//! token is evicted (layer-wide, like TOVA).
//!
//! Knobs: token `budget` per head (App. F.1: (input + max_gen) / CR);
//! the recent window is fixed to budget / 2. See `docs/POLICIES.md`.

use super::{Policy, PolicyKind, StepView};
use crate::kvcache::CacheStore;

pub struct H2oPolicy {
    budget: usize,
    recent: usize,
    /// cumulative attention per (layer, slot)
    cum: Vec<f32>,
    layers: usize,
    slots: usize,
}

impl H2oPolicy {
    pub fn new(budget: usize) -> Self {
        Self {
            budget,
            recent: budget / 2,
            cum: Vec::new(),
            layers: 0,
            slots: 0,
        }
    }

    fn ensure(&mut self, layers: usize, slots: usize) {
        if self.cum.len() != layers * slots {
            self.layers = layers;
            self.slots = slots;
            self.cum = vec![0.0; layers * slots];
        }
    }
}

impl Policy for H2oPolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::H2o
    }

    fn budget(&self) -> Option<usize> {
        Some(self.budget)
    }

    fn post_write(&mut self, cache: &mut CacheStore, view: &StepView<'_>) {
        let g = cache.geom;
        self.ensure(g.layers, g.slots);
        // accumulate this step's attention mass (summed over KV heads)
        for l in 0..g.layers {
            for slot in 0..g.slots {
                let mut mass = 0.0f32;
                for h in 0..g.kv_heads {
                    mass += view.attn[(l * g.kv_heads + h) * g.slots + slot];
                }
                self.cum[l * g.slots + slot] += mass;
            }
        }
        for l in 0..g.layers {
            while cache.live_count(view.lane, l, 0) > self.budget {
                // candidates: live tokens outside the recent window
                let cutoff = view.pos.saturating_sub(self.recent);
                let mut best = None;
                let mut best_score = f32::INFINITY;
                let mut oldest: Option<(usize, usize)> = None;
                for (slot, pos) in cache.live_slots(view.lane, l, 0) {
                    if oldest.map(|(_, p)| pos < p).unwrap_or(true) {
                        oldest = Some((slot, pos));
                    }
                    if pos >= cutoff {
                        continue;
                    }
                    let score = self.cum[l * g.slots + slot];
                    if score < best_score {
                        best_score = score;
                        best = Some(slot);
                    }
                }
                // all tokens recent → fall back to evicting the oldest
                let slot = match best.or(oldest.map(|(s, _)| s)) {
                    Some(s) => s,
                    None => break,
                };
                for h in 0..g.kv_heads {
                    cache.evict(view.lane, l, h, slot);
                }
                self.cum[l * g.slots + slot] = 0.0;
            }
        }
    }

    fn post_prefill(&mut self, cache: &mut CacheStore, lane: usize, _pos: usize) {
        // dense prefill until budget, then switch (App. F.1); without
        // prefill scores the heavy set starts from the recency prior.
        super::window::trim_to_window(cache, lane, self.budget);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::Geometry;

    fn store() -> CacheStore {
        CacheStore::new(
            Geometry {
                layers: 1,
                kv_heads: 1,
                slots: 8,
                head_dim: 2,
                page_size: 4,
            },
            1,
        )
    }

    fn fill(c: &mut CacheStore, n: usize) {
        for pos in 0..n {
            let s = c.alloc_slot(0, 0, 0).unwrap();
            c.write(0, 0, 0, s, pos, &[0.0; 2], &[0.0; 2]);
        }
    }

    #[test]
    fn evicts_lowest_cumulative_outside_recent() {
        let mut c = store();
        fill(&mut c, 5);
        let mut p = H2oPolicy::new(4); // recent window = 2
        let mut attn = vec![0.0f32; 8];
        // slots 0..4 hold positions 0..4; pos cutoff = 5-2 = 3
        attn[0] = 0.9; // heavy hitter
        attn[1] = 0.05; // light — should be evicted
        attn[2] = 0.4;
        p.post_write(
            &mut c,
            &StepView {
                lane: 0,
                pos: 5,
                alpha: &[0.0],
                attn: &attn,
                attn_self: &[0.0],
                written: &[],
            },
        );
        assert_eq!(c.live_count(0, 0, 0), 4);
        assert!(c.slot_pos(0, 0, 0, 1).is_none());
        assert!(c.slot_pos(0, 0, 0, 0).is_some(), "heavy hitter kept");
    }

    #[test]
    fn recent_window_is_protected() {
        let mut c = store();
        fill(&mut c, 5);
        let mut p = H2oPolicy::new(4);
        let attn = vec![0.0f32; 8];
        p.post_write(
            &mut c,
            &StepView {
                lane: 0,
                pos: 4,
                alpha: &[0.0],
                attn: &attn,
                attn_self: &[0.0],
                written: &[],
            },
        );
        // positions >= 4-2=2 are protected; eviction hit pos 0 or 1
        let kept: Vec<usize> = c.live_slots(0, 0, 0).iter().map(|&(_, p)| p).collect();
        assert!(kept.contains(&2) && kept.contains(&3) && kept.contains(&4));
    }

    #[test]
    fn accumulates_across_steps() {
        let mut c = store();
        fill(&mut c, 3);
        let mut p = H2oPolicy::new(2); // force eviction pressure
        let mut attn = vec![0.0f32; 8];
        attn[0] = 0.3;
        attn[1] = 0.2;
        attn[2] = 0.1;
        // two steps of accumulation then overflow
        p.post_write(
            &mut c,
            &StepView {
                lane: 0,
                pos: 3,
                alpha: &[0.0],
                attn: &attn,
                attn_self: &[0.0],
                written: &[],
            },
        );
        assert_eq!(c.live_count(0, 0, 0), 2);
    }
}
