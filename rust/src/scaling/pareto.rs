//! Pareto frontiers over (budget, accuracy) points and the App. E
//! average-margin integral:
//!
//!   margin(A, B) = ∫_{x ∈ I} (A(x) − B(x)) dx / |I|
//!
//! where A(x), B(x) are the piecewise-linear interpolations of the two
//! frontiers and I is the largest budget interval both cover.
//!
//! ## Budget units and quantized payloads
//!
//! The paper's x axis counts KV reads / peak tokens in **token
//! units**, which implicitly assumes every cached token costs the same
//! bytes. With quantized page payloads (q8/q4 — see
//! `docs/NUMERICS.md`) that assumption breaks: a q8 token costs ~⅓ the
//! host bytes of an f32 token, so two configurations with equal
//! token-unit budgets differ ~3× in memory-read cost.
//! [`kv_bytes_per_token`] converts a dtype + cache geometry into a
//! bytes-per-token factor and [`with_byte_budget`] rescales a point
//! cloud onto the byte axis, so frontiers of different dtypes become
//! comparable — eviction CR × precision shrink compose
//! multiplicatively on that axis.

use crate::compress::BudgetPlan;
use crate::kvcache::KvDtype;

/// One measured scaling configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct ScalePoint {
    /// Budget (KV reads or peak tokens — x axis).
    pub budget: f64,
    /// Accuracy in [0, 1] (y axis).
    pub accuracy: f64,
    /// L-W-CR label for annotation.
    pub label: String,
}

/// A Pareto frontier: budget-ascending, accuracy-ascending points.
#[derive(Clone, Debug, Default)]
pub struct Frontier {
    pub points: Vec<ScalePoint>,
}

/// K+V payload bytes one cached token costs across the whole model
/// under `dtype`: `layers × kv_heads` (row pairs per token) ×
/// per-row storage cost (codes + scale/zero-point for the quantized
/// formats). This is the conversion factor from the §5.1 token-unit
/// budget axis to a host-byte axis.
pub fn kv_bytes_per_token(dtype: KvDtype, layers: usize, kv_heads: usize, head_dim: usize) -> f64 {
    (layers * kv_heads) as f64 * 2.0 * dtype.row_payload_bytes(head_dim) as f64
}

/// Aggregate K+V payload bytes a full [`BudgetPlan`] footprint costs
/// under `dtype`: Σ over (layer, head) cells of the cell's token
/// budget × per-row storage (K and V rows). This is the byte-axis
/// aggregate of a *non-uniform* plan; a uniform plan reduces exactly
/// to [`kv_bytes_per_token`] × per-head budget, so frontiers built
/// from planned and scalar budgets stay comparable.
pub fn plan_kv_bytes(
    plan: &BudgetPlan,
    layers: usize,
    kv_heads: usize,
    dtype: KvDtype,
    head_dim: usize,
) -> f64 {
    plan.total(layers, kv_heads) as f64 * 2.0 * dtype.row_payload_bytes(head_dim) as f64
}

/// Effective K+V payload bytes per cached token of a **tiered** prefix
/// cache: a `hot_fraction` of cached tokens resident at `hot` dtype
/// and the remainder demoted to the cold tier at `cold` dtype. The
/// cold tier's whole point on the byte axis is visible here: demoting
/// the LRU tail to q4 lets an equal-byte budget retain strictly more
/// tokens than a hot-only pool, which is the retained-token gain the
/// serve bench's cold-tier cell measures.
pub fn tiered_kv_bytes_per_token(
    hot: KvDtype,
    cold: KvDtype,
    hot_fraction: f64,
    layers: usize,
    kv_heads: usize,
    head_dim: usize,
) -> f64 {
    assert!(
        (0.0..=1.0).contains(&hot_fraction),
        "hot_fraction must be in [0, 1]"
    );
    hot_fraction * kv_bytes_per_token(hot, layers, kv_heads, head_dim)
        + (1.0 - hot_fraction) * kv_bytes_per_token(cold, layers, kv_heads, head_dim)
}

/// Rescale a point cloud's budget axis from token units to bytes
/// (`bytes_per_token` from [`kv_bytes_per_token`]). Accuracy and
/// labels are untouched; with a positive factor the Pareto-dominance
/// structure is preserved, only the axis changes meaning.
pub fn with_byte_budget(points: &[ScalePoint], bytes_per_token: f64) -> Vec<ScalePoint> {
    assert!(bytes_per_token > 0.0, "bytes/token must be positive");
    points
        .iter()
        .map(|p| ScalePoint {
            budget: p.budget * bytes_per_token,
            accuracy: p.accuracy,
            label: p.label.clone(),
        })
        .collect()
}

/// Extract the Pareto frontier (max accuracy for min budget) from a
/// point cloud: a point survives iff no other point has ≤ budget and
/// > accuracy.
pub fn frontier(points: &[ScalePoint]) -> Frontier {
    let mut sorted: Vec<&ScalePoint> = points.iter().collect();
    sorted.sort_by(|a, b| {
        a.budget
            .partial_cmp(&b.budget)
            .unwrap()
            .then(b.accuracy.partial_cmp(&a.accuracy).unwrap())
    });
    let mut out: Vec<ScalePoint> = Vec::new();
    let mut best = f64::NEG_INFINITY;
    for p in sorted {
        // keep weakly-dominated ties: a flat terminal segment extends
        // the frontier's budget range, which the App. E margin integral
        // relies on (accuracy never decreases with more budget).
        if p.accuracy > best {
            best = p.accuracy;
            out.push(p.clone());
        } else if p.accuracy == best
            && out.last().map(|q| p.budget > q.budget).unwrap_or(false)
        {
            out.push(p.clone());
        }
    }
    Frontier { points: out }
}

impl Frontier {
    /// Interpolated accuracy at `budget` (linear between frontier
    /// points; clamped at the ends). None outside the covered range.
    pub fn at(&self, budget: f64) -> Option<f64> {
        let pts = &self.points;
        if pts.is_empty() || budget < pts[0].budget || budget > pts[pts.len() - 1].budget
        {
            return None;
        }
        let mut prev = &pts[0];
        for p in pts.iter().skip(1) {
            if budget <= p.budget {
                let span = p.budget - prev.budget;
                if span <= 0.0 {
                    return Some(p.accuracy.max(prev.accuracy));
                }
                let t = (budget - prev.budget) / span;
                return Some(prev.accuracy + t * (p.accuracy - prev.accuracy));
            }
            prev = p;
        }
        Some(pts[pts.len() - 1].accuracy)
    }

    pub fn budget_range(&self) -> Option<(f64, f64)> {
        if self.points.is_empty() {
            None
        } else {
            Some((
                self.points[0].budget,
                self.points[self.points.len() - 1].budget,
            ))
        }
    }
}

/// App. E average margin of frontier `a` over frontier `b` on their
/// common budget interval (trapezoid integration over the union of
/// both frontiers' knots). None when the projections are disjoint
/// (the paper's "NA" cells).
pub fn margin(a: &Frontier, b: &Frontier) -> Option<f64> {
    let (a_lo, a_hi) = a.budget_range()?;
    let (b_lo, b_hi) = b.budget_range()?;
    let lo = a_lo.max(b_lo);
    let hi = a_hi.min(b_hi);
    if hi <= lo {
        return None;
    }
    // knots: both frontiers' budgets inside [lo, hi] plus the ends
    let mut xs: Vec<f64> = vec![lo, hi];
    for p in a.points.iter().chain(&b.points) {
        if p.budget > lo && p.budget < hi {
            xs.push(p.budget);
        }
    }
    xs.sort_by(|x, y| x.partial_cmp(y).unwrap());
    xs.dedup();
    let mut integral = 0.0;
    for w in xs.windows(2) {
        let (x0, x1) = (w[0], w[1]);
        let d0 = a.at(x0)? - b.at(x0)?;
        let d1 = a.at(x1)? - b.at(x1)?;
        integral += 0.5 * (d0 + d1) * (x1 - x0);
    }
    Some(integral / (hi - lo))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(budget: f64, acc: f64) -> ScalePoint {
        ScalePoint {
            budget,
            accuracy: acc,
            label: String::new(),
        }
    }

    #[test]
    fn frontier_removes_dominated() {
        let cloud = vec![pt(1.0, 0.3), pt(2.0, 0.2), pt(2.0, 0.5), pt(3.0, 0.4)];
        let f = frontier(&cloud);
        // (2.0, 0.2) and (3.0, 0.4) are dominated
        assert_eq!(f.points.len(), 2);
        assert_eq!(f.points[0].accuracy, 0.3);
        assert_eq!(f.points[1].accuracy, 0.5);
    }

    #[test]
    fn interpolation_is_linear() {
        let f = frontier(&[pt(0.0, 0.0), pt(10.0, 1.0)]);
        assert_eq!(f.at(5.0), Some(0.5));
        assert_eq!(f.at(0.0), Some(0.0));
        assert_eq!(f.at(10.0), Some(1.0));
        assert_eq!(f.at(11.0), None);
    }

    #[test]
    fn margin_constant_gap() {
        let a = frontier(&[pt(0.0, 0.6), pt(10.0, 0.8)]);
        let b = frontier(&[pt(0.0, 0.4), pt(10.0, 0.6)]);
        let m = margin(&a, &b).unwrap();
        assert!((m - 0.2).abs() < 1e-12);
    }

    #[test]
    fn margin_on_partial_overlap() {
        let a = frontier(&[pt(5.0, 1.0), pt(20.0, 1.0)]);
        let b = frontier(&[pt(0.0, 0.5), pt(10.0, 0.5)]);
        // common interval [5, 10]; constant gap 0.5
        let m = margin(&a, &b).unwrap();
        assert!((m - 0.5).abs() < 1e-12);
    }

    #[test]
    fn margin_disjoint_is_none() {
        let a = frontier(&[pt(0.0, 1.0), pt(1.0, 1.0)]);
        let b = frontier(&[pt(5.0, 0.5), pt(6.0, 0.5)]);
        assert!(margin(&a, &b).is_none());
    }

    #[test]
    fn margin_can_be_negative() {
        let a = frontier(&[pt(0.0, 0.2), pt(10.0, 0.4)]);
        let b = frontier(&[pt(0.0, 0.5), pt(10.0, 0.7)]);
        assert!(margin(&a, &b).unwrap() < 0.0);
    }

    #[test]
    fn bytes_per_token_reflects_dtype() {
        // 4 layers × 2 heads × head_dim 16
        let f = kv_bytes_per_token(KvDtype::F32, 4, 2, 16);
        let q8 = kv_bytes_per_token(KvDtype::Q8, 4, 2, 16);
        let q4 = kv_bytes_per_token(KvDtype::Q4, 4, 2, 16);
        assert_eq!(f, 8.0 * 2.0 * 64.0);
        assert!(f / q8 >= 3.0, "q8 shrinks the byte axis ≥ 3×");
        assert!(f / q4 >= 4.5, "q4 shrinks it further");
    }

    #[test]
    fn tiered_bytes_interpolate_between_hot_and_cold() {
        let (l, h, hd) = (4, 2, 16);
        let hot = kv_bytes_per_token(KvDtype::F32, l, h, hd);
        let cold = kv_bytes_per_token(KvDtype::Q4, l, h, hd);
        // endpoints: all-hot and all-cold recover the plain factors
        assert_eq!(tiered_kv_bytes_per_token(KvDtype::F32, KvDtype::Q4, 1.0, l, h, hd), hot);
        assert_eq!(tiered_kv_bytes_per_token(KvDtype::F32, KvDtype::Q4, 0.0, l, h, hd), cold);
        // a half-demoted cache sits strictly between, at the mean
        let half = tiered_kv_bytes_per_token(KvDtype::F32, KvDtype::Q4, 0.5, l, h, hd);
        assert!((half - 0.5 * (hot + cold)).abs() < 1e-12);
        assert!(cold < half && half < hot);
        // equal byte budget ⇒ more retained tokens with a cold tier:
        // tokens = budget / bytes-per-token grows as the factor falls
        let budget = 1e6;
        assert!(budget / half > budget / hot);
    }

    #[test]
    fn plan_bytes_reduce_to_per_token_bytes_when_uniform() {
        // 4 layers × 2 heads, budget 40 per head
        let plan = BudgetPlan::uniform(40);
        let bytes = plan_kv_bytes(&plan, 4, 2, KvDtype::F32, 16);
        assert_eq!(bytes, kv_bytes_per_token(KvDtype::F32, 4, 2, 16) * 40.0);
        // a non-uniform plan with the same total costs the same bytes
        // (conservation on the byte axis)
        let skewed = BudgetPlan::per_head(4, 2, vec![70, 70, 50, 50, 30, 30, 10, 10]);
        assert_eq!(plan_kv_bytes(&skewed, 4, 2, KvDtype::F32, 16), bytes);
        // quantized payloads shrink plan bytes like they shrink tokens
        let q8 = plan_kv_bytes(&plan, 4, 2, KvDtype::Q8, 16);
        assert!(bytes / q8 >= 3.0);
    }

    #[test]
    fn byte_rescale_preserves_frontier_structure() {
        let cloud = vec![pt(1.0, 0.3), pt(2.0, 0.2), pt(2.0, 0.5), pt(3.0, 0.4)];
        let scaled = with_byte_budget(&cloud, 128.0);
        let f_tok = frontier(&cloud);
        let f_byte = frontier(&scaled);
        assert_eq!(f_tok.points.len(), f_byte.points.len());
        for (t, b) in f_tok.points.iter().zip(&f_byte.points) {
            assert_eq!(t.accuracy, b.accuracy);
            assert!((b.budget - t.budget * 128.0).abs() < 1e-9);
        }
    }

    #[test]
    fn quantization_compounds_with_eviction_on_byte_axis() {
        // same token-unit measurements (e.g. a CR8 eviction run), once
        // stored f32 and once q8: on the byte axis the q8 frontier
        // reaches equal accuracy at ≥ 3× smaller budget.
        let cloud = vec![pt(10.0, 0.5), pt(20.0, 0.8)];
        let f = kv_bytes_per_token(KvDtype::F32, 4, 2, 16);
        let q = kv_bytes_per_token(KvDtype::Q8, 4, 2, 16);
        let f32_bytes = frontier(&with_byte_budget(&cloud, f));
        let q8_bytes = frontier(&with_byte_budget(&cloud, q));
        let (q_lo, q_hi) = q8_bytes.budget_range().unwrap();
        let (f_lo, f_hi) = f32_bytes.budget_range().unwrap();
        assert!(f_lo / q_lo >= 3.0 && f_hi / q_hi >= 3.0);
        // peak accuracy is available at ≥3× fewer bytes read
        assert_eq!(q8_bytes.at(q_hi), Some(0.8));
        assert!(f32_bytes.at(q_hi).is_none(), "f32 can't reach that budget");
    }
}
