//! Pareto frontiers over (budget, accuracy) points and the App. E
//! average-margin integral:
//!
//!   margin(A, B) = ∫_{x ∈ I} (A(x) − B(x)) dx / |I|
//!
//! where A(x), B(x) are the piecewise-linear interpolations of the two
//! frontiers and I is the largest budget interval both cover.

/// One measured scaling configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct ScalePoint {
    /// Budget (KV reads or peak tokens — x axis).
    pub budget: f64,
    /// Accuracy in [0, 1] (y axis).
    pub accuracy: f64,
    /// L-W-CR label for annotation.
    pub label: String,
}

/// A Pareto frontier: budget-ascending, accuracy-ascending points.
#[derive(Clone, Debug, Default)]
pub struct Frontier {
    pub points: Vec<ScalePoint>,
}

/// Extract the Pareto frontier (max accuracy for min budget) from a
/// point cloud: a point survives iff no other point has ≤ budget and
/// > accuracy.
pub fn frontier(points: &[ScalePoint]) -> Frontier {
    let mut sorted: Vec<&ScalePoint> = points.iter().collect();
    sorted.sort_by(|a, b| {
        a.budget
            .partial_cmp(&b.budget)
            .unwrap()
            .then(b.accuracy.partial_cmp(&a.accuracy).unwrap())
    });
    let mut out: Vec<ScalePoint> = Vec::new();
    let mut best = f64::NEG_INFINITY;
    for p in sorted {
        // keep weakly-dominated ties: a flat terminal segment extends
        // the frontier's budget range, which the App. E margin integral
        // relies on (accuracy never decreases with more budget).
        if p.accuracy > best {
            best = p.accuracy;
            out.push(p.clone());
        } else if p.accuracy == best
            && out.last().map(|q| p.budget > q.budget).unwrap_or(false)
        {
            out.push(p.clone());
        }
    }
    Frontier { points: out }
}

impl Frontier {
    /// Interpolated accuracy at `budget` (linear between frontier
    /// points; clamped at the ends). None outside the covered range.
    pub fn at(&self, budget: f64) -> Option<f64> {
        let pts = &self.points;
        if pts.is_empty() || budget < pts[0].budget || budget > pts[pts.len() - 1].budget
        {
            return None;
        }
        let mut prev = &pts[0];
        for p in pts.iter().skip(1) {
            if budget <= p.budget {
                let span = p.budget - prev.budget;
                if span <= 0.0 {
                    return Some(p.accuracy.max(prev.accuracy));
                }
                let t = (budget - prev.budget) / span;
                return Some(prev.accuracy + t * (p.accuracy - prev.accuracy));
            }
            prev = p;
        }
        Some(pts[pts.len() - 1].accuracy)
    }

    pub fn budget_range(&self) -> Option<(f64, f64)> {
        if self.points.is_empty() {
            None
        } else {
            Some((
                self.points[0].budget,
                self.points[self.points.len() - 1].budget,
            ))
        }
    }
}

/// App. E average margin of frontier `a` over frontier `b` on their
/// common budget interval (trapezoid integration over the union of
/// both frontiers' knots). None when the projections are disjoint
/// (the paper's "NA" cells).
pub fn margin(a: &Frontier, b: &Frontier) -> Option<f64> {
    let (a_lo, a_hi) = a.budget_range()?;
    let (b_lo, b_hi) = b.budget_range()?;
    let lo = a_lo.max(b_lo);
    let hi = a_hi.min(b_hi);
    if hi <= lo {
        return None;
    }
    // knots: both frontiers' budgets inside [lo, hi] plus the ends
    let mut xs: Vec<f64> = vec![lo, hi];
    for p in a.points.iter().chain(&b.points) {
        if p.budget > lo && p.budget < hi {
            xs.push(p.budget);
        }
    }
    xs.sort_by(|x, y| x.partial_cmp(y).unwrap());
    xs.dedup();
    let mut integral = 0.0;
    for w in xs.windows(2) {
        let (x0, x1) = (w[0], w[1]);
        let d0 = a.at(x0)? - b.at(x0)?;
        let d1 = a.at(x1)? - b.at(x1)?;
        integral += 0.5 * (d0 + d1) * (x1 - x0);
    }
    Some(integral / (hi - lo))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(budget: f64, acc: f64) -> ScalePoint {
        ScalePoint {
            budget,
            accuracy: acc,
            label: String::new(),
        }
    }

    #[test]
    fn frontier_removes_dominated() {
        let cloud = vec![pt(1.0, 0.3), pt(2.0, 0.2), pt(2.0, 0.5), pt(3.0, 0.4)];
        let f = frontier(&cloud);
        // (2.0, 0.2) and (3.0, 0.4) are dominated
        assert_eq!(f.points.len(), 2);
        assert_eq!(f.points[0].accuracy, 0.3);
        assert_eq!(f.points[1].accuracy, 0.5);
    }

    #[test]
    fn interpolation_is_linear() {
        let f = frontier(&[pt(0.0, 0.0), pt(10.0, 1.0)]);
        assert_eq!(f.at(5.0), Some(0.5));
        assert_eq!(f.at(0.0), Some(0.0));
        assert_eq!(f.at(10.0), Some(1.0));
        assert_eq!(f.at(11.0), None);
    }

    #[test]
    fn margin_constant_gap() {
        let a = frontier(&[pt(0.0, 0.6), pt(10.0, 0.8)]);
        let b = frontier(&[pt(0.0, 0.4), pt(10.0, 0.6)]);
        let m = margin(&a, &b).unwrap();
        assert!((m - 0.2).abs() < 1e-12);
    }

    #[test]
    fn margin_on_partial_overlap() {
        let a = frontier(&[pt(5.0, 1.0), pt(20.0, 1.0)]);
        let b = frontier(&[pt(0.0, 0.5), pt(10.0, 0.5)]);
        // common interval [5, 10]; constant gap 0.5
        let m = margin(&a, &b).unwrap();
        assert!((m - 0.5).abs() < 1e-12);
    }

    #[test]
    fn margin_disjoint_is_none() {
        let a = frontier(&[pt(0.0, 1.0), pt(1.0, 1.0)]);
        let b = frontier(&[pt(5.0, 0.5), pt(6.0, 0.5)]);
        assert!(margin(&a, &b).is_none());
    }

    #[test]
    fn margin_can_be_negative() {
        let a = frontier(&[pt(0.0, 0.2), pt(10.0, 0.4)]);
        let b = frontier(&[pt(0.0, 0.5), pt(10.0, 0.7)]);
        assert!(margin(&a, &b).unwrap() < 0.0);
    }
}
