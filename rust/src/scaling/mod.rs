//! Inference-time scaling machinery: the L-W-CR budget controller and
//! Pareto-frontier analysis (paper §5.1, App. E).

pub mod pareto;

pub use pareto::{
    frontier, kv_bytes_per_token, margin, plan_kv_bytes, with_byte_budget, Frontier,
    ScalePoint,
};
