//! Inference-time scaling machinery: the L-W-CR budget controller and
//! Pareto-frontier analysis (paper §5.1, App. E).

pub mod pareto;

pub use pareto::{frontier, margin, Frontier, ScalePoint};
