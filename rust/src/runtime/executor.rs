//! Executable wrappers: literal plumbing for decode and prefill steps.
//!
//! PJRT 0.5.1's CPU client returns the executable's root tuple as a
//! single tuple buffer (no untupling), so each call copies the output
//! tuple to host once and decomposes it. Inputs are host literals; the
//! parameter literals are built once (`Weights::literals`) and borrowed
//! on every call, and the cache arrays are uploaded from the
//! `CacheStore`'s flat layout without reshuffling.
//!
//! ## Dequant-on-upload
//!
//! The k/v/mask/pmin/pmax slices these wrappers upload are the store's
//! **dequantized lane views**: with a quantized `kv_dtype`, pool-owned
//! page payloads are decoded into the lanes' f32 regions by
//! `CacheStore::materialize_pending` (which the engine runs right
//! before each executor call), and the upload itself is always plain
//! f32 — the compiled executables are precision-agnostic and never
//! recompile when the storage format changes. The decode cost is
//! accounted in `CacheStore::dequant_us` (`kv.dequant_us` gauge),
//! kept separate from snapshot-buffer acquisition on the publish side
//! (`CacheStore::alloc_us`, the `kv.alloc_us` gauge) so codec cost
//! and allocator churn never conflate; the upload *volume* is
//! [`cache_upload_bytes`]. See `docs/NUMERICS.md` for the full
//! contract.

use std::rc::Rc;

use anyhow::{anyhow, bail, Result};

use super::manifest::ExeMeta;

/// Bytes of cache state one decode step uploads to the device: the
/// dequantized f32 lane views of K, V, the additive mask, and the
/// Quest page bounds. Upload volume is fixed by the executor ABI —
/// quantization shrinks *host pool* bytes (`kv.bytes_per_token`), not
/// this per-step figure.
///
/// ```
/// use hyperscale::kvcache::Geometry;
/// use hyperscale::runtime::cache_upload_bytes;
///
/// let g = Geometry { layers: 2, kv_heads: 2, slots: 32, head_dim: 4, page_size: 8 };
/// // k + v: 2·(L·B·H·S·hd), mask: L·B·H·S, bounds: 2·(L·B·H·P·hd)
/// let elems = 2 * 2 * 3 * 2 * 32 * 4 + 2 * 3 * 2 * 32 + 2 * 2 * 3 * 2 * 4 * 4;
/// assert_eq!(cache_upload_bytes(&g, 3), elems * 4);
/// ```
pub fn cache_upload_bytes(geom: &crate::kvcache::Geometry, batch: usize) -> usize {
    let kv = 2 * geom.layers * batch * geom.kv_heads * geom.slots * geom.head_dim;
    let mask = geom.layers * batch * geom.kv_heads * geom.slots;
    let bounds = 2 * geom.layers * batch * geom.kv_heads * geom.pages() * geom.head_dim;
    (kv + mask + bounds) * 4
}

/// Decode-step outputs (flat host vectors, layouts in comments).
pub struct DecodeOutputs {
    /// f32[B, V]
    pub logits: Vec<f32>,
    /// f32[L, B, H, hd]
    pub k_new: Vec<f32>,
    /// f32[L, B, H, hd]
    pub v_new: Vec<f32>,
    /// f32[L, B, H]
    pub alpha: Vec<f32>,
    /// f32[L, B, H, S]
    pub attn: Vec<f32>,
    /// f32[L, B, H]
    pub attn_self: Vec<f32>,
    /// f32[L, B, H, P]
    pub qsel: Vec<f32>,
}

/// Prefill-chunk outputs.
pub struct PrefillOutputs {
    /// f32[B, C, V]
    pub logits: Vec<f32>,
    /// f32[L, B, H, C, hd]
    pub k_new: Vec<f32>,
    /// f32[L, B, H, C, hd]
    pub v_new: Vec<f32>,
    /// f32[L, B, H, C]
    pub alpha: Vec<f32>,
}

/// A compiled executable plus its export-time metadata.
pub struct Executor {
    exe: Rc<xla::PjRtLoadedExecutable>,
    pub meta: ExeMeta,
}

/// Typed input ordering for the buffered path.
#[derive(Clone, Copy)]
enum InputSlot {
    F32(usize),
    I32(usize),
}

fn lit_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    debug_assert_eq!(n, data.len());
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, dims, bytes)
        .map_err(|e| anyhow!("literal f32 {dims:?}: {e:?}"))
}

fn lit_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, dims, bytes)
        .map_err(|e| anyhow!("literal i32 {dims:?}: {e:?}"))
}

/// Parameter set resident on device (uploaded once per variant; the
/// §Perf-pass optimization that removes ~2.3 MB of per-step uploads).
pub struct ParamBuffers {
    pub buffers: Vec<xla::PjRtBuffer>,
}

impl ParamBuffers {
    pub fn from_weights(
        client: &xla::PjRtClient,
        weights: &crate::runtime::Weights,
    ) -> Result<Self> {
        let mut buffers = Vec::new();
        for lit in weights.literals() {
            buffers.push(
                client
                    .buffer_from_host_literal(None, lit)
                    .map_err(|e| anyhow!("param upload: {e:?}"))?,
            );
        }
        Ok(Self { buffers })
    }
}

impl Executor {
    pub fn new(exe: Rc<xla::PjRtLoadedExecutable>, meta: ExeMeta) -> Self {
        Self { exe, meta }
    }

    fn client(&self) -> &xla::PjRtClient {
        self.exe.client()
    }

    fn run(&self, params: &[xla::Literal], inputs: Vec<xla::Literal>) -> Result<Vec<xla::Literal>> {
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(params.len() + inputs.len());
        args.extend(params.iter());
        args.extend(inputs.iter());
        let outs = self
            .exe
            .execute::<&xla::Literal>(&args)
            .map_err(|e| anyhow!("execute: {e:?}"))?;
        let lit = outs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        let parts = lit.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
        Ok(parts)
    }

    /// Buffered execution: device-resident params + direct slice→device
    /// uploads for the per-step inputs (no intermediate Literal).
    fn run_buffered(
        &self,
        params: &ParamBuffers,
        f32_inputs: &[(&[f32], &[usize])],
        i32_inputs: &[(&[i32], &[usize])],
        order: &[InputSlot],
    ) -> Result<Vec<xla::Literal>> {
        let client = self.client().clone();
        let mut step_bufs: Vec<xla::PjRtBuffer> = Vec::with_capacity(order.len());
        for slot in order {
            let buf = match *slot {
                InputSlot::F32(i) => {
                    let (data, dims) = f32_inputs[i];
                    client
                        .buffer_from_host_buffer::<f32>(data, dims, None)
                        .map_err(|e| anyhow!("f32 upload {dims:?}: {e:?}"))?
                }
                InputSlot::I32(i) => {
                    let (data, dims) = i32_inputs[i];
                    client
                        .buffer_from_host_buffer::<i32>(data, dims, None)
                        .map_err(|e| anyhow!("i32 upload {dims:?}: {e:?}"))?
                }
            };
            step_bufs.push(buf);
        }
        let mut args: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity(params.buffers.len() + step_bufs.len());
        args.extend(params.buffers.iter());
        args.extend(step_bufs.iter());
        let outs = self
            .exe
            .execute_b::<&xla::PjRtBuffer>(&args)
            .map_err(|e| anyhow!("execute_b: {e:?}"))?;
        let lit = outs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))
    }

    /// One decode step. Slice lengths must match the executable's
    /// geometry (L·B·H·S·hd etc.); `quest_k ≥ pages` disables Quest.
    #[allow(clippy::too_many_arguments)]
    pub fn decode(
        &self,
        params: &[xla::Literal],
        k_cache: &[f32],
        v_cache: &[f32],
        tokens: &[i32],
        positions: &[i32],
        mask: &[f32],
        pmin: &[f32],
        pmax: &[f32],
        quest_k: i32,
        geom: &crate::kvcache::Geometry,
    ) -> Result<DecodeOutputs> {
        let (l, h, s, hd, p) = (
            geom.layers,
            geom.kv_heads,
            geom.slots,
            geom.head_dim,
            geom.pages(),
        );
        let b = self.meta.batch;
        if self.meta.kind != "decode" {
            bail!("not a decode executable");
        }
        let inputs = vec![
            lit_f32(k_cache, &[l, b, h, s, hd])?,
            lit_f32(v_cache, &[l, b, h, s, hd])?,
            lit_i32(tokens, &[b])?,
            lit_i32(positions, &[b])?,
            lit_f32(mask, &[l, b, h, s])?,
            lit_f32(pmin, &[l, b, h, p, hd])?,
            lit_f32(pmax, &[l, b, h, p, hd])?,
            xla::Literal::scalar(quest_k),
        ];
        let parts = self.run(params, inputs)?;
        if parts.len() != 7 {
            bail!("decode returned {} outputs, expected 7", parts.len());
        }
        let mut it = parts.into_iter();
        let take = |l: xla::Literal| -> Result<Vec<f32>> {
            l.to_vec::<f32>().map_err(|e| anyhow!("output: {e:?}"))
        };
        Ok(DecodeOutputs {
            logits: take(it.next().unwrap())?,
            k_new: take(it.next().unwrap())?,
            v_new: take(it.next().unwrap())?,
            alpha: take(it.next().unwrap())?,
            attn: take(it.next().unwrap())?,
            attn_self: take(it.next().unwrap())?,
            qsel: take(it.next().unwrap())?,
        })
    }

    /// Buffered variant of [`Executor::decode`] (see `run_buffered`).
    #[allow(clippy::too_many_arguments)]
    pub fn decode_buffered(
        &self,
        params: &ParamBuffers,
        k_cache: &[f32],
        v_cache: &[f32],
        tokens: &[i32],
        positions: &[i32],
        mask: &[f32],
        pmin: &[f32],
        pmax: &[f32],
        quest_k: i32,
        geom: &crate::kvcache::Geometry,
    ) -> Result<DecodeOutputs> {
        let (l, h, s, hd, p) = (
            geom.layers,
            geom.kv_heads,
            geom.slots,
            geom.head_dim,
            geom.pages(),
        );
        let b = self.meta.batch;
        if self.meta.kind != "decode" {
            bail!("not a decode executable");
        }
        let kv_dims = [l, b, h, s, hd];
        let mask_dims = [l, b, h, s];
        let pg_dims = [l, b, h, p, hd];
        let b_dims = [b];
        let scalar: [usize; 0] = [];
        let qk = [quest_k];
        let f32_inputs: [(&[f32], &[usize]); 5] = [
            (k_cache, &kv_dims),
            (v_cache, &kv_dims),
            (mask, &mask_dims),
            (pmin, &pg_dims),
            (pmax, &pg_dims),
        ];
        let i32_inputs: [(&[i32], &[usize]); 3] =
            [(tokens, &b_dims), (positions, &b_dims), (&qk, &scalar)];
        let order = [
            InputSlot::F32(0),
            InputSlot::F32(1),
            InputSlot::I32(0),
            InputSlot::I32(1),
            InputSlot::F32(2),
            InputSlot::F32(3),
            InputSlot::F32(4),
            InputSlot::I32(2),
        ];
        let parts = self.run_buffered(params, &f32_inputs, &i32_inputs, &order)?;
        if parts.len() != 7 {
            bail!("decode returned {} outputs, expected 7", parts.len());
        }
        let mut it = parts.into_iter();
        let take = |l: xla::Literal| -> Result<Vec<f32>> {
            l.to_vec::<f32>().map_err(|e| anyhow!("output: {e:?}"))
        };
        Ok(DecodeOutputs {
            logits: take(it.next().unwrap())?,
            k_new: take(it.next().unwrap())?,
            v_new: take(it.next().unwrap())?,
            alpha: take(it.next().unwrap())?,
            attn: take(it.next().unwrap())?,
            attn_self: take(it.next().unwrap())?,
            qsel: take(it.next().unwrap())?,
        })
    }

    /// One prefill chunk (C tokens per lane; pad with valid = 0).
    #[allow(clippy::too_many_arguments)]
    pub fn prefill(
        &self,
        params: &[xla::Literal],
        k_cache: &[f32],
        v_cache: &[f32],
        cache_mask: &[f32],
        tokens: &[i32],
        positions: &[i32],
        valid: &[f32],
        geom: &crate::kvcache::Geometry,
    ) -> Result<PrefillOutputs> {
        let (l, h, s, hd) = (geom.layers, geom.kv_heads, geom.slots, geom.head_dim);
        let b = self.meta.batch;
        let c = self.meta.chunk;
        if self.meta.kind != "prefill" {
            bail!("not a prefill executable");
        }
        let inputs = vec![
            lit_f32(k_cache, &[l, b, h, s, hd])?,
            lit_f32(v_cache, &[l, b, h, s, hd])?,
            lit_f32(cache_mask, &[l, b, h, s])?,
            lit_i32(tokens, &[b, c])?,
            lit_i32(positions, &[b, c])?,
            lit_f32(valid, &[b, c])?,
        ];
        let parts = self.run(params, inputs)?;
        if parts.len() != 4 {
            bail!("prefill returned {} outputs, expected 4", parts.len());
        }
        let mut it = parts.into_iter();
        let take = |l: xla::Literal| -> Result<Vec<f32>> {
            l.to_vec::<f32>().map_err(|e| anyhow!("output: {e:?}"))
        };
        Ok(PrefillOutputs {
            logits: take(it.next().unwrap())?,
            k_new: take(it.next().unwrap())?,
            v_new: take(it.next().unwrap())?,
            alpha: take(it.next().unwrap())?,
        })
    }
}
