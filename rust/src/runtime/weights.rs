//! `.bin` weight checkpoints (format defined in `aot.py::save_bin`):
//! `[u32 header_len][JSON header][raw little-endian f32 payload]`.

use std::path::Path;

use anyhow::{anyhow, bail, Result};

use crate::util::Json;

/// One loaded tensor.
#[derive(Debug)]
pub struct Tensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

/// The full parameter set of a model variant, with `Literal`s prepared
/// in `param_order` for direct use as leading executable inputs.
pub struct Weights {
    pub tensors: Vec<Tensor>,
    literals: Vec<xla::Literal>,
}

impl Weights {
    pub fn load(path: &Path, param_order: &[String]) -> Result<Self> {
        let bytes = std::fs::read(path)
            .map_err(|e| anyhow!("read {}: {e}", path.display()))?;
        if bytes.len() < 4 {
            bail!("weight file too short");
        }
        let header_len = u32::from_le_bytes(bytes[0..4].try_into().unwrap()) as usize;
        let header_end = 4 + header_len;
        if bytes.len() < header_end {
            bail!("weight header truncated");
        }
        let header = Json::parse(std::str::from_utf8(&bytes[4..header_end])?)?;
        let payload = &bytes[header_end..];

        let mut tensors = Vec::new();
        for t in header
            .req("tensors")?
            .as_arr()
            .ok_or_else(|| anyhow!("tensors must be an array"))?
        {
            let name = t.req("name")?.as_str().unwrap_or("").to_string();
            let shape: Vec<usize> = t
                .req("shape")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(Json::as_usize)
                .collect();
            let offset = t.req("offset")?.as_usize().unwrap_or(0);
            let n: usize = shape.iter().product::<usize>().max(1);
            let end = offset + n * 4;
            if end > payload.len() {
                bail!("tensor '{name}' exceeds payload");
            }
            let mut data = vec![0f32; n];
            for (i, chunk) in payload[offset..end].chunks_exact(4).enumerate() {
                data[i] = f32::from_le_bytes(chunk.try_into().unwrap());
            }
            tensors.push(Tensor { name, shape, data });
        }

        // order tensors per param_order and build literals once
        let mut ordered = Vec::with_capacity(param_order.len());
        for name in param_order {
            let idx = tensors
                .iter()
                .position(|t| &t.name == name)
                .ok_or_else(|| anyhow!("missing parameter '{name}'"))?;
            ordered.push(idx);
        }
        let mut literals = Vec::with_capacity(ordered.len());
        for &idx in &ordered {
            let t = &tensors[idx];
            let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(&t.data)
                .reshape(&dims)
                .map_err(|e| anyhow!("reshape {}: {e:?}", t.name))?;
            literals.push(lit);
        }
        Ok(Self { tensors, literals })
    }

    /// Parameter literals in executable-input order.
    pub fn literals(&self) -> &[xla::Literal] {
        &self.literals
    }

    pub fn tensor(&self, name: &str) -> Option<&Tensor> {
        self.tensors.iter().find(|t| t.name == name)
    }

    pub fn total_params(&self) -> usize {
        self.tensors
            .iter()
            .map(|t| t.shape.iter().product::<usize>())
            .sum()
    }
}
