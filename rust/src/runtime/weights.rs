//! `.bin` weight checkpoints (format defined in `aot.py::save_bin`):
//! `[u32 header_len][JSON header][raw little-endian payload]`.
//!
//! Tensor payloads are f32 by default; a header entry may also declare
//! `"dtype": "q8"` / `"dtype": "q4"` with per-tensor `"scale"` and
//! `"zero_point"` metadata (the checkpoint-level sibling of the KV
//! cache's per-row page quantization — see `docs/NUMERICS.md`). The
//! loader **dequantizes on load**: whatever the storage format, the
//! parameter `Literal`s handed to the executor are f32, so the
//! executable ABI never changes and quantization stays a pure storage
//! concern:
//!
//! ```text
//! x = scale · (q − zero_point)     q8: one byte/element
//!                                  q4: nibble-packed, low nibble first
//! ```
//!
//! Parsing is split from literal construction (`parse_tensors`) so the
//! byte format — including the quantized paths — is unit-testable
//! without a PJRT client.

use std::path::Path;

use anyhow::{anyhow, bail, Result};

use crate::kvcache::quant::{dequant_code, unpack_q4};
use crate::util::Json;

/// One loaded tensor (always f32 on the host, whatever the storage).
#[derive(Debug)]
pub struct Tensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

/// Decode the `[u32 header_len][JSON header][payload]` container into
/// host-f32 tensors, dequantizing q8/q4 entries on the fly.
pub fn parse_tensors(bytes: &[u8]) -> Result<Vec<Tensor>> {
    if bytes.len() < 4 {
        bail!("weight file too short");
    }
    let header_len = u32::from_le_bytes(bytes[0..4].try_into().unwrap()) as usize;
    let header_end = 4 + header_len;
    if bytes.len() < header_end {
        bail!("weight header truncated");
    }
    let header = Json::parse(std::str::from_utf8(&bytes[4..header_end])?)?;
    let payload = &bytes[header_end..];

    let mut tensors = Vec::new();
    for t in header
        .req("tensors")?
        .as_arr()
        .ok_or_else(|| anyhow!("tensors must be an array"))?
    {
        let name = t.req("name")?.as_str().unwrap_or("").to_string();
        let shape: Vec<usize> = t
            .req("shape")?
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .filter_map(Json::as_usize)
            .collect();
        let offset = t.req("offset")?.as_usize().unwrap_or(0);
        let n: usize = shape.iter().product::<usize>().max(1);
        let dtype = t.get("dtype").and_then(Json::as_str).unwrap_or("f32");
        let data = match dtype {
            "f32" => {
                let end = offset + n * 4;
                if end > payload.len() {
                    bail!("tensor '{name}' exceeds payload");
                }
                let mut data = vec![0f32; n];
                for (i, chunk) in payload[offset..end].chunks_exact(4).enumerate() {
                    data[i] = f32::from_le_bytes(chunk.try_into().unwrap());
                }
                data
            }
            "q8" | "q4" => {
                let Some(scale) = t.req("scale")?.as_f64() else {
                    bail!("tensor '{name}': scale must be a number");
                };
                let scale = scale as f32;
                let zp = t.get("zero_point").and_then(|x| x.as_f64()).unwrap_or(0.0) as f32;
                let nbytes = if dtype == "q8" { n } else { n.div_ceil(2) };
                let end = offset + nbytes;
                if end > payload.len() {
                    bail!("tensor '{name}' exceeds payload");
                }
                let codes = &payload[offset..end];
                let mut data = vec![0f32; n];
                for (i, x) in data.iter_mut().enumerate() {
                    let q = if dtype == "q8" {
                        codes[i]
                    } else {
                        unpack_q4(codes, i)
                    };
                    *x = dequant_code(q, scale, zp);
                }
                data
            }
            other => bail!("tensor '{name}': unknown dtype '{other}'"),
        };
        tensors.push(Tensor { name, shape, data });
    }
    Ok(tensors)
}

/// The full parameter set of a model variant, with `Literal`s prepared
/// in `param_order` for direct use as leading executable inputs.
pub struct Weights {
    pub tensors: Vec<Tensor>,
    literals: Vec<xla::Literal>,
}

impl Weights {
    pub fn load(path: &Path, param_order: &[String]) -> Result<Self> {
        let bytes = std::fs::read(path)
            .map_err(|e| anyhow!("read {}: {e}", path.display()))?;
        let tensors = parse_tensors(&bytes)?;

        // order tensors per param_order and build f32 literals once
        // (dequantized host data — the executor ABI stays f32)
        let mut ordered = Vec::with_capacity(param_order.len());
        for name in param_order {
            let idx = tensors
                .iter()
                .position(|t| &t.name == name)
                .ok_or_else(|| anyhow!("missing parameter '{name}'"))?;
            ordered.push(idx);
        }
        let mut literals = Vec::with_capacity(ordered.len());
        for &idx in &ordered {
            let t = &tensors[idx];
            let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(&t.data)
                .reshape(&dims)
                .map_err(|e| anyhow!("reshape {}: {e:?}", t.name))?;
            literals.push(lit);
        }
        Ok(Self { tensors, literals })
    }

    /// Parameter literals in executable-input order.
    pub fn literals(&self) -> &[xla::Literal] {
        &self.literals
    }

    pub fn tensor(&self, name: &str) -> Option<&Tensor> {
        self.tensors.iter().find(|t| t.name == name)
    }

    pub fn total_params(&self) -> usize {
        self.tensors
            .iter()
            .map(|t| t.shape.iter().product::<usize>())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a `.bin` container from a header string and payload.
    fn container(header: &str, payload: &[u8]) -> Vec<u8> {
        let mut out = (header.len() as u32).to_le_bytes().to_vec();
        out.extend_from_slice(header.as_bytes());
        out.extend_from_slice(payload);
        out
    }

    #[test]
    fn parses_f32_tensors() {
        let header = r#"{"tensors": [
            {"name": "w", "shape": [2, 2], "offset": 0}
        ]}"#;
        let payload: Vec<u8> = [1.0f32, -2.5, 0.0, 4.25]
            .iter()
            .flat_map(|x| x.to_le_bytes())
            .collect();
        let ts = parse_tensors(&container(header, &payload)).unwrap();
        assert_eq!(ts.len(), 1);
        assert_eq!(ts[0].shape, vec![2, 2]);
        assert_eq!(ts[0].data, vec![1.0, -2.5, 0.0, 4.25]);
    }

    #[test]
    fn dequantizes_q8_tensor_on_load() {
        // scale 0.5, zp 2: codes [0, 2, 5, 255] → [-1.0, 0.0, 1.5, 126.5]
        let header = r#"{"tensors": [
            {"name": "w", "shape": [4], "offset": 0,
             "dtype": "q8", "scale": 0.5, "zero_point": 2}
        ]}"#;
        let ts = parse_tensors(&container(header, &[0u8, 2, 5, 255])).unwrap();
        assert_eq!(ts[0].data, vec![-1.0, 0.0, 1.5, 126.5]);
    }

    #[test]
    fn dequantizes_q4_tensor_nibble_packed() {
        // 5 elements (odd), scale 2.0, zp 0: codes 1,2,3,4,15 pack into
        // bytes [0x21, 0x43, 0x0F] (low nibble first)
        let header = r#"{"tensors": [
            {"name": "w", "shape": [5], "offset": 0,
             "dtype": "q4", "scale": 2.0}
        ]}"#;
        let ts = parse_tensors(&container(header, &[0x21, 0x43, 0x0F])).unwrap();
        assert_eq!(ts[0].data, vec![2.0, 4.0, 6.0, 8.0, 30.0]);
    }

    #[test]
    fn mixed_precision_checkpoint_shares_one_payload() {
        let mut payload: Vec<u8> = 3.0f32.to_le_bytes().to_vec();
        payload.extend_from_slice(&[10u8, 20]); // q8 tensor at offset 4
        let header = r#"{"tensors": [
            {"name": "a", "shape": [1], "offset": 0},
            {"name": "b", "shape": [2], "offset": 4,
             "dtype": "q8", "scale": 0.1, "zero_point": 10}
        ]}"#;
        let ts = parse_tensors(&container(header, &payload)).unwrap();
        assert_eq!(ts[0].data, vec![3.0]);
        assert!((ts[1].data[0] - 0.0).abs() < 1e-6);
        assert!((ts[1].data[1] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn truncated_and_unknown_dtypes_error() {
        let header = r#"{"tensors": [
            {"name": "w", "shape": [8], "offset": 0, "dtype": "q8", "scale": 1.0}
        ]}"#;
        assert!(parse_tensors(&container(header, &[0u8; 4])).is_err());
        let header = r#"{"tensors": [
            {"name": "w", "shape": [1], "offset": 0, "dtype": "bf16"}
        ]}"#;
        assert!(parse_tensors(&container(header, &[0u8; 4])).is_err());
        assert!(parse_tensors(&[0u8, 0]).is_err());
    }
}
