//! `artifacts/manifest.json` schema (written by `python/compile/aot.py`).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Result};

use crate::util::Json;

/// Model geometry recorded at export time.
#[derive(Clone, Copy, Debug)]
pub struct ModelConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_q_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub max_pos: usize,
    pub page_size: usize,
}

/// One exported executable.
#[derive(Clone, Debug)]
pub struct ExeMeta {
    pub kind: String, // "decode" | "prefill"
    pub file: String,
    pub batch: usize,
    pub slots: usize,
    pub pages: usize,         // decode only
    pub chunk: usize,         // prefill only
    pub pallas: bool,
    pub window: Option<usize>, // prefill: baked DMS window
    pub immediate: Option<bool>,
    pub dms: Option<bool>,
}

/// One model variant (weights + retrofit metadata).
#[derive(Clone, Debug)]
pub struct VariantMeta {
    pub weights: String,
    pub alpha_mode: String,
    pub window: usize,
    pub immediate: bool,
}

#[derive(Debug)]
pub struct Manifest {
    pub config: ModelConfig,
    pub param_order: Vec<String>,
    pub vocab: Vec<String>,
    pub pad_id: u32,
    pub bos_id: u32,
    pub eos_id: u32,
    pub variants: BTreeMap<String, VariantMeta>,
    pub executables: BTreeMap<String, ExeMeta>,
}

fn req_usize(j: &Json, key: &str) -> Result<usize> {
    j.req(key)?
        .as_usize()
        .ok_or_else(|| anyhow!("'{key}' must be a number"))
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Self> {
        let j = Json::parse_file(path)?;
        let c = j.req("config")?;
        let config = ModelConfig {
            vocab: req_usize(c, "vocab")?,
            d_model: req_usize(c, "d_model")?,
            n_layers: req_usize(c, "n_layers")?,
            n_q_heads: req_usize(c, "n_q_heads")?,
            n_kv_heads: req_usize(c, "n_kv_heads")?,
            head_dim: req_usize(c, "head_dim")?,
            d_ff: req_usize(c, "d_ff")?,
            max_pos: req_usize(c, "max_pos")?,
            page_size: req_usize(c, "page_size")?,
        };
        let param_order = j
            .req("param_order")?
            .as_arr()
            .ok_or_else(|| anyhow!("param_order must be an array"))?
            .iter()
            .map(|v| v.as_str().unwrap_or("").to_string())
            .collect();
        let vocab: Vec<String> = j
            .req("vocab")?
            .as_arr()
            .ok_or_else(|| anyhow!("vocab must be an array"))?
            .iter()
            .map(|v| v.as_str().unwrap_or("").to_string())
            .collect();
        let specials = j.req("specials")?;
        let mut variants = BTreeMap::new();
        for (name, v) in j.req("variants")?.as_obj().unwrap_or(&[]) {
            variants.insert(
                name.clone(),
                VariantMeta {
                    weights: v.req("weights")?.as_str().unwrap_or("").to_string(),
                    alpha_mode: v
                        .req("alpha_mode")?
                        .as_str()
                        .unwrap_or("off")
                        .to_string(),
                    window: req_usize(v, "window")?,
                    immediate: v
                        .get("immediate")
                        .and_then(Json::as_bool)
                        .unwrap_or(false),
                },
            );
        }
        let mut executables = BTreeMap::new();
        for (name, e) in j.req("executables")?.as_obj().unwrap_or(&[]) {
            executables.insert(
                name.clone(),
                ExeMeta {
                    kind: e.req("kind")?.as_str().unwrap_or("").to_string(),
                    file: e.req("file")?.as_str().unwrap_or("").to_string(),
                    batch: req_usize(e, "batch")?,
                    slots: e.get("slots").and_then(Json::as_usize).unwrap_or(0),
                    pages: e.get("pages").and_then(Json::as_usize).unwrap_or(0),
                    chunk: e.get("chunk").and_then(Json::as_usize).unwrap_or(0),
                    pallas: e.get("pallas").and_then(Json::as_bool).unwrap_or(true),
                    window: e.get("window").and_then(Json::as_usize),
                    immediate: e.get("immediate").and_then(Json::as_bool),
                    dms: e.get("dms").and_then(Json::as_bool),
                },
            );
        }
        Ok(Self {
            config,
            param_order,
            vocab,
            pad_id: req_usize(specials, "pad")? as u32,
            bos_id: req_usize(specials, "bos")? as u32,
            eos_id: req_usize(specials, "eos")? as u32,
            variants,
            executables,
        })
    }

    pub fn cache_geometry(&self, slots: usize) -> crate::kvcache::Geometry {
        crate::kvcache::Geometry {
            layers: self.config.n_layers,
            kv_heads: self.config.n_kv_heads,
            slots,
            head_dim: self.config.head_dim,
            page_size: self.config.page_size,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn sample_manifest() -> String {
        r#"{
          "config": {"vocab":64,"d_model":128,"n_layers":4,"n_q_heads":8,
                     "n_kv_heads":2,"head_dim":16,"d_ff":256,"max_pos":512,
                     "rope_base":10000.0,"page_size":16},
          "param_order": ["embed","ln_f","lm_head"],
          "vocab": ["<pad>","<bos>","<eos>","0"],
          "specials": {"pad":0,"bos":1,"eos":2},
          "variants": {"base":{"weights":"weights_base.bin",
                       "alpha_mode":"off","window":16,"immediate":false}},
          "executables": {"decode_b8_s320":{"kind":"decode","batch":8,
                          "slots":320,"pages":20,"pallas":true,
                          "file":"decode_b8_s320.hlo.txt"}}
        }"#
        .to_string()
    }

    #[test]
    fn parses_sample() {
        let dir = std::env::temp_dir().join("hs_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("manifest.json");
        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(sample_manifest().as_bytes()).unwrap();
        let m = Manifest::load(&path).unwrap();
        assert_eq!(m.config.n_layers, 4);
        assert_eq!(m.config.page_size, 16);
        assert_eq!(m.pad_id, 0);
        assert_eq!(m.variants["base"].alpha_mode, "off");
        assert_eq!(m.executables["decode_b8_s320"].slots, 320);
        let g = m.cache_geometry(320);
        assert_eq!(g.pages(), 20);
    }
}
