//! PJRT runtime: artifact manifest, weight loading, executable wrappers.
//!
//! The interchange format is HLO **text** (see DESIGN.md §5 and
//! `python/compile/aot.py`): `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `PjRtClient::compile`. Weights are
//! executable *inputs*: loaded once from `weights_<variant>.bin` into
//! `Literal`s and passed by reference on every call.
//!
//! Precision boundary: the executor ABI is f32 end to end. Quantized
//! storage — q8/q4 checkpoint tensors ([`parse_tensors`]) and
//! q8/q4 KV page payloads (`kvcache::quant`) — is dequantized to f32
//! *before* anything reaches a `Literal` or device buffer, so
//! compiled HLO never changes with the storage format. See
//! `docs/NUMERICS.md`.

mod executor;
mod manifest;
mod weights;

pub use executor::{cache_upload_bytes, DecodeOutputs, Executor, ParamBuffers, PrefillOutputs};
pub use manifest::{ExeMeta, Manifest, ModelConfig, VariantMeta};
pub use weights::{parse_tensors, Tensor, Weights};

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{anyhow, Context, Result};

/// Handle to the PJRT client plus the artifact set.
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    artifacts: PathBuf,
    compiled: std::cell::RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    weights: std::cell::RefCell<HashMap<String, Rc<Weights>>>,
}

impl Runtime {
    /// Create a CPU PJRT client and load the manifest.
    pub fn open(artifacts: &Path) -> Result<Self> {
        let manifest = Manifest::load(&artifacts.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", artifacts.display()))?;
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Self {
            client,
            manifest,
            artifacts: artifacts.to_path_buf(),
            compiled: Default::default(),
            weights: Default::default(),
        })
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts
    }

    /// Compile (and cache) an executable by manifest name.
    pub fn load_executable(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.compiled.borrow().get(name) {
            return Ok(exe.clone());
        }
        let meta = self
            .manifest
            .executables
            .get(name)
            .ok_or_else(|| anyhow!("executable '{name}' not in manifest"))?;
        let path = self.artifacts.join("hlo").join(&meta.file);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        crate::debug!("compiled {name} in {:.2}s", t0.elapsed().as_secs_f64());
        let exe = Rc::new(exe);
        self.compiled
            .borrow_mut()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Load (and cache) the weights for a model variant, ordered per the
    /// manifest's `param_order`.
    pub fn load_weights(&self, variant: &str) -> Result<Rc<Weights>> {
        if let Some(w) = self.weights.borrow().get(variant) {
            return Ok(w.clone());
        }
        let vmeta = self
            .manifest
            .variants
            .get(variant)
            .ok_or_else(|| anyhow!("variant '{variant}' not in manifest"))?;
        let path = self.artifacts.join(&vmeta.weights);
        let w = Weights::load(&path, &self.manifest.param_order)?;
        let w = Rc::new(w);
        self.weights
            .borrow_mut()
            .insert(variant.to_string(), w.clone());
        Ok(w)
    }

    /// Pick the decode executable name for (batch, slots, pallas/jnp).
    pub fn decode_exe_name(&self, batch: usize, slots: usize, jnp: bool) -> Result<String> {
        let want_pallas = !jnp;
        for (name, meta) in &self.manifest.executables {
            if meta.kind == "decode"
                && meta.batch == batch
                && meta.slots == slots
                && meta.pallas == want_pallas
            {
                return Ok(name.clone());
            }
        }
        Err(anyhow!(
            "no decode executable for batch={batch} slots={slots} jnp={jnp}"
        ))
    }

    /// Pick the prefill executable for a variant's DMS flavour.
    pub fn prefill_exe_name(
        &self,
        batch: usize,
        slots: usize,
        window: usize,
        immediate: bool,
        dms: bool,
    ) -> Result<String> {
        for (name, meta) in &self.manifest.executables {
            if meta.kind == "prefill"
                && meta.batch == batch
                && meta.slots == slots
                && meta.dms == Some(dms)
                && (!dms
                    || (meta.window == Some(window) && meta.immediate == Some(immediate)))
            {
                return Ok(name.clone());
            }
        }
        Err(anyhow!(
            "no prefill executable for batch={batch} slots={slots} window={window} \
             immediate={immediate} dms={dms}"
        ))
    }
}
